"""Roofline analysis (deliverable g): three terms per (arch × shape × mesh)
from the dry-run's compiled artifacts.

  compute term    = HLO_FLOPs(per-device) / peak_FLOP/s
  memory term     = HLO_bytes(per-device) / HBM_bw
  collective term = collective_bytes(per-device) / link_bw

v5e constants: 197 TFLOP/s bf16/chip, 819 GB/s HBM, ~50 GB/s/link ICI.
cost_analysis() reports the per-device (post-SPMD) module, so no extra chip
division is applied.  MODEL_FLOPS uses 6·N_active·D (§Roofline) divided by
chip count for the per-device comparison.

Usage: PYTHONPATH=src python -m benchmarks.roofline [dryrun_results.json]
"""
from __future__ import annotations

import json
import sys
from typing import Dict, List

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9


def analyze(results: List[Dict], corrected: Dict = None) -> List[Dict]:
    from repro.configs import get_config
    from repro.launch.shapes import SHAPES
    from repro.models.analysis import model_flops

    corrected = corrected or {}
    rows = []
    for r in results:
        if r.get("status") != "ok":
            rows.append({"arch": r["arch"], "shape": r["shape"],
                         "mesh": r.get("mesh", "?"),
                         "status": r.get("status"),
                         "reason": r.get("reason", r.get("error", ""))[:90]})
            continue
        chips = 512 if r["mesh"] == "2x16x16" else 256
        cfg = get_config(r["arch"])
        shp = SHAPES[r["shape"]]
        # prefer loop-corrected costs (XLA cost_analysis counts while bodies
        # once — see benchmarks/extrapolate_costs.py)
        corr = r.get("corrected") or corrected.get(
            (r["arch"], r["shape"], r["mesh"]))
        if corr and "flops" in corr:
            flops, byts, coll = (corr["flops"], corr["bytes_accessed"],
                                 corr["collective_bytes"])
        else:
            flops, byts, coll = (r["cost"]["flops"], r["cost"]["bytes_accessed"],
                                 r["collectives"]["total_bytes"])
        r = dict(r)
        r["cost"] = {"flops": flops, "bytes_accessed": byts}
        r["collectives"] = {"total_bytes": coll}
        t_c = r["cost"]["flops"] / PEAK_FLOPS
        t_m = r["cost"]["bytes_accessed"] / HBM_BW
        t_x = r["collectives"]["total_bytes"] / ICI_BW
        dom = max(("compute", t_c), ("memory", t_m), ("collective", t_x),
                  key=lambda kv: kv[1])[0]
        mflops = model_flops(cfg, shp.kind, shp.global_batch, shp.seq_len) / chips
        ratio = mflops / r["cost"]["flops"] if r["cost"]["flops"] else 0.0
        rows.append({
            "arch": r["arch"], "shape": r["shape"], "mesh": r["mesh"],
            "status": "ok",
            "t_compute_s": t_c, "t_memory_s": t_m, "t_collective_s": t_x,
            "bottleneck": dom,
            "model_flops_ratio": ratio,
            "temp_GB": r["memory"]["temp_size_bytes"] / 1e9,
            "arg_GB": r["memory"]["argument_size_bytes"] / 1e9,
        })
    return rows


def render(rows: List[Dict]) -> str:
    hdr = (f"{'arch':26s} {'shape':12s} {'mesh':8s} {'t_comp':>9s} {'t_mem':>9s} "
           f"{'t_coll':>9s} {'bound':>10s} {'MF/HLO':>7s} {'temp_GB':>8s}")
    out = [hdr, "-" * len(hdr)]
    for r in rows:
        if r["status"] != "ok":
            out.append(f"{r['arch']:26s} {r['shape']:12s} {r.get('mesh','?'):8s} "
                       f"-- {r['status']}: {r.get('reason','')}")
            continue
        out.append(
            f"{r['arch']:26s} {r['shape']:12s} {r['mesh']:8s} "
            f"{r['t_compute_s']:9.4f} {r['t_memory_s']:9.4f} "
            f"{r['t_collective_s']:9.4f} {r['bottleneck']:>10s} "
            f"{r['model_flops_ratio']:7.3f} {r['temp_GB']:8.2f}")
    return "\n".join(out)


def main():
    path = sys.argv[1] if len(sys.argv) > 1 else "dryrun_results.json"
    with open(path) as f:
        results = json.load(f)
    corrected = {}
    corr_path = sys.argv[2] if len(sys.argv) > 2 else "corrected_costs.json"
    try:
        with open(corr_path) as f:
            for row in json.load(f):
                c = row.get("corrected")
                if c and "flops" in c:
                    # corrections were measured single-pod; the §Roofline
                    # table is single-pod only, multi-pod rows stay raw
                    corrected[(row["arch"], row["shape"], "16x16")] = c
    except FileNotFoundError:
        print("# no corrected_costs.json — using raw cost_analysis numbers")
    rows = analyze(results, corrected)
    print(render(rows))
    with open("roofline_table.json", "w") as f:
        json.dump(rows, f, indent=1)
    print("\nwrote roofline_table.json")


if __name__ == "__main__":
    main()
