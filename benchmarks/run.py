"""Benchmark harness (deliverable d): one entry per paper table/figure plus
kernel micro-benches.  Prints ``name,us_per_call,derived`` CSV.

  PYTHONPATH=src python -m benchmarks.run            # everything
  PYTHONPATH=src python -m benchmarks.run --only fig1r1
  PYTHONPATH=src python -m benchmarks.run --only fig1r1 --json

The paper-figure benches are thin wrappers over the declarative experiment
registry (`repro.exp`): each pulls its method/compressor/basis cells from
the registered experiment and times/evaluates them through the same
`run_cell` engine the figure CSVs come from — there is exactly one place a
figure's configuration lives.

`derived` encodes the figure's headline quantity — for the convergence
figures that is Mbits/node to reach gap 1e-6 (the paper's x-axis) plus an
explicit ``reached=`` flag (an ``inf`` alone cannot distinguish "diverged"
from "stopped early"; the flag also lands in the JSON record's ``extra``
field so BENCH trajectories can tell the two apart), for kernels GFLOP/s
(interpret-mode: correctness-path timing only).

``--json`` additionally writes one ``BENCH_<name>.json`` perf record per
bench group (per-bench µs + derived metric + extras, plus an
``environment`` block — jax/jaxlib versions, backend, device population —
so records from different machines are comparable), seeding the repo's
benchmark trajectory; ``--json-dir`` picks the output directory.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np


def _timeit(fn, reps=3):
    fn()  # warm/compile
    t0 = time.perf_counter()
    for _ in range(reps):
        fn()
    return (time.perf_counter() - t0) / reps * 1e6  # µs


def _mbits(hist, tol=1e-6):
    """Headline metric string + extra dict, via the shared `repro.exp`
    helper (one implementation for benches, sweeps and artifacts — the old
    local copy returned a bare ``inf`` with no reached flag)."""
    from repro.exp import bits_to_tol

    b = bits_to_tol(hist, tol)
    return (f"Mbits_to_{tol:g}={b.mbits:.3f};reached={b.reached}",
            {"mbits_to_tol": None if not b.reached else b.mbits,
             "reached": b.reached})


def _exp(name):
    """(experiment, built problem) for a registered `repro.exp` experiment."""
    from repro.exp import build_problem, get_experiment

    exp = get_experiment(name)
    return exp, build_problem(exp.problem)


BENCHES = {}


def bench(name):
    def deco(fn):
        BENCHES[name] = fn
        return fn
    return deco


# ---------------- paper figures (comm complexity) ---------------------------
@bench("fig1r1_BL1_vs_FedNL")
def fig1r1():
    from repro.exp import run_cell
    exp, prob = _exp("fig1r1")
    STEPS = 3

    def runner(cell_name, backend):
        cell = exp.cell(cell_name)
        return lambda: run_cell(exp, cell, prob, steps=STEPS, backend=backend)

    t_bl = _timeit(runner("BL1", "fast"), reps=3)
    t_bl_ref = _timeit(runner("BL1", "reference"), reps=1)
    t_fn = _timeit(runner("FedNL", "fast"), reps=3)    # FedNL timed on its own config
    h_bl = run_cell(exp, exp.cell("BL1"), prob, steps=18)
    h_fn = run_cell(exp, exp.cell("FedNL"), prob, steps=18)
    d_bl, x_bl = _mbits(h_bl)
    d_fn, x_fn = _mbits(h_fn)
    return [("fig1r1_BL1", t_bl / STEPS, d_bl, x_bl),
            ("fig1r1_BL1_reference", t_bl_ref / STEPS,
             f"fast_speedup={t_bl_ref / t_bl:.1f}x"),
            ("fig1r1_FedNL", t_fn / STEPS, d_fn, x_fn)]


@bench("fig1r2_BL1_vs_first_order")
def fig1r2():
    from repro.exp import run_cell
    exp, prob = _exp("fig1r2")
    rows = []
    for cell_name, steps in (("BL1", 18), ("GD", 150), ("DIANA", 150)):
        h = run_cell(exp, exp.cell(cell_name), prob, steps=steps)
        derived, extra = _mbits(h)
        rows.append((f"fig1r2_{cell_name}", 0.0, derived, extra))
    return rows


@bench("fig2_newton_basis")
def fig2():
    from repro.exp import run_cell
    exp, prob = _exp("fig2")
    h1 = run_cell(exp, exp.cell("newton_std"), prob)
    h2 = run_cell(exp, exp.cell("newton_basis"), prob)
    per1 = h1.up_bits[2] - h1.up_bits[1]
    per2 = h2.up_bits[2] - h2.up_bits[1]
    return [("fig2_newton_std", 0.0, f"bits_per_iter={per1:.0f}"),
            ("fig2_newton_basis", 0.0,
             f"bits_per_iter={per2:.0f};saving={per1/per2:.2f}x")]


@bench("fig4_partial_participation")
def fig4():
    from repro.exp import run_cell
    exp, prob = _exp("fig4")
    out = []
    for tag, tau in (("full", 10), ("half", 5)):
        h = run_cell(exp, exp.cell(f"BL2_tau_{tag}"), prob, steps=80)
        derived, extra = _mbits(h)
        out.append((f"fig4_BL2_tau{tau}", 0.0, derived, extra))
    return out


@bench("fig5_bidirectional")
def fig5():
    from repro.exp import run_cell
    exp, prob = _exp("fig5")
    # the registry's BL1-BC cell is the convergent bidirectional config
    # (K=r both ways, p=1/2; the paper's most aggressive A.7 setting
    # diverges on this harder synthetic instance)
    h = run_cell(exp, exp.cell("BL1-BC"), prob, steps=60)
    derived, extra = _mbits(h)
    return [("fig5_BL1_BC", 0.0, derived, extra)]


@bench("fig6_bl2_vs_bl3")
def fig6():
    from repro.exp import run_cell
    exp, prob = _exp("fig6")
    h2 = run_cell(exp, exp.cell("BL2_p1.00"), prob, steps=30)
    h3 = run_cell(exp, exp.cell("BL3_p1.00"), prob, steps=30)
    return [("fig6_BL2_std", 0.0, f"gap@30={h2.gaps[-1]:.2e}"),
            ("fig6_BL3", 0.0, f"gap@30={h3.gaps[-1]:.2e}")]


@bench("basis_matrix")
def basis_matrix():
    """The paper's thesis as one grid: bits-to-ε for every REGISTERED basis
    × {Top-K, Rank-R} on BL1, one-time basis shipment included (the ledger's
    basis_ship leg is broken out in `derived`).  Every basis gets the SAME
    coefficient budget (K = r² — the data basis's full coefficient count),
    so differences are purely where the basis concentrates energy."""
    from repro.core import bl
    from repro.core.basis import available_bases, is_pytree_basis, make_bases
    from repro.core.compressors import Identity, RankR, TopK

    from repro.exp import build_problem, get_experiment

    prob = build_problem(get_experiment("fig1r1").problem)
    clients, x0, xs = prob.clients, prob.x0, prob.x_star
    r = 24
    STEPS = 16
    comps = {"topk": TopK(k=r * r), "rankr": RankR(r=2)}
    rows = []
    for bname in available_bases():
        if bname == "psd" or is_pytree_basis(bname):
            # psd is BL3's basis (Example 5.1); pytree bases (per_layer_svd)
            # are the DNN workload's — see the fed_dnn bench
            continue
        bases = make_bases(bname, clients, x0=x0)
        for cname, comp in comps.items():
            h = bl.bl1(clients, bases, [comp for _ in clients], Identity(),
                       x0, xs, STEPS, backend="fast")
            ship = h.legs["basis_ship"][-1] / 1e6
            derived, extra = _mbits(h)
            rows.append((
                f"basis_matrix_{bname}_{cname}", 0.0,
                f"{derived};gap@{STEPS}={h.gaps[-1]:.2e}"
                f";basis_ship_Mbits={ship:.3f}", extra))
    return rows


@bench("basis_ship")
def basis_ship():
    """The ISSUE's headline grid: basis × shipment wire × refresh period →
    bits-to-tol on the fig-dnn problem.  The question the grid answers is
    whether the per-layer SVD basis can HOLD its rounds-to-accuracy win
    once the one-time (U_ℓ, V_ℓ) shipment is billed: compressed wires
    (bf16/int8) shrink the basis_ship leg 2–4×, amortized refresh re-bills
    it on a drift trigger, and the structured DCT/Hadamard rotations ship
    zero floats by construction.  Each row records total Mbits-to-tol plus
    the basis_ship share so the trade is auditable.  ``REPRO_BENCH_TINY=1``
    shrinks to 3 cells at smoke depth for CI."""
    from repro.exp import build_problem, get_experiment
    from repro.fed import bldnn as B

    prob = build_problem(get_experiment("fig-dnn").problem)
    tiny = os.environ.get("REPRO_BENCH_TINY", "0") == "1"
    STEPS = 6 if tiny else 40
    TOL = 0.1   # fig-dnn's tolerance: training error ≤ 10%
    cells = [
        ("topk_nobasis", dict(use_basis=False)),
        ("svd_f32", {}),
        ("svd_bf16", dict(ship_float_bits=16)),
        ("svd_int8", dict(ship_float_bits=8)),
        ("svd_int8_T5", dict(ship_float_bits=8, rounds_per_refresh=5,
                             drift_threshold=0.05)),
        ("dct_tree", dict(basis_kind="dct_tree")),
        ("hadamard_tree", dict(basis_kind="hadamard_tree")),
    ]
    if tiny:
        cells = [cells[0], cells[3], cells[5]]
    rows = []
    for tag, kw in cells:
        cfg = B.BLDNNConfig(lr=0.05, top_k_frac=0.1, **kw)
        h = B.run_bldnn(prob.loss_fn, prob.eval_fn, prob.params0,
                        prob.batch, STEPS, cfg)
        derived, extra = _mbits(h, tol=TOL)
        ship = h.legs["basis_ship"][-1] / 1e6
        extra.update(basis_ship_mbits=ship, gap_end=float(h.gaps[-1]))
        rows.append((f"basis_ship_{tag}", 0.0,
                     f"{derived};basis_ship_Mbits={ship:.3f}"
                     f";gap@{STEPS}={h.gaps[-1]:.3f}", extra))
    return rows


#: per-round cost of the retired hand-rolled BL-DNN shard_map loop
#: (`fed.bldnn.make_fed_train_step`, one jitted step dispatched per round
#: over an 8-virtual-device mesh), measured on the fig-dnn problem in the
#: commit that deleted it — the engine rows below are re-measured live
#: against this frozen baseline.
_FED_DNN_LEGACY_US = 19162.0


@bench("fed_dnn")
def fed_dnn():
    """BL-DNN round cost on the pytree engine (the fig-dnn problem):
    single-device chunked scan (with and without the post-scan trajectory
    evaluation) and the 8-virtual-device client-sharded backend — exact
    (fixed-order gather, bitwise-checked against the fast path) and
    exact=False (BLDNNSpec's pmean ReducePlan) — vs the retired
    hand-rolled loop's recorded per-round cost (subprocess: the device
    count is locked at first jax init here)."""
    import subprocess
    import sys

    script = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import time
import jax
from repro.core import rounds
from repro.fed import bldnn as B
from repro.exp import build_problem, get_experiment

exp = get_experiment("fig-dnn")
prob = build_problem(exp.problem)
cfg = B.BLDNNConfig(lr=0.05, top_k_frac=0.1)
STEPS = 40

from repro.core.basis import per_layer_svd_basis
spec = B.build_spec(prob.loss_fn, prob.eval_fn, prob.params0, cfg)
basis = per_layer_svd_basis(prob.params0)
root = jax.random.PRNGKey(0)

def scan_run():
    # chunked driver without the trajectory eval (run_chunk donates its
    # carry, so each rep pays the cheap carry init too)
    c = rounds.init_serve_carry(spec, prob.batch, basis, prob.params0)
    c, ys = rounds.run_chunk(spec, prob.batch, basis, prob.params0, c, 0,
                             STEPS, root)
    jax.block_until_ready((c, ys))

def e2e(backend, exact=True):
    return lambda: B.run_bldnn(prob.loss_fn, prob.eval_fn, prob.params0,
                               prob.batch, STEPS, cfg, backend=backend,
                               exact=exact)

hists = {}
for name, fn in (("scan_only", scan_run), ("fast", e2e("fast")),
                 ("sharded", e2e("fast+sharded")),
                 ("sharded_approx", e2e("fast+sharded", exact=False))):
    hists[name] = fn()   # warm/compile (History for the e2e rows)
    t0 = time.perf_counter()
    for _ in range(3):
        fn()
    print(f"RESULT {name} {(time.perf_counter() - t0) / 3 / STEPS * 1e6:.1f}")
bw = (hists["sharded"].gaps == hists["fast"].gaps
      and hists["sharded"].up_bits == hists["fast"].up_bits)
print(f"BITWISE {bw}")
"""
    env = dict(os.environ, PYTHONPATH="src")
    env.setdefault("JAX_PLATFORMS", "cpu")
    proc = subprocess.run([sys.executable, "-c", script], capture_output=True,
                          text=True, timeout=900, env=env)
    res, bw = {}, None
    for line in proc.stdout.splitlines():
        if line.startswith("RESULT"):
            _, name, us = line.split()
            res[name] = float(us)
        elif line.startswith("BITWISE"):
            bw = line.split()[1] == "True"
    if set(res) != {"scan_only", "fast", "sharded", "sharded_approx"}:
        raise RuntimeError(proc.stdout + proc.stderr[-2000:])
    speedup = _FED_DNN_LEGACY_US / res["scan_only"]
    return [
        ("fed_dnn_engine_scan", res["scan_only"],
         f"per_round;old_loop_us={_FED_DNN_LEGACY_US:.0f}"
         f";speedup_vs_old_loop={speedup:.2f}x",
         {"old_loop_us_per_round": _FED_DNN_LEGACY_US,
          "speedup_vs_old_loop": speedup}),
        ("fed_dnn_engine_e2e", res["fast"],
         "per_round;includes_trajectory_eval"),
        ("fed_dnn_engine_sharded_8dev", res["sharded"],
         f"per_round;overhead_vs_fast={res['sharded'] / res['fast']:.2f}x"
         f";bitwise_equal_histories={bw}",
         {"overhead_vs_fast": res["sharded"] / res["fast"],
          "bitwise_equal_histories": bw}),
        ("fed_dnn_engine_sharded_8dev_approx", res["sharded_approx"],
         f"per_round;overhead_vs_fast="
         f"{res['sharded_approx'] / res['fast']:.2f}x;exact=False",
         {"overhead_vs_fast": res["sharded_approx"] / res["fast"]}),
    ]


_ENGINE_GRID_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=@NDEV@"
import time
import jax, jax.numpy as jnp
from repro.core import bl, glm
from repro.core.basis import orth_basis_from_data
from repro.core.compressors import Identity, TopK

TINY = @TINY@
# STEPS=24 amortizes the one-time init/dispatch cost so per_round reflects
# the steady-state marginal rate (at STEPS=6 the fixed ~10ms still dominates)
m, d, r, STEPS, REPS = (20, 24, 8, 3, 1) if TINY else (60, 120, 24, 24, 2)
clients = glm.make_synthetic(seed=0, n_clients=8, m=m, d=d, r=r, lam=1e-3)
x0 = jnp.zeros(d, jnp.float64)
xs = glm.newton_solve(clients, x0, 20)
bases = [orth_basis_from_data(c.A) for c in clients]
k = bases[0].r

def time_cell(tag, fn, steps):
    h = fn()   # warm/compile
    t0 = time.perf_counter()
    for _ in range(REPS):
        fn()
    us = (time.perf_counter() - t0) / REPS / steps * 1e6
    print(f"RESULT {tag} {us:.1f}", flush=True)
    return h

def run_bl1(backend, exact=True):
    return bl.bl1(clients, bases, [TopK(k=k)] * 8, Identity(), x0, xs,
                  STEPS, backend=backend, exact=exact)

h_fast = time_cell("bl1_fast", lambda: run_bl1("fast"), STEPS)
h_ex = time_cell("bl1_sharded", lambda: run_bl1("fast+sharded"), STEPS)
time_cell("bl1_sharded_approx",
          lambda: run_bl1("fast+sharded", exact=False), STEPS)
bw = (h_ex.gaps == h_fast.gaps and h_ex.up_bits == h_fast.up_bits
      and h_ex.down_bits == h_fast.down_bits)
print(f"BITWISE bl1 {bw}", flush=True)

if not TINY:
    from repro.fed import bldnn as B
    from repro.exp import build_problem, get_experiment
    prob = build_problem(get_experiment("fig-dnn").problem)
    cfg = B.BLDNNConfig(lr=0.05, top_k_frac=0.1)
    DSTEPS = 12

    def run_dnn(backend, exact=True):
        return B.run_bldnn(prob.loss_fn, prob.eval_fn, prob.params0,
                           prob.batch, DSTEPS, cfg, backend=backend,
                           exact=exact)

    h_fast = time_cell("bldnn_fast", lambda: run_dnn("fast"), DSTEPS)
    h_ex = time_cell("bldnn_sharded", lambda: run_dnn("fast+sharded"),
                     DSTEPS)
    time_cell("bldnn_sharded_approx",
              lambda: run_dnn("fast+sharded", exact=False), DSTEPS)
    bw = h_ex.gaps == h_fast.gaps and h_ex.up_bits == h_fast.up_bits
    print(f"BITWISE bldnn {bw}", flush=True)
"""


@bench("engine_sharded")
def engine_sharded():
    """Round-engine aggregation grid: method {BL1, BL-DNN} × device count
    {4, 8} × collective mode {exact fixed-order gather, exact=False ring
    psum/pmean per the spec's ReducePlan}, each against the single-device
    vmap baseline measured in the same subprocess (device count is locked
    at first jax init, so each mesh size gets its own child).  Exact-mode
    rows carry an ACTUAL bitwise-equality verdict, not an assumption.  On
    one physical CPU the sharded backend pays collective + replication
    overhead; these rows track that tax.  ``REPRO_BENCH_TINY=1`` shrinks
    the grid (8-device BL1 only, tiny sizes) for CI smoke."""
    import subprocess
    import sys

    tiny = os.environ.get("REPRO_BENCH_TINY", "0") == "1"
    env = dict(os.environ, PYTHONPATH="src")
    # pin the child to CPU when the parent doesn't say otherwise — on images
    # with a TPU plugin an unpinned child burns minutes probing for hardware
    env.setdefault("JAX_PLATFORMS", "cpu")
    rows = []
    for ndev in ((8,) if tiny else (8, 4)):
        script = (_ENGINE_GRID_SCRIPT.replace("@NDEV@", str(ndev))
                  .replace("@TINY@", str(tiny)))
        proc = subprocess.run([sys.executable, "-c", script],
                              capture_output=True, text=True, timeout=900,
                              env=env)
        res, bitwise = {}, {}
        for line in proc.stdout.splitlines():
            if line.startswith("RESULT"):
                _, tag, us = line.split()
                res[tag] = float(us)
            elif line.startswith("BITWISE"):
                _, meth, flag = line.split()
                bitwise[meth] = flag == "True"
        want = {"bl1_fast", "bl1_sharded", "bl1_sharded_approx"}
        if not tiny:
            want |= {"bldnn_fast", "bldnn_sharded", "bldnn_sharded_approx"}
        if set(res) != want:
            raise RuntimeError(proc.stdout + proc.stderr[-2000:])
        for meth in ("bl1",) if tiny else ("bl1", "bldnn"):
            fast = res[f"{meth}_fast"]
            if ndev == 8:   # one baseline row per method (mesh-independent)
                rows.append((f"engine_{meth}_fast_8clients", fast,
                             "per_round;single_device_baseline"))
            for mode, suffix in (("sharded", ""), ("sharded_approx",
                                                   "_approx")):
                us = res[f"{meth}_{mode}"]
                tax = us / fast
                derived = (f"per_round;ndev={ndev}"
                           f";overhead_vs_fast={tax:.2f}x")
                extra = {"ndev": ndev, "overhead_vs_fast": tax}
                if suffix:
                    derived += ";exact=False"
                else:
                    derived += (";bitwise_equal_histories="
                                f"{bitwise[meth]}")
                    extra["bitwise_equal_histories"] = bitwise[meth]
                rows.append((f"engine_{meth}_sharded_{ndev}dev{suffix}",
                             us, derived, extra))
    return rows


_COHORT_STREAM_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=8")
import time
import numpy as np
import jax, jax.numpy as jnp
from repro.core import cohort, client_batch, rounds, compressors, specs

TINY = @TINY@
d, m = 24, 8
COHORT = 64 if TINY else 256
RPC = 4
ROUNDS = 8 if TINY else 16
NS = (512, 2048) if TINY else (1000, 10000, 100000)
N_PARITY = 64 if TINY else 256
x0 = jnp.zeros(d, jnp.float64)
key = jax.random.PRNGKey(0)

def bl2(n, tau):
    bb = cohort.standard_basisb(d, n)
    return specs.BL2Spec(
        hess_comp=compressors.TopK(k=2 * d),
        model_comp=compressors.Identity(),
        alpha=1.0, eta=1.0, p=1.0, tau=tau, init_exact=True,
        init_hess_bits=bb.init_coeff_bits_mean(True),
        basis_bits=bb.transmission_bits_mean(), block=False)

# flat-in-n: the SAME cohort/epoch geometry at every fleet size, so the
# jitted chunk program (shapes keyed on the cohort capacity) is shared and
# the only n-dependence left is the engine's host plane
for n in NS:
    store = client_batch.synthetic_store(0, n, m, d, lam=1e-3)
    eng = cohort.CohortEngine(bl2(n, COHORT // 2), store, x0, cohort=COHORT,
                              rounds_per_cohort=RPC, root_key=key,
                              basis="standard")
    jax.block_until_ready(eng.run_chunk(0, ROUNDS))       # warm/compile
    t0 = time.perf_counter()
    jax.block_until_ready(eng.run_chunk(ROUNDS, ROUNDS))
    us = (time.perf_counter() - t0) / ROUNDS * 1e6
    print(f"RESULT n{n} {us:.1f}", flush=True)
    print(f"OVERLAP n{n} {eng.prefetch_overlap:.4f}", flush=True)
    eng.close()

# cohort==fleet bitwise parity vs the stacked engine, both reducers
for sharded, tag in ((False, "vmap"), (True, "sharded")):
    n = N_PARITY
    spec = bl2(n, n // 2)
    store = client_batch.synthetic_store(0, n, m, d, lam=1e-3)
    batch = store.gather_batch(np.arange(n))
    bb = cohort.standard_basisb(d, n)
    c0 = rounds.init_serve_carry(spec, batch, bb, x0, sharded=sharded)
    _, ys1 = rounds.run_chunk(spec, batch, bb, x0, c0, 0, 6, key,
                              sharded=sharded)
    eng = cohort.CohortEngine(spec, store, x0, cohort=n, rounds_per_cohort=2,
                              root_key=key, basis="standard", sharded=sharded)
    ys2 = eng.run_chunk(0, 6)
    eng.close()
    eq = all(np.array_equal(np.asarray(a), np.asarray(b))
             for a, b in zip(jax.tree_util.tree_leaves(ys1),
                             jax.tree_util.tree_leaves(ys2)))
    print(f"BITWISE {tag} {eq}", flush=True)
"""


@bench("cohort_stream")
def cohort_stream():
    """Cohort-streaming engine (`repro.core.cohort`): per-round wall time
    vs fleet size at FIXED cohort geometry — the tentpole headline is that
    rounds are flat in n (the device only ever sees the cohort; the host
    plane is O(cohort) per epoch), pinned at ≤1.15× from the smallest to
    the largest fleet.  Also records the measured prefetch overlap (the
    fraction of next-epoch gather+H2D hidden behind the chunk scan) and an
    ACTUAL cohort==fleet bitwise-parity verdict against the stacked engine
    on both reducers.  ``REPRO_BENCH_TINY=1`` shrinks fleets for CI smoke
    (subprocess: the sharded parity leg needs the 8-device mesh, and the
    device count is locked at first jax init)."""
    import subprocess
    import sys

    tiny = os.environ.get("REPRO_BENCH_TINY", "0") == "1"
    env = dict(os.environ, PYTHONPATH="src")
    env.setdefault("JAX_PLATFORMS", "cpu")
    script = _COHORT_STREAM_SCRIPT.replace("@TINY@", str(tiny))
    proc = subprocess.run([sys.executable, "-c", script],
                          capture_output=True, text=True, timeout=900,
                          env=env)
    res, overlap, bitwise = {}, {}, {}
    for line in proc.stdout.splitlines():
        if line.startswith("RESULT"):
            _, tag, us = line.split()
            res[tag] = float(us)
        elif line.startswith("OVERLAP"):
            _, tag, frac = line.split()
            overlap[tag] = float(frac)
        elif line.startswith("BITWISE"):
            _, tag, flag = line.split()
            bitwise[tag] = flag == "True"
    ns = (512, 2048) if tiny else (1000, 10000, 100000)
    if set(res) != {f"n{n}" for n in ns} or set(bitwise) != {"vmap",
                                                             "sharded"}:
        raise RuntimeError(proc.stdout + proc.stderr[-2000:])
    rows = []
    for n in ns:
        rows.append((f"cohort_stream_n{n}", res[f"n{n}"],
                     f"per_round;fleet={n};prefetch_overlap="
                     f"{overlap[f'n{n}']:.2f}",
                     {"n_clients": n,
                      "prefetch_overlap": overlap[f"n{n}"]}))
    flat = res[f"n{ns[-1]}"] / res[f"n{ns[0]}"]
    rows.append((
        "cohort_stream_flatness", 0.0,
        f"per_round_ratio_n{ns[-1]}_vs_n{ns[0]}={flat:.3f}x"
        f";bitwise_vmap={bitwise['vmap']}"
        f";bitwise_sharded={bitwise['sharded']}",
        {"flatness_ratio": flat, "n_small": ns[0], "n_large": ns[-1],
         "bitwise_equal_histories_vmap": bitwise["vmap"],
         "bitwise_equal_histories_sharded": bitwise["sharded"]}))
    return rows


@bench("cold_start")
def cold_start():
    """Cold vs warm-restart time-to-first-round through the two-tier
    program cache (`repro.core.progcache`): each backend serves a short
    run twice in fresh subprocesses against the SAME checkpoint directory
    — the cold child compiles and populates ``<ckpt>/progcache``, then its
    checkpoints are deleted (cache kept) and the warm child replays the
    identical run from deserialized executables.  Rows report both TTFRs,
    the speedup, and an ACTUAL bitwise-equality verdict over the full
    served histories (gaps + per-leg ledger bits + events), plus the warm
    child's hit/miss counters — a warm run that silently recompiles
    (fingerprint drift across processes) fails the bench rather than
    reporting a ~1x speedup.  ``REPRO_BENCH_TINY=1`` shrinks the round
    budget for CI smoke."""
    import shutil
    import subprocess
    import sys
    import tempfile

    tiny = os.environ.get("REPRO_BENCH_TINY", "0") == "1"
    max_rounds, chunk = (4, 2) if tiny else (12, 6)
    grid = (
        ("stacked", "fig4", "BL2_tau_half", "fast", None),
        ("sharded", "fig4", "BL2_tau_half", "fast+sharded", 8),
        ("cohort", "cohort-smoke", "BL2", None, None),
    )
    rows = []
    for name, exp, cell, backend, ndev in grid:
        work = tempfile.mkdtemp(prefix=f"bench_cold_start_{name}_")
        ckpt = os.path.join(work, "ckpt")
        env = dict(os.environ, PYTHONPATH="src")
        env.setdefault("JAX_PLATFORMS", "cpu")
        if ndev:
            env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                                + f" --xla_force_host_platform_device_count"
                                  f"={ndev}")
        try:
            recs = {}
            for phase in ("cold", "warm"):
                if phase == "warm":
                    # drop the checkpoints (else the warm child resumes a
                    # finished run and serves 0 rounds) but keep the
                    # progcache subdirectory they sit next to
                    for f in os.listdir(ckpt):
                        path = os.path.join(ckpt, f)
                        if os.path.isfile(path):
                            os.remove(path)
                res = os.path.join(work, f"{phase}.json")
                cmd = [sys.executable, "-m", "repro.launch.fed_serve",
                       "--exp", exp, "--cell", cell, "--ckpt-dir", ckpt,
                       "--chunk", str(chunk),
                       "--max-rounds", str(max_rounds), "--result", res]
                if backend:
                    cmd += ["--backend", backend]
                proc = subprocess.run(cmd, capture_output=True, text=True,
                                      timeout=900, env=env)
                if proc.returncode != 0:
                    raise RuntimeError(
                        f"cold_start {name}/{phase} failed:\n"
                        + proc.stdout[-2000:] + proc.stderr[-2000:])
                with open(res) as f:
                    recs[phase] = json.load(f)
            cold_s = recs["cold"]["meta"]["ttfr_s"]
            warm_s = recs["warm"]["meta"]["ttfr_s"]
            warm_stats = (recs["warm"]["meta"]["progcache"]
                          or {}).get("stats", {})
            if not warm_stats.get("hit"):
                raise RuntimeError(
                    f"cold_start {name}: warm run hit nothing "
                    f"(stats {warm_stats}) — cache key unstable across "
                    "processes?")
            eq = recs["cold"]["history"] == recs["warm"]["history"]
            speedup = cold_s / warm_s
            rows.append((
                f"cold_start_{name}", warm_s * 1e6,
                f"ttfr_cold={cold_s:.3f}s;ttfr_warm={warm_s:.3f}s"
                f";speedup={speedup:.1f}x;bitwise_equal_histories={eq}",
                {"ttfr_cold_s": cold_s, "ttfr_warm_s": warm_s,
                 "speedup": speedup, "bitwise_equal_histories": eq,
                 "rounds": max_rounds, "chunk": chunk,
                 "backend": backend or "cohort",
                 "progcache_warm_stats": warm_stats}))
        finally:
            shutil.rmtree(work, ignore_errors=True)
    return rows


# ---------------- kernel micro-benches --------------------------------------
@bench("kernel_matmul")
def kmatmul():
    from repro.kernels import ops
    a = jnp.ones((512, 512), jnp.float32)
    b = jnp.ones((512, 512), jnp.float32)
    us = _timeit(lambda: jax.block_until_ready(ops.matmul(a, b)))
    fl = 2 * 512**3
    return [("kernel_matmul_512", us, f"GFLOPs={fl/us/1e3:.2f}(interp)")]


@bench("kernel_flash_attention")
def kflash():
    from repro.kernels.flash_attention import flash_attention
    q = jnp.ones((4, 512, 64), jnp.float32)
    us = _timeit(lambda: jax.block_until_ready(
        flash_attention(q, q, q, causal=True, bq=128, bk=128)))
    return [("kernel_flash_512", us, "interp")]


@bench("kernel_ssd")
def kssd():
    from repro.kernels import ops
    x = jnp.ones((8, 256, 64), jnp.float32)
    dt = jnp.full((8, 256), 0.1, jnp.float32)
    A = jnp.full((8,), -1.0, jnp.float32)
    Bm = jnp.ones((8, 256, 16), jnp.float32)
    us = _timeit(lambda: jax.block_until_ready(ops.ssd(x, dt, A, Bm, Bm, chunk=64)))
    return [("kernel_ssd_256", us, "interp")]


@bench("kernel_topk")
def ktopk():
    from repro.kernels import ops
    x = jnp.asarray(np.random.default_rng(0).standard_normal((256, 256)), jnp.float32)
    us = _timeit(lambda: jax.block_until_ready(ops.topk_compress(x, 512)[0]))
    out, kept = ops.topk_compress(x, 512)
    return [("kernel_topk_256x256", us, f"kept={int(kept)}/target512")]


@bench("kernel_basis_project")
def kbasis():
    from repro.kernels import ops
    rng = np.random.default_rng(0)
    V = jnp.asarray(np.linalg.qr(rng.standard_normal((512, 64)))[0], jnp.float32)
    A = jnp.asarray(rng.standard_normal((512, 512)), jnp.float32)
    us = _timeit(lambda: jax.block_until_ready(ops.basis_project(V, A)))
    return [("kernel_basis_project_512", us, "interp")]


def _write_json(json_dir, group, rows):
    from repro.core.progcache import env_fingerprint

    record = {
        "bench": group,
        "unix_time": time.time(),
        "environment": env_fingerprint(),
        "rows": [
            {"name": row[0], "us_per_call": row[1], "derived": row[2],
             **({"extra": row[3]} if len(row) > 3 else {})}
            for row in rows
        ],
    }
    os.makedirs(json_dir, exist_ok=True)
    path = os.path.join(json_dir, f"BENCH_{group}.json")
    with open(path, "w") as f:
        json.dump(record, f, indent=2)
        f.write("\n")
    return path


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--json", action="store_true",
                    help="write a BENCH_<name>.json record per bench group")
    ap.add_argument("--json-dir", default=".",
                    help="directory for --json records (default: cwd)")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    for name, fn in BENCHES.items():
        if args.only and args.only not in name:
            continue
        try:
            rows = fn()
            for row in rows:
                print(f"{row[0]},{row[1]:.1f},{row[2]}", flush=True)
            if args.json:
                _write_json(args.json_dir, name, rows)
        except Exception as e:  # keep the harness robust
            print(f"{name},ERROR,{type(e).__name__}:{e}", flush=True)


if __name__ == "__main__":
    main()
