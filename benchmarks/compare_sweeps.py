"""Baseline vs optimized dry-run comparison (the §Perf before/after table).

  PYTHONPATH=src python -m benchmarks.compare_sweeps \
      dryrun_results_baseline.json dryrun_results_opt.json
"""
import json
import sys


def load(path):
    out = {}
    for r in json.load(open(path)):
        if r.get("status") == "ok":
            out[(r["arch"], r["shape"], r["mesh"])] = r
    return out


def main():
    base = load(sys.argv[1] if len(sys.argv) > 1 else "dryrun_results_baseline.json")
    opt = load(sys.argv[2] if len(sys.argv) > 2 else "dryrun_results_opt.json")
    hdr = (f"{'arch':26s} {'shape':12s} {'mesh':8s} "
           f"{'coll GB base→opt':>22s} {'temp GB base→opt':>22s}")
    print(hdr)
    print("-" * len(hdr))
    for k in sorted(base):
        if k not in opt:
            continue
        b, o = base[k], opt[k]
        cb = b["collectives"]["total_bytes"] / 1e9
        co = o["collectives"]["total_bytes"] / 1e9
        tb = b["memory"]["temp_size_bytes"] / 1e9
        to = o["memory"]["temp_size_bytes"] / 1e9
        mark = ""
        if cb > 1.5 * co or tb > 1.5 * to:
            mark = "  <<<"
        elif co > 1.5 * cb or to > 1.5 * tb:
            mark = "  !!! regression"
        print(f"{k[0]:26s} {k[1]:12s} {k[2]:8s} "
              f"{cb:10.1f} → {co:8.1f} {tb:10.1f} → {to:8.1f}{mark}")


if __name__ == "__main__":
    main()
