"""Loop-corrected cost sweep (single-pod): G=1/G=2 compiles per (arch×shape),
linear extrapolation to full depth — see dryrun.extrapolate_costs.

  PYTHONPATH=src python -m benchmarks.extrapolate_costs [out.json]
"""
import json
import sys

from repro.launch.dryrun import extrapolate_costs  # sets XLA_FLAGS first
from repro.configs import ARCH_IDS, get_config
from repro.launch.shapes import SHAPES, shape_applicable


def main():
    out_path = sys.argv[1] if len(sys.argv) > 1 else "corrected_costs.json"
    rows = []
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shp in SHAPES:
            ok, _ = shape_applicable(cfg, SHAPES[shp])
            if not ok:
                continue
            try:
                corr = extrapolate_costs(arch, shp, cfg.n_groups,
                                         cfg.n_enc_layers, False)
            except Exception as e:
                corr = {"error": f"{type(e).__name__}: {e}"}
            rows.append({"arch": arch, "shape": shp, "mesh": "16x16",
                         "corrected": corr})
            print(json.dumps(rows[-1]), flush=True)
    with open(out_path, "w") as f:
        json.dump(rows, f, indent=1)


if __name__ == "__main__":
    main()
