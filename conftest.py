"""Repo-level pytest config.

The property-based tests use `hypothesis`, which is a dev-only dependency
(requirements-dev.txt).  When it is absent (e.g. a minimal container), we
install a stub module so the test files still *import*, and every
`@given`-decorated test is collected as an explicit skip instead of killing
the whole session at collection time.
"""
import importlib.util
import sys
import types

if importlib.util.find_spec("hypothesis") is None:
    import pytest

    def _given(*_args, **_kwargs):
        def deco(fn):
            # Zero-arg stub: pytest must not try to resolve the strategy
            # parameters as fixtures, so the original signature is hidden.
            def stub():
                pytest.skip("hypothesis not installed (see requirements-dev.txt)")

            stub.__name__ = fn.__name__
            stub.__doc__ = fn.__doc__
            stub.__module__ = fn.__module__
            return stub

        return deco

    def _settings(*_args, **_kwargs):
        def deco(fn):
            return fn

        return deco

    def _strategy(*_args, **_kwargs):
        return None

    _st = types.ModuleType("hypothesis.strategies")
    for _name in (
        "integers",
        "floats",
        "booleans",
        "sampled_from",
        "lists",
        "tuples",
        "one_of",
        "just",
        "text",
    ):
        setattr(_st, _name, _strategy)

    _mod = types.ModuleType("hypothesis")
    _mod.given = _given
    _mod.settings = _settings
    _mod.strategies = _st
    sys.modules["hypothesis"] = _mod
    sys.modules["hypothesis.strategies"] = _st
