"""Repo-level pytest config.

The property-based tests use `hypothesis`, which is a dev-only dependency
(requirements-dev.txt).  When it is absent (e.g. a minimal container), we
install a stub module so the test files still *import*, and every
`@given`-decorated test is collected as an explicit skip instead of killing
the whole session at collection time.

Each stubbed test is tagged with the ``requires_hypothesis`` marker and
skips with a reason naming the missing dependency, so the tier-1 skip
population is auditable:

    pytest -m requires_hypothesis --collect-only -q   # list them
    pytest -rs                                        # see the reason

As of this writing that population is exactly the 18 ``@given`` tests in
tests/{test_core_bl,test_basis_registry,test_core_compressors,
test_kernels,test_faults,test_comm_properties,test_cohort}.py.  Every
``@given`` property in tests/test_comm_properties.py and the basis-ship
additions keeps a deterministic ``_check_*`` battery companion, so the
algebra is still exercised where hypothesis is absent.  Nothing else in
tier-1 skips: a
new skip showing up under ``-rs`` without this marker is a regression to
investigate, not environment noise.
"""
import importlib.util
import sys
import types

HYPOTHESIS_AVAILABLE = importlib.util.find_spec("hypothesis") is not None

#: the one sanctioned tier-1 skip reason — tied to the marker so `-rs`
#: output is attributable to the environment, not to broken tests
_SKIP_REASON = ("requires_hypothesis: optional dev dependency 'hypothesis' "
                "is not importable in this environment (see "
                "requirements-dev.txt); property-based test stubbed at "
                "collection by conftest.py")


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "requires_hypothesis: property-based test that runs only when the "
        "optional dev dependency 'hypothesis' is importable; auto-applied "
        "by the conftest stub when it is absent")


if not HYPOTHESIS_AVAILABLE:
    import pytest

    def _given(*_args, **_kwargs):
        def deco(fn):
            # Zero-arg stub: pytest must not try to resolve the strategy
            # parameters as fixtures, so the original signature is hidden.
            def stub():
                pytest.skip(_SKIP_REASON)

            stub.__name__ = fn.__name__
            stub.__doc__ = fn.__doc__
            stub.__module__ = fn.__module__
            return pytest.mark.requires_hypothesis(stub)

        return deco

    def _settings(*_args, **_kwargs):
        def deco(fn):
            return fn

        return deco

    def _strategy(*_args, **_kwargs):
        return None

    _st = types.ModuleType("hypothesis.strategies")
    for _name in (
        "integers",
        "floats",
        "booleans",
        "sampled_from",
        "lists",
        "tuples",
        "one_of",
        "just",
        "text",
    ):
        setattr(_st, _name, _strategy)

    _mod = types.ModuleType("hypothesis")
    _mod.given = _given
    _mod.settings = _settings
    _mod.strategies = _st
    sys.modules["hypothesis"] = _mod
    sys.modules["hypothesis.strategies"] = _st
