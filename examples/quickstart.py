"""Quickstart: Basis Learn in 60 seconds.

Reproduces the paper's central claim on a synthetic federated logistic
regression whose client data has intrinsic dimension r ≪ d: BL1 with the
data-induced basis reaches the same accuracy as FedNL (standard basis) in a
fraction of the communicated bits, and Newton-in-the-basis matches Newton
bit-for-bit in iterates at (r²+r)/(d²+d) the cost.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax.numpy as jnp
import numpy as np

from repro.core import baselines, bl, glm
from repro.core.basis import StandardBasis, make_bases, orth_basis_from_data
from repro.core.compressors import Identity, RankR, TopK

def main():
    d, r = 120, 24
    clients = glm.make_synthetic(seed=0, n_clients=10, m=60, d=d, r=r, lam=1e-3)
    x0 = jnp.zeros(d, jnp.float64)
    x_star = glm.newton_solve(clients, x0, 20)
    print(f"problem: n=10 clients, m=60 points, d={d}, intrinsic r={r}")
    print(f"f* = {float(glm.global_loss(clients, x_star)):.6f}\n")

    data_bases = [orth_basis_from_data(c.A) for c in clients]
    std_bases = [StandardBasis(d) for _ in clients]

    eigen_bases = make_bases("eigen", clients, x0=x0)  # registry lookup

    runs = {
        "BL1 (data basis, Top-r)": lambda: bl.bl1(
            clients, data_bases, [TopK(k=b.r) for b in data_bases],
            Identity(), x0, x_star, steps=20),
        "BL1 (eigen basis, Top-r²)": lambda: bl.bl1(
            clients, eigen_bases, [TopK(k=r * r) for _ in clients],
            Identity(), x0, x_star, steps=20),
        "FedNL (std basis, Rank-1)": lambda: bl.bl1(
            clients, std_bases, [RankR(r=1) for _ in clients],
            Identity(), x0, x_star, steps=20),
        "Newton (naive)": lambda: baselines.newton(clients, x0, x_star, 12),
        "Newton (data basis)": lambda: baselines.newton(
            clients, x0, x_star, 12, bases=data_bases),
        "GD (1/L)": lambda: baselines.gd(clients, x0, x_star, 200),
    }
    print(f"{'method':28s} {'gap@end':>10s} {'Mbits/node to 1e-6':>20s}")
    last = None
    for name, fn in runs.items():
        h = fn()
        g = np.asarray(h.gaps)
        reached = g < 1e-6
        bits = h.up_bits[int(np.argmax(reached))] if reached.any() else float("inf")
        print(f"{name:28s} {g[-1]:10.2e} {bits/1e6:20.3f}")
        if name.startswith("BL1 (data"):
            last = h

    # the comm ledger breaks the uplink into legs (per node, cumulative)
    print("\nBL1 (data basis) per-leg bits at the last round:")
    for leg, stream in last.legs.items():
        print(f"  {leg:12s} {stream[-1]/1e6:8.3f} Mbits")

if __name__ == "__main__":
    main()
