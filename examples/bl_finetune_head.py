"""Federated last-layer fine-tuning with the PAPER-EXACT method (BL1).

Bridges the two halves of the framework: a (reduced) transformer backbone
produces features; n federated clients fine-tune a binary logistic head on
their private feature sets with BL1 — exact d×d Hessians, data-induced
bases, Top-K coefficient compression.  Because transformer features live
near a low-dimensional manifold, the per-client intrinsic dimension r is
far below d_model and Basis Learn pays off exactly as in §2.3.

Run:  PYTHONPATH=src python examples/bl_finetune_head.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import baselines, bl, glm
from repro.core.basis import StandardBasis, orth_basis_from_data
from repro.core.compressors import Identity, RankR, TopK
from repro.models import model as M


def main():
    cfg = get_config("stablelm_12b").reduced()
    params = M.init_params(jax.random.PRNGKey(0), cfg, jnp.float32)
    d = cfg.d_model
    n_clients, m = 8, 48
    rng = np.random.default_rng(0)

    # per-client private token sequences → mean-pooled backbone features
    feats = []
    for i in range(n_clients):
        toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (m, 16)), jnp.int32)
        h, _, _ = M.forward(params, cfg, None, toks, remat=False,
                            return_hidden=True)
        feats.append(np.asarray(h.mean(axis=1), np.float64))

    # effective rank of client features (the r of §2.3)
    ranks = []
    for F in feats:
        s = np.linalg.svd(F, compute_uv=False)
        ranks.append(int((s > s[0] * 1e-6).sum()))
    print(f"d_model={d}, per-client feature rank r≈{ranks} (m={m})")

    # planted labels from a random probe direction
    w_true = rng.standard_normal(d) / np.sqrt(d)
    clients = []
    for F in feats:
        z = F @ w_true
        b = np.where(rng.random(m) < 1 / (1 + np.exp(-2 * z)), 1.0, -1.0)
        clients.append(glm.ClientData(A=jnp.asarray(F), b=jnp.asarray(b),
                                      lam=1e-2))

    x0 = jnp.zeros(d, jnp.float64)
    xs = glm.newton_solve(clients, x0, 20)
    bases = [orth_basis_from_data(c.A) for c in clients]
    sbases = [StandardBasis(d) for _ in clients]

    runs = {
        "BL1 (feature basis)": bl.bl1(clients, bases,
                                      [TopK(k=b.r) for b in bases],
                                      Identity(), x0, xs, 30),
        "FedNL (Rank-1)": bl.bl1(clients, sbases,
                                 [RankR(r=1) for _ in clients],
                                 Identity(), x0, xs, 30),
        "GD": baselines.gd(clients, x0, xs, 150),
    }
    print(f"{'method':22s} {'gap@end':>10s} {'Mbits/node to 1e-7':>20s}")
    for name, h in runs.items():
        g = np.asarray(h.gaps)
        hit = g < 1e-7
        bits = h.up_bits[int(np.argmax(hit))] / 1e6 if hit.any() else float("inf")
        print(f"{name:22s} {g[-1]:10.2e} {bits:20.3f}")


if __name__ == "__main__":
    main()
