"""End-to-end training driver (deliverable b): train a language model with the
production stack — config system, data pipeline, AdamW, scan/remat model —
on whatever devices exist (CPU here, the production mesh via launch/train.py).

Default: a ~10M-param gemma3-family model, 60 steps (CI-friendly, ~2 min).
The 100M/300-step run the deliverable names:
  PYTHONPATH=src python examples/train_lm.py --preset 100m --steps 300

Add --fed to train through the BL-DNN federated path (paper's communication
layer: per-layer SVD bases + compressed-difference learning + Fisher
preconditioning) instead of AdamW data-parallel.
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.data import make_batch_iterator
from repro.models import model as M
from repro.models.config import LayerSpec, ModelConfig
from repro.models.steps import make_train_step
from repro.optim import adamw_init


def make_cfg(preset: str) -> ModelConfig:
    if preset == "100m":
        return ModelConfig(
            name="lm-100m", n_layers=12, d_model=768, n_heads=12, n_kv_heads=4,
            d_ff=2048, vocab_size=32768, group=(LayerSpec(),), max_seq=1024)
    return ModelConfig(
        name="lm-10m", n_layers=4, d_model=384, n_heads=6, n_kv_heads=2,
        d_ff=1024, vocab_size=8192, group=(LayerSpec(),), max_seq=512)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="10m", choices=["10m", "100m"])
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--fed", action="store_true")
    args = ap.parse_args()

    cfg = make_cfg(args.preset)
    params = M.init_params(jax.random.PRNGKey(0), cfg, jnp.float32)
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"model: {cfg.name}  params={n_params/1e6:.1f}M  "
          f"devices={len(jax.devices())}")

    it = make_batch_iterator(cfg.vocab_size, args.seq + 1, args.batch, seed=0)

    if args.fed:
        from repro.fed.bldnn import (BLDNNConfig, init_fed_state,
                                     layer_bases_from_params, make_fed_train_step)
        n_dev = len(jax.devices())
        mesh = jax.make_mesh((n_dev,), ("data",))
        fcfg = BLDNNConfig(lr=args.lr, top_k_frac=0.05)
        bases = layer_bases_from_params(params)
        state = init_fed_state(params, bases, n_dev)

        def loss_fn(p, batch):
            tokens = batch["tokens"]
            h, _, aux = M.forward(p, cfg, None, tokens[:, :-1],
                                  remat=False, return_hidden=True)
            from repro.models.steps import make_fused_vocab_xent
            return make_fused_vocab_xent(cfg, None)(
                h, p["unembed"], tokens[:, 1:]) + aux

        step = jax.jit(make_fed_train_step(loss_fn, mesh, fcfg, bases, params))
        t0 = time.time()
        for i in range(args.steps):
            params, state, m = step(params, state, next(it))
            if i % 10 == 0 or i == args.steps - 1:
                print(f"step {i:4d}  loss {float(m['loss']):.4f}  "
                      f"floats/round {float(m['floats_sent'])/1e3:.0f}k  "
                      f"({time.time()-t0:.0f}s)")
        return

    opt = adamw_init(params)
    step = jax.jit(make_train_step(cfg, None, lr=args.lr, remat=False))
    t0 = time.time()
    losses = []
    for i in range(args.steps):
        params, opt, m = step(params, opt, next(it))
        losses.append(float(m["loss"]))
        if i % 10 == 0 or i == args.steps - 1:
            print(f"step {i:4d}  loss {losses[-1]:.4f}  ({time.time()-t0:.0f}s)")
    assert losses[-1] < losses[0], "loss must decrease"
    print(f"final loss {losses[-1]:.4f} (start {losses[0]:.4f}) — OK")


if __name__ == "__main__":
    main()
