"""End-to-end training driver (deliverable b): train a language model with the
production stack — config system, data pipeline, AdamW, scan/remat model —
on whatever devices exist (CPU here, the production mesh via launch/train.py).

Default: a ~10M-param gemma3-family model, 60 steps (CI-friendly, ~2 min).
The 100M/300-step run the deliverable names:
  PYTHONPATH=src python examples/train_lm.py --preset 100m --steps 300

Add --fed to train through the BL-DNN federated path (paper's communication
layer: per-layer SVD bases + compressed-difference learning + Fisher
preconditioning) instead of AdamW data-parallel.
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.data import make_batch_iterator
from repro.models import model as M
from repro.models.config import LayerSpec, ModelConfig
from repro.models.steps import make_train_step
from repro.optim import adamw_init


def make_cfg(preset: str) -> ModelConfig:
    if preset == "100m":
        return ModelConfig(
            name="lm-100m", n_layers=12, d_model=768, n_heads=12, n_kv_heads=4,
            d_ff=2048, vocab_size=32768, group=(LayerSpec(),), max_seq=1024)
    return ModelConfig(
        name="lm-10m", n_layers=4, d_model=384, n_heads=6, n_kv_heads=2,
        d_ff=1024, vocab_size=8192, group=(LayerSpec(),), max_seq=512)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="10m", choices=["10m", "100m"])
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--fed", action="store_true")
    args = ap.parse_args()

    cfg = make_cfg(args.preset)
    params = M.init_params(jax.random.PRNGKey(0), cfg, jnp.float32)
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"model: {cfg.name}  params={n_params/1e6:.1f}M  "
          f"devices={len(jax.devices())}")

    it = make_batch_iterator(cfg.vocab_size, args.seq + 1, args.batch, seed=0)

    if args.fed:
        # BL-DNN on the unified round engine: clients are a stacked
        # (n_clients, B, S) TreeBatch scanned for --steps full-batch
        # rounds (each client keeps one fixed local batch — the paper's
        # full-batch federated setting); backend "fast+sharded" shards
        # clients over however many devices divide the fleet.
        from repro.core.client_batch import tree_batch
        from repro.fed.bldnn import BLDNNConfig, run_bldnn

        n_clients = max(len(jax.devices()), 2)
        args.steps = max(args.steps, 2)   # ≥1 round + a comparison point
        fcfg = BLDNNConfig(lr=args.lr, top_k_frac=0.05)
        batch = tree_batch(
            jax.tree.map(lambda *bs: jnp.stack(bs),
                         *[next(it) for _ in range(n_clients)]))

        def loss_fn(p, data):
            tokens = data["tokens"]
            h, _, aux = M.forward(p, cfg, None, tokens[:, :-1],
                                  remat=False, return_hidden=True)
            from repro.models.steps import make_fused_vocab_xent
            return make_fused_vocab_xent(cfg, None)(
                h, p["unembed"], tokens[:, 1:]) + aux

        def eval_fn(p, data):
            losses = jax.vmap(lambda d: loss_fn(p, d))(data)
            return {"gap": jnp.mean(losses)}

        backend = "fast+sharded" if len(jax.devices()) > 1 else "fast"
        t0 = time.time()
        hist = run_bldnn(loss_fn, eval_fn, params, batch, args.steps, fcfg,
                         backend=backend)
        for i in range(0, len(hist.gaps), 10):
            print(f"round {i:4d}  loss {hist.gaps[i]:.4f}")
        print(f"final loss {hist.gaps[-1]:.4f}  "
              f"uplink {hist.up_bits[-1]/1e6:.1f} Mbits/node  "
              f"({time.time()-t0:.0f}s)")
        # gaps[t] is the loss BEFORE round t's update — steps ≥ 2 above
        # guarantees there is a later round to compare against
        assert hist.gaps[-1] < hist.gaps[0], "loss must decrease"
        return

    opt = adamw_init(params)
    step = jax.jit(make_train_step(cfg, None, lr=args.lr, remat=False))
    t0 = time.time()
    losses = []
    for i in range(args.steps):
        params, opt, m = step(params, opt, next(it))
        losses.append(float(m["loss"]))
        if i % 10 == 0 or i == args.steps - 1:
            print(f"step {i:4d}  loss {losses[-1]:.4f}  ({time.time()-t0:.0f}s)")
    assert losses[-1] < losses[0], "loss must decrease"
    print(f"final loss {losses[-1]:.4f} (start {losses[0]:.4f}) — OK")


if __name__ == "__main__":
    main()
