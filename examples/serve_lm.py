"""Batched serving example (deliverable b): prefill a batch of prompts, then
greedy-decode N tokens per sequence through the KV-cache serve path — the
same serve_step the dry-run lowers for decode_32k / long_500k.

Run:  PYTHONPATH=src python examples/serve_lm.py [--arch gemma3_4b]
(uses the .reduced() smoke variant of the chosen architecture on CPU).
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import model as M
from repro.models.steps import make_prefill_step, make_serve_step, stub_inputs


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3_4b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    params = M.init_params(jax.random.PRNGKey(0), cfg, jnp.float32)
    B, P, G = args.batch, args.prompt_len, args.gen
    max_seq = P + G
    rng = np.random.default_rng(0)
    prompts = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, P)), jnp.int32)
    extras = stub_inputs(cfg, B, jnp.float32)

    cache = M.init_cache(cfg, B, max_seq, jnp.float32)
    prefill = jax.jit(make_prefill_step(cfg, None))
    serve = jax.jit(make_serve_step(cfg, None))

    t0 = time.time()
    logits, cache = prefill(params, {"tokens": prompts, **extras}, cache)
    tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)
    t_prefill = time.time() - t0
    print(f"{cfg.name}: prefill {B}×{P} tokens in {t_prefill:.2f}s")

    out = [tok]
    svex = {k: v for k, v in extras.items() if k == "frames"}
    t0 = time.time()
    for t in range(G - 1):
        tok, cache = serve(params, {"tokens": tok[:, None], **svex}, cache,
                           jnp.asarray(P + t, jnp.int32))
        out.append(tok)
    dt = time.time() - t0
    gen = np.stack([np.asarray(t) for t in out], 1)
    print(f"decoded {G-1} steps × {B} seqs in {dt:.2f}s "
          f"({(G-1)*B/max(dt,1e-9):.1f} tok/s)")
    print("sample token ids:", gen[0][:16])
    assert gen.shape == (B, G)
    assert (gen >= 0).all() and (gen < cfg.vocab_size).all()
    print("OK")


if __name__ == "__main__":
    main()
