"""Paper-figure reproduction driver — now a thin wrapper over the
declarative experiment subsystem (`repro.exp`).

  PYTHONPATH=src python examples/fed_glm_figures.py [--fast] [--out results]

Every figure configuration lives in `repro.exp.registry` (one frozen
`Experiment` per figure); this script just invokes the same CLI as

  PYTHONPATH=src python -m repro.exp run --all

and exists for backwards compatibility with the original entry point.
The registry's round budgets ARE the historical ``--fast`` regime (the
committed ``results/`` curves), so ``--fast`` is accepted as a no-op;
full-history runs always execute the registered budgets.  Sweeps are
resumable: a re-run completes only the missing cells (use
``python -m repro.exp run --force`` for a clean rebuild).  See
docs/REPRODUCING.md for the figure-by-figure table.
"""
import argparse
import os
import sys

from repro.exp.__main__ import main as exp_main


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="accepted for compatibility (the registry budgets "
                         "already are the fast regime)")
    ap.add_argument("--out", default="results")
    args = ap.parse_args()
    rc = exp_main(["run", "--all", "--out", args.out,
                   "--artifacts", os.path.join(args.out, "exp")])
    if rc == 0:
        print(f"wrote CSVs under {args.out}/")
    return rc


if __name__ == "__main__":
    sys.exit(main())
