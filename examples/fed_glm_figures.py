"""Paper-figure reproduction driver (deliverable b/d companion): runs every
comparison from §6 / Appendix A on synthetic Table-2-style problems and
writes CSV curves (optimality gap vs communicated bits per node) under
results/.

  PYTHONPATH=src python examples/fed_glm_figures.py [--fast]

Figures covered: Fig.1 rows 1–3, Fig.2 (§A.4), Fig.3 (§A.5), Fig.4 (§A.6),
Fig.5 (§A.7), Fig.6 (§A.8).  benchmarks/run.py calls the same entry points
with --fast for the timing harness.
"""
import argparse
import csv
import os

import jax.numpy as jnp
import numpy as np

from repro.core import baselines, bl, glm
from repro.core.basis import StandardBasis, orth_basis_from_data
from repro.core.compressors import (
    Identity, RandomDithering, RankR, TopK, nrankr, ntopk, rrankr, rtopk,
)


def save(outdir, fig, name, hist):
    os.makedirs(outdir, exist_ok=True)
    path = os.path.join(outdir, f"{fig}_{name}.csv")
    g, up, down = hist.as_arrays()
    with open(path, "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(["iter", "gap", "up_bits_per_node", "down_bits_per_node"])
        for i in range(len(g)):
            w.writerow([i, g[i], up[i], down[i]])
    return path


def problem(seed=0, lam=1e-3, n=10, m=60, d=120, r=24):
    clients = glm.make_synthetic(seed=seed, n_clients=n, m=m, d=d, r=r, lam=lam)
    x0 = jnp.zeros(d, jnp.float64)
    xs = glm.newton_solve(clients, x0, 20)
    return clients, x0, xs


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--out", default="results")
    args = ap.parse_args()
    S = 12 if args.fast else 25
    SL = 60 if args.fast else 200

    clients, x0, xs = problem()
    d = x0.shape[0]
    dbases = [orth_basis_from_data(c.A) for c in clients]
    sbases = [StandardBasis(d) for _ in clients]
    n = len(clients)
    r = dbases[0].r

    # Fig 1 row 1: second-order comparison
    rows = {
        "BL1": bl.bl1(clients, dbases, [TopK(k=r) for _ in clients], Identity(), x0, xs, S),
        "FedNL": bl.bl1(clients, sbases, [RankR(r=1) for _ in clients], Identity(), x0, xs, S),
        "NL1": baselines.nl1(clients, x0, xs, S),
        "Newton": baselines.newton(clients, x0, xs, min(S, 12)),
    }
    for k, h in rows.items():
        save(args.out, "fig1r1", k, h)

    # Fig 1 row 2: vs first-order
    comp = RandomDithering(s=int(d ** 0.5))
    om = comp.omega_for(d)
    rows = {
        "BL1": bl.bl1(clients, dbases, [TopK(k=r) for _ in clients], Identity(), x0, xs, S),
        "GD": baselines.gd(clients, x0, xs, SL),
        "DIANA": baselines.diana(clients, x0, xs, SL, comp, om),
        "ADIANA": baselines.adiana(clients, x0, xs, SL, comp, om),
        "LocalGD": baselines.local_gd(clients, x0, xs, SL // 4),
    }
    for k, h in rows.items():
        save(args.out, "fig1r2", k, h)

    # Fig 1 row 3: BL2 with composed Rank-R compressors (std basis ⇒ FedNL)
    rows = {
        "RankR": bl.bl2(clients, sbases, [RankR(r=1) for _ in clients],
                        [TopK(k=d // 10) for _ in clients], x0, xs, S, p=0.1),
        "RRankR": bl.bl2(clients, sbases, [rrankr(1, d) for _ in clients],
                         [TopK(k=d // 10) for _ in clients], x0, xs, S, p=0.1),
        "NRankR": bl.bl2(clients, sbases, [nrankr(1) for _ in clients],
                         [TopK(k=d // 10) for _ in clients], x0, xs, S, p=0.1),
    }
    for k, h in rows.items():
        save(args.out, "fig1r3", k, h)

    # Fig 2 (§A.4): Newton in different bases
    save(args.out, "fig2", "newton_std", baselines.newton(clients, x0, xs, 10))
    save(args.out, "fig2", "newton_basis",
         baselines.newton(clients, x0, xs, 10, bases=dbases))

    # Fig 3 (§A.5): composed Top-K compressors in BL2 (data basis)
    rows = {
        "TopK": bl.bl2(clients, dbases, [TopK(k=r) for _ in clients],
                       [TopK(k=r // 2) for _ in clients], x0, xs, S, p=r / (2 * d)),
        "RTopK": bl.bl2(clients, dbases, [rtopk(r) for _ in clients],
                        [TopK(k=r // 2) for _ in clients], x0, xs, S, p=r / (2 * d)),
        "NTopK": bl.bl2(clients, dbases, [ntopk(r) for _ in clients],
                        [TopK(k=r // 2) for _ in clients], x0, xs, S, p=r / (2 * d)),
    }
    for k, h in rows.items():
        save(args.out, "fig3", k, h)

    # Fig 4 (§A.6): partial participation
    for tau_frac, tag in [(1.0, "full"), (0.5, "half"), (0.25, "quarter")]:
        tau = max(1, int(n * tau_frac))
        h = bl.bl2(clients, dbases, [TopK(k=r) for _ in clients],
                   [Identity() for _ in clients], x0, xs, 2 * S, tau=tau)
        save(args.out, "fig4", f"BL2_tau_{tag}", h)
        h = bl.bl3(clients, [TopK(k=d) for _ in clients],
                   [Identity() for _ in clients], x0, xs, 2 * S, tau=tau)
        save(args.out, "fig4", f"BL3_tau_{tag}", h)

    # Fig 5 (§A.7): bidirectional compression
    rows = {
        "FedNL-BC": bl.bl1(clients, sbases, [TopK(k=d * d // 2, symmetrize=True) for _ in clients],
                           TopK(k=d // 2), x0, xs, S),
        # K=r (not the paper's K=r/2) and p=1/2: the paper's most aggressive
        # A.7 setting diverges on this harder synthetic instance — see
        # EXPERIMENTS.md §Repro notes
        "BL1-BC": bl.bl1(clients, dbases, [TopK(k=r) for _ in clients],
                         TopK(k=r), x0, xs, 2 * S, p=0.5, seed=3),
        "BL2-BC": bl.bl2(clients, dbases, [TopK(k=r) for _ in clients],
                         [TopK(k=r) for _ in clients], x0, xs, 2 * S, p=0.5),
        "BL3-BC": bl.bl3(clients, [TopK(k=d // 2) for _ in clients],
                         [TopK(k=d // 2) for _ in clients], x0, xs, S, p=0.5),
        "DORE": baselines.dore_like(clients, x0, xs, SL, TopK(k=d // 2), TopK(k=d // 2)),
    }
    for k, h in rows.items():
        save(args.out, "fig5", k, h)

    # Fig 6 (§A.8): BL2 vs BL3 under PP + BC
    for p in ([1.0, 1 / 3] if args.fast else [1.0, 1 / 3, 1 / 5]):
        kk = max(1, int(p * d))
        h2 = bl.bl2(clients, sbases, [TopK(k=kk) for _ in clients],
                    [TopK(k=kk) for _ in clients], x0, xs, 2 * S, tau=n // 2, p=p)
        save(args.out, "fig6", f"BL2_p{p:.2f}", h2)
        h3 = bl.bl3(clients, [TopK(k=kk) for _ in clients],
                    [TopK(k=kk) for _ in clients], x0, xs, 2 * S, tau=n // 2, p=p)
        save(args.out, "fig6", f"BL3_p{p:.2f}", h3)

    print(f"wrote CSVs under {args.out}/")


if __name__ == "__main__":
    main()
