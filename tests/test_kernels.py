"""Per-kernel allclose tests against the ref.py oracles, swept over shapes
and dtypes (interpret=True on CPU — deliverable c)."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.ssd_scan import ssd_scan
from repro.kernels.tiled_matmul import matmul
from repro.kernels.topk_threshold import topk_threshold

RNG = np.random.default_rng(0)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(rtol=2e-4, atol=2e-4)


# ----------------------------- matmul ---------------------------------------
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", [(64, 64, 64), (300, 500, 200), (128, 1, 7),
                                   (1, 257, 129), (513, 128, 255)])
def test_matmul_sweep(shape, dtype):
    M, K, N = shape
    a = jnp.asarray(RNG.standard_normal((M, K)), dtype)
    b = jnp.asarray(RNG.standard_normal((K, N)), dtype)
    out = matmul(a, b, bm=128, bn=128, bk=128)
    want = ref.matmul_ref(a, b)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-2 if dtype == jnp.bfloat16 else 1e-4,
                               atol=2e-1 if dtype == jnp.bfloat16 else 1e-3)


@settings(max_examples=10, deadline=None)
@given(m=st.integers(1, 200), k=st.integers(1, 200), n=st.integers(1, 200))
def test_matmul_property(m, k, n):
    a = jnp.asarray(np.random.default_rng(m * k).standard_normal((m, k)), jnp.float32)
    b = jnp.asarray(np.random.default_rng(k * n + 1).standard_normal((k, n)), jnp.float32)
    out = matmul(a, b, bm=64, bn=64, bk=64)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref.matmul_ref(a, b)),
                               rtol=1e-4, atol=1e-3)


# ----------------------------- flash attention ------------------------------
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("cfg", [
    dict(BH=2, Sq=128, Sk=128, hd=64, causal=True, window=None),
    dict(BH=1, Sq=256, Sk=256, hd=32, causal=True, window=64),
    dict(BH=3, Sq=64, Sk=192, hd=64, causal=False, window=None),
    dict(BH=2, Sq=96, Sk=96, hd=128, causal=True, window=17),
])
def test_flash_attention_sweep(cfg, dtype):
    q = jnp.asarray(RNG.standard_normal((cfg["BH"], cfg["Sq"], cfg["hd"])), dtype)
    k = jnp.asarray(RNG.standard_normal((cfg["BH"], cfg["Sk"], cfg["hd"])), dtype)
    v = jnp.asarray(RNG.standard_normal((cfg["BH"], cfg["Sk"], cfg["hd"])), dtype)
    o = flash_attention(q, k, v, causal=cfg["causal"], window=cfg["window"],
                        bq=64, bk=64)
    want = ref.attention_ref(q, k, v, causal=cfg["causal"], window=cfg["window"])
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


def test_flash_gqa_wrapper_matches_blocked_model_attention():
    """ops.attention (GQA layout) vs the model's pure-jnp blocked attention."""
    from repro.models import layers as L
    B, S, H, KVH, hd = 2, 64, 4, 2, 32
    q = jnp.asarray(RNG.standard_normal((B, S, H, hd)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((B, S, KVH, hd)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((B, S, KVH, hd)), jnp.float32)
    o_kernel = ops.attention(q, k, v, causal=True, bq=32, bk=32)
    qg = q.reshape(B, S, KVH, H // KVH, hd)
    o_model = L._blocked_attn(qg, k, v, lambda qi, ki: ki <= qi, 32, None)
    np.testing.assert_allclose(np.asarray(o_kernel), np.asarray(o_model),
                               rtol=2e-4, atol=2e-4)


# ----------------------------- ssd scan -------------------------------------
@pytest.mark.parametrize("dtype", [jnp.float32])
@pytest.mark.parametrize("cfg", [
    dict(BH=2, S=64, hd=16, N=8, chunk=16),
    dict(BH=1, S=128, hd=32, N=16, chunk=32),
    dict(BH=4, S=96, hd=8, N=4, chunk=24),
    dict(BH=1, S=60, hd=16, N=8, chunk=32),  # chunk doesn't divide → shrink
])
def test_ssd_scan_sweep(cfg, dtype):
    rng = np.random.default_rng(cfg["S"])
    x = jnp.asarray(rng.standard_normal((cfg["BH"], cfg["S"], cfg["hd"])), dtype)
    dt = jnp.asarray(rng.random((cfg["BH"], cfg["S"])) * 0.5 + 0.01, jnp.float32)
    A = jnp.asarray(-rng.random(cfg["BH"]) - 0.1, jnp.float32)
    Bm = jnp.asarray(rng.standard_normal((cfg["BH"], cfg["S"], cfg["N"])), dtype)
    Cm = jnp.asarray(rng.standard_normal((cfg["BH"], cfg["S"], cfg["N"])), dtype)
    y = ssd_scan(x, dt, A, Bm, Cm, chunk=cfg["chunk"])
    want = ref.ssd_scan_ref(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y), np.asarray(want),
                               rtol=1e-3, atol=1e-3)


def test_ssd_scan_matches_model_layer_math():
    """Kernel vs the model's _ssd_chunked (two independent implementations)."""
    from repro.models.layers import _ssd_chunked
    rng = np.random.default_rng(7)
    B, S, H, hd, N = 2, 64, 3, 16, 8
    xh = jnp.asarray(rng.standard_normal((B, S, H, hd)), jnp.float32)
    dt = jnp.asarray(rng.random((B, S, H)) * 0.5 + 0.01, jnp.float32)
    A = jnp.asarray(-rng.random(H) - 0.1, jnp.float32)
    Bm = jnp.asarray(rng.standard_normal((B, S, N)), jnp.float32)
    Cm = jnp.asarray(rng.standard_normal((B, S, N)), jnp.float32)
    y_model, _ = _ssd_chunked(xh, dt, A, Bm, Cm, chunk=16)
    # fold heads for the kernel: B,C shared across heads
    xf = xh.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    dtf = dt.transpose(0, 2, 1).reshape(B * H, S)
    Af = jnp.tile(A, B)
    Bf = jnp.repeat(Bm[:, None], H, 1).reshape(B * H, S, N)
    Cf = jnp.repeat(Cm[:, None], H, 1).reshape(B * H, S, N)
    y_kernel = ssd_scan(xf, dtf, Af, Bf, Cf, chunk=16)
    y_kernel = y_kernel.reshape(B, H, S, hd).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(y_kernel), np.asarray(y_model),
                               rtol=1e-3, atol=1e-3)


# ----------------------------- topk -----------------------------------------
@pytest.mark.parametrize("shape,k", [((64, 64), 10), ((100, 100), 50),
                                     ((33, 77), 1), ((128,), 100), ((16, 16, 16), 64)])
def test_topk_threshold_sweep(shape, k):
    """The bitwise-binary-search kernel finds the EXACT k-th largest |x|
    (in f32), and the shared tie-break mask keeps exactly k entries."""
    x = jnp.asarray(np.random.default_rng(k).standard_normal(shape), jnp.float32)
    out, t, kept = topk_threshold(x, k)
    n = int(np.prod(shape))
    kk = min(k, n)
    assert int(kept) == kk
    flat = np.abs(np.asarray(x)).ravel()
    # threshold is exactly the k-th largest magnitude
    assert float(t) == np.sort(flat)[-kk]
    # the kept set: everything strictly above t, none below t
    kept_mask = np.asarray(ref.topk_threshold_ref(x, t)).ravel() != 0
    out_mask = np.asarray(out).ravel() != 0
    assert out_mask[flat > float(t)].all()
    assert not out_mask[~kept_mask].any()


def test_topk_threshold_matches_xla_topk_bitwise():
    """Kernel threshold == `lax.top_k`'s k-th value bitwise — the property
    that makes REPRO_BL_PALLAS=1 selection trajectory-invariant."""
    import jax

    from repro.kernels.topk_threshold import topk_row_threshold

    rng = np.random.default_rng(3)
    a = jnp.asarray(np.abs(rng.standard_normal((7, 333))), jnp.float32)
    for k in (1, 5, 332, 333):
        t_kernel = np.asarray(topk_row_threshold(a, k))
        t_xla = np.asarray(jax.lax.top_k(a, k)[0][:, -1:])
        np.testing.assert_array_equal(t_kernel, t_xla)


def test_topk_threshold_ties_and_zeros():
    tied = jnp.ones((10, 10), jnp.float32)
    out, t, kept = topk_threshold(tied, 7)
    assert int(kept) == 7 and float(t) == 1.0
    out0, t0, kept0 = topk_threshold(jnp.zeros((10, 10), jnp.float32), 7)
    # a zero tensor has threshold 0; the tie-break keeps the first 7 slots
    assert float(t0) == 0.0 and int(kept0) == 7
    # k = 0 keeps nothing (the 'send nothing' endpoint of a bits sweep)
    outz, tz, keptz = topk_threshold(tied, 0)
    assert int(keptz) == 0 and float(jnp.sum(jnp.abs(outz))) == 0.0


def test_topk_compress_sum_fuses_bitwise():
    """The fused compress-then-reduce kernel == the two-pass path (threshold
    → mask → XLA column sum) BITWISE, for edge and interior k — the property
    that lets the sharded engine's uplink pre-reduction ride the flag."""
    from repro.kernels.topk_threshold import (
        keep_mask, topk_compress_sum, topk_row_threshold)

    rng = np.random.default_rng(11)
    v = jnp.asarray(rng.standard_normal((6, 257)), jnp.float32)
    for k in (1, 13, 256, 257, 400):
        dense, s = topk_compress_sum(v, k)
        a = jnp.abs(v)
        kk = max(1, min(k, v.shape[1]))
        t = topk_row_threshold(a, kk)
        want = jnp.where(keep_mask(a, t, kk), v, jnp.zeros_like(v))
        np.testing.assert_array_equal(np.asarray(dense), np.asarray(want))
        np.testing.assert_array_equal(np.asarray(s),
                                      np.asarray(jnp.sum(want, axis=0)))
    with pytest.raises(TypeError, match="f32"):
        topk_compress_sum(v.astype(jnp.bfloat16), 3)


def test_topk_contraction_property():
    """Kernel output satisfies the paper's contraction inequality (Eq. 6)."""
    x = jnp.asarray(np.random.default_rng(1).standard_normal((64, 64)), jnp.float32)
    k = 200
    out, _, kept = topk_threshold(x, k)
    lhs = float(jnp.sum((x - out) ** 2))
    delta = k / x.size
    assert lhs <= (1 - delta) * float(jnp.sum(x ** 2)) + 1e-6


# ----------------------------- composite ops --------------------------------
def test_basis_project_matches_core_basis():
    """Kernel basis projection == core.DataOuterBasis.h coefficients."""
    from repro.core.basis import DataOuterBasis
    rng = np.random.default_rng(5)
    V = jnp.asarray(np.linalg.qr(rng.standard_normal((120, 20)))[0])
    Amat = rng.standard_normal((120, 120))
    Amat = jnp.asarray((Amat + Amat.T) / 2)
    basis = DataOuterBasis(V=V)
    want = np.asarray(basis.h(Amat))[:20, :20]
    got = np.asarray(ops.basis_project(V.astype(jnp.float32),
                                       Amat.astype(jnp.float32)))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_glm_hessian_matches_core_glm():
    from repro.core import glm
    clients = glm.make_synthetic(seed=0, n_clients=1, m=64, d=48, r=16, lam=1e-2)
    c = clients[0]
    x = jnp.zeros(48, jnp.float64)
    w = glm.hess_diag_weights(c, x)
    want = np.asarray(glm.hess(c, x))
    got = np.asarray(ops.glm_hessian(jnp.asarray(c.A, jnp.float32),
                                     jnp.asarray(w, jnp.float32), 1e-2))
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-4)


def test_basis_project_batched_leading_dim():
    """Leading-batch-dim path (the batched BL engine's stacked-client layout)
    must agree per client with the 2-D kernel path and the einsum oracle."""
    rng = np.random.default_rng(7)
    V = jnp.asarray(
        np.stack([np.linalg.qr(rng.standard_normal((96, 24)))[0] for _ in range(4)]),
        jnp.float32,
    )
    A = jnp.asarray(rng.standard_normal((4, 96, 96)), jnp.float32)
    got = np.asarray(ops.basis_project(V, A, bm=32, bn=32, bk=32))
    assert got.shape == (4, 24, 24)
    want = np.asarray(jnp.einsum("ndr,nde,nes->nrs", V, A, V))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)
    for i in range(4):
        one = np.asarray(ops.basis_project(V[i], A[i], bm=32, bn=32, bk=32))
        np.testing.assert_allclose(got[i], one, rtol=1e-5, atol=1e-5)


def test_basis_project_batched_shared_basis():
    """A shared 2-D V broadcasts over the batch of matrices."""
    rng = np.random.default_rng(8)
    V = jnp.asarray(np.linalg.qr(rng.standard_normal((64, 16)))[0], jnp.float32)
    A = jnp.asarray(rng.standard_normal((3, 64, 64)), jnp.float32)
    got = np.asarray(ops.basis_project(V, A, bm=32, bn=32, bk=32))
    want = np.asarray(jnp.einsum("dr,nde,es->nrs", V, A, V))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)
