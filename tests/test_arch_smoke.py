"""Per-architecture smoke tests (deliverable f): a REDUCED variant of each
assigned architecture family runs one forward/train step + prefill + decode on
CPU, asserting output shapes and the absence of NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import model as M
from repro.models.steps import (
    make_prefill_step,
    make_serve_step,
    make_train_step,
    stub_inputs,
)
from repro.optim import adamw_init

B, S = 2, 32


def _batch(cfg, seq, seed=0):
    rng = np.random.default_rng(seed)
    b = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, seq)), jnp.int32)}
    b.update(stub_inputs(cfg, B, jnp.float32))
    return b


@pytest.fixture(scope="module", params=ARCH_IDS)
def arch_setup(request):
    cfg = get_config(request.param).reduced()
    params = M.init_params(jax.random.PRNGKey(0), cfg, jnp.float32)
    return request.param, cfg, params


def test_reduced_config_limits(arch_setup):
    _, cfg, _ = arch_setup
    assert cfg.d_model <= 512
    if cfg.moe:
        assert cfg.moe.n_experts <= 4
    assert cfg.n_layers <= 17  # ≤ one group for patterned archs, else 2


def test_train_step(arch_setup):
    name, cfg, params = arch_setup
    batch = _batch(cfg, S + 1)
    opt = adamw_init(params)
    step = jax.jit(make_train_step(cfg, None, remat=False))
    p2, o2, metrics = step(params, opt, batch)
    loss = float(metrics["loss"])
    assert np.isfinite(loss), (name, loss)
    assert 0.0 < loss < 20.0, (name, loss)
    # params actually moved
    delta = jax.tree.reduce(
        lambda a, x: a + float(jnp.abs(x).sum()),
        jax.tree.map(lambda a, b: a - b, p2, params), 0.0)
    assert delta > 0.0
    # a second step decreases loss on the same batch (sanity of grads)
    _, _, m2 = step(p2, o2, batch)
    assert float(m2["loss"]) < loss + 1e-3


def test_prefill_and_decode(arch_setup):
    name, cfg, params = arch_setup
    max_seq = 64
    cache = M.init_cache(cfg, B, max_seq, jnp.float32)
    batch = _batch(cfg, S)
    logits, cache = jax.jit(make_prefill_step(cfg, None))(params, batch, cache)
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all(), name

    serve = jax.jit(make_serve_step(cfg, None))
    svb = {"tokens": jnp.ones((B, 1), jnp.int32)}
    if cfg.n_enc_layers:
        svb["frames"] = batch["frames"]
    tok, cache2 = serve(params, svb, cache, jnp.asarray(S, jnp.int32))
    assert tok.shape == (B,)
    assert (np.asarray(tok) >= 0).all() and (np.asarray(tok) < cfg.vocab_size).all()
    # cache advanced: at least one leaf changed
    changed = jax.tree.reduce(
        lambda a, x: a + float(jnp.abs(x).sum()),
        jax.tree.map(lambda a, b: (a.astype(jnp.float32) - b.astype(jnp.float32)),
                     cache2, cache), 0.0)
    assert changed > 0.0, name


def test_decode_matches_full_forward():
    """Decode-with-cache must reproduce the full-context forward logits
    (numerical parity of the KV-cache path) — checked on a dense arch."""
    cfg = get_config("granite_20b").reduced()
    params = M.init_params(jax.random.PRNGKey(1), cfg, jnp.float32)
    rng = np.random.default_rng(3)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (1, 9)), jnp.int32)

    # full forward over 9 tokens
    logits_full, _, _ = M.forward(params, cfg, None, toks, remat=False)

    # prefill 8 then decode token 9
    cache = M.init_cache(cfg, 1, 16, jnp.float32)
    _, cache = make_prefill_step(cfg, None)(params, {"tokens": toks[:, :8]}, cache)
    logits_dec, _, _ = M.forward(params, cfg, None, toks[:, 8:9], cache=cache,
                                 cache_pos=jnp.asarray(8, jnp.int32), remat=False)
    np.testing.assert_allclose(
        np.asarray(logits_dec[0, 0]), np.asarray(logits_full[0, -1]),
        rtol=2e-4, atol=2e-4)


def test_decode_matches_full_forward_mamba():
    """Same parity check for the SSM recurrence (chunked scan vs step)."""
    cfg = get_config("mamba2_370m").reduced()
    params = M.init_params(jax.random.PRNGKey(1), cfg, jnp.float32)
    rng = np.random.default_rng(4)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (1, 9)), jnp.int32)
    logits_full, _, _ = M.forward(params, cfg, None, toks, remat=False)
    cache = M.init_cache(cfg, 1, 16, jnp.float32)
    _, cache = make_prefill_step(cfg, None)(params, {"tokens": toks[:, :8]}, cache)
    logits_dec, _, _ = M.forward(params, cfg, None, toks[:, 8:9], cache=cache,
                                 cache_pos=jnp.asarray(8, jnp.int32), remat=False)
    np.testing.assert_allclose(
        np.asarray(logits_dec[0, 0]), np.asarray(logits_full[0, -1]),
        rtol=2e-4, atol=2e-4)


def test_sliding_window_ring_cache_matches_window_mask():
    """Gemma3-style ring cache decode == full cache with window masking."""
    from repro.models.config import LayerSpec, ModelConfig
    cfg = ModelConfig(
        name="win-test", n_layers=2, d_model=64, n_heads=2, n_kv_heads=2,
        head_dim=32, d_ff=128, vocab_size=97,
        group=(LayerSpec(window=4),), max_seq=64)
    params = M.init_params(jax.random.PRNGKey(0), cfg, jnp.float32)
    rng = np.random.default_rng(0)
    T = 12
    toks = jnp.asarray(rng.integers(0, 97, (1, T + 1)), jnp.int32)

    # reference: full forward with window mask
    logits_full, _, _ = M.forward(params, cfg, None, toks, remat=False)

    # ring: prefill 8 (window 4 ring), then decode tokens 8..T
    cache = M.init_cache(cfg, 1, 8, jnp.float32)   # ring size = window = 4
    assert cache["l0"]["k"].shape[2] == 4
    _, cache = make_prefill_step(cfg, None)(params, {"tokens": toks[:, :8]}, cache)
    outs = []
    for t in range(8, T + 1):
        lg, cache, _ = M.forward(params, cfg, None, toks[:, t:t+1], cache=cache,
                                 cache_pos=jnp.asarray(t, jnp.int32), remat=False)
        outs.append(np.asarray(lg[0, 0]))
    ref = np.asarray(logits_full[0, 8:])
    np.testing.assert_allclose(np.stack(outs), ref, rtol=2e-4, atol=2e-4)
