"""REPRO_BL_PALLAS=1 selection-backend parity (subprocess — the env flag is
read at trace time, so each backend gets a fresh process).

The Pallas bitwise-binary-search kernel must return the SAME f32 threshold
as the barrier'd XLA ``top_k`` path; the shared tie-break mask then selects
identical entries, so whole optimization trajectories are bitwise-invariant
to the selection backend.  This is the contract that lets accelerator
deployments flip the flag without re-validating convergence."""
import json
import subprocess
import sys

SCRIPT = r"""
import os
os.environ["REPRO_BL_PALLAS"] = "@FLAG@"
import json
import jax
import jax.numpy as jnp
import numpy as np
from repro.core import bl, glm
from repro.core.basis import orth_basis_from_data
from repro.core.compressors import Identity, TopK, ntopk, topk_keep_mask

clients = glm.make_synthetic(seed=0, n_clients=6, m=30, d=40, r=12, lam=1e-3)
x0 = jnp.zeros(40, jnp.float64)
xs = glm.newton_solve(clients, x0, 20)
bases = [orth_basis_from_data(c.A) for c in clients]
r = bases[0].r

# raw selection: masks straight off the shared routine
X = jnp.asarray(np.random.default_rng(3).standard_normal((6, 1600)))
masks = [np.asarray(topk_keep_mask(X, k)).tolist() for k in (1, 12, 144, 1600)]

# trajectories: deterministic Top-K (block §2.3 layout) and a stochastic
# composed Top-K — both consume the one shared selection implementation
h = bl.bl1(clients, bases, [TopK(k=r)] * 6, Identity(), x0, xs, 12,
           backend="fast")
h2 = bl.bl1(clients, bases, [ntopk(2 * r)] * 6, Identity(), x0, xs, 8,
            seed=5, backend="fast")

# fused compress-then-reduce: under the flag TopK.compress_sum takes the
# one-pass Pallas kernel (f32, non-symmetrized inputs); with it off, the
# two-pass compress + XLA sum.  Dense payload, counts AND the local
# partial sum must agree bitwise across backends.
comp = TopK(k=9)
Xc = jnp.asarray(np.random.default_rng(7).standard_normal((5, 33, 17)),
                 jnp.float32)
dense, counts, s = comp.compress_sum(jax.random.split(jax.random.PRNGKey(0), 5), Xc)
print("RESULT", json.dumps({
    "masks": masks,
    "gaps": h.gaps, "up": h.up_bits, "legs": h.legs,
    "gaps2": h2.gaps, "up2": h2.up_bits,
    "cs_dense": np.asarray(dense).tolist(),
    "cs_sum": np.asarray(s).tolist(),
    "cs_counts": [np.asarray(counts.floats).tolist(),
                  np.asarray(counts.indices).tolist()],
}))
"""


def _run(flag):
    r = subprocess.run(
        [sys.executable, "-c", SCRIPT.replace("@FLAG@", flag)],
        capture_output=True, text=True, timeout=900,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
             "JAX_PLATFORMS": "cpu"})
    lines = [ln for ln in r.stdout.splitlines() if ln.startswith("RESULT")]
    assert lines, r.stdout + r.stderr[-3000:]
    return json.loads(lines[0][len("RESULT "):])


def test_pallas_selection_bitwise_matches_xla_path():
    xla = _run("0")
    pallas = _run("1")
    assert pallas["masks"] == xla["masks"]
    assert pallas["gaps"] == xla["gaps"]
    assert pallas["up"] == xla["up"]
    assert pallas["legs"] == xla["legs"]
    assert pallas["gaps2"] == xla["gaps2"]
    assert pallas["up2"] == xla["up2"]
    assert pallas["cs_dense"] == xla["cs_dense"]
    assert pallas["cs_sum"] == xla["cs_sum"]
    assert pallas["cs_counts"] == xla["cs_counts"]
