"""Comm-layer contracts: WireFormat pricing, composed-format recursion,
CommLedger leg accounting, and the per-leg History streams the round engine
emits."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import bl, comm, glm
from repro.core.basis import orth_basis_from_data
from repro.core.compressors import Identity, RandomDithering, TopK, rtopk


def test_price_simple_wire():
    wf = comm.WireFormat()
    bits = comm.price(wf, comm.Counts(floats=jnp.asarray([3.0, 0.0]),
                                      indices=jnp.asarray([3.0, 1.0])))
    np.testing.assert_array_equal(np.asarray(bits), [3 * 64 + 3 * 32, 32.0])


def test_price_entry_bits_and_composed_recursion():
    inner = comm.WireFormat(entry_bits=5)  # dither s=11: 1 sign + 4 levels
    wire = (comm.WireFormat(), inner)
    counts = (comm.Counts(indices=jnp.asarray([6.0])),
              comm.Counts(floats=jnp.asarray([1.0]), entries=jnp.asarray([6.0])))
    bits = comm.price(wire, counts)
    assert float(bits[0]) == 6 * 32 + 64 + 6 * 5


def test_compressor_declares_wire_not_bits():
    """Wire-format knowledge lives in declarative descriptors, not in
    compressor bodies: pricing the declared wire reproduces the adapter's
    bit count."""
    comp = RandomDithering(s=11)
    assert comp.wire.entry_bits == 5
    x = jnp.asarray(np.random.default_rng(0).standard_normal((2, 32)))
    import jax
    keys = jax.random.split(jax.random.PRNGKey(0), 2)
    _, counts = comp.compress(keys, x)
    np.testing.assert_array_equal(np.asarray(comm.price(comp.wire, counts)),
                                  [64 + 32 * 5] * 2)


def test_ledger_add_is_functional_and_uplink_totals():
    led = comm.CommLedger.create(basis_ship=100.0)
    led2 = led.add(hess_up=10.0, grad_up=5.0)
    led3 = led2.add(model_down=7.0)
    assert float(led.hess_up) == 0.0          # original untouched
    assert float(led3.uplink) == 115.0        # hess + grad + basis
    assert float(led3.downlink) == 7.0


def test_ledger_is_pytree():
    import jax
    led = comm.CommLedger.create(hess_up=1.0)
    leaves = jax.tree_util.tree_leaves(led)
    assert len(leaves) == 4
    led2 = jax.tree.map(lambda a: a * 2, led)
    assert float(led2.hess_up) == 2.0


@pytest.fixture(scope="module")
def problem():
    clients = glm.make_synthetic(seed=0, n_clients=4, m=24, d=24, r=8, lam=1e-3)
    x0 = jnp.zeros(24, jnp.float64)
    xs = glm.newton_solve(clients, x0, 20)
    return clients, x0, xs


def test_history_per_leg_streams(problem):
    """The engine returns one cumulative stream per ledger leg; the legs sum
    to the History's up/down totals (the paper's axes are unchanged)."""
    clients, x0, xs = problem
    bases = [orth_basis_from_data(c.A) for c in clients]
    r = bases[0].r
    h = bl.bl1(clients, bases, [TopK(k=r)] * 4, TopK(k=10), x0, xs, 8,
               p=0.5, seed=1, backend="fast")
    assert set(h.legs) == set(comm.CommLedger.LEGS)
    for name in comm.CommLedger.LEGS:
        assert len(h.legs[name]) == 8
        assert all(b2 >= b1 for b1, b2 in zip(h.legs[name], h.legs[name][1:]))
    total = np.asarray(h.legs["hess_up"]) + np.asarray(h.legs["grad_up"]) \
        + np.asarray(h.legs["basis_ship"])
    np.testing.assert_allclose(total, np.asarray(h.up_bits), rtol=1e-12)
    np.testing.assert_allclose(h.legs["model_down"], h.down_bits, rtol=1e-12)
    # one-time basis shipment: constant stream at rd floats per node
    d = 24
    ship = sum(b.r * d * 64 for b in bases) / 4
    assert h.legs["basis_ship"] == [ship] * 8


def test_stochastic_wire_counts_are_data_dependent(problem):
    """BernoulliLazy-style counts flow through the ledger as traced values:
    a stochastic compressed run has non-constant per-round hess increments."""
    clients, x0, xs = problem
    bases = [orth_basis_from_data(c.A) for c in clients]
    h = bl.bl1(clients, bases, [rtopk(12)] * 4, Identity(), x0, xs, 6,
               alpha=0.5, backend="fast")
    inc = np.diff(np.asarray(h.legs["hess_up"]))
    assert (inc > 0).all()
