"""Property-test harness for the wire-accounting contract (repro.core.comm).

Pins the `WireFormat`/`Counts`/`price()` algebra the whole bit ledger rests
on: pricing is additive over pytree leaves (what lets specs sum per-leaf
counts onto one ledger leg), `with_float_bits` is idempotent and never
touches index/entry widths, `BasisShipSpec` prices exactly what its
factor counts say, `CommLedger.snapshot/restore` round-trips bitwise, and
every method's per-leg ledger streams are mutually consistent (BL1 / BL2 /
BL3 / FedNL-BAG / BL-DNN).

Layout: each algebraic property lives in a plain ``_check_*`` helper.  The
``@given`` wrappers (tagged ``requires_hypothesis``; they run for real in
CI where requirements-dev.txt installs hypothesis) drive the helpers with
randomized cases; deterministic companions sweep a fixed case battery so
the SAME assertions execute locally where conftest.py stubs hypothesis
out.  The method-stream contract is deterministic-only (real engine runs —
randomizing them buys nothing but wall clock).
"""
import jax
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

jax.config.update("jax_enable_x64", True)

from repro.core import baselines, bl, comm, glm  # noqa: E402
from repro.core.basis import (  # noqa: E402
    StandardBasis,
    make_bases,
    orth_basis_from_data,
)
from repro.core.comm import (  # noqa: E402
    CommLedger,
    Counts,
    WireFormat,
    price,
    with_float_bits,
)
from repro.core.compressors import Identity, RankR, TopK  # noqa: E402
from repro.fed import bldnn  # noqa: E402

# --------------------------------------------------------------------------
# fixed wire-tree zoo: plain formats and composed (tuple) trees, with
# nonzero index/entry widths so the "untouched" assertions have teeth
# --------------------------------------------------------------------------
WIRES = (
    WireFormat(),
    WireFormat(float_bits=32),
    WireFormat(float_bits=64, index_bits=16, entry_bits=4.5),
    WireFormat(float_bits=32, index_bits=0, entry_bits=9.0),
    (WireFormat(float_bits=32), WireFormat(64, 16, 9.0)),
    (WireFormat(), (WireFormat(16, 8, 1.0), WireFormat(64, 32, 2.0))),
)


def _flat_wires(wire):
    if isinstance(wire, tuple):
        return [w for leg in wire for w in _flat_wires(leg)]
    return [wire]


def _counts_like(wire, rng):
    """Counts tree mirroring `wire`, with small-integer leaves — integers
    are exact in f64, so additivity can be asserted with == not ≈."""
    if isinstance(wire, tuple):
        return tuple(_counts_like(w, rng) for w in wire)
    return Counts(*(float(rng.integers(0, 512)) for _ in range(3)))


def _add_counts(ca, cb):
    if isinstance(ca, tuple) and not isinstance(ca, Counts):
        return tuple(_add_counts(a, b) for a, b in zip(ca, cb))
    return Counts(ca.floats + cb.floats, ca.indices + cb.indices,
                  ca.entries + cb.entries)


# --------------------------------------------------------------------------
# property: pricing is additive over leaves
# --------------------------------------------------------------------------
def _check_price_additive(wire, seed):
    rng = np.random.default_rng(seed)
    ca, cb = _counts_like(wire, rng), _counts_like(wire, rng)
    per_leaf = price(wire, ca) + price(wire, cb)
    joint = price(wire, _add_counts(ca, cb))
    np.testing.assert_array_equal(np.asarray(per_leaf), np.asarray(joint))


@pytest.mark.requires_hypothesis
@given(wire_i=st.integers(0, len(WIRES) - 1),
       seed=st.integers(0, 2**31 - 1))
@settings(max_examples=60, deadline=None)
def test_price_additive_over_leaves_prop(wire_i, seed):
    """price(w, a) + price(w, b) == price(w, a+b) — the algebra that lets
    BLDNNSpec._bill sum per-leaf counts onto one ledger leg."""
    _check_price_additive(WIRES[wire_i], seed)


def test_price_additive_over_leaves_battery():
    for wire in WIRES:
        for seed in (0, 1, 2, 3):
            _check_price_additive(wire, seed)


def _scale_counts(c, k):
    if isinstance(c, tuple) and not isinstance(c, Counts):
        return tuple(_scale_counts(x, k) for x in c)
    return Counts(c.floats * k, c.indices * k, c.entries * k)


def _check_price_homogeneous(wire, seed, k):
    rng = np.random.default_rng(seed)
    c = _counts_like(wire, rng)
    np.testing.assert_array_equal(
        np.asarray(price(wire, _scale_counts(c, float(k)))),
        np.asarray(k * price(wire, c)))


@pytest.mark.requires_hypothesis
@given(wire_i=st.integers(0, len(WIRES) - 1),
       seed=st.integers(0, 2**31 - 1), k=st.integers(0, 1024))
@settings(max_examples=60, deadline=None)
def test_price_homogeneous_prop(wire_i, seed, k):
    """price(w, k·c) == k·price(w, c) — shipping the same payload k times
    (amortized-refresh billing) costs exactly k× one shipment."""
    _check_price_homogeneous(WIRES[wire_i], seed, k)


def test_price_homogeneous_battery():
    for wire in WIRES:
        for seed, k in ((0, 0), (1, 1), (2, 7), (3, 1024)):
            _check_price_homogeneous(wire, seed, k)


def test_price_structure_mismatch_raises():
    wire = (WireFormat(), WireFormat(32))
    with pytest.raises(ValueError):
        price(wire, Counts(1.0))
    with pytest.raises(ValueError):
        price(wire, (Counts(1.0),))


# --------------------------------------------------------------------------
# property: with_float_bits idempotent, index/entry widths untouched
# --------------------------------------------------------------------------
def _check_with_float_bits(wire, bits):
    once = with_float_bits(wire, bits)
    twice = with_float_bits(once, bits)
    assert once == twice, "with_float_bits must be idempotent"
    for w0, w1 in zip(_flat_wires(wire), _flat_wires(once)):
        assert w1.float_bits == bits
        assert w1.index_bits == w0.index_bits, "index width must not move"
        assert w1.entry_bits == w0.entry_bits, "entry width must not move"


@pytest.mark.requires_hypothesis
@given(wire_i=st.integers(0, len(WIRES) - 1),
       bits=st.sampled_from([8, 16, 32, 64]))
@settings(max_examples=40, deadline=None)
def test_with_float_bits_prop(wire_i, bits):
    """Remapping float width is idempotent and only ever touches floats."""
    _check_with_float_bits(WIRES[wire_i], bits)


def test_with_float_bits_battery():
    for wire in WIRES:
        for bits in (8, 16, 32, 64):
            _check_with_float_bits(wire, bits)


# --------------------------------------------------------------------------
# property: BasisShipSpec prices exactly its declared factor counts
# --------------------------------------------------------------------------
def _check_ship_spec_price(float_bits, col_frac, rows, cols):
    ship = comm.BasisShipSpec(float_bits=float_bits, col_frac=col_frac)
    kept = max(1, min(rows, int(np.ceil(col_frac * rows)))) * cols
    idx_bits = 0 if ship.dense else kept * comm.INDEX_BITS
    if float_bits == 8:
        expect = kept * 8 + cols * 32 + idx_bits   # entries + scales + idx
    else:
        expect = kept * float_bits + idx_bits
    got = float(price(ship.wire, ship.factor_counts(rows, cols)))
    assert got == float(expect), (ship, rows, cols, got, expect)


@pytest.mark.requires_hypothesis
@given(float_bits=st.sampled_from([8, 16, 32, 64]),
       col_frac=st.sampled_from([0.1, 0.25, 0.5, 0.75, 1.0]),
       rows=st.integers(1, 200), cols=st.integers(1, 200))
@settings(max_examples=80, deadline=None)
def test_ship_spec_price_prop(float_bits, col_frac, rows, cols):
    """Shipment bits == the closed-form count: kept values at the wire's
    width, int8 scale floats, kept-row indices when sparsified."""
    _check_ship_spec_price(float_bits, col_frac, rows, cols)


def test_ship_spec_price_battery():
    for fb in (8, 16, 32, 64):
        for cf in (0.1, 0.5, 1.0):
            for rows, cols in ((1, 1), (7, 3), (96, 32), (200, 200)):
                _check_ship_spec_price(fb, cf, rows, cols)


def test_ship_spec_validation():
    with pytest.raises(ValueError):
        comm.BasisShipSpec(float_bits=12)
    with pytest.raises(ValueError):
        comm.BasisShipSpec(col_frac=0.0)
    with pytest.raises(ValueError):
        comm.BasisShipSpec(col_frac=1.5)


# --------------------------------------------------------------------------
# property: CommLedger.snapshot/restore round-trips bitwise
# --------------------------------------------------------------------------
def _check_ledger_roundtrip(vals):
    led = CommLedger.create(**dict(zip(CommLedger.LEGS, vals)))
    led2 = CommLedger.restore(led.snapshot())
    for leg in CommLedger.LEGS:
        a = np.asarray(getattr(led, leg))
        b = np.asarray(getattr(led2, leg))
        assert a.dtype == b.dtype == np.float64
        np.testing.assert_array_equal(a, b)
    # and the derived totals agree exactly
    np.testing.assert_array_equal(np.asarray(led.uplink),
                                  np.asarray(led2.uplink))


@pytest.mark.requires_hypothesis
@given(vals=st.lists(st.floats(min_value=0.0, max_value=1e18,
                               allow_nan=False), min_size=4, max_size=4))
@settings(max_examples=60, deadline=None)
def test_ledger_snapshot_roundtrip_prop(vals):
    """restore(snapshot(led)) is the identity, bitwise, on f64 counters."""
    _check_ledger_roundtrip(vals)


def test_ledger_snapshot_roundtrip_battery():
    cases = [
        (0.0, 0.0, 0.0, 0.0),
        (1.0, 2.0, 3.0, 4.0),
        (0.1, 1e-300, 1e300, 123456789.123456789),
        (2.0 ** 53, 2.0 ** 53 + 2.0, np.pi, np.e),
    ]
    for vals in cases:
        _check_ledger_roundtrip(vals)


def test_ledger_restore_missing_leg_raises():
    snap = CommLedger.create(1.0, 2.0, 3.0, 4.0).snapshot()
    snap.pop("basis_ship")
    with pytest.raises(ValueError):
        CommLedger.restore(snap)


# --------------------------------------------------------------------------
# method-stream contract: every spec's per-leg streams are cumulative and
# sum to the History totals (BL1 / BL2 / BL3 / FedNL-BAG / BL-DNN)
# --------------------------------------------------------------------------
@pytest.fixture(scope="module")
def glm_problem():
    clients = glm.make_synthetic(seed=0, n_clients=4, m=20, d=12, r=4,
                                 lam=1e-3)
    x0 = np.zeros(12)
    xs = glm.newton_solve(clients, x0, iters=20)
    return clients, x0, xs


def _method_histories(glm_problem):
    clients, x0, xs = glm_problem
    n = len(clients)
    data_bases = [orth_basis_from_data(c.A) for c in clients]
    std_bases = [StandardBasis(12) for _ in clients]
    runs = {
        "bl1": bl.bl1(clients, data_bases,
                      [TopK(k=b.r) for b in data_bases], Identity(),
                      x0, xs, steps=6),
        "bl2": bl.bl2(clients, std_bases, [TopK(k=24) for _ in clients],
                      [Identity() for _ in clients], x0, xs, steps=6,
                      tau=2, seed=1),
        "bl3": bl.bl3(clients, [TopK(k=24) for _ in clients],
                      [Identity() for _ in clients], x0, xs, steps=6,
                      tau=2, seed=1),
        "fednl_bag": baselines.fednl_bag(clients, std_bases,
                                         [RankR(r=1) for _ in clients],
                                         x0, xs, steps=6, q=0.5, seed=1),
    }
    del n
    return runs


def _check_leg_streams(name, h):
    assert h.legs is not None, f"{name}: batched engine must emit legs"
    T = len(h.up_bits)
    for leg, stream in h.legs.items():
        s = np.asarray(stream, np.float64)
        assert s.shape == (T,), (name, leg)
        assert np.all(np.diff(s) >= 0.0), (
            f"{name}: leg {leg} must be a CUMULATIVE stream")
    # per-leg streams sum to the History uplink total at EVERY round, and
    # the final total is the sum of round increments on top of round 0
    up = sum(np.asarray(h.legs[leg], np.float64)
             for leg in ("hess_up", "grad_up", "basis_ship"))
    np.testing.assert_array_equal(up, np.asarray(h.up_bits, np.float64),
                                  err_msg=name)
    for leg in CommLedger.LEGS:
        s = np.asarray(h.legs[leg], np.float64)
        np.testing.assert_array_equal(
            s[0] + np.cumsum(np.diff(s)), s[1:], err_msg=(name, leg))


def test_method_leg_streams_glm(glm_problem):
    """BL1/BL2/BL3/FedNL-BAG: per-leg totals equal the sum of the
    per-round stream, every leg cumulative, legs sum to up_bits."""
    for name, h in _method_histories(glm_problem).items():
        _check_leg_streams(name, h)


def test_method_leg_streams_bldnn():
    """BL-DNN: the same stream contract on the pytree engine, plus the
    exact one-time shipment value on basis_ship."""
    batch, p0 = bldnn.make_synthetic_classification(0, 4, 16, 24, 3, 8)
    h = bldnn.run_bldnn(bldnn.make_loss_fn(3), bldnn.make_eval_fn(),
                        p0, batch, 5,
                        bldnn.BLDNNConfig(top_k_frac=0.25, lr=0.05), seed=0)
    _check_leg_streams("bldnn", h)
    ship = make_bases("per_layer_svd", p0).ship_floats() * 32
    np.testing.assert_array_equal(np.asarray(h.legs["basis_ship"]),
                                  np.full(5, ship))
