"""Tests for the declarative experiment subsystem (`repro.exp`).

Covers the contracts the figure-reproduction pipeline depends on:

  * registry completeness — every committed ``results/fig*.csv`` curve is
    producible from a registered experiment (no orphaned hand-made CSVs);
  * a smoke sweep — one small clamped cell per paper figure runs end to
    end, the artifact matches the schema, the running best gap makes
    progress, and the figure CSV has the versioned column layout;
  * resume idempotence — re-running a sweep with existing artifacts skips
    them and reproduces byte-identical CSVs; deleting one artifact re-runs
    exactly that cell and converges to the same bytes;
  * the mid-scan `StreamHook` fires without perturbing trajectories.
"""
import json
import os

import numpy as np
import pytest

from repro.exp import (
    CSV_COLUMNS,
    SCHEMA,
    available_experiments,
    best_gap_stream,
    bits_to_tol,
    build_problem,
    get_experiment,
    run_cell,
    run_experiment,
)
from repro.exp.artifacts import artifact_path, csv_path

RESULTS_DIR = os.path.join(os.path.dirname(__file__), os.pardir, "results")

#: the paper-figure experiments (fig1-xl is excluded from smoke runs: its
#: point is scale, and its registration is covered by the registry tests)
PAPER_EXPS = ["fig1r1", "fig1r2", "fig1r3", "fig2", "fig3", "fig4", "fig5",
              "fig6"]


# --------------------------------------------------------------------------
# registry completeness
# --------------------------------------------------------------------------
def test_every_results_csv_has_a_registered_experiment():
    producible = set()
    for name in available_experiments():
        exp = get_experiment(name)
        for cell in exp.cells:
            producible.add(f"{exp.name}_{cell.name}.csv")
    committed = sorted(f for f in os.listdir(RESULTS_DIR)
                       if f.startswith("fig") and f.endswith(".csv"))
    assert committed, "no committed figure CSVs found?"
    orphans = [f for f in committed if f not in producible]
    assert not orphans, (
        f"results/ CSVs with no registered experiment cell: {orphans}")


def test_all_covers_every_paper_figure_plus_xl():
    names = available_experiments()
    for required in PAPER_EXPS + ["fig1-xl"]:
        assert required in names
    xl = get_experiment("fig1-xl")
    assert "xl" in xl.tags
    assert xl.cells[0].backend == "fast+sharded"
    assert xl.problem.n_clients >= 512 and xl.problem.d >= 1200


def test_registry_lookup_errors():
    with pytest.raises(KeyError):
        get_experiment("nope")
    with pytest.raises(KeyError):
        get_experiment("fig1r1").cell("nope")


# --------------------------------------------------------------------------
# smoke sweep: one clamped cell per figure
# --------------------------------------------------------------------------
@pytest.mark.parametrize("name", PAPER_EXPS)
def test_smoke_cell_artifact_and_csv(name, tmp_path):
    exp = get_experiment(name)
    cell = exp.cells[0]
    out = str(tmp_path / "results")
    adir = str(tmp_path / "artifacts")
    [summary] = run_experiment(exp, out, adir, max_steps=4,
                               cells=[cell.name], log=lambda *_: None)
    assert summary["status"] == "ran"

    with open(artifact_path(adir, exp.name, cell.name, exp.seeds[0])) as f:
        rec = json.load(f)
    assert rec["schema"] == SCHEMA
    for key in ("config_digest", "config", "history", "bits_to_tol"):
        assert key in rec
    h = rec["history"]
    assert len(h["gaps"]) == len(h["up_bits"]) == len(h["down_bits"]) == 4
    if h["legs"] is not None:   # fast-path methods carry per-leg streams
        for leg in ("hess_up", "grad_up", "model_down", "basis_ship"):
            assert len(h["legs"][leg]) == 4
        # uplink total is consistent with its legs
        np.testing.assert_allclose(
            np.asarray(h["up_bits"]),
            np.asarray(h["legs"]["hess_up"]) + np.asarray(h["legs"]["grad_up"])
            + np.asarray(h["legs"]["basis_ship"]))
    assert rec["bits_to_tol"]["reached"] == (summary["mbits_to_tol"] is not None)

    # the running best gap is monotone non-increasing and makes progress
    # (strict progress where 4 rounds suffice — fig1r3/fig3's first cells
    # are rare-gradient-refresh BL2 runs whose round-0 eval already
    # reflects the exact initial Hessian, so they only tie in 4 rounds)
    best = best_gap_stream(h["gaps"])
    assert np.isfinite(h["gaps"][0])
    assert (np.diff(best) <= 0).all()
    assert best[-1] <= h["gaps"][0]
    if name not in ("fig1r3", "fig3"):
        assert best[-1] < h["gaps"][0]

    # figure CSV: versioned column schema, one row per round
    with open(csv_path(out, exp.name, cell.name)) as f:
        lines = f.read().splitlines()
    assert lines[0] == ",".join(CSV_COLUMNS)
    assert len(lines) == 1 + 4


# --------------------------------------------------------------------------
# resume-from-partial-artifacts idempotence
# --------------------------------------------------------------------------
def test_resume_is_idempotent(tmp_path):
    exp = get_experiment("fig1r1")
    out = str(tmp_path / "results")
    adir = str(tmp_path / "artifacts")
    kw = dict(max_steps=3, log=lambda *_: None)

    first = run_experiment(exp, out, adir, **kw)
    assert all(s["status"] == "ran" for s in first)
    blobs = {s["cell"]: open(s["csv"], "rb").read() for s in first}

    # full re-run: everything cached, CSVs byte-identical
    second = run_experiment(exp, out, adir, **kw)
    assert all(s["status"] == "cached" for s in second)
    for s in second:
        assert open(s["csv"], "rb").read() == blobs[s["cell"]]

    # partial artifacts: deleting one cell's JSON re-runs exactly that cell
    victim = first[0]
    os.remove(victim["artifact"])
    third = run_experiment(exp, out, adir, **kw)
    statuses = {s["cell"]: s["status"] for s in third}
    assert statuses.pop(victim["cell"]) == "ran"
    assert set(statuses.values()) == {"cached"}
    # the fixed-seed re-run reproduces the identical curve, bitwise
    assert open(victim["csv"], "rb").read() == blobs[victim["cell"]]

    # a config change (different clamp) invalidates the digest and re-runs
    fourth = run_experiment(exp, out, adir, max_steps=2, log=lambda *_: None)
    assert all(s["status"] == "ran" for s in fourth)


# --------------------------------------------------------------------------
# engine details
# --------------------------------------------------------------------------
def test_stream_hook_fires_and_preserves_trajectory():
    import jax

    from repro.core.rounds import StreamHook

    exp = get_experiment("fig1r1")
    prob = build_problem(exp.problem)
    seen = []
    hook = StreamHook(every=2, callback=lambda t, x, led: seen.append(t))
    h1 = run_cell(exp, exp.cell("BL1"), prob, steps=5, stream=hook)
    jax.effects_barrier()
    h0 = run_cell(exp, exp.cell("BL1"), prob, steps=5)
    assert seen == [0, 2, 4]
    assert h1.gaps == h0.gaps and h1.up_bits == h0.up_bits


def test_stream_hook_works_on_sharded_backend():
    """Attaching a StreamHook under the ShardMapReducer used to be refused
    at dispatch; the chunked driver now emits at chunk boundaries on every
    fast backend — same cadence, bitwise-identical history."""
    import jax

    from repro.core.rounds import StreamHook

    exp = get_experiment("fig1r1")
    prob = build_problem(exp.problem)
    seen = []
    hook = StreamHook(every=1, callback=lambda t, x, led: seen.append(int(t)))
    h1 = run_cell(exp, exp.cell("BL1"), prob, steps=3,
                  backend="fast+sharded", stream=hook)
    jax.effects_barrier()
    h0 = run_cell(exp, exp.cell("BL1"), prob, steps=3,
                  backend="fast+sharded")
    assert seen == [0, 1, 2]
    assert h1.gaps == h0.gaps and h1.up_bits == h0.up_bits


def test_bits_to_tol_reached_flag():
    class H:
        gaps = [1.0, 1e-3, 1e-9]
        up_bits = [0.0, 1e6, 2e6]

    hit = bits_to_tol(H(), 1e-6)
    assert hit.reached and hit.mbits == 2.0
    miss = bits_to_tol(H(), 1e-12)
    assert not miss.reached and miss.mbits == float("inf")
