"""Step-function tests: fused CE parity, microbatch equivalence, M-RoPE."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import layers as L
from repro.models import model as M
from repro.models.steps import _xent, make_fused_vocab_xent, make_train_step
from repro.optim import adamw_init


def test_fused_ce_matches_plain_xent():
    cfg = get_config("granite_20b").reduced()
    rng = np.random.default_rng(0)
    B, S, D = 2, 8, cfg.d_model
    h = jnp.asarray(rng.standard_normal((B, S, D)), jnp.float32)
    W = jnp.asarray(rng.standard_normal((D, cfg.padded_vocab)) * 0.05, jnp.float32)
    labels = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    xent = make_fused_vocab_xent(cfg, None)
    loss_fused = xent(h, W, labels)
    logits = h @ W
    loss_plain = _xent(logits, labels, None)
    np.testing.assert_allclose(float(loss_fused), float(loss_plain), rtol=1e-5)
    # gradients match autodiff through the plain path
    g_f = jax.grad(lambda hh: xent(hh, W, labels))(h)
    g_p = jax.grad(lambda hh: _xent(hh @ W, labels, None))(h)
    np.testing.assert_allclose(np.asarray(g_f), np.asarray(g_p),
                               rtol=1e-4, atol=1e-6)
    gW_f = jax.grad(lambda ww: xent(h, ww, labels))(W)
    gW_p = jax.grad(lambda ww: _xent(h @ ww, labels, None))(W)
    np.testing.assert_allclose(np.asarray(gW_f), np.asarray(gW_p),
                               rtol=1e-4, atol=1e-6)


def test_fused_ce_pad_masking():
    """Padded vocab slots must never receive probability mass."""
    import dataclasses
    cfg = dataclasses.replace(get_config("mamba2_370m").reduced(),
                              name="padtest", vocab_size=500)
    assert cfg.padded_vocab == 512 > cfg.vocab_size
    rng = np.random.default_rng(1)
    h = jnp.asarray(rng.standard_normal((1, 4, cfg.d_model)), jnp.float32)
    W = jnp.asarray(rng.standard_normal((cfg.d_model, cfg.padded_vocab)), jnp.float32)
    labels = jnp.zeros((1, 4), jnp.int32)
    xent = make_fused_vocab_xent(cfg, None)
    # gradient wrt W in pad columns comes only from softmax mass ≈ exp(-1e30)=0
    gW = jax.grad(lambda ww: xent(h, ww, labels))(W)
    pad_grad = np.abs(np.asarray(gW[:, cfg.vocab_size:])).max()
    assert pad_grad < 1e-12


@pytest.mark.parametrize("mb", [2, 4])
def test_microbatch_equivalence(mb):
    """microbatch=k must produce the same update as microbatch=1."""
    cfg = get_config("codeqwen15_7b").reduced()
    params = M.init_params(jax.random.PRNGKey(0), cfg, jnp.float32)
    rng = np.random.default_rng(2)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (8, 17)),
                                   jnp.int32)}
    opt = adamw_init(params)
    p1, _, m1 = jax.jit(make_train_step(cfg, None, remat=False))(params, opt, batch)
    pk, _, mk = jax.jit(make_train_step(cfg, None, remat=False,
                                        microbatch=mb))(params, opt, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(mk["loss"]), rtol=1e-5)
    d = jax.tree.map(lambda a, b: float(jnp.abs(a - b).max()), p1, pk)
    # Adam re-scales tiny fp-ordering grad diffs; loss parity is the tight check
    assert max(jax.tree.leaves(d)) < 1e-3


def test_mrope_text_equals_rope():
    """With equal position components, M-RoPE must reduce to 1-D RoPE."""
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.standard_normal((2, 6, 4, 64)), jnp.float32)
    pos1 = jnp.broadcast_to(jnp.arange(6)[None], (2, 6))
    pos3 = jnp.broadcast_to(pos1[None], (3, 2, 6))
    a = L.apply_rope(x, pos1, 10_000.0, mrope=False)
    b = L.apply_rope(x, pos3, 10_000.0, mrope=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-6)


def test_mrope_components_differ():
    """Different h/w components must change the rotation (VLM positions)."""
    rng = np.random.default_rng(4)
    x = jnp.asarray(rng.standard_normal((1, 4, 2, 48)), jnp.float32)
    pos_text = jnp.broadcast_to(jnp.arange(4)[None, None], (3, 1, 4))
    pos_img = pos_text.at[1].add(7).at[2].add(3)
    a = L.apply_rope(x, pos_text, 10_000.0, mrope=True)
    b = L.apply_rope(x, pos_img, 10_000.0, mrope=True)
    assert float(jnp.abs(a - b).max()) > 1e-3


def test_windowed_kv_slicing_matches_full_masking():
    """_blocked_attn with window slicing == full-sequence masked reference."""
    rng = np.random.default_rng(5)
    B, S, KVH, rep, hd, W = 2, 64, 2, 2, 16, 8
    q = jnp.asarray(rng.standard_normal((B, S, KVH, rep, hd)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S, KVH, hd)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, KVH, hd)), jnp.float32)
    mask = lambda qi, ki: (ki <= qi) & (ki > qi - W)
    out_sliced = L._blocked_attn(q, k, v, mask, 16, None, window=W)
    out_masked = L._blocked_attn(q, k, v, mask, 16, None, window=None)
    np.testing.assert_allclose(np.asarray(out_sliced), np.asarray(out_masked),
                               rtol=1e-5, atol=1e-5)
