"""Two-tier AOT program cache (`repro.core.progcache`).

The contract under test (ISSUE 10 acceptance): serve programs dispatched
through the cache produce trajectories bitwise-identical to the uncached
fast path whether the executable was freshly compiled (miss) or
deserialized from disk (hit), on both reducers; and EVERY failure mode —
corrupt payload, torn manifest, version/environment skew — falls back to a
live compile that is itself bitwise-identical, never an error and never
different bits.
"""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import batched, comm, progcache, rounds
from repro.core.compressors import Identity, TopK

jax.config.update("jax_enable_x64", True)


@pytest.fixture(scope="module")
def problem():
    from repro.core import glm
    from repro.core.basis import orth_basis_from_data

    clients = glm.make_synthetic(seed=0, n_clients=6, m=24, d=18, r=6,
                                 lam=1e-3)
    bases = [orth_basis_from_data(c.A) for c in clients]
    x0 = jnp.zeros(18, jnp.float64)
    spec, batch, basisb = batched.bl2_setup(
        clients, bases, [TopK(k=6) for _ in clients],
        [Identity() for _ in clients], tau=3)
    return spec, batch, basisb, x0


@pytest.fixture
def cache_dir(tmp_path):
    """A fresh active cache per test; the global active-cache slot and the
    in-process executable memo are scrubbed on the way out so later tests
    (here and in other files) see the pre-subsystem fast path."""
    root = str(tmp_path / "progcache")
    rounds.clear_aot_memo()
    progcache.activate(root, persistent_compilation_cache=False)
    yield root
    progcache.deactivate()
    rounds.clear_aot_memo()


def _serve_rounds(problem, *, sharded=False, t1=8, chunk=4):
    """Drive [0, t1) in chunks from a fresh carry; returns concrete
    (trajectory, per-leg bits, events) arrays."""
    spec, batch, basisb, x0 = problem
    root = jax.random.PRNGKey(7)
    carry = rounds.init_serve_carry(spec, batch, basisb, x0, sharded=sharded)
    xs, evs = [], []
    led = {leg: [] for leg in comm.CommLedger.LEGS}
    t = 0
    while t < t1:
        steps = min(chunk, t1 - t)
        carry, ys = rounds.run_chunk(spec, batch, basisb, x0, carry, t,
                                     steps, root, sharded=sharded)
        xs.append(np.asarray(ys[0]))
        evs.append(np.asarray(ys[2]))
        for leg in comm.CommLedger.LEGS:
            led[leg].append(np.asarray(getattr(ys[1], leg)))
        t += steps
    return (np.concatenate(xs),
            {k: np.concatenate(v) for k, v in led.items()},
            np.concatenate(evs))


def _assert_streams_equal(a, b):
    np.testing.assert_array_equal(a[0], b[0])
    for leg in comm.CommLedger.LEGS:
        np.testing.assert_array_equal(a[1][leg], b[1][leg])
    np.testing.assert_array_equal(a[2], b[2])


def _uncached_reference(problem, sharded):
    progcache.deactivate()
    rounds.clear_aot_memo()
    return _serve_rounds(problem, sharded=sharded)


def _entry_files(cache_dir, kind, ext):
    return sorted(f for f in os.listdir(cache_dir)
                  if f.startswith(kind + "-") and f.endswith(ext))


# ==========================================================================
# Hit == miss == uncached, both reducers
# ==========================================================================
@pytest.mark.parametrize("sharded", [False, True],
                         ids=["vmap", "shard_map"])
def test_miss_then_hit_bitwise_equal_uncached(problem, tmp_path, sharded):
    ref = _uncached_reference(problem, sharded)

    root = str(tmp_path / "pc")
    cache = progcache.activate(root, persistent_compilation_cache=False)
    try:
        rounds.clear_aot_memo()
        missed = _serve_rounds(problem, sharded=sharded)
        assert cache.stats["miss"] > 0 and cache.stats["hit"] == 0
        assert _entry_files(root, "serve_chunk", ".bin"), \
            "miss did not persist the chunk executable"

        # drop the in-process memo: the next dispatch must come back
        # through the on-disk cache as a deserialize hit
        rounds.clear_aot_memo()
        hit = _serve_rounds(problem, sharded=sharded)
        assert cache.stats["hit"] > 0
        assert cache.stats["miss"] == cache.stats["absent"]  # no new class

        _assert_streams_equal(missed, ref)
        _assert_streams_equal(hit, ref)
    finally:
        progcache.deactivate()
        rounds.clear_aot_memo()


# ==========================================================================
# Every miss class falls back to a live compile with identical bits
# ==========================================================================
def _populated(problem, cache_dir):
    out = _serve_rounds(problem)
    rounds.clear_aot_memo()
    return out


def test_corrupt_payload_falls_back_bitwise(problem, cache_dir):
    ref = _populated(problem, cache_dir)
    for f in _entry_files(cache_dir, "serve_chunk", ".bin"):
        path = os.path.join(cache_dir, f)
        blob = bytearray(open(path, "rb").read())
        blob[len(blob) // 2] ^= 0xFF
        open(path, "wb").write(bytes(blob))

    again = _serve_rounds(problem)
    assert progcache.active().stats["corrupt"] > 0
    _assert_streams_equal(again, ref)


def test_torn_manifest_falls_back_bitwise(problem, cache_dir):
    ref = _populated(problem, cache_dir)
    for f in _entry_files(cache_dir, "serve_chunk", ".json"):
        path = os.path.join(cache_dir, f)
        raw = open(path, "rb").read()
        open(path, "wb").write(raw[: len(raw) // 2])   # torn mid-write

    again = _serve_rounds(problem)
    assert progcache.active().stats["corrupt"] > 0
    _assert_streams_equal(again, ref)


def test_version_skew_falls_back_bitwise(problem, cache_dir):
    ref = _populated(problem, cache_dir)
    for f in _entry_files(cache_dir, "serve_chunk", ".json"):
        path = os.path.join(cache_dir, f)
        manifest = json.load(open(path))
        manifest["env"]["jax"] = "0.0.0-somebody-upgraded"
        json.dump(manifest, open(path, "w"))

    again = _serve_rounds(problem)
    assert progcache.active().stats["skew"] > 0
    _assert_streams_equal(again, ref)


def test_schema_version_bump_falls_back(problem, cache_dir):
    ref = _populated(problem, cache_dir)
    for f in _entry_files(cache_dir, "serve_chunk", ".json"):
        path = os.path.join(cache_dir, f)
        manifest = json.load(open(path))
        manifest["schema"] = "repro.progcache/entry@0"
        json.dump(manifest, open(path, "w"))

    again = _serve_rounds(problem)
    assert progcache.active().stats["skew"] > 0
    _assert_streams_equal(again, ref)


# ==========================================================================
# Cache keys
# ==========================================================================
def test_pallas_flag_keys_distinct_entries(monkeypatch):
    monkeypatch.setenv("REPRO_BL_PALLAS", "0")
    k0 = progcache.entry_key(("serve_chunk", "specfp"))
    monkeypatch.setenv("REPRO_BL_PALLAS", "1")
    k1 = progcache.entry_key(("serve_chunk", "specfp"))
    assert k0 != k1, ("REPRO_BL_PALLAS reroutes top-k selection, so the "
                      "two program families must land under distinct keys")


def test_fingerprint_deterministic_and_discriminating(problem):
    spec = problem[0]
    a, b = progcache.fingerprint(spec), progcache.fingerprint(spec)
    assert a == b
    # rebuild an equivalent spec from scratch: same fingerprint even
    # though every closure/callable inside it is a fresh object
    from repro.core import glm
    from repro.core.basis import orth_basis_from_data

    clients = glm.make_synthetic(seed=0, n_clients=6, m=24, d=18, r=6,
                                 lam=1e-3)
    bases = [orth_basis_from_data(c.A) for c in clients]
    spec2, _, _ = batched.bl2_setup(
        clients, bases, [TopK(k=6) for _ in clients],
        [Identity() for _ in clients], tau=3)
    assert progcache.fingerprint(spec2) == a
    spec3, _, _ = batched.bl2_setup(
        clients, bases, [TopK(k=6) for _ in clients],
        [Identity() for _ in clients], tau=2)
    assert progcache.fingerprint(spec3) != a


def test_env_fingerprint_is_hostname_free():
    import platform
    import socket

    fp = progcache.env_fingerprint()
    blob = json.dumps(fp)
    for ident in (socket.gethostname(), platform.node()):
        if ident:
            assert ident not in blob
    assert {"jax", "jaxlib", "backend", "device_count",
            "pallas"} <= set(fp)


# ==========================================================================
# Entry validation (tools/schema_diff.py --progcache rides on this)
# ==========================================================================
def test_validate_entry_accepts_real_and_rejects_corrupt(problem, cache_dir):
    _populated(problem, cache_dir)
    manifests = (_entry_files(cache_dir, "serve_init", ".json")
                 + _entry_files(cache_dir, "serve_chunk", ".json"))
    assert manifests
    for f in manifests:
        assert progcache.validate_entry(os.path.join(cache_dir, f)) == []

    target = os.path.join(cache_dir, manifests[0])
    bpath = target[: -len(".json")] + ".bin"
    open(bpath, "ab").write(b"junk")
    problems = progcache.validate_entry(target)
    assert problems and "sha256 mismatch" in problems[0]


def test_from_env_respects_disable(monkeypatch, tmp_path):
    monkeypatch.setenv("REPRO_PROGCACHE_DIR", str(tmp_path / "envpc"))
    monkeypatch.setenv("REPRO_PROGCACHE", "0")
    assert progcache.from_env() is None
    monkeypatch.setenv("REPRO_PROGCACHE", "1")
    cache = progcache.from_env()
    try:
        assert cache is not None
        assert cache.root == str(tmp_path / "envpc")
    finally:
        progcache.deactivate()
        # from_env also pointed jax's tier-2 cache at the tmp dir; undo so
        # later tests don't persist compiles into a deleted directory
        jax.config.update("jax_compilation_cache_dir", None)
        from jax._src import compilation_cache as _cc

        _cc.reset_cache()
