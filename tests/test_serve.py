"""Service-loop driver + checkpoint/restore bit-exactness.

The contract under test (ISSUE 6 acceptance): a serve run interrupted at
ANY point — graceful chunk boundary or kill -9 mid-run — and resumed from
its latest valid checkpoint produces a trajectory, `History.events` stream
and per-leg `CommLedger` bit accounting bit-exactly equal to the
uninterrupted run at the same seed, on both aggregation backends."""
import json
import os
import signal
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import batched, comm, faults, glm, rounds
from repro.core.compressors import Identity, TopK
from repro.exp import artifacts

jax.config.update("jax_enable_x64", True)


@pytest.fixture(scope="module")
def problem():
    clients = glm.make_synthetic(seed=0, n_clients=6, m=24, d=18, r=6,
                                 lam=1e-3)
    from repro.core.basis import orth_basis_from_data

    bases = [orth_basis_from_data(c.A) for c in clients]
    x0 = jnp.zeros(18, jnp.float64)
    spec, batch, basisb = batched.bl2_setup(
        clients, bases, [TopK(k=6) for _ in clients],
        [Identity() for _ in clients], tau=3)
    return spec, batch, basisb, x0


def _chunks(spec, batch, basisb, x0, carry, plan, t0, t1, chunk, root_key,
            sharded=False):
    """Drive [t0, t1) in `chunk`-round pieces; returns (carry, streams)."""
    xs, evs = [], []
    led = {leg: [] for leg in comm.CommLedger.LEGS}
    t = t0
    while t < t1:
        steps = min(chunk, t1 - t)
        avail = None if plan is None else plan.schedule(t, steps)[0]
        carry, ys = rounds.run_chunk(spec, batch, basisb, x0, carry, t,
                                     steps, root_key, avail=avail,
                                     sharded=sharded)
        xs.append(np.asarray(ys[0]))
        evs.append(np.asarray(ys[2]))
        for leg in comm.CommLedger.LEGS:
            led[leg].append(np.asarray(getattr(ys[1], leg)))
        t += steps
    return carry, (np.concatenate(xs),
                   {k: np.concatenate(v) for k, v in led.items()},
                   np.concatenate(evs))


def _assert_streams_equal(a, b):
    np.testing.assert_array_equal(a[0], b[0])          # trajectory
    for leg in comm.CommLedger.LEGS:                   # per-leg bits
        np.testing.assert_array_equal(a[1][leg], b[1][leg])
    np.testing.assert_array_equal(a[2], b[2])          # events


@pytest.mark.parametrize("sharded", [False, True],
                         ids=["vmap", "shard_map"])
def test_checkpoint_roundtrip_resume_bitwise(problem, tmp_path, sharded):
    """save → restore → run ≡ uninterrupted, on both reducers, including
    the CommLedger counters and the PRNG key riding the checkpoint."""
    spec, batch, basisb, x0 = problem
    root = jax.random.PRNGKey(5)
    plan = faults.FaultPlan(n=batch.n, dropout_p=0.3, seed=3)
    kw = dict(sharded=sharded)

    c0 = rounds.init_serve_carry(spec, batch, basisb, x0, **kw)
    _, ref = _chunks(spec, batch, basisb, x0, c0, plan, 0, 14, 14, root, **kw)

    # run 6 rounds, checkpoint through the artifact layer, restore, finish
    # (fresh carry: run_chunk DONATES its carry argument, so c0's buffers
    # died inside the reference run above)
    c0 = rounds.init_serve_carry(spec, batch, basisb, x0, **kw)
    mid, head = _chunks(spec, batch, basisb, x0, c0, plan, 0, 6, 3, root, **kw)
    artifacts.save_checkpoint(
        str(tmp_path), t=6,
        carry_leaves=[np.asarray(l) for l in jax.tree_util.tree_leaves(mid)],
        streams={"eval_x": head[0], "events": head[2],
                 **{f"led_{k}": v for k, v in head[1].items()}},
        root_key=np.asarray(root), config_digest="test")
    ck = artifacts.load_checkpoint(str(tmp_path), config_digest="test")
    assert ck is not None and ck["t"] == 6
    treedef = jax.tree_util.tree_structure(c0)
    restored = jax.tree_util.tree_unflatten(
        treedef, [jnp.asarray(l) for l in ck["carry_leaves"]])
    root_restored = jnp.asarray(ck["root_key"])
    np.testing.assert_array_equal(np.asarray(root), np.asarray(root_restored))

    _, tail = _chunks(spec, batch, basisb, x0, restored, plan, 6, 14, 5,
                      root_restored, **kw)
    resumed = (np.concatenate([ck["streams"]["eval_x"], tail[0]]),
               {k: np.concatenate([ck["streams"][f"led_{k}"], tail[1][k]])
                for k in comm.CommLedger.LEGS},
               np.concatenate([ck["streams"]["events"], tail[2]]))
    _assert_streams_equal(resumed, ref)


def test_vmap_and_sharded_serve_bitwise_equal(problem):
    """The exact=True cross-backend contract extends to the chunked driver:
    same chunks, same faults, bitwise-equal streams."""
    spec, batch, basisb, x0 = problem
    root = jax.random.PRNGKey(1)
    plan = faults.FaultPlan(n=batch.n, dropout_p=0.25,
                            outages=(faults.Outage(2, 3, 9),), seed=7)
    cv = rounds.init_serve_carry(spec, batch, basisb, x0, sharded=False)
    cs = rounds.init_serve_carry(spec, batch, basisb, x0, sharded=True)
    for lv, ls in zip(jax.tree_util.tree_leaves(cv),
                      jax.tree_util.tree_leaves(cs)):
        np.testing.assert_array_equal(np.asarray(lv), np.asarray(ls))
    _, sv = _chunks(spec, batch, basisb, x0, cv, plan, 0, 10, 4, root,
                    sharded=False)
    _, ss = _chunks(spec, batch, basisb, x0, cs, plan, 0, 10, 4, root,
                    sharded=True)
    _assert_streams_equal(sv, ss)


def test_commledger_snapshot_restore_bitwise():
    led = comm.CommLedger.create(hess_up=1.25, basis_ship=3e7)
    led = led.add(grad_up=0.1, model_down=7.0)
    snap = led.snapshot()
    back = comm.CommLedger.restore(snap)
    for leg in comm.CommLedger.LEGS:
        np.testing.assert_array_equal(np.asarray(getattr(led, leg)),
                                      np.asarray(getattr(back, leg)))
    with pytest.raises(ValueError, match="missing legs"):
        comm.CommLedger.restore({"hess_up": 0.0})


def test_load_checkpoint_skips_corrupt_and_mismatched(tmp_path):
    def save(t):
        artifacts.save_checkpoint(
            str(tmp_path), t=t, carry_leaves=[np.arange(3.0) + t],
            streams={"eval_x": np.zeros((t, 2))},
            root_key=np.zeros(2, np.uint32), config_digest="d1", keep=10)

    save(5)
    save(10)
    # newest payload torn mid-write → loader must fall back to t=5
    npz = os.path.join(str(tmp_path), "ckpt-00000010.npz")
    with open(npz, "r+b") as f:
        f.truncate(os.path.getsize(npz) // 2)
    ck = artifacts.load_checkpoint(str(tmp_path), config_digest="d1")
    assert ck is not None and ck["t"] == 5
    np.testing.assert_array_equal(ck["carry_leaves"][0], np.arange(3.0) + 5)
    # a different serve config must not resume from these checkpoints
    assert artifacts.load_checkpoint(str(tmp_path),
                                     config_digest="other") is None
    # empty dir → None
    assert artifacts.load_checkpoint(str(tmp_path / "void")) is None


def test_checkpoint_pruning(tmp_path):
    for t in (1, 2, 3, 4):
        artifacts.save_checkpoint(
            str(tmp_path), t=t, carry_leaves=[np.zeros(1)], streams={},
            root_key=np.zeros(2, np.uint32), config_digest="d", keep=2)
    assert [t for t, _ in artifacts.list_checkpoints(str(tmp_path))] == [3, 4]


# ---------------------------------------------------------------- fed_serve
def test_fed_serve_refuses_faults_on_synchronous_method(tmp_path):
    """bl1 models a fully synchronous fleet (supports_faults=False) —
    injecting a non-trivial fault plan must refuse, not silently ignore."""
    from repro.launch import fed_serve

    plan = faults.FaultPlan(n=10, dropout_p=0.5)
    with pytest.raises(SystemExit, match="synchronous"):
        fed_serve.serve(exp_name="fig1r1", cell_name="BL1",
                        ckpt_dir=str(tmp_path), plan=plan, max_rounds=2)


def test_fed_serve_inprocess_graceful_degradation(tmp_path):
    """Outage of most of the fleet → rounds degrade (events flagged), the
    loop keeps serving, and the record counts the degraded rounds."""
    from repro.launch import fed_serve

    plan = faults.FaultPlan(
        n=10, outages=tuple(faults.Outage(c, 2, 6) for c in range(9)))
    rec = fed_serve.serve(exp_name="fig4", cell_name="BL2_tau_half", seed=1,
                          chunk=4, max_rounds=8, ckpt_dir=str(tmp_path),
                          plan=plan, log=lambda *a: None)
    ev = rec["history"]["events"]
    assert len(ev) == 8
    assert all(isinstance(e, int) for e in ev)
    assert any(e & rounds.EVENT_DEGRADED for e in ev[2:6])
    assert ev[:2] == [0, 0] and ev[6:] == [0, 0]     # healthy outside window
    assert rec["degraded_rounds"] == sum(1 for e in ev if e)
    assert rec["schema"] == artifacts.SERVE_SCHEMA


_ENV = {"PYTHONPATH": "src", "PATH": os.environ.get("PATH", "/usr/bin:/bin"),
        "JAX_PLATFORMS": "cpu", "HOME": os.environ.get("HOME", "/tmp")}


def _serve_cli(tmp, *extra):
    return subprocess.run(
        [sys.executable, "-m", "repro.launch.fed_serve", "--exp", "fig4",
         "--cell", "BL2_tau_half", "--seed", "3", "--max-rounds", "30",
         "--chunk", "6", "--dropout-p", "0.2", "--fault-seed", "11",
         *extra],
        env=_ENV, capture_output=True, text=True, timeout=900,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))))


def test_fed_serve_kill9_resume_bitwise(tmp_path):
    """The acceptance scenario end-to-end through the CLI: SIGKILL mid-run
    (after round 14, losing the in-flight chunk), restart, and the final
    record — trajectory, events, per-leg bits — is byte-identical to the
    uninterrupted reference."""
    ref_json = str(tmp_path / "ref.json")
    res_json = str(tmp_path / "res.json")
    r = _serve_cli(tmp_path, "--ckpt-dir", str(tmp_path / "ref"),
                   "--result", ref_json)
    assert r.returncode == 0, r.stderr[-2000:]

    r = _serve_cli(tmp_path, "--ckpt-dir", str(tmp_path / "crash"),
                   "--crash-after-round", "14")
    assert r.returncode == -signal.SIGKILL, (r.returncode, r.stderr[-500:])
    # the kill must have actually cost progress: newest checkpoint < 30
    ts = [t for t, _ in artifacts.list_checkpoints(str(tmp_path / "crash"))]
    assert ts and max(ts) < 30

    r = _serve_cli(tmp_path, "--ckpt-dir", str(tmp_path / "crash"),
                   "--result", res_json)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "resumed from checkpoint" in r.stdout

    with open(ref_json) as f:
        ref = json.load(f)
    with open(res_json) as f:
        res = json.load(f)
    assert res["meta"]["resumed_from"] == max(ts)
    ref.pop("meta")
    res.pop("meta")
    assert ref == res    # bit-exact: gaps, events, every ledger leg


def test_schema_diff_validates_ckpt_dir(tmp_path):
    ckpt_dir = tmp_path / "ck"
    artifacts.save_checkpoint(
        str(ckpt_dir), t=3, carry_leaves=[np.zeros((2, 2))],
        streams={"eval_x": np.zeros((3, 2)), "events": np.zeros(3, np.int32)},
        root_key=np.zeros(2, np.uint32), config_digest="abc")
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    tool = os.path.join(repo, "tools", "schema_diff.py")
    r = subprocess.run([sys.executable, tool, "--ckpt", str(ckpt_dir)],
                       capture_output=True, text=True, timeout=120)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "ckpt schema ok" in r.stdout
    # corrupt the payload → digest mismatch must be reported
    npz = ckpt_dir / "ckpt-00000003.npz"
    npz.write_bytes(b"garbage")
    r = subprocess.run([sys.executable, tool, "--ckpt", str(ckpt_dir)],
                       capture_output=True, text=True, timeout=120)
    assert r.returncode == 1
    assert "sha256 mismatch" in r.stdout
