"""Fault-injection layer + participation edge cases.

Covers the `rounds.participation` τ validation/clamping contract and its
availability-masked fallback path, the determinism/chunk-invariance of
`repro.core.faults` schedules, and chunk-boundary StreamHook emission on
the sharded backend (cadence + trajectory non-perturbation)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import faults, rounds
from repro.core.rounds import (
    EVENT_ALL_DOWN,
    EVENT_DEGRADED,
    EVENT_FORCED,
    EVENT_NONE,
    VmapReducer,
    participation,
)

N = 10
R = VmapReducer(n=N)


# ---------------------------------------------------------------- τ edges
def test_participation_tau_zero_raises():
    with pytest.raises(ValueError, match="τ ≥ 1"):
        participation(R, jax.random.PRNGKey(0), 0)


def test_participation_tau_negative_raises():
    with pytest.raises(ValueError, match="τ ≥ 1"):
        participation(R, jax.random.PRNGKey(0), -3)


def test_participation_tau_above_n_clamps_to_full():
    """τ > n clamps to full participation — and is bitwise-identical to
    τ = n (Bernoulli(p ≥ 1) is always-true either way)."""
    key = jax.random.PRNGKey(7)
    over, ev_over = participation(R, key, N + 5)
    full, ev_full = participation(R, key, N)
    assert bool(jnp.all(over))
    np.testing.assert_array_equal(np.asarray(over), np.asarray(full))
    assert int(ev_over) == int(ev_full) == EVENT_NONE


def test_participation_unmasked_matches_allones_avail_bitwise():
    """avail of all-ones must reproduce the unmasked path bitwise — mask
    AND event — so attaching a trivial fault layer changes nothing."""
    ones = jnp.ones((N,), bool)
    for seed in range(40):
        key = jax.random.PRNGKey(seed)
        m0, e0 = participation(R, key, 3)
        m1, e1 = participation(R, key, 3, avail=ones)
        np.testing.assert_array_equal(np.asarray(m0), np.asarray(m1))
        assert int(e0) == int(e1)


def test_participation_forced_event_fires_without_avail():
    """With τ=1 some seed draws an empty cohort; the fallback must force
    exactly one client and flag EVENT_FORCED."""
    forced_seen = False
    for seed in range(200):
        mask, ev = participation(R, jax.random.PRNGKey(seed), 1)
        assert int(jnp.sum(mask)) >= 1     # never an empty round
        if int(ev) & EVENT_FORCED:
            forced_seen = True
            assert int(jnp.sum(mask)) == 1
    assert forced_seen, "no forced fallback in 200 draws of τ=1 — suspicious"


# ------------------------------------------------- availability masking
def test_participation_avail_removes_down_clients():
    avail = jnp.asarray([True] * 5 + [False] * 5)
    for seed in range(50):
        mask, _ = participation(R, jax.random.PRNGKey(seed), 8, avail=avail)
        assert not bool(jnp.any(mask[5:])), "down client participated"


def test_participation_all_zero_draw_forces_one_available_client():
    """When faults wipe the whole drawn cohort, the fallback must force
    exactly one client from the AVAILABLE set and flag it."""
    avail = jnp.asarray([False] * 9 + [True])   # only client 9 is up
    hit = 0
    for seed in range(50):
        mask, ev = participation(R, jax.random.PRNGKey(seed), 5, avail=avail)
        m = np.asarray(mask)
        assert m.sum() == 1 and m[9], "fallback must pick the one up client"
        if int(ev) & EVENT_FORCED:
            hit += 1
        assert int(ev) & EVENT_DEGRADED or int(jnp.sum(mask)) >= 1
    assert hit > 0


def test_participation_all_down_stalls_with_event():
    avail = jnp.zeros((N,), bool)
    mask, ev = participation(R, jax.random.PRNGKey(0), 5, avail=avail)
    assert not bool(jnp.any(mask))
    assert int(ev) & EVENT_ALL_DOWN


@given(seed=st.integers(0, 2**31 - 1), tau=st.integers(1, 2 * N))
@settings(max_examples=60, deadline=None)
def test_participation_never_empty_when_any_client_up(seed, tau):
    """Property: for every (seed, τ) and a one-client availability mask,
    the round still has exactly that participant (the force-one-client
    fallback under an arbitrarily bad draw)."""
    avail = jnp.asarray([True] + [False] * (N - 1))
    mask, _ = participation(R, jax.random.PRNGKey(seed), tau, avail=avail)
    m = np.asarray(mask)
    assert m.sum() == 1 and m[0]


@given(seed=st.integers(0, 2**31 - 1), tau=st.integers(1, N))
@settings(max_examples=60, deadline=None)
def test_participation_mask_subset_of_avail(seed, tau):
    avail = jnp.asarray(
        np.random.default_rng(seed ^ 0x5EED).random(N) < 0.5)
    mask, ev = participation(R, jax.random.PRNGKey(seed), tau, avail=avail)
    m, a = np.asarray(mask), np.asarray(avail)
    assert not (m & ~a).any()
    if not a.any():
        assert int(ev) & EVENT_ALL_DOWN and not m.any()
    else:
        assert m.sum() >= 1


# ----------------------------------------------------------- fault plans
def test_fault_plan_schedule_is_chunk_invariant():
    plan = faults.FaultPlan(
        n=8, dropout_p=0.3, outages=(faults.Outage(2, 5, 12),),
        straggler=faults.StragglerModel(mean_s=0.1, timeout_s=0.15,
                                        retries=1),
        seed=42)
    whole, _ = plan.schedule(0, 20)
    first, _ = plan.schedule(0, 7)
    rest, _ = plan.schedule(7, 13)
    np.testing.assert_array_equal(whole, np.concatenate([first, rest]))


def test_fault_plan_outage_window_and_rejoin():
    plan = faults.FaultPlan(n=4, outages=(faults.Outage(1, 3, 6),))
    sched, _ = plan.schedule(0, 10)
    assert sched[:3, 1].all() and sched[6:, 1].all()   # up before & rejoined
    assert not sched[3:6, 1].any()                     # down in the window
    others = np.delete(sched, 1, axis=1)
    assert others.all()                                # nobody else affected


def test_fault_plan_trivial_flag():
    assert faults.FaultPlan(n=4).trivial
    assert not faults.FaultPlan(n=4, dropout_p=0.1).trivial
    assert not faults.FaultPlan(n=4, outages=(faults.Outage(0, 0, 1),)).trivial


def test_fault_plan_validation():
    with pytest.raises(ValueError, match="dropout_p"):
        faults.FaultPlan(n=4, dropout_p=1.0)
    with pytest.raises(ValueError, match="out of range"):
        faults.FaultPlan(n=4, outages=(faults.Outage(7, 0, 3),))
    with pytest.raises(ValueError, match="empty outage"):
        faults.Outage(0, 5, 5)
    with pytest.raises(ValueError, match="client:start:stop"):
        faults.Outage.parse("nonsense")
    assert faults.Outage.parse("2:10:20") == faults.Outage(2, 10, 20)


def test_straggler_survivors_monotone_in_retries():
    """A bigger retry budget can only ADD survivors, never remove them."""
    base = dict(mean_s=0.2, timeout_s=0.1, backoff=2.0, slow_frac=0.25,
                slow_factor=5.0)
    for t in range(10):
        prev = None
        for retries in range(4):
            sm = faults.StragglerModel(retries=retries, **base)
            ok, _ = sm.round_outcome(seed=9, t=t, n=16)
            if prev is not None:
                assert (prev <= ok).all(), (t, retries)
            prev = ok


def test_straggler_validation():
    with pytest.raises(ValueError, match="backoff"):
        faults.StragglerModel(backoff=0.5)
    with pytest.raises(ValueError, match="retry"):
        faults.StragglerModel(retries=-1)
    with pytest.raises(ValueError, match="slow_frac"):
        faults.StragglerModel(slow_frac=1.5)


# ---------------------------------------------- StreamHook on ShardMapReducer
def test_streamhook_works_on_sharded_backend():
    """Sharded streaming used to be refused at dispatch; the chunked driver
    now emits at chunk boundaries under the ShardMapReducer too — the hook
    must fire on cadence AND leave the trajectory bitwise unperturbed."""
    from repro.core import batched, glm

    clients = glm.make_synthetic(seed=0, n_clients=4, m=10, d=6, r=3,
                                 lam=1e-3)
    spec, batch, basisb = batched.bl3_setup(
        clients, [batched.Identity() for _ in clients],
        [batched.Identity() for _ in clients], tau=4)
    seen = []
    hook = rounds.StreamHook(every=1,
                             callback=lambda t, x, led: seen.append(int(t)))
    x0 = jnp.zeros(6, jnp.float64)
    keys = jax.random.split(jax.random.PRNGKey(0), 3)
    evals, leds = rounds.run_rounds(spec, batch, basisb, x0, 0.0, keys,
                                    sharded=True, stream=hook)
    jax.effects_barrier()
    assert seen == [0, 1, 2]
    ref_evals, ref_leds = rounds.run_rounds(spec, batch, basisb, x0, 0.0,
                                            keys, sharded=True)
    np.testing.assert_array_equal(np.asarray(evals["gap"]),
                                  np.asarray(ref_evals["gap"]))
    np.testing.assert_array_equal(np.asarray(leds.hess_up),
                                  np.asarray(ref_leds.hess_up))
