"""Retrace audit: the dispatch path traces each program body ONCE.

The invariant (ISSUE 10 acceptance): one trace per (spec, shapes) per
process and ZERO retraces across chunk boundaries and cohort epoch
boundaries, on every backend.  A retrace costs ~1000x the compiled
per-round dispatch, so a silent one is a serious perf regression — these
tests pin the counter deltas (`repro.core.rounds.trace_counts`), not
absolute counts, so they are immune to what earlier tests in the process
already traced.

The last test pins the cold-start subsystem's strongest form of the
invariant: a warm dispatch served from the program cache traces NOTHING —
the executable deserializes without ever running the Python body.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import batched, progcache, rounds
from repro.core.compressors import Identity, TopK

jax.config.update("jax_enable_x64", True)


@pytest.fixture(scope="module")
def problem():
    # dims deliberately distinct from every other test file's problem so
    # the first chunk here is a guaranteed fresh (spec, shapes) trace
    from repro.core import glm
    from repro.core.basis import orth_basis_from_data

    clients = glm.make_synthetic(seed=2, n_clients=5, m=20, d=14, r=5,
                                 lam=1e-3)
    bases = [orth_basis_from_data(c.A) for c in clients]
    x0 = jnp.zeros(14, jnp.float64)
    spec, batch, basisb = batched.bl2_setup(
        clients, bases, [TopK(k=5) for _ in clients],
        [Identity() for _ in clients], tau=2)
    return spec, batch, basisb, x0


def _delta(before, after, kind):
    return after.get(kind, 0) - before.get(kind, 0)


@pytest.mark.parametrize("sharded", [False, True],
                         ids=["fast", "fast+sharded"])
def test_one_trace_per_spec_zero_retraces_across_chunks(problem, sharded):
    spec, batch, basisb, x0 = problem
    root = jax.random.PRNGKey(0)
    carry = rounds.init_serve_carry(spec, batch, basisb, x0, sharded=sharded)

    before = rounds.trace_counts()
    carry, _ = rounds.run_chunk(spec, batch, basisb, x0, carry, 0, 4, root,
                                sharded=sharded)
    first = rounds.trace_counts()
    assert _delta(before, first, "chunk") == 1, \
        "first chunk at a fresh (spec, shapes) must trace exactly once"

    for t in (4, 8, 12):        # chunk AND epoch-of-work boundaries
        carry, _ = rounds.run_chunk(spec, batch, basisb, x0, carry, t, 4,
                                    root, sharded=sharded)
    after = rounds.trace_counts()
    assert _delta(first, after, "chunk") == 0, \
        f"retraced across chunk boundaries: {first} -> {after}"
    # the shape-only evaluations carry_client_flags runs are tagged apart
    # and must never be counted as real chunk traces
    assert _delta(first, after, "chunk/shape_eval") == 0


def test_zero_retraces_across_cohort_epochs():
    from repro.core import client_batch, cohort, compressors, specs

    d, m, n = 12, 8, 32
    bb = cohort.standard_basisb(d, n)
    spec = specs.BL2Spec(
        hess_comp=compressors.TopK(k=2 * d),
        model_comp=compressors.Identity(),
        alpha=1.0, eta=1.0, p=1.0, tau=8, init_exact=True,
        init_hess_bits=bb.init_coeff_bits_mean(True),
        basis_bits=bb.transmission_bits_mean(), block=False)
    store = client_batch.synthetic_store(0, n, m, d, lam=1e-3)
    # epoch = (n / cohort) * rounds_per_cohort = 4 rounds: every chunk
    # below crosses an epoch boundary (cohort swap + host scatter/gather)
    eng = cohort.CohortEngine(spec, store, x0=jnp.zeros(d, jnp.float64),
                              cohort=16, rounds_per_cohort=2,
                              root_key=jax.random.PRNGKey(0),
                              basis="standard")
    try:
        before = rounds.trace_counts()
        eng.run_chunk(0, 4)
        first = rounds.trace_counts()
        assert _delta(before, first, "cohort_chunk") == 1

        for t in (4, 8):
            eng.run_chunk(t, 4)
        after = rounds.trace_counts()
        assert _delta(first, after, "cohort_chunk") == 0, \
            f"retraced across epoch boundaries: {first} -> {after}"
    finally:
        eng.close()


def test_warm_cache_dispatch_traces_nothing(problem, tmp_path):
    """A cache-hit dispatch must deserialize, not trace: zero body traces
    for both the init and the chunk program."""
    spec, batch, basisb, x0 = problem
    root = jax.random.PRNGKey(1)
    progcache.activate(str(tmp_path / "pc"),
                       persistent_compilation_cache=False)
    try:
        rounds.clear_aot_memo()
        carry = rounds.init_serve_carry(spec, batch, basisb, x0)
        carry, ys_miss = rounds.run_chunk(spec, batch, basisb, x0, carry,
                                          0, 4, root)

        rounds.clear_aot_memo()      # next dispatch reloads from disk
        before = rounds.trace_counts()
        carry = rounds.init_serve_carry(spec, batch, basisb, x0)
        carry, ys_hit = rounds.run_chunk(spec, batch, basisb, x0, carry,
                                         0, 4, root)
        after = rounds.trace_counts()
        assert _delta(before, after, "chunk") == 0
        assert _delta(before, after, "init") == 0
        assert progcache.active().stats["hit"] >= 2
        np.testing.assert_array_equal(np.asarray(ys_miss[0]),
                                      np.asarray(ys_hit[0]))
    finally:
        progcache.deactivate()
        rounds.clear_aot_memo()
