"""Compressor contract tests (paper §3): contraction Eq. 6, unbiasedness Eq. 7,
symmetrization Lemma 3.1, composition Prop. 3.2 — incl. hypothesis sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import compressors as C


def _rand(shape, seed=0):
    return jnp.asarray(np.random.default_rng(seed).standard_normal(shape))


def _mc_expect(comp, x, trials=300, seed=0):
    keys = jax.random.split(jax.random.PRNGKey(seed), trials)
    outs = [comp(k, x)[0] for k in keys]
    return jnp.mean(jnp.stack(outs), 0), outs


# ----------------------------- contraction ---------------------------------
@settings(max_examples=15, deadline=None)
@given(
    d=st.integers(4, 12),
    k=st.integers(1, 10),
    seed=st.integers(0, 1000),
)
def test_topk_contraction(d, k, seed):
    x = _rand((d, d), seed)
    out, bits = C.TopK(k=k)(None, x)
    lhs = float(jnp.sum((x - out) ** 2))
    rhs = (1 - min(k, d * d) / (d * d)) * float(jnp.sum(x**2))
    assert lhs <= rhs + 1e-9
    assert float(bits) == min(k, d * d) * (C.FLOAT_BITS + C.INDEX_BITS)


@settings(max_examples=10, deadline=None)
@given(d=st.integers(3, 10), r=st.integers(1, 4), seed=st.integers(0, 100))
def test_rankr_contraction(d, r, seed):
    x = _rand((d, d), seed)
    out, _ = C.RankR(r=r)(None, x)
    lhs = float(jnp.sum((x - out) ** 2))
    rhs = (1 - min(r, d) / d) * float(jnp.sum(x**2))
    assert lhs <= rhs + 1e-9


def test_rankr_symmetric_in_symmetric_out():
    x = _rand((8, 8), 3)
    x = (x + x.T) / 2
    out, _ = C.RankR(r=2)(None, x)
    np.testing.assert_allclose(np.asarray(out), np.asarray(out).T, atol=1e-10)


def test_topk_symmetrize():
    x = _rand((8, 8), 3)
    x = (x + x.T) / 2
    out, _ = C.TopK(k=5, symmetrize=True)(None, x)
    np.testing.assert_allclose(np.asarray(out), np.asarray(out).T, atol=1e-12)
    # Lemma 3.1: symmetrized compressor still a contraction (δ = K/N_tri)
    lhs = float(jnp.sum((x - out) ** 2))
    assert lhs <= float(jnp.sum(x**2)) + 1e-9


# ----------------------------- unbiasedness --------------------------------
@pytest.mark.parametrize(
    "mk",
    [
        lambda: C.RandK(k=5),
        lambda: C.RandomDithering(s=4),
        lambda: C.NaturalCompression(),
        lambda: C.BernoulliLazy(p=0.3),
    ],
)
def test_unbiasedness(mk):
    comp = mk()
    x = _rand((6, 6), 7)
    mean, outs = _mc_expect(comp, x, trials=600)
    scale = float(jnp.abs(x).max())
    err = float(jnp.abs(mean - x).max())
    # MC error ~ std/sqrt(T); allow generous bound
    assert err < 0.35 * scale + 0.05, err


def test_dithering_variance_bound():
    comp = C.RandomDithering(s=6)
    x = _rand((50,), 2)
    omega = comp.omega_for(50)
    _, outs = _mc_expect(comp, x, trials=500)
    second = np.mean([float(jnp.sum(o**2)) for o in outs])
    assert second <= (omega + 1) * float(jnp.sum(x**2)) * 1.15


def test_natural_compression_relative_error():
    comp = C.NaturalCompression()
    x = _rand((40,), 5)
    out, _ = comp(jax.random.PRNGKey(0), x)
    # output is sign * power of two within [|x|, 2|x|]
    nz = np.asarray(x) != 0
    ratio = np.asarray(out)[nz] / np.asarray(x)[nz]
    assert (ratio > 0.49).all() and (ratio < 2.01).all()


# ----------------------------- compositions --------------------------------
def test_composed_rankr_contraction_prop32():
    """Prop 3.2: δ = R/(d(ω1+1)(ω2+1)), verified in expectation."""
    d, r = 8, 2
    x = _rand((d, d), 11)
    x = (x + x.T) / 2
    comp = C.nrankr(r)
    om = 1 / 8
    delta = r / (d * (om + 1) ** 2)
    errs = []
    for t in range(200):
        out, _ = comp(jax.random.PRNGKey(t), x)
        errs.append(float(jnp.sum((x - out) ** 2)))
    assert np.mean(errs) <= (1 - delta) * float(jnp.sum(x**2)) * 1.05


def test_composed_topk_keeps_support():
    comp = C.ntopk(6)
    x = _rand((5, 5), 1)
    out, _ = comp(jax.random.PRNGKey(0), x)
    assert int(jnp.sum(out != 0)) <= 6


def test_identity_bits():
    x = _rand((7,), 0)
    out, bits = C.Identity()(None, x)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(x))
    assert float(bits) == 7 * C.FLOAT_BITS


# --------------------- batched-contract property tests ----------------------
# Paper contracts under the native compress(keys, (n, ...)) API: contraction
# (Eq. 6) must hold PER CLIENT of a random batch, unbiasedness/variance
# (Eq. 7) in expectation over batched draws.
def _contractive_cases(d):
    return [
        (C.TopK(k=5), min(5, d * d) / (d * d)),
        (C.TopK(k=5, symmetrize=True), 0.0),   # Lemma 3.1: still a contraction
        (C.RankR(r=2), min(2, d) / d),
        (C.Identity(), 1.0),
    ]


@settings(max_examples=10, deadline=None)
@given(n=st.integers(1, 6), d=st.integers(4, 10), seed=st.integers(0, 500))
def test_batched_contraction_eq6(n, d, seed):
    X = _rand((n, d, d), seed)
    for comp, delta in _contractive_cases(d):
        Xs = (X + X.transpose(0, 2, 1)) / 2 if getattr(comp, "symmetrize", False) else X
        out, _ = comp.compress(None, Xs)
        lhs = np.asarray(jnp.sum((Xs - out) ** 2, axis=(1, 2)))
        rhs = (1 - delta) * np.asarray(jnp.sum(Xs**2, axis=(1, 2)))
        assert (lhs <= rhs + 1e-9).all(), type(comp).__name__


@settings(max_examples=6, deadline=None)
@given(n=st.integers(2, 5), seed=st.integers(0, 100))
def test_batched_unbiasedness_eq7(n, seed):
    """E[C(A)] = A per client, averaged over batched stochastic draws."""
    d = 6
    X = _rand((n, d, d), seed)
    for comp in (C.RandK(k=9), C.RandomDithering(s=6), C.NaturalCompression(),
                 C.BernoulliLazy(p=0.4)):
        trials = 400
        acc = jnp.zeros_like(X)
        for t in range(trials):
            keys = jax.random.split(jax.random.PRNGKey(1000 * seed + t), n)
            out, _ = comp.compress(keys, X)
            acc = acc + out
        err = float(jnp.abs(acc / trials - X).max())
        assert err < 0.35 * float(jnp.abs(X).max()) + 0.05, (type(comp).__name__, err)


def test_batched_variance_bound_eq7():
    """E‖C(A)‖² ≤ (ω+1)‖A‖² per client for dithering over a random batch."""
    comp = C.RandomDithering(s=6)
    X = _rand((4, 50), 2)
    omega = comp.omega_for(50)
    second = np.zeros(4)
    trials = 400
    for t in range(trials):
        keys = jax.random.split(jax.random.PRNGKey(t), 4)
        out, _ = comp.compress(keys, X)
        second += np.asarray(jnp.sum(out**2, axis=1)) / trials
    bound = (omega + 1) * np.asarray(jnp.sum(X**2, axis=1))
    assert (second <= bound * 1.15).all()


@pytest.mark.parametrize(
    "mk",
    [
        lambda: C.RandK(k=3),
        lambda: C.RandomDithering(s=4),
        lambda: C.NaturalCompression(),
        lambda: C.BernoulliLazy(p=0.5),
        lambda: C.rtopk(4),
        lambda: C.ntopk(4),
        lambda: C.rrankr(1, 6),
    ],
)
def test_stochastic_compressors_require_keys(mk):
    """keys=None must raise for stochastic compressors — the old contract
    silently substituted PRNGKey(0), repeating identical 'random' draws."""
    comp = mk()
    X = _rand((3, 6, 6), 0)
    with pytest.raises(ValueError, match="stochastic"):
        comp.compress(None, X)
    with pytest.raises(ValueError, match="stochastic"):
        comp(None, X[0])


def test_deterministic_compressors_accept_none_keys():
    X = _rand((3, 6, 6), 1)
    for comp in (C.Identity(), C.TopK(k=4), C.RankR(r=1)):
        out, _ = comp.compress(None, X)
        assert out.shape == X.shape
