"""Basis-shipment subsystem regressions: amortized refresh accounting,
chunk-boundary invariance, and the Pallas two-sided transform parity pin.

Three contracts the ISSUE pins bitwise:

  * `rounds_per_refresh == 1` (re-ship every round) leaves the TRAJECTORY
    bitwise identical to the policy-off default on both reducers — the
    refresh policy is pure accounting; only the `basis_ship` ledger leg
    moves, and it moves to exactly the analytic ship-every-round stream.
  * refresh placement is a pure function of the absolute round index, so
    any `run_chunk` segmentation (including boundaries that split a
    refresh round) reproduces the unsegmented streams bit-for-bit.
  * the Pallas `basis_transform` kernel (REPRO_BL_PALLAS=1 routing in
    `basis._two_sided`) is bitwise the XLA `A @ g @ B` it replaces.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

jax.config.update("jax_enable_x64", True)

from repro.core import rounds  # noqa: E402
from repro.core.basis import make_bases  # noqa: E402
from repro.core.specs import BasisRefreshPolicy  # noqa: E402
from repro.fed import bldnn  # noqa: E402

STEPS = 6


@pytest.fixture(scope="module")
def dnn_problem():
    batch, p0 = bldnn.make_synthetic_classification(0, 8, 16, 24, 3, 8)
    return batch, p0, bldnn.make_loss_fn(3), bldnn.make_eval_fn()


def _run(dnn_problem, cfg, backend="fast"):
    batch, p0, loss_fn, eval_fn = dnn_problem
    return bldnn.run_bldnn(loss_fn, eval_fn, p0, batch, STEPS, cfg,
                           seed=0, backend=backend)


def _ship_bits(p0):
    return make_bases("per_layer_svd", p0).ship_floats() * 32.0


# --------------------------------------------------------------------------
# T=1 parity: re-ship every round is pure accounting
# --------------------------------------------------------------------------
@pytest.mark.parametrize("backend", ["fast", "fast+sharded"])
def test_refresh_every_round_is_pure_accounting(dnn_problem, backend):
    """T=1 (θ=0 ⇒ the drift trigger always fires) must be BITWISE the
    policy-off trajectory on both reducers; the basis_ship stream becomes
    exactly ship·max(1, k) at round entry k (round 0's shipment is billed
    by init, refreshes bill at entry of rounds 1, 2, ...)."""
    _, p0, _, _ = dnn_problem
    base = bldnn.BLDNNConfig(top_k_frac=0.25, lr=0.05)
    amort = bldnn.BLDNNConfig(top_k_frac=0.25, lr=0.05,
                              rounds_per_refresh=1, drift_threshold=0.0)
    h0 = _run(dnn_problem, base, backend)
    h1 = _run(dnn_problem, amort, backend)
    np.testing.assert_array_equal(np.asarray(h0.gaps), np.asarray(h1.gaps))
    np.testing.assert_array_equal(np.asarray(h0.metrics["loss"]),
                                  np.asarray(h1.metrics["loss"]))
    for leg in ("hess_up", "grad_up", "model_down"):
        np.testing.assert_array_equal(np.asarray(h0.legs[leg]),
                                      np.asarray(h1.legs[leg]), err_msg=leg)
    ship = _ship_bits(p0)
    np.testing.assert_array_equal(
        np.asarray(h0.legs["basis_ship"]), np.full(STEPS, ship))
    np.testing.assert_array_equal(
        np.asarray(h1.legs["basis_ship"]),
        np.asarray([ship * max(1, k) for k in range(STEPS)]))


def test_high_drift_threshold_never_reships(dnn_problem):
    """A drift threshold no leakage can reach (θ=2: leakage ≤ 1 by
    construction) turns the policy into the policy-off billing exactly."""
    _, p0, _, _ = dnn_problem
    cfg = bldnn.BLDNNConfig(top_k_frac=0.25, lr=0.05,
                            rounds_per_refresh=2, drift_threshold=2.0)
    h = _run(dnn_problem, cfg)
    h0 = _run(dnn_problem, bldnn.BLDNNConfig(top_k_frac=0.25, lr=0.05))
    np.testing.assert_array_equal(np.asarray(h0.gaps), np.asarray(h.gaps))
    np.testing.assert_array_equal(
        np.asarray(h.legs["basis_ship"]), np.full(STEPS, _ship_bits(p0)))


def test_refresh_policy_validation():
    with pytest.raises(ValueError):
        BasisRefreshPolicy(rounds_per_refresh=-1)
    with pytest.raises(ValueError):
        BasisRefreshPolicy(drift_threshold=-0.5)
    assert not BasisRefreshPolicy().amortized
    assert BasisRefreshPolicy(rounds_per_refresh=3).amortized


def test_refresh_due_pure_in_absolute_round():
    due = [bool(rounds.refresh_due(t, 3)) for t in range(7)]
    assert due == [True, False, False, True, False, False, True]
    assert not bool(rounds.refresh_due(5, 0))  # policy off
    assert all(bool(rounds.refresh_due(t, 1)) for t in range(4))


# --------------------------------------------------------------------------
# chunk-boundary invariance: refresh placement survives any segmentation
# --------------------------------------------------------------------------
def _chunked_streams(dnn_problem, segs, *, T=3):
    batch, p0, loss_fn, eval_fn = dnn_problem
    cfg = bldnn.BLDNNConfig(top_k_frac=0.25, lr=0.05,
                            rounds_per_refresh=T, drift_threshold=0.0)
    basis = make_bases("per_layer_svd", p0)
    spec = bldnn.build_spec(loss_fn, eval_fn, p0, cfg,
                            basis_ship_bits=basis.ship_floats() * 32.0)
    key = jax.random.PRNGKey(7)
    carry = rounds.init_serve_carry(spec, batch, basis, p0)
    outs, t = [], 0
    for s in segs:
        carry, ys = rounds.run_chunk(spec, batch, basis, p0, carry, t, s,
                                     key)
        outs.append(ys)
        t += s
    cat = jax.tree.map(lambda *a: jnp.concatenate(a, 0), *outs)
    return carry, cat


def test_chunk_boundary_refresh_invariance(dnn_problem):
    """T=3 refreshes fire at absolute rounds 3, 6, ... — segmentations
    whose boundaries fall ON and OFF refresh rounds must all reproduce the
    unsegmented ledger streams and final carry bit-for-bit (mirrors the
    cohort engine's segmentation pin in tests/test_cohort.py)."""
    c_ref, ys_ref = _chunked_streams(dnn_problem, [6])
    for segs in ([3, 3], [2, 2, 2], [1, 2, 3], [4, 2]):
        c, ys = _chunked_streams(dnn_problem, segs)
        for a, b in zip(jax.tree.leaves(ys_ref), jax.tree.leaves(ys)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                          err_msg=f"streams @ {segs}")
        for a, b in zip(jax.tree.leaves(c_ref), jax.tree.leaves(c)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                          err_msg=f"carry @ {segs}")


# --------------------------------------------------------------------------
# Pallas two-sided transform: bitwise parity with the XLA path
# --------------------------------------------------------------------------
def test_pallas_basis_transform_bitwise_parity():
    from repro.kernels import ops

    rng = np.random.default_rng(0)
    A = jnp.asarray(rng.standard_normal((12, 12)), jnp.float32)
    B = jnp.asarray(rng.standard_normal((8, 8)), jnp.float32)
    g = jnp.asarray(rng.standard_normal((5, 12, 8)), jnp.float32)
    got = np.asarray(ops.basis_transform(A, g, B))
    want = np.asarray(A @ g @ B)
    np.testing.assert_array_equal(got, want)


def test_pallas_routing_in_rotate_is_bitwise(dnn_problem, monkeypatch):
    """`basis._two_sided` routed through the kernel (REPRO_BL_PALLAS=1)
    must be bitwise the default XLA rotate — kernel selection can never
    move a trajectory."""
    batch, p0, _, _ = dnn_problem
    basis = make_bases("per_layer_svd", p0)
    rng = np.random.default_rng(1)
    stack = jax.tree.map(
        lambda x: jnp.asarray(rng.standard_normal((4,) + x.shape),
                              jnp.float32), p0)
    monkeypatch.setenv("REPRO_BL_PALLAS", "0")
    xla = basis.rotate(stack)
    monkeypatch.setenv("REPRO_BL_PALLAS", "1")
    pallas = basis.rotate(stack)
    for a, b in zip(jax.tree.leaves(xla), jax.tree.leaves(pallas)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_basis_transform_rejects_bad_inputs():
    from repro.kernels import basis_transform as bt

    A = jnp.eye(4, dtype=jnp.float32)
    g3 = jnp.zeros((2, 4, 4), jnp.float32)
    with pytest.raises(TypeError):
        bt.basis_transform(A.astype(jnp.float64), g3.astype(jnp.float64),
                           A.astype(jnp.float64))
    with pytest.raises(ValueError):
        bt.basis_transform(A, jnp.zeros((4, 4), jnp.float32), A)
