"""BL-DNN federated layer tests: shard_map mechanics, compression contracts,
and the basis-rotation benefit (signal kept per coefficient budget)."""
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.fed.bldnn import (
    BLDNNConfig,
    _rotate,
    _topk_dense,
    _unrotate,
    accumulate_comm,
    basis_bits,
    init_comm_ledger,
    init_fed_state,
    layer_bases_from_params,
    make_fed_train_step,
)


def _tiny_params(key, d_in=32, d_h=48, d_out=16):
    k1, k2 = jax.random.split(key)
    return {
        "w1": jax.random.normal(k1, (d_in, d_h)) * 0.1,
        "b1": jnp.zeros((d_h,)),
        "w2": jax.random.normal(k2, (d_h, d_out)) * 0.1,
    }


def _loss(params, batch):
    h = jnp.tanh(batch["x"] @ params["w1"] + params["b1"])
    pred = h @ params["w2"]
    return jnp.mean((pred - batch["y"]) ** 2)


def test_topk_dense_contract():
    x = jnp.asarray(np.random.default_rng(0).standard_normal((40, 40)), jnp.float32)
    out, sent = _topk_dense(x, 0.1)
    k = max(1, int(x.size * 0.1))
    assert int(jnp.sum(out != 0)) == k  # exactly k kept — no tie overshoot
    assert int(sent) == k               # billed floats == actual nonzeros
    lhs = float(jnp.sum((x - out) ** 2))
    assert lhs <= (1 - k / x.size) * float(jnp.sum(x**2)) + 1e-5


def test_topk_dense_ties_and_zeros():
    """Ties must not inflate the kept set beyond k, and the transmitted-float
    count is the ACTUAL nonzero count (a zero tensor sends nothing)."""
    tied = jnp.ones((10, 10), jnp.float32)
    out, sent = _topk_dense(tied, 0.07)
    assert int(jnp.sum(out != 0)) == 7
    assert int(sent) == 7
    out0, sent0 = _topk_dense(jnp.zeros((10, 10), jnp.float32), 0.07)
    assert int(sent0) == 0 and float(jnp.sum(jnp.abs(out0))) == 0.0


def test_rotation_roundtrip():
    p = jax.random.normal(jax.random.PRNGKey(0), (24, 56))
    bases = layer_bases_from_params({"w": p})
    b = bases[0]
    g = jax.random.normal(jax.random.PRNGKey(1), (24, 56))
    back = _unrotate(_rotate(g, b), b)
    np.testing.assert_allclose(np.asarray(back), np.asarray(g), rtol=1e-4, atol=1e-4)
    assert basis_bits(bases) == 24 * 24 + 56 * 56  # complete U and V


def test_basis_concentrates_energy():
    """Top-K in the SVD basis of a low-rank-ish weight keeps more gradient
    energy than Top-K in the standard basis — the §2.3 intuition carried to
    DNN layers (gradients correlate with the weight's row/column spaces)."""
    rng = np.random.default_rng(0)
    d = 64
    # weight with decaying spectrum; gradient = W-aligned + small noise
    U, _ = np.linalg.qr(rng.standard_normal((d, d)))
    V, _ = np.linalg.qr(rng.standard_normal((d, d)))
    s = np.exp(-np.arange(d) / 8.0)
    W = (U * s) @ V.T
    G = (U[:, :8] * s[:8]) @ V[:, :8].T + 0.02 * rng.standard_normal((d, d))
    bases = layer_bases_from_params({"w": jnp.asarray(W, jnp.float32)})
    b = bases[0]
    g = jnp.asarray(G, jnp.float32)
    frac = 0.05
    comp_std, _ = _topk_dense(g, frac)
    comp_rot, _ = _topk_dense(_rotate(g, b), frac)
    kept_std = float(jnp.sum(comp_std**2)) / float(jnp.sum(g**2))
    kept_rot = float(jnp.sum(comp_rot**2)) / float(jnp.sum(g**2))
    assert kept_rot > kept_std, (kept_rot, kept_std)


def test_fed_step_single_client():
    """Mechanics on a 1-device mesh (1 client): loss decreases."""
    mesh = jax.make_mesh((1,), ("data",))
    params = _tiny_params(jax.random.PRNGKey(0))
    bases = layer_bases_from_params(params)
    state = init_fed_state(params, bases, 1)
    cfg = BLDNNConfig(lr=0.05, top_k_frac=0.2)
    step = jax.jit(make_fed_train_step(_loss, mesh, cfg, bases, params))
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((16, 32)), jnp.float32)
    wtrue = rng.standard_normal((32, 16)) * 0.5
    y = jnp.asarray(x @ wtrue, jnp.float32)
    batch = {"x": x, "y": y}
    losses = []
    ledger = init_comm_ledger(bases)
    for _ in range(30):
        params, state, m = step(params, state, batch)
        ledger = accumulate_comm(ledger, m)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] * 0.9, losses[::10]
    assert float(m["floats_sent"]) > 0
    # BL-DNN bills on the shared CommLedger: one-time basis shipment +
    # per-step gradient (grad leg) and Fisher (hess leg) streams, f32 wire
    assert float(ledger.basis_ship) == basis_bits(bases) * 32
    assert float(ledger.grad_up) > 0 and float(ledger.hess_up) > 0
    assert float(ledger.uplink) == pytest.approx(
        float(ledger.basis_ship + ledger.grad_up + ledger.hess_up))


MULTI_CLIENT_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.fed.bldnn import (BLDNNConfig, init_fed_state,
                             layer_bases_from_params, make_fed_train_step)

def loss(params, batch):
    h = jnp.tanh(batch["x"] @ params["w1"] + params["b1"])
    return jnp.mean((h @ params["w2"] - batch["y"]) ** 2)

k = jax.random.PRNGKey(0)
k1, k2 = jax.random.split(k)
params = {"w1": jax.random.normal(k1, (32, 48)) * 0.1,
          "b1": jnp.zeros((48,)),
          "w2": jax.random.normal(k2, (48, 16)) * 0.1}
mesh = jax.make_mesh((8,), ("data",))
bases = layer_bases_from_params(params)
state = init_fed_state(params, bases, 8)
cfg = BLDNNConfig(lr=0.05, top_k_frac=0.2)
step = jax.jit(make_fed_train_step(loss, mesh, cfg, bases, params))
rng = np.random.default_rng(0)
wtrue = rng.standard_normal((32, 16)) * 0.5
# heterogeneous clients: each shard gets a shifted input distribution
x = rng.standard_normal((64, 32)) + np.repeat(np.linspace(-1, 1, 8), 8)[:, None]
y = x @ wtrue
batch = {"x": jnp.asarray(x, jnp.float32), "y": jnp.asarray(y, jnp.float32)}
losses = []
for _ in range(40):
    params, state, m = step(params, state, batch)
    losses.append(float(m["loss"]))
assert losses[-1] < losses[0] * 0.7, losses[::10]
# per-client shifts differ (they compressed different gradients)
s0 = np.asarray(state["shift"][2])
assert s0.shape[0] == 8
norms = np.linalg.norm(s0.reshape(8, -1), axis=1)
assert np.std(norms) > 0
print("MULTI_CLIENT_OK", losses[0], "->", losses[-1])
"""


def test_fed_step_eight_clients_subprocess():
    """Real multi-client run (8 virtual devices; subprocess because jax
    device count is locked at first init in the main test process)."""
    r = subprocess.run([sys.executable, "-c", MULTI_CLIENT_SCRIPT],
                       capture_output=True, text=True, timeout=600,
                       env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                            "JAX_PLATFORMS": "cpu"})
    assert "MULTI_CLIENT_OK" in r.stdout, r.stdout + r.stderr
