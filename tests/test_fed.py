"""BL-DNN on the unified round engine: pytree basis contracts, per-leaf
compressor budgets, single-device (VmapReducer) training with ledger
billing, and cross-backend bitwise parity (vmap vs client-sharded
shard_map).  The pin against the legacy hand-rolled loop lives in the
commit that introduced the engine path (see the note above
MULTI_CLIENT_SCRIPT)."""
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.basis import PerLayerSVDBasis, make_bases, per_layer_svd_basis
from repro.core.compressors import topk_keep_mask
from repro.fed import bldnn as B


@pytest.fixture(scope="module")
def problem():
    batch, params0 = B.make_synthetic_classification(
        seed=0, n_clients=8, m=64, d=32, classes=4, width=48)
    return batch, params0, B.make_loss_fn(4), B.make_eval_fn()


# --------------------------------------------------------------------------
# pytree basis + per-leaf compressor contracts
# --------------------------------------------------------------------------
def test_per_layer_svd_rotation_roundtrip(problem):
    _, params0, _, _ = problem
    basis = make_bases("per_layer_svd", params0)
    assert isinstance(basis, PerLayerSVDBasis)
    g = jax.tree.map(lambda p: jnp.ones_like(p), params0)
    back = basis.unrotate(basis.rotate(g))
    for a, b in zip(jax.tree.leaves(back), jax.tree.leaves(g)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-4)
    # complete (U, V) per 2-D leaf, nothing for biases
    sizes = [p for p in jax.tree.leaves(params0) if p.ndim == 2]
    assert basis.ship_floats() == sum(p.shape[0] ** 2 + p.shape[1] ** 2
                                      for p in sizes)


def test_per_layer_svd_stacked_leaves_broadcast(problem):
    """Rotations broadcast over the engine's leading client axis and agree
    with the per-client computation."""
    _, params0, _, _ = problem
    basis = per_layer_svd_basis(params0)
    g1 = jax.tree.map(lambda p: jnp.ones_like(p), params0)
    stacked = jax.tree.map(lambda p: jnp.stack([p, 2.0 * p]), g1)
    rot = basis.rotate(stacked)
    rot1 = basis.rotate(g1)
    for rs, r1 in zip(jax.tree.leaves(rot), jax.tree.leaves(rot1)):
        np.testing.assert_allclose(np.asarray(rs[0]), np.asarray(r1),
                                   rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(np.asarray(rs[1]), 2 * np.asarray(r1),
                                   rtol=1e-5, atol=1e-5)


def test_leaf_compressors_scale_budgets(problem):
    """One registry compressor per leaf, k scaled to the leaf size; the
    engine path therefore keeps exactly k_ℓ entries per leaf per client."""
    _, params0, _, _ = problem
    comps = B.leaf_compressors("topk", 0.1, params0)
    leaves = jax.tree.leaves(params0)
    assert len(comps) == len(leaves)
    for comp, p in zip(comps, leaves):
        assert comp.k == max(1, int(0.1 * p.size))
        dense, counts = comp.compress(None, p[None])
        assert int(jnp.sum(dense != 0)) <= comp.k
        assert float(np.asarray(counts.floats)[0]) == comp.k
    with pytest.raises(ValueError, match="compressor kind"):
        B.leaf_compressors("warp", 0.1, params0)


def test_basis_concentrates_energy():
    """Top-K in the SVD basis of a low-rank-ish weight keeps more gradient
    energy than Top-K in the standard basis — the §2.3 intuition carried to
    DNN layers (gradients correlate with the weight's row/column spaces)."""
    rng = np.random.default_rng(0)
    d = 64
    U, _ = np.linalg.qr(rng.standard_normal((d, d)))
    V, _ = np.linalg.qr(rng.standard_normal((d, d)))
    s = np.exp(-np.arange(d) / 8.0)
    W = (U * s) @ V.T
    G = (U[:, :8] * s[:8]) @ V[:, :8].T + 0.02 * rng.standard_normal((d, d))
    basis = per_layer_svd_basis({"w": jnp.asarray(W, jnp.float32)})
    g = jnp.asarray(G, jnp.float32)
    k = max(1, int(0.05 * g.size))

    def kept_energy(t):
        v = t.reshape(-1)
        kept = jnp.where(topk_keep_mask(v, k), v, 0.0)
        return float(jnp.sum(kept ** 2)) / float(jnp.sum(v ** 2))

    kept_std = kept_energy(g)
    kept_rot = kept_energy(jax.tree.leaves(basis.rotate({"w": g}))[0])
    assert kept_rot > kept_std, (kept_rot, kept_std)


# --------------------------------------------------------------------------
# single-device engine runs (VmapReducer — no mesh required)
# --------------------------------------------------------------------------
def test_single_device_training_and_ledger(problem):
    batch, params0, loss_fn, eval_fn = problem
    cfg = B.BLDNNConfig(lr=0.05, top_k_frac=0.1)
    h = B.run_bldnn(loss_fn, eval_fn, params0, batch, 30, cfg, backend="fast")
    assert min(h.gaps) < 0.1 < h.gaps[0]          # error rate falls
    assert min(h.metrics["loss"]) < h.metrics["loss"][0] * 0.5
    # one-time basis shipment at the f32 wire + both uplink streams billed
    basis = per_layer_svd_basis(params0)
    assert h.legs["basis_ship"] == [basis.ship_floats() * 32] * 30
    assert h.legs["grad_up"][-1] > 0 and h.legs["hess_up"][-1] > 0
    np.testing.assert_allclose(
        np.asarray(h.up_bits),
        np.asarray(h.legs["grad_up"]) + np.asarray(h.legs["hess_up"])
        + np.asarray(h.legs["basis_ship"]))


def test_stochastic_compressor_runs_on_dnn(problem):
    """RTop-K (Top-K ∘ dithering) through the pytree engine: stochastic
    codecs get real per-leaf, per-client PRNG keys now."""
    batch, params0, loss_fn, eval_fn = problem
    cfg = B.BLDNNConfig(lr=0.05, top_k_frac=0.1, compressor="rtopk")
    h = B.run_bldnn(loss_fn, eval_fn, params0, batch, 15, cfg, backend="fast")
    assert h.gaps[-1] < h.gaps[0]
    h2 = B.run_bldnn(loss_fn, eval_fn, params0, batch, 15, cfg, seed=1,
                     backend="fast")
    assert h.metrics["loss"] != h2.metrics["loss"]   # seeds matter


def test_no_basis_and_fedavg_controls(problem):
    batch, params0, loss_fn, eval_fn = problem
    hn = B.run_bldnn(loss_fn, eval_fn, params0, batch, 10,
                     B.BLDNNConfig(lr=0.05, top_k_frac=0.1, use_basis=False),
                     backend="fast")
    assert hn.legs["basis_ship"] == [0.0] * 10       # nothing shipped
    hi = B.run_bldnn(loss_fn, eval_fn, params0, batch, 10,
                     B.BLDNNConfig(lr=0.05, compressor="identity",
                                   use_basis=False, precondition=False),
                     backend="fast")
    assert hi.legs["hess_up"] == [0.0] * 10          # no curvature stream
    assert hi.gaps[-1] < hi.gaps[0]
    with pytest.raises(ValueError, match="backend"):
        B.run_bldnn(loss_fn, eval_fn, params0, batch, 2,
                    backend="reference")


# --------------------------------------------------------------------------
# cross-backend parity
# --------------------------------------------------------------------------
# The legacy hand-rolled shard_map loop (fed.bldnn.make_fed_train_step) was
# deleted after its parity pin: the commit introducing the engine path
# carries a test pinning the BLDNNSpec per-round parameter trajectory
# against the old loop (bitwise for the gradient-only config, ≤1e-6 for the
# preconditioned one — the 1/(√F+ε) update amplifies last-ulp compile
# differences).  What remains load-bearing forever is the cross-backend
# contract below: VmapReducer and ShardMapReducer produce BITWISE-identical
# histories.


def test_vmap_vs_shardmap_bitwise_single_device(problem):
    """Even a 1-device world exercises the shard_map code path; histories
    (error, loss, per-leg bits) must match the vmap backend bitwise."""
    batch, params0, loss_fn, eval_fn = problem
    cfg = B.BLDNNConfig(lr=0.05, top_k_frac=0.1)
    h = B.run_bldnn(loss_fn, eval_fn, params0, batch, 10, cfg,
                    backend="fast")
    hs = B.run_bldnn(loss_fn, eval_fn, params0, batch, 10, cfg,
                     backend="fast+sharded")
    assert h.gaps == hs.gaps
    assert h.metrics["loss"] == hs.metrics["loss"]
    assert h.up_bits == hs.up_bits and h.legs == hs.legs


MULTI_CLIENT_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
from repro.fed import bldnn as B

batch, params0 = B.make_synthetic_classification(
    seed=0, n_clients=8, m=64, d=32, classes=4, width=48)
loss_fn = B.make_loss_fn(4); eval_fn = B.make_eval_fn()
assert len(jax.devices()) == 8

# engine: single-device vmap vs 8-device shard_map — BITWISE histories,
# for both the preconditioned and the gradient-only configurations
for cfg in (B.BLDNNConfig(lr=0.05, top_k_frac=0.1),
            B.BLDNNConfig(lr=0.05, top_k_frac=0.1, precondition=False)):
    h = B.run_bldnn(loss_fn, eval_fn, params0, batch, 20, cfg,
                    backend="fast")
    hs = B.run_bldnn(loss_fn, eval_fn, params0, batch, 20, cfg,
                     backend="fast+sharded")
    assert h.gaps == hs.gaps, (h.gaps, hs.gaps)
    assert h.metrics["loss"] == hs.metrics["loss"]
    assert h.up_bits == hs.up_bits and h.down_bits == hs.down_bits
    assert h.gaps[-1] < h.gaps[0]
print("FED_ENGINE_PARITY_OK", h.gaps[0], "->", h.gaps[-1])
"""


def test_engine_parity_eight_clients_subprocess():
    """8 real devices: engine vmap-vs-sharded histories are bitwise equal
    (subprocess because the device count locks at first jax init;
    JAX_PLATFORMS pinned — an unpinned child burns minutes probing TPUs)."""
    r = subprocess.run([sys.executable, "-c", MULTI_CLIENT_SCRIPT],
                       capture_output=True, text=True, timeout=900,
                       env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                            "JAX_PLATFORMS": "cpu"})
    assert "FED_ENGINE_PARITY_OK" in r.stdout, r.stdout + r.stderr[-3000:]
