"""BL-DNN on the unified round engine: pytree basis contracts, per-leaf
compressor budgets, single-device (VmapReducer) training with ledger
billing, parity against the legacy hand-rolled shard_map loop, and
cross-backend bitwise parity (vmap vs client-sharded shard_map)."""
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.basis import PerLayerSVDBasis, make_bases, per_layer_svd_basis
from repro.core.compressors import topk_keep_mask
from repro.fed import bldnn as B


@pytest.fixture(scope="module")
def problem():
    batch, params0 = B.make_synthetic_classification(
        seed=0, n_clients=8, m=64, d=32, classes=4, width=48)
    return batch, params0, B.make_loss_fn(4), B.make_eval_fn()


# --------------------------------------------------------------------------
# pytree basis + per-leaf compressor contracts
# --------------------------------------------------------------------------
def test_per_layer_svd_rotation_roundtrip(problem):
    _, params0, _, _ = problem
    basis = make_bases("per_layer_svd", params0)
    assert isinstance(basis, PerLayerSVDBasis)
    g = jax.tree.map(lambda p: jnp.ones_like(p), params0)
    back = basis.unrotate(basis.rotate(g))
    for a, b in zip(jax.tree.leaves(back), jax.tree.leaves(g)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-4)
    # complete (U, V) per 2-D leaf, nothing for biases
    sizes = [p for p in jax.tree.leaves(params0) if p.ndim == 2]
    assert basis.ship_floats() == sum(p.shape[0] ** 2 + p.shape[1] ** 2
                                      for p in sizes)


def test_per_layer_svd_stacked_leaves_broadcast(problem):
    """Rotations broadcast over the engine's leading client axis and agree
    with the per-client computation."""
    _, params0, _, _ = problem
    basis = per_layer_svd_basis(params0)
    g1 = jax.tree.map(lambda p: jnp.ones_like(p), params0)
    stacked = jax.tree.map(lambda p: jnp.stack([p, 2.0 * p]), g1)
    rot = basis.rotate(stacked)
    rot1 = basis.rotate(g1)
    for rs, r1 in zip(jax.tree.leaves(rot), jax.tree.leaves(rot1)):
        np.testing.assert_allclose(np.asarray(rs[0]), np.asarray(r1),
                                   rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(np.asarray(rs[1]), 2 * np.asarray(r1),
                                   rtol=1e-5, atol=1e-5)


def test_leaf_compressors_scale_budgets(problem):
    """One registry compressor per leaf, k scaled to the leaf size; the
    engine path therefore keeps exactly k_ℓ entries per leaf per client."""
    _, params0, _, _ = problem
    comps = B.leaf_compressors("topk", 0.1, params0)
    leaves = jax.tree.leaves(params0)
    assert len(comps) == len(leaves)
    for comp, p in zip(comps, leaves):
        assert comp.k == max(1, int(0.1 * p.size))
        dense, counts = comp.compress(None, p[None])
        assert int(jnp.sum(dense != 0)) <= comp.k
        assert float(np.asarray(counts.floats)[0]) == comp.k
    with pytest.raises(ValueError, match="compressor kind"):
        B.leaf_compressors("warp", 0.1, params0)


def test_basis_concentrates_energy():
    """Top-K in the SVD basis of a low-rank-ish weight keeps more gradient
    energy than Top-K in the standard basis — the §2.3 intuition carried to
    DNN layers (gradients correlate with the weight's row/column spaces)."""
    rng = np.random.default_rng(0)
    d = 64
    U, _ = np.linalg.qr(rng.standard_normal((d, d)))
    V, _ = np.linalg.qr(rng.standard_normal((d, d)))
    s = np.exp(-np.arange(d) / 8.0)
    W = (U * s) @ V.T
    G = (U[:, :8] * s[:8]) @ V[:, :8].T + 0.02 * rng.standard_normal((d, d))
    basis = per_layer_svd_basis({"w": jnp.asarray(W, jnp.float32)})
    g = jnp.asarray(G, jnp.float32)
    k = max(1, int(0.05 * g.size))

    def kept_energy(t):
        v = t.reshape(-1)
        kept = jnp.where(topk_keep_mask(v, k), v, 0.0)
        return float(jnp.sum(kept ** 2)) / float(jnp.sum(v ** 2))

    kept_std = kept_energy(g)
    kept_rot = kept_energy(jax.tree.leaves(basis.rotate({"w": g}))[0])
    assert kept_rot > kept_std, (kept_rot, kept_std)


# --------------------------------------------------------------------------
# single-device engine runs (VmapReducer — no mesh required)
# --------------------------------------------------------------------------
def test_single_device_training_and_ledger(problem):
    batch, params0, loss_fn, eval_fn = problem
    cfg = B.BLDNNConfig(lr=0.05, top_k_frac=0.1)
    h = B.run_bldnn(loss_fn, eval_fn, params0, batch, 30, cfg, backend="fast")
    assert min(h.gaps) < 0.1 < h.gaps[0]          # error rate falls
    assert min(h.metrics["loss"]) < h.metrics["loss"][0] * 0.5
    # one-time basis shipment at the f32 wire + both uplink streams billed
    basis = per_layer_svd_basis(params0)
    assert h.legs["basis_ship"] == [basis.ship_floats() * 32] * 30
    assert h.legs["grad_up"][-1] > 0 and h.legs["hess_up"][-1] > 0
    np.testing.assert_allclose(
        np.asarray(h.up_bits),
        np.asarray(h.legs["grad_up"]) + np.asarray(h.legs["hess_up"])
        + np.asarray(h.legs["basis_ship"]))


def test_stochastic_compressor_runs_on_dnn(problem):
    """RTop-K (Top-K ∘ dithering) through the pytree engine: stochastic
    codecs get real per-leaf, per-client PRNG keys now."""
    batch, params0, loss_fn, eval_fn = problem
    cfg = B.BLDNNConfig(lr=0.05, top_k_frac=0.1, compressor="rtopk")
    h = B.run_bldnn(loss_fn, eval_fn, params0, batch, 15, cfg, backend="fast")
    assert h.gaps[-1] < h.gaps[0]
    h2 = B.run_bldnn(loss_fn, eval_fn, params0, batch, 15, cfg, seed=1,
                     backend="fast")
    assert h.metrics["loss"] != h2.metrics["loss"]   # seeds matter


def test_no_basis_and_fedavg_controls(problem):
    batch, params0, loss_fn, eval_fn = problem
    hn = B.run_bldnn(loss_fn, eval_fn, params0, batch, 10,
                     B.BLDNNConfig(lr=0.05, top_k_frac=0.1, use_basis=False),
                     backend="fast")
    assert hn.legs["basis_ship"] == [0.0] * 10       # nothing shipped
    hi = B.run_bldnn(loss_fn, eval_fn, params0, batch, 10,
                     B.BLDNNConfig(lr=0.05, compressor="identity",
                                   use_basis=False, precondition=False),
                     backend="fast")
    assert hi.legs["hess_up"] == [0.0] * 10          # no curvature stream
    assert hi.gaps[-1] < hi.gaps[0]
    with pytest.raises(ValueError, match="backend"):
        B.run_bldnn(loss_fn, eval_fn, params0, batch, 2,
                    backend="reference")


# --------------------------------------------------------------------------
# parity: the engine path vs the legacy hand-rolled shard_map loop
# --------------------------------------------------------------------------
def _legacy_trajectory(loss_fn, params0, client_data, cfg, steps):
    """Per-round (pre-update) loss stream + param trajectory from the old
    `make_fed_train_step` loop on a 1-device mesh (1 client)."""
    mesh = jax.make_mesh((1,), ("data",))
    lcfg = B.LegacyBLDNNConfig(
        top_k_frac=cfg.top_k_frac, alpha=cfg.alpha, lr=cfg.lr,
        precondition=cfg.precondition, fisher_alpha=cfg.fisher_alpha,
        eps=cfg.eps, use_basis=cfg.use_basis)
    bases = B.layer_bases_from_params(params0, use_basis=cfg.use_basis)
    state = B.init_fed_state(params0, bases, 1)
    step = jax.jit(B.make_fed_train_step(loss_fn, mesh, lcfg, bases, params0))
    params, losses, traj = params0, [], []
    for _ in range(steps):
        traj.append(params)
        params, state, m = step(params, state, client_data)
        losses.append(float(m["loss"]))
    return losses, traj


@pytest.mark.parametrize("cfg,steps,tol", [
    # gradient leg only: the engine reproduces the legacy trajectory
    # BITWISE (tol 0) over 12 rounds
    (B.BLDNNConfig(lr=0.05, top_k_frac=0.1, precondition=False), 12, 0.0),
    # with the Fisher/preconditioning leg the 1/(√F+ε) update amplifies
    # last-ulp scan-vs-eager compile differences exponentially, so the pin
    # is short-horizon ≤1e-6
    (B.BLDNNConfig(lr=0.01, top_k_frac=0.1, precondition=True), 6, 1e-6),
])
def test_engine_matches_legacy_loop_single_client(problem, cfg, steps, tol):
    """The promoted `BLDNNSpec` reproduces the legacy hand-rolled loop's
    per-round parameter trajectory and loss stream (deterministic Top-K;
    1 client, so fleet means are identities) — the pin that licenses
    deleting the old path."""
    from repro.core.client_batch import tree_batch
    from repro.core.rounds import VmapReducer, _engine_jit

    batch, params0, loss_fn, eval_fn = problem
    one = jax.tree.map(lambda a: a[:1], batch.data)
    client_data = jax.tree.map(lambda a: a[0], one)

    legacy_losses, legacy_traj = _legacy_trajectory(
        loss_fn, params0, client_data, cfg, steps)

    b1 = tree_batch(one)
    spec = B.build_spec(loss_fn, eval_fn, params0, cfg)
    basis = per_layer_svd_basis(params0)
    keys = jax.random.split(jax.random.PRNGKey(0), steps)
    xs_t, _leds = _engine_jit(spec, VmapReducer(n=1), b1, basis, params0,
                              keys)

    h = B.run_bldnn(loss_fn, eval_fn, params0, b1, steps, cfg,
                    backend="fast")
    np.testing.assert_allclose(h.metrics["loss"], legacy_losses,
                               rtol=tol, atol=tol)
    for t, ref in enumerate(legacy_traj):
        got = jax.tree.map(lambda a, t=t: a[t], xs_t)
        for ga, gb in zip(jax.tree.leaves(got), jax.tree.leaves(ref)):
            np.testing.assert_allclose(np.asarray(ga), np.asarray(gb),
                                       rtol=tol, atol=tol)


MULTI_CLIENT_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.fed import bldnn as B

batch, params0 = B.make_synthetic_classification(
    seed=0, n_clients=8, m=64, d=32, classes=4, width=48)
loss_fn = B.make_loss_fn(4); eval_fn = B.make_eval_fn()
cfg = B.BLDNNConfig(lr=0.05, top_k_frac=0.1)
assert len(jax.devices()) == 8

# engine: single-device vmap vs 8-device shard_map — BITWISE histories
h = B.run_bldnn(loss_fn, eval_fn, params0, batch, 20, cfg, backend="fast")
hs = B.run_bldnn(loss_fn, eval_fn, params0, batch, 20, cfg,
                 backend="fast+sharded")
assert h.gaps == hs.gaps, (h.gaps, hs.gaps)
assert h.metrics["loss"] == hs.metrics["loss"]
assert h.up_bits == hs.up_bits and h.down_bits == hs.down_bits
assert h.gaps[-1] < h.gaps[0]

# engine vs the legacy hand-rolled loop (1 client per device): per-round
# loss stream parity to 1e-6 on the non-chaotic gradient-only config (the
# preconditioned update amplifies last-ulp compile differences — see the
# single-client parametrized pin)
gcfg = B.BLDNNConfig(lr=0.05, top_k_frac=0.1, precondition=False)
hg = B.run_bldnn(loss_fn, eval_fn, params0, batch, 20, gcfg, backend="fast")
mesh = jax.make_mesh((8,), ("data",))
lcfg = B.LegacyBLDNNConfig(top_k_frac=gcfg.top_k_frac, alpha=gcfg.alpha,
                           lr=gcfg.lr, precondition=False)
bases = B.layer_bases_from_params(params0)
state = B.init_fed_state(params0, bases, 8)
step = jax.jit(B.make_fed_train_step(loss_fn, mesh, lcfg, bases, params0))
# the legacy loop shards a FLAT (n·B, ...) batch over the mesh (client i's
# rows land on device i); the engine takes the client-stacked (n, B, ...)
flat = jax.tree.map(lambda a: a.reshape((-1,) + a.shape[2:]), batch.data)
params, losses = params0, []
for _ in range(20):
    params, state, m = step(params, state, flat)
    losses.append(float(m["loss"]))
np.testing.assert_allclose(hg.metrics["loss"], losses, rtol=1e-6, atol=1e-6)
print("FED_ENGINE_PARITY_OK", h.gaps[0], "->", h.gaps[-1])
"""


def test_engine_parity_eight_clients_subprocess():
    """8 real devices: engine vmap-vs-sharded bitwise + legacy-loop loss
    parity (subprocess because the device count locks at first jax init;
    JAX_PLATFORMS pinned — an unpinned child burns minutes probing TPUs)."""
    r = subprocess.run([sys.executable, "-c", MULTI_CLIENT_SCRIPT],
                       capture_output=True, text=True, timeout=900,
                       env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                            "JAX_PLATFORMS": "cpu"})
    assert "FED_ENGINE_PARITY_OK" in r.stdout, r.stdout + r.stderr[-3000:]
