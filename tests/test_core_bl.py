"""System behaviour tests for BL1/BL2/BL3 and baselines against the paper's
claims: basis exactness, FedNL equivalence, superlinear local rates, and the
communication-cost ordering of Table 1 / Figures 1–2."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import baselines, bl, glm
from repro.core.basis import (
    PSDBasis,
    StandardBasis,
    SymmetricBasis,
    orth_basis_from_data,
)
from repro.core.compressors import Identity, RankR, TopK


@pytest.fixture(scope="module")
def problem():
    clients = glm.make_synthetic(seed=0, n_clients=8, m=50, d=60, r=20, lam=1e-3)
    x0 = jnp.zeros(60, jnp.float64)
    xs = glm.newton_solve(clients, x0, 20)
    return clients, x0, xs


# ------------------------------ bases --------------------------------------
@settings(max_examples=10, deadline=None)
@given(d=st.integers(3, 12), seed=st.integers(0, 50))
def test_basis_roundtrip_property(d, seed):
    A = jnp.asarray(np.random.default_rng(seed).standard_normal((d, d)))
    A = (A + A.T) / 2
    for B in [StandardBasis(d), SymmetricBasis(d), PSDBasis(d)]:
        np.testing.assert_allclose(
            np.asarray(B.reconstruct(B.h(A))), np.asarray(A), atol=1e-10
        )


def test_data_basis_exact_on_hessian(problem):
    clients, x0, _ = problem
    for c in clients[:3]:
        basis = orth_basis_from_data(c.A)
        Hd = glm.hess_data_part(c, x0)
        np.testing.assert_allclose(
            np.asarray(basis.reconstruct(basis.h(Hd))), np.asarray(Hd), atol=1e-9
        )
        # coefficient matrix is exactly r×r — everything else is 0 (Eq. 5)
        hmat = np.asarray(basis.h(Hd))
        assert np.abs(hmat[basis.r :, :]).max() == 0
        assert np.abs(hmat[:, basis.r :]).max() == 0


def test_psd_basis_matrices_are_psd():
    """Example 5.1's defining property, needed by BL3."""
    d = 5
    for j in range(d):
        for l in range(j + 1):
            B = np.zeros((d, d))
            if j == l:
                B[j, j] = 1
            else:
                B[j, l] = B[l, j] = B[j, j] = B[l, l] = 1
            assert np.linalg.eigvalsh(B).min() >= -1e-12


def test_psd_htilde_reconstruct_roundtrip():
    d = 7
    A = np.random.default_rng(0).standard_normal((d, d))
    A = (A + A.T) / 2
    M = bl._psd_h_tilde(jnp.asarray(A))
    back = bl._psd_reconstruct_full(M)
    np.testing.assert_allclose(np.asarray(back), A, atol=1e-10)


# ------------------------------ BL1 -----------------------------------------
def test_bl1_standard_basis_equals_fednl_shape(problem):
    """BL1 with the standard basis IS FedNL: h(A) = A, so the trajectory must
    match a direct FedNL implementation (here: BL1 where basis ops are
    identities) — we check self-consistency + convergence."""
    clients, x0, xs = problem
    n = len(clients)
    bases = [StandardBasis(60) for _ in range(n)]
    comp = [RankR(r=1) for _ in range(n)]
    h = bl.bl1(clients, bases, comp, Identity(), x0, xs, steps=25)
    assert h.gaps[-1] < 1e-8
    assert h.gaps[-1] < h.gaps[0]


def test_bl1_superlinear_local_rate(problem):
    """Theorem 4.10: with exact init near x*, the gap ratio must shrink."""
    clients, x0, xs = problem
    n = len(clients)
    bases = [orth_basis_from_data(c.A) for c in clients]
    comp = [TopK(k=b.r) for b in bases]
    h = bl.bl1(clients, bases, comp, Identity(), x0, xs, steps=14)
    g = np.asarray(h.gaps)
    g = g[g > 1e-13]
    ratios = g[1:] / g[:-1]
    # superlinear: contraction factors shrink once the Hessian estimate is
    # learned (ratios[0] is the initial exact-Newton jump; ratios[1] is the
    # compression-lagged worst case)
    assert np.min(ratios[2:]) < 0.25 * ratios[1] + 1e-12
    assert ratios[-1] < ratios[1]
    assert g[-1] < 1e-9


def test_bl1_beats_standard_basis_in_bits(problem):
    """The paper's core claim: same accuracy with far fewer bits when r≪d."""
    clients, x0, xs = problem
    n = len(clients)
    data_bases = [orth_basis_from_data(c.A) for c in clients]
    std_bases = [StandardBasis(60) for _ in range(n)]
    h_data = bl.bl1(clients, data_bases, [TopK(k=b.r) for b in data_bases],
                    Identity(), x0, xs, steps=20)
    h_std = bl.bl1(clients, std_bases, [RankR(r=1) for _ in range(n)],
                   Identity(), x0, xs, steps=20)

    def bits_to(h, tol):
        g = np.asarray(h.gaps)
        idx = np.argmax(g < tol)
        return h.up_bits[idx] if g[idx] < tol else np.inf

    assert bits_to(h_data, 1e-8) < bits_to(h_std, 1e-8)


def test_bl1_bidirectional_compression_converges(problem):
    clients, x0, xs = problem
    bases = [orth_basis_from_data(c.A) for c in clients]
    comp = [TopK(k=max(1, b.r // 2)) for b in bases]
    h = bl.bl1(clients, bases, comp, TopK(k=30), x0, xs, steps=40,
               alpha=1.0, eta=1.0, p=0.5, seed=3)
    assert h.gaps[-1] < 1e-6
    assert h.down_bits[-1] > 0  # backside compression active


# ------------------------------ BL2 / BL3 -----------------------------------
def test_bl2_full_participation_converges(problem):
    clients, x0, xs = problem
    bases = [orth_basis_from_data(c.A) for c in clients]
    h = bl.bl2(clients, bases, [TopK(k=b.r * 4) for b in bases],
               [Identity() for _ in clients], x0, xs, steps=25)
    assert h.gaps[-1] < 1e-7
    assert h.gaps[-1] < h.gaps[0] * 1e-4


def test_bl2_partial_participation_converges(problem):
    clients, x0, xs = problem
    bases = [orth_basis_from_data(c.A) for c in clients]
    h = bl.bl2(clients, bases, [TopK(k=b.r * 2) for b in bases],
               [Identity() for _ in clients], x0, xs, steps=40, tau=4, seed=2)
    assert h.gaps[-1] < 1e-6


def test_bl3_converges_and_beats_gd_in_bits(problem):
    clients, x0, xs = problem
    h3 = bl.bl3(clients, [TopK(k=120) for _ in clients],
                [Identity() for _ in clients], x0, xs, steps=60, option=2)
    assert h3.gaps[-1] < h3.gaps[0] * 1e-2
    hg = baselines.gd(clients, x0, xs, 200)
    # at equal bit budgets BL3 achieves a lower gap
    budget = h3.up_bits[-1]
    gd_idx = np.searchsorted(hg.up_bits, budget)
    gd_idx = min(gd_idx, len(hg.gaps) - 1)
    assert h3.gaps[-1] < hg.gaps[gd_idx]


def test_bl3_option1_converges(problem):
    clients, x0, xs = problem
    h = bl.bl3(clients, [TopK(k=300) for _ in clients],
               [Identity() for _ in clients], x0, xs, steps=40, option=1)
    assert h.gaps[-1] < h.gaps[0] * 1e-2


# ------------------------------ baselines -----------------------------------
def test_newton_basis_trajectory_identical(problem):
    """§A.4 / Table 1: the basis change is LOSSLESS — identical iterates at
    ~ (d²+d)/(r²+r) fewer floats per iteration."""
    clients, x0, xs = problem
    bases = [orth_basis_from_data(c.A) for c in clients]
    h1 = baselines.newton(clients, x0, xs, 6)
    h2 = baselines.newton(clients, x0, xs, 6, bases=bases)
    np.testing.assert_allclose(h1.gaps, h2.gaps, rtol=1e-5, atol=1e-12)
    per_iter_naive = h1.up_bits[2] - h1.up_bits[1]
    per_iter_basis = h2.up_bits[2] - h2.up_bits[1]
    d, r = 60, bases[0].r
    assert per_iter_naive / per_iter_basis == pytest.approx(
        (d * d + d) / (r * r + r), rel=1e-6
    )


def test_second_order_beats_first_order_in_bits(problem):
    """Fig. 1 row 2: BL1 beats GD/DIANA by orders of magnitude in bits."""
    clients, x0, xs = problem
    bases = [orth_basis_from_data(c.A) for c in clients]
    h_bl = bl.bl1(clients, bases, [TopK(k=b.r) for b in bases],
                  Identity(), x0, xs, steps=15)
    from repro.core.compressors import RandomDithering
    comp = RandomDithering(s=8)
    h_d = baselines.diana(clients, x0, xs, 150, comp, comp.omega_for(60))
    tol = 1e-6
    gb = np.asarray(h_bl.gaps)
    bl_bits = h_bl.up_bits[int(np.argmax(gb < tol))]
    gd_ = np.asarray(h_d.gaps)
    reached = gd_ < tol
    diana_bits = h_d.up_bits[int(np.argmax(reached))] if reached.any() else np.inf
    assert bl_bits * 5 < diana_bits  # ≥5× better (paper: orders of magnitude)


def test_nl1_converges(problem):
    clients, x0, xs = problem
    h = baselines.nl1(clients, x0, xs, steps=30, k=1)
    assert h.gaps[-1] < h.gaps[0] * 1e-3


def test_first_order_methods_monotone_decrease(problem):
    clients, x0, xs = problem
    for fn in [
        lambda: baselines.gd(clients, x0, xs, 30),
        lambda: baselines.local_gd(clients, x0, xs, 15),
    ]:
        h = fn()
        g = np.asarray(h.gaps)
        assert g[-1] < g[0]


def test_dore_like_bidirectional(problem):
    clients, x0, xs = problem
    h = baselines.dore_like(clients, x0, xs, 60, TopK(k=30), TopK(k=30))
    assert h.gaps[-1] < h.gaps[0]
    assert h.down_bits[-1] > 0


# ------------------------------ projection ----------------------------------
@settings(max_examples=10, deadline=None)
@given(d=st.integers(2, 10), seed=st.integers(0, 100))
def test_proj_mu_property(d, seed):
    A = jnp.asarray(np.random.default_rng(seed).standard_normal((d, d)))
    mu = 0.1
    P = bl.proj_mu(A, mu)
    w = np.linalg.eigvalsh(np.asarray(P))
    assert w.min() >= mu - 1e-9
    np.testing.assert_allclose(np.asarray(P), np.asarray(P).T, atol=1e-10)
    # idempotent on feasible matrices
    P2 = bl.proj_mu(P, mu)
    np.testing.assert_allclose(np.asarray(P2), np.asarray(P), atol=1e-9)
