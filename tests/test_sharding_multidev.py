"""Multi-device sharding tests (subprocess: 8 virtual CPU devices).

Verifies that distributed execution is NUMERICALLY IDENTICAL to the
single-device reference — expert-parallel MoE vs the global dispatch path,
a sharded train step vs the unsharded one, and the round engine's
client-sharded aggregation backend (shard_map reducer) vs the single-device
fast path — the last one BITWISE.
"""
import subprocess
import sys


SCRIPT_MOE = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.models import layers as L
from repro.models.config import LayerSpec, ModelConfig, MoEConfig
from repro.sharding.rules import make_rules

cfg = ModelConfig(name="t", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                  d_ff=128, vocab_size=256,
                  group=(LayerSpec(ffn="moe"),),
                  moe=MoEConfig(n_experts=8, top_k=2, n_shared=1, d_expert=96,
                                capacity_factor=8.0))  # big cap: no drops
p = L.init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
rng = np.random.default_rng(0)
x = jnp.asarray(rng.standard_normal((4, 16, 64)), jnp.float32)

# reference: global path (rules=None)
ref, aux_ref = L.moe(p, x, cfg, None)

# distributed: 2 data x 4 model, expert-parallel path
mesh = jax.make_mesh((2, 4), ("data", "model"))
rules = make_rules(mesh, batch_size=4)
with mesh:
    xs = jax.device_put(x, NamedSharding(mesh, P("data", None, None)))
    ps = jax.tree.map(lambda a: jax.device_put(a, NamedSharding(mesh, P())), p)
    ps["wi"] = jax.device_put(p["wi"], NamedSharding(mesh, P("model", None, None)))
    ps["wg"] = jax.device_put(p["wg"], NamedSharding(mesh, P("model", None, None)))
    ps["wo"] = jax.device_put(p["wo"], NamedSharding(mesh, P("model", None, None)))
    out, aux = jax.jit(lambda pp, xx: L.moe(pp, xx, cfg, rules))(ps, xs)

err = float(jnp.abs(out - ref).max())
# aux is the mean of per-data-shard load-balance losses — close to but not
# bit-identical with the global one (documented local-aux convention)
auxerr = abs(float(aux) - float(aux_ref))
assert err < 2e-4, err
assert auxerr < 5e-3, auxerr
print("MOE_PARITY_OK", err, auxerr)
"""

SCRIPT_TRAIN = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import get_config
from repro.models import model as M
from repro.models.steps import make_train_step
from repro.optim import adamw_init
from repro.sharding.rules import make_rules, param_specs

cfg = get_config("stablelm_12b").reduced()
params = M.init_params(jax.random.PRNGKey(0), cfg, jnp.float32)
opt = adamw_init(params)
rng = np.random.default_rng(1)
batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (8, 33)), jnp.int32)}

# single-device reference
_,_,m_ref = jax.jit(make_train_step(cfg, None, remat=False))(params, opt, batch)

mesh = jax.make_mesh((4, 2), ("data", "model"))
rules = make_rules(mesh, batch_size=8)
with mesh:
    specs = param_specs(params, cfg, rules)
    ps = jax.tree.map(jax.device_put, params, specs)
    os_ = adamw_init(ps)
    bs = {"tokens": jax.device_put(batch["tokens"], NamedSharding(mesh, P(("data",), None)))}
    _,_,m = jax.jit(make_train_step(cfg, rules, remat=True))(ps, os_, bs)

d = abs(float(m["loss"]) - float(m_ref["loss"]))
assert d < 5e-3, (float(m["loss"]), float(m_ref["loss"]))
print("TRAIN_PARITY_OK", d)
"""


SCRIPT_ROUND_ENGINE = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.core import baselines, bl, glm
from repro.core.basis import orth_basis_from_data
from repro.core.compressors import Identity, TopK

clients = glm.make_synthetic(seed=0, n_clients=8, m=30, d=40, r=12, lam=1e-3)
x0 = jnp.zeros(40, jnp.float64)
xs = glm.newton_solve(clients, x0, 20)
bases = [orth_basis_from_data(c.A) for c in clients]
r = bases[0].r
n = 8
assert len(jax.devices()) == 8

runs = {
    # block-mode BL1, full-d BL2 with partial participation, PSD BL3, and
    # the Bernoulli-aggregation spec: every carry/reduction shape the
    # engine supports crosses the shard_map boundary here
    "bl1": lambda b: bl.bl1(clients, bases, [TopK(k=r)] * n, Identity(),
                            x0, xs, 12, backend=b),
    "bl2pp": lambda b: bl.bl2(clients, bases, [TopK(k=2 * r)] * n,
                              [Identity()] * n, x0, xs, 15, tau=3, seed=2,
                              backend=b),
    "bl3": lambda b: bl.bl3(clients, [Identity()] * n, [Identity()] * n,
                            x0, xs, 10, backend=b),
    "bag": lambda b: baselines.fednl_bag(clients, bases, [TopK(k=r)] * n,
                                         x0, xs, 12, q=0.5, seed=1, backend=b),
}
for name, run in runs.items():
    h_fast = run("fast")            # single-device: all 8 clients on dev 0
    h_sh = run("fast+sharded")      # 8 clients sharded 1-per-device
    assert h_sh.gaps == h_fast.gaps, (name, h_sh.gaps, h_fast.gaps)
    assert h_sh.up_bits == h_fast.up_bits, name
    assert h_sh.down_bits == h_fast.down_bits, name
# reference parity holds through the sharded backend too (deterministic,
# full-participation configs only — bl2pp/bag draw different PRNG streams)
for name in ("bl1", "bl3"):
    h_ref = runs[name]("reference")
    h_sh = runs[name]("fast+sharded")
    np.testing.assert_allclose(h_sh.gaps, h_ref.gaps, rtol=1e-9, atol=1e-8)
    np.testing.assert_allclose(h_sh.up_bits, h_ref.up_bits, rtol=1e-12)
print("ROUND_ENGINE_BITWISE_OK")
"""


SCRIPT_SERVE_CHUNKED = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.core import batched, comm, faults, glm, rounds
from repro.core.compressors import Identity, TopK

clients = glm.make_synthetic(seed=0, n_clients=8, m=24, d=20, r=8, lam=1e-3)
from repro.core.basis import orth_basis_from_data
bases = [orth_basis_from_data(c.A) for c in clients]
x0 = jnp.zeros(20, jnp.float64)
spec, batch, basisb = batched.bl2_setup(
    clients, bases, [TopK(k=8)] * 8, [Identity()] * 8, tau=4)
assert len(jax.devices()) == 8
root = jax.random.PRNGKey(3)
plan = faults.FaultPlan(n=8, dropout_p=0.25,
                        outages=(faults.Outage(5, 4, 10),), seed=13)

def drive(sharded, chunk, t1=16):
    carry = rounds.init_serve_carry(spec, batch, basisb, x0, sharded=sharded)
    xs, evs, legs = [], [], {k: [] for k in comm.CommLedger.LEGS}
    t = 0
    while t < t1:
        steps = min(chunk, t1 - t)
        avail, _ = plan.schedule(t, steps)
        carry, ys = rounds.run_chunk(spec, batch, basisb, x0, carry, t,
                                     steps, root, avail=avail,
                                     sharded=sharded)
        xs.append(np.asarray(ys[0])); evs.append(np.asarray(ys[2]))
        for k in legs:
            legs[k].append(np.asarray(getattr(ys[1], k)))
        t += steps
    return (np.concatenate(xs), np.concatenate(evs),
            {k: np.concatenate(v) for k, v in legs.items()}, carry)

# 8-device chunked serve ≡ single-device, and chunk-size invariant — the
# resume contract (carry crosses the shard_map boundary between chunks)
v1 = drive(False, 16)      # vmap, one chunk
s1 = drive(True, 16)       # shard_map, one chunk
s2 = drive(True, 5)        # shard_map, resumed every 5 rounds
for a, b in ((s1, v1), (s2, v1)):
    np.testing.assert_array_equal(a[0], b[0])
    np.testing.assert_array_equal(a[1], b[1])
    for k in a[2]:
        np.testing.assert_array_equal(a[2][k], b[2][k])
for la, lb in zip(jax.tree_util.tree_leaves(s2[3]),
                  jax.tree_util.tree_leaves(v1[3])):
    np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))
print("SERVE_CHUNKED_MULTIDEV_OK")
"""


SCRIPT_APPROX = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.core import baselines, bl, glm
from repro.core.basis import orth_basis_from_data
from repro.core.compressors import Identity, TopK

clients = glm.make_synthetic(seed=0, n_clients=8, m=30, d=40, r=12, lam=1e-3)
x0 = jnp.zeros(40, jnp.float64)
xs = glm.newton_solve(clients, x0, 20)
bases = [orth_basis_from_data(c.A) for c in clients]
r = bases[0].r
n = 8
assert len(jax.devices()) == 8

runs = {
    "bl1": lambda **kw: bl.bl1(clients, bases, [TopK(k=r)] * n, Identity(),
                               x0, xs, 12, **kw),
    "bl2pp": lambda **kw: bl.bl2(clients, bases, [TopK(k=2 * r)] * n,
                                 [Identity()] * n, x0, xs, 12, tau=3, seed=2,
                                 **kw),
    "bl3": lambda **kw: bl.bl3(clients, [Identity()] * n, [Identity()] * n,
                               x0, xs, 10, **kw),
    "bag": lambda **kw: baselines.fednl_bag(clients, bases, [TopK(k=r)] * n,
                                            x0, xs, 12, q=0.5, seed=1, **kw),
}
# exact=False swaps the fixed-order gather for ring collectives (psum /
# pmean per the spec's ReducePlan): reductions associate in ring order, so
# trajectories may drift by ulps — but over a pinned short horizon they
# must stay inside a tight envelope of the exact run, and the bit
# ACCOUNTING (sums of exactly-representable bit prices) must not move.
for name, run in runs.items():
    h_ex = run(backend="fast+sharded")               # exact=True default
    h_ap = run(backend="fast+sharded", exact=False)  # ring collectives
    np.testing.assert_allclose(h_ap.gaps, h_ex.gaps, rtol=1e-6, atol=1e-12,
                               err_msg=name)
    np.testing.assert_allclose(h_ap.up_bits, h_ex.up_bits, rtol=1e-9,
                               err_msg=name)
    np.testing.assert_allclose(h_ap.down_bits, h_ex.down_bits, rtol=1e-9,
                               err_msg=name)
print("APPROX_ENVELOPE_OK")
"""


SCRIPT_STREAM = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.core import bl, glm
from repro.core.basis import orth_basis_from_data
from repro.core.compressors import Identity, TopK
from repro.core.rounds import StreamHook

clients = glm.make_synthetic(seed=0, n_clients=8, m=24, d=20, r=8, lam=1e-3)
x0 = jnp.zeros(20, jnp.float64)
xs = glm.newton_solve(clients, x0, 20)
bases = [orth_basis_from_data(c.A) for c in clients]
assert len(jax.devices()) == 8

seen = []
def cb(t, x, led):
    # host callback sees fully-gathered server state: the round index, the
    # replicated iterate, and the cumulative ledger
    seen.append((int(t), np.asarray(x).shape, float(np.asarray(led.hess_up))))

hook = StreamHook(every=2, callback=cb)
h1 = bl.bl1(clients, bases, [TopK(k=8)] * 8, Identity(), x0, xs, 5,
            backend="fast+sharded", stream=hook)
jax.effects_barrier()
h0 = bl.bl1(clients, bases, [TopK(k=8)] * 8, Identity(), x0, xs, 5,
            backend="fast+sharded")
assert [t for t, _, _ in seen] == [0, 2, 4], seen
assert all(shape == (20,) for _, shape, _ in seen), seen
hb = [b for _, _, b in seen]
assert hb == sorted(hb), seen             # cumulative ledger is monotone
assert h1.gaps == h0.gaps and h1.up_bits == h0.up_bits
print("STREAM_SHARDED_OK")
"""


def _run(script):
    # JAX_PLATFORMS=cpu: on images with an accelerator plugin an unpinned
    # subprocess burns minutes probing for hardware before falling back
    return subprocess.run([sys.executable, "-c", script], capture_output=True,
                          text=True, timeout=900,
                          env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                               "JAX_PLATFORMS": "cpu"})


def test_expert_parallel_moe_matches_global_path():
    r = _run(SCRIPT_MOE)
    assert "MOE_PARITY_OK" in r.stdout, r.stdout + r.stderr[-3000:]


def test_sharded_train_step_matches_single_device():
    r = _run(SCRIPT_TRAIN)
    assert "TRAIN_PARITY_OK" in r.stdout, r.stdout + r.stderr[-3000:]


def test_round_engine_shard_map_reducer_bitwise():
    """Clients sharded over 8 devices reproduce the single-device fast-path
    histories BITWISE (gaps, uplink and downlink bits) for BL1/BL2/BL3 and
    the FedNL-BAG spec, and stay within reference parity."""
    r = _run(SCRIPT_ROUND_ENGINE)
    assert "ROUND_ENGINE_BITWISE_OK" in r.stdout, r.stdout + r.stderr[-3000:]


def test_serve_chunked_driver_multidev_bitwise():
    """The service-loop chunked driver on 8 devices — carry resumed across
    chunk boundaries through the shard_map program — is bitwise equal to the
    single-device single-chunk run under a non-trivial fault plan."""
    r = _run(SCRIPT_SERVE_CHUNKED)
    assert "SERVE_CHUNKED_MULTIDEV_OK" in r.stdout, r.stdout + r.stderr[-3000:]


def test_nonexact_collectives_stay_in_parity_envelope():
    """exact=False (ring psum/pmean per the spec's ReducePlan) on 8 devices
    tracks the exact fixed-order run within a ≤1e-6 relative envelope over
    a pinned horizon, for BL1/BL2/BL3 and FedNL-BAG, with unchanged bit
    accounting."""
    r = _run(SCRIPT_APPROX)
    assert "APPROX_ENVELOPE_OK" in r.stdout, r.stdout + r.stderr[-3000:]


def test_streamhook_mid_run_emission_on_8_devices():
    """The acceptance scenario for sharded streaming: a StreamHook attached
    to backend='fast+sharded' on 8 devices fires mid-run at its cadence
    with gathered server state, and the history it rode along is bitwise
    the hook-free run."""
    r = _run(SCRIPT_STREAM)
    assert "STREAM_SHARDED_OK" in r.stdout, r.stdout + r.stderr[-3000:]
