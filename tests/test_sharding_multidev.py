"""Multi-device sharding tests (subprocess: 8 virtual CPU devices).

Verifies that distributed execution is NUMERICALLY IDENTICAL to the
single-device reference — expert-parallel MoE vs the global dispatch path,
and a sharded train step vs the unsharded one.
"""
import subprocess
import sys

import pytest

SCRIPT_MOE = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.models import layers as L
from repro.models.config import LayerSpec, ModelConfig, MoEConfig
from repro.sharding.rules import make_rules

cfg = ModelConfig(name="t", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                  d_ff=128, vocab_size=256,
                  group=(LayerSpec(ffn="moe"),),
                  moe=MoEConfig(n_experts=8, top_k=2, n_shared=1, d_expert=96,
                                capacity_factor=8.0))  # big cap: no drops
p = L.init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
rng = np.random.default_rng(0)
x = jnp.asarray(rng.standard_normal((4, 16, 64)), jnp.float32)

# reference: global path (rules=None)
ref, aux_ref = L.moe(p, x, cfg, None)

# distributed: 2 data x 4 model, expert-parallel path
mesh = jax.make_mesh((2, 4), ("data", "model"))
rules = make_rules(mesh, batch_size=4)
with mesh:
    xs = jax.device_put(x, NamedSharding(mesh, P("data", None, None)))
    ps = jax.tree.map(lambda a: jax.device_put(a, NamedSharding(mesh, P())), p)
    ps["wi"] = jax.device_put(p["wi"], NamedSharding(mesh, P("model", None, None)))
    ps["wg"] = jax.device_put(p["wg"], NamedSharding(mesh, P("model", None, None)))
    ps["wo"] = jax.device_put(p["wo"], NamedSharding(mesh, P("model", None, None)))
    out, aux = jax.jit(lambda pp, xx: L.moe(pp, xx, cfg, rules))(ps, xs)

err = float(jnp.abs(out - ref).max())
# aux is the mean of per-data-shard load-balance losses — close to but not
# bit-identical with the global one (documented local-aux convention)
auxerr = abs(float(aux) - float(aux_ref))
assert err < 2e-4, err
assert auxerr < 5e-3, auxerr
print("MOE_PARITY_OK", err, auxerr)
"""

SCRIPT_TRAIN = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import get_config
from repro.models import model as M
from repro.models.steps import make_train_step
from repro.optim import adamw_init
from repro.sharding.rules import make_rules, param_specs

cfg = get_config("stablelm_12b").reduced()
params = M.init_params(jax.random.PRNGKey(0), cfg, jnp.float32)
opt = adamw_init(params)
rng = np.random.default_rng(1)
batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (8, 33)), jnp.int32)}

# single-device reference
_,_,m_ref = jax.jit(make_train_step(cfg, None, remat=False))(params, opt, batch)

mesh = jax.make_mesh((4, 2), ("data", "model"))
rules = make_rules(mesh, batch_size=8)
with mesh:
    specs = param_specs(params, cfg, rules)
    ps = jax.tree.map(jax.device_put, params, specs)
    os_ = adamw_init(ps)
    bs = {"tokens": jax.device_put(batch["tokens"], NamedSharding(mesh, P(("data",), None)))}
    _,_,m = jax.jit(make_train_step(cfg, rules, remat=True))(ps, os_, bs)

d = abs(float(m["loss"]) - float(m_ref["loss"]))
assert d < 5e-3, (float(m["loss"]), float(m_ref["loss"]))
print("TRAIN_PARITY_OK", d)
"""


def _run(script):
    return subprocess.run([sys.executable, "-c", script], capture_output=True,
                          text=True, timeout=900,
                          env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"})


def test_expert_parallel_moe_matches_global_path():
    r = _run(SCRIPT_MOE)
    assert "MOE_PARITY_OK" in r.stdout, r.stdout + r.stderr[-3000:]


def test_sharded_train_step_matches_single_device():
    r = _run(SCRIPT_TRAIN)
    assert "TRAIN_PARITY_OK" in r.stdout, r.stdout + r.stderr[-3000:]
