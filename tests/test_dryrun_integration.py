"""End-to-end dry-run integration (subprocess: 512 virtual devices).

Lowers one light (arch × shape) pair on the production mesh — exercises
mesh construction, ShapeDtypeStruct input specs, param/cache shardings and
the jit lowering path without paying a full compile.
"""
import json
import os
import subprocess
import sys

import pytest

# Inherit the parent environment (jax/XLA hang during backend init in
# sandboxed containers when HOME/proxy vars are scrubbed); the test's
# isolation only needs PYTHONPATH pinned to the repo's src tree.
_SUBPROC_ENV = {**os.environ, "PYTHONPATH": "src"}


@pytest.mark.parametrize("arch,shape", [
    ("whisper_small", "decode_32k"),
    ("mamba2_370m", "long_500k"),
])
def test_dryrun_lowers_on_production_mesh(arch, shape):
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--arch", arch, "--shape", shape, "--no-compile"],
        capture_output=True, text=True, timeout=900,
        env=_SUBPROC_ENV)
    lines = [l for l in r.stdout.splitlines() if l.startswith("{")]
    assert lines, r.stdout + r.stderr[-2000:]
    rec = json.loads(lines[0])
    assert rec["status"] == "lowered", rec
    assert rec["mesh"] == "16x16"


def test_dryrun_multipod_mesh_shape():
    r = subprocess.run(
        [sys.executable, "-c",
         "import os; os.environ['XLA_FLAGS']='--xla_force_host_platform_device_count=512';"
         "from repro.launch.mesh import make_production_mesh;"
         "m = make_production_mesh(multi_pod=True);"
         "print(dict(m.shape), m.axis_names)"],
        capture_output=True, text=True, timeout=300,
        env=_SUBPROC_ENV)
    assert "{'pod': 2, 'data': 16, 'model': 16}" in r.stdout, r.stdout + r.stderr
    assert "('pod', 'data', 'model')" in r.stdout
