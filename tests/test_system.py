"""System-level tests: data pipeline determinism, sharding rules, dry-run
collective parser, config registry, analysis accounting."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, ALIASES, all_configs, get_config
from repro.data import SyntheticTokens, make_batch_iterator
from repro.models.analysis import active_param_count, model_flops, param_count


def test_registry_all_archs_load():
    cfgs = all_configs()
    assert len(cfgs) == 10
    for a, cfg in cfgs.items():
        assert cfg.n_layers % len(cfg.group) == 0
    # aliases resolve
    for alias in ALIASES:
        assert get_config(alias).name


def test_assigned_config_values_exact():
    """The registry must carry the EXACT assigned hyperparameters."""
    c = get_config("deepseek-moe-16b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads) == (28, 2048, 16, 16)
    assert (c.moe.n_experts, c.moe.top_k, c.moe.n_shared) == (64, 6, 2)
    assert c.vocab_size == 102400 and c.d_ff == 1408
    c = get_config("mamba2-370m")
    assert (c.n_layers, c.d_model, c.vocab_size) == (48, 1024, 50280)
    assert c.ssm.d_state == 128
    c = get_config("granite-20b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff) == \
        (52, 6144, 48, 1, 24576)
    c = get_config("gemma3-4b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads) == (34, 2560, 8, 4)
    windows = [s.window for s in c.layer_specs()]
    assert windows.count(None) * 5 <= len(windows)  # ≈5:1 local:global
    c = get_config("qwen2-vl-72b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff) == \
        (80, 8192, 64, 8, 29568)
    assert c.mrope
    c = get_config("jamba-1.5-large-398b")
    assert c.n_layers == 72 and len(c.group) == 8
    mixers = [s.mixer for s in c.group]
    assert mixers.count("attn") == 1 and mixers.count("mamba") == 7
    c = get_config("whisper-small")
    assert c.n_enc_layers == 12 and c.enc_seq == 1500


def test_param_counts_match_model_cards():
    """Total parameter counts land near the named sizes."""
    expect = {
        "deepseek_moe_16b": (14e9, 20e9),
        "mamba2_370m": (0.3e9, 0.5e9),
        "granite_20b": (18e9, 23e9),
        "llama4_maverick_400b_a17b": (350e9, 450e9),
        "gemma3_4b": (3.0e9, 5.5e9),
        "whisper_small": (0.15e9, 0.35e9),
        "codeqwen15_7b": (6e9, 9e9),
        "qwen2_vl_72b": (62e9, 80e9),
        "stablelm_12b": (10e9, 14e9),
        "jamba_15_large_398b": (330e9, 430e9),
    }
    for arch, (lo, hi) in expect.items():
        n = param_count(get_config(arch))
        assert lo <= n <= hi, f"{arch}: {n/1e9:.1f}B not in [{lo/1e9},{hi/1e9}]"


def test_active_params_a17b():
    cfg = get_config("llama4_maverick_400b_a17b")
    a = active_param_count(cfg)
    assert 12e9 <= a <= 22e9, a / 1e9   # "a17b"
    cfg = get_config("deepseek_moe_16b")
    a = active_param_count(cfg)
    assert 2e9 <= a <= 4.5e9, a / 1e9   # 16B total / 2.8B active


def test_model_flops_kinds():
    cfg = get_config("mamba2_370m")
    t = model_flops(cfg, "train", 256, 4096)
    p = model_flops(cfg, "prefill", 32, 32768)
    d = model_flops(cfg, "decode", 128, 32768)
    assert t > p > d
    assert d == pytest.approx(2.0 * active_param_count(cfg) * 128)


def test_data_pipeline_deterministic_and_learnable():
    gen = SyntheticTokens(vocab_size=1000, seq_len=64, global_batch=4, seed=7)
    b1, b2 = gen.batch(3), gen.batch(3)
    np.testing.assert_array_equal(b1, b2)
    assert not np.array_equal(gen.batch(3), gen.batch(4))
    assert b1.min() >= 0 and b1.max() < 1000
    # bigram structure: successor pairs appear more than chance
    succ = gen.successor
    hits = sum(int(succ[b1[i, j - 1]] == b1[i, j])
               for i in range(4) for j in range(1, 64))
    assert hits > 0.2 * 4 * 63


def test_batch_iterator_extras():
    it = make_batch_iterator(100, 16, 2, extras={"frames": (2, 8, 4)},
                             dtype=jnp.float32)
    b = next(it)
    assert b["tokens"].shape == (2, 16)
    assert b["frames"].shape == (2, 8, 4)


def test_collective_parser():
    from repro.launch.dryrun import collective_bytes
    hlo = """
HloModule m
%body (x: f32[8]) -> f32[8] {
  %ag = f32[64,128]{1,0} all-gather(%p), dimensions={0}
  %ar = bf16[32]{0} all-reduce(%q), to_apply=%add
}
ENTRY %main () -> f32[8] {
  %w = f32[8] while(%init), body=%body, condition=%cond
  %a2a = f32[16,16]{1,0} all-to-all(%z), dimensions={1}
}
"""
    r = collective_bytes(hlo)
    assert r["bytes_by_kind"]["all-to-all"] == 16 * 16 * 4
    assert r["bytes_by_kind"]["all-gather"] >= 64 * 128 * 4
    assert r["bytes_by_kind"]["all-reduce"] >= 32 * 2
    assert r["total_bytes"] > 0


def test_shape_applicability():
    from repro.launch.shapes import SHAPES, shape_applicable
    ok, _ = shape_applicable(get_config("mamba2_370m"), SHAPES["long_500k"])
    assert ok
    ok, why = shape_applicable(get_config("granite_20b"), SHAPES["long_500k"])
    assert not ok and "full-attention" in why
    ok, _ = shape_applicable(get_config("gemma3_4b"), SHAPES["long_500k"])
    assert ok  # sliding-window variant
    for name in ("train_4k", "prefill_32k", "decode_32k"):
        for arch in ARCH_IDS:
            ok, _ = shape_applicable(get_config(arch), SHAPES[name])
            assert ok


def test_sharding_rules_divisibility():
    """Every spec'd dim must divide by its mesh axes for every arch."""
    from repro.models import model as M
    from repro.sharding.rules import make_rules, param_specs
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        shapes = M.param_shapes(cfg, jnp.bfloat16)
        rules = make_rules(mesh, batch_size=256)
        specs = param_specs(shapes, cfg, rules)  # must not raise
        n = len(jax.tree.leaves(specs))
        assert n == len(jax.tree.leaves(shapes))
