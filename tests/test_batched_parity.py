"""Fast-path (repro.core.batched) vs reference-backend parity.

Deterministic compressors + full participation must give identical
trajectories (≤1e-8 gap difference); stochastic configurations draw from a
different PRNG stream and are checked on their convergence envelope only.
BL3's Top-K configurations are additionally tie-sensitive (a 1e-15
perturbation can flip which of two near-tied coefficients is kept), so the
strict parity check uses a tie-free compressor and the Top-K check is a
relative envelope.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import baselines, batched, bl, glm
from repro.core import compressors as C
from repro.core.basis import StandardBasis, orth_basis_from_data
from repro.core.compressors import (
    Identity,
    NaturalCompression,
    RandK,
    RandomDithering,
    RankR,
    TopK,
    nrankr,
    ntopk,
    rrankr,
    rtopk,
)

GAP_TOL = 1e-8


@pytest.fixture(scope="module")
def problem():
    clients = glm.make_synthetic(seed=0, n_clients=6, m=30, d=40, r=12, lam=1e-3)
    x0 = jnp.zeros(40, jnp.float64)
    xs = glm.newton_solve(clients, x0, 20)
    return clients, x0, xs


def _both(fn):
    """Run the same config on both backends and return (reference, fast)."""
    return fn("reference"), fn("fast")


def _assert_parity(h_ref, h_fast, gap_tol=GAP_TOL):
    # atol pins converged trajectories at ≤1e-8; the tiny rtol only matters
    # for transient gaps ≫1 where 1e-8 absolute is below f64 resolution
    np.testing.assert_allclose(h_fast.gaps, h_ref.gaps, rtol=1e-9, atol=gap_tol)
    np.testing.assert_allclose(h_fast.up_bits, h_ref.up_bits, rtol=1e-12)
    np.testing.assert_allclose(h_fast.down_bits, h_ref.down_bits, rtol=1e-12)


# ------------------------------ BL1 -----------------------------------------
def test_bl1_parity_data_basis_topk(problem):
    clients, x0, xs = problem
    bases = [orth_basis_from_data(c.A) for c in clients]
    r = bases[0].r
    h_ref, h_fast = _both(
        lambda b: bl.bl1(clients, bases, [TopK(k=r) for _ in clients],
                         Identity(), x0, xs, 14, backend=b)
    )
    _assert_parity(h_ref, h_fast)
    assert h_fast.gaps[-1] < 1e-9  # still superlinear on the fast path


def test_bl1_parity_standard_basis_rankr(problem):
    """StandardBasis + Rank-R ≡ FedNL — the paper's headline comparison."""
    clients, x0, xs = problem
    bases = [StandardBasis(40) for _ in clients]
    h_ref, h_fast = _both(
        lambda b: bl.bl1(clients, bases, [RankR(r=1) for _ in clients],
                         Identity(), x0, xs, 14, backend=b)
    )
    _assert_parity(h_ref, h_fast)


def test_bl1_parity_no_exact_init(problem):
    clients, x0, xs = problem
    bases = [orth_basis_from_data(c.A) for c in clients]
    r = bases[0].r
    h_ref, h_fast = _both(
        lambda b: bl.bl1(clients, bases, [TopK(k=2 * r) for _ in clients],
                         Identity(), x0, xs, 12, init_exact_hessian=False,
                         backend=b)
    )
    _assert_parity(h_ref, h_fast)


def test_bl1_stochastic_envelope(problem):
    """Different PRNG streams ⇒ distributional match only: both backends
    converge with the composed dithered Top-K compressor."""
    clients, x0, xs = problem
    bases = [orth_basis_from_data(c.A) for c in clients]
    r = bases[0].r
    h_ref, h_fast = _both(
        lambda b: bl.bl1(clients, bases, [rtopk(2 * r) for _ in clients],
                         Identity(), x0, xs, 20, alpha=0.5, backend=b)
    )
    assert h_fast.gaps[-1] < 1e-8
    assert h_ref.gaps[-1] < 1e-8


def test_bl1_bidirectional_stochastic_envelope(problem):
    clients, x0, xs = problem
    bases = [orth_basis_from_data(c.A) for c in clients]
    r = bases[0].r
    h_fast = bl.bl1(clients, bases, [TopK(k=r) for _ in clients],
                    TopK(k=20), x0, xs, 30, p=0.5, seed=3, backend="fast")
    assert h_fast.gaps[-1] < 1e-8
    assert h_fast.down_bits[-1] > 0


# ------------------------------ BL2 -----------------------------------------
def test_bl2_parity_full_participation(problem):
    clients, x0, xs = problem
    bases = [orth_basis_from_data(c.A) for c in clients]
    r = bases[0].r
    h_ref, h_fast = _both(
        lambda b: bl.bl2(clients, bases, [TopK(k=4 * r) for _ in clients],
                         [Identity() for _ in clients], x0, xs, 14, backend=b)
    )
    _assert_parity(h_ref, h_fast)
    assert h_fast.gaps[-1] < 1e-7


def test_bl2_partial_participation_envelope(problem):
    """τ<n draws participation masks from different streams — envelope only."""
    clients, x0, xs = problem
    bases = [orth_basis_from_data(c.A) for c in clients]
    r = bases[0].r
    h_fast = bl.bl2(clients, bases, [TopK(k=2 * r) for _ in clients],
                    [Identity() for _ in clients], x0, xs, 35, tau=3, seed=2,
                    backend="fast")
    assert h_fast.gaps[-1] < 1e-6


# ------------------------------ BL3 -----------------------------------------
def test_bl3_parity_tie_free(problem):
    """Identity Hessian compressor: no Top-K tie-flips, strict parity holds
    for both β options."""
    clients, x0, xs = problem
    for option in (1, 2):
        h_ref, h_fast = _both(
            lambda b, option=option: bl.bl3(
                clients, [Identity() for _ in clients],
                [Identity() for _ in clients], x0, xs, 12, option=option,
                backend=b)
        )
        _assert_parity(h_ref, h_fast)


def test_bl3_topk_envelope(problem):
    """Aggressive Top-K is tie-sensitive: the backends may pick different
    near-tied coefficients, so require a tight *relative* envelope."""
    clients, x0, xs = problem
    h_ref, h_fast = _both(
        lambda b: bl.bl3(clients, [TopK(k=80) for _ in clients],
                         [Identity() for _ in clients], x0, xs, 15, backend=b)
    )
    g_ref = np.asarray(h_ref.gaps)
    g_fast = np.asarray(h_fast.gaps)
    np.testing.assert_allclose(g_fast, g_ref, rtol=1e-3)
    np.testing.assert_allclose(h_fast.up_bits, h_ref.up_bits, rtol=1e-12)


# ------------------------------ shard_map reducer ---------------------------
def test_sharded_reducer_parity_all_methods(problem):
    """backend="fast+sharded" routes all cross-client reductions through the
    shard_map `Reducer` (a trivial 1-device client mesh in this process).
    It must (a) stay within the reference-parity envelope and (b) reproduce
    the vmap backend's histories bitwise — the engine emits evaluation
    iterates from the scan and computes gaps in one shared program, so any
    trajectory divergence between the aggregation backends shows up here."""
    clients, x0, xs = problem
    bases = [orth_basis_from_data(c.A) for c in clients]
    r = bases[0].r
    runs = {
        "bl1": lambda b: bl.bl1(clients, bases, [TopK(k=r) for _ in clients],
                                Identity(), x0, xs, 12, backend=b),
        "bl2": lambda b: bl.bl2(clients, bases, [TopK(k=4 * r) for _ in clients],
                                [Identity() for _ in clients], x0, xs, 12,
                                backend=b),
        "bl3": lambda b: bl.bl3(clients, [Identity() for _ in clients],
                                [Identity() for _ in clients], x0, xs, 10,
                                backend=b),
    }
    for name, run in runs.items():
        h_ref, h_fast, h_sh = run("reference"), run("fast"), run("fast+sharded")
        _assert_parity(h_ref, h_sh)
        assert h_sh.gaps == h_fast.gaps, name
        assert h_sh.up_bits == h_fast.up_bits, name
        assert h_sh.down_bits == h_fast.down_bits, name


# ------------------------------ FedNL-BAG spec ------------------------------
def _bag_hand_rolled(clients, bases, comp, x0, x_star, steps, alpha, q, seed):
    """Op-by-op loop mirroring specs.FedNLBAGSpec's PRNG layout exactly."""
    from repro.core.bl import (_client_hcoef, _init_bits, _server_reconstruct,
                               proj_mu)

    n = len(clients)
    d = x0.shape[0]
    lam = clients[0].lam
    f_star = float(glm.global_loss(clients, x_star))
    keys = jax.random.split(jax.random.PRNGKey(seed), steps)
    z = x0
    L = [_client_hcoef(bases[i], clients[i], x0) for i in range(n)]
    H = sum(_server_reconstruct(bases[i], L[i], lam) for i in range(n)) / n
    gtab = [glm.grad(clients[i], x0) for i in range(n)]  # lazy gradient table
    up = sum(_init_bits(b, True) for b in bases) / n + d * C.FLOAT_BITS
    gaps, ups = [], []
    for t in range(steps):
        gaps.append(max(float(glm.global_loss(clients, z)) - f_star, 0.0))
        ups.append(up)
        k_h, k_b = jax.random.split(keys[t], 2)
        send = np.asarray(jax.random.bernoulli(k_b, q, (n,)))
        for i in range(n):
            if send[i]:
                gtab[i] = glm.grad(clients[i], z)
        ghat = sum(gtab) / n
        up += send.sum() * d * C.FLOAT_BITS / n
        cks = jax.random.split(k_h, n)
        H_delta = jnp.zeros((d, d), x0.dtype)
        bits = 0.0
        for i in range(n):
            target = _client_hcoef(bases[i], clients[i], z)
            S, b_ = comp(cks[i], target - L[i])
            L[i] = L[i] + alpha * S
            H_delta = H_delta + bases[i].reconstruct(alpha * S)
            bits += float(b_)
        H = H + H_delta / n
        up += bits / n
        # η = q damping (the public wrapper's default)
        z = z - q * jnp.linalg.solve(proj_mu(H, clients[0].lam), ghat)
    return gaps, ups


@pytest.mark.parametrize("q", [1.0, 0.5])
def test_fednl_bag_matches_hand_rolled_reference(problem, q):
    """The new Bernoulli-aggregation spec (the 'methods are cheap specs'
    demonstration) against an independent op-by-op loop drawing from the
    same PRNG stream: deterministic Top-K ⇒ strict parity."""
    clients, x0, xs = problem
    bases = [orth_basis_from_data(c.A) for c in clients]
    r = bases[0].r
    h = baselines.fednl_bag(clients, bases, [TopK(k=2 * r) for _ in clients],
                            x0, xs, 25, q=q, seed=3, backend="fast")
    gaps, ups = _bag_hand_rolled(clients, bases, TopK(k=2 * r), x0, xs, 25,
                                 alpha=1.0, q=q, seed=3)
    np.testing.assert_allclose(h.gaps, gaps, rtol=1e-9, atol=GAP_TOL)
    np.testing.assert_allclose(h.up_bits, ups, rtol=1e-12)
    assert h.gaps[-1] < 1e-6  # Newton-type convergence survives q<1


def test_fednl_bag_rejects_reference_backend(problem):
    clients, x0, xs = problem
    with pytest.raises(ValueError):
        baselines.fednl_bag(clients, [StandardBasis(40)] * 6, [Identity()] * 6,
                            x0, xs, 2, backend="reference")


# ------------------------------ dispatch ------------------------------------
def test_fast_backend_raises_on_heterogeneous_compressors(problem):
    clients, x0, xs = problem
    bases = [orth_basis_from_data(c.A) for c in clients]
    comps = [TopK(k=5 + i) for i in range(len(clients))]  # per-client configs
    with pytest.raises(batched.FastPathUnavailable):
        bl.bl1(clients, bases, comps, Identity(), x0, xs, 2, backend="fast")


def test_auto_backend_falls_back(problem):
    """auto silently routes heterogeneous configs to the reference loops and
    must agree with an explicit reference run."""
    clients, x0, xs = problem
    bases = [orth_basis_from_data(c.A) for c in clients]
    comps = [TopK(k=10 + i) for i in range(len(clients))]
    h_auto = bl.bl1(clients, bases, comps, Identity(), x0, xs, 4, backend="auto")
    h_ref = bl.bl1(clients, bases, comps, Identity(), x0, xs, 4, backend="reference")
    np.testing.assert_allclose(h_auto.gaps, h_ref.gaps, atol=0)


def test_invalid_backend_rejected(problem):
    clients, x0, xs = problem
    with pytest.raises(ValueError):
        bl.bl1(clients, [StandardBasis(40)] * 6, [Identity()] * 6, Identity(),
               x0, xs, 1, backend="warp")


# ------------------------------ compressors ---------------------------------
@pytest.mark.parametrize(
    "mk",
    [
        lambda: Identity(),
        lambda: TopK(k=9),
        lambda: TopK(k=9, symmetrize=True),
        lambda: RandK(k=7),
        lambda: RankR(r=2),
        lambda: RandomDithering(s=4),
        lambda: NaturalCompression(),
        lambda: ntopk(6),
        lambda: rtopk(6),
        lambda: nrankr(2),
        lambda: rrankr(2, 12),
    ],
)
def test_batched_compressor_matches_loop(mk):
    """`Compressor.compress` (the one natively-batched contract) must agree
    bitwise with the per-client adapter loop — this is what makes the fast
    path's wire identical to the reference's."""
    from repro.core import comm

    comp = mk()
    X = jnp.asarray(np.random.default_rng(1).standard_normal((5, 12, 12)))
    if getattr(comp, "symmetrize", False):
        X = (X + X.transpose(0, 2, 1)) / 2.0
    keys = jax.random.split(jax.random.PRNGKey(0), 5)
    out_b, counts = comp.compress(keys, X)
    bits_b = comm.price(comp.wire, counts)
    assert bits_b.shape == (5,)
    for i in range(5):
        out_i, bits_i = comp(keys[i], X[i])
        np.testing.assert_array_equal(np.asarray(out_b[i]), np.asarray(out_i))
        np.testing.assert_array_equal(np.asarray(bits_b[i]), np.asarray(bits_i))


def test_dither_bit_count_is_host_side():
    """The dithering bit count must not force a device→host sync (satellite
    fix): it is a Python number before jnp.asarray, derived with math.log2."""
    comp = RandomDithering(s=11)
    x = jnp.asarray(np.random.default_rng(0).standard_normal(32))
    _, bits = jax.jit(comp.__call__)(jax.random.PRNGKey(0), x)
    # 1 norm float + 32 * (1 sign + ceil(log2(12)) = 4 level bits)
    assert float(bits) == C.FLOAT_BITS + 32 * (1 + 4)


# ------------------------------ baselines -----------------------------------
def test_gd_fast_parity(problem):
    clients, x0, xs = problem
    h_ref = baselines.gd(clients, x0, xs, 25, backend="reference")
    h_fast = baselines.gd(clients, x0, xs, 25, backend="fast")
    np.testing.assert_allclose(h_fast.gaps, h_ref.gaps, atol=GAP_TOL)
    np.testing.assert_allclose(h_fast.up_bits, h_ref.up_bits)


def test_newton_fast_parity(problem):
    clients, x0, xs = problem
    bases = [orth_basis_from_data(c.A) for c in clients]
    for kw in (dict(), dict(bases=bases)):
        h_ref = baselines.newton(clients, x0, xs, 6, backend="reference", **kw)
        h_fast = baselines.newton(clients, x0, xs, 6, backend="fast", **kw)
        np.testing.assert_allclose(h_fast.gaps, h_ref.gaps, atol=GAP_TOL)
        np.testing.assert_allclose(h_fast.up_bits, h_ref.up_bits, rtol=1e-12)


def test_diana_fast_envelope(problem):
    clients, x0, xs = problem
    comp = RandomDithering(s=8)
    h_ref = baselines.diana(clients, x0, xs, 120, comp, comp.omega_for(40),
                            backend="reference")
    h_fast = baselines.diana(clients, x0, xs, 120, comp, comp.omega_for(40),
                             backend="fast")
    # same deterministic bit schedule, stochastic gaps within the same decade
    np.testing.assert_allclose(h_fast.up_bits, h_ref.up_bits)
    assert h_fast.gaps[-1] < h_fast.gaps[0]
    assert abs(np.log10(h_fast.gaps[-1] + 1e-16) - np.log10(h_ref.gaps[-1] + 1e-16)) < 1.5


def test_baselines_invalid_backend_rejected(problem):
    clients, x0, xs = problem
    with pytest.raises(ValueError):
        baselines.gd(clients, x0, xs, 2, backend="warp")
    with pytest.raises(ValueError):
        baselines.newton(clients, x0, xs, 2, backend="refrence")
