"""Basis-registry contracts: exact h/reconstruct round-trips for EVERY
registered basis (including the new eigen/DCT rotations), registry lookup,
batched-kind agreement, shipment billing, and the two new bases running
end-to-end through BL1/BL2 with per-leg ledger output."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import bl, client_batch, glm
from repro.core.basis import (
    DCTBasis,
    EigenBasis,
    PerLayerSVDBasis,
    StructuredTreeBasis,
    available_bases,
    basis_transmission_bits,
    is_pytree_basis,
    make_bases,
    quantize_ship_factor,
)
from repro.core.comm import BasisShipSpec
from repro.core.compressors import Identity, TopK

EXPECTED = {"standard", "symmetric", "psd", "data_outer", "eigen", "dct",
            "per_layer_svd", "dct_tree", "hadamard_tree"}
PYTREE_KINDS = ("per_layer_svd", "dct_tree", "hadamard_tree")


def _matrix_bases():
    """The d×d-contract bases (pytree bases transform parameter trees and
    have their own contract tests in tests/test_fed.py)."""
    return [n for n in available_bases() if not is_pytree_basis(n)]


@pytest.fixture(scope="module")
def problem():
    clients = glm.make_synthetic(seed=0, n_clients=5, m=30, d=30, r=10, lam=1e-3)
    x0 = jnp.zeros(30, jnp.float64)
    xs = glm.newton_solve(clients, x0, 20)
    return clients, x0, xs


def test_registry_contents():
    assert EXPECTED <= set(available_bases())
    assert is_pytree_basis("per_layer_svd") and not is_pytree_basis("eigen")
    with pytest.raises(KeyError, match="unknown basis"):
        make_bases("warp", [])


def test_per_layer_svd_registry_roundtrip():
    """The pytree basis builds through the same `make_bases` registry door
    and round-trips parameter trees exactly (its full contract tests live
    with the BL-DNN layer in tests/test_fed.py)."""
    import jax

    params = {"w": jnp.asarray(np.random.default_rng(0)
                               .standard_normal((12, 7)), jnp.float32),
              "b": jnp.zeros((7,), jnp.float32)}
    basis = make_bases("per_layer_svd", params)
    assert isinstance(basis, PerLayerSVDBasis)
    back = basis.unrotate(basis.rotate(params))
    for a, b in zip(jax.tree.leaves(back), jax.tree.leaves(params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-5)
    assert basis.ship_floats() == 12 * 12 + 7 * 7


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 200))
def test_roundtrip_every_registered_basis(problem, seed):
    """reconstruct(h(A)) == A exactly (to fp) for every registered basis on
    symmetric matrices — data bases on matrices in their span."""
    clients, x0, _ = problem
    rng = np.random.default_rng(seed)
    d = 30
    S = rng.standard_normal((d, d))
    S = jnp.asarray((S + S.T) / 2)
    for name in _matrix_bases():
        bases = make_bases(name, clients, x0=x0)
        b = bases[0]
        if name == "data_outer":
            # a matrix in the client's span: V M Vᵀ
            M = rng.standard_normal((b.r, b.r))
            M = jnp.asarray((M + M.T) / 2)
            target = b.V @ M @ b.V.T
        else:
            target = S
        back = b.reconstruct(b.h(target))
        np.testing.assert_allclose(np.asarray(back), np.asarray(target),
                                   atol=1e-9, err_msg=name)


def test_rotation_bases_are_orthogonal():
    clients = glm.make_synthetic(seed=1, n_clients=3, m=20, d=16, r=6, lam=1e-3)
    for name in ("eigen", "dct"):
        b = make_bases(name, clients, x0=jnp.zeros(16, jnp.float64))[0]
        QtQ = np.asarray(b.Q.T @ b.Q)
        np.testing.assert_allclose(QtQ, np.eye(16), atol=1e-9)


def test_batched_kind_matches_per_client_ops(problem):
    """BatchedBasis.h/reconstruct == the per-client MatrixBasis ops for every
    stackable registered basis (the fast path's wire == the reference's)."""
    clients, x0, _ = problem
    rng = np.random.default_rng(7)
    A = rng.standard_normal((5, 30, 30))
    A = jnp.asarray((A + A.transpose(0, 2, 1)) / 2)
    for name in _matrix_bases():
        bases = make_bases(name, clients, x0=x0)
        bb = client_batch.stack_bases(bases)
        assert bb is not None, name
        hb = np.asarray(bb.h(A))
        rb = np.asarray(bb.reconstruct(bb.h(A)))
        for i, b in enumerate(bases):
            np.testing.assert_allclose(hb[i], np.asarray(b.h(A[i])),
                                       atol=1e-10, err_msg=name)
            np.testing.assert_allclose(
                rb[i], np.asarray(b.reconstruct(b.h(A[i]))), atol=1e-10,
                err_msg=name)


def test_shipment_billing():
    clients = glm.make_synthetic(seed=2, n_clients=3, m=20, d=12, r=5, lam=1e-3)
    x0 = jnp.zeros(12, jnp.float64)
    eig = make_bases("eigen", clients, x0=x0)[0]
    dct = make_bases("dct", clients)[0]
    std = make_bases("standard", clients)[0]
    dat = make_bases("data_outer", clients)[0]
    assert basis_transmission_bits(eig) == 12 * 12 * 64   # learned: Q ships
    assert basis_transmission_bits(dct) == 0.0            # convention: free
    assert basis_transmission_bits(std) == 0.0
    assert basis_transmission_bits(dat) == dat.d * dat.r * 64
    assert isinstance(eig, EigenBasis) and isinstance(dct, DCTBasis)


@pytest.mark.parametrize("name", ["eigen", "dct"])
def test_new_bases_end_to_end_bl1_bl2(problem, name):
    """Acceptance: EigenBasis and DCTBasis run through BL1 AND BL2 on the
    fast path, converge, agree with the reference loops, and report per-leg
    ledger output (eigen pays a d² basis shipment, dct ships free)."""
    clients, x0, xs = problem
    bases = make_bases(name, clients, x0=x0)
    comp = [TopK(k=200) for _ in clients]
    h1r = bl.bl1(clients, bases, comp, Identity(), x0, xs, 12,
                 backend="reference")
    h1 = bl.bl1(clients, bases, comp, Identity(), x0, xs, 12, backend="fast")
    np.testing.assert_allclose(h1.gaps, h1r.gaps, rtol=1e-9, atol=1e-8)
    np.testing.assert_allclose(h1.up_bits, h1r.up_bits, rtol=1e-12)
    assert h1.gaps[-1] < 1e-8
    h2 = bl.bl2(clients, bases, comp, [Identity()] * 5, x0, xs, 12,
                backend="fast")
    assert h2.gaps[-1] < 1e-6
    for h in (h1, h2):
        ship = 30 * 30 * 64 if name == "eigen" else 0.0
        assert h.legs["basis_ship"] == [ship] * 12
        assert h.legs["hess_up"][-1] > 0


# --------------------------------------------------------------------------
# pytree bases: DCT/Hadamard structured rotations + compressed shipment
# --------------------------------------------------------------------------
def _dnn_params(seed=0, d_in=12, width=8, d_out=7):
    rng = np.random.default_rng(seed)
    return {"w1": jnp.asarray(rng.standard_normal((d_in, width)) * 0.3,
                              jnp.float32),
            "b1": jnp.asarray(rng.standard_normal((width,)), jnp.float32),
            "w2": jnp.asarray(rng.standard_normal((width, d_out)) * 0.3,
                              jnp.float32)}


def _check_pytree_roundtrip(kind, seed):
    params = _dnn_params(seed)
    basis = make_bases(kind, params)
    tree = jax.tree.map(
        lambda x: jnp.asarray(np.random.default_rng(seed + 1)
                              .standard_normal(x.shape), x.dtype), params)
    back = basis.unrotate(basis.rotate(tree))
    for a, b in zip(jax.tree.leaves(back), jax.tree.leaves(tree)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-5, err_msg=kind)


@pytest.mark.parametrize("kind", PYTREE_KINDS)
def test_pytree_basis_roundtrip(kind):
    """rotate/unrotate is the identity (to fp) for every registered pytree
    basis, including the structured DCT/Hadamard rotations."""
    for seed in (0, 1, 2):
        _check_pytree_roundtrip(kind, seed)


@pytest.mark.requires_hypothesis
@settings(max_examples=12, deadline=None)
@given(kind=st.sampled_from(PYTREE_KINDS), seed=st.integers(0, 5000))
def test_pytree_basis_roundtrip_prop(kind, seed):
    _check_pytree_roundtrip(kind, seed)


@pytest.mark.parametrize("kind", PYTREE_KINDS)
def test_pytree_basis_batched_agreement(kind):
    """Rotating an (n, ...) client stack equals stacking per-client
    rotations — the batched engine's wire is the per-client wire."""
    params = _dnn_params(3)
    basis = make_bases(kind, params)
    rng = np.random.default_rng(4)
    n = 5
    stack = jax.tree.map(
        lambda x: jnp.asarray(rng.standard_normal((n,) + x.shape),
                              jnp.float32), params)
    rot = basis.rotate(stack)
    for i in range(n):
        per = basis.rotate(jax.tree.map(lambda x: x[i], stack))
        for a, b in zip(jax.tree.leaves(rot), jax.tree.leaves(per)):
            np.testing.assert_allclose(np.asarray(a)[i], np.asarray(b),
                                       rtol=1e-5, atol=1e-6, err_msg=kind)


def test_structured_tree_basis_ships_free():
    params = _dnn_params(5)
    for kind in ("dct_tree", "hadamard_tree"):
        basis = make_bases(kind, params)
        assert isinstance(basis, StructuredTreeBasis)
        assert basis.ship_floats() == 0.0
        shipped, bits = basis.shipped(BasisShipSpec(float_bits=8))
        assert shipped is basis and bits == 0.0
    svd = make_bases("per_layer_svd", params)
    assert svd.ship_floats() == (12 * 12 + 8 * 8) + (8 * 8 + 7 * 7)


@pytest.mark.parametrize("kind", PYTREE_KINDS)
def test_pytree_ship_floats_matches_ledger(kind):
    """End-to-end: the BL-DNN ledger's basis_ship leg equals exactly what
    the basis object says it ships (0 for the structured rotations)."""
    from repro.fed import bldnn

    batch, p0 = bldnn.make_synthetic_classification(0, 4, 16, 24, 3, 8)
    cfg = bldnn.BLDNNConfig(top_k_frac=0.25, lr=0.05, basis_kind=kind)
    h = bldnn.run_bldnn(bldnn.make_loss_fn(3), bldnn.make_eval_fn(),
                        p0, batch, 4, cfg, seed=0)
    ship = make_bases(kind, p0).ship_floats() * 32
    assert h.legs["basis_ship"] == [ship] * 4


# --------------------------------------------------------------------------
# compressed shipment: quantizer contract + bf16 eigen convergence envelope
# --------------------------------------------------------------------------
def _check_quantize_contract(seed, rows, cols):
    rng = np.random.default_rng(seed)
    M = jnp.asarray(rng.standard_normal((rows, cols)), jnp.float32)
    # dense f32 shipment is the identity on f32 inputs
    W32, bits32 = quantize_ship_factor(M, BasisShipSpec(float_bits=32))
    np.testing.assert_array_equal(np.asarray(W32), np.asarray(M))
    assert bits32 == rows * cols * 32
    # bf16 is idempotent: re-quantizing a quantized factor is a no-op
    W16, bits16 = quantize_ship_factor(M, BasisShipSpec(float_bits=16))
    W16b, _ = quantize_ship_factor(W16, BasisShipSpec(float_bits=16))
    np.testing.assert_array_equal(np.asarray(W16), np.asarray(W16b))
    assert bits16 == rows * cols * 16
    # int8 error is bounded by half a quantization step per column
    W8, bits8 = quantize_ship_factor(M, BasisShipSpec(float_bits=8))
    scale = np.max(np.abs(np.asarray(M)), axis=0, keepdims=True) / 127.0
    assert np.all(np.abs(np.asarray(W8) - np.asarray(M))
                  <= scale * 0.5 + 1e-7)
    assert bits8 == rows * cols * 8 + cols * 32
    # sparsified columns keep exactly ceil(col_frac·rows) entries each
    ship = BasisShipSpec(float_bits=32, col_frac=0.5)
    Ws, bitss = quantize_ship_factor(M, ship)
    kept = max(1, min(rows, int(np.ceil(0.5 * rows))))
    nnz = np.count_nonzero(np.asarray(Ws), axis=0)
    assert np.all(nnz <= kept)
    assert bitss == kept * cols * 32 + kept * cols * 32  # values + indices


@pytest.mark.requires_hypothesis
@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10_000), rows=st.integers(2, 40),
       cols=st.integers(1, 40))
def test_quantize_ship_factor_prop(seed, rows, cols):
    """f32 dense = identity; bf16 idempotent; int8 within half a step;
    top-k column sparsity keeps what the counts bill."""
    _check_quantize_contract(seed, rows, cols)


def test_quantize_ship_factor_battery():
    for seed, rows, cols in ((0, 2, 1), (1, 12, 7), (2, 40, 40), (3, 5, 30)):
        _check_quantize_contract(seed, rows, cols)


def test_eigen_bf16_ship_convergence_envelope(problem):
    """fig1-regime acceptance: a bf16-shipped eigen basis (half the
    basis_ship bits) still drives BL1 into the same convergence envelope —
    quantizing Q costs accuracy in the basis, not the method."""
    clients, x0, xs = problem
    bases = make_bases("eigen", clients, x0=x0)
    comp = [TopK(k=200) for _ in clients]
    q16, bits16 = bases[0].shipped(BasisShipSpec(float_bits=16))
    assert bits16 == 30 * 30 * 16 == basis_transmission_bits(bases[0], 16)
    assert isinstance(q16, EigenBasis)
    # quantized Q is near-orthogonal (bf16 has ~3 decimal digits)
    QtQ = np.asarray(q16.Q.T @ q16.Q)
    np.testing.assert_allclose(QtQ, np.eye(30), atol=0.05)
    h64 = bl.bl1(clients, bases, comp, Identity(), x0, xs, 12,
                 backend="fast")
    h16 = bl.bl1(clients, [q16] * len(clients), comp, Identity(), x0, xs,
                 12, backend="fast")
    assert h64.gaps[-1] < 1e-8
    assert h16.gaps[-1] < 1e-6, "bf16 basis must stay in the envelope"
