"""Basis-registry contracts: exact h/reconstruct round-trips for EVERY
registered basis (including the new eigen/DCT rotations), registry lookup,
batched-kind agreement, shipment billing, and the two new bases running
end-to-end through BL1/BL2 with per-leg ledger output."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import bl, client_batch, glm
from repro.core.basis import (
    DCTBasis,
    EigenBasis,
    PerLayerSVDBasis,
    available_bases,
    basis_transmission_bits,
    is_pytree_basis,
    make_bases,
)
from repro.core.compressors import Identity, TopK

EXPECTED = {"standard", "symmetric", "psd", "data_outer", "eigen", "dct",
            "per_layer_svd"}


def _matrix_bases():
    """The d×d-contract bases (pytree bases transform parameter trees and
    have their own contract tests in tests/test_fed.py)."""
    return [n for n in available_bases() if not is_pytree_basis(n)]


@pytest.fixture(scope="module")
def problem():
    clients = glm.make_synthetic(seed=0, n_clients=5, m=30, d=30, r=10, lam=1e-3)
    x0 = jnp.zeros(30, jnp.float64)
    xs = glm.newton_solve(clients, x0, 20)
    return clients, x0, xs


def test_registry_contents():
    assert EXPECTED <= set(available_bases())
    assert is_pytree_basis("per_layer_svd") and not is_pytree_basis("eigen")
    with pytest.raises(KeyError, match="unknown basis"):
        make_bases("warp", [])


def test_per_layer_svd_registry_roundtrip():
    """The pytree basis builds through the same `make_bases` registry door
    and round-trips parameter trees exactly (its full contract tests live
    with the BL-DNN layer in tests/test_fed.py)."""
    import jax

    params = {"w": jnp.asarray(np.random.default_rng(0)
                               .standard_normal((12, 7)), jnp.float32),
              "b": jnp.zeros((7,), jnp.float32)}
    basis = make_bases("per_layer_svd", params)
    assert isinstance(basis, PerLayerSVDBasis)
    back = basis.unrotate(basis.rotate(params))
    for a, b in zip(jax.tree.leaves(back), jax.tree.leaves(params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-5)
    assert basis.ship_floats() == 12 * 12 + 7 * 7


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 200))
def test_roundtrip_every_registered_basis(problem, seed):
    """reconstruct(h(A)) == A exactly (to fp) for every registered basis on
    symmetric matrices — data bases on matrices in their span."""
    clients, x0, _ = problem
    rng = np.random.default_rng(seed)
    d = 30
    S = rng.standard_normal((d, d))
    S = jnp.asarray((S + S.T) / 2)
    for name in _matrix_bases():
        bases = make_bases(name, clients, x0=x0)
        b = bases[0]
        if name == "data_outer":
            # a matrix in the client's span: V M Vᵀ
            M = rng.standard_normal((b.r, b.r))
            M = jnp.asarray((M + M.T) / 2)
            target = b.V @ M @ b.V.T
        else:
            target = S
        back = b.reconstruct(b.h(target))
        np.testing.assert_allclose(np.asarray(back), np.asarray(target),
                                   atol=1e-9, err_msg=name)


def test_rotation_bases_are_orthogonal():
    clients = glm.make_synthetic(seed=1, n_clients=3, m=20, d=16, r=6, lam=1e-3)
    for name in ("eigen", "dct"):
        b = make_bases(name, clients, x0=jnp.zeros(16, jnp.float64))[0]
        QtQ = np.asarray(b.Q.T @ b.Q)
        np.testing.assert_allclose(QtQ, np.eye(16), atol=1e-9)


def test_batched_kind_matches_per_client_ops(problem):
    """BatchedBasis.h/reconstruct == the per-client MatrixBasis ops for every
    stackable registered basis (the fast path's wire == the reference's)."""
    clients, x0, _ = problem
    rng = np.random.default_rng(7)
    A = rng.standard_normal((5, 30, 30))
    A = jnp.asarray((A + A.transpose(0, 2, 1)) / 2)
    for name in _matrix_bases():
        bases = make_bases(name, clients, x0=x0)
        bb = client_batch.stack_bases(bases)
        assert bb is not None, name
        hb = np.asarray(bb.h(A))
        rb = np.asarray(bb.reconstruct(bb.h(A)))
        for i, b in enumerate(bases):
            np.testing.assert_allclose(hb[i], np.asarray(b.h(A[i])),
                                       atol=1e-10, err_msg=name)
            np.testing.assert_allclose(
                rb[i], np.asarray(b.reconstruct(b.h(A[i]))), atol=1e-10,
                err_msg=name)


def test_shipment_billing():
    clients = glm.make_synthetic(seed=2, n_clients=3, m=20, d=12, r=5, lam=1e-3)
    x0 = jnp.zeros(12, jnp.float64)
    eig = make_bases("eigen", clients, x0=x0)[0]
    dct = make_bases("dct", clients)[0]
    std = make_bases("standard", clients)[0]
    dat = make_bases("data_outer", clients)[0]
    assert basis_transmission_bits(eig) == 12 * 12 * 64   # learned: Q ships
    assert basis_transmission_bits(dct) == 0.0            # convention: free
    assert basis_transmission_bits(std) == 0.0
    assert basis_transmission_bits(dat) == dat.d * dat.r * 64
    assert isinstance(eig, EigenBasis) and isinstance(dct, DCTBasis)


@pytest.mark.parametrize("name", ["eigen", "dct"])
def test_new_bases_end_to_end_bl1_bl2(problem, name):
    """Acceptance: EigenBasis and DCTBasis run through BL1 AND BL2 on the
    fast path, converge, agree with the reference loops, and report per-leg
    ledger output (eigen pays a d² basis shipment, dct ships free)."""
    clients, x0, xs = problem
    bases = make_bases(name, clients, x0=x0)
    comp = [TopK(k=200) for _ in clients]
    h1r = bl.bl1(clients, bases, comp, Identity(), x0, xs, 12,
                 backend="reference")
    h1 = bl.bl1(clients, bases, comp, Identity(), x0, xs, 12, backend="fast")
    np.testing.assert_allclose(h1.gaps, h1r.gaps, rtol=1e-9, atol=1e-8)
    np.testing.assert_allclose(h1.up_bits, h1r.up_bits, rtol=1e-12)
    assert h1.gaps[-1] < 1e-8
    h2 = bl.bl2(clients, bases, comp, [Identity()] * 5, x0, xs, 12,
                backend="fast")
    assert h2.gaps[-1] < 1e-6
    for h in (h1, h2):
        ship = 30 * 30 * 64 if name == "eigen" else 0.0
        assert h.legs["basis_ship"] == [ship] * 12
        assert h.legs["hess_up"][-1] > 0
