"""Cohort-streaming engine (`repro.core.cohort`): the parity, invariance
and resume contracts that license the flat-in-n refactor.

The load-bearing pins:

  * **cohort == fleet is bitwise the stacked engine** — full mode gathers
    the whole fleet once and dispatches to the EXISTING `rounds.run_chunk`
    program, on both reducers (the sharded leg runs in a subprocess: the
    device count is locked at first jax init).
  * **chunk-boundary invariance** — any segmentation of `run_chunk` calls
    produces the same streams (per-round keys fold in the absolute round
    index; the cohort schedule is a pure function of the absolute epoch).
  * **kill -9 + resume is bit-exact** through the ckpt@2 ``host_state``
    payload (store rows, fleet aggregate totals, the epoch's frozen stats),
    mid-epoch or at an epoch boundary, in-process and through the CLI.

Also here: the `ClientBatch`/`TreeBatch` constructor validation added with
the streaming engine (a mis-shaped gather must fail loudly, not broadcast
into wrong per-client math), and the fig1-xxl registry scenario's shape.
"""
import json
import os
import signal
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import client_batch, cohort, compressors, rounds, specs
from repro.exp import artifacts

jax.config.update("jax_enable_x64", True)

D, M = 6, 8
KEY = jax.random.PRNGKey(7)
X0 = jnp.zeros(D, jnp.float64)


def _bl2(n, tau):
    bb = cohort.standard_basisb(D, n)
    return specs.BL2Spec(
        hess_comp=compressors.TopK(k=2 * D),
        model_comp=compressors.Identity(),
        alpha=1.0, eta=1.0, p=1.0, tau=tau, init_exact=True,
        init_hess_bits=bb.init_coeff_bits_mean(True),
        basis_bits=bb.transmission_bits_mean(), block=False)


def _store(n, seed=11):
    return client_batch.synthetic_store(seed, n, M, D)


def _engine(n, tau, cohort_size, seed=11, **kw):
    kw.setdefault("prefetch", False)
    return cohort.CohortEngine(
        _bl2(n, tau), _store(n, seed), X0, cohort=cohort_size,
        rounds_per_cohort=2, root_key=KEY, basis="standard", **kw)


def _assert_trees_equal(a, b, msg=""):
    la, lb = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb), msg
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                      err_msg=msg)


# --------------------------------------------------------------------------
# constructor validation (ClientBatch / TreeBatch)
# --------------------------------------------------------------------------
def test_clientbatch_rejects_unstacked_A():
    with pytest.raises(ValueError,
                       match=r"client-stacked \(n, m, d\); got shape"):
        client_batch.ClientBatch(A=jnp.zeros((4, 3)), b=jnp.zeros((4,)),
                                 lam=1e-3)


def test_clientbatch_rejects_mismatched_b():
    with pytest.raises(ValueError, match=r"shape \(n, m\) = A\.shape"):
        client_batch.ClientBatch(A=jnp.zeros((4, 3, 2)), b=jnp.zeros((4, 2)),
                                 lam=1e-3)
    # the error names both shapes so a bad gather is diagnosable on sight
    with pytest.raises(ValueError, match=r"\(4, 3\).*got \(3, 4\)"):
        client_batch.ClientBatch(A=jnp.zeros((4, 3, 2)), b=jnp.zeros((3, 4)),
                                 lam=1e-3)


def test_clientbatch_accepts_tracers():
    # validation must not fire on jit re-unflattens of abstract values
    out = jax.eval_shape(
        lambda A, b: client_batch.ClientBatch(A=A, b=b, lam=0.1).A,
        jax.ShapeDtypeStruct((4, 3, 2), jnp.float64),
        jax.ShapeDtypeStruct((4, 3), jnp.float64))
    assert out.shape == (4, 3, 2)


def test_treebatch_rejects_scalar_leaf():
    with pytest.raises(ValueError, match="leading client axis"):
        client_batch.TreeBatch(data={"w": np.zeros(()),
                                     "v": np.zeros((4, 2))}, n_clients=4)


def test_treebatch_rejects_disagreeing_client_axes():
    with pytest.raises(ValueError,
                       match="disagree on the leading client axis"):
        client_batch.TreeBatch(data={"w": np.zeros((4, 2)),
                                     "v": np.zeros((5, 2))}, n_clients=4)


def test_tree_batch_builder_validation():
    with pytest.raises(ValueError, match="at least one data leaf"):
        client_batch.tree_batch({})
    # tree_leaves orders dict keys, so "v" fixes n and "w" violates it
    with pytest.raises(ValueError, match="leading n_clients=5 axis"):
        client_batch.tree_batch({"w": np.zeros((4, 2)),
                                 "v": np.zeros((5, 2))})


def test_cohort_engine_constructor_validation():
    with pytest.raises(ValueError, match="rounds_per_cohort must be >= 1"):
        cohort.CohortEngine(_bl2(8, 8), _store(8), X0, cohort=4,
                            rounds_per_cohort=0, root_key=KEY)
    with pytest.raises(ValueError, match="cohort must be >= 1"):
        cohort.CohortEngine(_bl2(8, 8), _store(8), X0, cohort=0,
                            rounds_per_cohort=1, root_key=KEY)
    with pytest.raises(ValueError, match="not cohort-capable"):
        cohort.CohortEngine(object(), _store(8), X0, cohort=4,
                            rounds_per_cohort=1, root_key=KEY)
    with pytest.raises(ValueError, match="convention basis"):
        cohort.CohortEngine(_bl2(8, 8), _store(8), X0, cohort=8,
                            rounds_per_cohort=1, root_key=KEY,
                            basis="data_outer")


# --------------------------------------------------------------------------
# full mode: cohort == fleet is bitwise the stacked engine
# --------------------------------------------------------------------------
def test_full_mode_bitwise_parity_vmap():
    n = 32
    spec = _bl2(n, n)
    store = _store(n)
    batch = store.gather_batch(np.arange(n))
    bb = cohort.standard_basisb(D, n)
    c0 = rounds.init_serve_carry(spec, batch, bb, X0)
    c1, ys1 = rounds.run_chunk(spec, batch, bb, X0, c0, 0, 6, KEY)

    eng = cohort.CohortEngine(spec, _store(n), X0, cohort=n,
                              rounds_per_cohort=2, root_key=KEY,
                              basis="standard")
    # two calls: full mode must also be invariant to call segmentation
    ys2 = jax.tree.map(lambda *a: jnp.concatenate(a, 0),
                       eng.run_chunk(0, 3), eng.run_chunk(3, 3))
    _assert_trees_equal(ys1, ys2, "full-mode streams != stacked streams")
    _assert_trees_equal(c1, eng._cur["carry"],
                        "full-mode carry != stacked carry")
    eng.close()


_SHARDED_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np
import jax, jax.numpy as jnp
from repro.core import cohort, client_batch, rounds, specs, compressors

d, m = 6, 8
key = jax.random.PRNGKey(7)
x0 = jnp.zeros(d, jnp.float64)

def bl2(n, tau):
    bb = cohort.standard_basisb(d, n)
    return specs.BL2Spec(
        hess_comp=compressors.TopK(k=2 * d),
        model_comp=compressors.Identity(),
        alpha=1.0, eta=1.0, p=1.0, tau=tau, init_exact=True,
        init_hess_bits=bb.init_coeff_bits_mean(True),
        basis_bits=bb.transmission_bits_mean(), block=False)

def eq(a, b):
    return all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(jax.tree_util.tree_leaves(a),
                               jax.tree_util.tree_leaves(b)))

# full mode vs the stacked sharded engine
n = 32
spec = bl2(n, n)
store = client_batch.synthetic_store(11, n, m, d)
batch = store.gather_batch(np.arange(n))
bb = cohort.standard_basisb(d, n)
c0 = rounds.init_serve_carry(spec, batch, bb, x0, sharded=True)
_, ys1 = rounds.run_chunk(spec, batch, bb, x0, c0, 0, 6, key, sharded=True)
eng = cohort.CohortEngine(spec, client_batch.synthetic_store(11, n, m, d),
                          x0, cohort=n, rounds_per_cohort=2, root_key=key,
                          basis="standard", sharded=True)
ys2 = eng.run_chunk(0, 6)
eng.close()
print("FULL_SHARDED", eq(ys1, ys2), flush=True)

# streaming: sharded reducer bitwise == vmap reducer (exact mode)
n2 = 64
spec2 = bl2(n2, 16)
outs = []
for sharded in (False, True):
    e = cohort.CohortEngine(spec2, client_batch.synthetic_store(11, n2, m, d),
                            x0, cohort=16, rounds_per_cohort=2, root_key=key,
                            basis="standard", sharded=sharded, prefetch=False)
    outs.append(e.run_chunk(0, 8))
    e.close()
print("STREAM_SHARDED", eq(outs[0], outs[1]), flush=True)
"""


def test_sharded_parity_subprocess():
    """Both sharded pins in one 8-virtual-device child: full-mode parity
    vs the stacked sharded engine, and streaming vmap == streaming sharded
    (the exact fixed-order reducer contract)."""
    env = dict(os.environ, PYTHONPATH="src", JAX_PLATFORMS="cpu")
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    r = subprocess.run([sys.executable, "-c", _SHARDED_SCRIPT], env=env,
                       cwd=repo, capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, r.stdout + r.stderr[-2000:]
    assert "FULL_SHARDED True" in r.stdout, r.stdout
    assert "STREAM_SHARDED True" in r.stdout, r.stdout


# --------------------------------------------------------------------------
# chunk-boundary invariance
# --------------------------------------------------------------------------
def _run_segmented(segs, seed=11):
    eng = _engine(64, 16, 16, seed=seed)
    outs, t = [], 0
    for s in segs:
        outs.append(eng.run_chunk(t, s))
        t += s
    eng.close()
    if len(outs) == 1:
        return outs[0]
    return jax.tree.map(lambda *a: jnp.concatenate(a, 0), *outs)


def test_chunk_boundary_invariance():
    ref = _run_segmented([12])
    # boundaries landing mid-epoch, at epoch edges, and one-round calls
    _assert_trees_equal(ref, _run_segmented([1, 4, 3, 2, 2]),
                        "segmentation changed the trajectory")
    _assert_trees_equal(ref, _run_segmented([6, 6]),
                        "segmentation changed the trajectory")


@settings(max_examples=8, deadline=None)
@given(cuts=st.lists(st.integers(1, 11), min_size=0, max_size=3),
       seed=st.integers(0, 3))
def test_chunk_boundary_invariance_property(cuts, seed):
    """Property form: ANY sorted cut set of [0, 12) produces the reference
    streams (the deterministic test pins two hand-picked segmentations;
    this one searches the space)."""
    bounds = sorted(set(cuts)) + [12]
    segs, prev = [], 0
    for b in bounds:
        if b > prev:
            segs.append(b - prev)
            prev = b
    _assert_trees_equal(_run_segmented([12], seed=seed),
                        _run_segmented(segs, seed=seed),
                        f"segmentation {segs} changed the trajectory")


# --------------------------------------------------------------------------
# sampler
# --------------------------------------------------------------------------
def test_cohort_sampler_deterministic_and_unique():
    eng = _engine(64, 16, 16)
    i1 = eng.cohort_indices(3)
    assert np.array_equal(i1, eng.cohort_indices(3))
    assert np.unique(i1).size == 16 and i1.min() >= 0 and i1.max() < 64
    assert not np.array_equal(i1, eng.cohort_indices(4))
    # both sampler paths (rejection at c*8 <= n, permutation otherwise)
    big = _engine(64, 32, 32)
    j = big.cohort_indices(0)
    assert np.unique(j).size == 32
    eng.close()
    big.close()


# --------------------------------------------------------------------------
# checkpoint / restore
# --------------------------------------------------------------------------
@pytest.mark.parametrize("tck", [5, 6], ids=["mid_epoch", "epoch_boundary"])
def test_checkpoint_restore_bitwise(tck):
    e1 = _engine(64, 16, 16)
    e1.run_chunk(0, tck)
    leaves, host = e1.checkpoint_payload()
    assert any(k.startswith("store/") for k in host)
    assert any(k.startswith("frozen/") for k in host)
    treedef = jax.tree_util.tree_structure(e1.carry_template())
    tail_ref = e1.run_chunk(tck, 12 - tck)
    e1.close()

    e2 = _engine(64, 16, 16)
    carry = jax.tree_util.tree_unflatten(
        treedef, [jnp.asarray(l) for l in leaves])
    e2.restore(tck, carry, host)
    tail = e2.run_chunk(tck, 12 - tck)
    e2.close()
    _assert_trees_equal(tail_ref, tail, f"restore@{tck} diverged")


def test_restore_rejects_non_streaming_host_state():
    eng = _engine(64, 16, 16)
    template = eng.carry_template()
    with pytest.raises(ValueError, match="lacks.*frozen"):
        eng.restore(4, template, {})
    eng.close()


def test_ckpt_schema_v1_walked_past(tmp_path):
    """A pre-host-state ckpt@1 directory must not be adopted: the loader
    walks past the stale manifest to the newest valid @2 checkpoint (or
    None), instead of resuming without the engine's host plane."""
    artifacts.save_checkpoint(
        str(tmp_path), t=3, carry_leaves=[np.arange(4.0)],
        streams={"eval_x": np.zeros((3, 2))}, root_key=np.zeros(2, np.uint32),
        config_digest="dg", host_state={"store/z": np.ones((4, 2))})
    artifacts.save_checkpoint(
        str(tmp_path), t=9, carry_leaves=[np.arange(4.0) + 9],
        streams={"eval_x": np.zeros((9, 2))}, root_key=np.zeros(2, np.uint32),
        config_digest="dg")
    # downgrade the newest manifest to the retired @1 schema tag
    man = tmp_path / "ckpt-00000009.json"
    m = json.loads(man.read_text())
    m["schema"] = "repro.exp/ckpt@1"
    man.write_text(json.dumps(m))
    ck = artifacts.load_checkpoint(str(tmp_path), config_digest="dg")
    assert ck is not None and ck["t"] == 3
    assert set(ck["host_state"]) == {"store/z"}
    np.testing.assert_array_equal(ck["host_state"]["store/z"],
                                  np.ones((4, 2)))


# --------------------------------------------------------------------------
# serve CLI: kill -9 through ckpt@2
# --------------------------------------------------------------------------
_ENV = {"PYTHONPATH": "src", "PATH": os.environ.get("PATH", "/usr/bin:/bin"),
        "JAX_PLATFORMS": "cpu", "HOME": os.environ.get("HOME", "/tmp")}


def _serve_cli(ckpt_dir, *extra):
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    return subprocess.run(
        [sys.executable, "-m", "repro.launch.fed_serve", "--exp",
         "cohort-smoke", "--cell", "BL2", "--seed", "2", "--max-rounds",
         "12", "--chunk", "3", "--ckpt-dir", str(ckpt_dir), *extra],
        env=_ENV, capture_output=True, text=True, timeout=900, cwd=repo)


def test_serve_cohort_kill9_resume_bitwise(tmp_path):
    """The acceptance scenario on the streaming engine: SIGKILL a serve of
    the cohort-smoke scenario mid-run (losing the in-flight chunk), restart,
    and the final record equals the uninterrupted reference — the ckpt@2
    host_state payload carried the store rows, totals and frozen stats."""
    ref_json = str(tmp_path / "ref.json")
    res_json = str(tmp_path / "res.json")
    r = _serve_cli(tmp_path / "ref", "--result", ref_json)
    assert r.returncode == 0, r.stdout + r.stderr[-2000:]

    r = _serve_cli(tmp_path / "crash", "--crash-after-round", "5")
    assert r.returncode == -signal.SIGKILL, (r.returncode, r.stderr[-500:])
    ts = [t for t, _ in artifacts.list_checkpoints(str(tmp_path / "crash"))]
    assert ts and max(ts) < 12      # the kill actually cost progress

    r = _serve_cli(tmp_path / "crash", "--result", res_json)
    assert r.returncode == 0, r.stdout + r.stderr[-2000:]
    assert "resumed from checkpoint" in r.stdout

    with open(ref_json) as f:
        ref = json.load(f)
    with open(res_json) as f:
        res = json.load(f)
    assert res["meta"]["resumed_from"] == max(ts)
    ref.pop("meta")
    res.pop("meta")
    assert ref == res   # bit-exact: gaps, events, every ledger leg


def test_serve_cohort_refuses_fault_plan_and_stacked_backend(tmp_path):
    from repro.core import faults
    from repro.launch import fed_serve

    with pytest.raises(SystemExit, match="fault"):
        fed_serve.serve(exp_name="cohort-smoke", cell_name="BL2",
                        ckpt_dir=str(tmp_path), max_rounds=2,
                        plan=faults.FaultPlan(n=96, dropout_p=0.5))
    with pytest.raises(SystemExit, match="cohort"):
        fed_serve.serve(exp_name="cohort-smoke", cell_name="BL2",
                        ckpt_dir=str(tmp_path), max_rounds=2,
                        backend="fast")


# --------------------------------------------------------------------------
# registry / engine integration
# --------------------------------------------------------------------------
def test_fig1_xxl_registered_at_streaming_scale():
    from repro.exp import get_experiment

    exp = get_experiment("fig1-xxl")
    assert exp.problem.kind == "synthetic_stream"
    assert exp.problem.n_clients >= 100_000
    assert "stream" in exp.tags
    for cell in exp.cells:
        params = cell.params_dict()
        assert cell.backend == "cohort"
        assert params["cohort"] <= 512


def test_run_cell_streams_cohort_smoke():
    from repro.exp import build_problem, get_experiment, run_cell

    exp = get_experiment("cohort-smoke")
    prob = build_problem(exp.problem)
    cell = exp.cell("BL2")
    h = run_cell(exp, cell, prob, steps=12)
    assert len(h.gaps) == 12
    assert h.gaps[-1] < h.gaps[0]
    assert h.up_bits[-1] > 0.0
    with pytest.raises(ValueError, match="cohort backends"):
        run_cell(exp, cell, prob, steps=2, backend="fast")
