#!/usr/bin/env python3
"""Dead-relative-link checker for the repo's markdown docs.

    python tools/check_links.py README.md docs

Scans the given markdown files (directories are walked for ``*.md``) for
inline links/images ``[text](target)`` and verifies every *relative*
target resolves to an existing file or directory (fragments are stripped;
``http(s):``/``mailto:`` targets are skipped — this repo's CI is offline).
Exits 1 listing every dead link.  Used by the CI docs job.
"""
from __future__ import annotations

import os
import re
import sys

# inline [text](target) — ignores fenced code by the crude-but-effective
# rule that links inside backticks don't match the pattern anyway
_LINK = re.compile(r"!?\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
_SKIP = ("http://", "https://", "mailto:", "#")


def md_files(paths):
    for p in paths:
        if os.path.isdir(p):
            for root, _dirs, files in os.walk(p):
                for f in sorted(files):
                    if f.endswith(".md"):
                        yield os.path.join(root, f)
        else:
            yield p


def dead_links(md_path):
    base = os.path.dirname(os.path.abspath(md_path))
    text = open(md_path, encoding="utf-8").read()
    for m in _LINK.finditer(text):
        target = m.group(1)
        if target.startswith(_SKIP):
            continue
        rel = target.split("#", 1)[0]
        if not rel:
            continue
        if not os.path.exists(os.path.join(base, rel)):
            line = text[: m.start()].count("\n") + 1
            yield line, target


def main(argv):
    if not argv:
        print(__doc__)
        return 2
    bad = 0
    for md in md_files(argv):
        for line, target in dead_links(md):
            print(f"{md}:{line}: dead link -> {target}")
            bad += 1
    if bad:
        print(f"{bad} dead link(s)")
        return 1
    print("all relative links resolve")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
