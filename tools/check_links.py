#!/usr/bin/env python3
"""Dead-reference checker for the repo's markdown docs AND source files.

    python tools/check_links.py README.md docs src

Markdown files (directories are walked for ``*.md``) are scanned for
inline links/images ``[text](target)``: every *relative* target must
resolve to an existing file or directory (fragments are stripped;
``http(s):``/``mailto:`` targets are skipped — this repo's CI is offline).

Python files (directories are walked for ``*.py``) are scanned for
doc-file references — any ``Foo.md`` / ``docs/Foo.md`` token in a
docstring or comment — and each referenced markdown file must exist,
resolved against the repo root (the directory holding ``tools/``) and the
file's own directory.  This is what keeps docstrings from citing design
docs that do not exist (a ``DESIGN.md`` cited by seven docstrings was
never committed).

Exits 1 listing every dead reference.  Used by the CI lint job.
"""
from __future__ import annotations

import os
import re
import sys

# inline [text](target) — ignores fenced code by the crude-but-effective
# rule that links inside backticks don't match the pattern anyway
_LINK = re.compile(r"!?\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
_SKIP = ("http://", "https://", "mailto:", "#")
# a markdown-file token in python source: optional dir prefix + Name.md
_MD_REF = re.compile(r"[\w][\w./-]*\.md\b")

_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def source_files(paths):
    for p in paths:
        if os.path.isdir(p):
            for root, _dirs, files in os.walk(p):
                for f in sorted(files):
                    if f.endswith((".md", ".py")):
                        yield os.path.join(root, f)
        else:
            yield p


def dead_links(md_path):
    base = os.path.dirname(os.path.abspath(md_path))
    text = open(md_path, encoding="utf-8").read()
    for m in _LINK.finditer(text):
        target = m.group(1)
        if target.startswith(_SKIP):
            continue
        rel = target.split("#", 1)[0]
        if not rel:
            continue
        if not os.path.exists(os.path.join(base, rel)):
            line = text[: m.start()].count("\n") + 1
            yield line, target


def dead_doc_refs(py_path):
    """Markdown files referenced by a python file that do not exist —
    resolved against the repo root and the file's own directory."""
    if os.path.abspath(py_path) == os.path.abspath(__file__):
        return  # this docstring's Foo.md examples are illustrative
    base = os.path.dirname(os.path.abspath(py_path))
    text = open(py_path, encoding="utf-8").read()
    for m in _MD_REF.finditer(text):
        ref = m.group(0)
        if os.path.exists(os.path.join(_REPO_ROOT, ref)):
            continue
        if os.path.exists(os.path.join(base, ref)):
            continue
        line = text[: m.start()].count("\n") + 1
        yield line, ref


def main(argv):
    if not argv:
        print(__doc__)
        return 2
    bad = 0
    for path in source_files(argv):
        finder = dead_doc_refs if path.endswith(".py") else dead_links
        for line, target in finder(path):
            print(f"{path}:{line}: dead reference -> {target}")
            bad += 1
    if bad:
        print(f"{bad} dead reference(s)")
        return 1
    print("all doc references resolve")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
