#!/usr/bin/env python3
"""Schema-diff freshly generated experiment output against committed artifacts.

    python tools/schema_diff.py <generated_dir> <committed_results_dir>
    python tools/schema_diff.py --ckpt <checkpoint_dir>
    python tools/schema_diff.py --progcache <progcache_dir>

For every figure CSV in <generated_dir>, the same-named committed CSV must
share the exact header row (the versioned `repro.exp.artifacts.CSV_COLUMNS`
layout); for every per-cell JSON under <generated_dir>/exp/, the committed
counterpart must exist with the same ``schema`` tag, the same top-level
keys and the same ``history`` keys.  Values are NOT compared — CI runs the
smoke sweep with a clamped round budget, so only the *shape* of the
artifacts is comparable.  Exits 1 listing every mismatch.

``--ckpt`` validates a service-loop checkpoint directory instead
(`repro.launch.fed_serve` output): every manifest must carry the current
``repro.exp/ckpt@N`` schema tag and the required keys, reference an npz
payload whose sha256 matches the manifest, and agree with the payload on
the carry leaf count; a serve result JSON in the directory (if present) is
checked for the ``repro.exp/serve@N`` tag and its history keys.

``--progcache`` validates an AOT program-cache directory
(`repro.core.progcache` output): every ``<name>-<key>.json`` manifest must
carry the current ``repro.progcache/entry@N`` schema tag, the required
keys, and reference a ``.bin`` payload whose sha256 matches.  The entry
check itself lives in ``repro.core.progcache.validate_entry`` so the tool
and the runtime's own load-time validation can never disagree.
"""
from __future__ import annotations

import hashlib
import json
import os
import sys
import zipfile

CKPT_SCHEMA = "repro.exp/ckpt@2"
SERVE_SCHEMA = "repro.exp/serve@1"
# host_state (the @2 addition — cohort-streaming host plane) is validated
# when present but deliberately NOT required: stacked serves write
# host_state=[] and pre-@2 tooling may re-check old directories.
_MANIFEST_KEYS = {"schema", "config_digest", "t", "n_carry_leaves",
                  "carry_leaves", "streams", "payload_sha256"}
_SERVE_HISTORY_KEYS = {"gaps", "up_bits", "down_bits", "legs", "events"}


def _fail(msgs):
    for m in msgs:
        print(f"schema-diff: {m}")
    print(f"{len(msgs)} schema mismatch(es)")
    return 1


def _sha256(path):
    h = hashlib.sha256()
    with open(path, "rb") as f:
        for block in iter(lambda: f.read(1 << 20), b""):
            h.update(block)
    return h.hexdigest()


def check_ckpt_dir(ckpt_dir):
    """Validate every checkpoint manifest/payload pair in a directory; a
    serve result record found alongside is validated too."""
    problems = []
    if not os.path.isdir(ckpt_dir):
        return [f"{ckpt_dir}: not a directory"]
    manifests = sorted(f for f in os.listdir(ckpt_dir)
                       if f.startswith("ckpt-") and f.endswith(".json"))
    if not manifests:
        problems.append(f"no checkpoint manifests found in {ckpt_dir}")
    for f in manifests:
        path = os.path.join(ckpt_dir, f)
        try:
            with open(path) as fh:
                m = json.load(fh)
        except (json.JSONDecodeError, OSError) as e:
            problems.append(f"{f}: unreadable manifest ({e})")
            continue
        if m.get("schema") != CKPT_SCHEMA:
            problems.append(f"{f}: schema tag {m.get('schema')!r} != "
                            f"{CKPT_SCHEMA!r}")
        missing = _MANIFEST_KEYS - set(m)
        if missing:
            problems.append(f"{f}: manifest missing keys {sorted(missing)}")
            continue
        npz = path[:-len(".json")] + ".npz"
        if not os.path.exists(npz):
            problems.append(f"{f}: payload {os.path.basename(npz)} missing")
            continue
        if _sha256(npz) != m["payload_sha256"]:
            problems.append(f"{f}: payload sha256 mismatch (torn write?)")
            continue
        try:
            with zipfile.ZipFile(npz) as z:
                names = set(z.namelist())
        except zipfile.BadZipFile:
            problems.append(f"{f}: payload is not a valid npz archive")
            continue
        want = ({f"carry/{i}.npy" for i in range(m["n_carry_leaves"])}
                | {f"stream/{s}.npy" for s in m["streams"]}
                | {f"host/{h}.npy" for h in m.get("host_state", [])}
                | {"root_key.npy"})
        if not want <= names:
            problems.append(
                f"{f}: payload missing entries {sorted(want - names)}")
    n_results = 0
    for f in sorted(os.listdir(ckpt_dir)):
        if f.startswith("ckpt-") or not f.endswith(".json"):
            continue
        try:
            with open(os.path.join(ckpt_dir, f)) as fh:
                rec = json.load(fh)
        except (json.JSONDecodeError, OSError):
            continue
        if rec.get("schema") != SERVE_SCHEMA:
            continue
        n_results += 1
        hk = set(rec.get("history", {}))
        if hk != _SERVE_HISTORY_KEYS:
            problems.append(f"{f}: serve history keys "
                            f"{sorted(hk ^ _SERVE_HISTORY_KEYS)} differ")
    if not problems:
        print(f"ckpt schema ok: {len(manifests)} checkpoint(s), "
              f"{n_results} serve record(s) in {ckpt_dir}")
    return problems


def check_progcache_dir(cache_dir):
    """Validate every AOT cache-entry manifest in a progcache directory via
    the runtime's own `repro.core.progcache.validate_entry`."""
    sys.path.insert(0, os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                    os.pardir, "src"))
    from repro.core.progcache import validate_entry

    if not os.path.isdir(cache_dir):
        return [f"{cache_dir}: not a directory"]
    manifests = sorted(f for f in os.listdir(cache_dir)
                       if f.endswith(".json"))
    if not manifests:
        return [f"no cache-entry manifests found in {cache_dir}"]
    problems = []
    for f in manifests:
        problems.extend(validate_entry(os.path.join(cache_dir, f)))
    if not problems:
        print(f"progcache schema ok: {len(manifests)} entry manifest(s) "
              f"in {cache_dir}")
    return problems


def check_serve_result(path):
    """Validate one serve result record (callable with a file outside the
    checkpoint dir, e.g. a CI-archived result)."""
    try:
        with open(path) as fh:
            rec = json.load(fh)
    except (json.JSONDecodeError, OSError) as e:
        return [f"{path}: unreadable ({e})"]
    problems = []
    if rec.get("schema") != SERVE_SCHEMA:
        problems.append(f"{path}: schema tag {rec.get('schema')!r} != "
                        f"{SERVE_SCHEMA!r}")
    hk = set(rec.get("history", {}))
    if hk != _SERVE_HISTORY_KEYS:
        problems.append(f"{path}: serve history keys "
                        f"{sorted(hk ^ _SERVE_HISTORY_KEYS)} differ")
    return problems


def main(argv):
    if len(argv) == 2 and argv[0] == "--ckpt":
        problems = check_ckpt_dir(argv[1])
        return _fail(problems) if problems else 0
    if len(argv) == 2 and argv[0] == "--progcache":
        problems = check_progcache_dir(argv[1])
        return _fail(problems) if problems else 0
    if len(argv) != 2:
        print(__doc__)
        return 2
    gen, committed = argv
    problems = []
    csvs = sorted(f for f in os.listdir(gen)
                  if f.startswith("fig") and f.endswith(".csv"))
    if not csvs:
        problems.append(f"no generated figure CSVs found in {gen}")
    for f in csvs:
        ref = os.path.join(committed, f)
        if not os.path.exists(ref):
            problems.append(f"{f}: no committed counterpart in {committed}")
            continue
        with open(os.path.join(gen, f)) as fh:
            got = fh.readline().strip()
        with open(ref) as fh:
            want = fh.readline().strip()
        if got != want:
            problems.append(f"{f}: header {got!r} != committed {want!r}")
    gen_exp = os.path.join(gen, "exp")
    n_json = 0
    for root, _dirs, files in os.walk(gen_exp):
        for f in sorted(files):
            if not f.endswith(".json"):
                continue
            n_json += 1
            rel = os.path.relpath(os.path.join(root, f), gen_exp)
            ref = os.path.join(committed, "exp", rel)
            if not os.path.exists(ref):
                problems.append(f"exp/{rel}: no committed counterpart")
                continue
            with open(os.path.join(root, f)) as fh:
                got = json.load(fh)
            with open(ref) as fh:
                want = json.load(fh)
            if got.get("schema") != want.get("schema"):
                problems.append(f"exp/{rel}: schema tag "
                                f"{got.get('schema')!r} != {want.get('schema')!r}")
            if set(got) != set(want):
                problems.append(f"exp/{rel}: top-level keys "
                                f"{sorted(set(got) ^ set(want))} differ")
            hg, hw = got.get("history", {}), want.get("history", {})
            if set(hg) != set(hw):
                problems.append(f"exp/{rel}: history keys "
                                f"{sorted(set(hg) ^ set(hw))} differ")
    if os.path.isdir(gen_exp) and n_json == 0:
        problems.append(f"no generated artifact JSONs found under {gen_exp}")
    if problems:
        return _fail(problems)
    print(f"schema ok: {len(csvs)} CSV(s), {n_json} artifact JSON(s)")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
