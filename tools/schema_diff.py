#!/usr/bin/env python3
"""Schema-diff freshly generated experiment output against committed artifacts.

    python tools/schema_diff.py <generated_dir> <committed_results_dir>

For every figure CSV in <generated_dir>, the same-named committed CSV must
share the exact header row (the versioned `repro.exp.artifacts.CSV_COLUMNS`
layout); for every per-cell JSON under <generated_dir>/exp/, the committed
counterpart must exist with the same ``schema`` tag, the same top-level
keys and the same ``history`` keys.  Values are NOT compared — CI runs the
smoke sweep with a clamped round budget, so only the *shape* of the
artifacts is comparable.  Exits 1 listing every mismatch.
"""
from __future__ import annotations

import json
import os
import sys


def _fail(msgs):
    for m in msgs:
        print(f"schema-diff: {m}")
    print(f"{len(msgs)} schema mismatch(es)")
    return 1


def main(argv):
    if len(argv) != 2:
        print(__doc__)
        return 2
    gen, committed = argv
    problems = []
    csvs = sorted(f for f in os.listdir(gen)
                  if f.startswith("fig") and f.endswith(".csv"))
    if not csvs:
        problems.append(f"no generated figure CSVs found in {gen}")
    for f in csvs:
        ref = os.path.join(committed, f)
        if not os.path.exists(ref):
            problems.append(f"{f}: no committed counterpart in {committed}")
            continue
        with open(os.path.join(gen, f)) as fh:
            got = fh.readline().strip()
        with open(ref) as fh:
            want = fh.readline().strip()
        if got != want:
            problems.append(f"{f}: header {got!r} != committed {want!r}")
    gen_exp = os.path.join(gen, "exp")
    n_json = 0
    for root, _dirs, files in os.walk(gen_exp):
        for f in sorted(files):
            if not f.endswith(".json"):
                continue
            n_json += 1
            rel = os.path.relpath(os.path.join(root, f), gen_exp)
            ref = os.path.join(committed, "exp", rel)
            if not os.path.exists(ref):
                problems.append(f"exp/{rel}: no committed counterpart")
                continue
            with open(os.path.join(root, f)) as fh:
                got = json.load(fh)
            with open(ref) as fh:
                want = json.load(fh)
            if got.get("schema") != want.get("schema"):
                problems.append(f"exp/{rel}: schema tag "
                                f"{got.get('schema')!r} != {want.get('schema')!r}")
            if set(got) != set(want):
                problems.append(f"exp/{rel}: top-level keys "
                                f"{sorted(set(got) ^ set(want))} differ")
            hg, hw = got.get("history", {}), want.get("history", {})
            if set(hg) != set(hw):
                problems.append(f"exp/{rel}: history keys "
                                f"{sorted(set(hg) ^ set(hw))} differ")
    if os.path.isdir(gen_exp) and n_json == 0:
        problems.append(f"no generated artifact JSONs found under {gen_exp}")
    if problems:
        return _fail(problems)
    print(f"schema ok: {len(csvs)} CSV(s), {n_json} artifact JSON(s)")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
