"""Fault-tolerant federated service loop over the unified round engine.

    python -m repro.launch.fed_serve --exp fig4 --cell BL2_tau_half \
        --max-rounds 200 --chunk 25 --ckpt-dir runs/serve

Where `repro.exp` runs a cell as one fixed-length batch scan and exits, this
launcher *serves* it: rounds run in bounded-length chunks against the
chunked scan driver (`repro.core.rounds.run_chunk` — the jitted program is
reused across chunks, control returns to the host every chunk), and between
chunks the orchestrator

  1. **injects faults** — a `repro.core.faults.FaultPlan` (i.i.d. dropout,
     deterministic outage windows, straggler timeouts with retry/backoff)
     materializes the next chunk's availability schedule, which reaches the
     method spec as `RoundCtx.avail`.  When a round's surviving cohort falls
     below its τ target the engine degrades gracefully (force-one-client
     fallback) and the round is flagged in the events stream
     (`History.events`, `rounds.EVENT_*` bitmasks).
  2. **checkpoints** the full server state — scan carry (iterate, shifts,
     `comm.CommLedger`), accumulated history streams, root PRNG key and
     round counter — via `repro.exp.artifacts.save_checkpoint`
     (schema-versioned, atomically written, digest-keyed to this serve
     config).

Start-up is **compile-free on a warm restart**: the serve programs resolve
through the AOT program cache (`repro.core.progcache`, rooted at
``<ckpt_dir>/progcache`` by default, ``--progcache-dir``/``--no-progcache``
to move/disable) *before* checkpoint restore, so a restarted server
deserializes its executables in milliseconds instead of recompiling —
time-to-first-round and cache outcomes land in the record's ``meta``
(``ttfr_s``, ``progcache``).  ``--metrics-out`` additionally streams an
append-only, crash-safe JSONL line per round (round, gap, degradation
events, per-leg ledger bits — `MetricsSink`).

Because per-round PRNG keys are ``fold_in(root_key, t)`` and every fault
draw is a pure function of ``(fault seed, t)``, the trajectory is invariant
to chunk boundaries: kill -9 the process at any point, rerun the same
command, and the run resumes from the latest valid checkpoint **bit-exactly
** — trajectory, `History.events` and per-leg `CommLedger` bit streams all
match an uninterrupted run at the same seed (pinned by tests/test_serve.py
and the CI ``serve-smoke`` job).  ``--crash-after-round N`` arms
`faults.CrashInjector` — a deterministic in-process SIGKILL after round N
is computed but before its covering checkpoint lands (omit the flag on
restart, or it crashes at the same boundary forever).

Supported methods: the GLM specs with client-stacked state (bl1, bl2, bl3,
fednl_bag).  Fault injection additionally requires the method to react to
availability (`MethodSpec.supports_faults`: bl2/bl3 partial participation,
fednl_bag lazy aggregation) — serving bl1 works, but injecting faults into
it is refused rather than silently ignored.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import batched, comm, faults, progcache, rounds
from repro.exp import artifacts
from repro.exp.engine import (
    StreamProblem,
    _comp,
    build_problem,
    build_stream_spec,
)
from repro.exp.registry import get_experiment

#: methods the serve loop can drive (GLM specs; the DNN spec's pytree
#: eval stream needs a different stream accumulator)
SERVE_METHODS = ("bl1", "bl2", "bl3", "fednl_bag")

#: checkpoint stream names: eval iterates, events, one per ledger leg
_STREAMS = ("eval_x", "events") + tuple(
    f"led_{leg}" for leg in comm.CommLedger.LEGS)


def build_setup(exp, cell, prob):
    """(spec, batch, basisb) for a registered cell — the static half of a
    run, shared between the batch engine and the serve loop (the
    `repro.core.batched` ``*_setup`` factorization)."""
    m = cell.method
    if m not in SERVE_METHODS:
        raise SystemExit(
            f"fed_serve drives methods {', '.join(SERVE_METHODS)}; cell "
            f"{cell.name!r} uses {m!r} (run it via `python -m repro.exp`)")
    params = cell.params_dict()
    params.pop("seed", None)        # the serve PRNG root comes from --seed
    n, d = prob.n, prob.d
    clients = prob.clients
    hc = [_comp(cell.hess_comp, d, "hessian")] * n
    if m == "bl1":
        mc = _comp(cell.model_comp, d, "model")
        return batched.bl1_setup(clients, prob.bases(cell.basis), hc, mc,
                                 **params)
    if m == "bl2":
        mc = [_comp(cell.model_comp, d, "model")] * n
        return batched.bl2_setup(clients, prob.bases(cell.basis), hc, mc,
                                 **params)
    if m == "bl3":
        mc = [_comp(cell.model_comp, d, "model")] * n
        return batched.bl3_setup(clients, hc, mc, **params)
    return batched.fednl_bag_setup(clients, prob.bases(cell.basis), hc,
                                   **params)


def serve_config(exp, cell, seed: int, backend: str,
                 plan: faults.FaultPlan) -> dict:
    """The serve run's identity record — digest-keyed checkpoints resume
    only runs with identical identity.  Deliberately excludes the chunk
    length and round budget: chunking does not change the trajectory (the
    fold_in key contract), and raising ``--max-rounds`` on a finished run
    *extends* it from its last checkpoint instead of restarting."""
    return {
        "schema": artifacts.SERVE_SCHEMA,
        "experiment": exp.name,
        "problem": dataclasses.asdict(exp.problem),
        "cell": dataclasses.asdict(cell),
        "seed": seed,
        "backend": backend,
        "faults": plan.describe(),
    }


def _resolve_backend(cell, override: Optional[str]) -> str:
    backend = override or cell.backend
    if backend == "auto":
        backend = "fast"
    if backend not in ("fast", "fast+sharded"):
        raise SystemExit(
            f"fed_serve runs on the engine backends 'fast' or "
            f"'fast+sharded', not {backend!r} (the reference backend has "
            "no checkpointable scan carry)")
    return backend


def _resolve_cohort_backend(cell, override: Optional[str]) -> str:
    backend = override or cell.backend
    if backend == "auto":
        backend = "cohort"
    if backend not in ("cohort", "cohort+sharded"):
        raise SystemExit(
            f"a synthetic_stream cell serves on the 'cohort' or "
            f"'cohort+sharded' backends, not {backend!r} (the stacked "
            "backends would materialize the whole fleet on device)")
    return backend


def _empty_streams(d: int) -> dict:
    z64 = lambda: np.zeros((0,), np.float64)
    return {"eval_x": np.zeros((0, d), np.float64),
            "events": np.zeros((0,), np.int32),
            **{f"led_{leg}": z64() for leg in comm.CommLedger.LEGS}}


def _append_chunk(streams: dict, ys) -> dict:
    xs, leds, evs = ys
    cat = lambda name, arr: np.concatenate(
        [streams[name], np.asarray(arr)], axis=0)
    out = {"eval_x": cat("eval_x", xs), "events": cat("events", evs)}
    for leg in comm.CommLedger.LEGS:
        out[f"led_{leg}"] = cat(f"led_{leg}", getattr(leds, leg))
    return out


def _restore_carry(ck: dict, template) -> object:
    """Checkpoint leaves → carry pytree, validated leaf-by-leaf against a
    fresh `init_serve_carry` shape evaluation (the serialization contract:
    a spec whose carry changed shape fails loudly, not bit-rottingly)."""
    leaves0, treedef = jax.tree_util.tree_flatten(template)
    got = ck["carry_leaves"]
    if len(got) != len(leaves0):
        raise SystemExit(
            f"checkpoint carry has {len(got)} leaves, this spec expects "
            f"{len(leaves0)} — the method's carry structure changed; "
            "delete the checkpoint directory to restart from round 0")
    for i, (g, w) in enumerate(zip(got, leaves0)):
        if tuple(g.shape) != tuple(w.shape) or g.dtype != np.asarray(w).dtype:
            raise SystemExit(
                f"checkpoint carry leaf {i} is {g.dtype}{tuple(g.shape)}, "
                f"spec expects {np.asarray(w).dtype}{tuple(np.asarray(w).shape)}"
                " — incompatible checkpoint; delete the checkpoint "
                "directory to restart from round 0")
    return jax.tree_util.tree_unflatten(
        treedef, [jnp.asarray(g) for g in got])


class MetricsSink:
    """Append-only, crash-safe JSONL metrics stream for a serve run.

    One line per round: ``{"round", "gap", "events", "legs": {leg: bits}}``
    (cumulative per-leg `comm.CommLedger` bits, like the history record).
    Crash safety mirrors the checkpoint walk: on open, the existing file is
    scanned up to its last PARSEABLE line and emission resumes strictly
    after that round — a torn tail line from a killed process is simply
    overwritten territory (a lone "\\n" terminates it first), and re-served
    chunks after a resume never duplicate rounds.  Each chunk's lines are
    flushed and fsynced together, so the stream trails the trajectory by at
    most one chunk."""

    def __init__(self, path: str):
        self.path = path
        self.last_round = -1
        self._needs_newline = False
        if os.path.exists(path):
            with open(path, "rb") as f:
                raw = f.read()
            for line in raw.splitlines():
                try:
                    rec = json.loads(line)
                    self.last_round = max(self.last_round, int(rec["round"]))
                except (ValueError, KeyError, TypeError):
                    break               # torn tail — ignore it and beyond
            self._needs_newline = bool(raw) and not raw.endswith(b"\n")
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)

    def emit_chunk(self, ts, gaps, events, legs: dict) -> None:
        """Append rounds ``ts`` (parallel arrays); rounds at or below the
        resume point are skipped."""
        lines = []
        for i, t in enumerate(ts):
            t = int(t)
            if t <= self.last_round:
                continue
            lines.append(json.dumps({
                "round": t,
                "gap": float(gaps[i]),
                "events": int(events[i]),
                "legs": {leg: float(legs[leg][i]) for leg in legs},
            }))
            self.last_round = t
        if not lines:
            return
        with open(self.path, "a") as f:
            if self._needs_newline:
                f.write("\n")
                self._needs_newline = False
            f.write("\n".join(lines) + "\n")
            f.flush()
            os.fsync(f.fileno())

    def as_stream_hook(self, every: int, batch, f_star) -> rounds.StreamHook:
        """Adapter for the batch driver: a `rounds.StreamHook` whose
        emissions land in this sink (gap computed from the emitted
        iterate; chunk-boundary rounds only)."""
        def cb(t, eval_x, ledger):
            gap = rounds.default_gap_stream(
                batch, jnp.asarray(eval_x)[None, :], f_star)[0]
            self.emit_chunk([t], [float(gap)], [0],
                            {leg: [float(getattr(ledger, leg))]
                             for leg in comm.CommLedger.LEGS})
        return rounds.StreamHook(every=every, callback=cb)


def _activate_progcache(ckpt_dir: str, progcache_dir: Optional[str],
                        no_progcache: bool, log):
    """Serve-loop cache policy: ON by default, rooted next to the
    checkpoints (``<ckpt_dir>/progcache``) so a warm restart finds both."""
    if no_progcache:
        progcache.deactivate()
        return None
    cache = progcache.activate(progcache_dir
                               or os.path.join(ckpt_dir, "progcache"))
    log(f"[serve] program cache at {cache.root}")
    return cache


def _serve_cohort(exp, cell, prob: StreamProblem, *, seed: int, chunk: int,
                  max_rounds: int, ckpt_dir: str, backend: Optional[str],
                  keep: int, plan: Optional[faults.FaultPlan],
                  crash_after_round: Optional[int],
                  result_path: Optional[str],
                  progcache_dir: Optional[str] = None,
                  no_progcache: bool = False,
                  metrics_out: Optional[str] = None, log=print) -> dict:
    """The serve loop over the cohort-streaming engine: same chunked
    checkpoint/resume/crash contract as the stacked path, with the engine's
    host plane (client store, fleet totals, frozen epoch stats) riding in
    the ckpt@2 ``host_state`` payload.  The trajectory stays chunk-boundary
    invariant — per-round keys are ``fold_in(root_key, t)`` and the cohort
    schedule is a pure function of the absolute epoch index — so kill -9 +
    rerun is bit-exact here too (tests/test_cohort.py)."""
    from repro.core import cohort

    plan = plan if plan is not None else faults.FaultPlan(n=prob.n)
    if not plan.trivial:
        raise SystemExit(
            "cohort streaming does not take an injected fault schedule: "
            "client absence is the engine's own per-round participation "
            "draw over the global fleet (Alg. 2-3 partial participation); "
            "drop the fault flags or serve a stacked cell")
    backend = _resolve_cohort_backend(cell, backend)
    crash = (faults.CrashInjector(crash_after_round)
             if crash_after_round is not None else None)
    params = cell.params_dict()
    params.pop("seed", None)        # the serve PRNG root comes from --seed
    spec, basis, csize, rpc, _ = build_stream_spec(
        cell, prob.d, prob.n, prob.store.lam, params)
    config = serve_config(exp, cell, seed, backend, plan)
    digest = artifacts.config_digest(config)
    root_key = jax.random.PRNGKey(seed)
    cache = _activate_progcache(ckpt_dir, progcache_dir, no_progcache, log)
    t0_wall = time.perf_counter()      # time-to-first-round starts here
    eng = cohort.CohortEngine(
        spec, prob.store, prob.x0, cohort=csize, rounds_per_cohort=rpc,
        root_key=root_key, basis=basis,
        sharded=backend == "cohort+sharded")
    template = eng.carry_template()
    # resolve the chunk program BEFORE checkpoint restore: on a warm
    # restart the executable deserializes in milliseconds and the first
    # round starts compile-free
    eng.warm_programs(min(chunk, max_rounds))
    ck = artifacts.load_checkpoint(ckpt_dir, config_digest=digest)
    resumed_from = None
    if ck is not None:
        t = int(ck["t"])
        carry = _restore_carry(ck, template)
        eng.restore(t, carry, ck.get("host_state"))
        streams = {name: np.asarray(ck["streams"][name])
                   for name in _STREAMS}
        resumed_from = t
        log(f"[serve] {exp.name}/{cell.name}: resumed from checkpoint at "
            f"round {t} (config {digest})")
    else:
        t = 0
        streams = _empty_streams(prob.d)
        log(f"[serve] {exp.name}/{cell.name}: fresh run (config {digest}, "
            f"cohort {eng.cohort}/{eng.n})")

    sink = MetricsSink(metrics_out) if metrics_out else None
    f_star = cohort.store_loss(prob.store, prob.x_star) if sink else None
    chunks_run = 0
    ttfr_s = None
    try:
        while t < max_rounds:
            steps = min(chunk, max_rounds - t)
            ys = eng.run_chunk(t, steps)
            streams = _append_chunk(streams, ys)
            t += steps
            chunks_run += 1
            if ttfr_s is None:
                ttfr_s = time.perf_counter() - t0_wall
            log(f"[serve] rounds {t - steps}..{t - 1} done "
                f"(epoch {(t - 1) // rpc})")
            if sink is not None:
                xs_new = np.asarray(streams["eval_x"][-steps:])
                sink.emit_chunk(
                    range(t - steps, t),
                    [cohort.store_loss(prob.store, x) - f_star
                     for x in xs_new],
                    streams["events"][-steps:],
                    {leg: streams[f"led_{leg}"][-steps:]
                     for leg in comm.CommLedger.LEGS})
            if crash is not None:
                crash.maybe_crash(t - 1)
            leaves, host_state = eng.checkpoint_payload()
            artifacts.save_checkpoint(
                ckpt_dir, t=t, carry_leaves=leaves, streams=streams,
                root_key=np.asarray(root_key), config_digest=digest,
                keep=keep, host_state=host_state)
    finally:
        eng.close()

    # fleet gaps evaluate slab-wise on the host (the device never holds
    # more than the cohort)
    xs = np.asarray(streams["eval_x"])
    f_star = (cohort.store_loss(prob.store, prob.x_star)
              if f_star is None else f_star)
    evals = {"gap": np.array([cohort.store_loss(prob.store, xs[i]) - f_star
                              for i in range(xs.shape[0])])}
    led_streams = comm.CommLedger(
        *(jnp.asarray(streams[f"led_{leg}"])
          for leg in comm.CommLedger.LEGS))
    hist = batched._history(evals, led_streams)
    hist.events = [int(e) for e in streams["events"]]
    record = {
        "schema": artifacts.SERVE_SCHEMA,
        "experiment": exp.name,
        "cell": cell.name,
        "seed": seed,
        "config_digest": digest,
        "config": config,
        "rounds": t,
        "history": {
            "gaps": [float(g) for g in hist.gaps],
            "up_bits": [float(b) for b in hist.up_bits],
            "down_bits": [float(b) for b in hist.down_bits],
            "legs": {leg: [float(v) for v in hist.legs[leg]]
                     for leg in comm.CommLedger.LEGS},
            "events": hist.events,
        },
        "degraded_rounds": int(np.count_nonzero(streams["events"])),
        "meta": {
            "backend": backend,
            "chunk": chunk,
            "chunks_run": chunks_run,
            "resumed_from": resumed_from,
            "straggler_wait_s": 0.0,
            "runtime_s": time.perf_counter() - t0_wall,
            "ttfr_s": ttfr_s,
            "progcache": cache.summary() if cache is not None else None,
            "cohort": eng.cohort,
            "rounds_per_cohort": rpc,
            "n_clients": eng.n,
            "prefetch_overlap": eng.prefetch_overlap,
            "prefetch": dict(eng.metrics),
        },
    }
    if result_path:
        artifacts.write_json(result_path, record)
        log(f"[serve] result → {result_path}")
    log(f"[serve] {t} rounds, final gap {record['history']['gaps'][-1]:.3e}, "
        f"prefetch overlap {eng.prefetch_overlap:.0%}")
    return record


def serve(*, exp_name: str, cell_name: str, seed: int = 0, chunk: int = 25,
          max_rounds: int = 200, ckpt_dir: str, backend: Optional[str] = None,
          keep: int = 3, plan: Optional[faults.FaultPlan] = None,
          crash_after_round: Optional[int] = None,
          result_path: Optional[str] = None,
          progcache_dir: Optional[str] = None, no_progcache: bool = False,
          metrics_out: Optional[str] = None, log=print) -> dict:
    """Run (or resume) a serve loop to ``max_rounds``; returns the final
    serve record (also written to ``result_path`` when given).

    ``progcache_dir`` roots the AOT program cache (default
    ``<ckpt_dir>/progcache``; ``no_progcache=True`` disables both cache
    tiers); ``metrics_out`` appends a crash-safe JSONL metrics line per
    round (`MetricsSink`)."""
    if chunk < 1:
        raise SystemExit(f"--chunk must be >= 1, got {chunk}")
    exp = get_experiment(exp_name)
    cell = exp.cell(cell_name)
    prob = build_problem(exp.problem)
    if isinstance(prob, StreamProblem):
        return _serve_cohort(
            exp, cell, prob, seed=seed, chunk=chunk, max_rounds=max_rounds,
            ckpt_dir=ckpt_dir, backend=backend, keep=keep, plan=plan,
            crash_after_round=crash_after_round, result_path=result_path,
            progcache_dir=progcache_dir, no_progcache=no_progcache,
            metrics_out=metrics_out, log=log)
    spec, batch, basisb = build_setup(exp, cell, prob)
    plan = plan if plan is not None else faults.FaultPlan(n=batch.n)
    if plan.n != batch.n:
        raise SystemExit(
            f"fault plan is for n={plan.n} clients, fleet has {batch.n}")
    if not plan.trivial and not getattr(spec, "supports_faults", False):
        raise SystemExit(
            f"method {cell.method!r} models a fully synchronous fleet and "
            "cannot absorb injected faults (MethodSpec.supports_faults is "
            "False) — drop the fault flags or serve a partial-participation "
            "cell (bl2/bl3) or fednl_bag")
    backend = _resolve_backend(cell, backend)
    sharded = backend == "fast+sharded"
    crash = (faults.CrashInjector(crash_after_round)
             if crash_after_round is not None else None)
    x0, x_star = prob.x0, prob.x_star

    config = serve_config(exp, cell, seed, backend, plan)
    digest = artifacts.config_digest(config)
    cache = _activate_progcache(ckpt_dir, progcache_dir, no_progcache, log)
    t0_wall = time.perf_counter()      # time-to-first-round starts here
    template = rounds.init_serve_carry(spec, batch, basisb, x0,
                                       sharded=sharded)
    # resolve the chunk program BEFORE checkpoint restore: on a warm
    # restart the executable deserializes in milliseconds and the first
    # round starts compile-free
    rounds.warm_chunk_program(spec, batch, basisb, x0, template,
                              min(chunk, max_rounds),
                              jax.random.PRNGKey(seed), sharded=sharded)
    ck = artifacts.load_checkpoint(ckpt_dir, config_digest=digest)
    resumed_from = None
    if ck is not None:
        t = int(ck["t"])
        carry = _restore_carry(ck, template)
        streams = {name: np.asarray(ck["streams"][name]) for name in _STREAMS}
        root_key = jnp.asarray(ck["root_key"])
        resumed_from = t
        log(f"[serve] {exp.name}/{cell.name}: resumed from checkpoint at "
            f"round {t} (config {digest})")
    else:
        t = 0
        carry = template
        streams = _empty_streams(prob.d)
        root_key = jax.random.PRNGKey(seed)
        log(f"[serve] {exp.name}/{cell.name}: fresh run (config {digest})")

    sink = MetricsSink(metrics_out) if metrics_out else None
    f_star = batched._f_star(batch, x_star) if sink else None
    chunks_run = 0
    waited_total = 0.0
    ttfr_s = None
    while t < max_rounds:
        steps = min(chunk, max_rounds - t)
        if plan.trivial:
            avail, waited = None, 0.0
        else:
            avail, waited = plan.schedule(t, steps)
        # run_chunk DONATES the carry (its buffers back the next chunk's
        # output) — reassign, and only ever checkpoint the returned carry
        carry, ys = rounds.run_chunk(spec, batch, basisb, x0, carry, t,
                                     steps, root_key, avail=avail,
                                     sharded=sharded)
        streams = _append_chunk(streams, ys)
        t += steps
        chunks_run += 1
        waited_total += waited
        if ttfr_s is None:
            ttfr_s = time.perf_counter() - t0_wall
        if sink is not None:
            gaps = spec.eval_streams(
                batch, jnp.asarray(streams["eval_x"][-steps:]),
                f_star)["gap"]
            sink.emit_chunk(
                range(t - steps, t), np.asarray(gaps),
                streams["events"][-steps:],
                {leg: streams[f"led_{leg}"][-steps:]
                 for leg in comm.CommLedger.LEGS})
        evs = streams["events"][-steps:]
        n_deg = int(np.count_nonzero(evs))
        log(f"[serve] rounds {t - steps}..{t - 1} done"
            + (f", {n_deg} degraded" if n_deg else "")
            + (f", straggler wait {waited:.2f}s" if waited else ""))
        if crash is not None:
            # fires BEFORE the covering checkpoint: the chunk is lost and
            # the resume path must recompute it (the acceptance scenario)
            crash.maybe_crash(t - 1)
        artifacts.save_checkpoint(
            ckpt_dir, t=t,
            carry_leaves=[np.asarray(leaf)
                          for leaf in jax.tree_util.tree_leaves(carry)],
            streams=streams, root_key=np.asarray(root_key),
            config_digest=digest, keep=keep)

    evals = spec.eval_streams(batch, jnp.asarray(streams["eval_x"]),
                              batched._f_star(batch, x_star))
    led_streams = comm.CommLedger(
        *(jnp.asarray(streams[f"led_{leg}"])
          for leg in comm.CommLedger.LEGS))
    hist = batched._history(evals, led_streams)
    hist.events = [int(e) for e in streams["events"]]
    record = {
        "schema": artifacts.SERVE_SCHEMA,
        "experiment": exp.name,
        "cell": cell.name,
        "seed": seed,
        "config_digest": digest,
        "config": config,
        "rounds": t,
        "history": {
            "gaps": [float(g) for g in hist.gaps],
            "up_bits": [float(b) for b in hist.up_bits],
            "down_bits": [float(b) for b in hist.down_bits],
            "legs": {leg: [float(v) for v in hist.legs[leg]]
                     for leg in comm.CommLedger.LEGS},
            "events": hist.events,
        },
        "degraded_rounds": int(np.count_nonzero(streams["events"])),
        # operational facts, outside the bit-exactness contract (the CI
        # smoke job compares records with "meta" stripped)
        "meta": {
            "backend": backend,
            "chunk": chunk,
            "chunks_run": chunks_run,
            "resumed_from": resumed_from,
            "straggler_wait_s": waited_total,
            "runtime_s": time.perf_counter() - t0_wall,
            "ttfr_s": ttfr_s,
            "progcache": cache.summary() if cache is not None else None,
        },
    }
    if result_path:
        artifacts.write_json(result_path, record)
        log(f"[serve] result → {result_path}")
    log(f"[serve] {t} rounds, final gap {record['history']['gaps'][-1]:.3e}, "
        f"{record['degraded_rounds']} degraded round(s)")
    return record


def _build_plan(args, n: int) -> faults.FaultPlan:
    straggler = None
    if args.straggler_mean > 0.0:
        straggler = faults.StragglerModel(
            mean_s=args.straggler_mean, slow_frac=args.slow_frac,
            slow_factor=args.slow_factor, timeout_s=args.timeout,
            retries=args.retries, backoff=args.backoff)
    return faults.FaultPlan(
        n=n, dropout_p=args.dropout_p,
        outages=tuple(faults.Outage.parse(o) for o in args.outage),
        straggler=straggler, seed=args.fault_seed)


def main(argv=None):
    ap = argparse.ArgumentParser(
        prog="python -m repro.launch.fed_serve",
        description=__doc__.split("\n\n")[0])
    ap.add_argument("--exp", required=True,
                    help="registered experiment (e.g. fig4)")
    ap.add_argument("--cell", required=True,
                    help="cell within the experiment (e.g. BL2_tau_half)")
    ap.add_argument("--seed", type=int, default=0,
                    help="root PRNG seed (per-round keys fold in the round)")
    ap.add_argument("--chunk", type=int, default=25,
                    help="rounds per scan chunk / checkpoint interval")
    ap.add_argument("--max-rounds", type=int, default=200,
                    help="serve until this many total rounds")
    ap.add_argument("--ckpt-dir", default="runs/serve",
                    help="checkpoint directory (resume looks here)")
    ap.add_argument("--backend",
                    choices=("fast", "fast+sharded", "cohort",
                             "cohort+sharded"),
                    default=None, help="override the cell's engine backend "
                    "(cohort* for synthetic_stream cells)")
    ap.add_argument("--keep", type=int, default=3,
                    help="checkpoints retained after pruning")
    ap.add_argument("--result", default=None,
                    help="write the final serve record JSON here")
    ap.add_argument("--progcache-dir", default=None,
                    help="AOT program cache directory (default: "
                         "<ckpt-dir>/progcache)")
    ap.add_argument("--no-progcache", action="store_true",
                    help="disable the program cache (always live-compile)")
    ap.add_argument("--metrics-out", default=None,
                    help="append per-round JSONL metrics (round, gap, "
                         "events, per-leg ledger bits) to this file")
    # fault injection
    ap.add_argument("--dropout-p", type=float, default=0.0,
                    help="i.i.d. per-(client, round) dropout probability")
    ap.add_argument("--outage", action="append", default=[],
                    metavar="CLIENT:START:STOP",
                    help="deterministic outage window (repeatable)")
    ap.add_argument("--fault-seed", type=int, default=0,
                    help="fault stream seed (independent of --seed)")
    ap.add_argument("--straggler-mean", type=float, default=0.0,
                    help="mean client response delay in s (0 = no "
                         "straggler model)")
    ap.add_argument("--timeout", type=float, default=0.25,
                    help="per-round response deadline in s")
    ap.add_argument("--retries", type=int, default=1,
                    help="extra attempts for timed-out clients")
    ap.add_argument("--backoff", type=float, default=2.0,
                    help="deadline multiplier per retry")
    ap.add_argument("--slow-frac", type=float, default=0.0,
                    help="fraction of persistently slow clients")
    ap.add_argument("--slow-factor", type=float, default=10.0,
                    help="delay multiplier for slow clients")
    # crash harness
    ap.add_argument("--crash-after-round", type=int, default=None,
                    help="SIGKILL self after this round is computed but "
                         "before its checkpoint (crash test harness; omit "
                         "on restart)")
    args = ap.parse_args(argv)

    exp = get_experiment(args.exp)
    prob = build_problem(exp.problem)
    serve(exp_name=args.exp, cell_name=args.cell, seed=args.seed,
          chunk=args.chunk, max_rounds=args.max_rounds,
          ckpt_dir=args.ckpt_dir, backend=args.backend, keep=args.keep,
          plan=_build_plan(args, prob.n),
          crash_after_round=args.crash_after_round,
          result_path=args.result, progcache_dir=args.progcache_dir,
          no_progcache=args.no_progcache, metrics_out=args.metrics_out)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
