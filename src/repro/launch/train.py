"""Production training launcher.

On the real cluster:
  python -m repro.launch.train --arch gemma3-4b --shape train_4k \
      [--multi-pod] [--steps N] [--fed]

builds the production mesh, shards params/optimizer with the rules in
repro.sharding, and runs the jitted train_step over the synthetic pipeline
(swap data.make_batch_iterator for the real corpus reader in deployment).

On this CPU container the same entry point runs with --debug (1-device mesh,
reduced config) — the code path is identical, only mesh/config size differ.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config
from repro.data import make_batch_iterator
from repro.launch import shapes as SH
from repro.launch.mesh import make_debug_mesh, make_production_mesh
from repro.models import model as M
from repro.models.steps import make_train_step
from repro.optim import adamw_init
from repro.sharding.rules import make_rules, param_specs, wants_seq_parallel


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-4b")
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--debug", action="store_true",
                    help="1-device mesh + reduced config (CPU container)")
    ap.add_argument("--steps", type=int, default=10)
    ap.add_argument("--lr", type=float, default=3e-4)
    args = ap.parse_args(argv)

    if args.debug:
        cfg = get_config(args.arch).reduced()
        mesh = make_debug_mesh(1, 1)
        B, S = 4, 64
    else:
        cfg = get_config(args.arch)
        mesh = make_production_mesh(multi_pod=args.multi_pod)
        shp = SH.SHAPES[args.shape]
        B, S = shp.global_batch, shp.seq_len

    rules = make_rules(mesh, batch_size=B, seq_parallel=wants_seq_parallel(cfg, mesh))
    with mesh:
        params = M.init_params(jax.random.PRNGKey(0), cfg,
                               jnp.float32 if args.debug else jnp.bfloat16)
        pspecs = param_specs(params, cfg, rules)
        params = jax.tree.map(
            lambda x, s: jax.device_put(x, s) if not args.debug else x,
            params, pspecs)
        opt = adamw_init(params, jnp.float32 if args.debug else jnp.bfloat16)
        step = jax.jit(make_train_step(cfg, rules if not args.debug else None,
                                       lr=args.lr, remat=not args.debug),
                       donate_argnums=(0, 1))
        extras = {}
        if cfg.n_enc_layers:
            extras["frames"] = (B, cfg.enc_seq, cfg.d_model)
        if cfg.n_prefix_embeds:
            extras["prefix_embeds"] = (B, cfg.n_prefix_embeds, cfg.d_model)
        it = make_batch_iterator(cfg.vocab_size, S + 1, B, seed=0, extras=extras,
                                 dtype=jnp.float32 if args.debug else jnp.bfloat16)
        t0 = time.time()
        for i in range(args.steps):
            batch = next(it)
            if not args.debug:
                bspec = NamedSharding(mesh, P(rules.amap["batch"], None))
                batch = {k: jax.device_put(v, bspec if v.ndim == 2 else
                                           NamedSharding(mesh, P(rules.amap["batch"], None, None)))
                         for k, v in batch.items()}
            params, opt, m = step(params, opt, batch)
            print(f"step {i:4d} loss {float(m['loss']):.4f} "
                  f"({time.time()-t0:.1f}s)", flush=True)
    print("done")


if __name__ == "__main__":
    main()
