"""Production serving launcher: prefill + batched decode on the mesh.

  python -m repro.launch.serve --arch gemma3-4b --shape decode_32k [--multi-pod]
  python -m repro.launch.serve --arch gemma3_4b --debug     # CPU container
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.launch import shapes as SH
from repro.launch.mesh import make_debug_mesh, make_production_mesh
from repro.models import model as M
from repro.models.steps import make_prefill_step, make_serve_step, stub_inputs
from repro.sharding.rules import make_rules, wants_seq_parallel


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-4b")
    ap.add_argument("--shape", default="decode_32k")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--debug", action="store_true")
    ap.add_argument("--gen", type=int, default=8)
    args = ap.parse_args(argv)

    if args.debug:
        cfg = get_config(args.arch).reduced()
        mesh = make_debug_mesh(1, 1)
        B, prompt, max_seq = 4, 32, 96
    else:
        cfg = get_config(args.arch)
        mesh = make_production_mesh(multi_pod=args.multi_pod)
        shp = SH.SHAPES[args.shape]
        B, prompt, max_seq = shp.global_batch, shp.seq_len // 2, shp.seq_len

    rules = None if args.debug else make_rules(mesh, batch_size=B, seq_parallel=wants_seq_parallel(cfg, mesh))
    dtype = jnp.float32 if args.debug else jnp.bfloat16
    rng = np.random.default_rng(0)
    with mesh:
        params = M.init_params(jax.random.PRNGKey(0), cfg, dtype)
        cache = M.init_cache(cfg, B, max_seq, dtype)
        prompts = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, prompt)), jnp.int32)
        extras = stub_inputs(cfg, B, dtype)
        prefill = jax.jit(make_prefill_step(cfg, rules), donate_argnums=(2,))
        serve = jax.jit(make_serve_step(cfg, rules), donate_argnums=(2,))
        t0 = time.time()
        logits, cache = prefill(params, {"tokens": prompts, **extras}, cache)
        tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)
        print(f"prefill {B}×{prompt}: {time.time()-t0:.2f}s", flush=True)
        svex = {k: v for k, v in extras.items() if k == "frames"}
        t0 = time.time()
        for t in range(args.gen):
            tok, cache = serve(params, {"tokens": tok[:, None], **svex}, cache,
                               jnp.asarray(prompt + t, jnp.int32))
        dt = time.time() - t0
        print(f"decoded {args.gen} steps × {B}: {dt:.2f}s "
              f"({args.gen*B/max(dt,1e-9):.1f} tok/s)")
    print("done")


if __name__ == "__main__":
    main()
