"""The four assigned input shapes + ShapeDtypeStruct input_specs builders.

input_specs(cfg, shape_name, rules) returns (step_kind, kwargs) where kwargs
are ShapeDtypeStructs (weak-type-correct, sharded, zero allocation) matching
the step function's signature for that shape.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from ..models import model as M
from ..models.config import ModelConfig
from ..sharding.rules import Rules, cache_specs


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


def shape_applicable(cfg: ModelConfig, shape: InputShape) -> Tuple[bool, str]:
    """long_500k only for sub-quadratic archs (skip rationale in each
    config's docstring)."""
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False, "pure full-attention arch: no sub-quadratic variant in source config"
    return True, ""


def _sds(shape, dtype, rules: Rules, spec: P):
    return jax.ShapeDtypeStruct(shape, dtype,
                                sharding=NamedSharding(rules.mesh, spec))


def batch_struct(cfg: ModelConfig, shape: InputShape, rules: Rules,
                 act_dtype=jnp.bfloat16) -> Dict[str, Any]:
    B = shape.global_batch
    bspec = P(rules.amap["batch"], None)
    if shape.kind == "train":
        toks = _sds((B, shape.seq_len + 1), jnp.int32, rules, bspec)
    elif shape.kind == "prefill":
        toks = _sds((B, shape.seq_len), jnp.int32, rules, bspec)
    else:
        toks = _sds((B, 1), jnp.int32, rules, bspec)
    batch: Dict[str, Any] = {"tokens": toks}
    if cfg.n_enc_layers:
        batch["frames"] = _sds((B, cfg.enc_seq, cfg.d_model), act_dtype, rules,
                               P(rules.amap["batch"], None, None))
    if cfg.n_prefix_embeds and shape.kind != "decode":
        batch["prefix_embeds"] = _sds((B, cfg.n_prefix_embeds, cfg.d_model),
                                      act_dtype, rules,
                                      P(rules.amap["batch"], None, None))
    return batch


def cache_struct(cfg: ModelConfig, shape: InputShape, rules: Rules,
                 dtype=jnp.bfloat16):
    # prefill caches must also hold the stubbed VLM prefix embeddings
    max_seq = shape.seq_len
    if shape.kind == "prefill" and cfg.n_prefix_embeds:
        max_seq += cfg.n_prefix_embeds
    shapes = M.cache_shapes(cfg, shape.global_batch, max_seq, dtype)
    specs = cache_specs(shapes, cfg, rules)
    return jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        shapes, specs,
    )


def pos_struct(rules: Rules):
    return jax.ShapeDtypeStruct((), jnp.int32,
                                sharding=NamedSharding(rules.mesh, P()))
