import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run (deliverable e).

For every (architecture × input shape) on the production mesh:
  jit(step).lower(*ShapeDtypeStructs).compile()
then record memory_analysis(), cost_analysis() and the collective byte totals
parsed from the optimized HLO — the raw material for the perf and roofline
notes in README.md §EXPERIMENTS.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch gemma3-4b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--out out.json]
"""
import argparse
import json
import re
import sys
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCH_IDS, get_config
from repro.core import progcache
from repro.launch.mesh import make_production_mesh
from repro.launch import shapes as SH
from repro.models import model as M
from repro.models.steps import make_prefill_step, make_serve_step, make_train_step
from repro.optim import adamw_init
from repro.sharding.rules import make_rules, param_specs, wants_seq_parallel

# ---------------------------------------------------------------------------
# HLO collective parsing
# ---------------------------------------------------------------------------
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
    "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
    "f8e4m3fn": 1, "f8e5m2": 1,
}
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _tensor_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, Any]:
    """Sum output-operand sizes of every collective op in the optimized HLO.

    Counts the bytes that cross the interconnect once per op instance (the
    scan body appears once in HLO; XLA while-loops execute it n_groups times —
    we scale by the enclosing loop trip count when detectable)."""
    per_kind: Dict[str, float] = {k: 0.0 for k in _COLLECTIVES}
    counts: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    # map computation name -> body of while loops with trip counts
    trip_re = re.compile(r"trip_count=(\d+)")
    lines = hlo_text.splitlines()
    current_comp = ""
    comp_re = re.compile(r"^\s*%?([\w\.\-]+)\s*\(.*\)\s*->")
    # detect scan loop bodies: body computations referenced by while ops
    body_trips: Dict[str, int] = {}
    for ln in lines:
        if "while(" in ln and "body=" in ln:
            m = re.search(r"body=%?([\w\.\-]+)", ln)
            t = trip_re.search(ln)
            if m:
                body_trips[m.group(1)] = int(t.group(1)) if t else 1
    for ln in lines:
        mc = comp_re.match(ln)
        if mc and ("{" in ln or ln.rstrip().endswith("{")):
            current_comp = mc.group(1)
        for kind in _COLLECTIVES:
            if f" {kind}(" in ln or f"= {kind}(" in ln or kind + "-start" in ln:
                # output shape is the first shape on the line (lhs type)
                shape_part = ln.split("=")[0] + "=" + ln.split("=", 1)[1]
                b = _tensor_bytes(ln.split("=")[1].split(kind)[0]) or _tensor_bytes(ln)
                mult = body_trips.get(current_comp, 1)
                per_kind[kind] += b * mult
                counts[kind] += mult
                break
    per_kind_total = {k: v for k, v in per_kind.items()}
    return {
        "bytes_by_kind": per_kind_total,
        "counts": counts,
        "total_bytes": float(sum(per_kind_total.values())),
    }


# ---------------------------------------------------------------------------
# Lowering one (arch, shape, mesh)
# ---------------------------------------------------------------------------
def _compile_via_progcache(lowered, *key_bits):
    """``lowered.compile()`` routed through the active program cache
    (`repro.core.progcache`) when one is on: repeat dry-runs of the same
    (arch, shape, mesh, config) deserialize instead of recompiling — the
    analyses below (`memory_analysis`, `cost_analysis`, `as_text`) all work
    on deserialized executables.  With no cache active this IS
    ``lowered.compile()``.  Returns ``(compiled, status)``; status is None
    when uncached, else the cache outcome ("hit"/"miss")."""
    cache = progcache.active()
    if cache is None:
        return lowered.compile(), None
    return cache.load_or_compile(
        name="dryrun",
        key_parts=("dryrun",) + tuple(str(b) for b in key_bits),
        lower=lambda: lowered)


def lower_case(
    arch: str,
    shape_name: str,
    multi_pod: bool = False,
    compile_: bool = True,
    adam_dtype=jnp.bfloat16,
) -> Dict[str, Any]:
    cfg = get_config(arch)
    shape = SH.SHAPES[shape_name]
    ok, why = SH.shape_applicable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "status": "skipped", "reason": why}

    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = make_rules(mesh, batch_size=shape.global_batch,
                       seq_parallel=wants_seq_parallel(cfg, mesh))
    t0 = time.time()

    pshapes = M.param_shapes(cfg, jnp.bfloat16)
    pspecs = param_specs(pshapes, cfg, rules)
    p_structs = jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        pshapes, pspecs)
    batch = SH.batch_struct(cfg, shape, rules)
    out: Dict[str, Any] = {"arch": arch, "shape": shape_name,
                           "mesh": "2x16x16" if multi_pod else "16x16"}

    with mesh:
        if shape.kind == "train":
            opt_shapes = jax.eval_shape(lambda p: adamw_init(p, adam_dtype), pshapes)
            opt_structs = jax.tree.map(
                lambda s, leafspec: jax.ShapeDtypeStruct(
                    s.shape, s.dtype, sharding=leafspec),
                opt_shapes,
                {"m": pspecs, "v": pspecs,
                 "step": NamedSharding(mesh, P())},
            )
            step = make_train_step(cfg, rules)
            # shardings are carried by the ShapeDtypeStructs themselves
            jitted = jax.jit(step, donate_argnums=(0, 1))
            lowered = jitted.lower(p_structs, opt_structs, batch)
        elif shape.kind == "prefill":
            cache = SH.cache_struct(cfg, shape, rules)
            step = make_prefill_step(cfg, rules)
            jitted = jax.jit(step, donate_argnums=(2,))
            lowered = jitted.lower(p_structs, batch, cache)
        else:  # decode
            cache = SH.cache_struct(cfg, shape, rules)
            step = make_serve_step(cfg, rules)
            jitted = jax.jit(step, donate_argnums=(2,))
            lowered = jitted.lower(p_structs, batch, cache, SH.pos_struct(rules))

        out["lower_s"] = round(time.time() - t0, 1)
        if not compile_:
            out["status"] = "lowered"
            return out
        t1 = time.time()
        from repro.models import layers as _layers
        # the cfg fingerprint keys depth-truncated variants
        # (`lower_case_depth` swaps the registry) apart from the full model
        compiled, pc_status = _compile_via_progcache(
            lowered, arch, shape_name, out["mesh"], shape.kind,
            jnp.dtype(adam_dtype).name, progcache.fingerprint(cfg),
            getattr(_layers, "UNROLL_FOR_COSTS", False))
        out["compile_s"] = round(time.time() - t1, 1)
        if pc_status is not None:
            out["progcache"] = pc_status

        mem = compiled.memory_analysis()
        out["memory"] = {
            "argument_size_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_size_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_size_bytes": getattr(mem, "temp_size_in_bytes", None),
            "generated_code_size_bytes": getattr(mem, "generated_code_size_in_bytes", None),
        }
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        out["cost"] = {
            "flops": float(ca.get("flops", 0.0)),
            "bytes_accessed": float(ca.get("bytes accessed", ca.get("bytes_accessed", 0.0))),
        }
        hlo = compiled.as_text()
        out["collectives"] = collective_bytes(hlo)
        out["status"] = "ok"
    return out


def lower_case_depth(arch: str, shape_name: str, n_groups: int,
                     multi_pod: bool = False,
                     unroll: bool = True) -> Optional[Dict[str, Any]]:
    """lower_case with the layer stack truncated to n_groups groups (and the
    whisper encoder to n_groups layers) — used for cost extrapolation.

    unroll=True replaces every lax.scan with a Python loop during lowering:
    XLA's cost_analysis counts while-loop bodies ONCE (measured: flops flat
    in depth), so only fully-unrolled measurement programs report true
    costs.  Unrolling the full configs is intractable; unrolling G∈{1,2} is
    cheap, and cost(G) is affine in G.
    """
    import dataclasses as _dc
    from repro.configs import get_config as _gc
    from repro.models import layers as _L
    cfg = _gc(arch)
    short = _dc.replace(cfg, n_layers=len(cfg.group) * n_groups,
                        n_enc_layers=min(cfg.n_enc_layers, n_groups) if cfg.n_enc_layers else 0)
    # swap the registry lookup used by lower_case for this call
    g = globals()
    orig = g["get_config"]
    g["get_config"] = lambda name: short if name == arch else orig(name)
    _L.UNROLL_FOR_COSTS = unroll
    try:
        return lower_case(arch, shape_name, multi_pod=multi_pod)
    finally:
        g["get_config"] = orig
        _L.UNROLL_FOR_COSTS = False


def extrapolate_costs(arch: str, shape_name: str, full_groups: int,
                      enc_layers: int, multi_pod: bool = False) -> Optional[Dict[str, Any]]:
    """Corrected whole-model costs: XLA's cost_analysis counts while-loop
    bodies ONCE (not ×trip_count), so scan-stacked models under-report by
    ~n_groups.  cost(G) is affine in G ⇒ measure G=1,2 and extrapolate:
        total(G) = c1 + (G − 1) · (c2 − c1).
    (For whisper the encoder depth is scaled alongside, keeping affinity.)
    """
    r1 = lower_case_depth(arch, shape_name, 1, multi_pod)
    if r1.get("status") != "ok":
        return None
    r2 = lower_case_depth(arch, shape_name, 2, multi_pod)
    if r2.get("status") != "ok":
        return None

    def lin(f1, f2):
        return f1 + (full_groups - 1) * (f2 - f1)

    out = {
        "flops": lin(r1["cost"]["flops"], r2["cost"]["flops"]),
        "bytes_accessed": lin(r1["cost"]["bytes_accessed"],
                              r2["cost"]["bytes_accessed"]),
        "collective_bytes": lin(r1["collectives"]["total_bytes"],
                                r2["collectives"]["total_bytes"]),
        "method": "G1/G2 linear extrapolation",
    }
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", type=str, default=None)
    ap.add_argument("--shape", type=str, default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--no-compile", action="store_true")
    ap.add_argument("--extrapolate", action="store_true",
                    help="also compute loop-corrected costs via G=1/G=2 compiles")
    ap.add_argument("--out", type=str, default=None)
    ap.add_argument("--progcache-dir", type=str, default=None,
                    help="persist compiled dry-run programs here; repeat "
                         "runs deserialize instead of recompiling")
    args = ap.parse_args(argv)
    if args.progcache_dir:
        progcache.activate(args.progcache_dir)

    cases = []
    archs = ARCH_IDS if (args.all or not args.arch) else [args.arch]
    shapes = list(SH.SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    results = []
    for arch in archs:
        for shp in shapes:
            for mp in meshes:
                try:
                    r = lower_case(arch, shp, multi_pod=mp,
                                   compile_=not args.no_compile)
                    if args.extrapolate and r.get("status") == "ok":
                        cfg = get_config(arch)
                        corr = extrapolate_costs(arch, shp, cfg.n_groups,
                                                 cfg.n_enc_layers, mp)
                        if corr:
                            r["corrected"] = corr
                except Exception as e:
                    r = {"arch": arch, "shape": shp,
                         "mesh": "2x16x16" if mp else "16x16",
                         "status": "error", "error": f"{type(e).__name__}: {e}",
                         "trace": traceback.format_exc()[-2000:]}
                results.append(r)
                line = {k: v for k, v in r.items() if k not in ("trace",)}
                print(json.dumps(line), flush=True)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)
    bad = [r for r in results if r["status"] == "error"]
    print(f"# {len(results)} cases, {len(bad)} errors", file=sys.stderr)
    return 1 if bad else 0


if __name__ == "__main__":
    sys.exit(main())
