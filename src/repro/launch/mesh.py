"""Production mesh construction (TPU v5e target).

Defined as functions (never module-level constants) so importing this module
never touches jax device state — required because the dry-run must set
XLA_FLAGS before first jax init while smoke tests want a 1-device world.
"""
from __future__ import annotations

import functools

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(data: int = 1, model: int = 1):
    """Tiny mesh over however many (CPU) devices exist — for tests."""
    n = len(jax.devices())
    assert data * model <= n, (data, model, n)
    return jax.make_mesh((data, model), ("data", "model"))


@functools.lru_cache(maxsize=None)
def make_client_mesh(n_clients: int):
    """1-D client mesh (axis = sharding.rules.CLIENT_AXIS) for the round
    engine (`repro.core.rounds`).

    Spans the most local devices that evenly divide the client count, so
    every shard holds the same number of clients (the engine's bitwise
    parity contract needs equal shards).  Returns (mesh, n_devices); a
    1-device world yields a trivial mesh that still exercises shard_map.
    Cached: the device world is locked at first jax init, so the mesh for a
    given client count never changes within a process (and Mesh identity
    keeps the downstream jitted-program caches hot).
    """
    import numpy as np
    from jax.sharding import Mesh

    from repro.sharding.rules import CLIENT_AXIS

    devs = jax.devices()
    ndev = max(k for k in range(1, len(devs) + 1) if n_clients % k == 0)
    return Mesh(np.asarray(devs[:ndev]), (CLIENT_AXIS,)), ndev
