"""Production mesh construction (TPU v5e target).

Defined as functions (never module-level constants) so importing this module
never touches jax device state — required because the dry-run must set
XLA_FLAGS before first jax init while smoke tests want a 1-device world.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(data: int = 1, model: int = 1):
    """Tiny mesh over however many (CPU) devices exist — for tests."""
    n = len(jax.devices())
    assert data * model <= n, (data, model, n)
    return jax.make_mesh((data, model), ("data", "model"))
