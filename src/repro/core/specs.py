"""Declarative method specs for the unified round engine (`repro.core.rounds`).

Each spec is a small frozen dataclass (hashable → static under jit) holding
the method's hyperparameters and three hooks consumed by the engine driver:

  * ``prepare(R, batch, basisb, x0)`` — per-run traced precomputation
    (typically a `CoeffLayout`);
  * ``init(R, env)``                 — the scan carry at round 0;
  * ``step(R, env, carry, rc)``      — one round (``rc`` is a
    `rounds.RoundCtx`: the round's PRNG key, the absolute round index and
    the fault layer's optional availability mask), returning
    ``(carry, (eval_x, ledger, event))``: the iterate the round is
    evaluated at, the cumulative `comm.CommLedger`, and the round's int32
    `rounds.EVENT_*` degradation bitmask (the engine turns the eval_x
    stream into f(x)−f* gaps outside the scan, the ledger stream into
    per-leg bit histories, and the event stream into `History.events` on
    the service loop).

Communication accounting is per-leg and declarative: compressors return
message `Counts`, specs price them with ``comm.price(comp.wire, counts)``
and charge the right ledger leg (`hess_up` / `grad_up` / `model_down`; the
one-time basis shipment sits on `basis_ship` from round 0).  No spec keeps
hand-maintained ``up = up + ...`` scalars.

All cross-client reductions go through the `Reducer` R, so every spec runs
unchanged on the single-device backend and on the client-sharded shard_map
backend.  The specs here are ports of the previously triplicated scan bodies
in `repro.core.batched` — parity with the op-by-op reference backend is
pinned by tests/test_batched_parity.py — plus one new method (FedNL with
Bernoulli aggregation, after "Distributed Newton-Type Methods with
Communication Compression and Bernoulli Aggregation", arXiv 2206.03588)
that exists to demonstrate that a new method is a ~50-line spec.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp

from . import client_batch, comm
from .bl import _psd_h_tilde, _psd_reconstruct_full, _psd_sum_matrix, proj_mu
from .comm import FLOAT_BITS, CommLedger
from .compressors import Compressor
from .rounds import (
    EVENT_ALL_DOWN,
    EVENT_DEGRADED,
    EVENT_NONE,
    Reducer,
    ReducePlan,
    coeff_layout,
    downlink_broadcast,
    global_grad,
    participation,
    refresh_due,
    shift_update,
    tree_shift_update,
    tree_shift_update_sum,
    xi_mask,
    xi_scalar,
)


def _sym_b(H):
    """(n, d, d) batched symmetrization."""
    return (H + jnp.transpose(H, (0, 2, 1))) / 2.0


def _fro_b(H):
    """(n, d, d) → (n,) Frobenius norms."""
    return jnp.sqrt(jnp.sum(H * H, axis=(1, 2)))


def _mv(Hb, xb):
    """(n, d, d) @ (n, d) → (n, d), batch-size-invariantly (see bmv)."""
    return client_batch.bmv(Hb, xb)


class MethodSpec:
    """Base hooks; subclasses are frozen dataclasses (static under jit)."""

    #: True for specs whose basis is a fleet-global pytree with no client
    #: axis (BL-DNN) — the sharded engine replicates it instead of sharding
    #: its leading dimension over the client mesh.
    basis_replicated = False

    #: True for specs whose round reacts to the fault layer's availability
    #: mask (`RoundCtx.avail`): the partial-participation methods (BL2/BL3)
    #: and the Bernoulli-lazy uplink (FedNL-BAG).  Specs modelling a fully
    #: synchronous fleet leave this False and `repro.launch.fed_serve`
    #: refuses to inject faults into them rather than silently ignoring
    #: the schedule.
    supports_faults = False

    #: Collective-mode selection for the sharded reducer's exact=False path
    #: (see `rounds.ReducePlan`).  The default psums every leg; specs with
    #: f32 payloads (BL-DNN) override toward pmean to keep local partials
    #: O(1).  Ignored entirely in exact mode.
    reduce_plan = ReducePlan()

    #: True for specs whose `step` runs correctly under the cohort-streaming
    #: engine (`repro.core.cohort`): every fleet reduction goes through a
    #: NAMED `reduce_tree` dict declared in `cohort_aggregates`, so the
    #: engine can maintain the absent clients' frozen contributions.  The
    #: natural cohort methods are the partial-participation ones (BL2/BL3,
    #: Alg. 2–3) and the Bernoulli-lazy uplink (FedNL-BAG).
    supports_cohort = False

    #: Names for the TOP-LEVEL elements of the carry tuple, in order — the
    #: streaming engine's handle for splitting the carry into host-resident
    #: client state (`ClientStore.state`) and resident server state, and for
    #: matching `cohort_aggregates` entries to carry leaves.
    carry_names: Tuple[str, ...] = ()

    def cohort_aggregates(self):
        """Fleet aggregates this spec's `step` reduces over RAW carry
        leaves: ``{aggregate_name: (carry_leaf_name, op)}`` with op in
        {"mean", "max"}.  For each ``mean`` entry the streaming engine
        incrementally maintains the fleet-wide sum of that carry leaf and
        hands the chunk program ``frozen[name] = sum over absent clients``;
        for ``max`` it computes the absent clients' max per epoch.
        Delta-style mean aggregates (absent clients contribute exactly 0)
        are NOT declared — a missing frozen entry is an implicit zero."""
        return {}

    def cohort_init_extras(self, R: Reducer, env, carry):
        """Per-client stacked arrays whose FLEET SUM feeds a derived piece
        of server init state (``{name: (n_local, ...) array}``).  The
        engine evaluates this slab-by-slab at fleet init, accumulates the
        sums, and passes them to `cohort_server_init`."""
        return {}

    def cohort_server_init(self, env, sums, n_total: int, carry):
        """Server carry elements that depend on a fleet reduction at init:
        ``{carry_name: value}`` computed from the accumulated
        `cohort_init_extras` sums.  Everything not named here keeps its
        per-slab `init` value (which must then be fleet-independent)."""
        return {}

    def prepare(self, R: Reducer, batch, basisb, x0):
        return None

    def init(self, R: Reducer, env):
        raise NotImplementedError

    def step(self, R: Reducer, env, carry, rc):
        raise NotImplementedError

    def eval_streams(self, batch, xs_t, f_star):
        """Post-scan evaluation of the whole trajectory: the ``xs_t`` the
        spec's ``step`` emitted (stacked over rounds) → a dict of named
        (steps,) streams, always containing ``"gap"`` (what `History.gaps`
        records).  Runs OUTSIDE the scan in one shared program on every
        aggregation backend — that is what keeps recorded histories
        bitwise-identical across backends.  The default is the GLM
        optimality gap f(x_t) − f*; pytree specs override (BL-DNN reports
        training error rate plus a loss stream)."""
        from .rounds import default_gap_stream

        return {"gap": default_gap_stream(batch, xs_t, f_star)}


# ==========================================================================
# BL1 — Algorithm 1
# ==========================================================================
@dataclasses.dataclass(frozen=True)
class BL1Spec(MethodSpec):
    hess_comp: Compressor
    model_comp: Compressor
    alpha: float
    eta: float
    p: float
    mu: float
    init_exact: bool
    grad_bits: float
    init_hess_bits: float
    basis_bits: float
    block: bool

    def prepare(self, R, batch, basisb, x0):
        return coeff_layout(R, batch, basisb, x0, self.block)

    def init(self, R, env):
        lay = env.extra
        x0 = env.x0
        L0 = lay.target_at(x0) if self.init_exact else jnp.zeros(lay.shape, x0.dtype)
        H0 = R.mean(lay.recon(L0)) + lay.ridge
        grad_w0 = global_grad(R, env.batch, x0)
        led0 = CommLedger.create(hess_up=self.init_hess_bits,
                                 basis_ship=self.basis_bits)
        return (x0, x0, L0, H0, grad_w0, jnp.asarray(True), led0)

    def step(self, R, env, carry, rc):
        key_t = rc.key
        z, w, L, H, grad_w, xi, led = carry
        lay = env.extra
        ys = (z, led, jnp.int32(EVENT_NONE))  # gap evaluated at z, post-scan

        # client-side legs: gradients + Hessian-coefficient learning, then
        # ONE fused uplink reduction for the round (gradient stack, Hessian
        # shift reconstruction, and the bit accounting share a collective)
        k_h, k_m, k_xi = jax.random.split(key_t, 3)
        S, L_n, counts = shift_update(
            lambda delta: self.hess_comp.compress(R.client_keys(k_h), delta),
            lay.target_at(z), L, self.alpha)
        red = R.reduce_tree(
            {"grad_z": client_batch.grads(env.batch, z),
             "dH": lay.recon(self.alpha * S),
             "sbits": comm.price(self.hess_comp.wire, counts)})
        grad_z = red["grad_z"]
        H_n = H + red["dH"]
        led = led.add(grad_up=jnp.where(xi, self.grad_bits, 0.0),
                      hess_up=red["sbits"])

        # gradient leg (both branches evaluated, selected by ξ)
        w_n = jnp.where(xi, z, w)
        grad_w_n = jnp.where(xi, grad_z, grad_w)

        # server model step (μ-projection + Newton solve computed once per
        # fleet, not once per shard) + compressed broadcast
        def server_step(H, grad_z, z, w, grad_w, xi):
            Hmu = proj_mu(H, self.mu)
            g = jnp.where(xi, grad_z, Hmu @ (z - w) + grad_w)
            return z - jnp.linalg.solve(Hmu, g)

        x_next = R.once(server_step, H, grad_z, z, w, grad_w, xi)
        v, vbits = self.model_comp(k_m, x_next - z)
        led = led.add(model_down=vbits)
        z_n = z + self.eta * v
        xi_n = xi_scalar(k_xi, self.p)
        return (z_n, w_n, L_n, H_n, grad_w_n, xi_n, led), ys


# ==========================================================================
# BL2 — Algorithm 2
# ==========================================================================
@dataclasses.dataclass(frozen=True)
class BL2Spec(MethodSpec):
    hess_comp: Compressor
    model_comp: Compressor
    alpha: float
    eta: float
    p: float
    tau: int
    init_exact: bool
    init_hess_bits: float
    basis_bits: float
    block: bool

    supports_faults = True        # partial participation absorbs dropouts
    supports_cohort = True        # Alg. 2: absent clients' state freezes
    carry_names = ("z", "w", "L", "Hi", "li", "gi", "led")

    def cohort_aggregates(self):
        # the server system is assembled from RAW per-client carry state
        # every round, so absent clients' frozen rows must keep
        # contributing their epoch-start values
        return {"H": ("Hi", "mean"), "l": ("li", "mean"), "g": ("gi", "mean")}

    def prepare(self, R, batch, basisb, x0):
        return coeff_layout(R, batch, basisb, x0, self.block)

    def init(self, R, env):
        lay = env.extra
        x0 = env.x0
        x0b = jnp.broadcast_to(x0, (R.n_local, env.batch.d))
        L0 = lay.target_at(x0) if self.init_exact else jnp.zeros(lay.shape, x0.dtype)
        Hi0 = lay.recon(L0) + lay.ridge
        li0 = _fro_b(_sym_b(Hi0) - client_batch.hess(env.batch, x0b))
        gi0 = (_mv(_sym_b(Hi0), x0b) + li0[:, None] * x0b
               - client_batch.grads(env.batch, x0b))
        led0 = CommLedger.create(hess_up=self.init_hess_bits,
                                 basis_ship=self.basis_bits)
        return (x0b, x0b, L0, Hi0, li0, gi0, led0)

    def step(self, R, env, carry, rc):
        key_t = rc.key
        z, w, L, Hi, li, gi, led = carry
        batch = env.batch
        d = batch.d
        lay = env.extra
        I = jnp.eye(d, dtype=env.x0.dtype)

        # one fused uplink collective for the server system, one solve per
        # fleet (shard 0) instead of one per shard
        red = R.reduce_tree({"H": Hi, "l": li, "g": gi})
        x_cur = R.once(
            lambda H, l_avg, g: jnp.linalg.solve(
                (H + H.T) / 2.0 + l_avg * I, g),
            red["H"], red["l"], red["g"])
        ys = (x_cur, led)  # gap evaluated at x_cur, outside the scan

        k_part, k_m, k_h, k_xi = jax.random.split(key_t, 4)
        part, pev = participation(R, k_part, self.tau, avail=rc.avail)

        # compressed model broadcast (participants only)
        z_n, dbits = downlink_broadcast(R, self.model_comp, k_m, z, x_cur,
                                        self.eta, part)
        led = led.add(model_down=dbits)

        # Hessian-coefficient learning
        S, L_plus, counts = shift_update(
            lambda delta: self.hess_comp.compress(R.client_keys(k_h), delta),
            lay.target_at(z_n), L, self.alpha)
        sbits = comm.price(self.hess_comp.wire, counts)
        L_n = jnp.where(part[:, None, None], L_plus, L)
        Hi_n = jnp.where(part[:, None, None], Hi + lay.recon(self.alpha * S), Hi)
        Hs_n = _sym_b(Hi_n)
        li_n = jnp.where(part, _fro_b(Hs_n - client_batch.hess(batch, z_n)), li)

        xi = xi_mask(R, k_xi, self.p) & part
        w_n = jnp.where(xi[:, None], z_n, w)
        # ξ=1: fresh g_i at the new w; ξ=0: server-reconstructed difference.
        # Non-participants: Hi_n = Hi and li_n = li exactly, so gi_recon = gi.
        gi_fresh = (_mv(Hs_n, w_n) + li_n[:, None] * w_n
                    - client_batch.grads(batch, w_n))
        gi_recon = gi + _mv(Hs_n - _sym_b(Hi), w) + (li_n - li)[:, None] * w
        gi_n = jnp.where(xi[:, None], gi_fresh, gi_recon)

        g_bits = jnp.where(xi, d * FLOAT_BITS, FLOAT_BITS + 1.0)
        bits = R.reduce_tree({"s": jnp.where(part, sbits, 0.0),
                              "g": jnp.where(part, g_bits, 0.0)}, "sum")
        led = led.add(hess_up=bits["s"] / R.n_total,
                      grad_up=bits["g"] / R.n_total)
        return (z_n, w_n, L_n, Hi_n, li_n, gi_n, led), (*ys, pev)


# ==========================================================================
# BL3 — Algorithm 3 (PSD basis of Example 5.1)
# ==========================================================================
@dataclasses.dataclass(frozen=True)
class BL3Spec(MethodSpec):
    hess_comp: Compressor
    model_comp: Compressor
    alpha: float
    eta: float
    p: float
    tau: int
    c: float
    option: int

    supports_faults = True        # partial participation absorbs dropouts
    supports_cohort = True        # Alg. 3: absent clients' state freezes
    carry_names = ("z", "w", "zprev", "L", "gam", "A", "C", "g1", "g2",
                   "beta", "led")

    def cohort_aggregates(self):
        return {"A": ("A", "mean"), "C": ("C", "mean"), "g1": ("g1", "mean"),
                "g2": ("g2", "mean"), "beta": ("beta", "max")}

    def prepare(self, R, batch, basisb, x0):
        return _psd_sum_matrix(batch.d, x0.dtype)

    def init(self, R, env):
        Ssum = env.extra
        x0b = jnp.broadcast_to(env.x0, (R.n_local, env.batch.d))
        L0 = jax.vmap(_psd_h_tilde)(client_batch.hess(env.batch, x0b))
        gam0 = jnp.maximum(self.c, jnp.max(jnp.abs(L0), axis=(1, 2)))
        A0 = jax.vmap(_psd_reconstruct_full)(L0) + 2.0 * gam0[:, None, None] * Ssum
        C0 = 2.0 * gam0[:, None, None] * Ssum
        # h̃(∇²f_i(w⁰)) = L⁰ at init, so β_i⁰ = 1 exactly (as the reference
        # backend's max over a ratio of identical matrices evaluates to)
        beta0 = jnp.ones((R.n_local,), env.x0.dtype)
        g1_0 = _mv(A0, x0b)
        g2_0 = _mv(C0, x0b) + client_batch.grads(env.batch, x0b)
        led0 = CommLedger.create(
            hess_up=(env.batch.d * (env.batch.d + 1) // 2) * FLOAT_BITS)
        return (x0b, x0b, x0b, L0, gam0, A0, C0, g1_0, g2_0, beta0, led0)

    def step(self, R, env, carry, rc):
        key_t = rc.key
        z, w, zprev, L, gam, A_i, C_i, g1, g2, beta_i, led = carry
        batch = env.batch
        d = batch.d
        Ssum = env.extra
        h_tilde = jax.vmap(_psd_h_tilde)
        recon_full = jax.vmap(_psd_reconstruct_full)

        # four means + the β max fused into one uplink collective; the
        # server system assembles and solves once per fleet (shard 0)
        red = R.reduce_tree(
            {"A": A_i, "C": C_i, "g1": g1, "g2": g2, "beta": beta_i},
            {"A": "mean", "C": "mean", "g1": "mean", "g2": "mean",
             "beta": "max"})
        x_cur = R.once(
            lambda beta, A, C, g1m, g2m: jnp.linalg.solve(
                beta * A - C, beta * g1m - g2m),
            red["beta"], red["A"], red["C"], red["g1"], red["g2"])
        ys = (x_cur, led)  # gap evaluated at x_cur, outside the scan

        k_part, k_m, k_h, k_xi = jax.random.split(key_t, 4)
        part, pev = participation(R, k_part, self.tau, avail=rc.avail)

        zprev_n = jnp.where(part[:, None], z, zprev)
        z_n, dbits = downlink_broadcast(R, self.model_comp, k_m, z, x_cur,
                                        self.eta, part)
        led = led.add(model_down=dbits)

        target = h_tilde(client_batch.hess(batch, z_n))
        S, L_plus, counts = shift_update(
            lambda delta: self.hess_comp.compress(R.client_keys(k_h), delta),
            target, L, self.alpha)
        sbits = comm.price(self.hess_comp.wire, counts)
        L_n = jnp.where(part[:, None, None], L_plus, L)
        gam_n = jnp.where(part,
                          jnp.maximum(self.c, jnp.max(jnp.abs(L_n), axis=(1, 2))),
                          gam)
        if self.option == 1:
            num = h_tilde(client_batch.hess(batch, zprev_n))
        else:
            num = target
        beta_cand = jnp.max(
            (num + 2.0 * gam_n[:, None, None]) / (L_n + 2.0 * gam_n[:, None, None]),
            axis=(1, 2),
        )
        beta_i_n = jnp.where(part, beta_cand, beta_i)
        dgam = (gam_n - gam)[:, None, None]
        A_n = jnp.where(part[:, None, None],
                        A_i + recon_full(L_n - L) + 2.0 * dgam * Ssum, A_i)
        C_n = jnp.where(part[:, None, None], C_i + 2.0 * dgam * Ssum, C_i)

        xi = xi_mask(R, k_xi, self.p) & part
        w_n = jnp.where(xi[:, None], z_n, w)
        g1_fresh = _mv(A_n, w_n)
        g2_fresh = _mv(C_n, w_n) + client_batch.grads(batch, w_n)
        # non-participants: A_n = A_i, C_n = C_i ⇒ recon branch keeps g1/g2
        g1_recon = g1 + _mv(A_n - A_i, w)
        g2_recon = g2 + _mv(C_n - C_i, w)
        g1_n = jnp.where(xi[:, None], g1_fresh, g1_recon)
        g2_n = jnp.where(xi[:, None], g2_fresh, g2_recon)

        # every PARTICIPANT's β_i^{k+1} reaches the server (one float,
        # billed with the Hessian leg; silent clients send nothing)
        g_bits = jnp.where(xi, 2.0 * d * FLOAT_BITS, 2.0 * FLOAT_BITS + 1.0)
        bits = R.reduce_tree(
            {"s": jnp.where(part, sbits + FLOAT_BITS, 0.0),
             "g": jnp.where(part, g_bits, 0.0)}, "sum")
        led = led.add(hess_up=bits["s"] / R.n_total,
                      grad_up=bits["g"] / R.n_total)
        carry_n = (z_n, w_n, zprev_n, L_n, gam_n, A_n, C_n, g1_n, g2_n,
                   beta_i_n, led)
        return carry_n, (*ys, pev)


# ==========================================================================
# Baselines: GD, DIANA, Newton
# ==========================================================================
@dataclasses.dataclass(frozen=True)
class GDSpec(MethodSpec):
    lr: float

    def init(self, R, env):
        return (env.x0, CommLedger.create())

    def step(self, R, env, carry, rc):
        x, led = carry
        x_n = x - self.lr * global_grad(R, env.batch, x)
        return ((x_n, led.add(grad_up=env.batch.d * FLOAT_BITS)),
                (x, led, jnp.int32(EVENT_NONE)))


@dataclasses.dataclass(frozen=True)
class DianaSpec(MethodSpec):
    comp: Compressor
    alpha_h: float
    lr: float

    def init(self, R, env):
        h0 = jnp.zeros((R.n_local, env.batch.d), env.x0.dtype)
        return (env.x0, h0, CommLedger.create())

    def step(self, R, env, carry, rc):
        x, h, led = carry
        gi = client_batch.grads(env.batch, x)
        q, counts = self.comp.compress(R.client_keys(rc.key), gi - h)
        bits = comm.price(self.comp.wire, counts)
        red = R.reduce_tree({"ghat": h + q, "bits": bits})
        h_n = h + self.alpha_h * q
        x_n = x - self.lr * red["ghat"]
        return ((x_n, h_n, led.add(grad_up=red["bits"])),
                (x, led, jnp.int32(EVENT_NONE)))


@dataclasses.dataclass(frozen=True)
class NewtonSpec(MethodSpec):
    hess_bits: float
    grad_bits: float
    basis_bits: float

    def init(self, R, env):
        return (env.x0, CommLedger.create(basis_ship=self.basis_bits))

    def step(self, R, env, carry, rc):
        x, led = carry
        batch = env.batch
        if env.basisb is None:
            Hc = client_batch.hess(batch, x)
        else:
            coef = client_batch.hess_coeff_target(env.basisb, batch, x)
            Hc = env.basisb.server_reconstruct(coef, batch.lam)
        red = R.reduce_tree({"H": Hc, "g": client_batch.grads(batch, x)})
        x_n = R.once(lambda H, g: x - jnp.linalg.solve(H, g),
                     red["H"], red["g"])
        return ((x_n, led.add(hess_up=self.hess_bits,
                              grad_up=self.grad_bits)),
                (x, led, jnp.int32(EVENT_NONE)))


# ==========================================================================
# FedNL-BAG — FedNL Hessian learning + Bernoulli gradient aggregation
# (the new-method-as-a-spec demonstration; arXiv 2206.03588's BAG mechanism)
# ==========================================================================
@dataclasses.dataclass(frozen=True)
class FedNLBAGSpec(MethodSpec):
    """Newton-type method with compressed Hessian learning and a
    Bernoulli-lazy gradient uplink: each round every client independently
    reports its exact local gradient with probability q; the server keeps
    the latest gradient per client (lazy aggregation — stale entries of
    silent clients are reused, which is the BAG mechanism's point) and
    takes the projected-Newton step with ĝ = mean of the gradient table.
    Staleness vanishes as the iterates converge, so the local Newton-type
    rate survives q < 1."""

    hess_comp: Compressor
    alpha: float
    q: float
    eta: float
    mu: float
    init_exact: bool
    init_hess_bits: float
    basis_bits: float
    block: bool

    supports_faults = True        # lazy table reuses silent clients' rows
    supports_cohort = True        # the lazy table IS frozen absent state
    carry_names = ("z", "L", "H", "gtab", "led")

    def cohort_aggregates(self):
        # ĝ is the mean of the RAW gradient table; absent clients' stale
        # rows keep contributing (exactly the BAG mechanism).  dH/sbits are
        # delta-style (absent clients contribute 0) — undeclared on purpose.
        return {"ghat": ("gtab", "mean")}

    def cohort_init_extras(self, R, env, carry):
        # H⁰ = mean_i recon(L⁰_i) + ridge is a fleet reduction; hand the
        # engine the per-client reconstructions to sum across slabs
        _, L0, _, _, _ = carry
        return {"recL": env.extra.recon(L0)}

    def cohort_server_init(self, env, sums, n_total, carry):
        return {"H": sums["recL"] / n_total + env.extra.ridge}

    def prepare(self, R, batch, basisb, x0):
        return coeff_layout(R, batch, basisb, x0, self.block)

    def init(self, R, env):
        lay = env.extra
        x0 = env.x0
        L0 = lay.target_at(x0) if self.init_exact else jnp.zeros(lay.shape, x0.dtype)
        H0 = R.mean(lay.recon(L0)) + lay.ridge
        gtab0 = client_batch.grads(env.batch, x0)  # exact init gradients
        led0 = CommLedger.create(hess_up=self.init_hess_bits,
                                 grad_up=env.batch.d * FLOAT_BITS,
                                 basis_ship=self.basis_bits)
        return (x0, L0, H0, gtab0, led0)

    def step(self, R, env, carry, rc):
        key_t = rc.key
        z, L, H, gtab, led = carry
        batch = env.batch
        lay = env.extra

        k_h, k_b = jax.random.split(key_t, 2)
        # Bernoulli-lazy aggregation: reporters refresh their table row.
        # Unavailable clients (fault layer) just stay silent — BAG's lazy
        # table reuses their stale rows, so dropouts degrade staleness
        # rather than correctness (the event stream records the outage).
        send = jax.random.bernoulli(k_b, self.q, (R.n,))
        if rc.avail is None:
            ev = jnp.int32(EVENT_NONE)
        else:
            n_av = jnp.sum(rc.avail)
            ev = (jnp.int32(EVENT_DEGRADED) * (n_av < R.n)
                  + jnp.int32(EVENT_ALL_DOWN) * (n_av == 0)).astype(jnp.int32)
            send = send & rc.avail
        send = R.shard(send)
        ys = (z, led, ev)  # gap evaluated at z, outside the scan
        gtab_n = jnp.where(send[:, None], client_batch.grads(batch, z), gtab)

        # FedNL Hessian-coefficient learning (same shift recursion as BL1);
        # both legs' payloads and bit accounting share one fused collective
        S, L_n, counts = shift_update(
            lambda delta: self.hess_comp.compress(R.client_keys(k_h), delta),
            lay.target_at(z), L, self.alpha)
        red = R.reduce_tree(
            {"ghat": gtab_n, "dH": lay.recon(self.alpha * S),
             "gbits": jnp.where(send, batch.d * FLOAT_BITS, 0.0),
             "sbits": comm.price(self.hess_comp.wire, counts)},
            {"ghat": "mean", "dH": "mean", "gbits": "sum", "sbits": "mean"})
        led = led.add(grad_up=red["gbits"] / R.n_total, hess_up=red["sbits"])
        H_n = H + red["dH"]

        # damped Newton step: η < 1 tempers the staleness feedback loop an
        # aggressive q would otherwise excite (η = 1 recovers FedNL when
        # q = 1); projected + solved once per fleet (shard 0)
        z_n = R.once(
            lambda H_n, ghat: z - self.eta * jnp.linalg.solve(
                proj_mu(H_n, self.mu), ghat),
            H_n, red["ghat"])
        return (z_n, L_n, H_n, gtab_n, led), ys


# ==========================================================================
# BL-DNN — the paper's communication layer on parameter PYTREES
# (the beyond-paper deep-network workload; see repro.fed.bldnn for the
# public entry point, model builders and the experiment wiring)
# ==========================================================================
@dataclasses.dataclass(frozen=True)
class BasisRefreshPolicy:
    """Amortized basis shipment for specs that bill a shipped basis.

    ``rounds_per_refresh = 0`` (default) is the legacy ship-once policy:
    one shipment billed at round 0, reused forever.  ``T ≥ 1`` amortizes:
    the round-0 shipment is still billed in full, and at every later
    boundary (``rounds.refresh_due``: ``t % T == 0``, pure in the ABSOLUTE
    round index so chunking and checkpoint resume can't move it) the
    shipment is re-billed ONLY when the drift trigger fires — the previous
    round's fleet-mean rotated-coefficient energy leakage
    (1 − ‖compressed‖²/‖target‖² on the gradient leg) has reached
    ``drift_threshold``.  A threshold of 0 re-ships at every boundary
    (``T = 1`` then bills every round); a threshold > 1 never re-ships.

    Accounting-only by construction: the basis is numerically FIXED for
    the run (every client derives it from the shared initialization, so a
    "re-shipment" carries the same factors), which is what makes
    trajectories invariant to the policy — only the ``basis_ship`` ledger
    leg and the drift carry leaf change (pinned bitwise on both reducers
    in tests/test_basis_ship.py)."""

    rounds_per_refresh: int = 0
    drift_threshold: float = 0.0

    @property
    def amortized(self) -> bool:
        return self.rounds_per_refresh > 0

    def __post_init__(self):
        if self.rounds_per_refresh < 0:
            raise ValueError("rounds_per_refresh must be >= 0 "
                             f"(0 = ship once), got {self.rounds_per_refresh}")
        if self.drift_threshold < 0.0:
            raise ValueError("drift_threshold must be >= 0, got "
                             f"{self.drift_threshold}")


@dataclasses.dataclass(frozen=True)
class BLDNNSpec(MethodSpec):
    """Basis Learn + compressed-shift learning applied per layer of a DNN.

    The same round skeleton as the GLM specs, with every array generalized
    to a parameter *pytree* (leaves carry the engine's leading client
    axis):

      1. per-client gradients in the per-layer SVD basis (`env.basisb`, a
         `basis.PerLayerSVDBasis`; None ⇒ standard basis) go through the
         Alg. 1 shift recursion via `rounds.tree_shift_update` — one
         compressor per leaf (Top-K budgets scale with leaf size), per-leaf
         `Counts` priced and summed onto the ledger's ``grad_up`` leg;
      2. the curvature stream: clients learn a per-parameter Fisher
         diagonal (g², standard basis) through the identical recursion —
         the FedNL Hessian-learning loop with diag(F) standing in for
         ∇²f_i — billed on ``hess_up``; the server preconditions the
         aggregated update with it;
      3. the server step x ← x − lr·ĝ/(√F̂+ε) on the replicated params.

    DNN tensors ship as f32, so every leg is priced through
    `comm.with_float_bits(comp.wire, 32)` (index/entry widths untouched)
    and the (U_ℓ, V_ℓ) shipment bills on ``basis_ship`` — by default once
    at 32 bits/float, or at a compressed price via ``basis_ship_bits``
    (the `comm.price` of the quantized factors the engine actually
    rotates with), re-billed on the `BasisRefreshPolicy` schedule when
    ``refresh`` amortizes the shipment.

    ``loss_fn(params, client_data) -> scalar`` is the per-client loss;
    ``eval_fn(params, data) -> {"gap": ..., ...}`` produces the post-scan
    evaluation streams (BL-DNN reports training error rate as the gap — so
    the registered experiment's bits-to-tolerance IS bits-to-accuracy —
    plus a ``"loss"`` stream).  Both are static spec fields: specs holding
    different functions compile separate engine programs.
    """

    loss_fn: Callable
    eval_fn: Callable
    grad_comps: Tuple[Compressor, ...]
    fisher_comps: Tuple[Compressor, ...]
    alpha: float = 1.0            # shift learning rate (contractive ⇒ 1)
    fisher_alpha: float = 0.1
    lr: float = 1e-3
    eps: float = 1e-2
    precondition: bool = True
    #: bits one basis shipment costs on the wire.  None derives the legacy
    #: dense-f32 price (``ship_floats() × 32``); compressed shipments pass
    #: the `comm.price` of the quantized factors (see
    #: `basis.PerLayerSVDBasis.shipped` — `repro.fed.bldnn.run_bldnn`
    #: wires both sides: the quantized basis into the engine AND its exact
    #: price in here).
    basis_ship_bits: Optional[float] = None
    #: amortized re-shipment schedule; default is the legacy ship-once.
    refresh: BasisRefreshPolicy = BasisRefreshPolicy()

    basis_replicated = True       # PerLayerSVDBasis is fleet-global

    #: exact=False collectives: f32 coefficient/Fisher payloads travel as
    #: pmean (local partials stay O(1) in f32); the f64 bit accounting
    #: scalars psum (bit counts are integers in f64, so order-exact).
    reduce_plan = ReducePlan(dense="pmean", vector="pmean", scalar="psum")

    WIRE_FLOAT_BITS = 32          # DNN tensors are f32 on the wire

    def _bill(self, comps, auxs):
        """Per-client bits: per-leaf counts priced at the f32 wire, summed
        across leaves (one ledger leg per stream, never per leaf)."""
        return sum(
            comm.price(comm.with_float_bits(c.wire, self.WIRE_FLOAT_BITS), a)
            for c, a in zip(comps, auxs))

    def _ship_bits(self, env) -> float:
        """Bits of ONE basis shipment (round 0 and every fired refresh)."""
        if env.basisb is None:
            return 0.0
        if self.basis_ship_bits is not None:
            return float(self.basis_ship_bits)
        return env.basisb.ship_floats() * self.WIRE_FLOAT_BITS

    def init(self, R, env):
        params = env.x0
        stacked = lambda p: jnp.zeros((R.n_local,) + p.shape, jnp.float32)
        shift = jax.tree.map(stacked, params)   # complete basis ⇒ coeff
        fshift = jax.tree.map(stacked, params)  # shapes == param shapes
        server_f = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                params)
        led0 = CommLedger.create(basis_ship=self._ship_bits(env))
        carry = (params, shift, fshift, server_f, led0)
        if self.refresh.amortized:
            # last round's fleet-mean rotated-coefficient energy leakage —
            # the drift trigger's input, replicated (no client axis) so it
            # checkpoints with the server state
            carry = carry + (jnp.zeros((), jnp.float64),)
        return carry

    def step(self, R, env, carry, rc):
        key_t = rc.key
        amortized = self.refresh.amortized
        if amortized:
            params, shift, fshift, server_f, led, drift = carry
        else:
            params, shift, fshift, server_f, led = carry
        ys = (params, led, jnp.int32(EVENT_NONE))  # evaluated post-scan
        data = env.batch.data                     # leaves (n_local, ...)
        basis = env.basisb

        # per-client gradients, rotated into the per-layer basis
        g = jax.vmap(jax.grad(self.loss_fn), in_axes=(None, 0))(params, data)
        coeff = g if basis is None else basis.rotate(g)

        k_g, k_f = jax.random.split(key_t)
        n_leaves = len(jax.tree_util.tree_leaves(params))
        gks = jax.random.split(k_g, n_leaves)
        S, shift_n, gauxs = tree_shift_update(
            lambda i, delta: self.grad_comps[i].compress(
                R.client_keys(gks[i]), delta),
            coeff, shift, self.alpha)
        gbits = self._bill(self.grad_comps, gauxs)

        if self.precondition:
            # the second-order leg: Fisher diagonal through the same
            # recursion (diagonal curvature lives in the standard basis),
            # driven through the fused compress-then-reduce codec — the
            # compressor also emits the local client-axis partial sum, so
            # the bandwidth-optimal sharded path reduces one payload-sized
            # tensor per leaf instead of the dense client stack
            ftarget = jax.tree.map(lambda gi: gi.astype(jnp.float32) ** 2, g)
            fks = jax.random.split(k_f, n_leaves)
            Fc, fshift_n, fauxs, fsums = tree_shift_update_sum(
                lambda i, delta: self.fisher_comps[i].compress_sum(
                    R.client_keys(fks[i]), delta),
                ftarget, fshift, self.fisher_alpha)
            fbits = self._bill(self.fisher_comps, fauxs)
        else:
            fshift_n = fshift
            fbits = jnp.zeros((R.n_local,), jnp.float64)

        # ONE fused uplink reduction for the round: every coefficient leaf
        # plus both bit-accounting legs (per dtype: f32 coeffs, f64 bits).
        # The server mirrors every client's recursion, so the aggregated
        # gradient estimate is the fleet mean of the UPDATED shifts.
        agg = {"coeff": shift_n, "gbits": gbits, "fbits": fbits}
        if amortized:
            # per-client rotated-coefficient energy leakage of this round's
            # gradient leg (1 − ‖C(Δ)‖²/‖Δ‖², clipped at 0 for unbiased
            # codecs that can overshoot); its fleet mean rides the SAME
            # fused collective as the bit legs, so both reducers produce
            # the identical drift scalar
            sq = lambda x: jnp.sum(jnp.square(x.astype(jnp.float64)),
                                   axis=tuple(range(1, x.ndim)))
            kept = sum(sq(s) for s in jax.tree_util.tree_leaves(S))
            total = sum(sq(c - s0)
                        for c, s0 in zip(jax.tree_util.tree_leaves(coeff),
                                         jax.tree_util.tree_leaves(shift)))
            safe = jnp.where(total > 0.0, total, 1.0)
            agg["drift"] = jnp.maximum(
                jnp.where(total > 0.0, 1.0 - kept / safe, 0.0), 0.0)
        red = R.reduce_tree(agg)
        coeff_mean = red["coeff"]
        g_hat = coeff_mean if basis is None else basis.unrotate(coeff_mean)

        if self.precondition:
            fmeans = R.tree_mean_presummed(Fc, fsums)
            server_f_n = jax.tree.map(
                lambda sf, fm: sf + self.fisher_alpha * fm, server_f, fmeans)
            update = jax.tree.map(
                lambda gh, sf: gh / (jnp.sqrt(jnp.maximum(sf, 0.0)) + self.eps),
                g_hat, server_f_n)
        else:
            server_f_n, update = server_f, g_hat

        params_n = jax.tree.map(
            lambda p, u: (p.astype(jnp.float32) - self.lr * u).astype(p.dtype),
            params, update)
        if amortized:
            # re-ship at refresh boundaries (pure in the absolute round
            # index — see rounds.refresh_due) when LAST round's drift has
            # reached the trigger; round 0's shipment is billed by init
            fire = (refresh_due(rc.t, self.refresh.rounds_per_refresh)
                    & (rc.t > 0)
                    & (drift >= self.refresh.drift_threshold))
            led = led.add(grad_up=red["gbits"], hess_up=red["fbits"],
                          basis_ship=jnp.where(fire, self._ship_bits(env),
                                               0.0))
            return (params_n, shift_n, fshift_n, server_f_n, led,
                    red["drift"]), ys
        led = led.add(grad_up=red["gbits"], hess_up=red["fbits"])
        return (params_n, shift_n, fshift_n, server_f_n, led), ys

    def eval_streams(self, batch, xs_t, f_star):
        """Vmapped whole-trajectory evaluation of `eval_fn` (one shared
        program on every backend); ``f_star`` is unused — DNN training has
        no reference optimum, the gap stream is the training error rate."""
        return jax.jit(jax.vmap(lambda p: self.eval_fn(p, batch.data)))(xs_t)
