"""Batched, jit-compiled execution engine for BL1/BL2/BL3 and the baselines.

Per-client state lives in leading-axis-`n` stacked arrays (`ClientBatch`,
`BatchedBasis` — see `client_batch.py`); compressors run through their
vmapped `Compressor.batched` entry points; rounds run under one
`jax.lax.scan`, so a whole optimization trajectory is a single XLA program
with zero device→host syncs until the histories come back at the end.
Partial participation is a Bernoulli mask folded into `jnp.where` updates
instead of a Python `if part[i]`.

Every runner is a module-level `jax.jit` with the compressors and scalar
hyperparameters as *static* arguments (compressor dataclasses are hashable),
so repeated calls with the same configuration — the benchmark and test
pattern — hit the jit cache instead of retracing.

Parity contract (pinned by tests/test_batched_parity.py): with deterministic
compressors and full participation the fast path reproduces the reference
backend (`bl_reference.py`) trajectories to ~1e-8 in the gap; stochastic
configurations draw from a different PRNG stream (one split per round
instead of a serial per-client chain) and match in distribution only.

Raises `FastPathUnavailable` for configurations the stacked representation
cannot express (heterogeneous client shapes, mixed basis kinds, mixed
compressor configurations); the public `bl.bl1/bl2/bl3` dispatchers fall
back to the reference backend in that case.
"""
from __future__ import annotations

import functools
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from . import client_batch, glm
from .basis import MatrixBasis
from .bl import (
    History,
    _psd_h_tilde,
    _psd_reconstruct_full,
    _psd_sum_matrix,
    proj_mu,
)
from .compressors import (
    FLOAT_BITS,
    BernoulliLazy,
    ComposedRankR,
    ComposedTopK,
    Compressor,
    Identity,
    NaturalCompression,
    RandK,
    RandomDithering,
    RankR,
    TopK,
)


class FastPathUnavailable(Exception):
    """This configuration cannot run batched; use the reference backend."""


_SUPPORTED = (
    Identity,
    TopK,
    RandK,
    RankR,
    RandomDithering,
    NaturalCompression,
    ComposedTopK,
    ComposedRankR,
    BernoulliLazy,
)


def _check_supported(comp: Compressor) -> None:
    if type(comp) not in _SUPPORTED:
        raise FastPathUnavailable(f"unsupported compressor {type(comp).__name__}")
    for inner in ("inner", "inner_u", "inner_v"):
        if hasattr(comp, inner):
            _check_supported(getattr(comp, inner))


def _one_of(comps: Sequence[Compressor], what: str) -> Compressor:
    """The fleet's single compressor config; raise if heterogeneous."""
    c0 = comps[0]
    _check_supported(c0)
    for c in comps[1:]:
        if type(c) is not type(c0) or c != c0:
            raise FastPathUnavailable(f"heterogeneous {what} compressors")
    return c0


def _stack_or_raise(clients, bases=None):
    batch = client_batch.from_clients(clients)
    if batch is None:
        raise FastPathUnavailable("heterogeneous client shapes / λ")
    basisb = None
    if bases is not None:
        basisb = client_batch.stack_bases(bases)
        if basisb is None:
            raise FastPathUnavailable("mixed basis kinds")
    return batch, basisb


def _history(gaps, ups, downs) -> History:
    g = np.maximum(np.asarray(gaps), 0.0)
    return History(list(map(float, g)), list(map(float, np.asarray(ups))),
                   list(map(float, np.asarray(downs))))


def _f_star(batch, x_star) -> jax.Array:
    return client_batch.global_loss(batch, x_star)


def _sym_b(H):
    """(n, d, d) batched symmetrization."""
    return (H + jnp.transpose(H, (0, 2, 1))) / 2.0


def _fro_b(H):
    """(n, d, d) → (n,) Frobenius norms."""
    return jnp.sqrt(jnp.sum(H * H, axis=(1, 2)))


def _mv(Hb, xb):
    """(n, d, d) @ (n, d) → (n, d)."""
    return jnp.einsum("nde,ne->nd", Hb, xb)


def _participation(key, n: int, tau: int):
    """Bernoulli(τ/n) mask with the reference's force-one-client fallback."""
    part = jax.random.bernoulli(key, tau / n, (n,))
    idx = jax.random.randint(key, (), 0, n)
    return part | (~part.any() & (jnp.arange(n) == idx))


def _xi_mask(key, n: int, p: float):
    if p >= 1.0:
        return jnp.ones((n,), bool)
    return jax.random.bernoulli(key, p, (n,))


def _block_mode(basisb, comp) -> bool:
    """True when coefficient state can live in compact (n, r, r) blocks.

    Valid only for the data basis (support is exactly the top-left r×r
    block) with compressors whose output *and bit accounting* are invariant
    to dropping the padding zeros: Top-K style selection with K ≤ r².
    (Identity/RankR/dithering bill by element count or vector length of the
    padded d×d array, so they keep the full representation.)
    """
    if basisb is None or basisb.kind != "data_outer":
        return False
    rb = basisb.r_max
    if type(comp) is TopK and not comp.symmetrize and comp.k <= rb * rb:
        return True
    if type(comp) is ComposedTopK and comp.k <= rb * rb:
        return True
    return False


# ==========================================================================
# BL1 — Algorithm 1 (fast path)
# ==========================================================================
@functools.partial(
    jax.jit,
    static_argnames=(
        "hess_comp", "model_comp", "alpha", "eta", "p", "mu",
        "init_exact", "grad_bits", "init_up", "block",
    ),
)
def _bl1_run(batch, basisb, x0, f_star, keys, *, hess_comp, model_comp,
             alpha, eta, p, mu, init_exact, grad_bits, init_up, block):
    n, d = batch.n, batch.d
    lam = batch.lam

    if block:
        # §2.3 block mode: coefficient state stays (n, r, r); the d×d data
        # Hessian is never materialized (Γ = (AV)ᵀD(AV)/m)
        AV = client_batch.basis_AV(basisb, batch)
        rb = basisb.r_max
        target_at = lambda z: client_batch.hess_coeff_block(basisb, batch, z, AV)
        recon = lambda S: client_batch.reconstruct_block(basisb, S)
        L_shape = (n, rb, rb)
        ridge = lam * jnp.eye(d, dtype=x0.dtype)
    else:
        target_at = lambda z: client_batch.hess_coeff_target(basisb, batch, z)
        recon = basisb.reconstruct
        L_shape = (n, d, d)
        ridge = (lam * jnp.eye(d, dtype=x0.dtype)
                 if basisb.kind == "data_outer" else jnp.zeros((d, d), x0.dtype))

    L0 = target_at(x0) if init_exact else jnp.zeros(L_shape, x0.dtype)
    H0 = jnp.mean(recon(L0), axis=0) + ridge
    grad_w0 = client_batch.global_grad(batch, x0)

    def step(carry, key_t):
        z, w, L, H, grad_w, xi, up, down = carry
        gap = client_batch.global_loss(batch, z) - f_star
        ys = (gap, up, down)

        Hmu = proj_mu(H, mu)
        # gradient leg (both branches evaluated, selected by ξ)
        grad_z = client_batch.global_grad(batch, z)
        w_n = jnp.where(xi, z, w)
        grad_w_n = jnp.where(xi, grad_z, grad_w)
        g = jnp.where(xi, grad_z, Hmu @ (z - w) + grad_w)
        up = up + jnp.where(xi, grad_bits, 0.0)

        # Hessian-coefficient learning, all clients at once
        k_h, k_m, k_xi = jax.random.split(key_t, 3)
        target = target_at(z)
        S, bits = hess_comp.batched(jax.random.split(k_h, n), target - L)
        L_n = L + alpha * S
        H_delta = jnp.mean(recon(alpha * S), axis=0)
        up = up + jnp.mean(bits)

        # server model step + compressed broadcast
        x_next = z - jnp.linalg.solve(Hmu, g)
        H_n = H + H_delta
        v, vbits = model_comp(k_m, x_next - z)
        down = down + vbits
        z_n = z + eta * v
        xi_n = _xi_mask(k_xi, 1, p)[0]
        return (z_n, w_n, L_n, H_n, grad_w_n, xi_n, up, down), ys

    carry0 = (
        x0, x0, L0, H0, grad_w0, jnp.asarray(True),
        jnp.asarray(init_up, jnp.float64), jnp.asarray(0.0, jnp.float64),
    )
    _, ys = jax.lax.scan(step, carry0, keys)
    return ys


def bl1_fast(clients, bases, hess_comp, model_comp, x0, x_star, steps,
             alpha=1.0, eta=1.0, p=1.0, mu=None, seed=0,
             init_exact_hessian=True) -> History:
    batch, basisb = _stack_or_raise(clients, bases)
    hc = _one_of(list(hess_comp), "hessian")
    _check_supported(model_comp)
    mu = batch.lam if mu is None else mu
    keys = jax.random.split(jax.random.PRNGKey(seed), steps)
    gaps, ups, downs = _bl1_run(
        batch, basisb, x0, _f_star(batch, x_star), keys,
        hess_comp=hc, model_comp=model_comp, alpha=alpha, eta=eta, p=p,
        mu=mu, init_exact=init_exact_hessian,
        grad_bits=basisb.grad_uplink_bits_mean(),
        init_up=basisb.init_bits_mean(init_exact_hessian),
        block=_block_mode(basisb, hc),
    )
    return _history(gaps, ups, downs)


# ==========================================================================
# BL2 — Algorithm 2 (fast path)
# ==========================================================================
@functools.partial(
    jax.jit,
    static_argnames=(
        "hess_comp", "model_comp", "alpha", "eta", "p", "tau",
        "init_exact", "init_up", "block",
    ),
)
def _bl2_run(batch, basisb, x0, f_star, keys, *, hess_comp, model_comp,
             alpha, eta, p, tau, init_exact, init_up, block):
    n, d = batch.n, batch.d
    lam = batch.lam
    I = jnp.eye(d, dtype=x0.dtype)

    if block:
        AV = client_batch.basis_AV(basisb, batch)
        rb = basisb.r_max
        target_at = lambda z: client_batch.hess_coeff_block(basisb, batch, z, AV)
        recon = lambda S: client_batch.reconstruct_block(basisb, S)
        L_shape = (n, rb, rb)
    else:
        target_at = lambda z: client_batch.hess_coeff_target(basisb, batch, z)
        recon = basisb.reconstruct
        L_shape = (n, d, d)
    ridge = (lam * jnp.eye(d, dtype=x0.dtype)
             if basisb.kind == "data_outer" else jnp.zeros((d, d), x0.dtype))

    x0b = jnp.broadcast_to(x0, (n, d))
    L0 = target_at(x0) if init_exact else jnp.zeros(L_shape, x0.dtype)
    Hi0 = recon(L0) + ridge
    li0 = _fro_b(_sym_b(Hi0) - client_batch.hess(batch, x0b))
    gi0 = _mv(_sym_b(Hi0), x0b) + li0[:, None] * x0b - client_batch.grads(batch, x0b)

    def step(carry, key_t):
        z, w, L, Hi, li, gi, up, down = carry
        H = jnp.mean(Hi, axis=0)
        l_avg = jnp.mean(li)
        g = jnp.mean(gi, axis=0)
        x_cur = jnp.linalg.solve((H + H.T) / 2.0 + l_avg * I, g)
        gap = client_batch.global_loss(batch, x_cur) - f_star
        ys = (gap, up, down)

        k_part, k_m, k_h, k_xi = jax.random.split(key_t, 4)
        part = _participation(k_part, n, tau)

        # compressed model broadcast (participants only)
        v, vbits = model_comp.batched(jax.random.split(k_m, n), x_cur[None, :] - z)
        z_n = jnp.where(part[:, None], z + eta * v, z)
        down = down + jnp.sum(jnp.where(part, vbits, 0.0)) / n

        # Hessian-coefficient learning
        target = target_at(z_n)
        S, sbits = hess_comp.batched(jax.random.split(k_h, n), target - L)
        L_n = jnp.where(part[:, None, None], L + alpha * S, L)
        Hi_n = jnp.where(part[:, None, None], Hi + recon(alpha * S), Hi)
        Hs_n = _sym_b(Hi_n)
        li_n = jnp.where(part, _fro_b(Hs_n - client_batch.hess(batch, z_n)), li)

        xi = _xi_mask(k_xi, n, p) & part
        w_n = jnp.where(xi[:, None], z_n, w)
        # ξ=1: fresh g_i at the new w; ξ=0: server-reconstructed difference.
        # Non-participants: Hi_n = Hi and li_n = li exactly, so gi_recon = gi.
        gi_fresh = _mv(Hs_n, w_n) + li_n[:, None] * w_n - client_batch.grads(batch, w_n)
        gi_recon = gi + _mv(Hs_n - _sym_b(Hi), w) + (li_n - li)[:, None] * w
        gi_n = jnp.where(xi[:, None], gi_fresh, gi_recon)

        g_bits = jnp.where(xi, d * FLOAT_BITS, FLOAT_BITS + 1.0)
        up = up + jnp.sum(jnp.where(part, sbits + g_bits, 0.0)) / n
        return (z_n, w_n, L_n, Hi_n, li_n, gi_n, up, down), ys

    carry0 = (
        x0b, x0b, L0, Hi0, li0, gi0,
        jnp.asarray(init_up, jnp.float64), jnp.asarray(0.0, jnp.float64),
    )
    _, ys = jax.lax.scan(step, carry0, keys)
    return ys


def bl2_fast(clients, bases, hess_comp, model_comp, x0, x_star, steps,
             alpha=1.0, eta=1.0, p=1.0, tau=None, seed=0,
             init_exact_hessian=True) -> History:
    batch, basisb = _stack_or_raise(clients, bases)
    hc = _one_of(list(hess_comp), "hessian")
    mc = _one_of(list(model_comp), "model")
    tau = batch.n if tau is None else tau
    keys = jax.random.split(jax.random.PRNGKey(seed), steps)
    gaps, ups, downs = _bl2_run(
        batch, basisb, x0, _f_star(batch, x_star), keys,
        hess_comp=hc, model_comp=mc, alpha=alpha, eta=eta, p=p, tau=tau,
        init_exact=init_exact_hessian,
        init_up=basisb.init_bits_mean(init_exact_hessian),
        block=_block_mode(basisb, hc),
    )
    return _history(gaps, ups, downs)


# ==========================================================================
# BL3 — Algorithm 3 (fast path, PSD basis of Example 5.1)
# ==========================================================================
@functools.partial(
    jax.jit,
    static_argnames=("hess_comp", "model_comp", "alpha", "eta", "p", "tau",
                     "c", "option"),
)
def _bl3_run(batch, x0, f_star, keys, *, hess_comp, model_comp, alpha, eta,
             p, tau, c, option):
    n, d = batch.n, batch.d
    Ssum = _psd_sum_matrix(d, x0.dtype)
    h_tilde = jax.vmap(_psd_h_tilde)
    recon_full = jax.vmap(_psd_reconstruct_full)

    x0b = jnp.broadcast_to(x0, (n, d))
    L0 = h_tilde(client_batch.hess(batch, x0b))
    gam0 = jnp.maximum(c, jnp.max(jnp.abs(L0), axis=(1, 2)))
    A0 = recon_full(L0) + 2.0 * gam0[:, None, None] * Ssum
    C0 = 2.0 * gam0[:, None, None] * Ssum
    beta0 = jnp.max(
        (L0 + 2.0 * gam0[:, None, None]) / (L0 + 2.0 * gam0[:, None, None]),
        axis=(1, 2),
    )  # h̃(∇²f_i(w⁰)) = L⁰ at init, so β_i⁰ = 1 exactly (as the reference)
    g1_0 = _mv(A0, x0b)
    g2_0 = _mv(C0, x0b) + client_batch.grads(batch, x0b)

    def step(carry, key_t):
        z, w, zprev, L, gam, A_i, C_i, g1, g2, beta_i, up, down = carry
        beta = jnp.max(beta_i)
        Hk = beta * jnp.mean(A_i, axis=0) - jnp.mean(C_i, axis=0)
        gk = beta * jnp.mean(g1, axis=0) - jnp.mean(g2, axis=0)
        x_cur = jnp.linalg.solve(Hk, gk)
        gap = client_batch.global_loss(batch, x_cur) - f_star
        ys = (gap, up, down)

        k_part, k_m, k_h, k_xi = jax.random.split(key_t, 4)
        part = _participation(k_part, n, tau)

        v, vbits = model_comp.batched(jax.random.split(k_m, n), x_cur[None, :] - z)
        zprev_n = jnp.where(part[:, None], z, zprev)
        z_n = jnp.where(part[:, None], z + eta * v, z)
        down = down + jnp.sum(jnp.where(part, vbits, 0.0)) / n

        target = h_tilde(client_batch.hess(batch, z_n))
        S, sbits = hess_comp.batched(jax.random.split(k_h, n), target - L)
        L_n = jnp.where(part[:, None, None], L + alpha * S, L)
        gam_n = jnp.where(part, jnp.maximum(c, jnp.max(jnp.abs(L_n), axis=(1, 2))), gam)
        if option == 1:
            num = h_tilde(client_batch.hess(batch, zprev_n))
        else:
            num = target
        beta_cand = jnp.max(
            (num + 2.0 * gam_n[:, None, None]) / (L_n + 2.0 * gam_n[:, None, None]),
            axis=(1, 2),
        )
        beta_i_n = jnp.where(part, beta_cand, beta_i)
        dgam = (gam_n - gam)[:, None, None]
        A_n = jnp.where(part[:, None, None], A_i + recon_full(L_n - L) + 2.0 * dgam * Ssum, A_i)
        C_n = jnp.where(part[:, None, None], C_i + 2.0 * dgam * Ssum, C_i)

        xi = _xi_mask(k_xi, n, p) & part
        w_n = jnp.where(xi[:, None], z_n, w)
        g1_fresh = _mv(A_n, w_n)
        g2_fresh = _mv(C_n, w_n) + client_batch.grads(batch, w_n)
        # non-participants: A_n = A_i, C_n = C_i ⇒ recon branch keeps g1/g2
        g1_recon = g1 + _mv(A_n - A_i, w)
        g2_recon = g2 + _mv(C_n - C_i, w)
        g1_n = jnp.where(xi[:, None], g1_fresh, g1_recon)
        g2_n = jnp.where(xi[:, None], g2_fresh, g2_recon)

        g_bits = jnp.where(xi, 2.0 * d * FLOAT_BITS, 2.0 * FLOAT_BITS + 1.0)
        up = up + jnp.sum(jnp.where(part, sbits + g_bits + FLOAT_BITS, 0.0)) / n
        carry_n = (z_n, w_n, zprev_n, L_n, gam_n, A_n, C_n, g1_n, g2_n,
                   beta_i_n, up, down)
        return carry_n, ys

    up0 = jnp.asarray((d * (d + 1) // 2) * FLOAT_BITS, jnp.float64)
    carry0 = (x0b, x0b, x0b, L0, gam0, A0, C0, g1_0, g2_0, beta0, up0,
              jnp.asarray(0.0, jnp.float64))
    _, ys = jax.lax.scan(step, carry0, keys)
    return ys


def bl3_fast(clients, hess_comp, model_comp, x0, x_star, steps, alpha=1.0,
             eta=1.0, p=1.0, tau=None, c=1e-8, option=2, seed=0) -> History:
    batch, _ = _stack_or_raise(clients)
    hc = _one_of(list(hess_comp), "hessian")
    mc = _one_of(list(model_comp), "model")
    tau = batch.n if tau is None else tau
    keys = jax.random.split(jax.random.PRNGKey(seed), steps)
    gaps, ups, downs = _bl3_run(
        batch, x0, _f_star(batch, x_star), keys,
        hess_comp=hc, model_comp=mc, alpha=alpha, eta=eta, p=p, tau=tau,
        c=c, option=option,
    )
    return _history(gaps, ups, downs)


# ==========================================================================
# Baselines (fast paths): GD, DIANA, Newton
# ==========================================================================
@functools.partial(jax.jit, static_argnames=("lr",))
def _gd_run(batch, x0, f_star, steps_arr, *, lr):
    d = batch.d

    def step(carry, _):
        x, up = carry
        gap = client_batch.global_loss(batch, x) - f_star
        x_n = x - lr * client_batch.global_grad(batch, x)
        return (x_n, up + d * FLOAT_BITS), (gap, up)

    carry0 = (x0, jnp.asarray(0.0, jnp.float64))
    _, ys = jax.lax.scan(step, carry0, steps_arr)
    return ys


def gd_fast(clients, x0, x_star, steps, lr: Optional[float] = None) -> History:
    from .baselines import smoothness_constant

    batch, _ = _stack_or_raise(clients)
    lr = 1.0 / smoothness_constant(clients) if lr is None else lr
    gaps, ups = _gd_run(batch, x0, _f_star(batch, x_star), jnp.arange(steps), lr=lr)
    return _history(gaps, ups, np.zeros(steps))


@functools.partial(jax.jit, static_argnames=("comp", "alpha_h", "lr"))
def _diana_run(batch, x0, f_star, keys, *, comp, alpha_h, lr):
    n, d = batch.n, batch.d

    def step(carry, key_t):
        x, h, up = carry
        gap = client_batch.global_loss(batch, x) - f_star
        gi = client_batch.grads(batch, x)
        q, bits = comp.batched(jax.random.split(key_t, n), gi - h)
        ghat = jnp.mean(h + q, axis=0)
        h_n = h + alpha_h * q
        x_n = x - lr * ghat
        return (x_n, h_n, up + jnp.mean(bits)), (gap, up)

    carry0 = (x0, jnp.zeros((n, d), x0.dtype), jnp.asarray(0.0, jnp.float64))
    _, ys = jax.lax.scan(step, carry0, keys)
    return ys


def diana_fast(clients, x0, x_star, steps, comp: Compressor, omega: float,
               lr: Optional[float] = None, seed: int = 0) -> History:
    from .baselines import smoothness_constant

    batch, _ = _stack_or_raise(clients)
    _check_supported(comp)
    L = smoothness_constant(clients)
    mu = batch.lam
    alpha_h = 1.0 / (omega + 1.0)
    n = batch.n
    if lr is None:
        lr = min(alpha_h / (2.0 * mu), 1.0 / (L * (1.0 + 6.0 * omega / n)))
    keys = jax.random.split(jax.random.PRNGKey(seed), steps)
    gaps, ups = _diana_run(batch, x0, _f_star(batch, x_star), keys,
                           comp=comp, alpha_h=alpha_h, lr=lr)
    return _history(gaps, ups, np.zeros(steps))


@functools.partial(jax.jit, static_argnames=("per_iter_bits",))
def _newton_run(batch, basisb, x0, f_star, steps_arr, *, per_iter_bits):
    lam = batch.lam

    def step(carry, _):
        x, up = carry
        gap = client_batch.global_loss(batch, x) - f_star
        if basisb is None:
            H = client_batch.global_hess(batch, x)
        else:
            coef = client_batch.hess_coeff_target(basisb, batch, x)
            H = jnp.mean(basisb.server_reconstruct(coef, lam), axis=0)
        g = client_batch.global_grad(batch, x)
        x_n = x - jnp.linalg.solve(H, g)
        return (x_n, up + per_iter_bits), (gap, up)

    carry0 = (x0, jnp.asarray(0.0, jnp.float64))
    _, ys = jax.lax.scan(step, carry0, steps_arr)
    return ys


def newton_fast(clients, x0, x_star, steps,
                bases: Optional[Sequence[MatrixBasis]] = None) -> History:
    batch, basisb = _stack_or_raise(clients, bases)
    d = batch.d
    if basisb is None:
        init_up = 0.0
        per_iter = (d * d + d) * FLOAT_BITS
    else:
        if basisb.kind != "data_outer":
            raise FastPathUnavailable("newton basis path expects DataOuterBasis")
        rs = basisb.rs
        init_up = sum(d * r * FLOAT_BITS for r in rs) / len(rs)
        per_iter = sum(r * r + r for r in rs) / len(rs) * FLOAT_BITS
    gaps, ups = _newton_run(batch, basisb, x0, _f_star(batch, x_star),
                            jnp.arange(steps), per_iter_bits=per_iter)
    return _history(gaps, np.asarray(ups) + init_up, np.zeros(steps))
