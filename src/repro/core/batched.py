"""Batched, jit-compiled execution engine for BL1/BL2/BL3 and the baselines.

Per-client state lives in leading-axis-`n` stacked arrays (`ClientBatch`,
`BatchedBasis` — see `client_batch.py`); compressors run through their
natively-batched `Compressor.compress` contract; rounds run under one
`jax.lax.scan`, so a whole optimization trajectory is a single XLA program
with zero device→host syncs until the histories come back at the end.
Communication is accounted per leg by a `comm.CommLedger` in the scan carry
(`History.legs` exposes the hess/grad/model/basis-shipment streams).

The algorithms themselves live in `repro.core.specs` as declarative method
specs (BL1/BL2/BL3/GD/DIANA/Newton/FedNL-BAG) plugged into the unified round
engine `repro.core.rounds` — this module is the configuration layer: it
validates/stacks the client fleet, builds the spec, and dispatches to the
engine on either aggregation backend (`sharded=False` → single-device
vmap reductions; `sharded=True` → clients sharded over the mesh `data`
axis via shard_map, bitwise-identical trajectories by default).

Parity contract (pinned by tests/test_batched_parity.py): with deterministic
compressors and full participation the fast path reproduces the reference
backend (`bl_reference.py`) trajectories to ~1e-8 in the gap; stochastic
configurations draw from a different PRNG stream (one split per round
instead of a serial per-client chain) and match in distribution only.

Raises `FastPathUnavailable` for configurations the stacked representation
cannot express (heterogeneous client shapes, mixed basis kinds, mixed
compressor configurations); the public `bl.bl1/bl2/bl3` dispatchers fall
back to the reference backend in that case.
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax
import numpy as np

from . import client_batch, comm, rounds, specs
from .basis import MatrixBasis
from .bl import History
from .comm import FLOAT_BITS
from .compressors import (
    BernoulliLazy,
    ComposedRankR,
    ComposedTopK,
    Compressor,
    Identity,
    NaturalCompression,
    RandK,
    RandomDithering,
    RankR,
    TopK,
)


class FastPathUnavailable(Exception):
    """This configuration cannot run batched; use the reference backend."""


_SUPPORTED = (
    Identity,
    TopK,
    RandK,
    RankR,
    RandomDithering,
    NaturalCompression,
    ComposedTopK,
    ComposedRankR,
    BernoulliLazy,
)


def _check_supported(comp: Compressor) -> None:
    if type(comp) not in _SUPPORTED:
        raise FastPathUnavailable(f"unsupported compressor {type(comp).__name__}")
    for inner in ("inner", "inner_u", "inner_v"):
        if hasattr(comp, inner):
            _check_supported(getattr(comp, inner))


def _one_of(comps: Sequence[Compressor], what: str) -> Compressor:
    """The fleet's single compressor config; raise if heterogeneous."""
    c0 = comps[0]
    _check_supported(c0)
    for c in comps[1:]:
        if type(c) is not type(c0) or c != c0:
            raise FastPathUnavailable(f"heterogeneous {what} compressors")
    return c0


def _stack_or_raise(clients, bases=None):
    batch = client_batch.from_clients(clients)
    if batch is None:
        raise FastPathUnavailable("heterogeneous client shapes / λ")
    basisb = None
    if bases is not None:
        basisb = client_batch.stack_bases(bases)
        if basisb is None:
            raise FastPathUnavailable("mixed basis kinds")
    return batch, basisb


def _history(evals, leds: comm.CommLedger) -> History:
    """History from the engine's (eval streams, per-leg ledger streams):
    `up_bits` is the ledger's uplink total (hess + grad + basis shipment)
    so the paper's x-axis is unchanged, every leg stays inspectable in
    `History.legs`, and any extra streams the spec's ``eval_streams``
    emitted besides ``"gap"`` land in `History.metrics`."""
    g = np.maximum(np.asarray(evals["gap"]), 0.0)
    legs = {name: list(map(float, np.asarray(getattr(leds, name))))
            for name in comm.CommLedger.LEGS}
    metrics = {k: list(map(float, np.asarray(v)))
               for k, v in evals.items() if k != "gap"} or None
    return History(list(map(float, g)),
                   list(map(float, np.asarray(leds.uplink))),
                   list(map(float, np.asarray(leds.model_down))),
                   legs=legs, metrics=metrics)


def _f_star(batch, x_star) -> jax.Array:
    return client_batch.global_loss(batch, x_star)


def _block_mode(basisb, comp) -> bool:
    """True when coefficient state can live in compact (n, r, r) blocks.

    Valid only for the data basis (support is exactly the top-left r×r
    block) with compressors whose output *and bit accounting* are invariant
    to dropping the padding zeros: Top-K style selection with K ≤ r².
    (Identity/RankR/dithering bill by element count or vector length of the
    padded d×d array, so they keep the full representation.)
    """
    if basisb is None or basisb.kind != "data_outer":
        return False
    rb = basisb.r_max
    if type(comp) is TopK and not comp.symmetrize and comp.k <= rb * rb:
        return True
    if type(comp) is ComposedTopK and comp.k <= rb * rb:
        return True
    return False


def _run(spec, batch, basisb, x0, x_star, steps, seed, *, sharded,
         exact=True, stream=None):
    keys = jax.random.split(jax.random.PRNGKey(seed), steps)
    evals, leds = rounds.run_rounds(
        spec, batch, basisb, x0, _f_star(batch, x_star), keys,
        sharded=sharded, exact=exact, stream=stream)
    return _history(evals, leds)


# ==========================================================================
# BL1 — Algorithm 1 (fast path)
# ==========================================================================
# Each method has a `*_setup` (validate + stack the fleet, build the frozen
# `MethodSpec` — everything static about a run) and a `*_fast` wrapper that
# adds the batch driver.  The service loop (`repro.launch.fed_serve`) reuses
# the setups with the chunked driver instead.
def bl1_setup(clients, bases, hess_comp, model_comp, alpha=1.0, eta=1.0,
              p=1.0, mu=None, init_exact_hessian=True):
    batch, basisb = _stack_or_raise(clients, bases)
    hc = _one_of(list(hess_comp), "hessian")
    _check_supported(model_comp)
    spec = specs.BL1Spec(
        hess_comp=hc, model_comp=model_comp, alpha=alpha, eta=eta, p=p,
        mu=batch.lam if mu is None else mu, init_exact=init_exact_hessian,
        grad_bits=basisb.grad_uplink_bits_mean(),
        init_hess_bits=basisb.init_coeff_bits_mean(init_exact_hessian),
        basis_bits=basisb.transmission_bits_mean(),
        block=_block_mode(basisb, hc),
    )
    return spec, batch, basisb


def bl1_fast(clients, bases, hess_comp, model_comp, x0, x_star, steps,
             alpha=1.0, eta=1.0, p=1.0, mu=None, seed=0,
             init_exact_hessian=True, sharded=False, exact=True,
             stream=None) -> History:
    spec, batch, basisb = bl1_setup(
        clients, bases, hess_comp, model_comp, alpha=alpha, eta=eta, p=p,
        mu=mu, init_exact_hessian=init_exact_hessian)
    return _run(spec, batch, basisb, x0, x_star, steps, seed, sharded=sharded,
                exact=exact, stream=stream)


# ==========================================================================
# BL2 — Algorithm 2 (fast path)
# ==========================================================================
def bl2_setup(clients, bases, hess_comp, model_comp, alpha=1.0, eta=1.0,
              p=1.0, tau=None, init_exact_hessian=True):
    batch, basisb = _stack_or_raise(clients, bases)
    hc = _one_of(list(hess_comp), "hessian")
    mc = _one_of(list(model_comp), "model")
    spec = specs.BL2Spec(
        hess_comp=hc, model_comp=mc, alpha=alpha, eta=eta, p=p,
        tau=batch.n if tau is None else tau, init_exact=init_exact_hessian,
        init_hess_bits=basisb.init_coeff_bits_mean(init_exact_hessian),
        basis_bits=basisb.transmission_bits_mean(),
        block=_block_mode(basisb, hc),
    )
    return spec, batch, basisb


def bl2_fast(clients, bases, hess_comp, model_comp, x0, x_star, steps,
             alpha=1.0, eta=1.0, p=1.0, tau=None, seed=0,
             init_exact_hessian=True, sharded=False, exact=True,
             stream=None) -> History:
    spec, batch, basisb = bl2_setup(
        clients, bases, hess_comp, model_comp, alpha=alpha, eta=eta, p=p,
        tau=tau, init_exact_hessian=init_exact_hessian)
    return _run(spec, batch, basisb, x0, x_star, steps, seed, sharded=sharded,
                exact=exact, stream=stream)


# ==========================================================================
# BL3 — Algorithm 3 (fast path, PSD basis of Example 5.1)
# ==========================================================================
def bl3_setup(clients, hess_comp, model_comp, alpha=1.0, eta=1.0, p=1.0,
              tau=None, c=1e-8, option=2):
    batch, _ = _stack_or_raise(clients)
    hc = _one_of(list(hess_comp), "hessian")
    mc = _one_of(list(model_comp), "model")
    spec = specs.BL3Spec(
        hess_comp=hc, model_comp=mc, alpha=alpha, eta=eta, p=p,
        tau=batch.n if tau is None else tau, c=c, option=option,
    )
    return spec, batch, None


def bl3_fast(clients, hess_comp, model_comp, x0, x_star, steps, alpha=1.0,
             eta=1.0, p=1.0, tau=None, c=1e-8, option=2, seed=0,
             sharded=False, exact=True, stream=None) -> History:
    spec, batch, basisb = bl3_setup(
        clients, hess_comp, model_comp, alpha=alpha, eta=eta, p=p, tau=tau,
        c=c, option=option)
    return _run(spec, batch, basisb, x0, x_star, steps, seed, sharded=sharded,
                exact=exact, stream=stream)


# ==========================================================================
# Baselines (fast paths): GD, DIANA, Newton, FedNL-BAG
# ==========================================================================
def gd_fast(clients, x0, x_star, steps, lr: Optional[float] = None,
            sharded=False) -> History:
    from .baselines import smoothness_constant

    batch, _ = _stack_or_raise(clients)
    spec = specs.GDSpec(lr=1.0 / smoothness_constant(clients) if lr is None else lr)
    return _run(spec, batch, None, x0, x_star, steps, 0, sharded=sharded)


def diana_fast(clients, x0, x_star, steps, comp: Compressor, omega: float,
               lr: Optional[float] = None, seed: int = 0,
               sharded=False) -> History:
    from .baselines import smoothness_constant

    batch, _ = _stack_or_raise(clients)
    _check_supported(comp)
    L = smoothness_constant(clients)
    mu = batch.lam
    alpha_h = 1.0 / (omega + 1.0)
    if lr is None:
        lr = min(alpha_h / (2.0 * mu), 1.0 / (L * (1.0 + 6.0 * omega / batch.n)))
    spec = specs.DianaSpec(comp=comp, alpha_h=alpha_h, lr=lr)
    return _run(spec, batch, None, x0, x_star, steps, seed, sharded=sharded)


def newton_fast(clients, x0, x_star, steps,
                bases: Optional[Sequence[MatrixBasis]] = None,
                sharded=False) -> History:
    batch, basisb = _stack_or_raise(clients, bases)
    d = batch.d
    if basisb is None:
        basis_bits = 0.0
        hess_bits = d * d * FLOAT_BITS
        grad_bits = d * FLOAT_BITS
    else:
        if basisb.kind != "data_outer":
            raise FastPathUnavailable("newton basis path expects DataOuterBasis")
        rs = basisb.rs
        basis_bits = sum(d * r * FLOAT_BITS for r in rs) / len(rs)
        hess_bits = sum(r * r for r in rs) / len(rs) * FLOAT_BITS
        grad_bits = sum(float(r) for r in rs) / len(rs) * FLOAT_BITS
    spec = specs.NewtonSpec(hess_bits=hess_bits, grad_bits=grad_bits,
                            basis_bits=basis_bits)
    return _run(spec, batch, basisb, x0, x_star, steps, 0, sharded=sharded)


def fednl_bag_setup(clients, bases, hess_comp, alpha=1.0, q=0.5, eta=None,
                    mu=None, init_exact_hessian=True):
    batch, basisb = _stack_or_raise(clients, bases)
    hc = _one_of(list(hess_comp), "hessian")
    spec = specs.FedNLBAGSpec(
        hess_comp=hc, alpha=alpha, q=q, eta=q if eta is None else eta,
        mu=batch.lam if mu is None else mu,
        init_exact=init_exact_hessian,
        init_hess_bits=basisb.init_coeff_bits_mean(init_exact_hessian),
        basis_bits=basisb.transmission_bits_mean(),
        block=_block_mode(basisb, hc),
    )
    return spec, batch, basisb


def fednl_bag_fast(clients, bases, hess_comp, x0, x_star, steps, alpha=1.0,
                   q=0.5, eta=None, mu=None, seed=0, init_exact_hessian=True,
                   sharded=False, exact=True) -> History:
    """FedNL with Bernoulli gradient aggregation — see `specs.FedNLBAGSpec`.
    eta defaults to q: damping matched to the aggregation probability."""
    spec, batch, basisb = fednl_bag_setup(
        clients, bases, hess_comp, alpha=alpha, q=q, eta=eta, mu=mu,
        init_exact_hessian=init_exact_hessian)
    return _run(spec, batch, basisb, x0, x_star, steps, seed, sharded=sharded,
                exact=exact)
