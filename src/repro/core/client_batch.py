"""Stacked per-client state for the batched BL engine (`repro.core.batched`).

The op-by-op reference backend (`repro.core.bl_reference`) keeps clients as a
Python list and loops `for i in range(n)` every round.  The fast path instead
stacks everything into leading-axis-`n` device arrays:

  * `ClientBatch`  — data `A (n, m, d)`, labels `b (n, m)`, shared ridge λ;
  * `BatchedBasis` — one *kind* of `MatrixBasis` for the whole fleet, with
    per-client `DataOuterBasis` matrices zero-padded to a common `r_max`
    (`V (n, d, r_max)`; padded columns are exactly zero, so coefficients
    beyond a client's true rank are exactly zero — identical to the reference
    padding of r×r coefficients into a d×d array).

Both are registered JAX pytrees, so they flow through `jit`/`vmap`/`scan`
untouched.  The batched GLM math below mirrors `repro.core.glm` one-to-one
(same formulas, vectorized over the client axis), which is what makes the
fast-vs-reference parity tests in `tests/test_batched_parity.py` tight.

The hot coefficient transform Γ = VᵀAV can be routed through the batched
Pallas `basis_project` kernel (`repro.kernels.ops`) by setting
``REPRO_BL_PALLAS=1`` (or compiling the kernels with
``REPRO_PALLAS_COMPILE=1`` on a real accelerator); the default on CPU is a
float64 einsum, which the parity tests rely on (the Pallas MXU path
accumulates in f32).
"""
from __future__ import annotations

import dataclasses
import os
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import glm
from .basis import (
    DataOuterBasis,
    DCTBasis,
    EigenBasis,
    MatrixBasis,
    PSDBasis,
    StandardBasis,
    SymmetricBasis,
)
from .comm import FLOAT_BITS


# --------------------------------------------------------------------------
# pytrees
# --------------------------------------------------------------------------
@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class ClientBatch:
    """All clients' GLM data stacked on a leading client axis."""

    A: jax.Array  # (n, m, d)
    b: jax.Array  # (n, m)
    lam: float    # shared ridge coefficient (static)

    def __post_init__(self):
        # runs on every pytree unflatten too (jit/scan/shard_map rebuild the
        # dataclass), so only validate when both leaves look like arrays —
        # tracers and ShapeDtypeStructs carry .shape/.ndim, placeholder
        # objects used by some tree utilities don't
        A, b = self.A, self.b
        if not (hasattr(A, "ndim") and hasattr(b, "ndim")):
            return
        if A.ndim != 3:
            raise ValueError(
                "ClientBatch.A must be client-stacked (n, m, d); got shape "
                f"{tuple(A.shape)}")
        if tuple(b.shape) != tuple(A.shape[:2]):
            raise ValueError(
                "ClientBatch.b must have shape (n, m) = A.shape[:2] = "
                f"{tuple(A.shape[:2])}; got {tuple(b.shape)} — a mis-shaped "
                "label array would silently broadcast into wrong per-client "
                "math")

    @property
    def n(self) -> int:
        return self.A.shape[0]

    @property
    def m(self) -> int:
        return self.A.shape[1]

    @property
    def d(self) -> int:
        return self.A.shape[2]

    def tree_flatten(self):
        return (self.A, self.b), (self.lam,)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(A=children[0], b=children[1], lam=aux[0])


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class TreeBatch:
    """Client-stacked batch for arbitrary-pytree workloads (BL-DNN).

    The GLM engine's `ClientBatch` fixes the data layout to (A, b, λ); deep
    networks instead carry whatever pytree their loss consumes.  `data` is
    that pytree with every leaf stacked on a leading n_clients axis — the
    round engine shards it over `CLIENT_AXIS` exactly like `ClientBatch`
    (the shard_map in_spec is a per-leaf P(CLIENT_AXIS) prefix), and specs
    see the local (n_local, ...) slice.  `n_clients` is static so the
    driver can size reducers and meshes without touching device values.
    """

    data: object          # pytree; every leaf (n_clients, ...)
    n_clients: int        # static

    def __post_init__(self):
        # validate MUTUAL agreement of the stacked leaves' leading axis, not
        # agreement with the static n_clients: inside shard_map the leaves
        # are the (n_local, ...) shard while n_clients stays global, so a
        # check against n_clients would reject every sharded unflatten
        shaped = [leaf for leaf in jax.tree_util.tree_leaves(self.data)
                  if hasattr(leaf, "ndim")]
        if not shaped:
            return
        bad = [tuple(leaf.shape) for leaf in shaped if leaf.ndim < 1]
        if bad:
            raise ValueError(
                f"every TreeBatch leaf needs a leading client axis; got "
                f"scalar leaf shape(s) {bad}")
        leads = {leaf.shape[0] for leaf in shaped}
        if len(leads) > 1:
            raise ValueError(
                "TreeBatch leaves disagree on the leading client axis: got "
                f"sizes {sorted(leads, key=str)} across leaf shapes "
                f"{[tuple(leaf.shape) for leaf in shaped]}")

    @property
    def n(self) -> int:
        return self.n_clients

    def tree_flatten(self):
        return (self.data,), (self.n_clients,)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(data=children[0], n_clients=aux[0])


def tree_batch(data, n_clients: Optional[int] = None) -> TreeBatch:
    """Build a `TreeBatch`, validating the shared leading client axis."""
    leaves = jax.tree_util.tree_leaves(data)
    if not leaves:
        raise ValueError("TreeBatch needs at least one data leaf")
    n = leaves[0].shape[0] if n_clients is None else n_clients
    for leaf in leaves:
        if leaf.ndim < 1 or leaf.shape[0] != n:
            raise ValueError(
                f"every TreeBatch leaf needs a leading n_clients={n} axis; "
                f"got shape {leaf.shape}")
    return TreeBatch(data=data, n_clients=int(n))


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class BatchedBasis:
    """A fleet-wide basis: one basis *kind*, per-client parameters stacked.

    kind ∈ {"standard", "symmetric", "psd", "data_outer", "eigen", "dct"}.
    For "data_outer", `V` is (n, d, r_max) with orthonormal columns up to
    each client's true rank and exact-zero padding beyond; `rs` keeps the
    true per-client ranks for bit accounting (the wire cost depends on r_i,
    not r_max).  For the rotation kinds ("eigen", "dct") every client uses
    the SAME orthogonal rotation (the eigenbasis of ∇²f(x⁰) is global by
    construction, the DCT is a convention) — `Q` is stored client-stacked
    (n, d, d) anyway so it shards over the client mesh exactly like `V`
    (the engine's shard_map in_specs are a per-leaf P(CLIENT_AXIS) prefix).
    """

    kind: str                   # static
    d: int                      # static
    rs: Tuple[int, ...]         # static: per-client ranks (d for non-data bases)
    V: Optional[jax.Array] = None  # (n, d, r_max) for kind == "data_outer"
    Q: Optional[jax.Array] = None  # (n, d, d) stacked rotation for eigen/dct

    @property
    def r_max(self) -> int:
        return max(self.rs)

    def tree_flatten(self):
        return (self.V, self.Q), (self.kind, self.d, self.rs)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(kind=aux[0], d=aux[1], rs=aux[2], V=children[0],
                   Q=children[1])

    # ---- bit accounting (host-side floats, no device sync) ----------------
    def grad_uplink_bits_mean(self) -> float:
        """Per-client gradient uplink cost, averaged over the fleet (§2.3:
        r_i coefficients for data bases, d floats otherwise)."""
        if self.kind == "data_outer":
            return sum(r * FLOAT_BITS for r in self.rs) / len(self.rs)
        return self.d * FLOAT_BITS

    def transmission_bits_mean(self) -> float:
        """One-time basis shipping cost averaged over clients (Table 1:
        rd floats for the data basis, d² for the learned eigenbasis; the
        convention bases — standard/symmetric/psd/dct — are free)."""
        if self.kind == "data_outer":
            return sum(self.d * r * FLOAT_BITS for r in self.rs) / len(self.rs)
        if self.kind == "eigen":
            return float(self.d * self.d * FLOAT_BITS)
        return 0.0

    def coeff_count_mean(self) -> float:
        if self.kind == "data_outer":
            return sum(r * r for r in self.rs) / len(self.rs)
        if self.kind in ("symmetric", "psd"):
            return self.d * (self.d + 1) / 2
        return self.d * self.d

    def init_coeff_bits_mean(self, init_exact: bool) -> float:
        """Bits for shipping the exact initial coefficients (hess-up leg);
        the one-time basis shipment is billed separately by the ledger."""
        return self.coeff_count_mean() * FLOAT_BITS if init_exact else 0.0

    # ---- coefficient transforms (batched h / reconstruct) -----------------
    def h(self, A: jax.Array) -> jax.Array:
        """Batched coefficient matrices: A (n, d, d) → (n, d, d)."""
        if self.kind == "standard":
            return A
        if self.kind == "symmetric":
            return jnp.tril(A)
        if self.kind == "psd":
            off = jnp.tril(A, -1)
            diag_v = jnp.diagonal(A, axis1=-2, axis2=-1)
            rowsum = jnp.sum(A, axis=-1) - diag_v
            eye = jnp.eye(self.d, dtype=A.dtype)
            return off + eye * (diag_v - rowsum)[..., :, None]
        if self.kind in ("eigen", "dct"):
            return jnp.einsum("ndr,nde,nes->nrs", self.Q, A, self.Q)
        gamma = _basis_project(self.V, A)            # (n, r_max, r_max)
        out = jnp.zeros(A.shape, A.dtype)
        return out.at[:, : self.r_max, : self.r_max].set(gamma)

    def reconstruct(self, H: jax.Array) -> jax.Array:
        """Batched Σ_{jl} H_{jl} B^{jl}: H (n, d, d) → (n, d, d)."""
        if self.kind == "standard":
            return H
        if self.kind == "symmetric":
            return jnp.tril(H) + jnp.transpose(jnp.tril(H, -1), (0, 2, 1))
        if self.kind == "psd":
            off = jnp.tril(H, -1)
            sym_off = off + jnp.transpose(off, (0, 2, 1))
            contrib = jnp.sum(sym_off, axis=-1)
            diag_v = jnp.diagonal(H, axis1=-2, axis2=-1) + contrib
            eye = jnp.eye(self.d, dtype=H.dtype)
            return sym_off + eye * diag_v[..., :, None]
        if self.kind in ("eigen", "dct"):
            return jnp.einsum("ndr,nrs,nes->nde", self.Q, H, self.Q)
        gamma = H[:, : self.r_max, : self.r_max]
        return jnp.einsum("ndr,nrs,nes->nde", self.V, gamma, self.V)

    def server_reconstruct(self, H: jax.Array, lam: float) -> jax.Array:
        """Reconstruct + analytic λI ridge for data bases (as the server does).
        Rotation/convention bases encode the FULL Hessian — no ridge."""
        out = self.reconstruct(H)
        if self.kind == "data_outer":
            out = out + lam * jnp.eye(self.d, dtype=out.dtype)
        return out


def _basis_project(V: jax.Array, A: jax.Array) -> jax.Array:
    """Γ = VᵀAV batched over clients: (n,d,r),(n,d,d) → (n,r,r).

    Routed through the Pallas `basis_project` kernel when REPRO_BL_PALLAS=1
    (accelerator deployments); einsum in float64 otherwise.
    """
    if os.environ.get("REPRO_BL_PALLAS", "0") == "1":
        from repro.kernels import ops

        return ops.basis_project(V, A)
    return jnp.einsum("ndr,nde,nes->nrs", V, A, V)


# --------------------------------------------------------------------------
# builders
# --------------------------------------------------------------------------
def from_clients(clients: Sequence[glm.ClientData]) -> Optional[ClientBatch]:
    """Stack a homogeneous client list; None if shapes/λ differ (fall back)."""
    clients = list(clients)
    if not clients:
        return None
    shape = clients[0].A.shape
    lam = clients[0].lam
    for c in clients:
        if c.A.shape != shape or c.b.shape != (shape[0],) or c.lam != lam:
            return None
    return ClientBatch(
        A=jnp.stack([c.A for c in clients]),
        b=jnp.stack([c.b for c in clients]),
        lam=lam,
    )


def stack_bases(bases: Sequence[MatrixBasis]) -> Optional[BatchedBasis]:
    """Stack a homogeneous-kind basis list; None if mixed kinds (fall back)."""
    bases = list(bases)
    if not bases:
        return None
    b0 = bases[0]
    for cls, kind in ((StandardBasis, "standard"), (SymmetricBasis, "symmetric"),
                      (PSDBasis, "psd")):
        if all(type(b) is cls for b in bases):
            if any(b.d != b0.d for b in bases):
                return None
            return BatchedBasis(kind=kind, d=b0.d, rs=tuple(b.d for b in bases))
    if all(type(b) is DCTBasis for b in bases):
        if any(b.d != b0.d for b in bases):
            return None
        return BatchedBasis(kind="dct", d=b0.d, rs=tuple(b.d for b in bases),
                            Q=jnp.stack([b.Q for b in bases]))
    if all(type(b) is EigenBasis for b in bases):
        # the eigenbasis is global by construction — require one shared Q
        # (heterogeneous rotations fall back to the reference loops)
        same = all(b.Q is b0.Q or np.array_equal(np.asarray(b.Q),
                                                 np.asarray(b0.Q))
                   for b in bases[1:])
        if any(b.d != b0.d for b in bases) or not same:
            return None
        return BatchedBasis(kind="eigen", d=b0.d, rs=tuple(b.d for b in bases),
                            Q=jnp.stack([b.Q for b in bases]))
    if all(type(b) is DataOuterBasis for b in bases):
        if any(b.d != b0.d for b in bases):
            return None
        rs = tuple(b.r for b in bases)
        r_max = max(rs)
        V = jnp.stack(
            [
                jnp.pad(b.V, ((0, 0), (0, r_max - b.r)))  # zero cols beyond r_i
                for b in bases
            ]
        )
        return BatchedBasis(kind="data_outer", d=b0.d, rs=rs, V=V)
    return None


# --------------------------------------------------------------------------
# host-resident client store (cohort streaming)
# --------------------------------------------------------------------------
@dataclasses.dataclass
class ClientStore:
    """The full fleet's data and per-client carry state, host-resident.

    The stacked engine puts all n clients on device, which bounds n by HBM
    (fig1-xl tops out at 512 clients).  The cohort-streaming engine
    (`repro.core.cohort`) instead keeps the fleet here — numpy arrays in
    host RAM — and per epoch gathers only the sampled cohort's rows onto
    the device.  `state` holds the client-stacked carry leaves (shifts
    z_i/w_i, Hessian estimates, ...) between the rounds a client is
    sampled; per Alg. 2–3 an absent client's state stays frozen, which is
    exactly what "rows not gathered this epoch don't move" gives us.

    NOT a pytree on purpose: the store never crosses the jit boundary —
    only gathered cohorts do.
    """

    A: np.ndarray             # (n, m, d) float64, host
    b: np.ndarray             # (n, m) float64, host
    lam: float
    state: dict = dataclasses.field(default_factory=dict)  # name -> (n, ...)

    @property
    def n(self) -> int:
        return self.A.shape[0]

    @property
    def m(self) -> int:
        return self.A.shape[1]

    @property
    def d(self) -> int:
        return self.A.shape[2]

    # ---- data plane -------------------------------------------------------
    def gather_data(self, idx: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        """Host-side cohort gather: (A[idx], b[idx]) as fresh numpy arrays.
        Split from `gather_batch` so the prefetch thread can do the O(c·m·d)
        copy (and the H2D transfer) off the critical path."""
        return self.A[idx], self.b[idx]

    def gather_batch(self, idx: np.ndarray) -> ClientBatch:
        """Materialize the cohort's `ClientBatch` on device."""
        A, b = self.gather_data(idx)
        return ClientBatch(A=jnp.asarray(A), b=jnp.asarray(b), lam=self.lam)

    # ---- state plane ------------------------------------------------------
    def gather_state(self, idx: np.ndarray) -> dict:
        """Cohort rows of every carry leaf (fresh arrays, safe to mutate)."""
        return {name: leaf[idx] for name, leaf in self.state.items()}

    def scatter_state(self, idx: np.ndarray, updates: dict) -> None:
        """Write a cohort's updated carry rows back into the fleet store."""
        for name, rows in updates.items():
            self.state[name][idx] = rows

    def state_sums(self, names: Sequence[str]) -> dict:
        """Float64 fleet-wide sums of the named leaves (O(n), used once at
        init to seed the incrementally-maintained aggregate totals)."""
        return {name: np.sum(np.asarray(self.state[name], np.float64), axis=0)
                for name in names}


def synthetic_store(seed: int, n_clients: int, m: int, d: int,
                    lam: float = 1e-3, noise: float = 0.1) -> ClientStore:
    """Vectorized synthetic logistic-regression fleet for the streaming
    engine — same planted-model-with-flip-noise label scheme as
    `glm.make_synthetic`, but built in one shot with no per-client Python
    loop (the stacked builder's per-client QR is infeasible at n ≥ 100k).
    Rows are full-rank (the stream path runs the standard basis, so §2.3's
    low-rank row structure buys nothing here)."""
    rng = np.random.default_rng(seed)
    x_true = rng.standard_normal(d) / np.sqrt(d)
    A = rng.standard_normal((n_clients, m, d)) / np.sqrt(d)
    logits = A @ x_true
    p = 1.0 / (1.0 + np.exp(-logits))
    b = np.where(rng.random((n_clients, m)) < (1 - noise) * p + noise * 0.5,
                 1.0, -1.0)
    return ClientStore(A=np.asarray(A, np.float64),
                       b=np.asarray(b, np.float64), lam=lam)


# --------------------------------------------------------------------------
# batched GLM math (mirrors repro.core.glm, vectorized over clients)
# --------------------------------------------------------------------------
def bmv(M: jax.Array, v: jax.Array) -> jax.Array:
    """Per-client matvec (n, k, e) @ (n, e) → (n, k) as multiply+reduce
    (rank-3 M only — the broadcast inserts exactly one middle axis).

    `jnp.einsum("n...e,ne->n...")` lowers to a batched dot whose accumulation
    order depends on the leading batch size, so per-client results differ in
    the last ulp between a 1-client shard and an n-client stack — breaking
    the sharded aggregation backend's bitwise-parity contract
    (tests/test_sharding_multidev.py).  The multiply+last-axis-reduce form is
    batch-size invariant and cheap next to the engine's matrix-matrix
    contractions (which XLA compiles batch-invariantly already)."""
    return jnp.sum(M * v[:, None, :], axis=-1)


def _per_client_x(batch: ClientBatch, x: jax.Array) -> jax.Array:
    """Broadcast a shared iterate (d,) to (n, d); pass (n, d) through."""
    if x.ndim == 1:
        return jnp.broadcast_to(x, (batch.n, batch.d))
    return x


def losses(batch: ClientBatch, x: jax.Array) -> jax.Array:
    xb = _per_client_x(batch, x)
    z = bmv(batch.A, xb) * batch.b
    data = jnp.mean(jnp.logaddexp(0.0, -z), axis=1)
    return data + 0.5 * batch.lam * jnp.sum(xb * xb, axis=1)


def global_loss(batch: ClientBatch, x: jax.Array) -> jax.Array:
    return jnp.mean(losses(batch, x))


def grads(batch: ClientBatch, x: jax.Array) -> jax.Array:
    """Per-client gradients (n, d) at a shared or per-client iterate."""
    xb = _per_client_x(batch, x)
    z = bmv(batch.A, xb) * batch.b
    coef = -batch.b * glm.sigmoid(-z)
    return jnp.einsum("nmd,nm->nd", batch.A, coef) / batch.m + batch.lam * xb


def global_grad(batch: ClientBatch, x: jax.Array) -> jax.Array:
    return jnp.mean(grads(batch, x), axis=0)


def hess_weights(batch: ClientBatch, x: jax.Array) -> jax.Array:
    xb = _per_client_x(batch, x)
    z = bmv(batch.A, xb) * batch.b
    s = glm.sigmoid(z)
    return s * (1.0 - s)


def hess_data_part(batch: ClientBatch, x: jax.Array) -> jax.Array:
    """Per-client data-part Hessians (n, d, d) — no λI term (§2.3)."""
    w = hess_weights(batch, x)
    return jnp.einsum("nmd,nm,nme->nde", batch.A, w, batch.A) / batch.m


def hess(batch: ClientBatch, x: jax.Array) -> jax.Array:
    """Per-client full Hessians (n, d, d)."""
    H = hess_data_part(batch, x)
    return H + batch.lam * jnp.eye(batch.d, dtype=H.dtype)


def global_hess(batch: ClientBatch, x: jax.Array) -> jax.Array:
    return jnp.mean(hess(batch, x), axis=0)


def global_hess_fused(batch: ClientBatch, x: jax.Array) -> jax.Array:
    """Global Hessian ∇²f(x) = mean_i ∇²f_i(x) WITHOUT the (n, d, d)
    per-client intermediate: one (n·m, d)-shaped weighted Gram contraction.

    At `repro.exp`'s fig1-xl scale (n=512, d=1200) the stacked per-client
    Hessians alone are ~5.9 GB f64; this form never materializes them.
    Accumulation order differs from `global_hess` (contract over n·m at
    once vs per-client then mean), so results agree to f64 roundoff, not
    bitwise — use it for solver/reference-optimum work, not inside the
    parity-pinned round engine."""
    w = hess_weights(batch, x)                      # (n, m)
    Aw = batch.A * w[..., None]                     # (n, m, d)
    H = jnp.einsum("nmd,nme->de", Aw, batch.A) / (batch.n * batch.m)
    return H + batch.lam * jnp.eye(batch.d, dtype=H.dtype)


def newton_solve_fused(batch: ClientBatch, x0: jax.Array,
                       iters: int = 20) -> jax.Array:
    """Reference optimum x* by full Newton on the stacked fleet, using the
    low-memory `global_hess_fused` contraction each iteration.

    The scale-friendly analogue of `glm.newton_solve` (which loops clients
    in Python and stacks (n, d, d) Hessians) — same algorithm, fused math.
    """
    @jax.jit
    def one(x):
        g = global_grad(batch, x)
        H = global_hess_fused(batch, x)
        return x - jnp.linalg.solve(H, g)

    x = x0
    for _ in range(iters):
        x = one(x)
    return x


def hess_coeff_target(basisb: BatchedBasis, batch: ClientBatch, x: jax.Array) -> jax.Array:
    """Batched h^i(∇²f_i): data bases see only the data part (ridge is added
    analytically server-side), dense bases see the full Hessian — exactly
    `bl._client_hcoef` vectorized."""
    if basisb.kind == "data_outer":
        return basisb.h(hess_data_part(batch, x))
    return basisb.h(hess(batch, x))


# --------------------------------------------------------------------------
# r-dim coordinate-space fast path (§2.3): never materialize the d×d Hessian
# --------------------------------------------------------------------------
def basis_AV(basisb: BatchedBasis, batch: ClientBatch) -> jax.Array:
    """Per-client data matrices pre-rotated into the basis: (n, m, r_max).

    Computed once per run; with it the coefficient target collapses to an
    r-dim quadratic form (`hess_coeff_block`)."""
    return jnp.einsum("nmd,ndr->nmr", batch.A, basisb.V)


def hess_coeff_block(basisb: BatchedBasis, batch: ClientBatch, x: jax.Array,
                     AV: jax.Array) -> jax.Array:
    """Γ_i = Vᵢᵀ(∇²f_i^data)Vᵢ = (AᵢVᵢ)ᵀ Dᵢ (AᵢVᵢ)/m, natively (n, r, r).

    Same math as `hess_coeff_target` for the data basis, but O(n·m·r²)
    instead of O(n·m·d²) and no (n, d, d) intermediate — the batched
    engine's block mode keeps coefficient state in this compact form."""
    w = hess_weights(batch, x)
    return jnp.einsum("nmr,nm,nms->nrs", AV, w, AV) / batch.m


def reconstruct_block(basisb: BatchedBasis, G: jax.Array) -> jax.Array:
    """(n, r, r) block coefficients → (n, d, d) data-part Hessians."""
    return jnp.einsum("ndr,nrs,nes->nde", basisb.V, G, basisb.V)
