"""Paper-faithful Basis Learn library (the paper's primary contribution).

The paper's reference experiments run in float64 (NumPy/SciPy); superlinear
convergence demonstrations need it too, so importing `repro.core` enables
jax_enable_x64.  Model/framework code (repro.models, repro.launch, ...) never
imports this package and always passes explicit dtypes, so the flag is inert
there even when both are imported in one pytest process.
"""
import jax

jax.config.update("jax_enable_x64", True)

from . import basis, baselines, bl, compressors, glm  # noqa: E402,F401
from . import batched, bl_reference, client_batch  # noqa: E402,F401
from . import rounds, specs  # noqa: E402,F401
