"""Unified round engine: combinators + pluggable client-sharded aggregation.

Every method in this repo — BL1/BL2/BL3 (Algorithms 1–3), the FedNL family
they extend, and the first/second-order baselines — shares one round
skeleton: local Hessian/gradient compute → compressed-difference uplink →
server aggregate → (compressed) downlink.  This module factors that skeleton
into three pieces:

  1. **Combinators** — the shared round steps as small pure functions over
     client-stacked arrays: the compressed-shift recursion L ← L + αC(·−L)
     (`shift_update`; `tree_shift_update` maps it over parameter *pytrees*
     for the BL-DNN coefficient layout, per-leaf aux records summed into
     one ledger leg), Bernoulli participation with the force-one-client
     fallback (`participation`), the ξ gradient-refresh mask (`xi_mask`),
     the compressed model-stream downlink (`downlink_broadcast`), and the
     §2.3 coefficient layouts (`coeff_layout` — compact (n, r, r) blocks
     vs. full d×d) behind one (target_at, recon, ridge) interface.

  2. **Reducers** — the aggregation-backend axis.  All cross-client
     reductions (means/sums/maxes of Hessians, gradients, bit counts) go
     through a `Reducer` so the same method spec runs on two backends:

       * `VmapReducer`      — one device; the client axis is a plain leading
         array axis and reductions are `jnp.mean/sum/max(axis=0)`.
       * `ShardMapReducer`  — clients sharded over the mesh `data` axis
         inside `shard_map`; per-client state carries a leading local axis.
         `exact=True` (default) reduces by `all_gather` + the *identical*
         local reduction, which is bitwise-equal to the single-device
         backend (pinned by tests/test_sharding_multidev.py); `exact=False`
         reduces per the method's `ReducePlan` (`lax.psum/pmean/pmax` of
         locally pre-reduced partials), which is bandwidth-optimal but can
         differ in the last ulp (summation order).

     Specs batch a round's uplink legs through `Reducer.reduce_tree` (one
     collective per dtype instead of one per leg) and run server-only math
     — eigendecompositions, Newton solves — under `Reducer.once` (computed
     on shard 0 and broadcast by gather-and-select instead of replicated
     on every shard).  Both are bitwise-neutral restructurings; together
     they are what closed the sharded-vs-fast per-round gap.

  3. **Drivers** — jitted `lax.scan`s over rounds.  A `MethodSpec` (see
     `repro.core.specs`) supplies `prepare/init/step`; the drivers never
     know which algorithm they are running.  ONE chunked scan program
     underlies both entry points — the carry is an explicit, DONATED
     input/output and per-round PRNG keys are explicit scan inputs:

       * `run_rounds`  — the batch driver (figure path): feeds its
         pre-split key array through one chunk (or one chunk per
         `StreamHook.every` rounds, emitting progress at chunk boundaries
         from the host — which is why streaming works on both backends).
       * `run_chunk` / `init_serve_carry` — the *service-loop* driver:
         rounds run in bounded chunks so control returns to the host
         between chunks (fault injection, checkpointing — see
         `repro.launch.fed_serve`).  Per-round keys are
         ``fold_in(root_key, t)`` of the absolute round index, so a
         trajectory is invariant to how rounds are batched into chunks —
         the crash-safe bit-exact-resume contract.

     The sharded backend wraps the same scan bodies in a single `shard_map`
     over the client mesh, so a whole sharded trajectory (or chunk) is
     still one SPMD program.  The carry itself crosses the shard_map
     boundary; `carry_client_flags` derives which carry leaves are
     client-stacked (the carry serialization contract — see
     `init_serve_carry`).
"""
from __future__ import annotations

import collections
import dataclasses
import functools
from typing import Callable, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.sharding.rules import CLIENT_AXIS

from . import client_batch, comm, progcache


# ==========================================================================
# Reducers — the pluggable aggregation backend
# ==========================================================================
#: collective modes a `ReducePlan` can assign to an uplink payload class
_PLAN_MODES = ("gather", "psum", "pmean")
#: ops `Reducer.reduce_tree` understands, per leaf
_REDUCE_OPS = ("mean", "sum", "max")


@dataclasses.dataclass(frozen=True)
class ReducePlan:
    """Per-method collective-mode selection for the sharded reducer.

    Active only when ``ShardMapReducer(exact=False)``: each uplink leaf is
    classified by its payload rank (leaf shape minus the client axis —
    0 → ``scalar``, 1 → ``vector``, ≥2 → ``dense``) and reduced with the
    mode that class names:

      * ``"psum"``   — local pre-reduce + `lax.psum` in the mesh's fixed
        tree order (bandwidth-optimal; last-ulp summation-order drift);
      * ``"pmean"``  — local pre-mean + `lax.pmean` (same wire cost as
        psum; keeps magnitudes O(1) for f32 payloads);
      * ``"gather"`` — the exact-mode dataflow for just that class
        (all_gather + the identical local reduction, bitwise).

    ``exact=True`` ignores the mode fields — every leg gathers, which is
    what the cross-backend bitwise contract pins.  ``server_once`` gates
    `Reducer.once` (compute server-only math on shard 0, broadcast);
    ``fuse_uplink`` gates packing same-collective/same-dtype legs into one
    collective in `Reducer.reduce_tree`.  Both are bitwise-neutral — they
    are escape hatches for debugging, not parity knobs.

    Specs attach a plan as the ``MethodSpec.reduce_plan`` class attribute;
    the engine copies it onto the `ShardMapReducer` it builds."""

    dense: str = "psum"
    vector: str = "psum"
    scalar: str = "psum"
    server_once: bool = True
    fuse_uplink: bool = True

    def __post_init__(self):
        for f in ("dense", "vector", "scalar"):
            if getattr(self, f) not in _PLAN_MODES:
                raise ValueError(
                    f"ReducePlan.{f} must be one of {_PLAN_MODES}, "
                    f"got {getattr(self, f)!r}")

    def mode_for(self, payload_ndim: int) -> str:
        if payload_ndim == 0:
            return self.scalar
        if payload_ndim == 1:
            return self.vector
        return self.dense


@dataclasses.dataclass(frozen=True)
class Reducer:
    """Cross-client reduction interface.  `n` is the GLOBAL client count;
    per-client arrays seen by spec code always carry a leading `n_local`
    axis (== n on the vmap backend, n/ndev inside each shard otherwise)."""

    n: int

    @property
    def n_local(self) -> int:
        raise NotImplementedError

    @property
    def n_total(self) -> int:
        """The FLEET size — the denominator for per-node bit accounting.

        Equal to `n` on the stacked backends (every client is materialized),
        but under cohort streaming (`CohortReducer`) `n` is the cohort
        capacity while `n_total` stays the global client count: per-node
        costs are amortized over the whole fleet, not the sampled cohort."""
        return self.n

    def mean(self, x: jax.Array) -> jax.Array:
        """(n_local, ...) → (...): mean over the global client axis."""
        raise NotImplementedError

    def sum(self, x: jax.Array) -> jax.Array:
        raise NotImplementedError

    def max(self, x: jax.Array) -> jax.Array:
        raise NotImplementedError

    def shard(self, x: jax.Array) -> jax.Array:
        """Slice a replicated (n, ...) array down to this shard's clients.

        Fleet-wide randomness (participation masks, per-client PRNG keys)
        is always drawn for all n clients from the replicated key and then
        sharded, so every backend sees the same per-client draws."""
        raise NotImplementedError

    def client_keys(self, key: jax.Array) -> jax.Array:
        """Per-client PRNG keys for this shard: (n_local, 2)."""
        return self.shard(jax.random.split(key, self.n))

    def reduce_tree(self, tree, ops="mean"):
        """Reduce a whole uplink pytree across the fleet in one shot.

        ``ops`` is ``"mean" | "sum" | "max"`` applied to every leaf, or a
        matching pytree of those strings (one op per leaf).  Semantically
        identical to per-leaf `mean`/`sum`/`max` calls — bitwise so on the
        single-device backend and on the exact sharded backend — but the
        sharded reducer packs all leaves of the same (collective, dtype)
        group into ONE collective instead of one per leaf, which is where
        the per-round collective count collapses (see `ShardMapReducer`)."""
        ops_tree = (jax.tree.map(lambda _: ops, tree)
                    if isinstance(ops, str) else ops)

        def red(x, op):
            if op not in _REDUCE_OPS:
                raise ValueError(
                    f"reduce_tree op must be one of {_REDUCE_OPS}, got {op!r}")
            return getattr(self, op)(x)

        return jax.tree.map(red, tree, ops_tree)

    def tree_mean(self, tree):
        """`mean` mapped over a pytree of (n_local, ...) leaves — the
        cross-client reduction for pytree coefficient streams (BL-DNN)."""
        return self.reduce_tree(tree, "mean")

    def tree_mean_presummed(self, tree, local_sums):
        """Fleet mean of client-stacked leaves given precomputed LOCAL
        client-axis sums (`local_sums`, payload-shaped — the extra output
        of a fused compress-then-reduce codec, see
        `repro.core.compressors.Compressor.compress_sum`).

        Backends that reduce exactly ignore ``local_sums`` and reduce
        ``tree`` itself (bitwise-identical to `tree_mean`); the
        bandwidth-optimal sharded path (``exact=False``) psums only the
        pre-summed compressed payloads — the collective moves one
        payload-sized tensor per dtype instead of the dense client stack."""
        del local_sums
        return self.tree_mean(tree)

    def once(self, f: Callable, *args):
        """Run server-only math ``f(*args)`` once per fleet.

        On the single-device backend this is a plain call.  The sharded
        backend computes ``f`` on shard 0 only (the other shards' cores sit
        out instead of replicating the same eigendecomposition/solve ndev
        times) and broadcasts the result by gather-and-select — pure data
        movement, so the value every shard sees is bitwise the value the
        replicated computation would have produced.  ``f`` must be
        collective-free (inputs already reduced/replicated)."""
        return f(*args)


@dataclasses.dataclass(frozen=True)
class VmapReducer(Reducer):
    """Single-device backend: the client axis is a plain leading axis."""

    @property
    def n_local(self) -> int:
        return self.n

    def mean(self, x):
        return jnp.mean(x, axis=0)

    def sum(self, x):
        return jnp.sum(x, axis=0)

    def max(self, x):
        return jnp.max(x, axis=0)

    def shard(self, x):
        return x


#: per-op local reduction over a gathered (n, ...) stack — the SAME ops
#: `VmapReducer` applies, which is what makes the exact path bitwise
_LOCAL_REDUCE = {
    "mean": lambda g: jnp.mean(g, axis=0),
    "sum": lambda g: jnp.sum(g, axis=0),
    "max": lambda g: jnp.max(g, axis=0),
}


@dataclasses.dataclass(frozen=True)
class ShardMapReducer(Reducer):
    """Mesh backend: clients sharded over `axis` inside `shard_map`.

    exact=True reduces by `all_gather` + the same local reduction as
    `VmapReducer` — bitwise-identical trajectories to the single-device
    fast path; `reduce_tree` packs every leaf of a dtype into ONE tiled
    gather (reshape/concat/split are pure data movement, so fusion is
    bitwise-neutral).  exact=False reduces per the method's `ReducePlan`
    (`lax.psum`/`pmean`/`pmax` of locally pre-reduced partials — less wire
    traffic, last-ulp summation-order differences)."""

    ndev: int = 1
    axis: str = CLIENT_AXIS
    exact: bool = True
    plan: ReducePlan = ReducePlan()

    @property
    def n_local(self) -> int:
        return self.n // self.ndev

    def _gather(self, x):
        return jax.lax.all_gather(x, self.axis, axis=0, tiled=True)

    def mean(self, x):
        return self.reduce_tree(x, "mean")

    def sum(self, x):
        return self.reduce_tree(x, "sum")

    def max(self, x):
        return self.reduce_tree(x, "max")

    def shard(self, x):
        i = jax.lax.axis_index(self.axis)
        return jax.lax.dynamic_slice_in_dim(x, i * self.n_local, self.n_local, 0)

    # -------------------------------------------------- fused collectives
    def _gather_leaves(self, leaves):
        """All-gather a list of (n_local, ...) leaves as one tiled gather
        per dtype, returning the (n, ...) global stacks leaf-by-leaf.
        Reshape → concat → gather → split → reshape moves bits without
        arithmetic, so each returned stack is bitwise the stack a per-leaf
        `_gather` would have produced."""
        out = [None] * len(leaves)
        if not self.plan.fuse_uplink:
            for i, l in enumerate(leaves):
                out[i] = self._gather(l)
            return out
        by_dtype = {}
        for i, l in enumerate(leaves):
            by_dtype.setdefault(l.dtype, []).append(i)
        for idxs in by_dtype.values():
            flats = [leaves[i].reshape(self.n_local, -1) for i in idxs]
            widths = [f.shape[1] for f in flats]
            cat = flats[0] if len(flats) == 1 else jnp.concatenate(flats, axis=1)
            g = self._gather(cat)
            off = 0
            for i, w in zip(idxs, widths):
                out[i] = g[:, off:off + w].reshape(
                    (self.n,) + leaves[i].shape[1:])
                off += w
        return out

    def _fused_psum_like(self, entries):
        """One `psum`/`pmean` per (collective, dtype) group over a list of
        ``(index, collective, local_payload)`` entries; returns
        {index: reduced_payload}."""
        out = {}
        groups = {}
        for i, coll, v in entries:
            key = ((coll, v.dtype) if self.plan.fuse_uplink
                   else (coll, v.dtype, i))
            groups.setdefault(key, []).append((i, v))
        for key, items in groups.items():
            coll = key[0]
            flats = [v.reshape(-1) for _, v in items]
            cat = flats[0] if len(flats) == 1 else jnp.concatenate(flats)
            red = (jax.lax.pmean(cat, self.axis) if coll == "pmean"
                   else jax.lax.psum(cat, self.axis))
            off = 0
            for (i, v), f in zip(items, flats):
                out[i] = red[off:off + f.shape[0]].reshape(v.shape)
                off += f.shape[0]
        return out

    def reduce_tree(self, tree, ops="mean"):
        leaves, treedef = jax.tree_util.tree_flatten(tree)
        op_list = ([ops] * len(leaves) if isinstance(ops, str)
                   else treedef.flatten_up_to(ops))
        for op in op_list:
            if op not in _REDUCE_OPS:
                raise ValueError(
                    f"reduce_tree op must be one of {_REDUCE_OPS}, got {op!r}")
        out = [None] * len(leaves)
        if self.exact:
            gathered = self._gather_leaves(leaves)
            for i, (op, g) in enumerate(zip(op_list, gathered)):
                out[i] = _LOCAL_REDUCE[op](g)
            return treedef.unflatten(out)
        entries, colls = [], {}
        for i, (l, op) in enumerate(zip(leaves, op_list)):
            if op == "max":
                out[i] = jax.lax.pmax(jnp.max(l, axis=0), self.axis)
                continue
            mode = self.plan.mode_for(l.ndim - 1)
            if mode == "gather":
                out[i] = _LOCAL_REDUCE[op](self._gather(l))
                continue
            # pmean of equal-sized local means IS the global mean; sums (and
            # means under a psum-mode plan) go up as local sums
            colls[i] = "pmean" if (mode == "pmean" and op == "mean") else "psum"
            loc = jnp.mean(l, axis=0) if colls[i] == "pmean" else jnp.sum(l, axis=0)
            entries.append((i, colls[i], loc))
        for i, red in self._fused_psum_like(entries).items():
            if colls[i] == "psum" and op_list[i] == "mean":
                red = red / self.n
            out[i] = red
        return treedef.unflatten(out)

    def tree_mean_presummed(self, tree, local_sums):
        if self.exact:
            return self.reduce_tree(tree, "mean")
        leaves, treedef = jax.tree_util.tree_flatten(local_sums)
        entries = []
        for i, s in enumerate(leaves):
            if self.plan.mode_for(s.ndim) == "pmean":
                entries.append((i, "pmean", s / self.n_local))
            else:
                entries.append((i, "psum", s))
        red = self._fused_psum_like(entries)
        out = [red[i] if coll == "pmean" else red[i] / self.n
               for i, coll, _ in entries]
        return treedef.unflatten(out)

    def once(self, f: Callable, *args):
        if not self.plan.server_once:
            return f(*args)
        shapes = jax.eval_shape(f, *args)
        zeros = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), shapes)
        on_shard0 = jax.lax.axis_index(self.axis) == 0
        out = jax.lax.cond(on_shard0, lambda: f(*args), lambda: zeros)
        # broadcast by gather-and-select, NOT psum of a one-hot stack:
        # psum(x, 0, ..., 0) can flip the sign of -0.0, gather cannot
        return jax.tree.map(
            lambda o: jax.lax.all_gather(o, self.axis, axis=0,
                                         tiled=False)[0], out)


class CohortReducer:
    """Reducer view of a sampled cohort standing in for the whole fleet.

    Built INSIDE the cohort chunk program (it holds traced arrays, so it is
    never a jit argument): wraps an inner stacked `Reducer` sized to the
    cohort *capacity* c and presents the fleet to spec code so `MethodSpec.
    step` bodies run nearly verbatim:

      * ``n`` / ``n_local`` / ``shard`` / ``client_keys`` / ``once`` — the
        cohort axis (draw shapes, sharding) delegates to the inner reducer;
      * ``n_total`` — the GLOBAL fleet size, so ledger divisions and
        participation probabilities stay fleet-denominated;
      * ``idx`` — each slot's global client index (shard-local ``(n_local,)``
        int32), ``real`` — padding mask (capacity is padded to a multiple of
        the device count; padded slots hold garbage and must never reduce);
      * ``reduce_tree`` — fleet-wide aggregate from cohort rows plus the
        host-maintained ``frozen`` sums/maxes of the ABSENT clients' state
        (Alg. 2–3: a non-sampled client's shift state is frozen, so its
        contribution to Σᵢ Hᵢ etc. is exactly its epoch-start value, which
        the streaming engine maintains incrementally — see
        `repro.core.cohort`).  A ``mean`` aggregate with no frozen entry is
        delta-style (absent clients contribute exactly 0): only the cohort
        sum lands, still divided by ``n_total``.

    Bare ``mean``/``max`` are refused — an unnamed fleet reduction cannot
    be matched to a frozen statistic, and silently reducing over the cohort
    would be wrong math; cohort-capable specs route every fleet reduction
    through named `reduce_tree` dicts (or `once`-guarded server math).
    """

    is_cohort = True

    def __init__(self, inner: Reducer, idx: jax.Array, real: jax.Array,
                 frozen: dict, n_global: int):
        self.inner = inner
        self.idx = idx
        self.real = real
        self.frozen = frozen
        self.n_global = int(n_global)

    # ---- cohort axis (delegated) ------------------------------------------
    @property
    def n(self) -> int:
        return self.inner.n

    @property
    def n_local(self) -> int:
        return self.inner.n_local

    @property
    def n_total(self) -> int:
        return self.n_global

    def shard(self, x):
        return self.inner.shard(x)

    def client_keys(self, key):
        return self.inner.client_keys(key)

    def once(self, f: Callable, *args):
        return self.inner.once(f, *args)

    # ---- fleet reductions --------------------------------------------------
    def _mask(self, x, fill):
        r = self.real.reshape((-1,) + (1,) * (x.ndim - 1))
        return jnp.where(r, x, jnp.asarray(fill, x.dtype))

    def sum(self, x):
        """Fleet sum of a cohort-supported quantity (absent clients are 0 by
        construction — participation masks, bit counts)."""
        return self.inner.sum(self._mask(x, 0))

    def mean(self, x):
        raise NotImplementedError(
            "CohortReducer cannot take an unnamed fleet mean — absent "
            "clients' contributions live in named frozen sums; use "
            "reduce_tree({'name': x}) (supports_cohort specs do)")

    def max(self, x):
        raise NotImplementedError(
            "CohortReducer cannot take an unnamed fleet max — use "
            "reduce_tree with a named leaf and a frozen fleet stat")

    def reduce_tree(self, tree, ops="mean"):
        if not isinstance(tree, dict):
            raise NotImplementedError(
                "CohortReducer.reduce_tree needs a flat {name: leaf} dict "
                f"(frozen fleet stats are matched by name); got {type(tree)}")
        ops_d = ({name: ops for name in tree} if isinstance(ops, str)
                 else dict(ops))
        masked, inner_ops = {}, {}
        for name, leaf in tree.items():
            op = ops_d[name]
            if op not in _REDUCE_OPS:
                raise ValueError(
                    f"reduce_tree op must be one of {_REDUCE_OPS}, got {op!r}")
            masked[name] = self._mask(leaf, -jnp.inf if op == "max" else 0)
            inner_ops[name] = "max" if op == "max" else "sum"
        red = self.inner.reduce_tree(masked, inner_ops)
        out = {}
        for name, leaf in tree.items():
            op = ops_d[name]
            if op == "sum":
                out[name] = red[name]
            elif op == "mean":
                froz = self.frozen.get(name)
                s = red[name] if froz is None else froz + red[name]
                out[name] = s / self.n_total
            else:  # max
                if name not in self.frozen:
                    raise ValueError(
                        f"max-aggregate {name!r} needs a frozen fleet stat "
                        "(the absent clients' max) — the cohort engine "
                        "computes one per epoch")
                out[name] = jnp.maximum(self.frozen[name], red[name])
        return out

    def tree_mean(self, tree):
        raise NotImplementedError(
            "pytree coefficient streams (BL-DNN) are not cohort-capable yet")

    def tree_mean_presummed(self, tree, local_sums):
        raise NotImplementedError(
            "pytree coefficient streams (BL-DNN) are not cohort-capable yet")


def _cohort_participation(R: "CohortReducer", key: jax.Array, tau: int,
                          avail) -> Tuple[jax.Array, jax.Array]:
    """Participation over a sampled cohort: per-slot Bernoulli(τ/n_total)
    keyed by each slot's GLOBAL client index, so a client's draw for round t
    depends only on (round key, client id) — not its cohort slot, the
    cohort composition, or chunk boundaries.  Distributionally identical to
    the stacked fleet-wide draw restricted to the cohort, at O(c) cost.

    The force-one-client fallback picks the real slot with the minimum
    global index (a deterministic choice that is slot-order invariant).
    Fault injection is refused: availability masks are fleet-indexed and
    the streaming engine has no fleet on device to mask."""
    if avail is not None:
        raise ValueError(
            "cohort streaming does not support fault injection (avail must "
            "be None) — fault plans address the stacked fleet by index")
    tau = min(tau, R.n_total)
    k_mask, _ = jax.random.split(key)
    keys_i = jax.vmap(lambda i: jax.random.fold_in(k_mask, i))(R.idx)
    p = tau / R.n_total
    drawn = jax.vmap(lambda k: jax.random.bernoulli(k, p, ()))(keys_i)
    drawn = drawn & R.real
    n_surv = R.sum(drawn.astype(jnp.int32))
    # forced fallback: the real slot with the minimum global index, computed
    # as −max(−idx) (the reducer interface carries max, not min)
    big = jnp.iinfo(jnp.int32).max
    masked_idx = jnp.where(R.real, R.idx, big)
    gmin = -R.inner.reduce_tree({"i": -masked_idx}, "max")["i"]
    need = n_surv == 0
    part = drawn | (need & R.real & (R.idx == gmin))
    event = jnp.where(need, EVENT_FORCED, EVENT_NONE)
    return part, event.astype(jnp.int32)


# ==========================================================================
# Round context + degradation events
# ==========================================================================
#: `History.events` bit flags (per-round int32 bitmask, OR-combined).
EVENT_NONE = 0
#: faults shrank the round's surviving cohort below its τ target
EVENT_DEGRADED = 1
#: the force-one-client fallback engaged (empty cohort after the draw/faults)
EVENT_FORCED = 2
#: no client was available at all — the round stalls (nothing participates)
EVENT_ALL_DOWN = 4


@dataclasses.dataclass
class RoundCtx:
    """Per-round traced context handed to `MethodSpec.step`.

    ``key`` is the round's PRNG key (replicated), ``t`` the absolute
    0-based round index, and ``avail`` an optional fleet-wide ``(n,)`` bool
    availability mask from the fault-injection layer (`repro.core.faults`)
    — ``None`` (the batch driver) means every client is reachable.
    ``avail`` is *fleet-wide and replicated* like the participation draws;
    spec code shards it through the `Reducer` where needed."""

    key: jax.Array
    t: jax.Array
    avail: "jax.Array | None" = None


def refresh_due(t, rounds_per_refresh: int):
    """Basis-refresh boundary predicate: True at rounds where an amortized
    basis shipment MAY re-ship (``t % T == 0`` for ``T ≥ 1``; never for
    ``T ≤ 0``, the ship-once policy).

    Deliberately a pure function of the ABSOLUTE round index `t` (a traced
    ``RoundCtx.t``), never of chunk-local position or wall clock — the same
    invariance contract as the per-round keys (``fold_in(root_key, t)``):
    fed_serve chunk boundaries and checkpoint resume cannot move a refresh
    round (pinned in tests/test_basis_ship.py, mirroring the cohort
    epoch-invariance pin)."""
    T = int(rounds_per_refresh)
    if T <= 0:
        return jnp.asarray(False)
    return (jnp.asarray(t) % T) == 0


# ==========================================================================
# Round-step combinators
# ==========================================================================
def shift_update(compress: Callable, target: jax.Array, shift: jax.Array,
                 alpha: float) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """One step of the compressed-difference shift recursion (Alg. 1 core):

        S = C(target − L),   L ← L + α·S.

    `compress` maps a delta tensor to (compressed_dense, aux) where aux is
    whatever the codec reports (message `Counts` for core compressors; the
    caller prices them via `comm.price`).  Returns (S, new_shift, aux).
    Contractive compressors use α = 1, unbiased ones α = 1/(ω+1).  This is
    the single mechanism shared by the GLM methods (Hessian-coefficient
    learning) and `repro.fed.bldnn` (gradient and Fisher-diagonal
    learning)."""
    S, aux = compress(target - shift)
    return S, shift + alpha * S, aux


def tree_shift_update(compress: Callable, target, shift,
                      alpha: float) -> Tuple[object, object, tuple]:
    """`shift_update` mapped over parameter *pytrees* (the BL-DNN layout):
    one compressed-difference recursion per leaf, aux records kept per leaf.

    Args:
      compress: ``compress(i, delta) -> (dense, aux)`` — compresses leaf
        ``i``'s client-stacked delta tensor.  The leaf index is a static
        Python int, so callers can close over per-leaf compressors (Top-K
        budgets scale with leaf size) and per-leaf PRNG keys.
      target, shift: pytrees of identical structure; leaves are
        client-stacked ``(n_local, ...)`` arrays.
      alpha: shared shift learning rate.

    Returns:
      ``(S, new_shift, auxs)`` — two pytrees shaped like the inputs plus a
      tuple of per-leaf aux records in leaf order (message `Counts` for the
      core compressors; price each against its compressor's wire and sum
      into ONE `comm.CommLedger` leg — per-leaf counts never grow their own
      billing scheme).
    """
    t_leaves, treedef = jax.tree_util.tree_flatten(target)
    s_leaves = jax.tree_util.tree_leaves(shift)
    if len(t_leaves) != len(s_leaves):
        raise ValueError(
            f"target/shift leaf mismatch: {len(t_leaves)} vs {len(s_leaves)}")
    outs = [shift_update(lambda d, i=i: compress(i, d), t, s, alpha)
            for i, (t, s) in enumerate(zip(t_leaves, s_leaves))]
    S = treedef.unflatten([o[0] for o in outs])
    new_shift = treedef.unflatten([o[1] for o in outs])
    return S, new_shift, tuple(o[2] for o in outs)


def shift_update_sum(compress_sum: Callable, target: jax.Array,
                     shift: jax.Array, alpha: float):
    """`shift_update` through a fused compress-then-reduce codec.

    ``compress_sum`` maps a client-stacked delta to ``(dense, aux,
    local_sum)`` where ``local_sum == dense.sum(axis=0)`` (see
    `repro.core.compressors.Compressor.compress_sum` — under
    ``REPRO_BL_PALLAS=1`` Top-K fuses the selection and the partial sum
    into one kernel pass).  Returns ``(S, new_shift, aux, local_sum)``;
    feed the sum to `Reducer.tree_mean_presummed` so the bandwidth-optimal
    sharded path reduces the pre-summed payload instead of the stack."""
    S, aux, s_local = compress_sum(target - shift)
    return S, shift + alpha * S, aux, s_local


def tree_shift_update_sum(compress_sum: Callable, target, shift, alpha: float):
    """`tree_shift_update` through fused compress-then-reduce codecs:
    ``compress_sum(i, delta) -> (dense, aux, local_sum)`` per leaf.
    Returns ``(S, new_shift, auxs, local_sums)`` — the first two and last
    pytrees shaped like the inputs, auxs a tuple in leaf order."""
    t_leaves, treedef = jax.tree_util.tree_flatten(target)
    s_leaves = jax.tree_util.tree_leaves(shift)
    if len(t_leaves) != len(s_leaves):
        raise ValueError(
            f"target/shift leaf mismatch: {len(t_leaves)} vs {len(s_leaves)}")
    outs = [shift_update_sum(lambda d, i=i: compress_sum(i, d), t, s, alpha)
            for i, (t, s) in enumerate(zip(t_leaves, s_leaves))]
    S = treedef.unflatten([o[0] for o in outs])
    new_shift = treedef.unflatten([o[1] for o in outs])
    local_sums = treedef.unflatten([o[3] for o in outs])
    return S, new_shift, tuple(o[2] for o in outs), local_sums


def participation(R: Reducer, key: jax.Array, tau: int,
                  avail: "jax.Array | None" = None
                  ) -> Tuple[jax.Array, jax.Array]:
    """Bernoulli(τ/n) participation mask for this shard's clients, with the
    reference backend's force-one-client fallback (drawn fleet-wide from the
    replicated key, then sharded).

    τ is validated statically: τ < 1 raises `ValueError` (a Bernoulli(0)
    fleet would silently degenerate to the forced client every round) and
    τ > n clamps to full participation (bitwise-harmless: Bernoulli(p) with
    p ≥ 1 is always-true either way).

    ``avail`` is an optional fleet-wide ``(n,)`` bool availability mask from
    the fault layer (`RoundCtx.avail`): drawn participants that are down
    this round are removed, and when the surviving cohort is empty the
    fallback forces one *available* client instead.  ``avail`` of all-ones
    reproduces the unmasked path bitwise (mask and fallback index alike).

    Returns ``(mask, event)`` — the shard-local participation mask plus a
    replicated int32 `EVENT_*` bitmask for the round (`EVENT_DEGRADED` when
    faults pushed the cohort below τ, `EVENT_FORCED` when the fallback
    engaged, `EVENT_ALL_DOWN` when no client was available and the round
    stalls with an all-false mask).

    The mask and the fallback index come from SPLIT keys: reusing one key
    for both correlates the forced client with the mask draw (the reference
    backend mirrors this split, so parity stays bitwise)."""
    tau = int(tau)
    if tau < 1:
        raise ValueError(
            f"participation needs τ ≥ 1 expected clients per round, got "
            f"τ={tau} — pass τ in [1, n] (τ=n is full participation)")
    if getattr(R, "is_cohort", False):
        return _cohort_participation(R, key, tau, avail)
    tau = min(tau, R.n)
    k_mask, k_idx = jax.random.split(key)
    drawn = jax.random.bernoulli(k_mask, tau / R.n, (R.n,))
    idx = jax.random.randint(k_idx, (), 0, R.n)
    if avail is None:
        forced = ~drawn.any() & (jnp.arange(R.n) == idx)
        event = jnp.where(forced.any(), EVENT_FORCED, EVENT_NONE)
        return R.shard(drawn | forced), event.astype(jnp.int32)
    avail = jnp.asarray(avail, bool)
    n_avail = jnp.sum(avail)
    surviving = drawn & avail
    n_surv = jnp.sum(surviving)
    # fallback index rotated onto the available subset: with avail all-ones
    # cumsum(avail) == idx+1 first holds exactly at position idx, so the
    # masked path degenerates to the unmasked one bitwise
    pick = avail & (jnp.cumsum(avail) == idx % jnp.maximum(n_avail, 1) + 1)
    need_force = (n_surv == 0) & (n_avail > 0)
    part = surviving | (need_force & pick)
    event = (EVENT_DEGRADED * ((n_surv < jnp.sum(drawn)) & (n_surv < tau))
             + EVENT_FORCED * need_force
             + EVENT_ALL_DOWN * (n_avail == 0))
    return R.shard(part), event.astype(jnp.int32)


def xi_mask(R: Reducer, key: jax.Array, p: float) -> jax.Array:
    """Per-client ξ ~ Bernoulli(p) gradient-refresh mask (local slice)."""
    if p >= 1.0:
        return jnp.ones((R.n_local,), bool)
    return R.shard(jax.random.bernoulli(key, p, (R.n,)))


def xi_scalar(key: jax.Array, p: float) -> jax.Array:
    """Fleet-wide scalar ξ (BL1's single gradient-leg switch)."""
    if p >= 1.0:
        return jnp.asarray(True)
    return jax.random.bernoulli(key, p, (1,))[0]


def downlink_broadcast(R: Reducer, comp, key: jax.Array, z: jax.Array,
                       x_target: jax.Array, eta: float, part: jax.Array):
    """Compressed model-stream downlink to participating clients:
    z_i ← z_i + η·C_i(x − z_i).  Returns (z_new, down_bits_per_node)."""
    v, counts = comp.compress(R.client_keys(key), x_target[None, :] - z)
    vbits = comm.price(comp.wire, counts)
    z_n = jnp.where(part[:, None], z + eta * v, z)
    return z_n, R.sum(jnp.where(part, vbits, 0.0)) / R.n_total


def global_grad(R: Reducer, batch, x: jax.Array) -> jax.Array:
    return R.mean(client_batch.grads(batch, x))

# NOTE: there is deliberately no in-scan global_loss combinator — specs emit
# evaluation iterates and the engine evaluates the whole trajectory outside
# the scan (`MethodSpec.eval_streams`, default `default_gap_stream`); an
# in-scan loss evaluation compiles differently under shard_map and would
# break the cross-backend bitwise contract.


# ==========================================================================
# Coefficient layouts (§2.3): block (n, r, r) vs full (n, d, d)
# ==========================================================================
@dataclasses.dataclass
class CoeffLayout:
    """How Hessian-coefficient state is laid out on this run.

    `target_at(z)` gives the per-client coefficient target h^i(∇²f_i(z)),
    `recon(S)` maps coefficient-space updates back to (n_local, d, d)
    Hessian space, `shape` is the local coefficient-state shape, and
    `ridge` is the analytic λI the server adds for data bases."""

    target_at: Callable
    recon: Callable
    shape: Tuple[int, ...]
    ridge: jax.Array


def coeff_layout(R: Reducer, batch, basisb, x0: jax.Array,
                 block: bool) -> CoeffLayout:
    d = batch.d
    lam = batch.lam
    if block:
        # §2.3 block mode (data basis only): state stays (n, r, r) and the
        # d×d data Hessian is never materialized (Γ = (AV)ᵀD(AV)/m).
        AV = client_batch.basis_AV(basisb, batch)
        rb = basisb.r_max
        return CoeffLayout(
            target_at=lambda z: client_batch.hess_coeff_block(basisb, batch, z, AV),
            recon=lambda S: client_batch.reconstruct_block(basisb, S),
            shape=(R.n_local, rb, rb),
            ridge=lam * jnp.eye(d, dtype=x0.dtype),
        )
    ridge = (lam * jnp.eye(d, dtype=x0.dtype)
             if basisb.kind == "data_outer" else jnp.zeros((d, d), x0.dtype))
    return CoeffLayout(
        target_at=lambda z: client_batch.hess_coeff_target(basisb, batch, z),
        recon=basisb.reconstruct,
        shape=(R.n_local, d, d),
        ridge=ridge,
    )


# ==========================================================================
# Driver: one jitted scan over rounds, per (spec, reducer) pair
# ==========================================================================
@dataclasses.dataclass
class Env:
    """Per-run traced context handed to spec.init/step (not a scan carry)."""

    batch: object
    basisb: object
    x0: jax.Array
    extra: object  # spec-specific precomputation (e.g. a CoeffLayout)


@dataclasses.dataclass(frozen=True)
class StreamHook:
    """Mid-sweep instrumentation hook for long runs (`repro.exp` sweeps).

    The batch driver (`run_rounds`) splits its round budget into chunks of
    ``every`` rounds and emits ``callback(t, eval_x, ledger)`` from the
    host at each chunk boundary — ``t`` is the 0-based round index of the
    chunk's first round (so emissions land at t = 0, every, 2·every, ...),
    ``eval_x`` that round's evaluation iterate and ``ledger`` the
    cumulative per-leg `comm.CommLedger` at that round.  Because emission
    happens between chunk programs on the host, it works identically on
    BOTH aggregation backends — including `ShardMapReducer`, whose chunk
    outputs are replicated fleet-wide values, not shard-local ones.

    Emission is instrumentation only: the recorded `History` still comes
    from the full post-run gap evaluation, and chunking is bitwise-neutral
    (the chunk-size-invariance contract of the serve driver), so
    trajectories and gap streams are unchanged by attaching a hook.  Each
    distinct ``every`` compiles its own chunk program, so attach hooks to
    long runs, not micro-benches."""

    every: int
    callback: Callable

    def _emit(self, t, eval_x, ledger):
        self.callback(int(t), eval_x, ledger)


@jax.jit
def default_gap_stream(batch, xs_t, f_star):
    """f(x_t) − f* for a whole (steps, d) GLM trajectory in one vmapped
    pass — the default `MethodSpec.eval_streams` evaluation.

    Shared by both aggregation backends — same program + bitwise-identical
    iterates ⇒ bitwise-identical gap histories."""
    return jax.vmap(lambda x: jnp.mean(client_batch.losses(batch, x)))(xs_t) - f_star


def run_rounds(spec, batch, basisb, x0, f_star, keys, *,
               sharded: bool = False, exact: bool = True,
               stream: "StreamHook | None" = None):
    """Run `steps = len(keys)` rounds of `spec` and return the history
    streams ``(evals, CommLedger-of-streams)``: ``evals`` is the dict the
    spec's ``eval_streams`` hook derives from the trajectory (always
    containing ``"gap"``; pytree specs add extra named streams such as
    ``"loss"``), the ledger carries one per-leg bit stream per
    `comm.CommLedger` leg.

    sharded=False → `VmapReducer` on the default device.
    sharded=True  → `ShardMapReducer` over a 1-D client mesh spanning the
    most local devices that evenly divide the client count (a 1-device
    world still exercises the shard_map code path).  ``exact`` selects the
    bitwise gather path (default) vs the method's `ReducePlan` collectives.

    stream — optional `StreamHook`: the run is chunked every
    ``stream.every`` rounds and (round, eval_x, ledger) is emitted from
    each chunk boundary on the host.  Works on both backends.

    This is the chunked service-loop driver (`run_chunk`) under another
    entry point — one init program plus one scan program per chunk length,
    with per-round keys supplied explicitly (the batch path pre-splits
    them; the serve path derives them by `fold_in`).  The scan carry is
    DONATED between chunks, so per-chunk state never copies."""
    steps = int(keys.shape[0])
    init, chunk = _serve_backend(spec, batch, basisb, x0, sharded, exact)
    carry = init(batch, basisb, x0)
    chunk_len = steps if stream is None else max(1, int(stream.every))
    parts = []
    t = 0
    while t < steps:
        s = min(chunk_len, steps - t)
        ts = jnp.arange(t, t + s)
        avail = jnp.ones((s, batch.n), bool)
        carry, ys = chunk(batch, basisb, x0, carry, ts, keys[t:t + s], avail)
        if stream is not None:
            # row 0 of the chunk = round t's iterate + cumulative ledger
            stream._emit(ts[0], ys[0][0], jax.tree.map(lambda a: a[0], ys[1]))
        parts.append(ys)
        t += s
    if len(parts) == 1:
        xs_t, leds, _events = parts[0]
    else:
        xs_t, leds, _events = jax.tree.map(
            lambda *a: jnp.concatenate(a, axis=0), *parts)
    # ys = (eval_x (steps, d), CommLedger of (steps,) per-leg streams,
    # events (steps,) int32 EVENT_* bitmasks — all-zero without a fault
    # layer, so the batch path drops them).
    if sharded:
        # outputs come back committed to the client mesh; rehome them so the
        # gap evaluation below is the same default-device program on every
        # backend (this is what makes the histories bitwise-comparable)
        import numpy as np

        xs_t, leds = jax.tree.map(lambda a: jnp.asarray(np.asarray(a)),
                                  (xs_t, leds))
    evals = spec.eval_streams(batch, xs_t, f_star)
    return evals, leds


# ==========================================================================
# Chunked service-loop driver (repro.launch.fed_serve)
# ==========================================================================
# Retrace audit — every trace of a dispatch-path program body bumps a
# counter.  The invariant the audit pins (tests/test_retrace_audit.py):
# ONE trace per (spec, shapes) per process and ZERO retraces across
# chunk/epoch boundaries, on every backend — so the dispatch-cost
# regressions PR 7 closed (a retrace costs ~1000× the compiled per-round
# dispatch) can never silently return.  Shape-only evaluations
# (`carry_client_flags` runs `spec.init` under `jax.eval_shape` twice) are
# tagged with a "/shape_eval" suffix so real retraces stand out.
_TRACE_COUNTS: collections.Counter = collections.Counter()
_IN_SHAPE_EVAL = False


def _note_trace(kind: str) -> None:
    _TRACE_COUNTS[kind + "/shape_eval" if _IN_SHAPE_EVAL else kind] += 1


def trace_counts() -> dict:
    """Snapshot of {program kind: trace count} since the last reset.
    Kinds: "init", "chunk", "cohort_chunk" (+ "/shape_eval" variants)."""
    return dict(_TRACE_COUNTS)


def reset_trace_audit() -> None:
    _TRACE_COUNTS.clear()


def _with_client_dim(tree, n_new: int):
    """Abstract (shape-only) copy of a client-stacked pytree with the
    leading client axis resized — every leaf of `ClientBatch` /
    `BatchedBasis` / `TreeBatch` carries the client axis first (static aux
    like ``lam`` is not a leaf and survives unflattening untouched)."""
    return jax.tree.map(
        lambda l: jax.ShapeDtypeStruct((n_new,) + tuple(l.shape[1:]),
                                       l.dtype), tree)


def _init_body(spec, R: Reducer, batch, basisb, x0):
    _note_trace("init")
    env = Env(batch=batch, basisb=basisb, x0=x0,
              extra=spec.prepare(R, batch, basisb, x0))
    return spec.init(R, env)


_init_jit = functools.partial(jax.jit, static_argnames=("spec", "R"))(_init_body)


def carry_client_flags(spec, batch, basisb, x0):
    """Which carry leaves are client-stacked — the carry serialization /
    sharding contract for the chunked driver.

    Derived structurally, with no per-spec declarations: `spec.init` is
    shape-evaluated twice (at n and at 2n clients) and exactly the leaves
    whose shape moved carry the client axis.  This disambiguates d == n
    coincidences and works for any spec the engine can run.  Returns a
    bool pytree shaped like the carry."""
    n = batch.n

    def init_at(b, bb, nn):
        return _init_body(spec, VmapReducer(n=nn), b, bb, x0)

    global _IN_SHAPE_EVAL
    _IN_SHAPE_EVAL = True
    try:
        s1 = jax.eval_shape(functools.partial(init_at, nn=n), batch, basisb)
        b2 = _with_client_dim(batch, 2 * n)
        bb2 = (basisb if basisb is None
               or getattr(spec, "basis_replicated", False)
               else _with_client_dim(basisb, 2 * n))
        s2 = jax.eval_shape(functools.partial(init_at, nn=2 * n), b2, bb2)
    finally:
        _IN_SHAPE_EVAL = False
    return jax.tree.map(lambda a, b: a.shape != b.shape, s1, s2)


def _flags_key(flags):
    """Hashable (leaves, treedef) form of a carry-flags pytree — the cache
    key for the per-(spec, reducer, mesh) sharded chunk programs."""
    leaves, treedef = jax.tree_util.tree_flatten(flags)
    return tuple(leaves), treedef


def _abstract_sig(*trees):
    """Hashable shape/dtype signature of arbitrary pytrees — everything
    `carry_client_flags` (a pure shape evaluation) can depend on."""
    leaves, treedef = jax.tree_util.tree_flatten(trees)
    return treedef, tuple(
        (np.shape(l), str(np.result_type(getattr(l, "dtype", type(l)))))
        for l in leaves)


# carry_client_flags costs two full Python traces of spec.init — ~15ms on a
# mid-size GLM spec, which used to be paid per init_serve_carry AND per
# run_chunk dispatch (it dwarfed the ~4ms compiled sharded program and was
# most of the sharded backend's fixed per-call overhead).  The flags are a
# pure function of (spec, abstract shapes), so memoize on that signature.
_FLAGS_CACHE: dict = {}


def _carry_flags_key_cached(spec, batch, basisb, x0):
    key = (spec, _abstract_sig(batch, basisb, x0))
    fk = _FLAGS_CACHE.get(key)
    if fk is None:
        fk = _FLAGS_CACHE[key] = _flags_key(
            carry_client_flags(spec, batch, basisb, x0))
    return fk


def _chunk_body(spec, R: Reducer, batch, basisb, x0, carry, ts, keys, avail):
    _note_trace("chunk")
    env = Env(batch=batch, basisb=basisb, x0=x0,
              extra=spec.prepare(R, batch, basisb, x0))

    def step(carry, xt):
        t, key_t, avail_t = xt
        return spec.step(R, env, carry, RoundCtx(key=key_t, t=t,
                                                 avail=avail_t))

    return jax.lax.scan(step, carry, (ts, keys, avail))


# the carry is DONATED: its buffers are reused for the output carry, which
# kills the per-chunk state copy.  Callers must treat the argument as
# consumed and continue from the returned carry (every driver in this repo
# reassigns `carry, ys = chunk(...)`).
_chunk_jit = functools.partial(
    jax.jit, static_argnames=("spec", "R"),
    donate_argnames=("carry",))(_chunk_body)

# AOT twin WITHOUT donation, used for every program that goes through the
# progcache (`_AotProgram`).  Executables that came back through
# serialize/deserialize mishandle donated carry buffers once calls are
# CHAINED through engine state (outputs aliased into donated memory feed
# the next call): outputs go bitwise-wrong with bitwise-identical inputs,
# while the same executable on fresh copies is correct.  Donation never
# affects values, only buffer reuse, so compiling the cache path from a
# donation-free lowering pins hit == miss == uncached bitwise — at the cost
# of one in-flight carry copy per chunk call.  REPRO_PROGCACHE=0 restores
# the donating fast path above.
_chunk_jit_aot = functools.partial(
    jax.jit, static_argnames=("spec", "R"))(_chunk_body)


# --------------------------------------------------------------------------
# AOT program dispatch (repro.core.progcache tier 1)
# --------------------------------------------------------------------------
# resolved executables, keyed (kind, spec, backend scope, abstract arg sig)
# — module-level so the memo survives `_serve_backend`'s per-dispatch
# wrapper construction (a closure-held memo would be rebuilt every call)
_AOT_PROGS: dict = {}


def clear_aot_memo() -> None:
    """Drop the in-process executable memo (tests use this to force the
    next dispatch back through the on-disk cache)."""
    _AOT_PROGS.clear()


class _AotProgram:
    """One serve program behind cache-aware dispatch.

    With no active `progcache` cache, ``__call__`` IS the plain jitted
    ``fast`` path — the pre-subsystem dispatch, byte for byte.  With a
    cache active, the first call per abstract argument signature resolves
    an AOT executable — deserialized from disk on a hit, compiled from the
    *identical* lowering on any miss and persisted — and every later call
    reuses it.  AOT lowerings are DONATION-FREE (see `_chunk_jit_aot`):
    deserialized executables corrupt chained donated-carry calls, and
    donation is invisible to values, so the cache path trades the in-place
    carry update for a bitwise hit == miss == uncached guarantee.  Callers
    must still treat the carry argument as consumed — which path runs is a
    cache-availability detail.

    ``resolve`` is the execution-free half (lower/load only): the serve
    loop warms programs through it *before* checkpoint restore, which is
    what moves compile latency out of time-to-first-round."""

    def __init__(self, kind: str, spec, scope: tuple, fast: Callable,
                 lower: Callable):
        self.kind = kind
        self._spec = spec
        self._scope = scope
        self._fast = fast
        self._lower = lower

    def resolve(self, *args):
        """The compiled executable for these (concrete) args, or None when
        no cache is active.  Never executes the program."""
        cache = progcache.active()
        if cache is None:
            return None
        sig = _abstract_sig(*args)
        memo_key = (self.kind, self._spec, self._scope, sig)
        prog = _AOT_PROGS.get(memo_key)
        if prog is None:
            prog, _ = cache.load_or_compile(
                name=self.kind,
                key_parts=(self.kind, progcache.fingerprint(self._spec),
                           progcache.fingerprint(self._scope), repr(sig)),
                lower=lambda: self._lower(*args),
                aux={"scope": [str(s) for s in self._scope]})
            _AOT_PROGS[memo_key] = prog
        return prog

    def __call__(self, *args):
        prog = self.resolve(*args)
        if prog is None:
            return self._fast(*args)
        return prog(*args)


def _vmap_init_program(spec, R: Reducer) -> _AotProgram:
    return _AotProgram(
        "serve_init", spec, ("vmap", R.n),
        functools.partial(_init_jit, spec, R),
        functools.partial(_init_jit.lower, spec, R))


def serve_init(spec, R: Reducer, batch, basisb, x0):
    """The single-device init program under AOT dispatch — shared by the
    stacked serve backend and the cohort engine's fleet initialisation
    (`repro.core.cohort._init_fleet`), so both populate the same cache
    entries."""
    return _vmap_init_program(spec, R)(batch, basisb, x0)


@functools.lru_cache(maxsize=None)
def _sharded_chunk_fns(spec, R: "ShardMapReducer", mesh, flags_key):
    """Jitted shard_map (init, chunk) programs whose carry crosses the
    shard_map boundary: client-stacked carry leaves shard over the mesh,
    everything else is replicated (per `carry_client_flags`).  The chunk
    program donates its carry argument like the vmap path; its AOT twin
    (third element) is donation-free like `_chunk_jit_aot`."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.sharding.rules import CLIENT_AXIS, client_chunk_specs

    leaves, treedef = flags_key
    carry_specs = jax.tree_util.tree_unflatten(
        treedef, [P(CLIENT_AXIS) if f else P() for f in leaves])
    in_specs, out_specs = client_chunk_specs(
        carry_specs,
        basis_replicated=getattr(spec, "basis_replicated", False))
    body = shard_map(
        functools.partial(_chunk_body, spec, R), mesh=mesh,
        in_specs=in_specs, out_specs=out_specs, check_rep=False)
    init = jax.jit(shard_map(
        functools.partial(_init_body, spec, R), mesh=mesh,
        in_specs=in_specs[:3], out_specs=carry_specs, check_rep=False))
    # (batch, basisb, x0, carry, ts, keys, avail) — carry is argument 3
    chunk = jax.jit(body, donate_argnums=(3,))
    chunk_aot = jax.jit(body)
    return init, chunk, chunk_aot


def _serve_backend(spec, batch, basisb, x0, sharded: bool, exact: bool):
    if not sharded:
        R = VmapReducer(n=batch.n)
        return (_vmap_init_program(spec, R),
                _AotProgram("serve_chunk", spec, ("vmap", R.n),
                            functools.partial(_chunk_jit, spec, R),
                            functools.partial(_chunk_jit_aot.lower, spec,
                                              R)))
    from repro.launch.mesh import make_client_mesh
    from repro.sharding.rules import mesh_fingerprint

    mesh, ndev = make_client_mesh(batch.n)
    R = ShardMapReducer(n=batch.n, ndev=ndev, exact=exact,
                        plan=getattr(spec, "reduce_plan", ReducePlan()))
    fk = _carry_flags_key_cached(spec, batch, basisb, x0)
    init, chunk, chunk_aot = _sharded_chunk_fns(spec, R, mesh, fk)
    scope = ("shmap", ndev, exact, mesh_fingerprint(mesh))
    return (_AotProgram("serve_init", spec, scope, init, init.lower),
            _AotProgram("serve_chunk", spec, scope, chunk, chunk_aot.lower))


def init_serve_carry(spec, batch, basisb, x0, *, sharded: bool = False,
                     exact: bool = True):
    """The round-0 scan carry as an explicit (global) pytree — the state the
    service loop checkpoints.  Its structure and leaf shapes/dtypes ARE the
    carry serialization contract: `repro.exp.artifacts.save_checkpoint`
    stores the flattened leaves and restore validates them against a fresh
    `init_serve_carry` shape evaluation, so an incompatible spec change
    fails loudly instead of resuming garbage."""
    init, _ = _serve_backend(spec, batch, basisb, x0, sharded, exact)
    return init(batch, basisb, x0)


def run_chunk(spec, batch, basisb, x0, carry, t0: int, steps: int, root_key,
              *, avail=None, sharded: bool = False, exact: bool = True):
    """Run `steps` rounds starting at absolute round `t0` from an explicit
    carry; returns ``(carry, (eval_x stream, CommLedger of per-leg streams,
    events stream))`` with the new carry ready for the next chunk (or for a
    checkpoint).

    Per-round keys are ``fold_in(root_key, t)`` — a pure function of the
    absolute round index — so a trajectory is invariant to chunk boundaries
    and a run resumed from a checkpoint at any boundary is bit-exactly the
    uninterrupted run.  ``avail`` is an optional ``(steps, n)`` bool
    availability schedule from the fault layer (`repro.core.faults`); rows
    reach specs as `RoundCtx.avail`.  An all-ones schedule (the default) is
    bitwise-equivalent to no fault layer at all.

    The input ``carry`` is CONSUMED: continue (or checkpoint) from the
    returned carry, never the argument.  On the fast (no-progcache) path
    its buffers are donated outright — reuse raises jax's deleted-buffer
    error; under an active program cache the AOT executable is
    donation-free (see `_chunk_jit_aot`), but the consumed contract is the
    same on both paths.

    Chunk programs compile once per (spec, backend, chunk length); the
    service loop reuses one length for every full chunk, so only a trailing
    partial chunk costs a second compile."""
    ts = jnp.arange(t0, t0 + steps)
    # the fold_in happens outside the scan (vmapped over the chunk's round
    # indices — threefry is elementwise, so this is bitwise the in-scan
    # per-round fold_in) so the scan body takes explicit keys: the batch
    # driver feeds the same program its pre-split key array instead
    keys = jax.vmap(lambda t: jax.random.fold_in(root_key, t))(ts)
    if avail is None:
        avail = jnp.ones((steps, batch.n), bool)
    avail = jnp.asarray(avail, bool)
    if avail.shape != (steps, batch.n):
        raise ValueError(
            f"avail schedule must be (steps, n) = ({steps}, {batch.n}), "
            f"got {avail.shape}")
    _, chunk = _serve_backend(spec, batch, basisb, x0, sharded, exact)
    return chunk(batch, basisb, x0, carry, ts, keys, avail)


def warm_chunk_program(spec, batch, basisb, x0, carry, steps: int, root_key,
                       *, sharded: bool = False, exact: bool = True) -> bool:
    """Resolve the serve (init, chunk) programs for this cell — load from
    the active program cache or compile-and-persist — WITHOUT executing a
    round.  ``carry`` is a template (e.g. `init_serve_carry`'s output) used
    only for its shapes; nothing is donated or mutated.  The serve loop
    calls this before checkpoint restore so a warm restart's
    time-to-first-round contains no compilation.  Returns False (no-op)
    when no cache is active."""
    if progcache.active() is None:
        return False
    steps = int(steps)
    init, chunk = _serve_backend(spec, batch, basisb, x0, sharded, exact)
    init.resolve(batch, basisb, x0)
    ts = jnp.arange(0, steps)
    keys = jax.vmap(lambda t: jax.random.fold_in(root_key, t))(ts)
    avail = jnp.ones((steps, batch.n), bool)
    chunk.resolve(batch, basisb, x0, carry, ts, keys, avail)
    return True


# ==========================================================================
# Cohort-streaming chunk programs (repro.core.cohort)
# ==========================================================================
def _cohort_chunk_body(spec, R, n_global, batch, basisb, x0, carry, ts, keys,
                       cidx, creal, frozen):
    """One epoch-aligned chunk of cohort rounds: same scan skeleton as
    `_chunk_body`, but spec code sees a `CohortReducer` wrapping the
    cohort-capacity reducer `R`.  ``cidx``/``creal``/``frozen`` are
    constant for the chunk (the cohort engine cuts chunks at epoch
    boundaries), so they ride in as plain traced inputs, not scan xs."""
    _note_trace("cohort_chunk")
    CR = CohortReducer(inner=R, idx=cidx, real=creal, frozen=frozen,
                       n_global=n_global)
    env = Env(batch=batch, basisb=basisb, x0=x0,
              extra=spec.prepare(CR, batch, basisb, x0))

    def step(carry, xt):
        t, key_t = xt
        return spec.step(CR, env, carry, RoundCtx(key=key_t, t=t, avail=None))

    return jax.lax.scan(step, carry, (ts, keys))


_cohort_chunk_jit = functools.partial(
    jax.jit, static_argnames=("spec", "R", "n_global"),
    donate_argnames=("carry",))(_cohort_chunk_body)

# donation-free AOT twin — see `_chunk_jit_aot` for why cached programs
# must not donate
_cohort_chunk_jit_aot = functools.partial(
    jax.jit, static_argnames=("spec", "R", "n_global"))(_cohort_chunk_body)


@functools.lru_cache(maxsize=None)
def _sharded_cohort_chunk_fns(spec, R: "ShardMapReducer", mesh, flags_key,
                              n_global):
    """The cohort chunk program under shard_map: the COHORT axis shards
    over the client mesh (cidx/creal shard with it; frozen fleet stats are
    replicated like the server state)."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.sharding.rules import CLIENT_AXIS, cohort_chunk_specs

    leaves, treedef = flags_key
    carry_specs = jax.tree_util.tree_unflatten(
        treedef, [P(CLIENT_AXIS) if f else P() for f in leaves])
    in_specs, out_specs = cohort_chunk_specs(
        carry_specs,
        basis_replicated=getattr(spec, "basis_replicated", False))
    body = shard_map(
        functools.partial(_cohort_chunk_body, spec, R, n_global), mesh=mesh,
        in_specs=in_specs, out_specs=out_specs, check_rep=False)
    # (batch, basisb, x0, carry, ts, keys, cidx, creal, frozen) — carry is 3
    chunk = jax.jit(body, donate_argnums=(3,))
    chunk_aot = jax.jit(body)  # donation-free twin for the progcache path
    return chunk, chunk_aot


def _cohort_backend(spec, batch, basisb, x0, n_global: int, sharded: bool,
                    exact: bool) -> _AotProgram:
    if not sharded:
        R = VmapReducer(n=batch.n)
        return _AotProgram(
            "cohort_chunk", spec, ("vmap", n_global),
            functools.partial(_cohort_chunk_jit, spec, R, n_global),
            functools.partial(_cohort_chunk_jit_aot.lower, spec, R,
                              n_global))
    from repro.launch.mesh import make_client_mesh
    from repro.sharding.rules import mesh_fingerprint

    mesh, ndev = make_client_mesh(batch.n)
    R = ShardMapReducer(n=batch.n, ndev=ndev, exact=exact,
                        plan=getattr(spec, "reduce_plan", ReducePlan()))
    fk = _carry_flags_key_cached(spec, batch, basisb, x0)
    chunk, chunk_aot = _sharded_cohort_chunk_fns(spec, R, mesh, fk, n_global)
    scope = ("shmap", ndev, exact, mesh_fingerprint(mesh), n_global)
    return _AotProgram("cohort_chunk", spec, scope, chunk, chunk_aot.lower)


def run_cohort_chunk(spec, batch, basisb, x0, carry, t0: int, steps: int,
                     root_key, *, cidx, creal, frozen, n_global: int,
                     sharded: bool = False, exact: bool = True):
    """Run `steps` cohort rounds starting at absolute round `t0`.

    ``batch`` is the COHORT's `ClientBatch` (capacity c rows gathered from
    the `ClientStore`), ``carry`` the cohort-capacity carry, ``cidx`` the
    slots' global client indices (c,) int32, ``creal`` the padding mask
    (c,) bool, ``frozen`` the dict of fleet aggregate statistics for the
    epoch's ABSENT clients.  Per-round keys are ``fold_in(root_key, t)``
    exactly like `run_chunk`, so cohort trajectories share the serve
    driver's chunk-boundary invariance.  The carry is CONSUMED (donated on
    the fast path, left intact but still not reusable by contract under an
    active program cache — see `_chunk_jit_aot`)."""
    ts = jnp.arange(t0, t0 + steps)
    keys = jax.vmap(lambda t: jax.random.fold_in(root_key, t))(ts)
    cidx = jnp.asarray(cidx, jnp.int32)
    creal = jnp.asarray(creal, bool)
    chunk = _cohort_backend(spec, batch, basisb, x0, int(n_global), sharded,
                            exact)
    return chunk(batch, basisb, x0, carry, ts, keys, cidx, creal, frozen)


def warm_cohort_chunk_program(spec, batch, basisb, x0, carry, steps: int,
                              root_key, *, cidx, creal, frozen,
                              n_global: int, sharded: bool = False,
                              exact: bool = True) -> bool:
    """`warm_chunk_program` for the cohort chunk program: resolve (load or
    compile-and-persist) without executing.  All array arguments are shape
    templates; `repro.core.cohort.CohortEngine.warm_programs` builds them
    from the store's dtypes before any epoch is gathered."""
    if progcache.active() is None:
        return False
    ts = jnp.arange(0, int(steps))
    keys = jax.vmap(lambda t: jax.random.fold_in(root_key, t))(ts)
    prog = _cohort_backend(spec, batch, basisb, x0, int(n_global), sharded,
                           exact)
    prog.resolve(batch, basisb, x0, carry, ts, keys,
                 jnp.asarray(cidx, jnp.int32), jnp.asarray(creal, bool),
                 frozen)
    return True
