"""Unified round engine: combinators + pluggable client-sharded aggregation.

Every method in this repo — BL1/BL2/BL3 (Algorithms 1–3), the FedNL family
they extend, and the first/second-order baselines — shares one round
skeleton: local Hessian/gradient compute → compressed-difference uplink →
server aggregate → (compressed) downlink.  This module factors that skeleton
into three pieces:

  1. **Combinators** — the shared round steps as small pure functions over
     client-stacked arrays: the compressed-shift recursion L ← L + αC(·−L)
     (`shift_update`; `tree_shift_update` maps it over parameter *pytrees*
     for the BL-DNN coefficient layout, per-leaf aux records summed into
     one ledger leg), Bernoulli participation with the force-one-client
     fallback (`participation`), the ξ gradient-refresh mask (`xi_mask`),
     the compressed model-stream downlink (`downlink_broadcast`), and the
     §2.3 coefficient layouts (`coeff_layout` — compact (n, r, r) blocks
     vs. full d×d) behind one (target_at, recon, ridge) interface.

  2. **Reducers** — the aggregation-backend axis.  All cross-client
     reductions (means/sums/maxes of Hessians, gradients, bit counts) go
     through a `Reducer` so the same method spec runs on two backends:

       * `VmapReducer`      — one device; the client axis is a plain leading
         array axis and reductions are `jnp.mean/sum/max(axis=0)`.
       * `ShardMapReducer`  — clients sharded over the mesh `data` axis
         inside `shard_map`; per-client state carries a leading local axis.
         `exact=True` (default) reduces by `all_gather` + the *identical*
         local reduction, which is bitwise-equal to the single-device
         backend (pinned by tests/test_sharding_multidev.py); `exact=False`
         uses `lax.psum/pmean/pmax`, which is bandwidth-optimal but can
         differ in the last ulp (summation order).

  3. **Drivers** — jitted `lax.scan`s over rounds.  A `MethodSpec` (see
     `repro.core.specs`) supplies `prepare/init/step`; the drivers never
     know which algorithm they are running.  Two entry points:

       * `run_rounds`  — the batch driver: one scan over a fixed round
         budget, histories come back at the end (the figure path).
       * `run_chunk` / `init_serve_carry` — the *service-loop* driver: the
         scan carry is an explicit input/output, rounds run in bounded
         chunks so control returns to the host between chunks (fault
         injection, checkpointing — see `repro.launch.fed_serve`).  Per-
         round PRNG keys are ``fold_in(root_key, t)`` of the absolute round
         index, so a trajectory is invariant to how rounds are batched into
         chunks — the crash-safe bit-exact-resume contract.

     The sharded backend wraps the same scan bodies in a single `shard_map`
     over the client mesh, so a whole sharded trajectory (or chunk) is
     still one SPMD program.  For the chunked driver the carry itself
     crosses the shard_map boundary; `carry_client_flags` derives which
     carry leaves are client-stacked (the carry serialization contract —
     see `init_serve_carry`).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Tuple

import jax
import jax.numpy as jnp

from repro.sharding.rules import CLIENT_AXIS

from . import client_batch, comm


# ==========================================================================
# Reducers — the pluggable aggregation backend
# ==========================================================================
@dataclasses.dataclass(frozen=True)
class Reducer:
    """Cross-client reduction interface.  `n` is the GLOBAL client count;
    per-client arrays seen by spec code always carry a leading `n_local`
    axis (== n on the vmap backend, n/ndev inside each shard otherwise)."""

    n: int

    @property
    def n_local(self) -> int:
        raise NotImplementedError

    def mean(self, x: jax.Array) -> jax.Array:
        """(n_local, ...) → (...): mean over the global client axis."""
        raise NotImplementedError

    def sum(self, x: jax.Array) -> jax.Array:
        raise NotImplementedError

    def max(self, x: jax.Array) -> jax.Array:
        raise NotImplementedError

    def shard(self, x: jax.Array) -> jax.Array:
        """Slice a replicated (n, ...) array down to this shard's clients.

        Fleet-wide randomness (participation masks, per-client PRNG keys)
        is always drawn for all n clients from the replicated key and then
        sharded, so every backend sees the same per-client draws."""
        raise NotImplementedError

    def client_keys(self, key: jax.Array) -> jax.Array:
        """Per-client PRNG keys for this shard: (n_local, 2)."""
        return self.shard(jax.random.split(key, self.n))

    def tree_mean(self, tree):
        """`mean` mapped over a pytree of (n_local, ...) leaves — the
        cross-client reduction for pytree coefficient streams (BL-DNN)."""
        return jax.tree.map(self.mean, tree)


@dataclasses.dataclass(frozen=True)
class VmapReducer(Reducer):
    """Single-device backend: the client axis is a plain leading axis."""

    @property
    def n_local(self) -> int:
        return self.n

    def mean(self, x):
        return jnp.mean(x, axis=0)

    def sum(self, x):
        return jnp.sum(x, axis=0)

    def max(self, x):
        return jnp.max(x, axis=0)

    def shard(self, x):
        return x


@dataclasses.dataclass(frozen=True)
class ShardMapReducer(Reducer):
    """Mesh backend: clients sharded over `axis` inside `shard_map`.

    exact=True reduces by `all_gather` + the same local reduction as
    `VmapReducer` — bitwise-identical trajectories to the single-device
    fast path.  exact=False reduces with `lax.psum/pmean/pmax` (less wire
    traffic, last-ulp summation-order differences)."""

    ndev: int = 1
    axis: str = CLIENT_AXIS
    exact: bool = True

    @property
    def n_local(self) -> int:
        return self.n // self.ndev

    def _gather(self, x):
        return jax.lax.all_gather(x, self.axis, axis=0, tiled=True)

    def mean(self, x):
        if self.exact:
            return jnp.mean(self._gather(x), axis=0)
        return jax.lax.pmean(jnp.sum(x, axis=0), self.axis) / self.n_local

    def sum(self, x):
        if self.exact:
            return jnp.sum(self._gather(x), axis=0)
        return jax.lax.psum(jnp.sum(x, axis=0), self.axis)

    def max(self, x):
        if self.exact:
            return jnp.max(self._gather(x), axis=0)
        return jax.lax.pmax(jnp.max(x, axis=0), self.axis)

    def shard(self, x):
        i = jax.lax.axis_index(self.axis)
        return jax.lax.dynamic_slice_in_dim(x, i * self.n_local, self.n_local, 0)


# ==========================================================================
# Round context + degradation events
# ==========================================================================
#: `History.events` bit flags (per-round int32 bitmask, OR-combined).
EVENT_NONE = 0
#: faults shrank the round's surviving cohort below its τ target
EVENT_DEGRADED = 1
#: the force-one-client fallback engaged (empty cohort after the draw/faults)
EVENT_FORCED = 2
#: no client was available at all — the round stalls (nothing participates)
EVENT_ALL_DOWN = 4


@dataclasses.dataclass
class RoundCtx:
    """Per-round traced context handed to `MethodSpec.step`.

    ``key`` is the round's PRNG key (replicated), ``t`` the absolute
    0-based round index, and ``avail`` an optional fleet-wide ``(n,)`` bool
    availability mask from the fault-injection layer (`repro.core.faults`)
    — ``None`` (the batch driver) means every client is reachable.
    ``avail`` is *fleet-wide and replicated* like the participation draws;
    spec code shards it through the `Reducer` where needed."""

    key: jax.Array
    t: jax.Array
    avail: "jax.Array | None" = None


# ==========================================================================
# Round-step combinators
# ==========================================================================
def shift_update(compress: Callable, target: jax.Array, shift: jax.Array,
                 alpha: float) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """One step of the compressed-difference shift recursion (Alg. 1 core):

        S = C(target − L),   L ← L + α·S.

    `compress` maps a delta tensor to (compressed_dense, aux) where aux is
    whatever the codec reports (message `Counts` for core compressors; the
    caller prices them via `comm.price`).  Returns (S, new_shift, aux).
    Contractive compressors use α = 1, unbiased ones α = 1/(ω+1).  This is
    the single mechanism shared by the GLM methods (Hessian-coefficient
    learning) and `repro.fed.bldnn` (gradient and Fisher-diagonal
    learning)."""
    S, aux = compress(target - shift)
    return S, shift + alpha * S, aux


def tree_shift_update(compress: Callable, target, shift,
                      alpha: float) -> Tuple[object, object, tuple]:
    """`shift_update` mapped over parameter *pytrees* (the BL-DNN layout):
    one compressed-difference recursion per leaf, aux records kept per leaf.

    Args:
      compress: ``compress(i, delta) -> (dense, aux)`` — compresses leaf
        ``i``'s client-stacked delta tensor.  The leaf index is a static
        Python int, so callers can close over per-leaf compressors (Top-K
        budgets scale with leaf size) and per-leaf PRNG keys.
      target, shift: pytrees of identical structure; leaves are
        client-stacked ``(n_local, ...)`` arrays.
      alpha: shared shift learning rate.

    Returns:
      ``(S, new_shift, auxs)`` — two pytrees shaped like the inputs plus a
      tuple of per-leaf aux records in leaf order (message `Counts` for the
      core compressors; price each against its compressor's wire and sum
      into ONE `comm.CommLedger` leg — per-leaf counts never grow their own
      billing scheme).
    """
    t_leaves, treedef = jax.tree_util.tree_flatten(target)
    s_leaves = jax.tree_util.tree_leaves(shift)
    if len(t_leaves) != len(s_leaves):
        raise ValueError(
            f"target/shift leaf mismatch: {len(t_leaves)} vs {len(s_leaves)}")
    outs = [shift_update(lambda d, i=i: compress(i, d), t, s, alpha)
            for i, (t, s) in enumerate(zip(t_leaves, s_leaves))]
    S = treedef.unflatten([o[0] for o in outs])
    new_shift = treedef.unflatten([o[1] for o in outs])
    return S, new_shift, tuple(o[2] for o in outs)


def participation(R: Reducer, key: jax.Array, tau: int,
                  avail: "jax.Array | None" = None
                  ) -> Tuple[jax.Array, jax.Array]:
    """Bernoulli(τ/n) participation mask for this shard's clients, with the
    reference backend's force-one-client fallback (drawn fleet-wide from the
    replicated key, then sharded).

    τ is validated statically: τ < 1 raises `ValueError` (a Bernoulli(0)
    fleet would silently degenerate to the forced client every round) and
    τ > n clamps to full participation (bitwise-harmless: Bernoulli(p) with
    p ≥ 1 is always-true either way).

    ``avail`` is an optional fleet-wide ``(n,)`` bool availability mask from
    the fault layer (`RoundCtx.avail`): drawn participants that are down
    this round are removed, and when the surviving cohort is empty the
    fallback forces one *available* client instead.  ``avail`` of all-ones
    reproduces the unmasked path bitwise (mask and fallback index alike).

    Returns ``(mask, event)`` — the shard-local participation mask plus a
    replicated int32 `EVENT_*` bitmask for the round (`EVENT_DEGRADED` when
    faults pushed the cohort below τ, `EVENT_FORCED` when the fallback
    engaged, `EVENT_ALL_DOWN` when no client was available and the round
    stalls with an all-false mask).

    The mask and the fallback index come from SPLIT keys: reusing one key
    for both correlates the forced client with the mask draw (the reference
    backend mirrors this split, so parity stays bitwise)."""
    tau = int(tau)
    if tau < 1:
        raise ValueError(
            f"participation needs τ ≥ 1 expected clients per round, got "
            f"τ={tau} — pass τ in [1, n] (τ=n is full participation)")
    tau = min(tau, R.n)
    k_mask, k_idx = jax.random.split(key)
    drawn = jax.random.bernoulli(k_mask, tau / R.n, (R.n,))
    idx = jax.random.randint(k_idx, (), 0, R.n)
    if avail is None:
        forced = ~drawn.any() & (jnp.arange(R.n) == idx)
        event = jnp.where(forced.any(), EVENT_FORCED, EVENT_NONE)
        return R.shard(drawn | forced), event.astype(jnp.int32)
    avail = jnp.asarray(avail, bool)
    n_avail = jnp.sum(avail)
    surviving = drawn & avail
    n_surv = jnp.sum(surviving)
    # fallback index rotated onto the available subset: with avail all-ones
    # cumsum(avail) == idx+1 first holds exactly at position idx, so the
    # masked path degenerates to the unmasked one bitwise
    pick = avail & (jnp.cumsum(avail) == idx % jnp.maximum(n_avail, 1) + 1)
    need_force = (n_surv == 0) & (n_avail > 0)
    part = surviving | (need_force & pick)
    event = (EVENT_DEGRADED * ((n_surv < jnp.sum(drawn)) & (n_surv < tau))
             + EVENT_FORCED * need_force
             + EVENT_ALL_DOWN * (n_avail == 0))
    return R.shard(part), event.astype(jnp.int32)


def xi_mask(R: Reducer, key: jax.Array, p: float) -> jax.Array:
    """Per-client ξ ~ Bernoulli(p) gradient-refresh mask (local slice)."""
    if p >= 1.0:
        return jnp.ones((R.n_local,), bool)
    return R.shard(jax.random.bernoulli(key, p, (R.n,)))


def xi_scalar(key: jax.Array, p: float) -> jax.Array:
    """Fleet-wide scalar ξ (BL1's single gradient-leg switch)."""
    if p >= 1.0:
        return jnp.asarray(True)
    return jax.random.bernoulli(key, p, (1,))[0]


def downlink_broadcast(R: Reducer, comp, key: jax.Array, z: jax.Array,
                       x_target: jax.Array, eta: float, part: jax.Array):
    """Compressed model-stream downlink to participating clients:
    z_i ← z_i + η·C_i(x − z_i).  Returns (z_new, down_bits_per_node)."""
    v, counts = comp.compress(R.client_keys(key), x_target[None, :] - z)
    vbits = comm.price(comp.wire, counts)
    z_n = jnp.where(part[:, None], z + eta * v, z)
    return z_n, R.sum(jnp.where(part, vbits, 0.0)) / R.n


def global_grad(R: Reducer, batch, x: jax.Array) -> jax.Array:
    return R.mean(client_batch.grads(batch, x))

# NOTE: there is deliberately no in-scan global_loss combinator — specs emit
# evaluation iterates and the engine evaluates the whole trajectory outside
# the scan (`MethodSpec.eval_streams`, default `default_gap_stream`); an
# in-scan loss evaluation compiles differently under shard_map and would
# break the cross-backend bitwise contract.


# ==========================================================================
# Coefficient layouts (§2.3): block (n, r, r) vs full (n, d, d)
# ==========================================================================
@dataclasses.dataclass
class CoeffLayout:
    """How Hessian-coefficient state is laid out on this run.

    `target_at(z)` gives the per-client coefficient target h^i(∇²f_i(z)),
    `recon(S)` maps coefficient-space updates back to (n_local, d, d)
    Hessian space, `shape` is the local coefficient-state shape, and
    `ridge` is the analytic λI the server adds for data bases."""

    target_at: Callable
    recon: Callable
    shape: Tuple[int, ...]
    ridge: jax.Array


def coeff_layout(R: Reducer, batch, basisb, x0: jax.Array,
                 block: bool) -> CoeffLayout:
    d = batch.d
    lam = batch.lam
    if block:
        # §2.3 block mode (data basis only): state stays (n, r, r) and the
        # d×d data Hessian is never materialized (Γ = (AV)ᵀD(AV)/m).
        AV = client_batch.basis_AV(basisb, batch)
        rb = basisb.r_max
        return CoeffLayout(
            target_at=lambda z: client_batch.hess_coeff_block(basisb, batch, z, AV),
            recon=lambda S: client_batch.reconstruct_block(basisb, S),
            shape=(R.n_local, rb, rb),
            ridge=lam * jnp.eye(d, dtype=x0.dtype),
        )
    ridge = (lam * jnp.eye(d, dtype=x0.dtype)
             if basisb.kind == "data_outer" else jnp.zeros((d, d), x0.dtype))
    return CoeffLayout(
        target_at=lambda z: client_batch.hess_coeff_target(basisb, batch, z),
        recon=basisb.reconstruct,
        shape=(R.n_local, d, d),
        ridge=ridge,
    )


# ==========================================================================
# Driver: one jitted scan over rounds, per (spec, reducer) pair
# ==========================================================================
@dataclasses.dataclass
class Env:
    """Per-run traced context handed to spec.init/step (not a scan carry)."""

    batch: object
    basisb: object
    x0: jax.Array
    extra: object  # spec-specific precomputation (e.g. a CoeffLayout)


@dataclasses.dataclass(frozen=True)
class StreamHook:
    """Mid-sweep instrumentation hook for long runs (`repro.exp` sweeps).

    The engine emits ``callback(t, eval_x, ledger)`` from inside the scan via
    `jax.debug.callback` every ``every`` rounds — ``t`` is the 0-based round
    index, ``eval_x`` the round's evaluation iterate ``(d,)`` and ``ledger``
    the cumulative per-leg `comm.CommLedger` at that round.  Emission is
    asynchronous host-side instrumentation only: the recorded `History`
    still comes from the full post-scan gap evaluation, so trajectories and
    gap streams are unchanged by attaching a hook.  Only supported on the
    single-device backend — a shard_map callback would fire once per device
    with shard-local values, so `run_rounds(sharded=True, stream=...)`
    raises `ValueError` at dispatch instead of failing deep inside the
    sharded scan.

    The hook is a *static* jit argument: each distinct hook instance
    compiles its own engine program (stream-less runs keep sharing the
    original cache), so attach hooks to long runs, not micro-benches.
    """

    every: int
    callback: Callable

    def _emit(self, t, eval_x, ledger):
        self.callback(int(t), eval_x, ledger)


def _engine(spec, R: Reducer, batch, basisb, x0, keys, stream=None):
    env = Env(batch=batch, basisb=basisb, x0=x0,
              extra=spec.prepare(R, batch, basisb, x0))
    carry0 = spec.init(R, env)

    def step(carry, xt):
        t, key_t = xt
        carry, ys = spec.step(R, env, carry, RoundCtx(key=key_t, t=t))
        if stream is not None:
            # only ship (t, eval_x, ledger) to the host on emitting rounds
            jax.lax.cond(
                t % stream.every == 0,
                lambda: jax.debug.callback(stream._emit, t, ys[0], ys[1]),
                lambda: None)
        return carry, ys

    ts = jnp.arange(keys.shape[0])
    _, ys = jax.lax.scan(step, carry0, (ts, keys))
    # ys = (eval_x (steps, d), CommLedger of (steps,) per-leg streams,
    # events (steps,) int32 EVENT_* bitmasks — all-zero without a fault
    # layer, so the batch path drops them).
    # Specs emit the round's evaluation iterate, not the gap: loss
    # evaluation is instrumentation, and computing it outside the scan
    # (a) vectorizes it over all rounds and (b) keeps the gap stream
    # bitwise-identical across aggregation backends (XLA fuses in-scan loss
    # evaluation differently inside shard_map, wobbling the reported gap by
    # an ulp even though the trajectory itself is bitwise-invariant).
    return ys


_engine_jit = functools.partial(
    jax.jit, static_argnames=("spec", "R", "stream"))(_engine)


@jax.jit
def default_gap_stream(batch, xs_t, f_star):
    """f(x_t) − f* for a whole (steps, d) GLM trajectory in one vmapped
    pass — the default `MethodSpec.eval_streams` evaluation.

    Shared by both aggregation backends — same program + bitwise-identical
    iterates ⇒ bitwise-identical gap histories."""
    return jax.vmap(lambda x: jnp.mean(client_batch.losses(batch, x)))(xs_t) - f_star


@functools.lru_cache(maxsize=None)
def _sharded_engine(spec, R: ShardMapReducer, mesh):
    """One jitted shard_map program per (spec, reducer, mesh) config.

    Specs with ``basis_replicated = True`` (pytree bases shared by the
    whole fleet, e.g. BL-DNN's `PerLayerSVDBasis`) get a replicated basis
    in_spec; the default shards the basis's leading client axis like the
    data batch."""
    from jax.experimental.shard_map import shard_map

    from repro.sharding.rules import client_engine_specs

    in_specs, out_specs = client_engine_specs(
        basis_replicated=getattr(spec, "basis_replicated", False))
    body = functools.partial(_engine, spec, R)
    return jax.jit(shard_map(body, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_rep=False))


def run_rounds(spec, batch, basisb, x0, f_star, keys, *,
               sharded: bool = False, exact: bool = True,
               stream: "StreamHook | None" = None):
    """Run `steps = len(keys)` rounds of `spec` and return the history
    streams ``(evals, CommLedger-of-streams)``: ``evals`` is the dict the
    spec's ``eval_streams`` hook derives from the trajectory (always
    containing ``"gap"``; pytree specs add extra named streams such as
    ``"loss"``), the ledger carries one per-leg bit stream per
    `comm.CommLedger` leg.

    sharded=False → `VmapReducer` on the default device.
    sharded=True  → `ShardMapReducer` over a 1-D client mesh spanning the
    most local devices that evenly divide the client count (a 1-device
    world still exercises the shard_map code path).

    stream — optional `StreamHook` emitting (round, eval_x, ledger) to the
    host mid-scan (progress reporting for `repro.exp` sweeps).  Raises
    `ValueError` on the sharded backend (see `StreamHook`)."""
    if not sharded:
        xs_t, leds, _events = _engine_jit(spec, VmapReducer(n=batch.n), batch,
                                          basisb, x0, keys, stream=stream)
    else:
        if stream is not None:
            raise ValueError(
                "StreamHook is unsupported on the sharded aggregation "
                "backend (ShardMapReducer, backend='fast+sharded'): a "
                "shard_map debug callback fires once per device with "
                "shard-local values.  Run the cell on the single-device "
                "backend (backend='fast') to stream progress, or disable "
                "streaming (--progress-every 0).")
        from repro.launch.mesh import make_client_mesh

        mesh, ndev = make_client_mesh(batch.n)
        R = ShardMapReducer(n=batch.n, ndev=ndev, exact=exact)
        xs_t, leds, _events = _sharded_engine(spec, R, mesh)(
            batch, basisb, x0, keys)
        # outputs come back committed to the client mesh; rehome them so the
        # gap evaluation below is the same default-device program on every
        # backend (this is what makes the histories bitwise-comparable)
        import numpy as np

        xs_t, leds = jax.tree.map(lambda a: jnp.asarray(np.asarray(a)),
                                  (xs_t, leds))
    evals = spec.eval_streams(batch, xs_t, f_star)
    return evals, leds


# ==========================================================================
# Chunked service-loop driver (repro.launch.fed_serve)
# ==========================================================================
def _with_client_dim(tree, n_new: int):
    """Abstract (shape-only) copy of a client-stacked pytree with the
    leading client axis resized — every leaf of `ClientBatch` /
    `BatchedBasis` / `TreeBatch` carries the client axis first (static aux
    like ``lam`` is not a leaf and survives unflattening untouched)."""
    return jax.tree.map(
        lambda l: jax.ShapeDtypeStruct((n_new,) + tuple(l.shape[1:]),
                                       l.dtype), tree)


def _init_body(spec, R: Reducer, batch, basisb, x0):
    env = Env(batch=batch, basisb=basisb, x0=x0,
              extra=spec.prepare(R, batch, basisb, x0))
    return spec.init(R, env)


_init_jit = functools.partial(jax.jit, static_argnames=("spec", "R"))(_init_body)


def carry_client_flags(spec, batch, basisb, x0):
    """Which carry leaves are client-stacked — the carry serialization /
    sharding contract for the chunked driver.

    Derived structurally, with no per-spec declarations: `spec.init` is
    shape-evaluated twice (at n and at 2n clients) and exactly the leaves
    whose shape moved carry the client axis.  This disambiguates d == n
    coincidences and works for any spec the engine can run.  Returns a
    bool pytree shaped like the carry."""
    n = batch.n

    def init_at(b, bb, nn):
        return _init_body(spec, VmapReducer(n=nn), b, bb, x0)

    s1 = jax.eval_shape(functools.partial(init_at, nn=n), batch, basisb)
    b2 = _with_client_dim(batch, 2 * n)
    bb2 = (basisb if basisb is None
           or getattr(spec, "basis_replicated", False)
           else _with_client_dim(basisb, 2 * n))
    s2 = jax.eval_shape(functools.partial(init_at, nn=2 * n), b2, bb2)
    return jax.tree.map(lambda a, b: a.shape != b.shape, s1, s2)


def _flags_key(flags):
    """Hashable (leaves, treedef) form of a carry-flags pytree — the cache
    key for the per-(spec, reducer, mesh) sharded chunk programs."""
    leaves, treedef = jax.tree_util.tree_flatten(flags)
    return tuple(leaves), treedef


def _chunk_body(spec, R: Reducer, batch, basisb, x0, carry, ts, root_key,
                avail):
    env = Env(batch=batch, basisb=basisb, x0=x0,
              extra=spec.prepare(R, batch, basisb, x0))

    def step(carry, xt):
        t, avail_t = xt
        rc = RoundCtx(key=jax.random.fold_in(root_key, t), t=t,
                      avail=avail_t)
        return spec.step(R, env, carry, rc)

    return jax.lax.scan(step, carry, (ts, avail))


_chunk_jit = functools.partial(
    jax.jit, static_argnames=("spec", "R"))(_chunk_body)


@functools.lru_cache(maxsize=None)
def _sharded_chunk_fns(spec, R: "ShardMapReducer", mesh, flags_key):
    """Jitted shard_map (init, chunk) programs whose carry crosses the
    shard_map boundary: client-stacked carry leaves shard over the mesh,
    everything else is replicated (per `carry_client_flags`)."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.sharding.rules import CLIENT_AXIS, client_chunk_specs

    leaves, treedef = flags_key
    carry_specs = jax.tree_util.tree_unflatten(
        treedef, [P(CLIENT_AXIS) if f else P() for f in leaves])
    in_specs, out_specs = client_chunk_specs(
        carry_specs,
        basis_replicated=getattr(spec, "basis_replicated", False))
    init = jax.jit(shard_map(
        functools.partial(_init_body, spec, R), mesh=mesh,
        in_specs=in_specs[:3], out_specs=carry_specs, check_rep=False))
    chunk = jax.jit(shard_map(
        functools.partial(_chunk_body, spec, R), mesh=mesh,
        in_specs=in_specs, out_specs=out_specs, check_rep=False))
    return init, chunk


def _serve_backend(spec, batch, basisb, x0, sharded: bool, exact: bool):
    if not sharded:
        R = VmapReducer(n=batch.n)
        return (functools.partial(_init_jit, spec, R),
                functools.partial(_chunk_jit, spec, R))
    from repro.launch.mesh import make_client_mesh

    mesh, ndev = make_client_mesh(batch.n)
    R = ShardMapReducer(n=batch.n, ndev=ndev, exact=exact)
    flags = carry_client_flags(spec, batch, basisb, x0)
    init, chunk = _sharded_chunk_fns(spec, R, mesh, _flags_key(flags))
    return init, chunk


def init_serve_carry(spec, batch, basisb, x0, *, sharded: bool = False,
                     exact: bool = True):
    """The round-0 scan carry as an explicit (global) pytree — the state the
    service loop checkpoints.  Its structure and leaf shapes/dtypes ARE the
    carry serialization contract: `repro.exp.artifacts.save_checkpoint`
    stores the flattened leaves and restore validates them against a fresh
    `init_serve_carry` shape evaluation, so an incompatible spec change
    fails loudly instead of resuming garbage."""
    init, _ = _serve_backend(spec, batch, basisb, x0, sharded, exact)
    return init(batch, basisb, x0)


def run_chunk(spec, batch, basisb, x0, carry, t0: int, steps: int, root_key,
              *, avail=None, sharded: bool = False, exact: bool = True):
    """Run `steps` rounds starting at absolute round `t0` from an explicit
    carry; returns ``(carry, (eval_x stream, CommLedger of per-leg streams,
    events stream))`` with the new carry ready for the next chunk (or for a
    checkpoint).

    Per-round keys are ``fold_in(root_key, t)`` — a pure function of the
    absolute round index — so a trajectory is invariant to chunk boundaries
    and a run resumed from a checkpoint at any boundary is bit-exactly the
    uninterrupted run.  ``avail`` is an optional ``(steps, n)`` bool
    availability schedule from the fault layer (`repro.core.faults`); rows
    reach specs as `RoundCtx.avail`.  An all-ones schedule (the default) is
    bitwise-equivalent to no fault layer at all.

    Chunk programs compile once per (spec, backend, chunk length); the
    service loop reuses one length for every full chunk, so only a trailing
    partial chunk costs a second compile."""
    ts = jnp.arange(t0, t0 + steps)
    if avail is None:
        avail = jnp.ones((steps, batch.n), bool)
    avail = jnp.asarray(avail, bool)
    if avail.shape != (steps, batch.n):
        raise ValueError(
            f"avail schedule must be (steps, n) = ({steps}, {batch.n}), "
            f"got {avail.shape}")
    _, chunk = _serve_backend(spec, batch, basisb, x0, sharded, exact)
    return chunk(batch, basisb, x0, carry, ts, root_key, avail)
