"""Unified round engine: combinators + pluggable client-sharded aggregation.

Every method in this repo — BL1/BL2/BL3 (Algorithms 1–3), the FedNL family
they extend, and the first/second-order baselines — shares one round
skeleton: local Hessian/gradient compute → compressed-difference uplink →
server aggregate → (compressed) downlink.  This module factors that skeleton
into three pieces:

  1. **Combinators** — the shared round steps as small pure functions over
     client-stacked arrays: the compressed-shift recursion L ← L + αC(·−L)
     (`shift_update`; `tree_shift_update` maps it over parameter *pytrees*
     for the BL-DNN coefficient layout, per-leaf aux records summed into
     one ledger leg), Bernoulli participation with the force-one-client
     fallback (`participation`), the ξ gradient-refresh mask (`xi_mask`),
     the compressed model-stream downlink (`downlink_broadcast`), and the
     §2.3 coefficient layouts (`coeff_layout` — compact (n, r, r) blocks
     vs. full d×d) behind one (target_at, recon, ridge) interface.

  2. **Reducers** — the aggregation-backend axis.  All cross-client
     reductions (means/sums/maxes of Hessians, gradients, bit counts) go
     through a `Reducer` so the same method spec runs on two backends:

       * `VmapReducer`      — one device; the client axis is a plain leading
         array axis and reductions are `jnp.mean/sum/max(axis=0)`.
       * `ShardMapReducer`  — clients sharded over the mesh `data` axis
         inside `shard_map`; per-client state carries a leading local axis.
         `exact=True` (default) reduces by `all_gather` + the *identical*
         local reduction, which is bitwise-equal to the single-device
         backend (pinned by tests/test_sharding_multidev.py); `exact=False`
         uses `lax.psum/pmean/pmax`, which is bandwidth-optimal but can
         differ in the last ulp (summation order).

  3. **Driver** — one jitted `lax.scan` over rounds (`run_rounds`).  A
     `MethodSpec` (see `repro.core.specs`) supplies `prepare/init/step`;
     the driver never knows which algorithm it is running.  The sharded
     backend wraps the same scan body in a single `shard_map` over the
     client mesh, so a whole sharded trajectory is still one SPMD program.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Tuple

import jax
import jax.numpy as jnp

from repro.sharding.rules import CLIENT_AXIS

from . import client_batch, comm


# ==========================================================================
# Reducers — the pluggable aggregation backend
# ==========================================================================
@dataclasses.dataclass(frozen=True)
class Reducer:
    """Cross-client reduction interface.  `n` is the GLOBAL client count;
    per-client arrays seen by spec code always carry a leading `n_local`
    axis (== n on the vmap backend, n/ndev inside each shard otherwise)."""

    n: int

    @property
    def n_local(self) -> int:
        raise NotImplementedError

    def mean(self, x: jax.Array) -> jax.Array:
        """(n_local, ...) → (...): mean over the global client axis."""
        raise NotImplementedError

    def sum(self, x: jax.Array) -> jax.Array:
        raise NotImplementedError

    def max(self, x: jax.Array) -> jax.Array:
        raise NotImplementedError

    def shard(self, x: jax.Array) -> jax.Array:
        """Slice a replicated (n, ...) array down to this shard's clients.

        Fleet-wide randomness (participation masks, per-client PRNG keys)
        is always drawn for all n clients from the replicated key and then
        sharded, so every backend sees the same per-client draws."""
        raise NotImplementedError

    def client_keys(self, key: jax.Array) -> jax.Array:
        """Per-client PRNG keys for this shard: (n_local, 2)."""
        return self.shard(jax.random.split(key, self.n))

    def tree_mean(self, tree):
        """`mean` mapped over a pytree of (n_local, ...) leaves — the
        cross-client reduction for pytree coefficient streams (BL-DNN)."""
        return jax.tree.map(self.mean, tree)


@dataclasses.dataclass(frozen=True)
class VmapReducer(Reducer):
    """Single-device backend: the client axis is a plain leading axis."""

    @property
    def n_local(self) -> int:
        return self.n

    def mean(self, x):
        return jnp.mean(x, axis=0)

    def sum(self, x):
        return jnp.sum(x, axis=0)

    def max(self, x):
        return jnp.max(x, axis=0)

    def shard(self, x):
        return x


@dataclasses.dataclass(frozen=True)
class ShardMapReducer(Reducer):
    """Mesh backend: clients sharded over `axis` inside `shard_map`.

    exact=True reduces by `all_gather` + the same local reduction as
    `VmapReducer` — bitwise-identical trajectories to the single-device
    fast path.  exact=False reduces with `lax.psum/pmean/pmax` (less wire
    traffic, last-ulp summation-order differences)."""

    ndev: int = 1
    axis: str = CLIENT_AXIS
    exact: bool = True

    @property
    def n_local(self) -> int:
        return self.n // self.ndev

    def _gather(self, x):
        return jax.lax.all_gather(x, self.axis, axis=0, tiled=True)

    def mean(self, x):
        if self.exact:
            return jnp.mean(self._gather(x), axis=0)
        return jax.lax.pmean(jnp.sum(x, axis=0), self.axis) / self.n_local

    def sum(self, x):
        if self.exact:
            return jnp.sum(self._gather(x), axis=0)
        return jax.lax.psum(jnp.sum(x, axis=0), self.axis)

    def max(self, x):
        if self.exact:
            return jnp.max(self._gather(x), axis=0)
        return jax.lax.pmax(jnp.max(x, axis=0), self.axis)

    def shard(self, x):
        i = jax.lax.axis_index(self.axis)
        return jax.lax.dynamic_slice_in_dim(x, i * self.n_local, self.n_local, 0)


# ==========================================================================
# Round-step combinators
# ==========================================================================
def shift_update(compress: Callable, target: jax.Array, shift: jax.Array,
                 alpha: float) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """One step of the compressed-difference shift recursion (Alg. 1 core):

        S = C(target − L),   L ← L + α·S.

    `compress` maps a delta tensor to (compressed_dense, aux) where aux is
    whatever the codec reports (message `Counts` for core compressors; the
    caller prices them via `comm.price`).  Returns (S, new_shift, aux).
    Contractive compressors use α = 1, unbiased ones α = 1/(ω+1).  This is
    the single mechanism shared by the GLM methods (Hessian-coefficient
    learning) and `repro.fed.bldnn` (gradient and Fisher-diagonal
    learning)."""
    S, aux = compress(target - shift)
    return S, shift + alpha * S, aux


def tree_shift_update(compress: Callable, target, shift,
                      alpha: float) -> Tuple[object, object, tuple]:
    """`shift_update` mapped over parameter *pytrees* (the BL-DNN layout):
    one compressed-difference recursion per leaf, aux records kept per leaf.

    Args:
      compress: ``compress(i, delta) -> (dense, aux)`` — compresses leaf
        ``i``'s client-stacked delta tensor.  The leaf index is a static
        Python int, so callers can close over per-leaf compressors (Top-K
        budgets scale with leaf size) and per-leaf PRNG keys.
      target, shift: pytrees of identical structure; leaves are
        client-stacked ``(n_local, ...)`` arrays.
      alpha: shared shift learning rate.

    Returns:
      ``(S, new_shift, auxs)`` — two pytrees shaped like the inputs plus a
      tuple of per-leaf aux records in leaf order (message `Counts` for the
      core compressors; price each against its compressor's wire and sum
      into ONE `comm.CommLedger` leg — per-leaf counts never grow their own
      billing scheme).
    """
    t_leaves, treedef = jax.tree_util.tree_flatten(target)
    s_leaves = jax.tree_util.tree_leaves(shift)
    if len(t_leaves) != len(s_leaves):
        raise ValueError(
            f"target/shift leaf mismatch: {len(t_leaves)} vs {len(s_leaves)}")
    outs = [shift_update(lambda d, i=i: compress(i, d), t, s, alpha)
            for i, (t, s) in enumerate(zip(t_leaves, s_leaves))]
    S = treedef.unflatten([o[0] for o in outs])
    new_shift = treedef.unflatten([o[1] for o in outs])
    return S, new_shift, tuple(o[2] for o in outs)


def participation(R: Reducer, key: jax.Array, tau: int) -> jax.Array:
    """Bernoulli(τ/n) participation mask for this shard's clients, with the
    reference backend's force-one-client fallback (drawn fleet-wide from the
    replicated key, then sharded).

    The mask and the fallback index come from SPLIT keys: reusing one key
    for both correlates the forced client with the mask draw (the reference
    backend mirrors this split, so parity stays bitwise)."""
    k_mask, k_idx = jax.random.split(key)
    part = jax.random.bernoulli(k_mask, tau / R.n, (R.n,))
    idx = jax.random.randint(k_idx, (), 0, R.n)
    part = part | (~part.any() & (jnp.arange(R.n) == idx))
    return R.shard(part)


def xi_mask(R: Reducer, key: jax.Array, p: float) -> jax.Array:
    """Per-client ξ ~ Bernoulli(p) gradient-refresh mask (local slice)."""
    if p >= 1.0:
        return jnp.ones((R.n_local,), bool)
    return R.shard(jax.random.bernoulli(key, p, (R.n,)))


def xi_scalar(key: jax.Array, p: float) -> jax.Array:
    """Fleet-wide scalar ξ (BL1's single gradient-leg switch)."""
    if p >= 1.0:
        return jnp.asarray(True)
    return jax.random.bernoulli(key, p, (1,))[0]


def downlink_broadcast(R: Reducer, comp, key: jax.Array, z: jax.Array,
                       x_target: jax.Array, eta: float, part: jax.Array):
    """Compressed model-stream downlink to participating clients:
    z_i ← z_i + η·C_i(x − z_i).  Returns (z_new, down_bits_per_node)."""
    v, counts = comp.compress(R.client_keys(key), x_target[None, :] - z)
    vbits = comm.price(comp.wire, counts)
    z_n = jnp.where(part[:, None], z + eta * v, z)
    return z_n, R.sum(jnp.where(part, vbits, 0.0)) / R.n


def global_grad(R: Reducer, batch, x: jax.Array) -> jax.Array:
    return R.mean(client_batch.grads(batch, x))

# NOTE: there is deliberately no in-scan global_loss combinator — specs emit
# evaluation iterates and the engine evaluates the whole trajectory outside
# the scan (`MethodSpec.eval_streams`, default `default_gap_stream`); an
# in-scan loss evaluation compiles differently under shard_map and would
# break the cross-backend bitwise contract.


# ==========================================================================
# Coefficient layouts (§2.3): block (n, r, r) vs full (n, d, d)
# ==========================================================================
@dataclasses.dataclass
class CoeffLayout:
    """How Hessian-coefficient state is laid out on this run.

    `target_at(z)` gives the per-client coefficient target h^i(∇²f_i(z)),
    `recon(S)` maps coefficient-space updates back to (n_local, d, d)
    Hessian space, `shape` is the local coefficient-state shape, and
    `ridge` is the analytic λI the server adds for data bases."""

    target_at: Callable
    recon: Callable
    shape: Tuple[int, ...]
    ridge: jax.Array


def coeff_layout(R: Reducer, batch, basisb, x0: jax.Array,
                 block: bool) -> CoeffLayout:
    d = batch.d
    lam = batch.lam
    if block:
        # §2.3 block mode (data basis only): state stays (n, r, r) and the
        # d×d data Hessian is never materialized (Γ = (AV)ᵀD(AV)/m).
        AV = client_batch.basis_AV(basisb, batch)
        rb = basisb.r_max
        return CoeffLayout(
            target_at=lambda z: client_batch.hess_coeff_block(basisb, batch, z, AV),
            recon=lambda S: client_batch.reconstruct_block(basisb, S),
            shape=(R.n_local, rb, rb),
            ridge=lam * jnp.eye(d, dtype=x0.dtype),
        )
    ridge = (lam * jnp.eye(d, dtype=x0.dtype)
             if basisb.kind == "data_outer" else jnp.zeros((d, d), x0.dtype))
    return CoeffLayout(
        target_at=lambda z: client_batch.hess_coeff_target(basisb, batch, z),
        recon=basisb.reconstruct,
        shape=(R.n_local, d, d),
        ridge=ridge,
    )


# ==========================================================================
# Driver: one jitted scan over rounds, per (spec, reducer) pair
# ==========================================================================
@dataclasses.dataclass
class Env:
    """Per-run traced context handed to spec.init/step (not a scan carry)."""

    batch: object
    basisb: object
    x0: jax.Array
    extra: object  # spec-specific precomputation (e.g. a CoeffLayout)


@dataclasses.dataclass(frozen=True)
class StreamHook:
    """Mid-sweep instrumentation hook for long runs (`repro.exp` sweeps).

    The engine emits ``callback(t, eval_x, ledger)`` from inside the scan via
    `jax.debug.callback` every ``every`` rounds — ``t`` is the 0-based round
    index, ``eval_x`` the round's evaluation iterate ``(d,)`` and ``ledger``
    the cumulative per-leg `comm.CommLedger` at that round.  Emission is
    asynchronous host-side instrumentation only: the recorded `History`
    still comes from the full post-scan gap evaluation, so trajectories and
    gap streams are unchanged by attaching a hook.  Only supported on the
    single-device backend — a shard_map callback would fire once per device
    with shard-local values, so `run_rounds(sharded=True, stream=...)`
    raises `ValueError` at dispatch instead of failing deep inside the
    sharded scan.

    The hook is a *static* jit argument: each distinct hook instance
    compiles its own engine program (stream-less runs keep sharing the
    original cache), so attach hooks to long runs, not micro-benches.
    """

    every: int
    callback: Callable

    def _emit(self, t, eval_x, ledger):
        self.callback(int(t), eval_x, ledger)


def _engine(spec, R: Reducer, batch, basisb, x0, keys, stream=None):
    env = Env(batch=batch, basisb=basisb, x0=x0,
              extra=spec.prepare(R, batch, basisb, x0))
    carry0 = spec.init(R, env)

    def step(carry, xt):
        t, key_t = xt
        carry, ys = spec.step(R, env, carry, key_t)
        if stream is not None:
            # only ship (t, eval_x, ledger) to the host on emitting rounds
            jax.lax.cond(
                t % stream.every == 0,
                lambda: jax.debug.callback(stream._emit, t, ys[0], ys[1]),
                lambda: None)
        return carry, ys

    ts = jnp.arange(keys.shape[0])
    _, ys = jax.lax.scan(step, carry0, (ts, keys))
    # ys = (eval_x (steps, d), CommLedger of (steps,) per-leg streams).
    # Specs emit the round's evaluation iterate, not the gap: loss
    # evaluation is instrumentation, and computing it outside the scan
    # (a) vectorizes it over all rounds and (b) keeps the gap stream
    # bitwise-identical across aggregation backends (XLA fuses in-scan loss
    # evaluation differently inside shard_map, wobbling the reported gap by
    # an ulp even though the trajectory itself is bitwise-invariant).
    return ys


_engine_jit = functools.partial(
    jax.jit, static_argnames=("spec", "R", "stream"))(_engine)


@jax.jit
def default_gap_stream(batch, xs_t, f_star):
    """f(x_t) − f* for a whole (steps, d) GLM trajectory in one vmapped
    pass — the default `MethodSpec.eval_streams` evaluation.

    Shared by both aggregation backends — same program + bitwise-identical
    iterates ⇒ bitwise-identical gap histories."""
    return jax.vmap(lambda x: jnp.mean(client_batch.losses(batch, x)))(xs_t) - f_star


@functools.lru_cache(maxsize=None)
def _sharded_engine(spec, R: ShardMapReducer, mesh):
    """One jitted shard_map program per (spec, reducer, mesh) config.

    Specs with ``basis_replicated = True`` (pytree bases shared by the
    whole fleet, e.g. BL-DNN's `PerLayerSVDBasis`) get a replicated basis
    in_spec; the default shards the basis's leading client axis like the
    data batch."""
    from jax.experimental.shard_map import shard_map

    from repro.sharding.rules import client_engine_specs

    in_specs, out_specs = client_engine_specs(
        basis_replicated=getattr(spec, "basis_replicated", False))
    body = functools.partial(_engine, spec, R)
    return jax.jit(shard_map(body, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_rep=False))


def run_rounds(spec, batch, basisb, x0, f_star, keys, *,
               sharded: bool = False, exact: bool = True,
               stream: "StreamHook | None" = None):
    """Run `steps = len(keys)` rounds of `spec` and return the history
    streams ``(evals, CommLedger-of-streams)``: ``evals`` is the dict the
    spec's ``eval_streams`` hook derives from the trajectory (always
    containing ``"gap"``; pytree specs add extra named streams such as
    ``"loss"``), the ledger carries one per-leg bit stream per
    `comm.CommLedger` leg.

    sharded=False → `VmapReducer` on the default device.
    sharded=True  → `ShardMapReducer` over a 1-D client mesh spanning the
    most local devices that evenly divide the client count (a 1-device
    world still exercises the shard_map code path).

    stream — optional `StreamHook` emitting (round, eval_x, ledger) to the
    host mid-scan (progress reporting for `repro.exp` sweeps).  Raises
    `ValueError` on the sharded backend (see `StreamHook`)."""
    if not sharded:
        xs_t, leds = _engine_jit(spec, VmapReducer(n=batch.n), batch,
                                 basisb, x0, keys, stream=stream)
    else:
        if stream is not None:
            raise ValueError(
                "StreamHook is unsupported on the sharded backend: a "
                "shard_map debug callback fires once per device with "
                "shard-local values — run with sharded=False to stream "
                "progress, or drop the hook (see rounds.StreamHook)")
        from repro.launch.mesh import make_client_mesh

        mesh, ndev = make_client_mesh(batch.n)
        R = ShardMapReducer(n=batch.n, ndev=ndev, exact=exact)
        xs_t, leds = _sharded_engine(spec, R, mesh)(batch, basisb, x0, keys)
        # outputs come back committed to the client mesh; rehome them so the
        # gap evaluation below is the same default-device program on every
        # backend (this is what makes the histories bitwise-comparable)
        import numpy as np

        xs_t, leds = jax.tree.map(lambda a: jnp.asarray(np.asarray(a)),
                                  (xs_t, leds))
    evals = spec.eval_streams(batch, xs_t, f_star)
    return evals, leds
