"""Every method the paper compares against (§6, §A) — same History contract.

Second order: Newton (naive / problem-structure / data-basis implementations,
§2.1–2.3 + §A.4 — the data-basis column communicates r²+r floats/iter per
Table 1's §2.3 block layout), NL1 [Islamov et al. 2021].  FedNL variants
come from `bl.bl1/bl2` with `StandardBasis`; FedNL-BAG below adds the
Bernoulli-aggregation follow-up (arXiv 2206.03588).

First order: GD, DIANA, ADIANA, Local-GD (S-Local-GD's p=q special case), and
a DORE-style bidirectionally-compressed GD with error feedback.  Gradient
compressors obey the same Eq. 6 (contractive) / Eq. 7 (unbiased) contracts
as the Hessian codecs.

Shared conventions: ``clients`` is a sequence of `glm.ClientData`; ``x0``
and ``x_star`` are (d,) arrays (x* the 20-iterate Newton reference
optimum); every function returns a `bl.History` of per-round gaps and
cumulative per-node uplink/downlink bits.
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from . import glm
from .basis import MatrixBasis
from .bl import _BACKENDS, History, _client_hcoef, _server_reconstruct, proj_mu
from .compressors import FLOAT_BITS, Compressor, RandK


def _fstar(clients, x_star):
    return float(glm.global_loss(list(clients), x_star))


def smoothness_constant(clients: Sequence[glm.ClientData]) -> float:
    """L = λ_max(∇²f) upper bound: logistic φ″ ≤ 1/4 ⇒ L ≤ ‖AᵀA‖/(4m) + λ."""
    Ls = []
    for c in clients:
        m = c.A.shape[0]
        s = jnp.linalg.norm(c.A, 2)
        Ls.append(float(s * s) / (4 * m) + c.lam)
    return max(Ls)


# --------------------------------------------------------------------------
# Newton implementations (Table 1's three columns)
# --------------------------------------------------------------------------
def newton(
    clients: Sequence[glm.ClientData],
    x0: jax.Array,
    x_star: jax.Array,
    steps: int,
    bases: Optional[Sequence[MatrixBasis]] = None,
    backend: str = "auto",
) -> History:
    """Classical Newton.  bases=None → naive d² floats/iter (§2.1);
    per-client DataOuterBasis → r²+r floats/iter (§2.3, the §A.4 comparison)."""
    if backend not in _BACKENDS:
        raise ValueError(f"backend must be one of {_BACKENDS}, got {backend!r}")
    if backend != "reference":
        from . import batched

        try:
            return batched.newton_fast(clients, x0, x_star, steps, bases=bases,
                                       sharded=(backend == "fast+sharded"))
        except batched.FastPathUnavailable:
            if backend != "auto":
                raise
    clients = list(clients)
    n = len(clients)
    d = x0.shape[0]
    lam = clients[0].lam
    f_star = _fstar(clients, x_star)
    x = x0
    up = 0.0
    if bases is not None:
        up = sum(float(b.d * b.r * FLOAT_BITS) for b in bases) / n  # ship bases once
    hist = History([], [], [])
    for _ in range(steps):
        hist.append(float(glm.global_loss(clients, x)) - f_star, up, 0.0)
        if bases is None:
            H = glm.global_hess(clients, x)
            g = glm.global_grad(clients, x)
            up += (d * d + d) * FLOAT_BITS
        else:
            # clients send Γ_i = V_iᵀ∇²f_i^data V_i (r² floats) + r grad coeffs
            H = sum(
                _server_reconstruct(bases[i], _client_hcoef(bases[i], clients[i], x), lam)
                for i in range(n)
            ) / n
            g = glm.global_grad(clients, x)
            up += sum(b.r * b.r + b.r for b in bases) / n * FLOAT_BITS
        x = x - jnp.linalg.solve(H, g)
    return hist


def fednl_bag(
    clients: Sequence[glm.ClientData],
    bases: Sequence[MatrixBasis],
    hess_comp: Sequence[Compressor],
    x0: jax.Array,
    x_star: jax.Array,
    steps: int,
    alpha: float = 1.0,
    q: float = 0.5,
    eta: Optional[float] = None,
    mu: Optional[float] = None,
    seed: int = 0,
    init_exact_hessian: bool = True,
    backend: str = "auto",
    exact: bool = True,
) -> History:
    """FedNL with Bernoulli-lazy gradient aggregation (BAG — after arXiv
    2206.03588): the FedNL compressed Hessian-learning recursion plus a
    gradient uplink where each client reports with probability q and the
    server lazily reuses the last reported gradient of silent clients.

    Spec-only method (`specs.FedNLBAGSpec` on the unified round engine);
    there is no op-by-op reference backend — tests pin it against a
    hand-rolled loop instead.
    """
    if backend not in _BACKENDS:
        raise ValueError(f"backend must be one of {_BACKENDS}, got {backend!r}")
    if backend == "reference":
        raise ValueError("fednl_bag is spec-only; no reference backend")
    from . import batched

    try:
        return batched.fednl_bag_fast(
            clients, bases, hess_comp, x0, x_star, steps, alpha=alpha, q=q,
            eta=eta, mu=mu, seed=seed, init_exact_hessian=init_exact_hessian,
            sharded=(backend == "fast+sharded"), exact=exact)
    except batched.FastPathUnavailable as e:
        # "auto" falls back to the reference loops everywhere else; with no
        # reference backend to fall back to, surface a clear error instead
        # of leaking the internal fallback signal
        raise ValueError(
            f"fednl_bag requires a stackable homogeneous fleet ({e})") from e


def nl1(
    clients: Sequence[glm.ClientData],
    x0: jax.Array,
    x_star: jax.Array,
    steps: int,
    k: int = 1,
    seed: int = 0,
) -> History:
    """NewtonLearn-1 [Islamov et al. 2021]: learn the m per-sample φ″
    coefficients with Rand-K (ω = m/K−1, α = 1/(ω+1)).  The server knows the
    training data (the method's stated privacy cost — Table 1)."""
    clients = list(clients)
    n = len(clients)
    d = x0.shape[0]
    lam = clients[0].lam
    f_star = _fstar(clients, x_star)
    key = jax.random.PRNGKey(seed)
    x = x0
    # h_i ∈ R^m learned coefficients, init at x0's true values
    hcoef = [glm.hess_diag_weights(c, x0) for c in clients]
    up = float(clients[0].A.shape[0] * FLOAT_BITS)  # ship h^0 (data assumed known)
    hist = History([], [], [])
    mu = lam

    def H_from(hc):
        total = jnp.zeros((d, d), x0.dtype)
        for i, c in enumerate(clients):
            m = c.A.shape[0]
            total = total + (c.A * hc[i][:, None]).T @ c.A / m
        return total / n + lam * jnp.eye(d, dtype=x0.dtype)

    for _ in range(steps):
        hist.append(float(glm.global_loss(clients, x)) - f_star, up, 0.0)
        g = glm.global_grad(clients, x)
        H = proj_mu(H_from(hcoef), mu)
        x = x - jnp.linalg.solve(H, g)
        step_bits = 0.0
        for i, c in enumerate(clients):
            m = c.A.shape[0]
            comp = RandK(k=k)
            alpha = 1.0 / (m / min(k, m))
            key, sk = jax.random.split(key)
            target = glm.hess_diag_weights(c, x)
            S, bits = comp(sk, target - hcoef[i])
            hcoef[i] = hcoef[i] + alpha * S
            step_bits += float(bits)
        up += step_bits / n + d * FLOAT_BITS  # gradients every step
    return hist


# --------------------------------------------------------------------------
# First-order methods
# --------------------------------------------------------------------------
def gd(clients, x0, x_star, steps, lr: Optional[float] = None,
       backend: str = "auto") -> History:
    """Distributed gradient descent; d floats/node/round uplink.

    Args:
      lr: step size (default 1/L via `smoothness_constant`).
      backend: "auto" | "fast" | "fast+sharded" | "reference".

    Returns a `History` (downlink is uncounted: exact broadcast).
    """
    if backend not in _BACKENDS:
        raise ValueError(f"backend must be one of {_BACKENDS}, got {backend!r}")
    if backend != "reference":
        from . import batched

        try:
            return batched.gd_fast(clients, x0, x_star, steps, lr=lr,
                                   sharded=(backend == "fast+sharded"))
        except batched.FastPathUnavailable:
            if backend != "auto":
                raise
    clients = list(clients)
    d = x0.shape[0]
    f_star = _fstar(clients, x_star)
    L = smoothness_constant(clients)
    lr = 1.0 / L if lr is None else lr
    x = x0
    up = 0.0
    hist = History([], [], [])
    for _ in range(steps):
        hist.append(float(glm.global_loss(clients, x)) - f_star, up, 0.0)
        x = x - lr * glm.global_grad(clients, x)
        up += d * FLOAT_BITS
    return hist


def diana(
    clients,
    x0,
    x_star,
    steps,
    comp: Compressor,
    omega: float,
    lr: Optional[float] = None,
    seed: int = 0,
    backend: str = "auto",
) -> History:
    """DIANA [Mishchenko et al. 2019]: compressed gradient differences with
    local shifts h_i; theoretical stepsizes.

    Args:
      comp: unbiased gradient compressor (Eq. 7), e.g. `RandomDithering`.
      omega: its variance parameter ω (e.g. ``comp.omega_for(d)``).
      lr: step size (default: the paper's theoretical
        min(α_h/2μ, 1/(L(1+6ω/n))) with α_h = 1/(ω+1)).
      seed: PRNG seed for the stochastic compressor draws.

    Returns a `History`; uplink bills the compressed difference messages.
    """
    if backend not in _BACKENDS:
        raise ValueError(f"backend must be one of {_BACKENDS}, got {backend!r}")
    if backend != "reference":
        from . import batched

        try:
            return batched.diana_fast(clients, x0, x_star, steps, comp, omega,
                                      lr=lr, seed=seed,
                                      sharded=(backend == "fast+sharded"))
        except batched.FastPathUnavailable:
            if backend != "auto":
                raise
    clients = list(clients)
    n = len(clients)
    d = x0.shape[0]
    f_star = _fstar(clients, x_star)
    L = smoothness_constant(clients)
    mu = clients[0].lam
    alpha_h = 1.0 / (omega + 1.0)
    lr = min(alpha_h / (2.0 * mu), 1.0 / (L * (1.0 + 6.0 * omega / n))) if lr is None else lr
    key = jax.random.PRNGKey(seed)
    x = x0
    h = [jnp.zeros(d, x0.dtype) for _ in range(n)]
    up = 0.0
    hist = History([], [], [])
    for _ in range(steps):
        hist.append(float(glm.global_loss(clients, x)) - f_star, up, 0.0)
        ghat = jnp.zeros(d, x0.dtype)
        step_bits = 0.0
        for i, c in enumerate(clients):
            key, sk = jax.random.split(key)
            gi = glm.grad(c, x)
            q, bits = comp(sk, gi - h[i])
            ghat = ghat + (h[i] + q) / n
            h[i] = h[i] + alpha_h * q
            step_bits += float(bits)
        x = x - lr * ghat
        up += step_bits / n
    return hist


def adiana(
    clients,
    x0,
    x_star,
    steps,
    comp: Compressor,
    omega: float,
    seed: int = 0,
) -> History:
    """ADIANA [Li et al. 2020, Alg. 1] with the paper's theoretical parameters
    (strongly convex case).

    Args as `diana` (no lr override — the accelerated stepsizes are coupled).
    Reference backend only (no spec/fast path).  Returns a `History`; each
    round bills TWO compressed messages per client (x^k and w^k shifts).
    """
    clients = list(clients)
    n = len(clients)
    d = x0.shape[0]
    f_star = _fstar(clients, x_star)
    L = smoothness_constant(clients)
    mu = clients[0].lam
    key = jax.random.PRNGKey(seed)

    alpha_h = 1.0 / (omega + 1.0)
    if omega == 0:
        eta = 1.0 / (2.0 * L)
    else:
        eta = min(1.0 / (2.0 * L), n / (64.0 * omega * L))
    theta1 = min(1.0 / 4.0, jnp.sqrt(eta * mu / 4.0).item())
    theta2 = 0.5
    gamma = eta / (2.0 * (theta1 + theta2 * eta * mu))
    beta = 1.0 - gamma * mu
    prob = theta2

    x = x0
    y = x0
    zv = x0
    wv = x0
    h = [jnp.zeros(d, x0.dtype) for _ in range(n)]
    h_avg = jnp.zeros(d, x0.dtype)
    up = 0.0
    hist = History([], [], [])
    for _ in range(steps):
        hist.append(float(glm.global_loss(clients, y)) - f_star, up, 0.0)
        xk = theta1 * zv + theta2 * wv + (1 - theta1 - theta2) * y
        ghat = h_avg
        step_bits = 0.0
        for i, c in enumerate(clients):
            key, sk = jax.random.split(key)
            gi = glm.grad(c, xk)
            q, bits = comp(sk, gi - h[i])
            ghat = ghat + q / n
            step_bits += float(bits)
            # shift update against w (ADIANA uses ∇f_i(w) differences)
        # update shifts toward ∇f_i(w^k)
        for i, c in enumerate(clients):
            key, sk = jax.random.split(key)
            gw = glm.grad(c, wv)
            qw, bits = comp(sk, gw - h[i])
            h_avg = h_avg + alpha_h * qw / n
            h[i] = h[i] + alpha_h * qw
            step_bits += float(bits)
        y_next = xk - eta * ghat
        zv = beta * zv + (1 - beta) * xk + (gamma / eta) * (y_next - xk)
        key, sk = jax.random.split(key)
        if bool(jax.random.bernoulli(sk, prob)):
            wv = y
        y = y_next
        up += step_bits / n
    return hist


def local_gd(clients, x0, x_star, steps, local_steps: int = 5, lr: Optional[float] = None) -> History:
    """Local GD (S-Local-GD's deterministic-sync special case): clients run
    `local_steps` gradient steps, then average — one d-float uplink per sync.

    Args:
      local_steps: local gradient steps between synchronizations.
      lr: local step size (default 1/L).

    Returns a `History` with one row per synchronization round.
    """
    clients = list(clients)
    n = len(clients)
    d = x0.shape[0]
    f_star = _fstar(clients, x_star)
    L = smoothness_constant(clients)
    lr = 1.0 / L if lr is None else lr
    x = x0
    up = 0.0
    hist = History([], [], [])
    for _ in range(steps):
        hist.append(float(glm.global_loss(clients, x)) - f_star, up, 0.0)
        locals_ = []
        for c in clients:
            xi = x
            for _ in range(local_steps):
                xi = xi - lr * glm.grad(c, xi)
            locals_.append(xi)
        x = sum(locals_) / n
        up += d * FLOAT_BITS
    return hist


def dore_like(
    clients,
    x0,
    x_star,
    steps,
    up_comp: Compressor,
    down_comp: Compressor,
    lr: Optional[float] = None,
    seed: int = 0,
) -> History:
    """DORE-style bidirectionally compressed GD with error feedback both ways.

    Args:
      up_comp / down_comp: uplink (per-client gradient) and downlink
        (model delta) compressors; error feedback accumulates what each
        round's compression dropped.
      lr: step size (default 0.5/L).
      seed: PRNG seed for stochastic compressors.

    Returns a `History`; the downlink stream is billed (unlike gd/diana).
    """
    clients = list(clients)
    n = len(clients)
    d = x0.shape[0]
    f_star = _fstar(clients, x_star)
    L = smoothness_constant(clients)
    lr = 0.5 / L if lr is None else lr
    key = jax.random.PRNGKey(seed)
    x = x0           # server model
    x_dev = x0       # device copy
    err_up = [jnp.zeros(d, x0.dtype) for _ in range(n)]
    err_down = jnp.zeros(d, x0.dtype)
    up = 0.0
    down = 0.0
    hist = History([], [], [])
    for _ in range(steps):
        hist.append(float(glm.global_loss(clients, x)) - f_star, up, down)
        agg = jnp.zeros(d, x0.dtype)
        sb = 0.0
        for i, c in enumerate(clients):
            key, sk = jax.random.split(key)
            gi = glm.grad(c, x_dev) + err_up[i]
            q, bits = up_comp(sk, gi)
            err_up[i] = gi - q
            agg = agg + q / n
            sb += float(bits)
        up += sb / n
        x = x - lr * agg
        key, sk = jax.random.split(key)
        delta = x - x_dev + err_down
        qd, dbits = down_comp(sk, delta)
        err_down = delta - qd
        down += float(dbits)
        x_dev = x_dev + qd
    return hist
