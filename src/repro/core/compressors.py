"""Matrix/vector compression operators (paper §3, §A.2, §A.3).

Every compressor maps a tensor to a *compressed-dense* tensor of the same shape
(the zeros are what got dropped) plus an exact bit count for the wire format it
models.  Two contract classes:

  * contraction (Eq. 6):  E‖A − C(A)‖_F² ≤ (1−δ)‖A‖_F²
  * unbiased   (Eq. 7):  E[C(A)] = A,  E‖C(A)‖_F² ≤ (ω+1)‖A‖_F²

All operators work on arbitrary-shape arrays (treated as flattened vectors in
R^{numel}); matrix-specific ones (Rank-R) require 2-D input.

Bit accounting uses FLOAT_BITS per float and INDEX_BITS per transmitted index
(the paper counts floats; we count bits so dithering/natural compression are
comparable, matching the plots' "communicated bits per node" axis).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

FLOAT_BITS = 64  # the paper's experiments (NumPy) use float64 coefficients
INDEX_BITS = 32


class Compressor:
    """Base class. Subclasses set `is_unbiased`, `delta` or `omega`."""

    is_unbiased: bool = False
    #: contraction parameter δ ∈ (0,1]  (contractive compressors)
    delta: Optional[float] = None
    #: variance parameter ω ≥ 0        (unbiased compressors)
    omega: Optional[float] = None
    #: True if C(A) is deterministic given A (Asm. 4.4(ii)/4.6(ii))
    deterministic: bool = False

    def __call__(self, key: Optional[jax.Array], x: jax.Array) -> Tuple[jax.Array, jax.Array]:
        """Returns (compressed_dense, bits_transmitted)."""
        raise NotImplementedError

    def batched(self, keys: Optional[jax.Array], x: jax.Array) -> Tuple[jax.Array, jax.Array]:
        """Vectorized entry point: compress a stack of n inputs at once.

        `x` carries a leading client axis (n, ...); `keys` is (n, 2) PRNG keys
        (ignored by deterministic compressors — pass None to get dummies).
        Returns (compressed (n, ...), bits (n,)).  Every compressor here is
        jit/vmap-traceable, so this is the building block of the batched BL
        engine (`repro.core.batched`).
        """
        if keys is None:
            keys = jax.random.split(jax.random.PRNGKey(0), x.shape[0])
        return jax.vmap(self.__call__)(keys, x)

    # default recommended step size for Hessian learning
    def alpha(self) -> float:
        if self.is_unbiased:
            return 1.0 / (self.omega + 1.0)
        return 1.0


@dataclasses.dataclass(unsafe_hash=True)
class Identity(Compressor):
    """No compression; full tensor on the wire."""
    is_unbiased = True
    omega = 0.0
    delta = 1.0
    deterministic = True

    def __call__(self, key, x):
        return x, jnp.asarray(x.size * FLOAT_BITS, jnp.float64)


def _topk_keep_mask(v: jax.Array, k: int) -> jax.Array:
    """Boolean mask of the K largest-|v| entries along the last axis.

    The threshold search runs on an f32 copy — XLA's CPU sort/top_k on f64 is
    ~75× slower, and this selection is the batched BL engine's hot spot.
    Exactly K entries are kept per row: entries strictly above the f32
    threshold, then earliest-index entries inside the threshold tie group
    (sub-f32-ulp value differences inside the group are broken by index).
    Scatter-free on purpose: mask + `where` instead of `.at[idx].set`.
    """
    a32 = jnp.abs(v).astype(jnp.float32)
    vals, idx = jax.lax.top_k(a32, k)
    # keep both outputs alive: with the indices dead, XLA rewrites top_k into
    # a full stable sort (~12× slower on CPU for the d² coefficient arrays).
    # Barrier each output separately — a barrier consuming the top_k tuple
    # itself crashes XLA's TopkDecomposer under multi-device shard_map
    # (CreateVariadicComparator expects get-tuple-element users).
    vals = jax.lax.optimization_barrier(vals)
    _ = jax.lax.optimization_barrier(idx)
    t = vals[..., -1:]
    above = a32 > t
    eq = a32 == t
    n_above = jnp.sum(above, axis=-1, keepdims=True)
    cum = jnp.cumsum(eq, axis=-1)
    return above | (eq & (cum <= k - n_above))


@dataclasses.dataclass(unsafe_hash=True)
class TopK(Compressor):
    """Greedy sparsification (Eq. 21): keep K largest-|.| entries.

    Contractive with δ = K/numel.  Deterministic.
    """
    k: int
    symmetrize: bool = False  # apply to upper-triangular half, mirror (paper §A.2)

    def __post_init__(self):
        self.deterministic = True

    def __call__(self, key, x):
        shape = x.shape
        if self.symmetrize and x.ndim == 2 and shape[0] == shape[1]:
            d = shape[0]
            iu = jnp.triu_indices(d)
            v = x[iu]
            kk = min(self.k, v.size)
            keep_tri = _topk_keep_mask(v, kk)
            # gather the triangular mask back to the dense upper half
            # (static index map — no scatter)
            pos = jnp.zeros((d, d), jnp.int32).at[iu].set(jnp.arange(v.size, dtype=jnp.int32))
            upper = jnp.triu(jnp.ones((d, d), bool))
            keep_full = keep_tri[pos] & upper
            out = jnp.where(keep_full, x, 0.0)
            out = out + jnp.triu(out, 1).T
            bits = kk * (FLOAT_BITS + INDEX_BITS)
            return out, jnp.asarray(bits, jnp.float64)
        v = x.reshape(-1)
        kk = min(self.k, v.size)
        out = jnp.where(_topk_keep_mask(v, kk), v, 0.0).reshape(shape)
        return out, jnp.asarray(kk * (FLOAT_BITS + INDEX_BITS), jnp.float64)

    def batched(self, keys, x):
        """Natively batched (no vmap — optimization_barrier has no batching
        rule, and `top_k`/the mask algebra batch over the last axis anyway)."""
        n = x.shape[0]
        if self.symmetrize and x.ndim == 3 and x.shape[1] == x.shape[2]:
            d = x.shape[1]
            iu = jnp.triu_indices(d)
            v = x[:, iu[0], iu[1]]                      # (n, T)
            kk = min(self.k, v.shape[1])
            keep_tri = _topk_keep_mask(v, kk)
            pos = jnp.zeros((d, d), jnp.int32).at[iu].set(
                jnp.arange(v.shape[1], dtype=jnp.int32))
            upper = jnp.triu(jnp.ones((d, d), bool))
            keep_full = keep_tri[:, pos] & upper
            out = jnp.where(keep_full, x, 0.0)
            out = out + jnp.transpose(jnp.triu(out, 1), (0, 2, 1))
            bits = jnp.full((n,), kk * (FLOAT_BITS + INDEX_BITS), jnp.float64)
            return out, bits
        v = x.reshape(n, -1)
        kk = min(self.k, v.shape[1])
        out = jnp.where(_topk_keep_mask(v, kk), v, 0.0).reshape(x.shape)
        bits = jnp.full((n,), kk * (FLOAT_BITS + INDEX_BITS), jnp.float64)
        return out, bits

    @property
    def _delta_for(self):
        return None  # depends on input size; use delta_for(numel)

    def delta_for(self, numel: int) -> float:
        return min(self.k, numel) / numel


@dataclasses.dataclass(unsafe_hash=True)
class RandK(Compressor):
    """Random sparsification (Eq. 22): unbiased, ω = numel/K − 1."""
    k: int

    def __post_init__(self):
        self.is_unbiased = True

    def __call__(self, key, x):
        v = x.reshape(-1)
        n = v.size
        kk = min(self.k, n)
        idx = jax.random.choice(key, n, shape=(kk,), replace=False)
        scale = n / kk
        out = jnp.zeros_like(v).at[idx].set(v[idx] * scale).reshape(x.shape)
        return out, jnp.asarray(kk * (FLOAT_BITS + INDEX_BITS), jnp.float64)

    def omega_for(self, numel: int) -> float:
        return numel / min(self.k, numel) - 1.0

    def alpha_for(self, numel: int) -> float:
        return 1.0 / (self.omega_for(numel) + 1.0)


@dataclasses.dataclass(unsafe_hash=True)
class RankR(Compressor):
    """Low-rank approximation via SVD (Eq. 19–20).

    Contractive with δ = R/d on d×d matrices [Safaryan et al., 2021].
    Symmetric input ⇒ symmetric output automatically.
    """
    r: int

    def __post_init__(self):
        self.deterministic = True

    def __call__(self, key, x):
        assert x.ndim == 2, "Rank-R needs a matrix"
        u, s, vt = jnp.linalg.svd(x, full_matrices=False)
        rr = min(self.r, s.size)
        out = (u[:, :rr] * s[:rr]) @ vt[:rr, :]
        # wire format: R singular triples (u_i, σ_i, v_i)
        bits = rr * (x.shape[0] + x.shape[1] + 1) * FLOAT_BITS
        return out, jnp.asarray(bits, jnp.float64)

    def delta_for(self, d: int) -> float:
        return min(self.r, d) / d


def _dither(key, x, s, q=2):
    """Random dithering (Eq. 17–18) with s levels, q-norm."""
    v = x.reshape(-1)
    raw_norm = jnp.linalg.norm(v, ord=q)
    norm = jnp.where(raw_norm == 0, 1.0, raw_norm)
    a = jnp.abs(v) / norm * s          # in [0, s]
    low = jnp.floor(a)
    pup = a - low                       # P[round up]
    up = jax.random.bernoulli(key, pup.astype(jnp.float32))
    lev = low + up
    out = jnp.sign(v) * norm * lev / s
    out = jnp.where(raw_norm == 0, 0.0, out)
    # wire: 1 norm float + per-entry (sign + level) ~ (1 + ceil(log2(s+1))) bits
    # (s is a Python int — keep the bit count on the host, no device sync)
    lev_bits = math.ceil(math.log2(s + 1))
    bits = FLOAT_BITS + v.size * (1 + lev_bits)
    return out.reshape(x.shape), jnp.asarray(bits, jnp.float64)


@dataclasses.dataclass(unsafe_hash=True)
class RandomDithering(Compressor):
    """Unbiased; ω ≤ min(d/s², √d/s) for q=2 [Alistarh et al. 2017]."""
    s: int
    q: int = 2

    def __post_init__(self):
        self.is_unbiased = True

    def __call__(self, key, x):
        return _dither(key, x, self.s, self.q)

    def omega_for(self, numel: int) -> float:
        return min(numel / self.s**2, numel**0.5 / self.s)


@dataclasses.dataclass(unsafe_hash=True)
class NaturalCompression(Compressor):
    """Round |x| to a power of two, randomly up/down (unbiased, ω = 1/8).

    Wire format: sign + 8-bit exponent = 9 bits/entry.
    """
    def __post_init__(self):
        self.is_unbiased = True
        self.omega = 1.0 / 8.0

    def __call__(self, key, x):
        v = x.reshape(-1)
        nz = v != 0
        absv = jnp.where(nz, jnp.abs(v), 1.0)
        e = jnp.floor(jnp.log2(absv))
        low = jnp.exp2(e)
        pup = (absv - low) / low        # ∈ [0,1): P[round to 2^{e+1}]
        up = jax.random.bernoulli(key, pup.astype(jnp.float32))
        out = jnp.sign(v) * low * jnp.where(up, 2.0, 1.0)
        out = jnp.where(nz, out, 0.0).reshape(x.shape)
        return out, jnp.asarray(v.size * 9, jnp.float64)


@dataclasses.dataclass(unsafe_hash=True)
class ComposedTopK(Compressor):
    """Top-K followed by an unbiased compressor on the kept values (§A.5).

    RTop-K: inner = RandomDithering(s=√K);  NTop-K: inner = NaturalCompression.
    Contractive (composition of a contraction with an unbiased op, scaled by
    1/(ω+1), remains a contraction — Qian et al. 2021).
    """
    k: int
    inner: Compressor
    unbias_correct: bool = True

    def __post_init__(self):
        self.deterministic = False

    def __call__(self, key, x):
        v = x.reshape(-1)
        kk = min(self.k, v.size)
        # f32 selection (see _topk_keep_mask) — f64 top_k is the CPU hot
        # spot; the kept *values* stay full precision.  Barrier keeps the
        # TopK custom call from decomposing into a full sort (vals unused);
        # per-output barriers, not a tuple one (multi-device XLA crash).
        vals, idx = jax.lax.top_k(jnp.abs(v).astype(jnp.float32), kk)
        _ = jax.lax.optimization_barrier(vals)
        idx = jax.lax.optimization_barrier(idx)
        kept = v[idx]
        cv, inner_bits = self.inner(key, kept)
        if self.unbias_correct:
            om = getattr(self.inner, "omega", None)
            if om is None:
                om = self.inner.omega_for(kk)
            cv = cv / (om + 1.0)
        out = jnp.zeros_like(v).at[idx].set(cv).reshape(x.shape)
        bits = inner_bits + kk * INDEX_BITS
        return out, bits

    def batched(self, keys, x):
        """Natively batched — same selection/scatter as `__call__` per row
        (vmap would trip on optimization_barrier's missing batching rule)."""
        n = x.shape[0]
        v = x.reshape(n, -1)
        kk = min(self.k, v.shape[1])
        vals, idx = jax.lax.top_k(jnp.abs(v).astype(jnp.float32), kk)
        _ = jax.lax.optimization_barrier(vals)
        idx = jax.lax.optimization_barrier(idx)
        kept = jnp.take_along_axis(v, idx, axis=1)
        if keys is None:
            keys = jax.random.split(jax.random.PRNGKey(0), n)
        cv, inner_bits = jax.vmap(self.inner)(keys, kept)
        if self.unbias_correct:
            om = getattr(self.inner, "omega", None)
            if om is None:
                om = self.inner.omega_for(kk)
            cv = cv / (om + 1.0)
        out = jnp.zeros_like(v)
        out = jax.vmap(lambda o, i, c: o.at[i].set(c))(out, idx, cv)
        bits = inner_bits + kk * INDEX_BITS
        return out.reshape(x.shape), bits


@dataclasses.dataclass(unsafe_hash=True)
class ComposedRankR(Compressor):
    """C1 of §3: Rank-R with unbiasedly-compressed singular vectors.

    δ = R / (d (ω₁+1)(ω₂+1))  (Prop. 3.2).  We use a_i = b_i = 1.
    symmetrize=True gives C2 (Lemma 3.1 (ii)).
    """
    r: int
    inner_u: Compressor
    inner_v: Compressor
    symmetrize: bool = True

    def __call__(self, key, x):
        assert x.ndim == 2
        u, s, vt = jnp.linalg.svd(x, full_matrices=False)
        rr = min(self.r, s.size)
        keys = jax.random.split(key, 2 * rr)
        om1 = self.inner_u.omega if self.inner_u.omega is not None else self.inner_u.omega_for(x.shape[0])
        om2 = self.inner_v.omega if self.inner_v.omega is not None else self.inner_v.omega_for(x.shape[1])
        # vectorized over the rr singular triples (keys laid out exactly as the
        # historical op-by-op loop: even → u-vector, odd → v-vector)
        qu, bu = jax.vmap(self.inner_u)(keys[0::2], u[:, :rr].T)   # (rr, m)
        qv, bv = jax.vmap(self.inner_v)(keys[1::2], vt[:rr, :])    # (rr, n)
        out = jnp.einsum("r,rm,rn->mn", s[:rr], qu, qv) / ((om1 + 1.0) * (om2 + 1.0))
        bits = jnp.asarray(rr * FLOAT_BITS, jnp.float64) + jnp.sum(bu) + jnp.sum(bv)
        if self.symmetrize:
            out = jnp.where(jnp.allclose(x, x.T), (out + out.T) / 2.0, out)
        return out, bits


@dataclasses.dataclass(unsafe_hash=True)
class BernoulliLazy(Compressor):
    """Lazy Bernoulli compressor (§A.8): send full tensor w.p. p, else zero.

    Unbiased with ω = 1/p − 1.
    """
    p: float

    def __post_init__(self):
        self.is_unbiased = True
        self.omega = 1.0 / self.p - 1.0

    def __call__(self, key, x):
        send = jax.random.bernoulli(key, self.p)
        out = jnp.where(send, x / self.p, jnp.zeros_like(x))
        bits = jnp.where(send, x.size * FLOAT_BITS, 0).astype(jnp.float64)
        return out, bits


def rtopk(k: int) -> ComposedTopK:
    s = max(1, int(round(k ** 0.5)))
    return ComposedTopK(k=k, inner=RandomDithering(s=s))


def ntopk(k: int) -> ComposedTopK:
    return ComposedTopK(k=k, inner=NaturalCompression())


def rrankr(r: int, d: int) -> ComposedRankR:
    s = max(1, int(round(d ** 0.5)))
    return ComposedRankR(r=r, inner_u=RandomDithering(s=s), inner_v=RandomDithering(s=s))


def nrankr(r: int) -> ComposedRankR:
    return ComposedRankR(r=r, inner_u=NaturalCompression(), inner_v=NaturalCompression())
