"""Matrix/vector compression operators (paper §3, §A.2, §A.3).

One natively-batched contract: ``compress(keys, x)`` takes a stack of n
inputs (leading client axis) plus per-client PRNG keys ``(n, 2)`` and
returns ``(compressed_dense, counts)`` — the compressed tensors (zeros are
what got dropped) and a `repro.core.comm.Counts` record of what actually
hit the wire.  Compressors never compute bits: each declares a
`WireFormat` (`.wire`) and the comm layer prices counts
(``comm.price(comp.wire, counts)``).  Two contract classes:

  * contraction (Eq. 6):  E‖A − C(A)‖_F² ≤ (1−δ)‖A‖_F²
  * unbiased   (Eq. 7):  E[C(A)] = A,  E‖C(A)‖_F² ≤ (ω+1)‖A‖_F²

``keys=None`` is accepted only by deterministic compressors — stochastic
ones raise instead of silently substituting a fixed key (which would make
every "random" draw identical).

The single-client convenience ``comp(key, x)`` is a thin adapter over the
same batched implementation (n = 1) that additionally prices the message —
it exists for the op-by-op reference backend and tests; there is exactly
one selection/quantization implementation per operator.

|·|-Top-K selection (the batched engine's hot spot) is one shared routine,
`topk_keep_mask`, consumed by both `TopK` and `ComposedTopK`.  Its
threshold search runs on an f32 copy (XLA's CPU sort/top_k on f64 is ~75×
slower) through one of two parity-pinned backends:

  * default: barrier'd ``lax.top_k`` (the barriers stop XLA rewriting a
    partially-dead top_k into a full stable sort);
  * ``REPRO_BL_PALLAS=1``: the exact bitwise-binary-search Pallas kernel
    (`repro.kernels.topk_threshold`) — same threshold bitwise, so the
    shared tie-break mask selects identical entries and trajectories are
    unchanged (tests/test_pallas_parity.py).
"""
from __future__ import annotations

import dataclasses
import math
import os
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from . import comm
from .comm import FLOAT_BITS, INDEX_BITS  # noqa: F401  (historical re-export)


def _numel(x: jax.Array) -> int:
    """Per-client element count of a client-stacked (n, ...) array."""
    n = 1
    for s in x.shape[1:]:
        n *= s
    return n


def _full(n: int, value) -> jax.Array:
    return jnp.full((n,), value, jnp.float64)


class Compressor:
    """Base class. Subclasses set `is_unbiased`, `delta` or `omega`."""

    is_unbiased: bool = False
    #: contraction parameter δ ∈ (0,1]  (contractive compressors)
    delta: Optional[float] = None
    #: variance parameter ω ≥ 0        (unbiased compressors)
    omega: Optional[float] = None
    #: True if C(A) is deterministic given A (Asm. 4.4(ii)/4.6(ii))
    deterministic: bool = False

    @property
    def stochastic(self) -> bool:
        return not self.deterministic

    @property
    def wire(self):
        """`comm.WireFormat` (or tuple tree, for composed codecs) pricing
        this operator's `Counts`."""
        return comm.WireFormat()

    def compress(self, keys: Optional[jax.Array], x: jax.Array) -> Tuple[jax.Array, comm.Counts]:
        """Compress a client-stacked batch (the one batched contract).

        Args:
          keys: per-client PRNG keys, shape (n, 2); None is accepted only
            by deterministic compressors (stochastic ones raise).
          x: (n, ...) stack of per-client tensors (matrices for the
            Hessian codecs, vectors for model/gradient streams).

        Returns:
          (compressed, counts): ``compressed`` is (n, ...) dense with
          zeros where entries were dropped (Eq. 6 contraction / Eq. 7
          unbiased contract applies per client); ``counts`` is a
          `comm.Counts` whose leaves are per-client (n,) message counts —
          price them with ``comm.price(self.wire, counts)``.
        """
        raise NotImplementedError

    def compress_sum(self, keys: Optional[jax.Array], x: jax.Array
                     ) -> Tuple[jax.Array, comm.Counts, jax.Array]:
        """Fused compress-then-reduce: `compress` plus the LOCAL sum of the
        compressed stack over the client axis.

        Returns ``(compressed, counts, local_sum)`` with ``local_sum ==
        compressed.sum(axis=0)`` (payload-shaped).  The default is the
        obvious two-pass composition; codecs with a fused kernel override
        it (Top-K under ``REPRO_BL_PALLAS=1`` computes the selection
        threshold and the partial sum in one pass — see
        `repro.kernels.topk_threshold.topk_compress_sum`).  Consumers feed
        the sum to `rounds.Reducer.tree_mean_presummed`, which lets the
        bandwidth-optimal sharded path reduce the pre-summed payload
        instead of gathering the dense stack."""
        dense, counts = self.compress(keys, x)
        return dense, counts, jnp.sum(dense, axis=0)

    def _require_keys(self, keys: Optional[jax.Array], n: int) -> Optional[jax.Array]:
        if keys is None:
            if self.stochastic:
                raise ValueError(
                    f"{type(self).__name__} is stochastic: compress() needs "
                    "per-client PRNG keys (n, 2), got None — a substituted "
                    "fixed key would repeat the same draw every call")
            return None
        return keys

    def __call__(self, key: Optional[jax.Array], x: jax.Array) -> Tuple[jax.Array, jax.Array]:
        """Single-client adapter: compress one tensor and price it.
        Returns (compressed_dense, bits_transmitted)."""
        keys = None if key is None else jnp.asarray(key)[None]
        dense, counts = self.compress(keys, x[None])
        return dense[0], comm.price(self.wire, counts)[0]

    def alpha(self) -> float:
        """Recommended Hessian-learning step size: 1/(ω+1) for unbiased
        compressors (Eq. 7), 1 for contractive ones (Eq. 6)."""
        if self.is_unbiased:
            return 1.0 / (self.omega + 1.0)
        return 1.0


@dataclasses.dataclass(unsafe_hash=True)
class Identity(Compressor):
    """No compression; full tensor on the wire."""
    is_unbiased = True
    omega = 0.0
    delta = 1.0
    deterministic = True

    def compress(self, keys, x):
        return x, comm.Counts(floats=_full(x.shape[0], _numel(x)))


# --------------------------------------------------------------------------
# shared |·|-Top-K selection (one implementation, two backends)
# --------------------------------------------------------------------------
def _selection_threshold(a32: jax.Array, k: int) -> jax.Array:
    """k-th largest per row of non-negative f32 `a32` (..., T) → (..., 1).

    Backends return bitwise-identical thresholds; see module docstring."""
    if os.environ.get("REPRO_BL_PALLAS", "0") == "1":
        from repro.kernels import ops
        from repro.kernels.topk_threshold import topk_row_threshold

        t = topk_row_threshold(a32.reshape((-1,) + a32.shape[-1:]), k,
                               interpret=ops.INTERPRET)
        return t.reshape(a32.shape[:-1] + (1,))
    vals, idx = jax.lax.top_k(a32, k)
    # keep both outputs alive: with the indices dead, XLA rewrites top_k into
    # a full stable sort (~12× slower on CPU for the d² coefficient arrays).
    # Barrier each output separately — a barrier consuming the top_k tuple
    # itself crashes XLA's TopkDecomposer under multi-device shard_map
    # (CreateVariadicComparator expects get-tuple-element users).
    vals = jax.lax.optimization_barrier(vals)
    _ = jax.lax.optimization_barrier(idx)
    return vals[..., -1:]


def topk_keep_mask(v: jax.Array, k: int) -> jax.Array:
    """Boolean mask of the K largest-|v| entries along the last axis.

    Exactly K entries are kept per row: entries strictly above the f32
    threshold, then earliest-index entries inside the threshold tie group
    (sub-f32-ulp value differences inside the group are broken by index).
    Scatter-free on purpose: mask + `where` instead of `.at[idx].set`.

    Public building block for Top-K-style selection outside the compressor
    classes (exactly-k semantics, tie handling and the Pallas/XLA backend
    switch in one place).
    """
    from repro.kernels.topk_threshold import keep_mask

    a32 = jnp.abs(v).astype(jnp.float32)
    return keep_mask(a32, _selection_threshold(a32, k), k)


#: historical private name — new code should import `topk_keep_mask`.
_topk_keep_mask = topk_keep_mask


@dataclasses.dataclass(unsafe_hash=True)
class TopK(Compressor):
    """Greedy sparsification (Eq. 21): keep K largest-|.| entries.

    Contractive with δ = K/numel.  Deterministic.
    """
    k: int
    symmetrize: bool = False  # apply to upper-triangular half, mirror (paper §A.2)

    def __post_init__(self):
        self.deterministic = True

    def compress(self, keys, x):
        n = x.shape[0]
        if self.symmetrize and x.ndim == 3 and x.shape[1] == x.shape[2]:
            d = x.shape[1]
            iu = jnp.triu_indices(d)
            v = x[:, iu[0], iu[1]]                      # (n, T)
            kk = min(self.k, v.shape[1])
            keep_tri = topk_keep_mask(v, kk)
            # gather the triangular mask back to the dense upper half
            # (static index map — no scatter)
            pos = jnp.zeros((d, d), jnp.int32).at[iu].set(
                jnp.arange(v.shape[1], dtype=jnp.int32))
            upper = jnp.triu(jnp.ones((d, d), bool))
            keep_full = keep_tri[:, pos] & upper
            out = jnp.where(keep_full, x, 0.0)
            out = out + jnp.transpose(jnp.triu(out, 1), (0, 2, 1))
            c = _full(n, kk)
            return out, comm.Counts(floats=c, indices=c)
        v = x.reshape(n, -1)
        kk = min(self.k, v.shape[1])
        out = jnp.where(topk_keep_mask(v, kk), v, 0.0).reshape(x.shape)
        c = _full(n, kk)
        return out, comm.Counts(floats=c, indices=c)

    def compress_sum(self, keys, x):
        # fused selection + local client-axis partial sum in one Pallas
        # pass; the kernel's threshold/tie-break path is the bitwise-pinned
        # one, so dense/counts/sum all match the two-pass default exactly
        # (tests/test_pallas_parity.py).  f32 flat payloads only — the
        # symmetrized matrix codec and f64 GLM streams take the default.
        if (self.symmetrize or x.dtype != jnp.float32
                or os.environ.get("REPRO_BL_PALLAS", "0") != "1"):
            return super().compress_sum(keys, x)
        from repro.kernels import ops
        from repro.kernels.topk_threshold import topk_compress_sum

        n = x.shape[0]
        v = x.reshape(n, -1)
        kk = min(self.k, v.shape[1])
        out, s = topk_compress_sum(v, kk, interpret=ops.INTERPRET)
        c = _full(n, kk)
        return (out.reshape(x.shape), comm.Counts(floats=c, indices=c),
                s.reshape(x.shape[1:]))

    @property
    def _delta_for(self):
        return None  # depends on input size; use delta_for(numel)

    def delta_for(self, numel: int) -> float:
        return min(self.k, numel) / numel


@dataclasses.dataclass(unsafe_hash=True)
class RandK(Compressor):
    """Random sparsification (Eq. 22): unbiased, ω = numel/K − 1."""
    k: int

    def __post_init__(self):
        self.is_unbiased = True

    def compress(self, keys, x):
        n = x.shape[0]
        keys = self._require_keys(keys, n)
        numel = _numel(x)
        kk = min(self.k, numel)
        scale = numel / kk

        def one(key, xi):
            v = xi.reshape(-1)
            idx = jax.random.choice(key, numel, shape=(kk,), replace=False)
            return jnp.zeros_like(v).at[idx].set(v[idx] * scale).reshape(xi.shape)

        c = _full(n, kk)
        return jax.vmap(one)(keys, x), comm.Counts(floats=c, indices=c)

    def omega_for(self, numel: int) -> float:
        return numel / min(self.k, numel) - 1.0

    def alpha_for(self, numel: int) -> float:
        return 1.0 / (self.omega_for(numel) + 1.0)


@dataclasses.dataclass(unsafe_hash=True)
class RankR(Compressor):
    """Low-rank approximation via SVD (Eq. 19–20).

    Contractive with δ = R/d on d×d matrices [Safaryan et al., 2021].
    Symmetric input ⇒ symmetric output automatically.
    """
    r: int

    def __post_init__(self):
        self.deterministic = True

    def compress(self, keys, x):
        assert x.ndim == 3, "Rank-R needs a stack of matrices"
        n = x.shape[0]
        u, s, vt = jnp.linalg.svd(x, full_matrices=False)
        rr = min(self.r, s.shape[-1])
        out = jnp.matmul(u[:, :, :rr] * s[:, None, :rr], vt[:, :rr, :])
        # wire format: R singular triples (u_i, σ_i, v_i)
        c = _full(n, rr * (x.shape[1] + x.shape[2] + 1))
        return out, comm.Counts(floats=c)

    def delta_for(self, d: int) -> float:
        return min(self.r, d) / d


def _dither_vals(key, x, s, q=2):
    """Random dithering values (Eq. 17–18) with s levels, q-norm."""
    v = x.reshape(-1)
    raw_norm = jnp.linalg.norm(v, ord=q)
    norm = jnp.where(raw_norm == 0, 1.0, raw_norm)
    a = jnp.abs(v) / norm * s          # in [0, s]
    low = jnp.floor(a)
    pup = a - low                       # P[round up]
    up = jax.random.bernoulli(key, pup.astype(jnp.float32))
    lev = low + up
    out = jnp.sign(v) * norm * lev / s
    out = jnp.where(raw_norm == 0, 0.0, out)
    return out.reshape(x.shape)


def _dither_level_bits(s: int) -> int:
    return math.ceil(math.log2(s + 1))


@dataclasses.dataclass(unsafe_hash=True)
class RandomDithering(Compressor):
    """Unbiased; ω ≤ min(d/s², √d/s) for q=2 [Alistarh et al. 2017].

    Wire: 1 norm float + per-entry (sign + ⌈log₂(s+1)⌉ level) bits."""
    s: int
    q: int = 2

    def __post_init__(self):
        self.is_unbiased = True

    @property
    def wire(self):
        return comm.WireFormat(entry_bits=1 + _dither_level_bits(self.s))

    def compress(self, keys, x):
        n = x.shape[0]
        keys = self._require_keys(keys, n)
        out = jax.vmap(lambda k, xi: _dither_vals(k, xi, self.s, self.q))(keys, x)
        return out, comm.Counts(floats=_full(n, 1), entries=_full(n, _numel(x)))

    def omega_for(self, numel: int) -> float:
        return min(numel / self.s**2, numel**0.5 / self.s)


@dataclasses.dataclass(unsafe_hash=True)
class NaturalCompression(Compressor):
    """Round |x| to a power of two, randomly up/down (unbiased, ω = 1/8).

    Wire format: sign + 8-bit exponent = 9 bits/entry.
    """
    def __post_init__(self):
        self.is_unbiased = True
        self.omega = 1.0 / 8.0

    @property
    def wire(self):
        return comm.WireFormat(entry_bits=9)

    def compress(self, keys, x):
        n = x.shape[0]
        keys = self._require_keys(keys, n)

        def one(key, xi):
            v = xi.reshape(-1)
            nz = v != 0
            absv = jnp.where(nz, jnp.abs(v), 1.0)
            e = jnp.floor(jnp.log2(absv))
            low = jnp.exp2(e)
            pup = (absv - low) / low        # ∈ [0,1): P[round to 2^{e+1}]
            up = jax.random.bernoulli(key, pup.astype(jnp.float32))
            out = jnp.sign(v) * low * jnp.where(up, 2.0, 1.0)
            return jnp.where(nz, out, 0.0).reshape(xi.shape)

        out = jax.vmap(one)(keys, x)
        return out, comm.Counts(entries=_full(n, _numel(x)))


@dataclasses.dataclass(unsafe_hash=True)
class ComposedTopK(Compressor):
    """Top-K followed by an unbiased compressor on the kept values (§A.5).

    RTop-K: inner = RandomDithering(s=√K);  NTop-K: inner = NaturalCompression.
    Contractive (composition of a contraction with an unbiased op, scaled by
    1/(ω+1), remains a contraction — Qian et al. 2021).

    Selection is the shared `topk_keep_mask`; the kept values are compacted
    to (n, K) slots by a cumsum scatter (index order), run through the inner
    compressor's own batched contract, and gathered back — no second Top-K
    implementation.
    """
    k: int
    inner: Compressor
    unbias_correct: bool = True

    def __post_init__(self):
        self.deterministic = self.inner.deterministic

    @property
    def wire(self):
        return (comm.WireFormat(), self.inner.wire)

    def compress(self, keys, x):
        n = x.shape[0]
        v = x.reshape(n, -1)
        kk = min(self.k, v.shape[1])
        keys = self._require_keys(keys, n)
        mask = topk_keep_mask(v, kk)
        slot = jnp.cumsum(mask, axis=-1) - 1            # target slot per kept
        slot = jnp.where(mask, slot, kk)                # park dropped at k
        rows = jnp.arange(n)[:, None]
        kept = jnp.zeros((n, kk + 1), v.dtype).at[rows, slot].add(
            jnp.where(mask, v, 0.0))[:, :kk]
        cv, inner_counts = self.inner.compress(keys, kept)
        if self.unbias_correct:
            om = getattr(self.inner, "omega", None)
            if om is None:
                om = self.inner.omega_for(kk)
            cv = cv / (om + 1.0)
        cvp = jnp.concatenate([cv, jnp.zeros((n, 1), cv.dtype)], axis=1)
        out = jnp.where(mask, jnp.take_along_axis(cvp, slot, axis=1), 0.0)
        counts = (comm.Counts(indices=_full(n, kk)), inner_counts)
        return out.reshape(x.shape), counts


@dataclasses.dataclass(unsafe_hash=True)
class ComposedRankR(Compressor):
    """C1 of §3: Rank-R with unbiasedly-compressed singular vectors.

    δ = R / (d (ω₁+1)(ω₂+1))  (Prop. 3.2).  We use a_i = b_i = 1.
    symmetrize=True gives C2 (Lemma 3.1 (ii)).
    """
    r: int
    inner_u: Compressor
    inner_v: Compressor
    symmetrize: bool = True

    def __post_init__(self):
        self.deterministic = (self.inner_u.deterministic
                              and self.inner_v.deterministic)

    @property
    def wire(self):
        return (comm.WireFormat(), self.inner_u.wire, self.inner_v.wire)

    def compress(self, keys, x):
        assert x.ndim == 3
        n = x.shape[0]
        keys = self._require_keys(keys, n)
        if keys is None:  # fully deterministic inners (degenerate but legal)
            keys = jnp.zeros((n, 2), jnp.uint32)
        u, s, vt = jnp.linalg.svd(x, full_matrices=False)
        rr = min(self.r, s.shape[-1])
        om1 = (self.inner_u.omega if self.inner_u.omega is not None
               else self.inner_u.omega_for(x.shape[1]))
        om2 = (self.inner_v.omega if self.inner_v.omega is not None
               else self.inner_v.omega_for(x.shape[2]))

        def one(key, ui, si, vti, xi):
            # keys laid out exactly as the historical op-by-op loop:
            # even → u-vector, odd → v-vector
            ks = jax.random.split(key, 2 * rr)
            qu, cu = self.inner_u.compress(ks[0::2], ui[:, :rr].T)   # (rr, m)
            qv, cvn = self.inner_v.compress(ks[1::2], vti[:rr, :])   # (rr, p)
            out = jnp.einsum("r,rm,rn->mn", si[:rr], qu, qv) / ((om1 + 1.0) * (om2 + 1.0))
            if self.symmetrize:
                out = jnp.where(jnp.allclose(xi, xi.T), (out + out.T) / 2.0, out)
            # fold the rr per-triple counts into one per-client record
            total = jax.tree.map(lambda a: jnp.sum(jnp.asarray(a, jnp.float64)),
                                 (cu, cvn))
            return out, total

        out, (cu, cvn) = jax.vmap(one)(keys, u, s, vt, x)
        counts = (comm.Counts(floats=_full(n, rr)), cu, cvn)
        return out, counts


@dataclasses.dataclass(unsafe_hash=True)
class BernoulliLazy(Compressor):
    """Lazy Bernoulli compressor (§A.8): send full tensor w.p. p, else zero.

    Unbiased with ω = 1/p − 1.
    """
    p: float

    def __post_init__(self):
        self.is_unbiased = True
        self.omega = 1.0 / self.p - 1.0

    def compress(self, keys, x):
        n = x.shape[0]
        keys = self._require_keys(keys, n)
        send = jax.vmap(lambda k: jax.random.bernoulli(k, self.p))(keys)
        bshape = (n,) + (1,) * (x.ndim - 1)
        out = jnp.where(send.reshape(bshape), x / self.p, jnp.zeros_like(x))
        floats = jnp.where(send, _numel(x), 0).astype(jnp.float64)
        return out, comm.Counts(floats=floats)


def rtopk(k: int) -> ComposedTopK:
    s = max(1, int(round(k ** 0.5)))
    return ComposedTopK(k=k, inner=RandomDithering(s=s))


def ntopk(k: int) -> ComposedTopK:
    return ComposedTopK(k=k, inner=NaturalCompression())


def rrankr(r: int, d: int) -> ComposedRankR:
    s = max(1, int(round(d ** 0.5)))
    return ComposedRankR(r=r, inner_u=RandomDithering(s=s), inner_v=RandomDithering(s=s))


def nrankr(r: int) -> ComposedRankR:
    return ComposedRankR(r=r, inner_u=NaturalCompression(), inner_v=NaturalCompression())
