"""Two-tier program cache: compile-free serve-loop start-up.

Every process start — cold launch or post-crash restart — used to pay a
full retrace + XLA compile of the chunked scan program before round 1
could run, which for the 8-device shard_map and cohort backends dwarfs the
~4 ms compiled per-round cost (seconds of compile vs milliseconds of
round).  This module makes start-up a *load*:

  * **Tier 1 — AOT executable cache.**  The serve programs
    (`rounds.init_serve_carry` / `rounds.run_chunk` / the cohort chunk
    program) are lowered and compiled ahead of time
    (``jitted.lower(*args).compile()``), serialized with
    `jax.experimental.serialize_executable`, and persisted as

        <cache_dir>/<name>-<key>.bin     pickled (payload, in_tree, out_tree)
        <cache_dir>/<name>-<key>.json    manifest (schema, sha256, env, aux)

    ``<key>`` is a sha256 digest of the program identity: the caller's key
    parts (method-spec fingerprint, backend scope, abstract arg
    shapes/dtypes) plus the full :func:`env_fingerprint` — jax/jaxlib/XLA
    versions, backend, device count, and the ``REPRO_BL_PALLAS`` kernel
    flag.  A warm restart deserializes the executable in tens of
    milliseconds instead of recompiling in seconds.

  * **Tier 2 — JAX persistent compilation cache.**  Everything the AOT
    layer doesn't own (gap-stream evaluations, one-off partial-chunk
    lengths, dry-run compiles) still goes through ``jax.jit``; activating
    a cache also points ``jax_compilation_cache_dir`` at
    ``<cache_dir>/xla`` so those compiles persist across processes too.

Fallback contract: *any* anomaly — missing entry, torn payload, sha256
mismatch, schema or environment skew, a deserialization error — is a MISS,
never an error: the program live-compiles from the identical lowering and
the freshly stored entry replaces the bad one.  Because the cache stores
the executable itself (not a re-derivation recipe), a cache hit runs the
byte-identical program a miss would have compiled — trajectories are
bitwise-equal either way (measured, not assumed: tests/test_progcache.py
and the ``cold_start`` bench record).

Writes follow the `repro.exp.artifacts` checkpoint idiom: tmp file +
``os.replace`` + directory fsync, payload before manifest, so a crash
mid-write leaves at worst an orphaned ``.bin`` that no manifest points at.

Activation: nothing happens unless a cache is active.  `repro.launch.
fed_serve` activates one per serve (``--progcache-dir``, default
``<ckpt_dir>/progcache``); any process can opt in via the
``REPRO_PROGCACHE_DIR`` environment variable (``REPRO_PROGCACHE=0``
force-disables).  With no active cache the round engine's dispatch path is
byte-for-byte the plain jitted fast path — zero added work.
"""
from __future__ import annotations

import collections
import dataclasses
import functools
import hashlib
import json
import os
import pickle
import sys
import time
from typing import Any, Callable, Optional, Tuple

import numpy as np

# v2: entries must come from donation-free lowerings (`rounds._chunk_jit_aot`
# and twins) — v1 entries serialized donating programs, which corrupt chained
# carry calls after deserialization, so they are invalidated wholesale
SCHEMA_VERSION = 2
#: manifest schema tag of one AOT cache entry (re-exported by
#: `repro.exp.artifacts` next to the checkpoint schemas; validated by
#: ``tools/schema_diff.py --progcache``)
PROGCACHE_SCHEMA = f"repro.progcache/entry@{SCHEMA_VERSION}"

#: kernel-routing flag that changes traced programs (Pallas top-k selection)
_PALLAS_FLAG = "REPRO_BL_PALLAS"


# ==========================================================================
# Environment fingerprint (cache-key tier + BENCH_*.json metadata)
# ==========================================================================
def env_fingerprint() -> dict:
    """The compilation environment as plain JSON data — everything that can
    change what an identical lowering compiles to (jax/jaxlib/XLA versions,
    backend, device population) plus the repo's own program-shaping flag
    (``REPRO_BL_PALLAS``).  Deliberately hostname-free: the same wheel on a
    different machine of the same shape shares cache entries, and
    ``BENCH_*.json`` records (which embed this dict) stay comparable
    across machines without leaking identity."""
    import platform

    import jax
    import jaxlib

    try:
        from jax._src.lib import xla_extension_version
    except Exception:  # pragma: no cover - layout varies across jax versions
        xla_extension_version = None
    return {
        "jax": jax.__version__,
        "jaxlib": jaxlib.__version__,
        "xla_extension_version": xla_extension_version,
        "backend": jax.default_backend(),
        "device_count": jax.device_count(),
        "device_kind": jax.devices()[0].device_kind,
        "python": "%d.%d.%d" % sys.version_info[:3],
        "machine": platform.machine(),
        "pallas": os.environ.get(_PALLAS_FLAG, "0"),
    }


# ==========================================================================
# Deterministic object fingerprints (the cache-key spec tier)
# ==========================================================================
def fingerprint(obj: Any) -> str:
    """Process-stable canonical string for a cache-key object.

    Method specs are frozen dataclasses, but several hold *callables*
    (compressors close over budgets, the BL-DNN spec closes over loss/eval
    functions), whose ``repr`` embeds process-local addresses.  This walks
    the object structurally instead: dataclasses by qualified class name +
    field fingerprints, functions by ``module.qualname`` + defaults +
    closure-cell contents (addresses excluded), arrays by shape/dtype +
    content sha256, containers recursively.  Two processes building the
    same spec the same way produce the same string; anything unrecognized
    degrades to a type marker (worst case: a spurious cache miss, which
    just live-compiles)."""
    return _fp(obj, seen=set(), depth=0)


def _fp(o: Any, *, seen: set, depth: int) -> str:
    if depth > 10:
        return "<depth>"
    if o is None or isinstance(o, (bool, int, str)):
        return repr(o)
    if isinstance(o, float):
        return float.hex(o)
    if isinstance(o, bytes):
        return f"bytes:{hashlib.sha256(o).hexdigest()[:16]}"
    oid = id(o)
    if oid in seen:
        return "<cycle>"
    seen = seen | {oid}
    rec = functools.partial(_fp, seen=seen, depth=depth + 1)
    if isinstance(o, (tuple, list)):
        return "[" + ",".join(rec(v) for v in o) + "]"
    if isinstance(o, dict):
        items = sorted(o.items(), key=lambda kv: repr(kv[0]))
        return "{" + ",".join(f"{rec(k)}:{rec(v)}" for k, v in items) + "}"
    if isinstance(o, functools.partial):
        return (f"partial({rec(o.func)},{rec(tuple(o.args))},"
                f"{rec(dict(o.keywords))})")
    if dataclasses.is_dataclass(o) and not isinstance(o, type):
        fields = ",".join(
            f"{f.name}={rec(getattr(o, f.name))}"
            for f in dataclasses.fields(o))
        return f"{type(o).__module__}.{type(o).__qualname__}({fields})"
    if hasattr(o, "shape") and hasattr(o, "dtype"):
        try:
            arr = np.asarray(o)
            digest = hashlib.sha256(np.ascontiguousarray(arr)).hexdigest()[:16]
            return f"array({arr.shape},{arr.dtype},{digest})"
        except Exception:
            return (f"abstract({tuple(o.shape)},"
                    f"{np.dtype(o.dtype).name})")
    if callable(o):
        qual = (f"{getattr(o, '__module__', '?')}."
                f"{getattr(o, '__qualname__', type(o).__qualname__)}")
        cells = getattr(o, "__closure__", None) or ()
        closure = ",".join(rec(_cell_contents(c)) for c in cells)
        defaults = rec(getattr(o, "__defaults__", None))
        return f"fn({qual},defaults={defaults},closure=[{closure}])"
    return f"<{type(o).__module__}.{type(o).__qualname__}>"


def _cell_contents(cell):
    try:
        return cell.cell_contents
    except ValueError:          # empty cell
        return "<empty-cell>"


def entry_key(key_parts: Tuple) -> str:
    """sha256 digest over (caller key parts, environment fingerprint) —
    the on-disk entry name.  Any environment change (jax upgrade, device
    population, ``REPRO_BL_PALLAS``) lands entries under new keys; the
    manifest's stored env is additionally equality-checked on load, so a
    digest can never resurrect a stale-environment executable."""
    blob = json.dumps([[str(p) for p in key_parts], env_fingerprint()],
                      sort_keys=True).encode()
    return hashlib.sha256(blob).hexdigest()[:32]


# ==========================================================================
# Atomic file plumbing (the artifacts.py checkpoint idiom)
# ==========================================================================
def _sha256_file(path: str) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        for block in iter(lambda: f.read(1 << 20), b""):
            h.update(block)
    return h.hexdigest()


def _atomic_write(path: str, data: bytes) -> None:
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        f.write(data)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)
    try:
        dfd = os.open(os.path.dirname(path) or ".", os.O_RDONLY)
        try:
            os.fsync(dfd)
        finally:
            os.close(dfd)
    except OSError:
        pass


def _ensure_runtime_kernels() -> None:
    """Register the CPU runtime's legacy custom-call targets before running
    a deserialized executable.  jaxlib registers them lazily inside its
    LOWERING helpers (`jaxlib/lapack.py` calls ``_lapack.initialize()``
    from ``trsm_hlo`` etc.), so a process that only ever deserializes —
    never lowers — would hand XLA a program whose ``blas_dtrsm`` /
    ``lapack_*`` symbols were never registered and segfault at dispatch."""
    try:
        from jaxlib.cpu import _lapack

        _lapack.initialize()
    except Exception:   # non-CPU-only jaxlib layouts; GPU registers eagerly
        pass


# ==========================================================================
# The cache
# ==========================================================================
class ProgramCache:
    """One AOT executable cache directory (tier 1).

    ``stats`` counts dispatch outcomes (``hit`` / ``miss`` and the miss
    reasons ``absent`` / ``corrupt`` / ``skew`` / ``load_error``, plus
    ``store_error`` for failed writes); ``events`` keeps the per-program
    outcome log the serve loop reports in its record meta."""

    def __init__(self, root: str):
        self.root = os.path.abspath(root)
        os.makedirs(self.root, exist_ok=True)
        self.stats: collections.Counter = collections.Counter()
        self.events: list = []

    # ------------------------------------------------------------------
    def _paths(self, name: str, key: str) -> Tuple[str, str]:
        base = os.path.join(self.root, f"{name}-{key}")
        return base + ".bin", base + ".json"

    def load_manifest(self, name: str, key: str) -> Optional[dict]:
        _, mpath = self._paths(name, key)
        try:
            with open(mpath) as f:
                return json.load(f)
        except (OSError, json.JSONDecodeError):
            return None

    def _load(self, name: str, key: str):
        """(compiled, why) — compiled is None on any miss; ``why`` names
        the miss class for stats."""
        bpath, mpath = self._paths(name, key)
        manifest = self.load_manifest(name, key)
        if manifest is None:
            return None, ("absent" if not os.path.exists(mpath)
                          else "corrupt")
        if manifest.get("schema") != PROGCACHE_SCHEMA:
            return None, "skew"
        if manifest.get("env") != env_fingerprint():
            return None, "skew"
        if not os.path.exists(bpath):
            return None, "corrupt"
        if _sha256_file(bpath) != manifest.get("payload_sha256"):
            return None, "corrupt"
        try:
            from jax.experimental import serialize_executable as se

            _ensure_runtime_kernels()
            with open(bpath, "rb") as f:
                payload, in_tree, out_tree = pickle.load(f)
            return se.deserialize_and_load(payload, in_tree, out_tree), "hit"
        except Exception:
            return None, "load_error"

    def _store(self, name: str, key: str, compiled, aux: Optional[dict]):
        try:
            from jax.experimental import serialize_executable as se

            payload, in_tree, out_tree = se.serialize(compiled)
            bpath, mpath = self._paths(name, key)
            _atomic_write(bpath, pickle.dumps((payload, in_tree, out_tree)))
            manifest = {
                "schema": PROGCACHE_SCHEMA,
                "name": name,
                "key": key,
                "payload_sha256": _sha256_file(bpath),
                "payload_bytes": os.path.getsize(bpath),
                "env": env_fingerprint(),
                "created_unix": time.time(),
                "aux": aux or {},
            }
            _atomic_write(
                mpath, (json.dumps(manifest, indent=1) + "\n").encode())
            return True
        except Exception:
            # unserializable program (exotic backend/custom call) — the
            # live-compiled executable still runs; only persistence is lost
            self.stats["store_error"] += 1
            return False

    # ------------------------------------------------------------------
    def load_or_compile(self, *, name: str, key_parts: Tuple,
                        lower: Callable[[], Any],
                        aux: Optional[dict] = None):
        """The dispatch primitive: return ``(compiled, status)`` where
        ``status`` is ``"hit"`` or the miss class that forced the live
        compile.  ``lower`` is called only on a miss and must return a
        ``jax.stages.Lowered``; the freshly compiled executable is stored
        back (best-effort) so the next process hits."""
        key = entry_key(key_parts)
        compiled, why = self._load(name, key)
        if compiled is not None:
            self.stats["hit"] += 1
            self.events.append({"name": name, "key": key, "status": "hit"})
            return compiled, "hit"
        self.stats["miss"] += 1
        self.stats[why] += 1
        compiled = lower().compile()
        self._store(name, key, compiled, aux)
        self.events.append({"name": name, "key": key, "status": why})
        return compiled, why

    def summary(self) -> dict:
        """Operational stats for record metadata (serve ``meta``)."""
        return {"dir": self.root, "stats": dict(self.stats),
                "programs": list(self.events)}


# ==========================================================================
# Active-cache plumbing + tier 2
# ==========================================================================
_ACTIVE: Optional[ProgramCache] = None


def active() -> Optional[ProgramCache]:
    """The process's active `ProgramCache`, or None (caching disabled)."""
    return _ACTIVE


def activate(root: str, *, persistent_compilation_cache: bool = True
             ) -> ProgramCache:
    """Activate an AOT cache rooted at ``root`` (idempotent for the same
    directory) and, by default, point jax's persistent compilation cache
    (tier 2) at ``<root>/xla``."""
    global _ACTIVE
    if _ACTIVE is None or _ACTIVE.root != os.path.abspath(root):
        _ACTIVE = ProgramCache(root)
    if persistent_compilation_cache:
        enable_persistent_compilation_cache(os.path.join(_ACTIVE.root, "xla"))
    return _ACTIVE


def deactivate() -> None:
    global _ACTIVE
    _ACTIVE = None


def enable_persistent_compilation_cache(path: str) -> None:
    """Tier 2: persist every jit compile this process does (below the AOT
    layer — partial-chunk lengths, gap-stream evals, dry-runs) into jax's
    own on-disk compilation cache.  Thresholds are zeroed so CPU-fast
    programs cache too (jax's defaults skip sub-second compiles)."""
    import jax

    os.makedirs(path, exist_ok=True)
    jax.config.update("jax_compilation_cache_dir", path)
    jax.config.update("jax_persistent_cache_min_compile_time_secs", 0)
    jax.config.update("jax_persistent_cache_min_entry_size_bytes", -1)
    # jax initializes its cache AT MOST ONCE per process, latching whatever
    # `jax_compilation_cache_dir` held at the first compile.  Serve always
    # compiles before activation (problem/fleet construction jits), so the
    # latch has already locked in `None` — reset it or tier 2 silently
    # never engages.
    try:
        from jax._src import compilation_cache as _cc

        _cc.reset_cache()
    except Exception:  # pragma: no cover - private-module layout shifted
        pass


def from_env() -> Optional[ProgramCache]:
    """Honor ``REPRO_PROGCACHE_DIR`` (subprocess benches and tests opt in
    through the environment; ``REPRO_PROGCACHE=0`` force-disables)."""
    if os.environ.get("REPRO_PROGCACHE", "1") == "0":
        return None
    root = os.environ.get("REPRO_PROGCACHE_DIR")
    if not root:
        return _ACTIVE
    return activate(root)


def validate_entry(manifest_path: str) -> list:
    """Schema-validate one cache-entry manifest (``tools/schema_diff.py
    --progcache``); returns a list of problem strings (empty = valid)."""
    problems = []
    try:
        with open(manifest_path) as f:
            manifest = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        return [f"{manifest_path}: unreadable manifest ({e})"]
    if manifest.get("schema") != PROGCACHE_SCHEMA:
        problems.append(f"{manifest_path}: schema "
                        f"{manifest.get('schema')!r} != {PROGCACHE_SCHEMA!r}")
    for req in ("name", "key", "payload_sha256", "env"):
        if req not in manifest:
            problems.append(f"{manifest_path}: missing key {req!r}")
    bpath = manifest_path[:-len(".json")] + ".bin"
    if "payload_sha256" in manifest:
        if not os.path.exists(bpath):
            problems.append(f"{manifest_path}: payload {bpath} missing")
        elif _sha256_file(bpath) != manifest["payload_sha256"]:
            problems.append(f"{manifest_path}: payload sha256 mismatch")
    return problems


# a process that opts in via the environment gets its cache at import time,
# before any serve program dispatches
from_env()
