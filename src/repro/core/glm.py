"""Generalized linear models for the paper's experiments (Eq. 16).

Regularized logistic regression:
    f(x) = (1/n) Σ_i f_i(x) + (λ/2)‖x‖²,
    f_i(x) = (1/m) Σ_j log(1 + exp(−b_ij a_ijᵀ x)).

We fold the ridge evenly into every client: f_i^λ(x) = f_i(x) + (λ/2)‖x‖², so
∇²f_i^λ = (1/m) Aᵀ D A + λI with D = diag(φ″).  Synthetic data generators
reproduce the LibSVM regimes of Table 2 (n clients, m points each, d features,
intrinsic dimension r ≪ d).
"""
from __future__ import annotations

import dataclasses
from typing import List

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class ClientData:
    A: jax.Array  # (m, d) features
    b: jax.Array  # (m,) labels in {−1, +1}
    lam: float    # ridge coefficient (shared)


def sigmoid(t):
    return 0.5 * (jnp.tanh(t / 2.0) + 1.0)


def loss(data: ClientData, x: jax.Array) -> jax.Array:
    z = data.A @ x * data.b
    return jnp.mean(jnp.logaddexp(0.0, -z)) + 0.5 * data.lam * jnp.dot(x, x)


def grad(data: ClientData, x: jax.Array) -> jax.Array:
    z = data.A @ x * data.b
    coef = -data.b * sigmoid(-z)  # φ' = −b σ(−b aᵀx)
    return data.A.T @ coef / data.A.shape[0] + data.lam * x


def hess_diag_weights(data: ClientData, x: jax.Array) -> jax.Array:
    """φ″(a_jᵀx) for every sample: σ(z)(1−σ(z)) with z = b aᵀx (b²=1)."""
    z = data.A @ x * data.b
    s = sigmoid(z)
    return s * (1.0 - s)


def hess(data: ClientData, x: jax.Array) -> jax.Array:
    w = hess_diag_weights(data, x)
    m = data.A.shape[0]
    return (data.A * w[:, None]).T @ data.A / m + data.lam * jnp.eye(data.A.shape[1], dtype=x.dtype)


def hess_data_part(data: ClientData, x: jax.Array) -> jax.Array:
    """Hessian without the λI term (lives in the data subspace — §2.3)."""
    w = hess_diag_weights(data, x)
    m = data.A.shape[0]
    return (data.A * w[:, None]).T @ data.A / m


def global_loss(clients: List[ClientData], x: jax.Array) -> jax.Array:
    return jnp.mean(jnp.stack([loss(c, x) for c in clients]))


def global_grad(clients: List[ClientData], x: jax.Array) -> jax.Array:
    return jnp.mean(jnp.stack([grad(c, x) for c in clients]), axis=0)


def global_hess(clients: List[ClientData], x: jax.Array) -> jax.Array:
    return jnp.mean(jnp.stack([hess(c, x) for c in clients]), axis=0)


def newton_solve(clients: List[ClientData], x0: jax.Array, iters: int = 20) -> jax.Array:
    """Reference optimum: the paper uses the 20th Newton iterate as x*."""
    x = x0
    for _ in range(iters):
        g = global_grad(clients, x)
        Hm = global_hess(clients, x)
        x = x - jnp.linalg.solve(Hm, g)
    return x


def make_synthetic(
    seed: int,
    n_clients: int,
    m: int,
    d: int,
    r: int,
    lam: float = 1e-3,
    noise: float = 0.1,
    heterogeneity: float = 0.5,
) -> List[ClientData]:
    """Low-intrinsic-dimension federated logistic regression data.

    Each client i draws an orthonormal subspace basis V_i ∈ R^{d×r} (shared
    global subspace rotated per-client by `heterogeneity` to model non-iid
    data), samples coefficients α ∈ R^{m×r}, sets A_i = α V_iᵀ (so rows live in
    an r-dim subspace exactly, as §2.3 assumes), and labels from a planted
    model with flip noise.
    """
    rng = np.random.default_rng(seed)
    Q_global, _ = np.linalg.qr(rng.standard_normal((d, r)))
    x_true = rng.standard_normal(d) / np.sqrt(d)
    clients = []
    for i in range(n_clients):
        P, _ = np.linalg.qr(
            (1 - heterogeneity) * Q_global + heterogeneity * rng.standard_normal((d, r))
        )
        alpha = rng.standard_normal((m, r))
        A = alpha @ P.T                      # rows ∈ span(P) exactly, rank ≤ r
        logits = A @ x_true
        p = 1.0 / (1.0 + np.exp(-logits))
        b = np.where(rng.random(m) < (1 - noise) * p + noise * 0.5, 1.0, -1.0)
        clients.append(
            ClientData(A=jnp.asarray(A, jnp.float64), b=jnp.asarray(b, jnp.float64), lam=lam)
        )
    return clients


# Table 2 regimes (scaled down ~ where needed so CPU tests stay fast)
TABLE2 = {
    "a1a": dict(n_clients=16, m=100, d=123, r=64),
    "phishing": dict(n_clients=10, m=11, d=68, r=35),
    "madelon-mini": dict(n_clients=10, m=40, d=200, r=60),
    "w2a-mini": dict(n_clients=10, m=69, d=300, r=59),
}


def make_table2(name: str, seed: int = 0, lam: float = 1e-3) -> List[ClientData]:
    return make_synthetic(seed=seed, lam=lam, **TABLE2[name])
