"""Cohort-streaming engine: flat-in-n federated rounds for ≥100k clients.

The stacked engine (`repro.core.rounds.run_chunk`) materializes every
client's data and shift state on device, so the fleet size n is bounded by
accelerator memory — fig1-xl tops out at 512 clients.  The paper's
partial-participation methods (BL2/BL3, Alg. 2–3) and the Bernoulli-lazy
uplink (FedNL-BAG) only ever *touch* the sampled cohort, so this module
streams instead:

  * the full fleet lives in a host-resident `client_batch.ClientStore`
    (data plane A/b plus the per-client carry leaves — shifts z_i/w_i,
    Hessian estimates L_i, ...);
  * per **epoch** (``rounds_per_cohort`` consecutive rounds) a cohort of
    ``cohort`` clients is sampled by a counter-based host PRNG keyed on
    (root key, epoch) — a pure function of the absolute epoch index, so
    the schedule is invariant to how rounds are batched into chunks,
    exactly like the serve driver's ``fold_in(root_key, t)`` round keys;
  * only the cohort's rows are gathered onto the device and run through
    the cohort chunk program (`rounds.run_cohort_chunk`), with the next
    epoch's gather + host→device transfer **double-buffered** on a
    prefetch thread behind the current chunk's jitted scan;
  * absent clients' state stays frozen per Alg. 2–3 — their contribution
    to each fleet aggregate (Σᵢ Hᵢ, Σᵢ gᵢ, max βᵢ ...) is maintained
    *incrementally* on the host (`MethodSpec.cohort_aggregates`): per
    epoch the engine subtracts the cohort's epoch-start rows from the
    running fleet totals to get the ``frozen`` contribution, and adds the
    updated rows back at epoch end.  Per-round work is therefore O(cohort),
    not O(n) — per-round wall time is flat in the fleet size (the
    ``cohort_stream`` bench pins ≤1.15× from n=1k to n=100k).

When ``cohort >= n`` the engine drops into **full mode**: the whole fleet
is gathered once (an identity gather) and rounds dispatch to the EXISTING
stacked chunk program — same jitted program, same fold_in keys, same
reducers — so the cohort==fleet configuration is bitwise-identical to the
stacked engine on both backends (the parity pin that licenses this
refactor, asserted by tests/test_cohort.py and the bench record).

Checkpointing: the device carry (cohort rows + server state) is the usual
flattened-leaves payload; the host side (store state, aggregate totals,
the current epoch's frozen stats) rides in the ``repro.exp/ckpt@2``
``host_state`` payload (`repro.exp.artifacts.save_checkpoint`).  Restoring
at round t resamples the epoch's cohort deterministically and resumes
bit-exactly mid-epoch or at a boundary.
"""
from __future__ import annotations

import functools
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from . import client_batch, rounds

#: fold_in salt separating the cohort-sampler stream from the per-round
#: key stream (rounds use fold_in(root_key, t) with small t)
COHORT_SALT = 0x0C0407


def standard_basisb(d: int, n: int) -> client_batch.BatchedBasis:
    """A leafless standard-basis `BatchedBasis` for n clients — the basis
    kind of the store-backed problems (no per-client arrays to stream)."""
    return client_batch.BatchedBasis(kind="standard", d=d, rs=(d,) * n)


# ==========================================================================
# Host-side (numpy) fleet evaluation — slab-wise, never O(n) on device
# ==========================================================================
def store_loss(store: client_batch.ClientStore, x, slab: int = 8192) -> float:
    """Global logistic loss over the full fleet, slab-accumulated in f64 on
    the host (matches `client_batch.global_loss` / `glm` conventions:
    mean-over-clients of mean-over-samples logaddexp(0, −b·Ax) + λ/2‖x‖²)."""
    x = np.asarray(x, np.float64)
    tot = 0.0
    for lo in range(0, store.n, slab):
        A = np.asarray(store.A[lo:lo + slab], np.float64)
        b = np.asarray(store.b[lo:lo + slab], np.float64)
        z = np.einsum("nmd,d->nm", A, x) * b
        tot += float(np.sum(np.mean(np.logaddexp(0.0, -z), axis=1)))
    return tot / store.n + 0.5 * store.lam * float(np.dot(x, x))


def store_newton_solve(store: client_batch.ClientStore, x0, iters: int = 20,
                       slab: int = 8192) -> np.ndarray:
    """Reference optimum of the store's fleet objective by damped-free
    Newton, with the gradient/Hessian accumulated slab-by-slab on the host
    (the stacked `newton_solve_fused` would need the whole (n, m, d) fleet
    on device — infeasible at streaming scale)."""
    x = np.asarray(x0, np.float64).copy()
    d = store.d
    for _ in range(int(iters)):
        g = np.zeros(d)
        H = np.zeros((d, d))
        for lo in range(0, store.n, slab):
            A = np.asarray(store.A[lo:lo + slab], np.float64)
            b = np.asarray(store.b[lo:lo + slab], np.float64)
            z = np.einsum("nmd,d->nm", A, x) * b
            s = 1.0 / (1.0 + np.exp(z))          # σ(−z)
            m = A.shape[1]
            g += np.einsum("nmd,nm->d", A, -b * s) / m
            H += np.einsum("nmd,nm,nme->de", A, s * (1.0 - s), A) / m
        g = g / store.n + store.lam * x
        H = H / store.n + store.lam * np.eye(d)
        x = x - np.linalg.solve(H, g)
    return x


# ==========================================================================
# Slab-wise fleet init programs
# ==========================================================================
@functools.partial(jax.jit, static_argnames=("spec", "R"))
def _slab_extras(spec, R, batch, basisb, x0, carry):
    """`MethodSpec.cohort_init_extras` for one slab (separate program from
    the init itself so single-slab init reuses the EXACT stacked
    `rounds._init_jit` program — the full-mode bitwise parity pin)."""
    env = rounds.Env(batch=batch, basisb=basisb, x0=x0,
                     extra=spec.prepare(R, batch, basisb, x0))
    return spec.cohort_init_extras(R, env, carry)


class CohortEngine:
    """Streaming round driver over a `ClientStore`.

    Args:
      spec: a ``supports_cohort`` `MethodSpec` (BL2/BL3/FedNL-BAG).
      store: the host-resident fleet (`client_batch.ClientStore`); its
        ``state`` plane is (re)initialized by the engine.
      x0: initial iterate (d,).
      cohort: clients sampled per epoch.  ``cohort >= store.n`` selects
        full mode (identity gather + the stacked chunk program — bitwise
        the stacked engine).
      rounds_per_cohort: rounds a sampled cohort stays resident (the epoch
        length); higher amortizes the gather, lower refreshes participation
        across more of the fleet.
      root_key: the run's root PRNG key — per-round keys are
        ``fold_in(root_key, t)``, the sampler stream is
        ``fold_in(root_key, COHORT_SALT)``.
      basis: ``"standard"`` or None (BL3) — store-backed problems use
        convention bases only (nothing per-client to ship or stream).
      sharded: run chunks through the shard_map backend (the cohort axis
        shards over the client mesh); capacity is padded to a multiple of
        the device count.
      prefetch: double-buffer the next epoch's gather + H2D transfer on a
        background thread (pure data movement — bitwise-neutral).
    """

    def __init__(self, spec, store: client_batch.ClientStore, x0, *,
                 cohort: int, rounds_per_cohort: int, root_key,
                 basis: Optional[str] = "standard", sharded: bool = False,
                 exact: bool = True, slab: int = 4096, prefetch: bool = True):
        if rounds_per_cohort < 1:
            raise ValueError(
                f"rounds_per_cohort must be >= 1, got {rounds_per_cohort}")
        if cohort < 1:
            raise ValueError(f"cohort must be >= 1, got {cohort}")
        self.spec = spec
        self.store = store
        self.x0 = jnp.asarray(x0)
        self.n = store.n
        self.d = int(self.x0.shape[0])
        self.rpc = int(rounds_per_cohort)
        self.root_key = root_key
        self.sharded = bool(sharded)
        self.exact = bool(exact)
        self.slab = int(slab)
        self.full = int(cohort) >= self.n
        self.cohort = self.n if self.full else int(cohort)
        if not self.full and not getattr(spec, "supports_cohort", False):
            raise ValueError(
                f"{type(spec).__name__} is not cohort-capable "
                "(MethodSpec.supports_cohort is False) — absent clients' "
                "fleet contributions cannot be frozen; run it stacked or "
                "with cohort >= n")
        # padded capacity: every shard holds the same number of slots
        cap = self.cohort
        if self.sharded and not self.full:
            ndev = jax.local_device_count()
            cap = ((cap + ndev - 1) // ndev) * ndev
        self.cap = cap
        if basis not in (None, "standard"):
            raise ValueError(
                f"cohort streaming supports the 'standard' convention basis "
                f"or None, got {basis!r} (per-client basis arrays would "
                "have to stream with the cohort — not implemented)")
        self._basis_kind = basis
        self._basis_cap = (None if basis is None
                           else standard_basisb(self.d, self.cap))
        self._basis_full = (None if basis is None
                            else standard_basisb(self.d, self.n))
        self._seed64 = self._sampler_seed()
        self._aggs = dict(spec.cohort_aggregates()) if not self.full else {}
        self._totals: dict = {}
        self._server: dict = {}
        self._cur: Optional[dict] = None
        self._treedef = None
        self._is_client = None
        self.metrics = {"prefetch_wait_us": 0.0, "prefetch_work_us": 0.0,
                        "h2d_bytes": 0, "epochs_prefetched": 0,
                        "epochs_loaded": 0}
        self._prefetch_on = bool(prefetch) and not self.full
        self._pool = (ThreadPoolExecutor(max_workers=1)
                      if self._prefetch_on else None)
        self._pf = None
        self._pf_epoch = -1
        self._init_fleet()

    # ------------------------------------------------------------------
    # fleet init: slab-wise stacked init → host store + server state
    # ------------------------------------------------------------------
    def _make_basis(self, n: int):
        return (None if self._basis_kind is None
                else standard_basisb(self.d, n))

    def _init_fleet(self):
        spec, store, x0 = self.spec, self.store, self.x0
        n = self.n
        names = tuple(getattr(spec, "carry_names", ()))
        slabs = [(lo, min(lo + self.slab, n)) for lo in range(0, n, self.slab)]
        state: dict = {}
        extras_sums: dict = {}
        env_last = None
        carry_last = None
        for lo, hi in slabs:
            sn = hi - lo
            batch = store.gather_batch(np.arange(lo, hi))
            basisb = self._make_basis(sn)
            R = rounds.VmapReducer(n=sn)
            # the SAME cached program the stacked serve path inits with —
            # at one slab (== full mode at test scale) the carry is
            # bitwise the stacked engine's carry; `serve_init` also shares
            # the stacked path's AOT cache entries when a program cache is
            # active
            carry = rounds.serve_init(spec, R, batch, basisb, x0)
            if self._is_client is None:
                self._split_carry_contract(spec, names, carry, batch,
                                           basisb, x0)
            for name, elem, cl in zip(names, carry, self._is_client):
                if cl:
                    arr = np.asarray(elem)
                    if name not in state:
                        state[name] = np.empty((n,) + arr.shape[1:],
                                               arr.dtype)
                    state[name][lo:hi] = arr
                elif lo == 0:
                    self._server[name] = elem
            if len(slabs) > 1:
                ex = _slab_extras(spec, R, batch, basisb, x0, carry)
                for ename, ev in ex.items():
                    s = np.sum(np.asarray(ev, np.float64), axis=0)
                    extras_sums[ename] = (s if ename not in extras_sums
                                          else extras_sums[ename] + s)
                if hi == n:
                    env_last = rounds.Env(
                        batch=batch, basisb=basisb, x0=x0,
                        extra=spec.prepare(R, batch, basisb, x0))
                    carry_last = carry
        store.state = state
        if len(slabs) > 1:
            # server elements derived from a FLEET reduction (e.g. BAG's
            # H⁰ = meanᵢ recon(L⁰ᵢ) + ridge) must come from the accumulated
            # cross-slab sums, not from any single slab's init
            over = spec.cohort_server_init(
                env_last, {k: jnp.asarray(v) for k, v in extras_sums.items()},
                n, carry_last)
            for name, val in over.items():
                self._server[name] = jnp.asarray(val)
        for agg, (leaf, op) in self._aggs.items():
            if op == "mean":
                self._totals[agg] = np.sum(
                    state[leaf].astype(np.float64), axis=0)

    def _split_carry_contract(self, spec, names, carry, batch, basisb, x0):
        if not isinstance(carry, tuple) or len(names) != len(carry):
            raise ValueError(
                f"{type(spec).__name__}.carry_names has {len(names)} names "
                f"but init returns {len(carry) if isinstance(carry, tuple) else type(carry)} "
                "elements — the streaming engine needs one name per "
                "top-level carry element")
        flags = rounds.carry_client_flags(spec, batch, basisb, x0)
        is_client = []
        for name, fl, elem in zip(names, flags, carry):
            leaves = jax.tree_util.tree_leaves(fl)
            if any(leaves) and not all(leaves):
                raise ValueError(
                    f"carry element {name!r} mixes client-stacked and "
                    "server leaves — not streamable")
            cl = bool(leaves and all(leaves))
            if cl and len(jax.tree_util.tree_leaves(elem)) != 1:
                raise ValueError(
                    f"client-stacked carry element {name!r} must be a "
                    "single array to live in the ClientStore")
            is_client.append(cl)
        self._is_client = tuple(is_client)
        self._treedef = jax.tree_util.tree_structure(carry)
        for agg, (leaf, _op) in self._aggs.items():
            if leaf not in names or not is_client[names.index(leaf)]:
                raise ValueError(
                    f"cohort aggregate {agg!r} references carry leaf "
                    f"{leaf!r}, which is not a client-stacked element")
        self._names = names

    # ------------------------------------------------------------------
    # cohort sampling: counter-based, chunk-boundary invariant
    # ------------------------------------------------------------------
    def _sampler_seed(self) -> int:
        k = jax.random.fold_in(self.root_key, COHORT_SALT)
        try:
            if jnp.issubdtype(k.dtype, jax.dtypes.prng_key):
                k = jax.random.key_data(k)
        except (AttributeError, TypeError):
            pass
        kd = np.asarray(k).astype(np.uint64).ravel()
        seed = int(kd[0])
        if kd.size > 1:
            seed = (seed << 32) | int(kd[1])
        return seed

    def cohort_indices(self, epoch: int) -> np.ndarray:
        """Epoch's sorted cohort (unique global indices) — a pure function
        of (root key, epoch): Philox keyed by ``(seed64 << 64) + epoch``,
        so the schedule never depends on chunking or on trajectory state."""
        if self.full:
            return np.arange(self.n, dtype=np.int64)
        n, c = self.n, self.cohort
        rng = np.random.Generator(
            np.random.Philox(key=(self._seed64 << 64) + int(epoch)))
        if c * 8 <= n:
            # rejection path: first c distinct values in draw order (an
            # unbiased without-replacement sample at O(c) draws)
            chosen = np.empty(0, np.int64)
            while chosen.size < c:
                cand = rng.integers(0, n, size=2 * c, dtype=np.int64)
                merged = np.concatenate([chosen, cand])
                _uniq, first = np.unique(merged, return_index=True)
                chosen = merged[np.sort(first)]
            idx = chosen[:c]
        else:
            idx = rng.permutation(n)[:c]
        return np.sort(idx).astype(np.int64)

    def _padded(self, idx: np.ndarray):
        pidx = np.zeros(self.cap, np.int64)
        pidx[:idx.size] = idx
        real = np.zeros(self.cap, bool)
        real[:idx.size] = True
        return pidx, real

    # ------------------------------------------------------------------
    # prefetch: next epoch's gather + H2D behind the current chunk's scan
    # ------------------------------------------------------------------
    def _prefetch_submit(self, epoch: int):
        if not self._prefetch_on or self._pf_epoch == epoch:
            return

        def work():
            w0 = time.perf_counter()
            idx = self.cohort_indices(epoch)
            pidx, real = self._padded(idx)
            A, b = self.store.gather_data(pidx)
            if not self.sharded:
                # vmap backend: commit the H2D transfer on this thread too;
                # the sharded backend re-lays arrays across the mesh at
                # dispatch, so only the host gather is hoisted there
                A, b = jnp.asarray(A), jnp.asarray(b)
            return idx, pidx, real, A, b, time.perf_counter() - w0

        self._pf_epoch = epoch
        self._pf = self._pool.submit(work)

    def _fetch_epoch(self, epoch: int):
        if self._pf is not None and self._pf_epoch == epoch:
            w0 = time.perf_counter()
            idx, pidx, real, A, b, work_s = self._pf.result()
            self._pf = None
            self.metrics["prefetch_wait_us"] += (time.perf_counter() - w0) * 1e6
            self.metrics["prefetch_work_us"] += work_s * 1e6
            self.metrics["epochs_prefetched"] += 1
            return idx, pidx, real, A, b
        idx = self.cohort_indices(epoch)
        pidx, real = self._padded(idx)
        A, b = self.store.gather_data(pidx)
        return idx, pidx, real, A, b

    @property
    def prefetch_overlap(self) -> float:
        """Fraction of prefetch work hidden behind compute: 1 − wait/work
        over the prefetched epochs (1.0 = fully overlapped)."""
        work = self.metrics["prefetch_work_us"]
        if work <= 0.0:
            return 0.0
        return max(0.0, 1.0 - self.metrics["prefetch_wait_us"] / work)

    # ------------------------------------------------------------------
    # epoch residency
    # ------------------------------------------------------------------
    def _load_epoch(self, epoch: int):
        idx, pidx, real, A, b = self._fetch_epoch(epoch)
        self.metrics["h2d_bytes"] += int(A.nbytes) + int(b.nbytes)
        self.metrics["epochs_loaded"] += 1
        batch = client_batch.ClientBatch(A=jnp.asarray(A), b=jnp.asarray(b),
                                         lam=self.store.lam)
        elems = []
        for name, cl in zip(self._names, self._is_client):
            elems.append(jnp.asarray(self.store.state[name][pidx]) if cl
                         else self._server[name])
        frozen_np = {}
        for agg, (leaf, op) in self._aggs.items():
            rows = self.store.state[leaf][idx].astype(np.float64)
            if op == "mean":
                frozen_np[agg] = self._totals[agg] - rows.sum(axis=0)
            else:  # max over the ABSENT clients (streaming ⇒ some exist)
                mask = np.ones(self.n, bool)
                mask[idx] = False
                frozen_np[agg] = np.max(
                    self.store.state[leaf][mask].astype(np.float64), axis=0)
        self._cur = {
            "epoch": int(epoch), "idx": idx,
            "cidx": jnp.asarray(pidx, jnp.int32),
            "real": jnp.asarray(real),
            "batch": batch, "carry": tuple(elems),
            "frozen": {k: jnp.asarray(v) for k, v in frozen_np.items()},
            "frozen_np": frozen_np,
        }
        self._prefetch_submit(epoch + 1)

    def _unload_current(self):
        cur = self._cur
        if cur is None:
            return
        k = cur["idx"].size
        new_rows = {}
        for name, elem, cl in zip(self._names, cur["carry"],
                                  self._is_client):
            if cl:
                rows = np.asarray(elem)[:k]
                self.store.state[name][cur["idx"]] = rows
                new_rows[name] = rows
            else:
                self._server[name] = elem
        for agg, (leaf, op) in self._aggs.items():
            if op == "mean":
                # totals = frozen (absent, unchanged) + updated cohort rows
                self._totals[agg] = (cur["frozen_np"][agg]
                                     + new_rows[leaf].astype(np.float64)
                                     .sum(axis=0))
        self._cur = None

    def server_state(self, name: str):
        """Live value of a server carry element.  While an epoch is
        resident its server elements live in the (donated) device carry —
        ``self._server`` may hold deleted buffers until the next unload —
        so reads must go through the current carry."""
        i = self._names.index(name)
        if self._is_client[i]:
            raise ValueError(f"{name!r} is client-stacked, not server state")
        if self._cur is not None:
            return self._cur["carry"][i]
        return self._server[name]

    def _full_carry(self):
        elems = []
        for name, cl in zip(self._names, self._is_client):
            elems.append(jnp.asarray(self.store.state[name]) if cl
                         else self._server[name])
        return tuple(elems)

    def _ensure_full_loaded(self):
        if self._cur is not None:
            return
        batch = self.store.gather_batch(np.arange(self.n))
        self._cur = {"epoch": None, "idx": np.arange(self.n),
                     "batch": batch, "carry": self._full_carry(),
                     "frozen_np": {}}

    # ------------------------------------------------------------------
    # program warming (repro.core.progcache)
    # ------------------------------------------------------------------
    def warm_programs(self, chunk: int) -> bool:
        """Resolve this engine's chunk program — load from the active
        program cache or compile-and-persist — without running a round or
        touching engine state.  All arguments are zero-valued templates at
        dispatch shapes (the store's dtypes, the padded capacity, the
        epoch-aligned first-segment length), so the serve loop can warm
        BEFORE checkpoint restore.  Returns False when no cache is
        active."""
        if rounds.progcache.active() is None:
            return False
        chunk = int(chunk)
        rows = self.n if self.full else self.cap
        batch = client_batch.ClientBatch(
            A=jnp.zeros((rows,) + self.store.A.shape[1:],
                        self.store.A.dtype),
            b=jnp.zeros((rows,) + self.store.b.shape[1:],
                        self.store.b.dtype),
            lam=self.store.lam)
        carry = self.carry_template()
        if self.full:
            return rounds.warm_chunk_program(
                self.spec, batch, self._basis_full, self.x0, carry, chunk,
                self.root_key, sharded=self.sharded, exact=self.exact)
        # frozen templates mirror `_load_epoch`'s jnp.asarray(float64)
        # conversion so the warm signature matches the dispatch signature
        frozen = {}
        for agg, (leaf, op) in self._aggs.items():
            shape = (self._totals[agg].shape if op == "mean"
                     else self.store.state[leaf].shape[1:])
            frozen[agg] = jnp.asarray(np.zeros(shape, np.float64))
        # run_chunk cuts segments at epoch boundaries, so the first (and
        # dominant) segment length is min(chunk, rounds_per_cohort)
        return rounds.warm_cohort_chunk_program(
            self.spec, batch, self._basis_cap, self.x0, carry,
            min(chunk, self.rpc), self.root_key,
            cidx=np.zeros(self.cap, np.int32),
            creal=np.ones(self.cap, bool), frozen=frozen, n_global=self.n,
            sharded=self.sharded, exact=self.exact)

    # ------------------------------------------------------------------
    # driver
    # ------------------------------------------------------------------
    def run_chunk(self, t0: int, steps: int):
        """Run rounds [t0, t0+steps) and return the history streams
        ``(eval_x, CommLedger-of-streams, events)`` — the same tuple as
        `rounds.run_chunk`.  Segments are cut at epoch boundaries
        internally; any chunking of calls produces the same streams
        (chunk-boundary invariance, pinned by tests)."""
        outs = []
        t = int(t0)
        end = t + int(steps)
        while t < end:
            if self.full:
                self._ensure_full_loaded()
                cur = self._cur
                seg = end - t
                carry, ys = rounds.run_chunk(
                    self.spec, cur["batch"], self._basis_full, self.x0,
                    cur["carry"], t, seg, self.root_key,
                    sharded=self.sharded, exact=self.exact)
            else:
                e = t // self.rpc
                if self._cur is None or self._cur["epoch"] != e:
                    self._unload_current()
                    self._load_epoch(e)
                cur = self._cur
                seg = min(end, (e + 1) * self.rpc) - t
                carry, ys = rounds.run_cohort_chunk(
                    self.spec, cur["batch"], self._basis_cap, self.x0,
                    cur["carry"], t, seg, self.root_key,
                    cidx=cur["cidx"], creal=cur["real"],
                    frozen=cur["frozen"], n_global=self.n,
                    sharded=self.sharded, exact=self.exact)
            cur["carry"] = carry
            outs.append(ys)
            t += seg
        if len(outs) == 1:
            return outs[0]
        return jax.tree.map(lambda *a: jnp.concatenate(a, axis=0), *outs)

    # ------------------------------------------------------------------
    # checkpoint plumbing (repro.exp/ckpt@2)
    # ------------------------------------------------------------------
    def carry_template(self):
        """Shape/dtype template of the device carry (the serialization
        contract the serve loop validates checkpoints against)."""
        if self.full:
            return self._full_carry()
        elems = []
        for name, cl in zip(self._names, self._is_client):
            if cl:
                st = self.store.state[name]
                elems.append(jnp.zeros((self.cap,) + st.shape[1:], st.dtype))
            else:
                elems.append(self._server[name])
        return tuple(elems)

    def checkpoint_payload(self):
        """(carry_leaves, host_state) for `artifacts.save_checkpoint`.

        The store rows of the CURRENT cohort are its epoch-start values
        (scatter-back is lazy), the device carry holds their live values,
        and ``frozen`` is the epoch's frozen fleet contribution — together
        exactly the state `restore` needs for a bit-exact mid-epoch resume."""
        if self._cur is None:
            raise RuntimeError("no rounds have run — nothing to checkpoint")
        # copies, not views: the device carry's buffers are DONATED to the
        # next chunk program, and the store rows mutate in place at the next
        # epoch unload — a zero-copy np.asarray would silently corrupt the
        # payload the moment the run continues past the checkpoint
        leaves = [np.array(l)
                  for l in jax.tree_util.tree_leaves(self._cur["carry"])]
        if self.full:
            return leaves, {}
        host = {f"store/{k}": v.copy() for k, v in self.store.state.items()}
        host.update({f"totals/{k}": np.array(v)
                     for k, v in self._totals.items()})
        host.update({f"frozen/{k}": np.array(v)
                     for k, v in self._cur["frozen_np"].items()})
        return leaves, host

    def restore(self, t: int, carry, host_state: Optional[dict]):
        """Adopt a checkpoint taken at round ``t`` (``carry`` already
        validated/unflattened by the caller).  The resident epoch is
        ``(t−1) // rpc`` — the epoch of the last computed round; its cohort
        resamples deterministically and its data re-gathers from the store."""
        if self.full:
            batch = self.store.gather_batch(np.arange(self.n))
            self._cur = {"epoch": None, "idx": np.arange(self.n),
                         "batch": batch, "carry": tuple(carry),
                         "frozen_np": {}}
            return
        host_state = host_state or {}
        frozen_np = {}
        for key, val in host_state.items():
            if key.startswith("store/"):
                self.store.state[key[len("store/"):]] = np.array(val)
            elif key.startswith("totals/"):
                self._totals[key[len("totals/"):]] = np.array(val, np.float64)
            elif key.startswith("frozen/"):
                frozen_np[key[len("frozen/"):]] = np.array(val, np.float64)
        missing = ({f"frozen/{a}" for a in self._aggs}
                   - {k for k in host_state if k.startswith("frozen/")})
        if missing:
            raise ValueError(
                f"checkpoint host_state lacks {sorted(missing)} — not a "
                "cohort-streaming ckpt@2 checkpoint for this spec")
        e = (int(t) - 1) // self.rpc
        idx = self.cohort_indices(e)
        pidx, real = self._padded(idx)
        A, b = self.store.gather_data(pidx)
        batch = client_batch.ClientBatch(A=jnp.asarray(A), b=jnp.asarray(b),
                                         lam=self.store.lam)
        self._cur = {
            "epoch": e, "idx": idx,
            "cidx": jnp.asarray(pidx, jnp.int32),
            "real": jnp.asarray(real),
            "batch": batch, "carry": tuple(carry),
            "frozen": {k: jnp.asarray(v) for k, v in frozen_np.items()},
            "frozen_np": frozen_np,
        }
        self._prefetch_submit(e + 1)

    def close(self):
        if self._pool is not None:
            self._pool.shutdown(wait=False, cancel_futures=True)
            self._pool = None
