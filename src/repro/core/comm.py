"""Communication layer: wire formats, message counts, and the per-leg ledger.

The paper's headline axis is *communicated bits per node*, split across four
distinct legs (Table 1 / §2.3):

  * ``hess_up``    — compressed Hessian-coefficient uplink (the S_i stream);
  * ``grad_up``    — gradient-leg uplink (fresh g_i, Δl floats, ξ bits, β);
  * ``model_down`` — compressed model broadcast server → clients;
  * ``basis_ship`` — the one-time basis shipment (rd floats for the data
    basis, d² for an eigenbasis, zero for convention bases).

This module owns all of that accounting.  Compressors never compute bits:
they return *message counts* (`Counts` — how many floats / indices / packed
entries actually hit the wire) and declare a `WireFormat` describing how to
price one unit of each.  ``price(wire, counts)`` turns counts into bits, and
the `CommLedger` — a registered pytree threaded through the round engine's
scan carry — accumulates bits per leg.  The `History` contract's ``up_bits``
is the ledger's ``uplink`` total (hess + grad + basis), so the paper plots
are unchanged while every leg stays separately inspectable.

Composed compressors (Top-K ∘ dithering, Rank-R with compressed singular
vectors) have *structured* wire formats: a tuple of formats matching a tuple
of counts, priced leg-by-leg by recursion — pricing policy stays here even
for nested codecs.
"""
from __future__ import annotations

import dataclasses
import math
from typing import NamedTuple, Union

import jax
import jax.numpy as jnp

FLOAT_BITS = 64  # the paper's experiments (NumPy) use float64 coefficients
INDEX_BITS = 32


class Counts(NamedTuple):
    """What one compressed message physically carries, per client.

    Leaves are per-client ``(n,)`` float64 arrays (or scalars when the count
    is configuration-static and unused legs are 0).  `floats` are full-width
    values (thresholds, norms, singular values, dense payloads), `indices`
    are transmitted positions, `entries` are packed per-entry payloads whose
    width the `WireFormat` declares (dither sign+level, natural-compression
    sign+exponent).
    """

    floats: Union[jax.Array, float] = 0.0
    indices: Union[jax.Array, float] = 0.0
    entries: Union[jax.Array, float] = 0.0


@dataclasses.dataclass(frozen=True)
class WireFormat:
    """Declarative per-unit pricing of a message's counts."""

    float_bits: int = FLOAT_BITS
    index_bits: int = INDEX_BITS
    #: bits per packed entry (e.g. 1 sign + ⌈log₂(s+1)⌉ dither levels)
    entry_bits: float = 0.0


#: a wire format, or a tuple of wire trees for composed compressors
WireTree = Union[WireFormat, tuple]


def price(wire: WireTree, counts) -> jax.Array:
    """Bits on the wire for `counts` under `wire` — recursing through
    composed (tuple) formats so nested codecs price leg-by-leg.

    Args:
      wire: a `WireFormat`, or a tuple tree of them for composed codecs
        (must mirror the structure of `counts`).
      counts: a `Counts` (leaves: per-client (n,) arrays or scalars), or a
        matching tuple of them.

    Returns:
      Per-client transmitted bits, shape (n,) float64 (scalar counts
      broadcast).  Raises ValueError on wire/counts structure mismatch.
    """
    if isinstance(wire, tuple):
        if not isinstance(counts, tuple) or len(wire) != len(counts):
            raise ValueError(
                f"composed wire has {len(wire)} legs but counts is "
                f"{type(counts).__name__}"
                f"{' of ' + str(len(counts)) + ' legs' if isinstance(counts, tuple) else ''}"
                " — every wire leg must be priced")
        return sum(price(w, c) for w, c in zip(wire, counts))
    return (
        jnp.asarray(counts.floats, jnp.float64) * wire.float_bits
        + jnp.asarray(counts.indices, jnp.float64) * wire.index_bits
        + jnp.asarray(counts.entries, jnp.float64) * wire.entry_bits
    )


def with_float_bits(wire: WireTree, float_bits: int) -> WireTree:
    """`wire` with every leg's per-float width replaced by `float_bits`,
    recursing through composed (tuple) formats.

    The GLM stack prices floats at the paper's 64-bit convention; workloads
    whose tensors are genuinely narrower (the BL-DNN layer ships f32) remap
    a compressor's declared wire with this instead of re-implementing its
    count structure (index/entry widths are untouched)."""
    if isinstance(wire, tuple):
        return tuple(with_float_bits(w, float_bits) for w in wire)
    return dataclasses.replace(wire, float_bits=float_bits)


#: float widths a shipped basis may quantize to: f64/f32 casts, bf16
#: round-trip, or int8 with per-column f32 scales (see `BasisShipSpec`).
_SHIP_FLOAT_BITS = (8, 16, 32, 64)


@dataclasses.dataclass(frozen=True)
class BasisShipSpec:
    """How a shipped basis travels the wire (the ``basis_ship`` leg).

    The shipment leg goes through the SAME pricing machinery as every other
    leg: the spec derives a `WireFormat` (`.wire`) and the basis layer
    reports `Counts` of what its quantized factors actually carry
    (`repro.core.basis` — ``EigenBasis.shipped`` / ``PerLayerSVDBasis.
    shipped``), priced by `price`.  Quantization is REAL, not just billed:
    the rotation machinery afterwards uses the quantized factors, so the
    convergence impact of a narrow shipment is measurable
    (tests/test_basis_registry.py pins the bf16 envelope).

      * ``float_bits`` — per-value width: 64/32 are plain casts, 16 is a
        bfloat16 round-trip, 8 is symmetric int8 with one f32 scale per
        basis column (the scale floats are billed at 32 bits; the packed
        int8 values ride the wire's ``entry_bits``).
      * ``col_frac`` — top-|·| sparsification of each basis column: every
        column keeps its ``ceil(col_frac · rows)`` largest-magnitude
        entries (selection via the shared `compressors.topk_keep_mask`
        backend) and ships kept values + their row indices.

    The default (f32, dense) reproduces the legacy billing exactly:
    f32 factors pass through untouched and the priced bits equal
    ``ship_floats() × 32``."""

    float_bits: int = 32
    col_frac: float = 1.0

    def __post_init__(self):
        if self.float_bits not in _SHIP_FLOAT_BITS:
            raise ValueError(
                f"BasisShipSpec.float_bits must be one of {_SHIP_FLOAT_BITS}"
                f" (f64/f32 cast, bf16, int8+scales), got {self.float_bits}")
        if not 0.0 < self.col_frac <= 1.0:
            raise ValueError(
                f"BasisShipSpec.col_frac must be in (0, 1], got "
                f"{self.col_frac}")

    @property
    def dense(self) -> bool:
        return self.col_frac >= 1.0

    @property
    def wire(self) -> "WireFormat":
        """The shipment leg's wire.  int8 shipments price their packed
        values as 8-bit `Counts.entries` and their per-column scales as
        32-bit floats; every other width prices values as floats at
        ``float_bits``.  Sparsified columns ship kept-row indices at the
        standard index width."""
        if self.float_bits == 8:
            return WireFormat(float_bits=32, index_bits=INDEX_BITS,
                              entry_bits=8)
        return WireFormat(float_bits=self.float_bits, index_bits=INDEX_BITS)

    def factor_counts(self, rows: int, cols: int) -> "Counts":
        """Message `Counts` for shipping one (rows, cols) basis factor
        under this spec — static configuration counts (python floats), so
        shipment bits price at setup time, outside any scan."""
        kept_per_col = max(1, min(rows, int(math.ceil(self.col_frac * rows))))
        kept = float(kept_per_col * cols)
        idx = 0.0 if self.dense else kept
        if self.float_bits == 8:
            return Counts(floats=float(cols), indices=idx, entries=kept)
        return Counts(floats=kept, indices=idx)


def _f64(x):
    return jnp.asarray(x, jnp.float64)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class CommLedger:
    """Cumulative per-leg bit counters (per-node averages), a pytree so it
    rides the round engine's scan carry and comes back as one stream per
    leg.  All arithmetic is functional (`add` returns a new ledger)."""

    hess_up: jax.Array
    grad_up: jax.Array
    model_down: jax.Array
    basis_ship: jax.Array

    LEGS = ("hess_up", "grad_up", "model_down", "basis_ship")

    @classmethod
    def create(cls, hess_up=0.0, grad_up=0.0, model_down=0.0, basis_ship=0.0):
        """Fresh ledger with optional initial per-leg bits (e.g. the round-0
        exact-coefficient shipment on hess_up, the basis on basis_ship)."""
        return cls(_f64(hess_up), _f64(grad_up), _f64(model_down),
                   _f64(basis_ship))

    def add(self, hess_up=0.0, grad_up=0.0, model_down=0.0, basis_ship=0.0):
        """Functional per-leg accumulation: returns a NEW ledger with the
        given per-node bit amounts (scalars or traced values) added."""
        return CommLedger(
            hess_up=self.hess_up + hess_up,
            grad_up=self.grad_up + grad_up,
            model_down=self.model_down + model_down,
            basis_ship=self.basis_ship + basis_ship,
        )

    @property
    def uplink(self) -> jax.Array:
        """Total client→server bits (what the paper's x-axis plots)."""
        return self.hess_up + self.grad_up + self.basis_ship

    @property
    def downlink(self) -> jax.Array:
        return self.model_down

    def snapshot(self) -> dict:
        """Host-side numpy dict of the per-leg counters, keyed by leg name
        — the checkpointable form (`repro.exp.artifacts.save_checkpoint`
        serializes it alongside the rest of the scan carry)."""
        import numpy as np

        return {leg: np.asarray(getattr(self, leg)) for leg in self.LEGS}

    @classmethod
    def restore(cls, snap: dict) -> "CommLedger":
        """Rebuild a ledger from `snapshot()` output — the round-trip is
        bitwise (f64 counters pass through numpy untouched)."""
        missing = [leg for leg in cls.LEGS if leg not in snap]
        if missing:
            raise ValueError(f"ledger snapshot missing legs {missing}")
        return cls(*(jnp.asarray(snap[leg]) for leg in cls.LEGS))

    def tree_flatten(self):
        return (self.hess_up, self.grad_up, self.model_down,
                self.basis_ship), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)
