"""BL1 / BL2 / BL3 (Algorithms 1–3) — public API and backend dispatch.

Two backends implement the same algorithms with the same `History` contract:

  * ``repro.core.batched``      — the fast path: per-client state stacked on a
    leading axis, compressors vmapped, rounds run under `jax.lax.scan` inside
    one jitted XLA program.  Used whenever the configuration is homogeneous
    enough to stack (same client shapes, one basis kind, one compressor
    config per role).
  * ``repro.core.bl_reference`` — the original op-by-op Python loops, kept as
    the paper-faithful ground truth the fast path is pinned against.

`bl1/bl2/bl3` below take
``backend="auto"|"fast"|"fast+sharded"|"reference"``: "auto" (default) tries
the fast path and silently falls back, "fast" raises
`batched.FastPathUnavailable` instead of falling back, "fast+sharded" runs
the fast path with clients sharded over the mesh `data` axis (shard_map
aggregation backend — see `repro.core.rounds`), and "reference" forces the
loops.

Conventions
-----------
* Compression operates on *coefficient matrices* h^i(∇²f_i) in the client's
  basis.  With `DataOuterBasis` the Hessian's data part (which lives in the
  basis span) is encoded and the ridge λI is added analytically server-side,
  exactly as the paper's GLM experiments do; gradients likewise travel as r
  basis coefficients (§2.3, Table 1).
* `History` records per iteration: f(z)−f*, cumulative uplink bits/node and
  cumulative downlink bits/node (the paper plots uplink).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from . import glm
from .basis import DataOuterBasis, MatrixBasis, basis_transmission_bits
from .compressors import FLOAT_BITS, Compressor

_BACKENDS = ("auto", "fast", "fast+sharded", "reference")


def proj_mu(A: jax.Array, mu: float) -> jax.Array:
    """[A]_μ: projection onto {A = Aᵀ, A ⪰ μI} (used by BL1)."""
    S = (A + A.T) / 2.0
    w, V = jnp.linalg.eigh(S)
    return (V * jnp.maximum(w, mu)) @ V.T


def _sym(A):
    return (A + A.T) / 2.0


@dataclasses.dataclass
class History:
    gaps: List[float]
    up_bits: List[float]
    down_bits: List[float]
    #: optional per-leg cumulative bit streams keyed by `comm.CommLedger`
    #: leg name (hess_up / grad_up / model_down / basis_ship) — populated by
    #: the batched engine's ledger; the reference loops leave it None.
    legs: Optional[Dict[str, List[float]]] = None
    #: optional extra named evaluation streams beyond the gap (e.g. the
    #: BL-DNN spec's per-round training ``loss``) — whatever the method
    #: spec's ``eval_streams`` emitted besides ``"gap"``; None for GLM
    #: methods.
    metrics: Optional[Dict[str, List[float]]] = None
    #: optional per-round degradation-event bitmasks (`rounds.EVENT_*`,
    #: OR-combined ints) — populated by the service loop
    #: (`repro.launch.fed_serve`); the batch drivers leave it None.
    events: Optional[List[int]] = None

    def append(self, gap, up, down):
        self.gaps.append(float(max(gap, 0.0)))
        self.up_bits.append(float(up))
        self.down_bits.append(float(down))

    def as_arrays(self):
        return (np.asarray(self.gaps), np.asarray(self.up_bits), np.asarray(self.down_bits))


def _grad_uplink_bits(basis: MatrixBasis) -> float:
    return (basis.r if isinstance(basis, DataOuterBasis) else basis.d) * FLOAT_BITS


def _client_hcoef(basis: MatrixBasis, data: glm.ClientData, x: jax.Array) -> jax.Array:
    if isinstance(basis, DataOuterBasis):
        return basis.h(glm.hess_data_part(data, x))
    return basis.h(glm.hess(data, x))


def _server_reconstruct(basis: MatrixBasis, L: jax.Array, lam: float) -> jax.Array:
    H = basis.reconstruct(L)
    if isinstance(basis, DataOuterBasis):
        H = H + lam * jnp.eye(basis.d, dtype=H.dtype)
    return H


def _init_bits(basis: MatrixBasis, init_exact: bool) -> float:
    bits = basis_transmission_bits(basis)
    if init_exact:
        bits += basis.coeff_count() * FLOAT_BITS
    return bits


# --------------------------------------------------------------------------
# PSD-basis helpers shared by both BL3 backends (Example 5.1, §5)
# --------------------------------------------------------------------------
def _psd_sum_matrix(d: int, dtype) -> jax.Array:
    """Σ_{j,l} B^{jl} for the PSD basis (ordered pairs + diagonal)."""
    return 2.0 * jnp.ones((d, d), dtype) + (2.0 * d - 3.0) * jnp.eye(d, dtype=dtype)


def _psd_h_tilde(A: jax.Array) -> jax.Array:
    """h̃(A): symmetric coefficient matrix (halved off-diagonals) — §5."""
    off = (A - jnp.diag(jnp.diag(A))) / 2.0
    rowsum = jnp.sum(A, axis=1) - jnp.diag(A)
    return off + jnp.diag(jnp.diag(A) - rowsum)


def _psd_reconstruct_full(M: jax.Array) -> jax.Array:
    """Σ_{j,l} M_{jl} B^{jl} over all ordered pairs, for symmetric M."""
    off = M - jnp.diag(jnp.diag(M))
    diag = jnp.diag(M) + 2.0 * jnp.sum(off, axis=1)
    return 2.0 * off + jnp.diag(diag)


# --------------------------------------------------------------------------
# dispatchers
# --------------------------------------------------------------------------
def _dispatch(backend: str, fast_fn, ref_fn):
    """fast_fn takes sharded: bool (the aggregation backend of rounds.py)."""
    from .batched import FastPathUnavailable

    if backend not in _BACKENDS:
        raise ValueError(f"backend must be one of {_BACKENDS}, got {backend!r}")
    if backend == "reference":
        return ref_fn()
    try:
        return fast_fn(sharded=(backend == "fast+sharded"))
    except FastPathUnavailable:
        if backend == "auto":
            return ref_fn()
        raise


def bl1(
    clients: Sequence[glm.ClientData],
    bases: Sequence[MatrixBasis],
    hess_comp: Sequence[Compressor],
    model_comp: Compressor,
    x0: jax.Array,
    x_star: jax.Array,
    steps: int,
    alpha: float = 1.0,
    eta: float = 1.0,
    p: float = 1.0,
    mu: Optional[float] = None,
    seed: int = 0,
    init_exact_hessian: bool = True,
    backend: str = "auto",
    exact: bool = True,
    stream=None,
) -> History:
    """Basis Learn with Bidirectional Compression (Algorithm 1).

    StandardBasis + Rank-R + identity model compressor ≡ FedNL (option 1);
    Top-K model compressor ≡ FedNL-BC.

    Args:
      clients: n per-client GLM datasets (`glm.ClientData`).
      bases: one `MatrixBasis` per client (compression acts on the h^i(·)
        coefficient matrices in this basis — §2.3 / Eq. 10).
      hess_comp: one Hessian-coefficient compressor per client (contractive
        Eq. 6 with α=1, or unbiased Eq. 7 with α=1/(ω+1)).
      model_comp: single server→client model-stream compressor (Identity ⇒
        exact broadcast; Top-K ⇒ the bidirectional "BC" variants).
      x0: initial iterate, shape (d,).
      x_star: reference optimum (gap is f(z_t) − f(x_star)).
      steps: number of communication rounds.
      alpha: Hessian-learning step size of the shift recursion
        L ← L + αC(h(∇²f_i) − L).
      eta: model-stream step size z ← z + ηC(x − z).
      p: gradient-refresh probability (ξ ~ Bernoulli(p); p=1 ⇒ fresh
        gradients every round).
      mu: PSD-projection floor [·]_μ (defaults to the ridge λ).
      seed: PRNG seed for stochastic compressors / ξ draws.
      init_exact_hessian: ship exact initial coefficients (billed on the
        hess_up leg) instead of starting the learner at zero.
      backend: "auto" | "fast" | "fast+sharded" | "reference".
      exact: aggregation parity of the sharded backend (see
        `rounds.ShardMapReducer`): True (default) reduces via a fixed-order
        gather — bitwise identical to the single-device fast path; False
        uses ring collectives per the spec's `ReducePlan` — faster on real
        interconnects, reductions associate in ring order (≈ulp drift).
        Ignored off the "fast+sharded" backend.
      stream: optional `rounds.StreamHook` for mid-sweep progress emission
        (fast backends only; the reference loops ignore it).

    Returns:
      `History` — per-round gaps plus cumulative per-node uplink/downlink
      bits; `History.legs` carries the per-leg `CommLedger` streams on the
      fast backends.
    """
    from . import batched, bl_reference

    args = (clients, bases, hess_comp, model_comp, x0, x_star, steps)
    kw = dict(alpha=alpha, eta=eta, p=p, mu=mu, seed=seed,
              init_exact_hessian=init_exact_hessian)
    return _dispatch(
        backend,
        lambda sharded: batched.bl1_fast(*args, sharded=sharded, exact=exact,
                                         stream=stream, **kw),
        lambda: bl_reference.bl1_reference(*args, **kw),
    )


def bl2(
    clients: Sequence[glm.ClientData],
    bases: Sequence[MatrixBasis],
    hess_comp: Sequence[Compressor],
    model_comp: Sequence[Compressor],
    x0: jax.Array,
    x_star: jax.Array,
    steps: int,
    alpha: float = 1.0,
    eta: float = 1.0,
    p: float = 1.0,
    tau: Optional[int] = None,
    seed: int = 0,
    init_exact_hessian: bool = True,
    backend: str = "auto",
    exact: bool = True,
    stream=None,
) -> History:
    """Basis Learn with Bidirectional Compression and Partial Participation
    (Algorithm 2).  StandardBasis ≡ FedNL-PP (Rank-R, identity model comp).

    Args are as `bl1` except: `model_comp` is per-client (one compressor
    each, the downlink is client-individual z_i streams), `tau` is the
    expected participants per round (Bernoulli(τ/n) with a force-one-client
    fallback; defaults to full participation), and `p` is the per-client
    gradient-refresh probability (ξ_i masks, not the fleet-wide scalar).

    Returns a `History` (see `bl1`).
    """
    from . import batched, bl_reference

    args = (clients, bases, hess_comp, model_comp, x0, x_star, steps)
    kw = dict(alpha=alpha, eta=eta, p=p, tau=tau, seed=seed,
              init_exact_hessian=init_exact_hessian)
    return _dispatch(
        backend,
        lambda sharded: batched.bl2_fast(*args, sharded=sharded, exact=exact,
                                         stream=stream, **kw),
        lambda: bl_reference.bl2_reference(*args, **kw),
    )


def bl3(
    clients: Sequence[glm.ClientData],
    hess_comp: Sequence[Compressor],
    model_comp: Sequence[Compressor],
    x0: jax.Array,
    x_star: jax.Array,
    steps: int,
    alpha: float = 1.0,
    eta: float = 1.0,
    p: float = 1.0,
    tau: Optional[int] = None,
    c: float = 1e-8,
    option: int = 2,
    seed: int = 0,
    backend: str = "auto",
    exact: bool = True,
    stream=None,
) -> History:
    """BL3 with the PSD basis of Example 5.1 (both β options, Algorithm 3).

    Args are as `bl2` (no `bases` — the PSD basis is built in; no
    `init_exact_hessian` — BL3 always initializes at the exact h̃) plus:
    `c` is the γ_i floor (γ_i = max(c, max|L_i|)) and `option` selects the
    β_i candidate (1: previous-iterate numerator; 2: current target).

    Returns a `History` (see `bl1`).
    """
    from . import batched, bl_reference

    args = (clients, hess_comp, model_comp, x0, x_star, steps)
    kw = dict(alpha=alpha, eta=eta, p=p, tau=tau, c=c, option=option, seed=seed)
    return _dispatch(
        backend,
        lambda sharded: batched.bl3_fast(*args, sharded=sharded, exact=exact,
                                         stream=stream, **kw),
        lambda: bl_reference.bl3_reference(*args, **kw),
    )
