"""Op-by-op reference backend for BL1 / BL2 / BL3 (Algorithms 1–3).

These are the original, paper-faithful Python-loop implementations: one
`for i in range(n)` over clients per round, history kept on the host.  They
are kept as the ground truth the jitted fast path (`repro.core.batched`) is
pinned against in `tests/test_batched_parity.py` — do not optimize them.

Use them via the public dispatchers `repro.core.bl.bl1/bl2/bl3` with
``backend="reference"``.
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from . import glm
from .basis import MatrixBasis
from .bl import (
    History,
    _client_hcoef,
    _grad_uplink_bits,
    _init_bits,
    _psd_h_tilde,
    _psd_reconstruct_full,
    _psd_sum_matrix,
    _server_reconstruct,
    _sym,
    proj_mu,
)
from .compressors import FLOAT_BITS, Compressor


# --------------------------------------------------------------------------
# BL1 — Algorithm 1
# --------------------------------------------------------------------------
def bl1_reference(
    clients: Sequence[glm.ClientData],
    bases: Sequence[MatrixBasis],
    hess_comp: Sequence[Compressor],
    model_comp: Compressor,
    x0: jax.Array,
    x_star: jax.Array,
    steps: int,
    alpha: float = 1.0,
    eta: float = 1.0,
    p: float = 1.0,
    mu: Optional[float] = None,
    seed: int = 0,
    init_exact_hessian: bool = True,
) -> History:
    """Basis Learn with Bidirectional Compression.

    StandardBasis + Rank-R + identity model compressor ≡ FedNL (option 1);
    Top-K model compressor ≡ FedNL-BC.
    """
    clients = list(clients)
    n = len(clients)
    d = x0.shape[0]
    lam = clients[0].lam
    mu = lam if mu is None else mu
    key = jax.random.PRNGKey(seed)
    f_star = float(glm.global_loss(clients, x_star))

    z = x0
    w = x0
    if init_exact_hessian:
        L = [_client_hcoef(bases[i], clients[i], x0) for i in range(n)]
    else:
        L = [jnp.zeros((d, d), x0.dtype) for _ in range(n)]
    H = sum(_server_reconstruct(bases[i], L[i], lam) for i in range(n)) / n
    grad_w = glm.global_grad(clients, w)
    xi = 1

    # per-client ranks may differ (heterogeneous DataOuterBasis) — average
    up = sum(_init_bits(b, init_exact_hessian) for b in bases) / n
    grad_bits = sum(_grad_uplink_bits(b) for b in bases) / n
    down = 0.0
    hist = History([], [], [])

    for _ in range(steps):
        hist.append(float(glm.global_loss(clients, z)) - f_star, up, down)

        Hmu = proj_mu(H, mu)
        # gradient leg
        if xi == 1:
            w = z
            grad_w = glm.global_grad(clients, w)
            g = grad_w
            up += grad_bits
        else:
            g = Hmu @ (z - w) + grad_w

        # Hessian-coefficient learning (clients → server)
        H_delta = jnp.zeros((d, d), x0.dtype)
        step_bits = 0.0
        for i in range(n):
            key, sk = jax.random.split(key)
            target = _client_hcoef(bases[i], clients[i], z)
            S, bits = hess_comp[i](sk, target - L[i])
            L[i] = L[i] + alpha * S
            H_delta = H_delta + bases[i].reconstruct(alpha * S)
            step_bits += float(bits)
        up += step_bits / n

        # server model step + broadcast
        x_next = z - jnp.linalg.solve(Hmu, g)
        H = H + H_delta / n
        key, sk = jax.random.split(key)
        v, vbits = model_comp(sk, x_next - z)
        down += float(vbits)
        z = z + eta * v
        key, sk = jax.random.split(key)
        xi = 1 if p >= 1.0 else int(jax.random.bernoulli(sk, p))

    return hist


# --------------------------------------------------------------------------
# BL2 — Algorithm 2
# --------------------------------------------------------------------------
def bl2_reference(
    clients: Sequence[glm.ClientData],
    bases: Sequence[MatrixBasis],
    hess_comp: Sequence[Compressor],
    model_comp: Sequence[Compressor],
    x0: jax.Array,
    x_star: jax.Array,
    steps: int,
    alpha: float = 1.0,
    eta: float = 1.0,
    p: float = 1.0,
    tau: Optional[int] = None,
    seed: int = 0,
    init_exact_hessian: bool = True,
) -> History:
    """Basis Learn with Bidirectional Compression and Partial Participation.

    StandardBasis ≡ FedNL-PP (with Rank-R compressor, identity model comp).
    """
    clients = list(clients)
    n = len(clients)
    d = x0.shape[0]
    lam = clients[0].lam
    tau = n if tau is None else tau
    key = jax.random.PRNGKey(seed)
    f_star = float(glm.global_loss(clients, x_star))

    def full_hess(i, x):
        return glm.hess(clients[i], x)

    z = [x0 for _ in range(n)]
    w = [x0 for _ in range(n)]
    if init_exact_hessian:
        L = [_client_hcoef(bases[i], clients[i], x0) for i in range(n)]
    else:
        L = [jnp.zeros((d, d), x0.dtype) for _ in range(n)]
    Hi = [_server_reconstruct(bases[i], L[i], lam) for i in range(n)]
    li = [float(jnp.linalg.norm(_sym(Hi[i]) - full_hess(i, w[i]), "fro")) for i in range(n)]
    gi = [(_sym(Hi[i]) + li[i] * jnp.eye(d, dtype=x0.dtype)) @ w[i] - glm.grad(clients[i], w[i]) for i in range(n)]
    H = sum(Hi) / n
    l_avg = sum(li) / n
    g = sum(gi) / n

    up = sum(_init_bits(b, init_exact_hessian) for b in bases) / n
    down = 0.0
    hist = History([], [], [])

    for _ in range(steps):
        x_cur = jnp.linalg.solve(_sym(H) + l_avg * jnp.eye(d, dtype=x0.dtype), g)
        hist.append(float(glm.global_loss(clients, x_cur)) - f_star, up, down)

        key, sk = jax.random.split(key)
        # mirror rounds.participation: mask and fallback index from SPLIT
        # keys (one key for both correlates the forced client with the mask)
        sk_mask, sk_idx = jax.random.split(sk)
        part = np.array(jax.random.bernoulli(sk_mask, tau / n, (n,)))
        if not part.any():
            idx = int(jax.random.randint(sk_idx, (), 0, n))
            part[idx] = True

        step_up = 0.0
        step_down = 0.0
        for i in range(n):
            if not part[i]:
                continue
            key, sk = jax.random.split(key)
            v_i, vbits = model_comp[i](sk, x_cur - z[i])
            step_down += float(vbits)
            z[i] = z[i] + eta * v_i

            key, sk = jax.random.split(key)
            target = _client_hcoef(bases[i], clients[i], z[i])
            S, bits = hess_comp[i](sk, target - L[i])
            step_up += float(bits)
            L_new = L[i] + alpha * S
            Hi_new = Hi[i] + bases[i].reconstruct(alpha * S)
            li_new = float(jnp.linalg.norm(_sym(Hi_new) - full_hess(i, z[i]), "fro"))
            key, sk = jax.random.split(key)
            xi = 1 if p >= 1.0 else int(jax.random.bernoulli(sk, p))
            if xi == 1:
                w[i] = z[i]
                gi_new = (_sym(Hi_new) + li_new * jnp.eye(d, dtype=x0.dtype)) @ w[i] - glm.grad(clients[i], w[i])
                step_up += d * FLOAT_BITS  # g_i^{k+1} − g_i^k
            else:
                # server reconstructs the g-difference from S_i and Δl
                gi_new = gi[i] + (_sym(Hi_new) - _sym(Hi[i]) + (li_new - li[i]) * jnp.eye(d, dtype=x0.dtype)) @ w[i]
                step_up += FLOAT_BITS + 1  # Δl float + ξ bit
            # server-side aggregate updates
            g = g + (gi_new - gi[i]) / n
            H = H + (Hi_new - Hi[i]) / n
            l_avg = l_avg + (li_new - li[i]) / n
            L[i], Hi[i], li[i], gi[i] = L_new, Hi_new, li_new, gi_new

        up += step_up / n
        down += step_down / n

    return hist


# --------------------------------------------------------------------------
# BL3 — Algorithm 3
# --------------------------------------------------------------------------
def bl3_reference(
    clients: Sequence[glm.ClientData],
    hess_comp: Sequence[Compressor],
    model_comp: Sequence[Compressor],
    x0: jax.Array,
    x_star: jax.Array,
    steps: int,
    alpha: float = 1.0,
    eta: float = 1.0,
    p: float = 1.0,
    tau: Optional[int] = None,
    c: float = 1e-8,
    option: int = 2,
    seed: int = 0,
) -> History:
    """BL3 with the PSD basis of Example 5.1 (both β options)."""
    clients = list(clients)
    n = len(clients)
    d = x0.shape[0]
    tau = n if tau is None else tau
    key = jax.random.PRNGKey(seed)
    f_star = float(glm.global_loss(clients, x_star))
    Ssum = _psd_sum_matrix(d, x0.dtype)

    def h_full(i, x):
        return glm.hess(clients[i], x)

    z = [x0 for _ in range(n)]
    w = [x0 for _ in range(n)]
    zprev = [x0 for _ in range(n)]  # z_i^{k-1} for Option 1
    L = [_psd_h_tilde(h_full(i, x0)) for i in range(n)]
    gam = [max(c, float(jnp.max(jnp.abs(L[i])))) for i in range(n)]
    A_i = [_psd_reconstruct_full(L[i]) + 2.0 * gam[i] * Ssum for i in range(n)]
    C_i = [2.0 * gam[i] * Ssum for i in range(n)]
    beta_i = [float(jnp.max((_psd_h_tilde(h_full(i, w[i])) + 2 * gam[i]) / (L[i] + 2 * gam[i]))) for i in range(n)]
    beta = max(beta_i)
    g1 = [A_i[i] @ w[i] for i in range(n)]
    g2 = [C_i[i] @ w[i] + glm.grad(clients[i], w[i]) for i in range(n)]
    A_avg = sum(A_i) / n
    C_avg = sum(C_i) / n
    g1_avg = sum(g1) / n
    g2_avg = sum(g2) / n

    up = (d * (d + 1) // 2) * FLOAT_BITS  # ship L_i^0 coefficients
    down = 0.0
    hist = History([], [], [])

    for _ in range(steps):
        Hk = beta * A_avg - C_avg
        gk = beta * g1_avg - g2_avg
        x_cur = jnp.linalg.solve(Hk, gk)
        hist.append(float(glm.global_loss(clients, x_cur)) - f_star, up, down)

        key, sk = jax.random.split(key)
        # mirror rounds.participation's split-key draw (see bl2 above)
        sk_mask, sk_idx = jax.random.split(sk)
        part = np.array(jax.random.bernoulli(sk_mask, tau / n, (n,)))
        if not part.any():
            idx = int(jax.random.randint(sk_idx, (), 0, n))
            part[idx] = True

        step_up = 0.0
        step_down = 0.0
        for i in range(n):
            if not part[i]:
                continue
            key, sk = jax.random.split(key)
            v_i, vbits = model_comp[i](sk, x_cur - z[i])
            step_down += float(vbits)
            zprev[i] = z[i]
            z[i] = z[i] + eta * v_i

            key, sk = jax.random.split(key)
            target = _psd_h_tilde(h_full(i, z[i]))
            S, bits = hess_comp[i](sk, target - L[i])
            step_up += float(bits)
            L_new = L[i] + alpha * S
            gam_new = max(c, float(jnp.max(jnp.abs(L_new))))
            if option == 1:
                num = _psd_h_tilde(h_full(i, zprev[i]))
            else:
                num = target
            beta_new = float(jnp.max((num + 2 * gam_new) / (L_new + 2 * gam_new)))
            A_new = A_i[i] + _psd_reconstruct_full(L_new - L[i]) + 2.0 * (gam_new - gam[i]) * Ssum
            C_new = C_i[i] + 2.0 * (gam_new - gam[i]) * Ssum
            key, sk = jax.random.split(key)
            xi = 1 if p >= 1.0 else int(jax.random.bernoulli(sk, p))
            if xi == 1:
                w[i] = z[i]
                g1_new = A_new @ w[i]
                g2_new = C_new @ w[i] + glm.grad(clients[i], w[i])
                step_up += 2 * d * FLOAT_BITS  # the two g-differences
            else:
                g1_new = g1[i] + (A_new - A_i[i]) @ w[i]
                g2_new = g2[i] + (C_new - C_i[i]) @ w[i]
                step_up += 2 * FLOAT_BITS + 1  # β, Δγ floats + ξ bit
            step_up += FLOAT_BITS  # β_i^{k+1} always reaches the server
            A_avg = A_avg + (A_new - A_i[i]) / n
            C_avg = C_avg + (C_new - C_i[i]) / n
            g1_avg = g1_avg + (g1_new - g1[i]) / n
            g2_avg = g2_avg + (g2_new - g2[i]) / n
            L[i], gam[i], A_i[i], C_i[i], g1[i], g2[i] = L_new, gam_new, A_new, C_new, g1_new, g2_new
            beta_i[i] = beta_new

        beta = max(beta_i)
        up += step_up / n
        down += step_down / n

    return hist
