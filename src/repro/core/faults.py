"""Deterministic fault injection for the federated service loop.

The batch engine models client unreliability with one Bernoulli draw per
round (`rounds.participation`).  A *service* loop has to survive richer
failure modes — clients that drop and rejoin on schedules, stragglers that
miss their round deadline, and the server process itself dying — and it has
to survive them **reproducibly**: the whole point of the chunked driver's
bit-exact-resume contract (`rounds.run_chunk`) is that a crashed-and-resumed
run replays the identical trajectory, which it can only do if the fault
stream replays too.

So every draw here is a *pure function of (fault seed, absolute round)*:
`np.random.default_rng([seed, t, salt])` seeds a fresh generator per round,
there is no generator state to checkpoint, and the availability schedule for
rounds [t0, t0+K) is the same whether it is queried in one chunk or ten.
The layer composes three mechanisms into one per-round availability mask
(`FaultPlan.round_avail`), which reaches method specs as `RoundCtx.avail`:

  * **i.i.d. dropout** — each client independently unreachable with
    probability `dropout_p` each round (the service-loop generalization of
    the participation draw: availability ∧ participation).
  * **Outage windows** — deterministic down/rejoin schedules
    (`Outage(client, start, stop)`): client is down for rounds
    start ≤ t < stop and rejoins afterwards.
  * **Stragglers** — per-round response-time draws against a round
    deadline with retry/backoff (`StragglerModel`): a client misses the
    round only if it times out on *every* attempt, so the surviving set is
    monotone in the retry budget.

The server-side failure mode is `CrashInjector`: a SIGKILL of the serving
process itself at a configured round boundary, *before* the covering
checkpoint is written — the harness for the kill-9-and-resume acceptance
test (`repro.launch.fed_serve --crash-after-round`).
"""
from __future__ import annotations

import dataclasses
import os
import signal
from typing import Optional, Tuple

import numpy as np

#: rng salts so the dropout and straggler streams never collide
_SALT_DROPOUT = 1
_SALT_SLOW = 2
_SALT_DELAY = 3


def _round_rng(seed: int, t: int, salt: int) -> np.random.Generator:
    """Fresh generator for one (seed, round, stream) triple — stateless
    across rounds, so fault draws are invariant to chunk boundaries."""
    return np.random.default_rng([int(seed), int(t), int(salt)])


@dataclasses.dataclass(frozen=True)
class Outage:
    """Client ``client`` is down for rounds ``start <= t < stop`` and
    rejoins at ``stop`` (a deterministic dropout/rejoin schedule)."""

    client: int
    start: int
    stop: int

    def __post_init__(self):
        if self.stop <= self.start:
            raise ValueError(f"empty outage window [{self.start}, {self.stop})")
        if self.client < 0:
            raise ValueError(f"negative client index {self.client}")

    def down(self, t: int) -> bool:
        return self.start <= t < self.stop

    @classmethod
    def parse(cls, spec: str) -> "Outage":
        """Parse the CLI form ``client:start:stop``."""
        try:
            c, a, b = (int(p) for p in spec.split(":"))
        except ValueError:
            raise ValueError(
                f"outage spec {spec!r} is not client:start:stop") from None
        return cls(client=c, start=a, stop=b)


@dataclasses.dataclass(frozen=True)
class StragglerModel:
    """Per-round client response delays against a deadline with retries.

    Each attempt ``a`` (0-based, up to ``retries`` extra tries) redraws every
    client's response time from Exponential(``mean_s``) — scaled by
    ``slow_factor`` for the deterministic ``slow_frac`` fraction of
    persistently slow clients — and accepts clients whose draw beats the
    backed-off deadline ``timeout_s * backoff**a``.  A client misses the
    round only when every attempt times out, so the surviving cohort can
    only grow with the retry budget (pinned by tests/test_faults.py)."""

    mean_s: float = 0.05
    slow_frac: float = 0.0
    slow_factor: float = 10.0
    timeout_s: float = 0.25
    retries: int = 1
    backoff: float = 2.0

    def __post_init__(self):
        if self.timeout_s <= 0 or self.mean_s <= 0:
            raise ValueError("straggler timeout_s and mean_s must be > 0")
        if self.retries < 0:
            raise ValueError(f"negative retry budget {self.retries}")
        if self.backoff < 1.0:
            raise ValueError(
                f"backoff {self.backoff} < 1 shrinks the retry deadline")
        if not 0.0 <= self.slow_frac <= 1.0:
            raise ValueError(f"slow_frac {self.slow_frac} outside [0, 1]")

    def slow_mask(self, seed: int, n: int) -> np.ndarray:
        """The persistently slow clients — one draw per *run*, not per
        round (salted on the fault seed only, t pinned to 0)."""
        return _round_rng(seed, 0, _SALT_SLOW).random(n) < self.slow_frac

    def round_outcome(self, seed: int, t: int, n: int
                      ) -> Tuple[np.ndarray, float]:
        """(responded mask (n,), simulated seconds the server waited)."""
        slow = self.slow_mask(seed, n)
        scale = np.where(slow, self.mean_s * self.slow_factor, self.mean_s)
        ok = np.zeros(n, bool)
        waited = 0.0
        for a in range(self.retries + 1):
            deadline = self.timeout_s * self.backoff ** a
            delays = _round_rng(seed, t, _SALT_DELAY + a).exponential(scale)
            ok = ok | (delays <= deadline)
            # the server waits out the full deadline unless everyone is in
            waited += float(delays.max()) if ok.all() else deadline
            if ok.all():
                break
        return ok, waited


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """The composed per-round fleet availability schedule.

    ``round_avail(t)`` ANDs the three mechanisms into one (n,) bool mask —
    a pure function of ``(seed, t)``, so schedules are chunk-invariant and
    nothing here needs checkpointing.  ``trivial`` plans (no mechanism
    configured) stand for a fully reliable fleet; `repro.launch.fed_serve`
    passes ``avail=None`` to the engine in that case, which is
    bitwise-identical to an all-ones schedule (pinned by tests)."""

    n: int
    dropout_p: float = 0.0
    outages: Tuple[Outage, ...] = ()
    straggler: Optional[StragglerModel] = None
    seed: int = 0

    def __post_init__(self):
        if not 0.0 <= self.dropout_p < 1.0:
            raise ValueError(f"dropout_p {self.dropout_p} outside [0, 1)")
        for o in self.outages:
            if o.client >= self.n:
                raise ValueError(
                    f"outage client {o.client} out of range for n={self.n}")

    @property
    def trivial(self) -> bool:
        return (self.dropout_p == 0.0 and not self.outages
                and self.straggler is None)

    def round_avail(self, t: int) -> Tuple[np.ndarray, float]:
        """(availability mask (n,) bool, simulated straggler wait seconds)
        for absolute round ``t``."""
        up = np.ones(self.n, bool)
        if self.dropout_p > 0.0:
            up &= (_round_rng(self.seed, t, _SALT_DROPOUT).random(self.n)
                   >= self.dropout_p)
        for o in self.outages:
            if o.down(t):
                up[o.client] = False
        waited = 0.0
        if self.straggler is not None:
            ok, waited = self.straggler.round_outcome(self.seed, t, self.n)
            up &= ok
        return up, waited

    def schedule(self, t0: int, steps: int) -> Tuple[np.ndarray, float]:
        """Availability schedule for rounds [t0, t0+steps) — the (steps, n)
        bool array `rounds.run_chunk` consumes — plus the chunk's total
        simulated straggler wait."""
        rows, waited = [], 0.0
        for t in range(t0, t0 + steps):
            up, w = self.round_avail(t)
            rows.append(up)
            waited += w
        return np.stack(rows), waited

    def describe(self) -> dict:
        """Plain-JSON form for the serve config digest (fault plans are
        part of the run identity: changing one invalidates checkpoints)."""
        return {
            "n": self.n,
            "dropout_p": self.dropout_p,
            "outages": [dataclasses.asdict(o) for o in self.outages],
            "straggler": (None if self.straggler is None
                          else dataclasses.asdict(self.straggler)),
            "seed": self.seed,
        }


@dataclasses.dataclass(frozen=True)
class CrashInjector:
    """SIGKILL the serving process once round ``after_round`` has been
    *computed* but before its covering checkpoint is written — exactly the
    mid-chunk hard-crash the resume contract must survive.  The restarted
    process must NOT re-arm the injector (the CLI flag is simply omitted on
    restart), or it will crash at the same boundary forever."""

    after_round: int

    def maybe_crash(self, t_done: int) -> None:
        if t_done > self.after_round:
            # flush stdio so the pre-crash log survives the SIGKILL
            import sys

            sys.stdout.flush()
            sys.stderr.flush()
            os.kill(os.getpid(), signal.SIGKILL)
