"""Basis Learn: changes of basis in R^{d×d} and S^d (paper §2.3, §4, §5).

A `MatrixBasis` provides the coefficient transform h(A) (forward) and the
reconstruction A = Σ_{jl} h_{jl} B^{jl} (backward).  All transforms are exact
(lossless); lossy compression is applied to the *coefficient matrix* by the
algorithms.

The module doubles as a **basis registry** — "which basis" is the system's
primary configuration axis (the paper's thesis is that the basis, not the
compressor, is the big lever on communication), so bases are registered
under string names and built per-fleet with `make_bases(name, clients,
...)`:

  * ``standard``    — Example 4.1 (h(A) = A); N_B orthogonal.
  * ``symmetric``   — Example 4.2 (triangular coefficients for S^d).
  * ``psd``         — Example 5.1 (B^{jl} ⪰ 0, for BL3).
  * ``data_outer``  — §2.3: client data spans G_i = span{v_1..v_r}; the
                      coefficient matrix of any A = Σ γ_tl v_t v_l^T is the
                      r×r matrix Γ.  h(A) is computed in the r-dim
                      coordinate space (Γ = pinv-projection), NEVER via the
                      d²×d² inverse.
  * ``eigen``       — eigenbasis of the initial averaged Hessian ∇²f(x⁰):
                      B^{jl} = q_j q_lᵀ for Q the orthonormal eigenvectors.
                      Concentrates coefficient energy on the leading
                      curvature directions; shipped once (d² floats, billed
                      on the ledger's basis leg).
  * ``dct``         — fixed orthogonal DCT-II basis: same rotation machinery
                      as ``eigen`` but *conventional* — both sides generate
                      it, zero shipment cost.
  * ``per_layer_svd`` — the *pytree* basis (BL-DNN): per-2-D-weight complete
                      SVD rotations of a parameter tree's initialization,
                      shipped once like ``eigen``.  Registered with
                      ``pytree=True`` — it transforms parameter pytrees,
                      not d×d matrices (see `PerLayerSVDBasis`).
  * ``dct_tree``, ``hadamard_tree`` — free *structured* pytree bases
                      (`StructuredTreeBasis`): per-leaf DCT-II /
                      Walsh–Hadamard rotations generated from leaf shapes
                      by both sides — the same rotation machinery as
                      ``per_layer_svd`` at zero shipment cost.

Shipped bases (``eigen``'s Q, ``per_layer_svd``'s leaf factors) can travel
COMPRESSED: `quantize_ship_factor` applies a `comm.BasisShipSpec` (bf16 /
int8 quantization, top-|·| column sparsification) to the factors the
receiver actually rotates with, and prices the shipment through the same
`comm.price` algebra as every other leg.

For DataOuterBasis, coefficient matrices are r×r embedded in the top-left of
a d×d array padded with exact zeros, so the same compressor machinery
applies and the bit accountant only ever "sees" r² potentially-nonzero
coefficients.

New bases register with `@register_basis("name")` and are automatically
picked up by the benchmark grid (`benchmarks/run.py::basis_matrix`) and the
round-trip contract tests (tests/test_basis_registry.py).
"""
from __future__ import annotations

import dataclasses
import os
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import comm
from .comm import FLOAT_BITS


class MatrixBasis:
    d: int
    #: number of (potentially) nonzero coefficients for a symmetric input
    n_coeff: int
    #: orthogonal basis (N_B = 1 in Eq. 10) ?
    orthogonal: bool = False
    #: max_jl ||B^jl||_F  (R in Assumption 4.7)
    R: float = 1.0
    #: all basis matrices PSD (required by BL3)?
    psd: bool = False

    def h(self, A: jax.Array) -> jax.Array:
        """Coefficient matrix of A (Eq. 10 forward transform).

        Args:  A — (d, d) matrix (symmetric for the S^d bases).
        Returns: (d, d) coefficient array; exact zeros where the basis
        stores nothing (e.g. outside the top-left r×r block for
        `DataOuterBasis`), so the bit accountant only "sees" the
        potentially-nonzero coefficients.
        """
        raise NotImplementedError

    def reconstruct(self, H: jax.Array) -> jax.Array:
        """Backward transform Σ_{jl} H_{jl} B^{jl}: (d, d) coefficients →
        (d, d) matrix.  Exact inverse of `h` on the basis span."""
        raise NotImplementedError

    def coeff_count(self) -> int:
        """Number of potentially-nonzero coefficients for a symmetric
        input (what a dense uplink of h(A) would transmit)."""
        return self.n_coeff


@dataclasses.dataclass
class StandardBasis(MatrixBasis):
    """Example 4.1: B^{jl} = e_j e_l^T.  h(A) = A.  BL1 ≡ FedNL here."""
    d: int

    def __post_init__(self):
        self.n_coeff = self.d * self.d
        self.orthogonal = True
        self.R = 1.0

    def h(self, A):
        return A

    def reconstruct(self, H):
        return H


@dataclasses.dataclass
class SymmetricBasis(MatrixBasis):
    """Example 4.2 specialized to symmetric A: h(A) = lower-triangular part.

    B^{jl} (j>l) has 1 at (j,l) and (l,j); B^{jj} has 1 at (j,j).
    Reconstruction of a lower-triangular coefficient matrix gives back A.
    """
    d: int

    def __post_init__(self):
        self.n_coeff = self.d * (self.d + 1) // 2
        self.orthogonal = True  # the B^{jl} are mutually orthogonal in <.,.>_F
        self.R = float(np.sqrt(2.0))

    def h(self, A):
        return jnp.tril(A)

    def reconstruct(self, H):
        return jnp.tril(H) + jnp.tril(H, -1).T


@dataclasses.dataclass
class PSDBasis(MatrixBasis):
    """Example 5.1: for j≠l, B^{jl} has ones at (j,l),(l,j),(j,j),(l,l) — PSD.

    For a symmetric A with coefficients c_{jl} (j≥l):
        A_{jl} = c_{jl}                (j≠l)
        A_{jj} = c_{jj} + Σ_{l≠j} c_{max(j,l),min(j,l)}
    so  h: c_{jl} = A_{jl} (j>l),  c_{jj} = A_{jj} − Σ_{l≠j} A_{jl}.
    Not orthogonal (N_B = d² in Eq. 10).  R = 2 (‖B^{jl}‖_F = 2 for j≠l).
    """
    d: int

    def __post_init__(self):
        self.n_coeff = self.d * (self.d + 1) // 2
        self.orthogonal = False
        self.R = 2.0
        self.psd = True

    def h(self, A):
        off = jnp.tril(A, -1)
        rowsum = jnp.sum(A, axis=1) - jnp.diag(A)  # Σ_{l≠j} A_{jl}
        diag = jnp.diag(A) - rowsum
        return off + jnp.diag(diag)

    def reconstruct(self, H):
        # H lower-triangular coefficient matrix
        off = jnp.tril(H, -1)
        sym_off = off + off.T
        contrib = jnp.sum(sym_off, axis=1)         # Σ_{l≠j} c_.. landing on (j,j)
        diag = jnp.diag(H) + contrib
        return sym_off + jnp.diag(diag)


@dataclasses.dataclass
class DataOuterBasis(MatrixBasis):
    """§2.3 data-induced basis: {v_t v_l^T}_{t,l∈[r]} completed arbitrarily.

    V ∈ R^{d×r} has orthonormal columns spanning the client's data subspace
    (scipy.linalg.orth analogue, computed with jnp SVD).  For any A in the span
    (all GLM Hessians minus the λI ridge term are),  Γ = Vᵀ A V  and
    A = V Γ Vᵀ exactly.  Coefficients live in the top-left r×r block.

    The ridge term λI is handled *analytically* by the algorithms (the server
    knows λ), exactly as the paper's experiments do — only the data part of the
    Hessian is ever communicated.
    """
    V: jax.Array  # (d, r), orthonormal columns

    def __post_init__(self):
        self.d = int(self.V.shape[0])
        self.r = int(self.V.shape[1])
        self.n_coeff = self.r * self.r
        self.orthogonal = True  # orthonormal v ⇒ <v_t v_l^T, v_p v_q^T>_F = δ
        self.R = 1.0

    def h(self, A):
        gamma = self.V.T @ A @ self.V
        out = jnp.zeros((self.d, self.d), A.dtype)
        return out.at[: self.r, : self.r].set(gamma)

    def reconstruct(self, H):
        gamma = H[: self.r, : self.r]
        return self.V @ gamma @ self.V.T


@dataclasses.dataclass
class RotationBasis(MatrixBasis):
    """B^{jl} = q_j q_lᵀ for one orthogonal Q ∈ R^{d×d}: a complete
    orthonormal basis of R^{d×d}, so h(A) = QᵀAQ and A = Q h Qᵀ exactly for
    EVERY matrix (no data-span assumption, no analytic ridge)."""
    Q: jax.Array  # (d, d), orthogonal

    def __post_init__(self):
        self.d = int(self.Q.shape[0])
        self.n_coeff = self.d * self.d
        self.orthogonal = True
        self.R = 1.0

    def h(self, A):
        return self.Q.T @ A @ self.Q

    def reconstruct(self, H):
        return self.Q @ H @ self.Q.T


@dataclasses.dataclass
class EigenBasis(RotationBasis):
    """Eigenbasis of the initial averaged Hessian ∇²f(x⁰) (the "basis
    matters" demonstration basis): curvature concentrates coefficient energy
    in the leading eigendirections, so Top-K in this basis keeps more signal
    per bit than the standard basis.  Q is NOT a convention — it depends on
    the fleet's data — so it ships once (d² floats, `basis_transmission_bits`)
    and the comm ledger bills it on the ``basis_ship`` leg."""

    def shipped(self, ship: comm.BasisShipSpec
                ) -> Tuple["EigenBasis", float]:
        """The basis as it arrives after a compressed shipment: Q quantized
        per `ship`, plus the exact bits that shipment cost (priced through
        `comm.price` on the shipment wire).  The receiver rotates with the
        QUANTIZED Q — a narrow wire trades reconstruction fidelity for
        bits, and both sides of that trade are observable (the bf16
        envelope is pinned in tests/test_basis_registry.py)."""
        Q, bits = quantize_ship_factor(self.Q, ship)
        return EigenBasis(Q=Q), bits


class DCTBasis(RotationBasis):
    """Fixed orthonormal DCT-II rotation: the same machinery as `EigenBasis`
    but data-independent — server and clients both generate it, so shipment
    is free.  A useful control in the basis×compressor grid: it shows how
    much of the eigenbasis win is *data adaptivity* vs mere decorrelation."""

    def __init__(self, d: int):
        j = np.arange(d)[:, None]      # frequency index
        t = np.arange(d)[None, :]      # position index
        C = np.sqrt(2.0 / d) * np.cos(np.pi * (t + 0.5) * j / d)
        C[0] *= np.sqrt(0.5)           # orthonormalize the DC row
        super().__init__(Q=jnp.asarray(C.T))  # columns = DCT basis vectors


# --------------------------------------------------------------------------
# compressed basis shipment: quantize the factors that actually travel
# --------------------------------------------------------------------------
def quantize_ship_factor(M: jax.Array, ship: comm.BasisShipSpec
                         ) -> Tuple[jax.Array, float]:
    """One shipped (rows, cols) basis factor after the wire: quantized
    values and the exact bits they cost.

    The quantization is what the receiver actually rotates with — not just
    an accounting fiction:

      * ``col_frac < 1`` zeroes everything but each column's top
        ``⌈col_frac·rows⌉`` magnitudes (selection via the shared
        `compressors.topk_keep_mask` backend, so REPRO_BL_PALLAS=1 swaps
        the search kernel without changing the kept set);
      * ``float_bits = 16`` is a bfloat16 round-trip; ``8`` is symmetric
        per-column int8 (scale = max|col|/127, one f32 scale per column);
        ``32``/``64`` are plain casts (identity for factors already that
        wide).

    Bits are priced by `comm.price` on `ship.wire` with
    `ship.factor_counts` — the same Counts→bits algebra every other leg
    uses.  Returns the factor in its original dtype (every quantized value
    is exactly representable there) and the bits as a python float, so
    shipment billing stays configuration-static."""
    M = jnp.asarray(M)
    if M.ndim != 2:
        raise ValueError(f"shipped basis factors are 2-D, got {M.shape}")
    rows, cols = int(M.shape[0]), int(M.shape[1])
    W = M if ship.float_bits == 64 else M.astype(jnp.float32)
    if not ship.dense:
        from . import compressors  # local import: compressors imports comm

        k = max(1, min(rows, int(np.ceil(ship.col_frac * rows))))
        keep = compressors.topk_keep_mask(W.T, k).T
        W = jnp.where(keep, W, jnp.zeros_like(W))
    if ship.float_bits == 16:
        W = W.astype(jnp.bfloat16).astype(jnp.float32)
    elif ship.float_bits == 8:
        scale = jnp.max(jnp.abs(W), axis=0, keepdims=True) / 127.0
        scale = jnp.where(scale > 0.0, scale, 1.0)
        W = jnp.clip(jnp.round(W / scale), -127.0, 127.0) * scale
    counts = ship.factor_counts(rows, cols)
    bits = float(comm.price(ship.wire, counts))
    return W.astype(M.dtype), bits


def _two_sided(A: jax.Array, g: jax.Array, B: jax.Array) -> jax.Array:
    """One rotated leaf: ``A @ g @ B`` (left-associated, matching python
    ``@``).  Client-stacked f32 leaves route through the fused Pallas
    transform kernel under ``REPRO_BL_PALLAS=1`` — bitwise the XLA batched
    matmul in interpret mode (kernels/basis_transform.py), so the flag
    never perturbs trajectories."""
    if (g.ndim == 3 and g.dtype == jnp.float32
            and A.dtype == jnp.float32 and B.dtype == jnp.float32
            and os.environ.get("REPRO_BL_PALLAS", "0") == "1"):
        from repro.kernels import ops

        return ops.basis_transform(A, g, B)
    return A @ g @ B


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class PerLayerSVDBasis:
    """Pytree basis for DNN parameter trees (the BL-DNN layer, §2.3 carried
    beyond the paper): every 2-D weight leaf gets a COMPLETE orthogonal
    basis (U_ℓ, V_ℓ) from the SVD of its initialization — the weight matrix
    plays the data-matrix role — and its gradient is communicated as the
    rotated coefficients U_ℓᵀ g V_ℓ.  Non-matrix leaves (biases, norms)
    pass through unrotated.

    Unlike the d×d `MatrixBasis` classes this operates on whole parameter
    *pytrees*: `rotate`/`unrotate` are leaf-aligned maps, and leaves may
    carry a leading client axis (the round engine's (n, ...) stacks) — the
    rotations broadcast over it.  The basis is fleet-global (every client
    derives it from the shared initialization), so the engine replicates it
    across the client mesh instead of sharding it (`MethodSpec.
    basis_replicated`).

    Completeness matters: `full_matrices=True` in the construction — a
    truncated V would silently project out every gradient component outside
    the weight's row space.
    """

    #: per-leaf entries ordered like ``jax.tree.leaves(params)``:
    #: ``(U, V)`` for rotated 2-D leaves, ``None`` for pass-through leaves.
    UV: tuple

    def tree_flatten(self):
        return (self.UV,), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(UV=children[0])

    def _map(self, fn, tree):
        leaves, treedef = jax.tree_util.tree_flatten(tree)
        if len(leaves) != len(self.UV):
            raise ValueError(
                f"tree has {len(leaves)} leaves but basis covers "
                f"{len(self.UV)} — built from a different parameter tree?")
        return treedef.unflatten(
            [leaf if uv is None else fn(uv[0], uv[1], leaf)
             for uv, leaf in zip(self.UV, leaves)])

    def rotate(self, tree):
        """Leaf-wise forward transform U_ℓᵀ g V_ℓ (complete basis ⇒ the
        coefficient tensor keeps the leaf's own shape).  Leaves may carry
        leading batch/client axes — matrix products broadcast over them."""
        return self._map(
            lambda U, V, g: _two_sided(jnp.swapaxes(U, -1, -2),
                                       g.astype(U.dtype), V),
            tree)

    def unrotate(self, tree):
        """Exact inverse of `rotate`: U_ℓ c V_ℓᵀ per rotated leaf."""
        return self._map(
            lambda U, V, c: _two_sided(U, c, jnp.swapaxes(V, -1, -2)), tree)

    def ship_floats(self) -> float:
        """One-time basis shipment size in floats (Σ_ℓ |U_ℓ| + |V_ℓ| — the
        Table-1 analogue; bill it on the ledger's ``basis_ship`` leg at the
        shipping wire's float width)."""
        return float(sum(uv[0].size + uv[1].size
                         for uv in self.UV if uv is not None))

    def shipped(self, ship: comm.BasisShipSpec
                ) -> Tuple["PerLayerSVDBasis", float]:
        """The basis as it arrives after a compressed shipment: every
        rotated leaf's (U_ℓ, V_ℓ) quantized per `ship`
        (`quantize_ship_factor`) and the summed exact bits of the shipment.
        The default spec (f32, dense) is the identity on these f32 factors
        and prices exactly ``ship_floats() × 32`` — legacy billing."""
        new_uv, bits = [], 0.0
        for uv in self.UV:
            if uv is None:
                new_uv.append(None)
                continue
            U, bu = quantize_ship_factor(uv[0], ship)
            V, bv = quantize_ship_factor(uv[1], ship)
            new_uv.append((U, V))
            bits += bu + bv
        return type(self)(UV=tuple(new_uv)), bits


def per_layer_svd_basis(params, use_basis: bool = True,
                        min_dim: int = 2) -> PerLayerSVDBasis:
    """Build the `PerLayerSVDBasis` of a parameter pytree's initialization.

    Every 2-D leaf with both dims ≥ `min_dim` gets (U, V) from its full
    SVD; everything else passes through.  ``use_basis=False`` returns the
    identity basis (no rotations, zero shipment) — the no-basis control in
    the basis-vs-compressor experiments.
    """
    out = []
    for p in jax.tree_util.tree_leaves(params):
        if use_basis and p.ndim == 2 and min(p.shape) >= min_dim:
            u, _, vt = jnp.linalg.svd(p.astype(jnp.float32),
                                      full_matrices=True)
            out.append((u, vt.T))
        else:
            out.append(None)
    return PerLayerSVDBasis(UV=tuple(out))


@jax.tree_util.register_pytree_node_class
class StructuredTreeBasis(PerLayerSVDBasis):
    """Pytree basis whose per-leaf rotations are CONVENTIONS (DCT-II or
    Walsh–Hadamard), generalizing the d×d `DCTBasis` to parameter trees:
    the same `PerLayerSVDBasis` rotation machinery (and the same Pallas
    transform kernel under ``REPRO_BL_PALLAS=1``), but both sides generate
    the factors from the leaf shapes alone — nothing data-dependent ever
    travels, so ``ship_floats() == 0`` and `shipped` is the identity at
    zero bits.  The decorrelation-vs-adaptivity control of the BL-DNN
    grid: how much of the per-layer-SVD win survives when the basis is
    free?"""

    def ship_floats(self) -> float:
        return 0.0

    def shipped(self, ship: comm.BasisShipSpec
                ) -> Tuple["StructuredTreeBasis", float]:
        """Conventions don't travel: the factors are never on the wire, so
        quantizing them would model a cost (and a fidelity loss) that
        doesn't exist.  Identity, zero bits."""
        return self, 0.0


def _dct_matrix(d: int) -> jax.Array:
    """Orthonormal DCT-II factor (columns = basis vectors), f32 — the same
    construction as `DCTBasis` at any dimension."""
    j = np.arange(d)[:, None]
    t = np.arange(d)[None, :]
    C = np.sqrt(2.0 / d) * np.cos(np.pi * (t + 0.5) * j / d)
    C[0] *= np.sqrt(0.5)
    return jnp.asarray(C.T, jnp.float32)


def _hadamard_matrix(d: int) -> jax.Array:
    """Normalized Walsh–Hadamard factor H_d/√d for power-of-two d; identity
    otherwise (Sylvester's construction only exists at powers of two — a
    non-pow2 leaf axis simply passes through unrotated on that side)."""
    if d & (d - 1):
        return jnp.eye(d, dtype=jnp.float32)
    H = np.array([[1.0]])
    while H.shape[0] < d:
        H = np.block([[H, H], [H, -H]])
    return jnp.asarray(H / np.sqrt(d), jnp.float32)


def structured_tree_basis(params, kind: str = "dct",
                          min_dim: int = 2) -> StructuredTreeBasis:
    """Build the free structured basis of a parameter pytree: every 2-D
    leaf with both dims ≥ `min_dim` gets fixed orthogonal (U, V) factors
    from its SHAPE alone (``kind`` ∈ {"dct", "hadamard"}); other leaves
    pass through.  Zero shipment by construction."""
    factories = {"dct": _dct_matrix, "hadamard": _hadamard_matrix}
    if kind not in factories:
        raise KeyError(f"unknown structured-basis kind {kind!r}; "
                       f"one of {sorted(factories)}")
    make = factories[kind]
    out = []
    for p in jax.tree_util.tree_leaves(params):
        if p.ndim == 2 and min(p.shape) >= min_dim:
            out.append((make(int(p.shape[0])), make(int(p.shape[1]))))
        else:
            out.append(None)
    return StructuredTreeBasis(UV=tuple(out))


def orth_basis_from_data(A_data: jax.Array, rcond: float = 1e-10) -> DataOuterBasis:
    """Orthonormal basis of the row space of the client's data matrix (m, d).

    Mirrors the paper's use of scipy.linalg.orth on the feature matrix (§6.1).
    """
    # SVD of (m, d): row space spanned by right singular vectors
    _, s, vt = jnp.linalg.svd(A_data, full_matrices=False)
    tol = s.max() * max(A_data.shape) * rcond
    r = int(jnp.sum(s > tol))
    r = max(r, 1)
    V = vt[:r].T  # (d, r)
    return DataOuterBasis(V=V)


def eigen_basis_from_clients(clients, x0: Optional[jax.Array] = None) -> List[EigenBasis]:
    """One shared `EigenBasis` per client: eigenvectors of the fleet's
    averaged initial Hessian ∇²f(x⁰) (x⁰ = 0 by default, as the experiments
    initialize).  Returns the SAME basis object for every client — the
    batched engine exploits that (one (d, d) Q, not n copies)."""
    from . import glm  # local import: glm is a sibling leaf module

    clients = list(clients)
    d = int(clients[0].A.shape[1])
    if x0 is None:
        x0 = jnp.zeros(d, clients[0].A.dtype)
    H0 = glm.global_hess(clients, x0)
    _, Q = jnp.linalg.eigh((H0 + H0.T) / 2.0)
    basis = EigenBasis(Q=Q)
    return [basis for _ in clients]


def basis_transmission_bits(basis: MatrixBasis, float_bits: int = FLOAT_BITS) -> float:
    """One-time cost of shipping the basis to the server (Table 1: rd floats
    for the data basis, d² for an eigenbasis).

    Standard/symmetric/PSD/DCT bases are conventions — zero marginal cost.
    """
    if isinstance(basis, DataOuterBasis):
        return float(basis.d * basis.r * float_bits)
    if isinstance(basis, EigenBasis):
        return float(basis.d * basis.d * float_bits)
    return 0.0


# --------------------------------------------------------------------------
# registry: "which basis" as a first-class configuration axis
# --------------------------------------------------------------------------
BasisFactory = Callable[..., List[MatrixBasis]]
BASIS_REGISTRY: Dict[str, BasisFactory] = {}
#: names whose basis operates on parameter *pytrees* (e.g. ``per_layer_svd``)
#: rather than d×d matrices — they take the parameter tree where matrix
#: bases take the client fleet, and the d×d contract tests / benchmark
#: grids skip them (see `is_pytree_basis`).
PYTREE_BASES: set = set()


def register_basis(name: str, *, pytree: bool = False):
    """Register a fleet-level basis factory ``factory(clients, x0=None,
    **kw) -> List[MatrixBasis]`` under `name`.

    ``pytree=True`` marks a pytree-basis factory ``factory(params, x0=None,
    **kw)`` (first argument is a parameter pytree, not a client list)."""
    def deco(factory: BasisFactory) -> BasisFactory:
        BASIS_REGISTRY[name] = factory
        if pytree:
            PYTREE_BASES.add(name)
        return factory
    return deco


def available_bases() -> List[str]:
    return sorted(BASIS_REGISTRY)


def is_pytree_basis(name: str) -> bool:
    """True for registered bases that transform parameter pytrees (DNN
    workloads) instead of d×d coefficient matrices."""
    return name in PYTREE_BASES


def make_bases(name: str, clients: Sequence, x0: Optional[jax.Array] = None,
               **kw) -> List[MatrixBasis]:
    """Build the per-client basis list for a registered basis name.

    Args:
      name: registry key (see `available_bases()`).
      clients: the client fleet (`glm.ClientData` sequence) — data-adaptive
        bases derive their parameters from it.  For pytree bases
        (`is_pytree_basis`) this is the parameter pytree instead (the
        shared initialization every client derives the basis from).
      x0: initial iterate for bases anchored there (`eigen`); ignored by
        data-independent bases.
      **kw: factory-specific options (e.g. ``rcond`` for `data_outer`).

    Returns:
      One `MatrixBasis` per client (shared-object for global bases —
      the batched engine exploits the identity).  Pytree-basis factories
      return the fleet-global basis object itself (e.g.
      `PerLayerSVDBasis`), not a per-client list.
    """
    if name not in BASIS_REGISTRY:
        raise KeyError(
            f"unknown basis {name!r}; registered: {available_bases()}")
    if name in PYTREE_BASES:
        return BASIS_REGISTRY[name](clients, x0=x0, **kw)
    return BASIS_REGISTRY[name](list(clients), x0=x0, **kw)


def _fleet_d(clients) -> int:
    return int(clients[0].A.shape[1])


@register_basis("standard")
def _standard_bases(clients, x0=None):
    d = _fleet_d(clients)
    return [StandardBasis(d) for _ in clients]


@register_basis("symmetric")
def _symmetric_bases(clients, x0=None):
    d = _fleet_d(clients)
    return [SymmetricBasis(d) for _ in clients]


@register_basis("psd")
def _psd_bases(clients, x0=None):
    d = _fleet_d(clients)
    return [PSDBasis(d) for _ in clients]


@register_basis("data_outer")
def _data_outer_bases(clients, x0=None, rcond: float = 1e-10):
    return [orth_basis_from_data(c.A, rcond=rcond) for c in clients]


@register_basis("eigen")
def _eigen_bases(clients, x0=None):
    return eigen_basis_from_clients(clients, x0=x0)


@register_basis("dct")
def _dct_bases(clients, x0=None):
    basis = DCTBasis(_fleet_d(clients))
    return [basis for _ in clients]


@register_basis("per_layer_svd", pytree=True)
def _per_layer_svd_bases(params, x0=None, use_basis: bool = True):
    """Pytree basis of a DNN parameter tree (the BL-DNN workload): one
    complete per-layer SVD rotation per 2-D weight, shared by the whole
    fleet.  Shipment (Σ_ℓ |U_ℓ|+|V_ℓ| floats) bills on ``basis_ship``."""
    return per_layer_svd_basis(params, use_basis=use_basis)


@register_basis("dct_tree", pytree=True)
def _dct_tree_bases(params, x0=None, min_dim: int = 2):
    """Free structured pytree basis: per-leaf DCT-II rotations generated
    from leaf shapes by both sides — zero ``basis_ship`` bits."""
    return structured_tree_basis(params, kind="dct", min_dim=min_dim)


@register_basis("hadamard_tree", pytree=True)
def _hadamard_tree_bases(params, x0=None, min_dim: int = 2):
    """Free structured pytree basis: per-leaf normalized Walsh–Hadamard
    rotations (power-of-two axes; identity otherwise) — zero
    ``basis_ship`` bits."""
    return structured_tree_basis(params, kind="hadamard", min_dim=min_dim)
