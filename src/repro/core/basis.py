"""Basis Learn: changes of basis in R^{d×d} and S^d (paper §2.3, §4, §5).

A `MatrixBasis` provides the coefficient transform h(A) (forward) and the
reconstruction A = Σ_{jl} h_{jl} B^{jl} (backward).  All transforms are exact
(lossless); lossy compression is applied to the *coefficient matrix* by the
algorithms.

Implemented bases:

  * StandardBasis       — Example 4.1 (h(A) = A); N_B orthogonal.
  * SymmetricBasis      — Example 4.2 (triangular coefficients for S^d).
  * PSDBasis            — Example 5.1 (B^{jl} ⪰ 0, for BL3).
  * DataOuterBasis      — §2.3: client data spans G_i = span{v_1..v_r}; the
                          coefficient matrix of any A = Σ γ_tl v_t v_l^T is the
                          r×r matrix Γ.  h(A) is computed in the r-dim
                          coordinate space (Γ = pinv-projection), NEVER via the
                          d²×d² inverse — same math as Eq. 9 restricted to the
                          r²-dim subspace actually used.

For DataOuterBasis, coefficient matrices are r×r embedded in the top-left of a
d×d array padded with exact zeros, so the same compressor machinery applies and
the bit accountant only ever "sees" r² potentially-nonzero coefficients.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


class MatrixBasis:
    d: int
    #: number of (potentially) nonzero coefficients for a symmetric input
    n_coeff: int
    #: orthogonal basis (N_B = 1 in Eq. 10) ?
    orthogonal: bool = False
    #: max_jl ||B^jl||_F  (R in Assumption 4.7)
    R: float = 1.0
    #: all basis matrices PSD (required by BL3)?
    psd: bool = False

    def h(self, A: jax.Array) -> jax.Array:
        """Coefficient matrix of A (same d×d shape; zeros where unused)."""
        raise NotImplementedError

    def reconstruct(self, H: jax.Array) -> jax.Array:
        """Σ_{jl} H_{jl} B^{jl}."""
        raise NotImplementedError

    def coeff_count(self) -> int:
        return self.n_coeff


@dataclasses.dataclass
class StandardBasis(MatrixBasis):
    """Example 4.1: B^{jl} = e_j e_l^T.  h(A) = A.  BL1 ≡ FedNL here."""
    d: int

    def __post_init__(self):
        self.n_coeff = self.d * self.d
        self.orthogonal = True
        self.R = 1.0

    def h(self, A):
        return A

    def reconstruct(self, H):
        return H


@dataclasses.dataclass
class SymmetricBasis(MatrixBasis):
    """Example 4.2 specialized to symmetric A: h(A) = lower-triangular part.

    B^{jl} (j>l) has 1 at (j,l) and (l,j); B^{jj} has 1 at (j,j).
    Reconstruction of a lower-triangular coefficient matrix gives back A.
    """
    d: int

    def __post_init__(self):
        self.n_coeff = self.d * (self.d + 1) // 2
        self.orthogonal = True  # the B^{jl} are mutually orthogonal in <.,.>_F
        self.R = float(np.sqrt(2.0))

    def h(self, A):
        return jnp.tril(A)

    def reconstruct(self, H):
        return jnp.tril(H) + jnp.tril(H, -1).T


@dataclasses.dataclass
class PSDBasis(MatrixBasis):
    """Example 5.1: for j≠l, B^{jl} has ones at (j,l),(l,j),(j,j),(l,l) — PSD.

    For a symmetric A with coefficients c_{jl} (j≥l):
        A_{jl} = c_{jl}                (j≠l)
        A_{jj} = c_{jj} + Σ_{l≠j} c_{max(j,l),min(j,l)}
    so  h: c_{jl} = A_{jl} (j>l),  c_{jj} = A_{jj} − Σ_{l≠j} A_{jl}.
    Not orthogonal (N_B = d² in Eq. 10).  R = 2 (‖B^{jl}‖_F = 2 for j≠l).
    """
    d: int

    def __post_init__(self):
        self.n_coeff = self.d * (self.d + 1) // 2
        self.orthogonal = False
        self.R = 2.0
        self.psd = True

    def h(self, A):
        off = jnp.tril(A, -1)
        rowsum = jnp.sum(A, axis=1) - jnp.diag(A)  # Σ_{l≠j} A_{jl}
        diag = jnp.diag(A) - rowsum
        return off + jnp.diag(diag)

    def reconstruct(self, H):
        # H lower-triangular coefficient matrix
        off = jnp.tril(H, -1)
        sym_off = off + off.T
        contrib = jnp.sum(sym_off, axis=1)         # Σ_{l≠j} c_.. landing on (j,j)
        diag = jnp.diag(H) + contrib
        return sym_off + jnp.diag(diag)


@dataclasses.dataclass
class DataOuterBasis(MatrixBasis):
    """§2.3 data-induced basis: {v_t v_l^T}_{t,l∈[r]} completed arbitrarily.

    V ∈ R^{d×r} has orthonormal columns spanning the client's data subspace
    (scipy.linalg.orth analogue, computed with jnp SVD).  For any A in the span
    (all GLM Hessians minus the λI ridge term are),  Γ = Vᵀ A V  and
    A = V Γ Vᵀ exactly.  Coefficients live in the top-left r×r block.

    The ridge term λI is handled *analytically* by the algorithms (the server
    knows λ), exactly as the paper's experiments do — only the data part of the
    Hessian is ever communicated.
    """
    V: jax.Array  # (d, r), orthonormal columns

    def __post_init__(self):
        self.d = int(self.V.shape[0])
        self.r = int(self.V.shape[1])
        self.n_coeff = self.r * self.r
        self.orthogonal = True  # orthonormal v ⇒ <v_t v_l^T, v_p v_q^T>_F = δ
        self.R = 1.0

    def h(self, A):
        gamma = self.V.T @ A @ self.V
        out = jnp.zeros((self.d, self.d), A.dtype)
        return out.at[: self.r, : self.r].set(gamma)

    def reconstruct(self, H):
        gamma = H[: self.r, : self.r]
        return self.V @ gamma @ self.V.T


def orth_basis_from_data(A_data: jax.Array, rcond: float = 1e-10) -> DataOuterBasis:
    """Orthonormal basis of the row space of the client's data matrix (m, d).

    Mirrors the paper's use of scipy.linalg.orth on the feature matrix (§6.1).
    """
    # SVD of (m, d): row space spanned by right singular vectors
    _, s, vt = jnp.linalg.svd(A_data, full_matrices=False)
    tol = s.max() * max(A_data.shape) * rcond
    r = int(jnp.sum(s > tol))
    r = max(r, 1)
    V = vt[:r].T  # (d, r)
    return DataOuterBasis(V=V)


def basis_transmission_bits(basis: MatrixBasis, float_bits: int = 64) -> float:
    """One-time cost of shipping the basis to the server (Table 1: rd floats).

    Standard/symmetric/PSD bases are conventions — zero marginal cost.
    """
    if isinstance(basis, DataOuterBasis):
        return float(basis.d * basis.r * float_bits)
    return 0.0
