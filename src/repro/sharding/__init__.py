from .rules import Rules, make_rules, param_specs, batch_specs  # noqa: F401
