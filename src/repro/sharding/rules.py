"""Logical-axis sharding rules: one place that decides how every parameter,
activation and cache tensor maps onto the (pod, data, model) mesh.

Scheme (baseline, see README.md §EXPERIMENTS for hillclimbed variants):

* batch            → (pod, data)      (data parallelism)
* attention heads, FFN hidden, MoE experts, vocab → model  (tensor/expert par.)
* parameters       → FSDP over data on the d_model-ish dimension, TP over model
* KV caches        → batch over data when it divides; the *sequence* dimension
  shards over model (flash-decode style seq-parallel attention) because most
  assigned configs have n_kv_heads < 16; for global_batch == 1 (long_500k) the
  sequence additionally shards over data.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


# Mesh axis that client-stacked federated state shards over (the round
# engine in repro.core.rounds and the BL-DNN layer in repro.fed.bldnn both
# map their leading n_clients axis onto it).
CLIENT_AXIS = "data"


def mesh_fingerprint(mesh: "Mesh") -> str:
    """Identity-free description of a mesh for program-cache keys
    (`repro.core.progcache`): axis names/sizes plus the device platform and
    kind.  Two processes building the same-shape mesh over the same device
    model produce the same string; device ordinals and hostnames are
    deliberately excluded (an executable compiled for device 0..7 loads
    fine on any same-kind 8-device world)."""
    axes = ",".join(f"{a}={mesh.shape[a]}" for a in mesh.axis_names)
    dev = mesh.devices.ravel()[0]
    return f"mesh({axes}|{dev.platform}:{dev.device_kind})"


def client_chunk_specs(carry_specs, basis_replicated: bool = False):
    """shard_map specs for the unified chunked round driver's body
    (`repro.core.rounds._chunk_body` — the one scan program behind both
    `run_rounds` and `run_chunk`).

    Positional layout is (batch, basisb, x0, carry, ts, keys, avail) →
    (carry, (eval_x, ledger, events)).  The client-stacked pytrees
    (`ClientBatch`, `BatchedBasis`, `TreeBatch`) shard their leading
    client axis over CLIENT_AXIS; the scan carry crosses the shard_map
    boundary: ``carry_specs`` is the per-leaf spec pytree derived from
    `rounds.carry_client_flags` (client-stacked leaves shard over
    CLIENT_AXIS, server state is replicated).  Per-round keys and the
    fault-availability schedule ``avail`` (fleet-wide (steps, n)) are
    replicated, exactly like the participation draws; the history streams
    come back replicated (the P()s in the output tuple are pytree prefixes
    covering every ledger leg).

    ``basis_replicated=True`` replicates the basis argument instead of
    sharding it — pytree bases (`PerLayerSVDBasis`) are fleet-global with
    no client axis to shard (specs opt in via
    `MethodSpec.basis_replicated`)."""
    sharded = P(CLIENT_AXIS)
    in_specs = (sharded, P() if basis_replicated else sharded, P(),
                carry_specs, P(), P(), P())
    return in_specs, (carry_specs, (P(), P(), P()))


def cohort_chunk_specs(carry_specs, basis_replicated: bool = False):
    """shard_map specs for the cohort-streaming chunk body
    (`repro.core.rounds._cohort_chunk_body`).

    Positional layout is (batch, basisb, x0, carry, ts, keys, cidx, creal,
    frozen) → (carry, (eval_x, ledger, events)).  The COHORT axis takes the
    client axis's place across the shard_map boundary: the gathered cohort
    batch, the cohort-capacity carry's client-stacked leaves, and the
    per-slot global-index/padding-mask vectors all shard over CLIENT_AXIS,
    while the frozen fleet aggregates are replicated server state (every
    shard needs them to finish a fleet mean/max)."""
    sharded = P(CLIENT_AXIS)
    in_specs = (sharded, P() if basis_replicated else sharded, P(),
                carry_specs, P(), P(), sharded, sharded, P())
    return in_specs, (carry_specs, (P(), P(), P()))


@dataclasses.dataclass
class Rules:
    mesh: Mesh
    amap: Dict[str, Any]  # logical axis → mesh axis (or tuple / None)

    def spec(self, axes) -> P:
        return P(*[self.amap.get(a) if a is not None else None for a in axes])

    def sharding(self, axes) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec(axes))

    def constrain(self, x, axes):
        assert x.ndim == len(axes), (x.shape, axes)
        return jax.lax.with_sharding_constraint(x, self.sharding(axes))


def make_rules(
    mesh: Mesh,
    *,
    batch_size: Optional[int] = None,
    fsdp: bool = True,
    seq_parallel: bool = False,
) -> Rules:
    """Build rules for a mesh with axes ('data','model') or ('pod','data','model').

    batch_size (global) decides whether batch can shard over the data axes.
    """
    names = mesh.axis_names
    multi_pod = "pod" in names
    data_axes: Tuple[str, ...] = ("pod", "data") if multi_pod else ("data",)
    data_size = int(np.prod([mesh.shape[a] for a in data_axes]))
    batch_axes = data_axes
    kv_seq = None
    if batch_size is not None and batch_size < data_size:
        if batch_size == 1:
            batch_axes = None
            kv_seq = data_axes  # sequence takes over the idle data axes
        else:
            # shard over as many trailing data axes as divide the batch
            batch_axes = tuple(a for a in data_axes if batch_size % mesh.shape[a] == 0)[:1] or None
    amap = {
        "batch": batch_axes,
        "heads": "model",
        "kv_heads": None,       # most configs have kv < 16; see kv_seq instead
        "ffn": "model",
        "experts": "model",
        "vocab": "model",
        "kv_seq": kv_seq,       # extra data-axis seq sharding (long_500k)
        "fsdp": ("data" if fsdp else None),
        "model": "model",
        # sequence-parallel residual stream (archs whose head count doesn't
        # divide the model axis — gemma3/llama4/whisper; §Perf)
        "act_seq": ("model" if seq_parallel else None),
    }
    return Rules(mesh=mesh, amap=amap)


def wants_seq_parallel(cfg, mesh: Mesh) -> bool:
    m = mesh.shape["model"]
    specs = cfg.layer_specs()
    pure_attn = all(s.mixer == "attn" for s in specs)
    return pure_attn and cfg.n_heads % m != 0


# --------------------------------------------------------------------------
# Parameter / cache / optimizer specs by tree path
# --------------------------------------------------------------------------
def _param_spec_for(path: str, ndim: int, rules: Rules, cfg) -> P:
    f = rules.amap["fsdp"]
    m = "model"
    msize = rules.mesh.shape["model"]

    def fits(dim):  # only shard dims divisible by the mesh axis
        return dim % msize == 0

    # embed/unembed: vocab-only sharding.  2D (fsdp × vocab) sharding makes
    # the fused-CE backward contraction ambiguous and XLA all-gathers the
    # full (B,S,V) cotangent (13 GB for mamba2 train_4k) — measured in the
    # dry-run; vocab-only keeps dh as a cheap all-reduce partial.
    if path.endswith("unembed"):
        return P(None, m if fits(cfg.padded_vocab) else None)
    if path.endswith("embed") and ndim == 2:
        return P(m if fits(cfg.padded_vocab) else None, None)
    if path.endswith("enc_pos"):
        return P(None, None)
    # stacked layer params: leading axis = n_groups (or n_enc_layers)
    lead = (None,)
    name = path.split("/")[-1]
    if name in ("wq",):
        return P(*lead, f, m if fits(cfg.n_heads) else None, None)
    if name in ("wk", "wv"):
        return P(*lead, f, m if fits(cfg.n_kv_heads) else None, None)
    if name == "wo" and ndim == 4:
        return P(*lead, m if fits(cfg.n_heads) else None, None, f)
    if name in ("wi", "wg") and ndim == 3:   # dense MLP (G, D, F)
        return P(*lead, f, m)
    if name == "wo" and ndim == 3:           # dense MLP out (G, F, D)
        return P(*lead, m, f)
    if name in ("wi", "wg") and ndim == 4:   # MoE (G, E, D, F)
        mc = cfg.moe
        return P(*lead, m if fits(mc.n_experts) else None, f, None)
    if name == "wo" and ndim == 4:
        mc = cfg.moe
        return P(*lead, m if fits(mc.n_experts) else None, None, f)
    if name == "router":
        return P(*lead, None, None)
    if name == "in_proj":                    # mamba (G, D, E)
        return P(*lead, f, m)
    if name == "out_proj":                   # mamba (G, di, D)
        return P(*lead, m, f)
    if name == "conv_w":
        return P(*lead, None, m)
    if name in ("A_log", "D", "dt_bias"):
        return P(*lead, m if fits(cfg.n_ssm_heads) else None)
    # norms & everything else: replicated (tiny)
    return P(*([None] * ndim))


def _path_str(path) -> str:
    keys = []
    for k in path:
        if hasattr(k, "key"):
            keys.append(str(k.key))
        elif hasattr(k, "idx"):
            keys.append(str(k.idx))
    return "/".join(keys)


def _drop_indivisible(sp: P, shape, mesh: Mesh) -> P:
    """Replace any spec entry whose mesh-axis product doesn't divide the dim."""
    fixed = []
    for dim, entry in zip(shape, tuple(sp) + (None,) * (len(shape) - len(sp))):
        if entry is None:
            fixed.append(None)
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        size = int(np.prod([mesh.shape[a] for a in axes]))
        fixed.append(entry if dim % size == 0 else None)
    return P(*fixed)


def param_specs(params_tree, cfg, rules: Rules):
    """NamedSharding tree matching the parameter pytree."""
    def f(path, leaf):
        ps = _path_str(path)
        sp = _param_spec_for(ps, len(leaf.shape), rules, cfg)
        # MoE expert wo vs attn wo: both ndim 4 — disambiguate by path
        if ps.split("/")[-1] == "wo" and len(leaf.shape) == 4:
            m = "model"
            fx = rules.amap["fsdp"]
            msize = rules.mesh.shape["model"]
            if "moe" in ps:
                ok = cfg.moe.n_experts % msize == 0
                sp = P(None, m if ok else None, None, fx)
            else:
                ok = cfg.n_heads % msize == 0
                sp = P(None, m if ok else None, None, fx)
        sp = _drop_indivisible(sp, leaf.shape, rules.mesh)
        return NamedSharding(rules.mesh, sp)
    return jax.tree_util.tree_map_with_path(f, params_tree)


def cache_specs(cache_tree, cfg, rules: Rules):
    """KV/SSM cache shardings.  Attn K/V: (G, B, S, KVH, hd) — batch over the
    batch axes, sequence over model (+ data when batch==1).  SSM states:
    (G, B, H, hd, N) — heads over model when divisible."""
    msize = rules.mesh.shape["model"]
    batch_ax = rules.amap["batch"]
    kvseq_extra = rules.amap["kv_seq"]

    def f(path, leaf):
        ps = _path_str(path)
        name = ps.split("/")[-1]
        if name in ("k", "v"):
            seq_axes = ("model",) if kvseq_extra is None else tuple(kvseq_extra) + ("model",)
            if leaf.shape[2] % int(np.prod([rules.mesh.shape[a] for a in seq_axes])) != 0:
                seq_axes = None
            sp = P(None, batch_ax, seq_axes, None, None)
        elif name == "ssm":
            ok = leaf.shape[2] % msize == 0
            sp = P(None, batch_ax, "model" if ok else None, None, None)
        elif name == "conv":
            sp = P(None, batch_ax, None, "model" if leaf.shape[3] % msize == 0 else None)
        else:
            sp = P(*([None] * len(leaf.shape)))
        return NamedSharding(rules.mesh, sp)
    return jax.tree_util.tree_map_with_path(f, cache_tree)


def batch_specs(rules: Rules):
    return NamedSharding(rules.mesh, P(rules.amap["batch"], None))
