"""StableLM-2 12B [hf:stabilityai] — dense GQA decoder.

40L, d_model 5120, 32 heads (kv=8, head_dim 160), d_ff 13824 (SwiGLU),
vocab 100352.  Pure full attention ⇒ long_500k skipped.
"""
from repro.models.config import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="stablelm-12b",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    d_ff=13824,
    vocab_size=100352,
    group=(LayerSpec(mixer="attn", ffn="mlp"),),
    max_seq=131_072,
)
