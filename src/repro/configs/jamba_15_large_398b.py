"""Jamba-1.5-Large 398B [arXiv:2403.19887] — hybrid Mamba+attention, MoE.

72L in period-8 groups: 1 attention layer : 7 Mamba layers, MoE (16 experts,
top-2, d_expert 24576) every other layer and dense MLP (d_ff 24576) on the
rest — the source paper's exact interleave.  d_model 8192, 64 heads (kv=8),
vocab 65536.  Hybrid ⇒ runs long_500k (Mamba layers O(1) state; the 1-in-8
attention layers shard the 512k KV over the mesh).
"""
from repro.models.config import LayerSpec, ModelConfig, MoEConfig, SSMConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=24576,
    vocab_size=65536,
    group=(
        LayerSpec(mixer="mamba", ffn="mlp"),
        LayerSpec(mixer="mamba", ffn="moe"),
        LayerSpec(mixer="mamba", ffn="mlp"),
        LayerSpec(mixer="mamba", ffn="moe"),
        LayerSpec(mixer="attn", ffn="mlp"),
        LayerSpec(mixer="mamba", ffn="moe"),
        LayerSpec(mixer="mamba", ffn="mlp"),
        LayerSpec(mixer="mamba", ffn="moe"),
    ),
    moe=MoEConfig(n_experts=16, top_k=2, n_shared=0, d_expert=24576),
    ssm=SSMConfig(d_state=128, head_dim=64, expand=2, conv_width=4, chunk=256),
    subquadratic=True,
    max_seq=1_048_576,
)
