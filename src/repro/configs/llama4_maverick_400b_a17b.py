"""Llama-4 Maverick 400B-A17B [hf:meta-llama] — MoE, early fusion.

48L, d_model 5120, 40 heads (kv=8), 128 routed experts top-1 + 1 shared
expert (d_expert 8192), interleaved with dense layers (d_ff 16384) every
other layer — the interleave matches the model card's 400B total / 17B
active; a uniform all-MoE reading of the flat config would give ~770B.
Early-fusion multimodality enters through the stubbed prefix
embeddings (text-only token path exercised here).
"""
from repro.models.config import LayerSpec, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=16384,                      # dense interleave layers
    vocab_size=202048,
    group=(
        LayerSpec(mixer="attn", ffn="moe"),
        LayerSpec(mixer="attn", ffn="mlp"),
    ),
    moe=MoEConfig(n_experts=128, top_k=1, n_shared=1, d_expert=8192),
    rope_theta=500_000.0,
    max_seq=131_072,
)
