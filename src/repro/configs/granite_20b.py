"""Granite-20B code [arXiv:2405.04324] — GPT-BigCode-style dense, MQA (kv=1).

52L, d_model 6144, 48 heads, kv=1, d_ff 24576 (non-gated GELU MLP),
vocab 49152.  Pure full attention ⇒ long_500k skipped
(`launch/shapes.py::shape_applicable`).
"""
from repro.models.config import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="granite-20b",
    n_layers=52,
    d_model=6144,
    n_heads=48,
    n_kv_heads=1,
    d_ff=24576,
    vocab_size=49152,
    group=(LayerSpec(mixer="attn", ffn="mlp"),),
    mlp_gated=False,
    max_seq=131_072,
)
