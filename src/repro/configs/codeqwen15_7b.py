"""CodeQwen1.5-7B [hf:Qwen/CodeQwen1.5-7B] — dense MHA decoder.

32L, d_model 4096, 32 heads (kv=32: full MHA), d_ff 13440 (SwiGLU),
vocab 92416.  Pure full attention ⇒ long_500k skipped.
"""
from repro.models.config import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="codeqwen1.5-7b",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=32,
    d_ff=13440,
    vocab_size=92416,
    group=(LayerSpec(mixer="attn", ffn="mlp"),),
    rope_theta=1_000_000.0,
    max_seq=131_072,
)
