"""Mamba2-370m [arXiv:2405.21060] — SSD (state-space duality), attention-free.

48L, d_model 1024, d_inner 2048 (expand 2), 32 SSM heads × head_dim 64,
d_state 128, vocab 50280.  Sub-quadratic: runs long_500k.
"""
from repro.models.config import LayerSpec, ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-370m",
    n_layers=48,
    d_model=1024,
    n_heads=1,          # unused (attention-free)
    n_kv_heads=1,
    d_ff=0,
    vocab_size=50280,
    group=(LayerSpec(mixer="mamba", ffn="none"),),
    ssm=SSMConfig(d_state=128, head_dim=64, expand=2, conv_width=4, chunk=256),
    subquadratic=True,
    max_seq=1_048_576,
)
