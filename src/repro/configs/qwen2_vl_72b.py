"""Qwen2-VL-72B [arXiv:2409.12191] — VLM text backbone with M-RoPE.

80L, d_model 8192, 64 heads (kv=8), d_ff 29568 (SwiGLU), vocab 152064.
The ViT/dynamic-resolution frontend is a STUB: input_specs provide 256
precomputed patch embeddings per sample; M-RoPE (3-section rotary) is the
real mechanism exercised.  Pure full attention ⇒ long_500k skipped.
"""
from repro.models.config import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-72b",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=29568,
    vocab_size=152064,
    group=(LayerSpec(mixer="attn", ffn="mlp"),),
    mrope=True,
    n_prefix_embeds=256,
    rope_theta=1_000_000.0,
    max_seq=131_072,
)
