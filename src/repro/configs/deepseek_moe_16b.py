"""DeepSeek-MoE 16B [arXiv:2401.06066] — fine-grained MoE.

28L, d_model 2048, 16 heads (MHA: kv=16), 64 routed experts top-6 with
d_expert=1408 + 2 shared experts, vocab 102400.  The source model's first
layer is a dense MLP; we keep all layers MoE for scan homogeneity
(parameter count matches within 2%).
"""
from repro.models.config import LayerSpec, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab_size=102400,
    group=(LayerSpec(mixer="attn", ffn="moe"),),
    moe=MoEConfig(n_experts=64, top_k=6, n_shared=2, d_expert=1408),
    rope_theta=10_000.0,
    max_seq=131_072,
)
