"""Whisper-small [arXiv:2212.04356] — encoder–decoder audio backbone.

12L encoder + 12L decoder, d_model 768, 12 heads (MHA), d_ff 3072 (non-gated
GELU), vocab 51865.  The mel-spectrogram + conv frontend is a STUB:
input_specs provide precomputed frame embeddings (1500 frames = 30 s at the
model's 2× conv downsampling).  decode_32k exceeds the source card's
448-token context — exercised against the generic backbone as assigned.
"""
from repro.models.config import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="whisper-small",
    n_layers=12,
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    d_ff=3072,
    vocab_size=51865,
    group=(LayerSpec(mixer="attn", ffn="mlp"),),
    mlp_gated=False,
    n_enc_layers=12,
    enc_seq=1500,
    max_seq=65_536,
)
