"""Gemma-3 4B [hf:google/gemma-3] — dense, 5:1 local:global attention, 128k.

34L (pattern: 5 sliding-window-1024 layers then 1 global, remainder sliding),
d_model 2560, 8 heads (kv=4), head_dim 256, d_ff 10240, vocab 262144, tied
embeddings.  Sliding windows make long_500k tractable: local layers keep
ring KV caches of 1024; global layers shard the 512k KV over the mesh.
"""
from repro.models.config import LayerSpec, ModelConfig

_W = 1024
# period-6 pattern × 5 full periods = 30, + 4 trailing sliding layers = 34;
# we express it as a group of 17 repeated twice (scan over 2 groups) to keep
# the exact 5:1 cadence: positions 5, 11 global within each 17 ... the true
# cadence has globals at layer indices 5,11,17,23,29 — i.e. 5 globals in 34.
# Group of 17: sliding×5, global, sliding×5, global, sliding×5 → 2 globals
# per group + final arrangement gives 4 globals; we add the 5th by making the
# last layer of the second group global via a 2-group asymmetry — instead we
# use the uniform period-6 group repeated where 34 = 2 × 17 and accept 4
# globals (noted deviation; ratio stays ≈5:1).
CONFIG = ModelConfig(
    name="gemma3-4b",
    n_layers=34,
    d_model=2560,
    n_heads=8,
    n_kv_heads=4,
    head_dim=256,
    d_ff=10240,
    vocab_size=262144,
    group=(
        LayerSpec(mixer="attn", ffn="mlp", window=_W),
        LayerSpec(mixer="attn", ffn="mlp", window=_W),
        LayerSpec(mixer="attn", ffn="mlp", window=_W),
        LayerSpec(mixer="attn", ffn="mlp", window=_W),
        LayerSpec(mixer="attn", ffn="mlp", window=_W),
        LayerSpec(mixer="attn", ffn="mlp", window=None),
        LayerSpec(mixer="attn", ffn="mlp", window=_W),
        LayerSpec(mixer="attn", ffn="mlp", window=_W),
        LayerSpec(mixer="attn", ffn="mlp", window=_W),
        LayerSpec(mixer="attn", ffn="mlp", window=_W),
        LayerSpec(mixer="attn", ffn="mlp", window=_W),
        LayerSpec(mixer="attn", ffn="mlp", window=None),
        LayerSpec(mixer="attn", ffn="mlp", window=_W),
        LayerSpec(mixer="attn", ffn="mlp", window=_W),
        LayerSpec(mixer="attn", ffn="mlp", window=_W),
        LayerSpec(mixer="attn", ffn="mlp", window=_W),
        LayerSpec(mixer="attn", ffn="mlp", window=_W),
    ),
    tie_embeddings=True,
    rope_theta=1_000_000.0,
    subquadratic=True,   # 5:1 sliding + seq-sharded global KV
    max_seq=1_048_576,
)
