"""Deterministic synthetic token pipeline.

Seeded, stateless (batch i is a pure function of (seed, i)), shardable: the
generator produces the *global* batch; the caller places it with the batch
sharding.  The token stream is a Zipf-ish unigram mixture with a Markov
bigram component so cross-entropy is learnable (loss visibly decreases in the
end-to-end example) rather than uniform noise.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class SyntheticTokens:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.2

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        v = min(self.vocab_size, 4096)  # active vocab head
        ranks = np.arange(1, v + 1, dtype=np.float64)
        self.probs = (ranks ** -self.zipf_a)
        self.probs /= self.probs.sum()
        self.active_vocab = v
        # deterministic "grammar": each token has a preferred successor
        self.successor = rng.integers(0, v, size=v)

    def batch(self, i: int) -> np.ndarray:
        rng = np.random.default_rng((self.seed, i))
        B, S = self.global_batch, self.seq_len
        base = rng.choice(self.active_vocab, size=(B, S), p=self.probs)
        # with prob 0.5, token t+1 = successor(token t) → learnable bigrams
        follow = rng.random((B, S)) < 0.5
        out = base.copy()
        for s in range(1, S):
            out[:, s] = np.where(follow[:, s], self.successor[out[:, s - 1]],
                                 base[:, s])
        return out.astype(np.int32)


def make_batch_iterator(
    vocab_size: int,
    seq_len: int,
    global_batch: int,
    seed: int = 0,
    extras: Optional[Dict[str, tuple]] = None,
    dtype=jnp.bfloat16,
) -> Iterator[Dict[str, jax.Array]]:
    gen = SyntheticTokens(vocab_size, seq_len, global_batch, seed)
    i = 0
    rng = np.random.default_rng(seed + 1)
    while True:
        b: Dict[str, jax.Array] = {"tokens": jnp.asarray(gen.batch(i))}
        for name, shape in (extras or {}).items():
            b[name] = jnp.asarray(rng.standard_normal(shape), dtype) * 0.02
        yield b
        i += 1
