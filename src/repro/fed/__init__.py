from .bldnn import BLDNNConfig, make_fed_train_step, layer_bases_from_params  # noqa: F401
