from .bldnn import (  # noqa: F401
    BLDNNConfig,
    init_mlp_classifier,
    make_eval_fn,
    make_loss_fn,
    make_synthetic_classification,
    run_bldnn,
)
