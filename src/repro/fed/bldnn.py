"""BL-DNN: the paper's communication layer applied to deep-network training.

This is the labelled BEYOND-PAPER extension (docs/ARCHITECTURE.md §Layer 3):
the paper's exact second-order method needs d×d Hessians, impossible for
d ≥ 10⁹.  What *does* transfer is the communication mechanism, applied per
layer:

  1. **Basis Learn** — every 2-D weight's update is communicated in a fixed
     per-layer orthogonal basis (U_ℓ, V_ℓ) from the SVD of the initialization
     (shipped once; the server knows it — §2.3's recipe with the weight
     matrix playing the data-matrix role).  Gradient energy concentrates in
     the leading coefficients, so Top-K in the rotated space keeps more
     signal per bit than Top-K in the standard basis (tests/test_fed.py).
  2. **Compressed-difference learning with shifts** (the L_i^k recursion of
     Alg. 1 applied to gradients): client i sends C(γ_i − L_i); both sides
     update L_i ← L_i + αC(·).  Contractive compressors use α = 1
     (Assumption 4.6), unbiased ones α = 1/(ω+1) (Assumption 4.5).
  3. **Curvature learning** (the second-order part): clients learn a
     per-parameter Fisher-diagonal estimate through the same compressed
     recursion; the server preconditions the aggregated update — the FedNL
     Hessian-learning loop with diag(F) standing in for ∇²f_i.

The method itself is `repro.core.specs.BLDNNSpec` running on the unified
round engine (`repro.core.rounds`): per-client state is a parameter pytree
with a leading client axis, the shift recursion is the shared
`rounds.tree_shift_update` combinator, compressors come from the
`repro.core.compressors` registry (one per leaf, so Top-K budgets scale
with layer size — stochastic codecs like RTop-K work too), the basis is
the registered ``per_layer_svd`` kind (`repro.core.basis`), and every leg
bills onto the shared `comm.CommLedger` at the f32 wire.  Both aggregation
backends run it: `VmapReducer` on a single device (no mesh needed) and
`ShardMapReducer` with clients sharded over `CLIENT_AXIS` — bitwise
identical histories (tests/test_fed.py).

This module is the workload wiring: an MLP classifier assembled from
`repro.models.layers`, a synthetic fine-tuning-style classification fleet,
per-leaf compressor construction, and the public `run_bldnn` entry point
the `fig-dnn` experiment (`repro.exp.registry`) dispatches to.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import batched, comm, rounds, specs
from repro.core.basis import PerLayerSVDBasis, is_pytree_basis, make_bases
from repro.core.bl import History
from repro.core.client_batch import TreeBatch, tree_batch
from repro.core.compressors import Compressor, Identity, TopK, rtopk
from repro.models import layers as L

Params = Dict[str, Any]

_BACKENDS = ("fast", "fast+sharded")


@dataclasses.dataclass(frozen=True)
class BLDNNConfig:
    """BL-DNN hyperparameters (one frozen config → one `BLDNNSpec`)."""

    top_k_frac: float = 0.05       # per-leaf Top-K budget: k = max(1, ⌊frac·numel⌋)
    compressor: str = "topk"       # "topk" | "rtopk" | "identity"
    alpha: float = 1.0             # shift learning rate (contractive ⇒ 1)
    lr: float = 1e-3
    precondition: bool = True
    fisher_alpha: float = 0.1
    eps: float = 1e-2
    use_basis: bool = True
    #: which registered pytree basis (``per_layer_svd`` | ``dct_tree`` |
    #: ``hadamard_tree`` — the structured kinds ship zero floats)
    basis_kind: str = "per_layer_svd"
    #: shipment wire for the basis factors (comm.BasisShipSpec): per-float
    #: width (32/16 bf16/8 int8+scales) and top-|·| column sparsification
    ship_float_bits: int = 32
    ship_col_frac: float = 1.0
    #: amortized re-shipment (specs.BasisRefreshPolicy): 0 ships once;
    #: T ≥ 1 re-bills the shipment at t % T == 0 boundaries when the
    #: drift trigger (energy-leakage ≥ threshold) fires
    rounds_per_refresh: int = 0
    drift_threshold: float = 0.0


# --------------------------------------------------------------------------
# model: an MLP classifier assembled from the production layer library
# --------------------------------------------------------------------------
def init_mlp_classifier(key, d_in: int, width: int, classes: int,
                        spectral_decay: float = 0.0,
                        dtype=jnp.float32) -> Params:
    """Input projection → `models.layers` MLP block → class head.

    ``spectral_decay > 0`` re-spectralizes every 2-D weight to singular
    values exp(−i/decay) (energy concentrated in the leading directions, as
    pretrained-network weights are) — the regime where the per-layer SVD
    basis has structure to exploit.  0 keeps the plain random init.
    """
    ks = jax.random.split(key, 3)
    params = {
        "in": L._init(ks[0], (d_in, width), d_in ** -0.5, dtype),
        "mlp": L.init_mlp(ks[1], width, 2 * width, False, dtype),
        "out": L._init(ks[2], (width, classes), width ** -0.5, dtype),
    }
    if spectral_decay > 0.0:
        def respectralize(p):
            if p.ndim != 2 or min(p.shape) < 2:
                return p
            u, s, vt = jnp.linalg.svd(p.astype(jnp.float32),
                                      full_matrices=False)
            snew = jnp.exp(-jnp.arange(s.shape[0]) / spectral_decay)
            snew = snew * (jnp.linalg.norm(s) / jnp.linalg.norm(snew))
            return ((u * snew) @ vt).astype(p.dtype)
        params = jax.tree.map(respectralize, params)
    return params


def mlp_classifier_logits(params: Params, x: jax.Array) -> jax.Array:
    """(B, d_in) features → (B, classes) logits."""
    h = jnp.tanh(x @ params["in"])
    # the production MLP block operates on (batch, seq, d) activations
    h = h + L.mlp(params["mlp"], h[:, None, :], False, None)[:, 0, :]
    return h @ params["out"]


def make_loss_fn(classes: int):
    """Per-client mean softmax cross-entropy: (params, {"x", "y"}) → scalar."""
    del classes  # shapes carry it; kept for signature stability

    def loss_fn(params, data):
        logits = mlp_classifier_logits(params, data["x"])
        logp = jax.nn.log_softmax(logits)
        return -jnp.mean(jnp.take_along_axis(logp, data["y"][:, None],
                                             axis=1))
    return loss_fn


def make_eval_fn():
    """Fleet evaluation for `BLDNNSpec.eval_streams`: training error rate
    (the ``gap`` stream — so bits-to-tolerance IS bits-to-accuracy) plus
    the mean training loss as an extra ``loss`` stream."""

    def eval_fn(params, data):
        logits = jax.vmap(
            lambda xb: mlp_classifier_logits(params, xb))(data["x"])
        logp = jax.nn.log_softmax(logits)
        nll = -jnp.take_along_axis(logp, data["y"][..., None], axis=-1)
        err = jnp.mean((jnp.argmax(logits, -1) != data["y"])
                       .astype(jnp.float64))
        return {"gap": err, "loss": jnp.mean(nll).astype(jnp.float64)}
    return eval_fn


# --------------------------------------------------------------------------
# data: synthetic non-iid classification fleet (fine-tuning regime)
# --------------------------------------------------------------------------
def make_synthetic_classification(seed: int, n_clients: int, m: int, d: int,
                                  classes: int, width: int, r: int = 8,
                                  heterogeneity: float = 0.5,
                                  label_noise: float = 0.05,
                                  ) -> Tuple[TreeBatch, Params]:
    """A teacher-labelled classification fleet plus a near-teacher student —
    the §2.3 low-rank regime carried to a DNN.

    Client inputs live EXACTLY in a shared r-dimensional subspace span(P)
    (x = z Pᵀ, the DNN analogue of §2.3's "client rows span G_i"), so every
    input-layer gradient xᵀδ has its row space inside span(P) while being
    entrywise *dense* in standard coordinates.  The teacher's input layer
    is subspace-aligned (W_in = P M — what training on such data produces)
    and its deeper layers carry decaying spectra; the student is the
    teacher plus 40% perturbation whose input-layer component stays in the
    span.  Fine-tuning the student is therefore the regime BL-DNN targets:
    the per-layer SVD basis of W_in concentrates the (dense-looking)
    gradient into ~r·width leading coefficients, exactly as the paper's
    data basis concentrates Hessian coefficients.  Clients are non-iid
    (latent mean shifts scaled by `heterogeneity`); labels get
    `label_noise` uniform flips.

    Returns ``(batch, params0)``: the client-stacked `TreeBatch`
    ``{"x": (n, m, d), "y": (n, m)}`` and the student parameter pytree.
    """
    rng = np.random.default_rng(seed)
    kt, ks = jax.random.split(jax.random.PRNGKey(seed))
    P, _ = np.linalg.qr(rng.standard_normal((d, r)))      # shared subspace
    shifts = np.linspace(-1.0, 1.0, n_clients) * heterogeneity
    z = rng.standard_normal((n_clients, m, r)) + shifts[:, None, None]
    x = jnp.asarray(z @ P.T, jnp.float32)                 # rank-r rows

    teacher = init_mlp_classifier(kt, d, width, classes, spectral_decay=8.0)
    M = rng.standard_normal((r, width)) / np.sqrt(r)
    teacher["in"] = jnp.asarray(P @ M, jnp.float32)       # subspace-aligned
    logits = jax.vmap(lambda xb: mlp_classifier_logits(teacher, xb))(x)
    y = np.asarray(jnp.argmax(logits, -1))
    flip = rng.random((n_clients, m)) < label_noise
    y = np.where(flip, rng.integers(0, classes, (n_clients, m)), y)
    batch = tree_batch({"x": x, "y": jnp.asarray(y, jnp.int32)})

    # student: 60% teacher + 40% perturbation — near the task but not at
    # it (fine-tuning has work to do).  The input-layer perturbation stays
    # in span(P) (a model pretrained on this data distribution never grew
    # out-of-span input weights), so its SVD basis leads with span(P).
    fresh = init_mlp_classifier(ks, d, width, classes)
    fresh["in"] = jnp.asarray(P @ (P.T @ np.asarray(fresh["in"], np.float64)),
                              jnp.float32)
    student = jax.tree.map(lambda t, f: 0.6 * t + 0.4 * f, teacher, fresh)
    return batch, student


# --------------------------------------------------------------------------
# per-leaf compressors + the public entry point
# --------------------------------------------------------------------------
def leaf_compressors(kind: str, frac: float,
                     params: Params) -> Tuple[Compressor, ...]:
    """One registry compressor per parameter leaf, Top-K budgets scaled to
    the leaf: k_ℓ = max(1, ⌊frac·numel_ℓ⌋)."""
    comps = []
    for p in jax.tree_util.tree_leaves(params):
        k = max(1, int(frac * p.size))
        if kind == "identity":
            comps.append(Identity())
        elif kind == "topk":
            comps.append(TopK(k=k))
        elif kind == "rtopk":
            comps.append(rtopk(k))
        else:
            raise ValueError(
                f"unknown BL-DNN compressor kind {kind!r} "
                "(expected identity | topk | rtopk)")
    return tuple(comps)


def build_spec(loss_fn, eval_fn, params: Params, cfg: BLDNNConfig, *,
               basis_ship_bits: Optional[float] = None) -> specs.BLDNNSpec:
    """`BLDNNSpec` for a parameter tree under one `BLDNNConfig`.

    ``basis_ship_bits`` is the exact priced cost of one (possibly
    quantized) basis shipment; None keeps the legacy dense-f32 derivation
    from ``ship_floats()``."""
    comps = leaf_compressors(cfg.compressor, cfg.top_k_frac, params)
    return specs.BLDNNSpec(
        loss_fn=loss_fn, eval_fn=eval_fn,
        grad_comps=comps, fisher_comps=comps,
        alpha=cfg.alpha, fisher_alpha=cfg.fisher_alpha,
        lr=cfg.lr, eps=cfg.eps, precondition=cfg.precondition,
        basis_ship_bits=basis_ship_bits,
        refresh=specs.BasisRefreshPolicy(
            rounds_per_refresh=cfg.rounds_per_refresh,
            drift_threshold=cfg.drift_threshold))


def run_bldnn(loss_fn, eval_fn, params0: Params, batch: TreeBatch,
              steps: int, cfg: BLDNNConfig = BLDNNConfig(), *,
              seed: int = 0, backend: str = "fast", exact: bool = True,
              basis: Optional[PerLayerSVDBasis] = None,
              stream=None) -> History:
    """Train `steps` BL-DNN rounds on the unified round engine.

    Args:
      loss_fn: per-client loss ``(params, client_data) -> scalar``.
      eval_fn: fleet metrics ``(params, stacked_data) -> {"gap", ...}``
        (see `make_eval_fn`).
      params0: replicated initial parameter pytree.
      batch: client-stacked `TreeBatch` (leaves ``(n, ...)``).
      steps: communication rounds.
      cfg: hyperparameters; ``cfg.use_basis=False`` runs the standard
        basis (no rotations, zero shipment).
      seed: PRNG seed (stochastic compressors, per-round keys).
      backend: ``"fast"`` (single-device `VmapReducer`) or
        ``"fast+sharded"`` (clients over the mesh `CLIENT_AXIS`) — bitwise
        identical histories when ``exact``.
      exact: sharded aggregation parity (see `rounds.ShardMapReducer`).
        True gathers in fixed order (bitwise = single-device); False takes
        `BLDNNSpec.reduce_plan`'s ring collectives (pmean per dense/vector
        leg, psum for bit counters) — fewer bytes on the wire, reductions
        associate in ring order.  Ignored on the "fast" backend.
      basis: override the `per_layer_svd` basis (defaults to building it
        from ``params0`` via the basis registry).
      stream: optional `rounds.StreamHook` — chunk-boundary progress
        emission on either backend.

    Returns:
      `History` — ``gaps`` is the training error rate, ``metrics["loss"]``
      the loss stream, ``legs`` the per-leg `CommLedger` bit streams
      (gradient coefficients on ``grad_up``, the Fisher stream on
      ``hess_up``, the one-time (U_ℓ, V_ℓ) shipment on ``basis_ship``).
    """
    if backend not in _BACKENDS:
        raise ValueError(f"backend must be one of {_BACKENDS}, got {backend!r}")
    if cfg.use_basis and basis is None:
        if not is_pytree_basis(cfg.basis_kind):
            raise ValueError(
                f"BL-DNN needs a pytree basis, {cfg.basis_kind!r} is a "
                "d×d matrix basis (see basis.available_bases())")
        basis = make_bases(cfg.basis_kind, params0)
    if not cfg.use_basis:
        basis = None
    ship_bits = None
    if basis is not None:
        # the engine rotates with the basis AS SHIPPED: quantize the
        # factors per the shipment wire and bill their exact priced cost
        # (the default f32-dense spec is the identity at the legacy price;
        # structured zero-ship bases pass through at 0 bits)
        ship = comm.BasisShipSpec(float_bits=cfg.ship_float_bits,
                                  col_frac=cfg.ship_col_frac)
        basis, ship_bits = basis.shipped(ship)
    spec = build_spec(loss_fn, eval_fn, params0, cfg,
                      basis_ship_bits=ship_bits)
    keys = jax.random.split(jax.random.PRNGKey(seed), steps)
    evals, leds = rounds.run_rounds(
        spec, batch, basis, params0, 0.0, keys,
        sharded=(backend == "fast+sharded"), exact=exact, stream=stream)
    return batched._history(evals, leds)

