"""BL-DNN: the paper's communication layer applied to deep-network training.

This is the labelled BEYOND-PAPER extension (DESIGN.md §3): the paper's exact
second-order method needs d×d Hessians, impossible for d ≥ 10⁹.  What *does*
transfer is the communication mechanism, applied per layer:

  1. **Basis Learn** — every 2-D weight's update is communicated in a fixed
     per-layer orthogonal basis (U_ℓ, V_ℓ) from the SVD of the initialization
     (shipped once; the server knows it — §2.3's recipe with the weight
     matrix playing the data-matrix role).  Gradient energy concentrates in
     the leading coefficients, so Top-K in the rotated space keeps more
     signal per bit than Top-K in the standard basis (tests/test_fed.py).
  2. **Compressed-difference learning with shifts** (the L_i^k recursion of
     Alg. 1 applied to gradients): client i sends C(γ_i − L_i); both sides
     update L_i ← L_i + αC(·).  Contractive compressors use α = 1
     (Assumption 4.6), unbiased ones α = 1/(ω+1) (Assumption 4.5).  The
     recursion itself is the shared `repro.core.rounds.shift_update`
     combinator — the same code the GLM round engine runs.
  3. **Curvature learning** (the second-order part): clients learn a
     per-parameter Fisher-diagonal estimate through the same compressed
     recursion; the server preconditions the aggregated update — the FedNL
     Hessian-learning loop with diag(F) standing in for ∇²f_i.

Clients map onto the mesh's `data` axis via shard_map: one SPMD program; the
psum of compressed-dense tensors plays the server aggregation.  Per-client
state (shifts) carries a leading n_clients axis sharded over `data`.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.core import comm
from repro.core.compressors import _topk_keep_mask
from repro.core.rounds import shift_update
from repro.sharding.rules import CLIENT_AXIS

Params = Dict[str, Any]

#: BL-DNN communicates f32 tensors — one wire format, priced by the shared
#: comm layer (no hand-kept bit math in the training step).
WIRE_F32 = comm.WireFormat(float_bits=32)


@dataclasses.dataclass(frozen=True)
class BLDNNConfig:
    top_k_frac: float = 0.05
    alpha: float = 1.0             # shift learning rate (contractive ⇒ 1)
    lr: float = 1e-3
    precondition: bool = True
    fisher_alpha: float = 0.1
    eps: float = 1e-2
    use_basis: bool = True


def _leaves(tree):
    return jax.tree_util.tree_flatten(tree)[0]


def _unflatten_like(tree, leaves):
    return jax.tree_util.tree_unflatten(jax.tree_util.tree_structure(tree), leaves)


# --------------------------------------------------------------------------
# Per-layer bases (shipped once — §2.3's "initial communication cost")
# --------------------------------------------------------------------------
def layer_bases_from_params(params: Params, use_basis: bool = True) -> List:
    """List (ordered like tree leaves) of (U, V) per 2-D leaf, else None.

    full_matrices=True: the basis must be a COMPLETE orthogonal basis of
    R^{m×n} (the paper's requirement — a truncated V would silently project
    out every gradient component outside the weight's row space)."""
    out = []
    for p in _leaves(params):
        if use_basis and p.ndim == 2 and min(p.shape) >= 2:
            u, _, vt = jnp.linalg.svd(p.astype(jnp.float32), full_matrices=True)
            out.append((u, vt.T))
        else:
            out.append(None)
    return out


def basis_bits(bases) -> float:
    """One-time basis shipping cost (floats)."""
    total = 0.0
    for b in bases:
        if b is not None:
            total += b[0].size + b[1].size
    return total


def init_comm_ledger(bases) -> comm.CommLedger:
    """Fresh per-leg ledger with the one-time (U_ℓ, V_ℓ) shipment billed —
    the same `CommLedger` the GLM round engine threads through its scan, so
    BL-DNN runs report bits on the same axes (no separate billing scheme)."""
    ship = comm.price(WIRE_F32, comm.Counts(floats=basis_bits(bases)))
    return comm.CommLedger.create(basis_ship=ship)


def accumulate_comm(ledger: comm.CommLedger, metrics) -> comm.CommLedger:
    """Fold one fed_step's metrics into the ledger: basis-coefficient
    gradients on the grad leg, the Fisher-diagonal (curvature) stream on the
    hess leg."""
    return ledger.add(grad_up=metrics["grad_up_bits"],
                      hess_up=metrics["hess_up_bits"])


def _rotate(g, basis):
    if basis is None:
        return g
    U, V = basis
    return U.T @ g.astype(jnp.float32) @ V


def _unrotate(c, basis):
    if basis is None:
        return c
    U, V = basis
    return U @ c @ V.T


def _coeff_shape(p, basis):
    # complete basis ⇒ coefficient tensor has the parameter's own shape
    return p.shape


def _topk_dense(x, frac: float):
    """Keep exactly the k = ⌈frac·numel⌉ largest-|·| entries; ties broken by
    index via the core `_topk_keep_mask` machinery (the old ≥-threshold mask
    kept extra entries on ties while billing only k).  Returns the compressed
    tensor and the ACTUAL number of nonzeros on the wire — exactly k unless
    some selected entries are themselves zero."""
    k = max(1, int(x.size * frac))
    v = x.reshape(-1)
    out = jnp.where(_topk_keep_mask(v, k), v, 0.0).reshape(x.shape)
    return out, jnp.sum(out != 0).astype(jnp.float32)


def init_fed_state(params: Params, bases, n_clients: int) -> Dict[str, Any]:
    """Shifts carry a leading n_clients axis (sharded over `data`)."""
    pl = _leaves(params)
    shift = [jnp.zeros((n_clients,) + _coeff_shape(p, b), jnp.float32)
             for p, b in zip(pl, bases)]
    fshift = [jnp.zeros((n_clients,) + p.shape, jnp.float32) for p in pl]
    server_f = [jnp.zeros(p.shape, jnp.float32) for p in pl]
    return {"shift": shift, "fisher_shift": fshift, "server_fisher": server_f}


def make_fed_train_step(loss_fn, mesh, cfg: BLDNNConfig, bases, params_tree):
    """fed_step(params, state, batch) → (params, state, metrics).

    loss_fn(params, batch) → scalar (computed on the client's batch shard).
    batch leaves sharded over `data`; params replicated; per-client shifts
    sharded on their leading axis.
    """
    data_axis = CLIENT_AXIS
    treedef = jax.tree_util.tree_structure(params_tree)
    compress = lambda t: _topk_dense(t, cfg.top_k_frac)

    def body(params, shift, fshift, server_f, batch):
        # each shard: params replicated; shift (1, ...) per client; batch local
        pl = _leaves(params)
        g = jax.grad(loss_fn)(params, batch)
        gl = _leaves(g)

        new_shift, sent_g, sent_f = [], 0.0, 0.0
        for gi, si, b in zip(gl, shift, bases):
            coeff = _rotate(gi, b)
            # shared Alg. 1 recursion: c = C(γ − L), L ← L + αc; the server
            # aggregation below tracks the pmean of the updated shifts
            _, s_new, k = shift_update(compress, coeff, si[0], cfg.alpha)
            new_shift.append(s_new[None])
            sent_g += k
        shift_mean = [jax.lax.pmean(s[0], data_axis) for s in new_shift]
        g_hat = [_unrotate(sm, b) for sm, b in zip(shift_mean, bases)]

        if cfg.precondition:
            new_fshift, f_server_new, update = [], [], []
            for gi, fsi, sfi, gh in zip(gl, fshift, server_f, g_hat):
                fl = gi.astype(jnp.float32) ** 2
                # same recursion learning the Fisher diagonal
                fc, fs_new, kf = shift_update(compress, fl, fsi[0],
                                              cfg.fisher_alpha)
                new_fshift.append(fs_new[None])
                sent_f += kf
                sf = sfi + cfg.fisher_alpha * jax.lax.pmean(fc, data_axis)
                f_server_new.append(sf)
                update.append(gh / (jnp.sqrt(jnp.maximum(sf, 0.0)) + cfg.eps))
        else:
            new_fshift = fshift
            f_server_new = server_f
            update = g_hat

        new_pl = [
            (p.astype(jnp.float32) - cfg.lr * u.reshape(p.shape)).astype(p.dtype)
            for p, u in zip(pl, update)
        ]
        new_params = _unflatten_like(params, new_pl)
        loss = jax.lax.pmean(loss_fn(params, batch), data_axis)
        # counts are the ACTUAL per-client nonzero totals (data-dependent,
        # differ per shard) — reduce to the fleet mean so the replicated
        # out_spec P() is genuinely replicated on multi-device meshes
        sent_g = jax.lax.pmean(jnp.asarray(sent_g, jnp.float32), data_axis)
        sent_f = jax.lax.pmean(jnp.asarray(sent_f, jnp.float32), data_axis)
        metrics = {
            "loss": loss,
            "floats_sent": sent_g + sent_f,
            # per-leg bits priced by the shared comm layer (ledger legs:
            # rotated-gradient coefficients → grad_up, Fisher diagonal →
            # hess_up; fold into a CommLedger via `accumulate_comm`)
            "grad_up_bits": comm.price(WIRE_F32, comm.Counts(floats=sent_g)),
            "hess_up_bits": comm.price(WIRE_F32, comm.Counts(floats=sent_f)),
        }
        return (new_params, new_shift, new_fshift, f_server_new, metrics)

    prepl = jax.tree.map(lambda _: P(), params_tree)

    def fed_step(params, state, batch):
        f = shard_map(
            body, mesh=mesh,
            in_specs=(prepl,
                      [P(data_axis)] * len(state["shift"]),
                      [P(data_axis)] * len(state["fisher_shift"]),
                      [P()] * len(state["server_fisher"]),
                      jax.tree.map(lambda _: P(data_axis), batch)),
            out_specs=(prepl,
                       [P(data_axis)] * len(state["shift"]),
                       [P(data_axis)] * len(state["fisher_shift"]),
                       [P()] * len(state["server_fisher"]),
                       {"loss": P(), "floats_sent": P(),
                        "grad_up_bits": P(), "hess_up_bits": P()}),
            check_rep=False,
        )
        new_params, shift, fshift, server_f, metrics = f(
            params, state["shift"], state["fisher_shift"],
            state["server_fisher"], batch)
        return new_params, {"shift": shift, "fisher_shift": fshift,
                            "server_fisher": server_f}, metrics

    return fed_step
