"""Resumable, schema-versioned artifacts for experiment sweeps.

Two artifact kinds per experiment:

  * **Per-cell JSON** — ``<artifacts>/<experiment>/<cell>.seed<k>.json``:
    the full declarative config, a digest of it (resume key), the complete
    `History` streams *including the CommLedger per-leg bit streams*
    (hess_up / grad_up / model_down / basis_ship), and the headline
    bits-to-tolerance record with its reached/not-reached flag.
  * **Figure CSV** — ``<out>/<experiment>_<cell>.csv``: the plottable curve
    (compatible ``iter,gap,up_bits_per_node,down_bits_per_node`` prefix as
    the historical ``results/`` files, then one column per ledger leg;
    legs are empty for reference-backend methods that predate the ledger).

Resume contract: a sweep re-run skips any (cell, seed) whose JSON exists
with a matching ``config_digest`` — so interrupting a sweep and re-running
is idempotent, and editing a cell config invalidates exactly that cell's
artifact.  Bump ``SCHEMA_VERSION`` on any breaking record-shape change;
the digest covers it, so stale-schema artifacts re-run automatically.
"""
from __future__ import annotations

import csv
import dataclasses
import hashlib
import json
import os
import zipfile
from typing import Optional

import numpy as np

from .metrics import bits_to_tol

SCHEMA_VERSION = 1
SCHEMA = f"repro.exp/cell@{SCHEMA_VERSION}"

#: figure-CSV column schema: historical 4-column prefix + ledger legs
CSV_COLUMNS = (
    "iter", "gap", "up_bits_per_node", "down_bits_per_node",
    "hess_up_bits", "grad_up_bits", "model_down_bits", "basis_ship_bits",
)
LEG_NAMES = ("hess_up", "grad_up", "model_down", "basis_ship")


def cell_config(exp, cell, seed: int, steps: int) -> dict:
    """The exact declarative inputs of one run, as plain JSON data."""
    return {
        "schema": SCHEMA,
        "experiment": exp.name,
        "problem": dataclasses.asdict(exp.problem),
        "cell": dataclasses.asdict(cell),
        "seed": seed,
        "steps": steps,          # effective steps (CLI --max-steps clamps)
        "tol": exp.tol,
    }


def config_digest(config: dict) -> str:
    """Stable digest of a cell config — the resume/invalidate key."""
    blob = json.dumps(config, sort_keys=True, default=str).encode()
    return hashlib.sha256(blob).hexdigest()[:16]


def cell_record(exp, cell, seed: int, steps: int, hist,
                runtime_s: Optional[float] = None) -> dict:
    """Build the full per-cell artifact record from a finished `History`."""
    config = cell_config(exp, cell, seed, steps)
    b2t = bits_to_tol(hist, exp.tol)
    legs = None
    if hist.legs is not None:
        legs = {name: [float(v) for v in hist.legs[name]]
                for name in LEG_NAMES}
    history = {
        "gaps": [float(g) for g in hist.gaps],
        "up_bits": [float(b) for b in hist.up_bits],
        "down_bits": [float(b) for b in hist.down_bits],
        "legs": legs,
    }
    if getattr(hist, "metrics", None):
        # extra named eval streams (e.g. the BL-DNN loss curve) — the key
        # is present only when the method emits them, so committed
        # artifacts of stream-less methods keep their exact history shape
        history["metrics"] = {k: [float(v) for v in vs]
                              for k, vs in hist.metrics.items()}
    return {
        "schema": SCHEMA,
        "experiment": exp.name,
        "cell": cell.name,
        "seed": seed,
        "config_digest": config_digest(config),
        "config": config,
        "history": history,
        "bits_to_tol": {
            "tol": exp.tol,
            "mbits_per_node": (None if not b2t.reached else b2t.mbits),
            "reached": b2t.reached,
        },
        "runtime_s": runtime_s,
    }


def artifact_path(artifacts_dir: str, exp_name: str, cell_name: str,
                  seed: int) -> str:
    return os.path.join(artifacts_dir, exp_name,
                        f"{cell_name}.seed{seed}.json")


def write_json(path: str, record: dict) -> str:
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        json.dump(record, f, indent=1)
        f.write("\n")
    return path


def load_json(path: str) -> Optional[dict]:
    if not os.path.exists(path):
        return None
    try:
        with open(path) as f:
            return json.load(f)
    except (json.JSONDecodeError, OSError):
        return None       # truncated/corrupt partial artifact → re-run


def csv_path(out_dir: str, exp_name: str, cell_name: str) -> str:
    return os.path.join(out_dir, f"{exp_name}_{cell_name}.csv")


# ==========================================================================
# Service-loop checkpoints (repro.launch.fed_serve)
# ==========================================================================
# A checkpoint is a pair of files in the checkpoint directory:
#
#   ckpt-<t>.npz    — the flattened scan carry (``carry/<i>`` per leaf, leaf
#                     order = the engine's `init_serve_carry` flattening),
#                     the accumulated history streams (``stream/<name>``),
#                     and the run's root PRNG key data (``root_key``).
#   ckpt-<t>.json   — the manifest: schema tag, the serve config digest
#                     (resume key — a changed config invalidates the
#                     checkpoint), round counter, per-leaf shapes/dtypes,
#                     and the sha256 of the npz payload.
#
# Writes are atomic (tmp file + os.replace, npz before manifest) so a crash
# mid-write never leaves a manifest pointing at a torn payload; the loader
# walks checkpoints newest-first and falls back past any whose payload is
# missing, torn, or fails the digest — so the latest *valid* checkpoint
# wins even after a worst-case crash.
# @2 adds the optional host_state plane (the cohort-streaming engine's
# host-resident client store / fleet totals / frozen epoch stats — see
# repro.core.cohort).  Stacked serves write the same payload as @1 plus an
# empty host_state manifest list; @1 checkpoints are walked past by the
# schema check below (an old run restarts from round 0 rather than crashing
# or resuming state the new engine can't interpret).
CKPT_SCHEMA_VERSION = 2
CKPT_SCHEMA = f"repro.exp/ckpt@{CKPT_SCHEMA_VERSION}"

SERVE_SCHEMA_VERSION = 1
SERVE_SCHEMA = f"repro.exp/serve@{SERVE_SCHEMA_VERSION}"

# the AOT program-cache entry schema lives with its validation logic in
# repro.core.progcache; re-exported here so every artifact schema tag the
# repo writes is enumerable from one module
from repro.core.progcache import PROGCACHE_SCHEMA  # noqa: E402,F401


def _ckpt_base(ckpt_dir: str, t: int) -> str:
    return os.path.join(ckpt_dir, f"ckpt-{t:08d}")


def _sha256_file(path: str) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        for block in iter(lambda: f.read(1 << 20), b""):
            h.update(block)
    return h.hexdigest()


def _atomic_replace(tmp: str, dst: str) -> None:
    os.replace(tmp, dst)
    # best-effort directory fsync so the rename itself survives power loss
    try:
        dfd = os.open(os.path.dirname(dst) or ".", os.O_RDONLY)
        try:
            os.fsync(dfd)
        finally:
            os.close(dfd)
    except OSError:
        pass


def save_checkpoint(ckpt_dir: str, *, t: int, carry_leaves, streams: dict,
                    root_key, config_digest: str, keep: int = 3,
                    host_state: Optional[dict] = None) -> str:
    """Atomically write the service loop's full server state at round ``t``.

    ``carry_leaves`` is the flattened scan carry (numpy/JAX arrays, in the
    engine's canonical leaf order); ``streams`` maps stream name →
    accumulated (t, ...) array (eval iterates, per-leg ledger bit streams,
    events); ``root_key`` is the raw PRNG key data.  ``config_digest`` keys
    the checkpoint to one serve configuration.  ``host_state`` (ckpt@2) is
    an optional dict of named host-side arrays — the cohort-streaming
    engine's client store / fleet totals / frozen epoch stats
    (`CohortEngine.checkpoint_payload`); stacked serves omit it.  Keeps the
    newest ``keep`` checkpoints and prunes the rest.  Returns the manifest
    path."""
    os.makedirs(ckpt_dir, exist_ok=True)
    base = _ckpt_base(ckpt_dir, t)
    host_state = host_state or {}
    payload = {f"carry/{i}": np.asarray(leaf)
               for i, leaf in enumerate(carry_leaves)}
    for name, arr in streams.items():
        payload[f"stream/{name}"] = np.asarray(arr)
    for name, arr in host_state.items():
        payload[f"host/{name}"] = np.asarray(arr)
    payload["root_key"] = np.asarray(root_key)
    tmp = base + ".npz.tmp"
    with open(tmp, "wb") as f:
        np.savez(f, **payload)
        f.flush()
        os.fsync(f.fileno())
    _atomic_replace(tmp, base + ".npz")
    manifest = {
        "schema": CKPT_SCHEMA,
        "config_digest": config_digest,
        "t": int(t),
        "n_carry_leaves": len(carry_leaves),
        "carry_leaves": [{"shape": list(np.asarray(x).shape),
                          "dtype": str(np.asarray(x).dtype)}
                         for x in carry_leaves],
        "streams": sorted(streams),
        "host_state": sorted(host_state),
        "payload_sha256": _sha256_file(base + ".npz"),
    }
    tmp = base + ".json.tmp"
    with open(tmp, "w") as f:
        json.dump(manifest, f, indent=1)
        f.write("\n")
        f.flush()
        os.fsync(f.fileno())
    _atomic_replace(tmp, base + ".json")
    prune_checkpoints(ckpt_dir, keep=keep)
    return base + ".json"


def list_checkpoints(ckpt_dir: str):
    """(round, manifest path) pairs, oldest first."""
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for f in sorted(os.listdir(ckpt_dir)):
        if f.startswith("ckpt-") and f.endswith(".json"):
            try:
                t = int(f[len("ckpt-"):-len(".json")])
            except ValueError:
                continue
            out.append((t, os.path.join(ckpt_dir, f)))
    return out


def prune_checkpoints(ckpt_dir: str, keep: int) -> None:
    for t, manifest in list_checkpoints(ckpt_dir)[:-keep if keep else None]:
        for ext in (".json", ".npz"):
            try:
                os.remove(_ckpt_base(ckpt_dir, t) + ext)
            except OSError:
                pass


def load_checkpoint(ckpt_dir: str, *, config_digest: Optional[str] = None):
    """The newest valid checkpoint as a dict
    ``{t, carry_leaves, streams, root_key, host_state, manifest}`` — or
    None.

    Walks newest-first, skipping checkpoints whose manifest or payload is
    torn/corrupt (digest mismatch), that belong to a different serve
    config, or that carry an older schema tag (a ckpt@1 directory restarts
    from round 0 instead of crashing) — a crash during `save_checkpoint`
    therefore falls back to the previous intact checkpoint instead of
    resuming garbage."""
    for t, manifest_path in reversed(list_checkpoints(ckpt_dir)):
        manifest = load_json(manifest_path)
        if manifest is None or manifest.get("schema") != CKPT_SCHEMA:
            continue
        if (config_digest is not None
                and manifest.get("config_digest") != config_digest):
            continue
        npz_path = _ckpt_base(ckpt_dir, t) + ".npz"
        if not os.path.exists(npz_path):
            continue
        if _sha256_file(npz_path) != manifest.get("payload_sha256"):
            continue
        try:
            with np.load(npz_path) as z:
                n = manifest["n_carry_leaves"]
                carry = [z[f"carry/{i}"] for i in range(n)]
                streams = {name: z[f"stream/{name}"]
                           for name in manifest["streams"]}
                host_state = {name: z[f"host/{name}"]
                              for name in manifest.get("host_state", [])}
                root_key = z["root_key"]
        except (OSError, KeyError, ValueError, zipfile.BadZipFile):
            continue
        return {"t": manifest["t"], "carry_leaves": carry,
                "streams": streams, "root_key": root_key,
                "host_state": host_state, "manifest": manifest}
    return None


def write_fig_csv(out_dir: str, record: dict) -> str:
    """Write one figure curve CSV from a per-cell artifact record."""
    os.makedirs(out_dir, exist_ok=True)
    path = csv_path(out_dir, record["experiment"], record["cell"])
    h = record["history"]
    gaps, up, down = h["gaps"], h["up_bits"], h["down_bits"]
    legs = h.get("legs")
    with open(path, "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(CSV_COLUMNS)
        for i in range(len(gaps)):
            row = [i, np.float64(gaps[i]), np.float64(up[i]),
                   np.float64(down[i])]
            if legs is not None:
                row += [np.float64(legs[name][i]) for name in LEG_NAMES]
            else:
                row += ["", "", "", ""]
            w.writerow(row)
    return path
