"""Resumable, schema-versioned artifacts for experiment sweeps.

Two artifact kinds per experiment:

  * **Per-cell JSON** — ``<artifacts>/<experiment>/<cell>.seed<k>.json``:
    the full declarative config, a digest of it (resume key), the complete
    `History` streams *including the CommLedger per-leg bit streams*
    (hess_up / grad_up / model_down / basis_ship), and the headline
    bits-to-tolerance record with its reached/not-reached flag.
  * **Figure CSV** — ``<out>/<experiment>_<cell>.csv``: the plottable curve
    (compatible ``iter,gap,up_bits_per_node,down_bits_per_node`` prefix as
    the historical ``results/`` files, then one column per ledger leg;
    legs are empty for reference-backend methods that predate the ledger).

Resume contract: a sweep re-run skips any (cell, seed) whose JSON exists
with a matching ``config_digest`` — so interrupting a sweep and re-running
is idempotent, and editing a cell config invalidates exactly that cell's
artifact.  Bump ``SCHEMA_VERSION`` on any breaking record-shape change;
the digest covers it, so stale-schema artifacts re-run automatically.
"""
from __future__ import annotations

import csv
import dataclasses
import hashlib
import json
import os
from typing import Optional

import numpy as np

from .metrics import bits_to_tol

SCHEMA_VERSION = 1
SCHEMA = f"repro.exp/cell@{SCHEMA_VERSION}"

#: figure-CSV column schema: historical 4-column prefix + ledger legs
CSV_COLUMNS = (
    "iter", "gap", "up_bits_per_node", "down_bits_per_node",
    "hess_up_bits", "grad_up_bits", "model_down_bits", "basis_ship_bits",
)
LEG_NAMES = ("hess_up", "grad_up", "model_down", "basis_ship")


def cell_config(exp, cell, seed: int, steps: int) -> dict:
    """The exact declarative inputs of one run, as plain JSON data."""
    return {
        "schema": SCHEMA,
        "experiment": exp.name,
        "problem": dataclasses.asdict(exp.problem),
        "cell": dataclasses.asdict(cell),
        "seed": seed,
        "steps": steps,          # effective steps (CLI --max-steps clamps)
        "tol": exp.tol,
    }


def config_digest(config: dict) -> str:
    """Stable digest of a cell config — the resume/invalidate key."""
    blob = json.dumps(config, sort_keys=True, default=str).encode()
    return hashlib.sha256(blob).hexdigest()[:16]


def cell_record(exp, cell, seed: int, steps: int, hist,
                runtime_s: Optional[float] = None) -> dict:
    """Build the full per-cell artifact record from a finished `History`."""
    config = cell_config(exp, cell, seed, steps)
    b2t = bits_to_tol(hist, exp.tol)
    legs = None
    if hist.legs is not None:
        legs = {name: [float(v) for v in hist.legs[name]]
                for name in LEG_NAMES}
    history = {
        "gaps": [float(g) for g in hist.gaps],
        "up_bits": [float(b) for b in hist.up_bits],
        "down_bits": [float(b) for b in hist.down_bits],
        "legs": legs,
    }
    if getattr(hist, "metrics", None):
        # extra named eval streams (e.g. the BL-DNN loss curve) — the key
        # is present only when the method emits them, so committed
        # artifacts of stream-less methods keep their exact history shape
        history["metrics"] = {k: [float(v) for v in vs]
                              for k, vs in hist.metrics.items()}
    return {
        "schema": SCHEMA,
        "experiment": exp.name,
        "cell": cell.name,
        "seed": seed,
        "config_digest": config_digest(config),
        "config": config,
        "history": history,
        "bits_to_tol": {
            "tol": exp.tol,
            "mbits_per_node": (None if not b2t.reached else b2t.mbits),
            "reached": b2t.reached,
        },
        "runtime_s": runtime_s,
    }


def artifact_path(artifacts_dir: str, exp_name: str, cell_name: str,
                  seed: int) -> str:
    return os.path.join(artifacts_dir, exp_name,
                        f"{cell_name}.seed{seed}.json")


def write_json(path: str, record: dict) -> str:
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        json.dump(record, f, indent=1)
        f.write("\n")
    return path


def load_json(path: str) -> Optional[dict]:
    if not os.path.exists(path):
        return None
    try:
        with open(path) as f:
            return json.load(f)
    except (json.JSONDecodeError, OSError):
        return None       # truncated/corrupt partial artifact → re-run


def csv_path(out_dir: str, exp_name: str, cell_name: str) -> str:
    return os.path.join(out_dir, f"{exp_name}_{cell_name}.csv")


def write_fig_csv(out_dir: str, record: dict) -> str:
    """Write one figure curve CSV from a per-cell artifact record."""
    os.makedirs(out_dir, exist_ok=True)
    path = csv_path(out_dir, record["experiment"], record["cell"])
    h = record["history"]
    gaps, up, down = h["gaps"], h["up_bits"], h["down_bits"]
    legs = h.get("legs")
    with open(path, "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(CSV_COLUMNS)
        for i in range(len(gaps)):
            row = [i, np.float64(gaps[i]), np.float64(up[i]),
                   np.float64(down[i])]
            if legs is not None:
                row += [np.float64(legs[name][i]) for name in LEG_NAMES]
            else:
                row += ["", "", "", ""]
            w.writerow(row)
    return path
