"""CLI for the experiment subsystem.

    PYTHONPATH=src python -m repro.exp list
    PYTHONPATH=src python -m repro.exp run --fig fig1r1
    PYTHONPATH=src python -m repro.exp run --all
    PYTHONPATH=src python -m repro.exp run --fig fig4 --progress-every 4 --force

``run`` executes registered experiments (see `repro.exp.registry`), writes
per-cell JSON artifacts under ``--artifacts`` and regenerates the figure
CSVs under ``--out`` (defaults reproduce the committed ``results/``
layout).  Re-running resumes: cells with an up-to-date artifact are
skipped unless ``--force``.  ``--max-steps`` clamps every cell's round
budget (smoke tests / CI) — clamped histories are truncated, so the CLI
refuses to write them over the committed ``results/`` tree; point
``--out``/``--artifacts`` at a scratch directory as CI does.
``--progress-every`` streams (round, gap, Mbits) mid-scan for BL cells on
the single-device backends (sharded cells report at completion).
"""
from __future__ import annotations

import argparse
import os
import sys
import time

from .engine import build_problem, run_experiment
from .registry import available_experiments, get_experiment


def _cmd_list(args) -> int:
    for name in available_experiments():
        exp = get_experiment(name)
        cells = ", ".join(c.name for c in exp.cells)
        print(f"{name:10s} [{exp.figure}] {exp.title}")
        print(f"{'':10s}   {exp.paper_ref}; cells: {cells}")
    return 0


def _cmd_run(args) -> int:
    if args.all:
        names = available_experiments()
    elif args.fig:
        names = list(dict.fromkeys(args.fig))     # keep order, dedupe
    else:
        print("error: pass --fig <name> (repeatable) or --all",
              file=sys.stderr)
        return 2
    try:
        exps = [get_experiment(n) for n in names]
    except KeyError as e:
        print(f"error: {e.args[0]}", file=sys.stderr)
        return 2
    if args.max_steps is not None:
        committed = os.path.realpath("results")
        targets = (os.path.realpath(args.out), os.path.realpath(args.artifacts))
        if any(t == committed or t.startswith(committed + os.sep)
               for t in targets):
            print("error: --max-steps truncates histories; the committed "
                  "results/ tree only holds full-length runs — pass "
                  "--out/--artifacts pointing at a scratch directory "
                  "(e.g. --out /tmp/exp-smoke --artifacts /tmp/exp-smoke/exp)",
                  file=sys.stderr)
            return 2
    failures = 0
    for name, exp in zip(names, exps):
        print(f"== {name}: {exp.title}")
        t0 = time.perf_counter()
        try:
            run_experiment(
                exp, args.out, args.artifacts, force=args.force,
                max_steps=args.max_steps, cells=args.cell or None,
                seeds=args.seed or None, progress_every=args.progress_every)
        except Exception as e:  # keep the sweep robust across experiments
            if len(names) == 1:
                raise
            print(f"  {name} FAILED: {type(e).__name__}: {e}",
                  file=sys.stderr)
            failures += 1
            continue
        finally:
            if "xl" in exp.tags:
                # XL problems pin ~GBs in build_problem's memo; evict so the
                # remaining (small, shared) figure problems rebuild cheaply
                build_problem.cache_clear()
        print(f"== {name} done in {time.perf_counter() - t0:.1f}s")
    return 1 if failures else 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.exp",
                                 description=__doc__)
    sub = ap.add_subparsers(dest="cmd", required=True)
    sub.add_parser("list", help="list registered experiments")
    rp = sub.add_parser("run", help="run experiments, write artifacts + CSVs")
    rp.add_argument("--fig", action="append", default=[],
                    help="experiment name (repeatable)")
    rp.add_argument("--all", action="store_true",
                    help="run every registered experiment (incl. fig1-xl)")
    rp.add_argument("--cell", action="append", default=[],
                    help="restrict to named cells (repeatable)")
    rp.add_argument("--seed", action="append", type=int, default=[],
                    help="override sweep seeds (repeatable)")
    rp.add_argument("--out", default="results",
                    help="figure CSV directory (default: results)")
    rp.add_argument("--artifacts", default="results/exp",
                    help="per-cell JSON directory (default: results/exp)")
    rp.add_argument("--force", action="store_true",
                    help="re-run cells even when a fresh artifact exists")
    rp.add_argument("--max-steps", type=int, default=None,
                    help="clamp every cell's round budget (smoke runs)")
    rp.add_argument("--progress-every", type=int, default=None,
                    help="stream (round, gap, Mbits) every N rounds from "
                         "inside the scan (BL methods)")
    args = ap.parse_args(argv)
    return _cmd_list(args) if args.cmd == "list" else _cmd_run(args)


if __name__ == "__main__":
    sys.exit(main())
