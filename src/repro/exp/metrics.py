"""Headline metrics derived from `History` streams.

One shared implementation of the paper's headline quantity — communicated
Mbits per node to reach a target optimality gap (the x-axis of Fig. 1–6) —
used by both the experiment engine (`repro.exp.engine`) and the benchmark
harness (`benchmarks/run.py`).  The old benchmark-local helper returned
``inf`` silently when a run never reached the tolerance, which made
"diverged" indistinguishable from "slow" in the JSON records; `BitsToTol`
carries the reached/not-reached flag explicitly.
"""
from __future__ import annotations

from typing import NamedTuple

import numpy as np


class BitsToTol(NamedTuple):
    """Mbits/node to reach a gap tolerance, plus whether it was reached.

    ``mbits`` is ``inf`` when the trajectory never dips below ``tol`` —
    consumers must branch on ``reached`` (a record with ``reached=False``
    may be a divergent run OR simply one that was stopped early)."""

    mbits: float
    reached: bool


def bits_to_tol(hist, tol: float = 1e-6) -> BitsToTol:
    """First cumulative uplink cost (Mbits/node) at which ``hist.gaps``
    drops below ``tol``.

    Args:
      hist: a `repro.core.bl.History` (any object with ``gaps`` and
        ``up_bits`` sequences of equal length).
      tol: target optimality gap.

    Returns:
      `BitsToTol` — ``(mbits, reached)``; ``mbits == inf`` iff not reached.
    """
    g = np.asarray(hist.gaps, dtype=np.float64)
    up = np.asarray(hist.up_bits, dtype=np.float64)
    hit = g < tol
    if not hit.any():
        return BitsToTol(float("inf"), False)
    return BitsToTol(float(up[int(np.argmax(hit))]) / 1e6, True)


def best_gap_stream(gaps) -> np.ndarray:
    """Running best (monotone non-increasing) gap: cummin over rounds."""
    return np.minimum.accumulate(np.asarray(gaps, dtype=np.float64))
