"""The sweep engine: execute registered experiments, cell by cell.

`build_problem` materializes a `ProblemSpec` once (clients, x0, reference
optimum x*, memoized basis fleets); `run_cell` dispatches one `MethodCell`
to the public method entry points (`repro.core.bl`, `repro.core.baselines`)
— every fast-path cell therefore runs on the unified jitted round engine
(`repro.core.rounds`), on whichever aggregation backend the cell declares
(``backend="fast+sharded"`` shards clients over the mesh).  `run_experiment`
sweeps (cell × seed), skips cells whose artifact already exists with a
matching config digest (resume), and regenerates the figure CSVs from the
artifacts — so CSVs are always consistent with the JSON records.

Long cells can stream progress mid-sweep: ``progress_every=N`` attaches a
`repro.core.rounds.StreamHook` that reports (round, gap, Mbits/node) at
chunk boundaries for the BL methods — on the single-device AND sharded
backends alike (the driver chunks the scan to the hook's cadence).
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import baselines, bl, client_batch, compressors, glm
from repro.core.basis import make_bases
from repro.core.rounds import StreamHook

from . import artifacts
from .registry import (
    CompressorCfg,
    DNNProblemSpec,
    Experiment,
    MethodCell,
    ProblemSpec,
)


def build_compressor(cfg: CompressorCfg, d: int) -> compressors.Compressor:
    """Materialize a declarative `CompressorCfg` for a d-dimensional problem
    (the composed Rank-R codecs derive their dithering levels from d)."""
    k = cfg.kind
    if k == "identity":
        return compressors.Identity()
    if k == "topk":
        return compressors.TopK(k=cfg.k, symmetrize=cfg.symmetrize)
    if k == "randk":
        return compressors.RandK(k=cfg.k)
    if k == "rankr":
        return compressors.RankR(r=cfg.r)
    if k == "dither":
        return compressors.RandomDithering(s=cfg.s)
    if k == "natural":
        return compressors.NaturalCompression()
    if k == "rtopk":
        return compressors.rtopk(cfg.k)
    if k == "ntopk":
        return compressors.ntopk(cfg.k)
    if k == "rrankr":
        return compressors.rrankr(cfg.r, d)
    if k == "nrankr":
        return compressors.nrankr(cfg.r)
    if k == "bernoulli":
        return compressors.BernoulliLazy(p=cfg.p)
    raise ValueError(f"unknown compressor kind {cfg.kind!r}")


@dataclasses.dataclass
class Problem:
    """A built problem regime: data, initial iterate, reference optimum."""

    spec: ProblemSpec
    clients: list
    x0: jax.Array
    x_star: jax.Array
    _bases: Dict[str, list] = dataclasses.field(default_factory=dict)

    @property
    def d(self) -> int:
        return int(self.x0.shape[0])

    @property
    def n(self) -> int:
        return len(self.clients)

    def bases(self, name: str) -> list:
        """Per-client basis fleet for a `repro.core.basis` registry name,
        built once per problem and memoized across cells."""
        if name not in self._bases:
            self._bases[name] = make_bases(name, self.clients, x0=self.x0)
        return self._bases[name]


@dataclasses.dataclass
class DNNProblem:
    """A built `DNNProblemSpec`: client-stacked data, student init, and the
    (stable, memoized) loss/eval closures — stable function identities keep
    the engine's jit cache warm across cells and seeds."""

    spec: DNNProblemSpec
    batch: object                    # client_batch.TreeBatch
    params0: object                  # parameter pytree
    loss_fn: object
    eval_fn: object

    @property
    def n(self) -> int:
        return self.batch.n


@dataclasses.dataclass
class StreamProblem:
    """A built kind="synthetic_stream" regime: the fleet lives in a
    host-resident `client_batch.ClientStore` (never stacked on device) and
    the reference optimum comes from the slab-wise host Newton solver —
    the problem form the cohort-streaming engine (`repro.core.cohort`)
    consumes.  ≥100k clients fit where a stacked `Problem` would not."""

    spec: ProblemSpec
    store: object                    # client_batch.ClientStore
    x0: jax.Array
    x_star: np.ndarray

    @property
    def d(self) -> int:
        return int(self.x0.shape[0])

    @property
    def n(self) -> int:
        return self.store.n


@functools.lru_cache(maxsize=None)
def build_problem(spec: ProblemSpec) -> Problem:
    """Materialize a `ProblemSpec` or `DNNProblemSpec` (memoized — figures
    share regimes)."""
    if isinstance(spec, DNNProblemSpec):
        from repro.fed import bldnn

        batch, params0 = bldnn.make_synthetic_classification(
            seed=spec.seed, n_clients=spec.n_clients, m=spec.m, d=spec.d,
            classes=spec.classes, width=spec.width, r=spec.r,
            heterogeneity=spec.heterogeneity, label_noise=spec.label_noise)
        return DNNProblem(spec=spec, batch=batch, params0=params0,
                          loss_fn=bldnn.make_loss_fn(spec.classes),
                          eval_fn=bldnn.make_eval_fn())
    if spec.kind == "synthetic_stream":
        from repro.core import cohort

        store = client_batch.synthetic_store(
            spec.seed, spec.n_clients, spec.m, spec.d, lam=spec.lam)
        x0 = jnp.zeros(spec.d, jnp.float64)
        x_star = cohort.store_newton_solve(store, np.zeros(spec.d),
                                           iters=spec.newton_iters)
        return StreamProblem(spec=spec, store=store, x0=x0, x_star=x_star)
    if spec.kind == "table2":
        clients = glm.make_table2(spec.name, seed=spec.seed, lam=spec.lam)
    elif spec.kind == "synthetic":
        clients = glm.make_synthetic(
            seed=spec.seed, n_clients=spec.n_clients, m=spec.m, d=spec.d,
            r=spec.r, lam=spec.lam)
    else:
        raise ValueError(f"unknown problem kind {spec.kind!r}")
    d = int(clients[0].A.shape[1])
    x0 = jnp.zeros(d, jnp.float64)
    if spec.solver == "fused":
        batch = client_batch.from_clients(clients)
        x_star = client_batch.newton_solve_fused(batch, x0, spec.newton_iters)
    elif spec.solver == "loop":
        x_star = glm.newton_solve(clients, x0, spec.newton_iters)
    else:
        raise ValueError(f"unknown solver {spec.solver!r}")
    return Problem(spec=spec, clients=clients, x0=x0, x_star=x_star)


#: methods that accept a PRNG seed (the sweep seed is injected only here;
#: newton/gd/local_gd are deterministic and take none)
_SEEDED_METHODS = frozenset(
    {"bl1", "bl2", "bl3", "fednl_bag", "nl1", "diana", "adiana", "dore",
     "bldnn"})


def _comp(cfg: Optional[CompressorCfg], d: int, what: str):
    if cfg is None:
        raise ValueError(f"cell needs a {what} compressor config")
    return build_compressor(cfg, d)


def build_stream_spec(cell: MethodCell, d: int, n: int, lam: float,
                      params: dict):
    """`MethodSpec` + basis kind for a store-backed streaming cell, built
    directly from the cell config (the stacked setups in
    `repro.core.batched` start from per-client lists, which a streaming
    fleet never materializes).  Field values mirror bl2_setup / bl3_setup /
    fednl_bag_setup exactly — same defaults, same ledger bit accounting.
    Pops the engine-level params (cohort, rounds_per_cohort, seed) from
    ``params`` and returns ``(spec, basis, cohort, rounds_per_cohort,
    seed)``."""
    from repro.core import cohort, specs

    m = cell.method
    cohort_size = int(params.pop("cohort", n))
    rpc = int(params.pop("rounds_per_cohort", 1))
    seed = int(params.pop("seed", 0))
    hc = _comp(cell.hess_comp, d, "hessian")
    if m == "bl2":
        mc = _comp(cell.model_comp, d, "model")
        bb = cohort.standard_basisb(d, n)
        init_exact = bool(params.pop("init_exact_hessian", True))
        spec = specs.BL2Spec(
            hess_comp=hc, model_comp=mc,
            alpha=params.pop("alpha", 1.0), eta=params.pop("eta", 1.0),
            p=params.pop("p", 1.0), tau=int(params.pop("tau", n)),
            init_exact=init_exact,
            init_hess_bits=bb.init_coeff_bits_mean(init_exact),
            basis_bits=bb.transmission_bits_mean(), block=False)
        basis = "standard"
    elif m == "bl3":
        mc = _comp(cell.model_comp, d, "model")
        spec = specs.BL3Spec(
            hess_comp=hc, model_comp=mc,
            alpha=params.pop("alpha", 1.0), eta=params.pop("eta", 1.0),
            p=params.pop("p", 1.0), tau=int(params.pop("tau", n)),
            c=params.pop("c", 1e-8), option=int(params.pop("option", 2)))
        basis = None
    elif m == "fednl_bag":
        bb = cohort.standard_basisb(d, n)
        init_exact = bool(params.pop("init_exact_hessian", True))
        q = params.pop("q", 0.5)
        eta = params.pop("eta", None)
        mu = params.pop("mu", None)
        spec = specs.FedNLBAGSpec(
            hess_comp=hc, alpha=params.pop("alpha", 1.0), q=q,
            eta=q if eta is None else eta, mu=lam if mu is None else mu,
            init_exact=init_exact,
            init_hess_bits=bb.init_coeff_bits_mean(init_exact),
            basis_bits=bb.transmission_bits_mean(), block=False)
        basis = "standard"
    else:
        raise ValueError(
            f"method {m!r} has no cohort-streaming path (bl2, bl3 and "
            "fednl_bag stream — see MethodSpec.supports_cohort)")
    if params:
        raise ValueError(
            f"unused streaming cell params {sorted(params)} for {m!r}")
    return spec, basis, cohort_size, rpc, seed


def _run_stream_cell(cell: MethodCell, prob: StreamProblem, steps: int,
                     params: dict, backend: str) -> bl.History:
    from repro.core import batched, cohort

    spec, basis, csize, rpc, seed = build_stream_spec(
        cell, prob.d, prob.n, prob.store.lam, params)
    eng = cohort.CohortEngine(
        spec, prob.store, prob.x0, cohort=csize, rounds_per_cohort=rpc,
        root_key=jax.random.PRNGKey(seed), basis=basis,
        sharded=backend.endswith("+sharded"))
    try:
        eval_x, leds, _events = eng.run_chunk(0, steps)
    finally:
        eng.close()
    # fleet gaps evaluate slab-wise on the host — the device never holds
    # more than the cohort, so the stacked eval program has no input here
    xs = np.asarray(eval_x)
    f_star = cohort.store_loss(prob.store, prob.x_star)
    gaps = np.array([cohort.store_loss(prob.store, xs[t]) - f_star
                     for t in range(xs.shape[0])])
    return batched._history({"gap": gaps}, leds)


def run_cell(exp: Experiment, cell: MethodCell, prob: Problem, *,
             steps: Optional[int] = None, seed: Optional[int] = None,
             backend: Optional[str] = None,
             stream: Optional[StreamHook] = None) -> bl.History:
    """Run one cell and return its `History`.

    Args:
      exp, cell: the registered experiment and one of its cells.
      prob: the built problem (`build_problem(exp.problem)`).
      steps: override the cell's round budget — shorter OR longer (the
        benchmark wrappers extend runs; `run_experiment` clamps via its
        own ``max_steps``).
      seed: sweep seed; a ``seed`` in ``cell.params`` takes precedence
        (cells that pin a seed reproduce one specific committed curve).
      backend: override the cell's engine backend.
      stream: optional mid-sweep progress hook (BL methods, any fast
        backend — see `repro.core.rounds.StreamHook`).
    """
    m = cell.method
    steps = cell.steps if steps is None else steps
    backend = cell.backend if backend is None else backend
    params = cell.params_dict()
    if seed is not None and m in _SEEDED_METHODS:
        params.setdefault("seed", seed)

    if isinstance(prob, StreamProblem):
        if backend == "auto":
            backend = "cohort"
        if backend not in ("cohort", "cohort+sharded"):
            raise ValueError(
                f"cell {cell.name!r}: a synthetic_stream problem runs on "
                f"the cohort backends, got backend={backend!r}")
        return _run_stream_cell(cell, prob, steps, params, backend)

    if m == "bldnn":
        from repro.fed import bldnn

        if not isinstance(prob, DNNProblem):
            raise ValueError(
                f"cell {cell.name!r} needs a DNNProblemSpec problem")
        if cell.hess_comp is None:
            raise ValueError("bldnn cells configure the (gradient+Fisher) "
                             "compressor via hess_comp")
        run_seed = params.pop("seed", 0)
        from repro.core.basis import is_pytree_basis

        if cell.basis is not None and not is_pytree_basis(cell.basis):
            raise ValueError(
                f"cell {cell.name!r}: bldnn needs a pytree basis "
                f"(per_layer_svd / dct_tree / hadamard_tree), got "
                f"{cell.basis!r}")
        cfg = bldnn.BLDNNConfig(compressor=cell.hess_comp.kind,
                                use_basis=cell.basis is not None,
                                basis_kind=cell.basis or "per_layer_svd",
                                **params)
        # "auto" on a DNN cell means the engine's single-device fast path
        eng_backend = "fast" if backend == "auto" else backend
        return bldnn.run_bldnn(prob.loss_fn, prob.eval_fn, prob.params0,
                               prob.batch, steps, cfg, seed=run_seed,
                               backend=eng_backend, stream=stream)

    n, d = prob.n, prob.d
    clients, x0, xs = prob.clients, prob.x0, prob.x_star

    if m in ("bl1", "bl2", "bl3", "fednl_bag"):
        hc = [_comp(cell.hess_comp, d, "hessian")] * n
        if m == "bl1":
            mc = _comp(cell.model_comp, d, "model")
            return bl.bl1(clients, prob.bases(cell.basis), hc, mc, x0, xs,
                          steps, backend=backend, stream=stream, **params)
        if m == "bl2":
            mc = [_comp(cell.model_comp, d, "model")] * n
            return bl.bl2(clients, prob.bases(cell.basis), hc, mc, x0, xs,
                          steps, backend=backend, stream=stream, **params)
        if m == "bl3":
            mc = [_comp(cell.model_comp, d, "model")] * n
            return bl.bl3(clients, hc, mc, x0, xs, steps, backend=backend,
                          stream=stream, **params)
        return baselines.fednl_bag(clients, prob.bases(cell.basis), hc, x0,
                                   xs, steps, backend=backend, **params)
    if m == "newton":
        bases = prob.bases(cell.basis) if cell.basis else None
        return baselines.newton(clients, x0, xs, steps, bases=bases,
                                backend=backend, **params)
    if m == "nl1":
        return baselines.nl1(clients, x0, xs, steps, **params)
    if m == "gd":
        return baselines.gd(clients, x0, xs, steps, backend=backend, **params)
    if m == "diana":
        comp = _comp(cell.hess_comp, d, "gradient")
        return baselines.diana(clients, x0, xs, steps, comp,
                               comp.omega_for(d), backend=backend, **params)
    if m == "adiana":
        comp = _comp(cell.hess_comp, d, "gradient")
        return baselines.adiana(clients, x0, xs, steps, comp,
                                comp.omega_for(d), **params)
    if m == "local_gd":
        return baselines.local_gd(clients, x0, xs, steps, **params)
    if m == "dore":
        up = _comp(cell.hess_comp, d, "uplink")
        down = _comp(cell.model_comp, d, "downlink")
        return baselines.dore_like(clients, x0, xs, steps, up, down, **params)
    raise ValueError(f"unknown method {m!r} in cell {cell.name!r}")


def _progress_hook(exp: Experiment, cell: MethodCell, prob: Problem,
                   every: int, log) -> StreamHook:
    # The hook body runs inside a jax.debug.callback while the engine's
    # scan is still executing — re-entering JAX from a host callback can
    # deadlock, so the gap is evaluated in pure numpy on host copies of
    # the fleet (jax.debug.callback delivers eval_x/ledger as numpy).
    A = np.stack([np.asarray(c.A) for c in prob.clients])   # (n, m, d)
    b = np.stack([np.asarray(c.b) for c in prob.clients])   # (n, m)
    lam = prob.clients[0].lam
    x_star = np.asarray(prob.x_star)

    def loss(x):
        z = (A @ x) * b
        return float(np.mean(np.logaddexp(0.0, -z))
                     + 0.5 * lam * np.dot(x, x))

    f_star = loss(x_star)

    def report(t, eval_x, ledger):
        gap = loss(np.asarray(eval_x)) - f_star
        mb = float(np.asarray(ledger.uplink)) / 1e6
        log(f"    [{exp.name}/{cell.name}] round {t}: gap={gap:.3e} "
            f"up={mb:.3f} Mbits/node")

    return StreamHook(every=every, callback=report)


def run_experiment(exp: Experiment, out_dir: str, artifacts_dir: str, *,
                   force: bool = False, max_steps: Optional[int] = None,
                   cells: Optional[Sequence[str]] = None,
                   seeds: Optional[Sequence[int]] = None,
                   progress_every: Optional[int] = None,
                   log=print) -> List[dict]:
    """Sweep an experiment: run (cell × seed), write artifacts + CSVs.

    Cells whose artifact JSON already exists with a matching config digest
    are *skipped* (status "cached") unless ``force`` — re-running a partial
    sweep is idempotent and completes only the missing cells.  Figure CSVs
    are regenerated from the artifacts every time (cheap, keeps them
    consistent).  Returns one summary dict per (cell, seed).
    """
    summaries = []
    sweep_seeds = tuple(seeds) if seeds is not None else exp.seeds
    run_cells = (exp.cells if cells is None
                 else tuple(exp.cell(c) for c in cells))
    prob = None
    for cell in run_cells:
        eff_steps = (cell.steps if max_steps is None
                     else min(cell.steps, max_steps))
        for seed in sweep_seeds:
            config = artifacts.cell_config(exp, cell, seed, eff_steps)
            digest = artifacts.config_digest(config)
            path = artifacts.artifact_path(artifacts_dir, exp.name,
                                           cell.name, seed)
            record = None if force else artifacts.load_json(path)
            if record is not None and record.get("config_digest") == digest:
                status = "cached"
            else:
                if prob is None:
                    prob = build_problem(exp.problem)
                stream = None
                if progress_every and cell.method in ("bl1", "bl2", "bl3"):
                    # chunk-boundary emission works on every fast backend,
                    # sharded included (see rounds.StreamHook)
                    stream = _progress_hook(exp, cell, prob,
                                            progress_every, log)
                t0 = time.perf_counter()
                hist = run_cell(exp, cell, prob, steps=eff_steps, seed=seed,
                                stream=stream)
                jax.effects_barrier()   # drain any stream-hook callbacks
                runtime = time.perf_counter() - t0
                record = artifacts.cell_record(exp, cell, seed, eff_steps,
                                               hist, runtime_s=runtime)
                artifacts.write_json(path, record)
                status = "ran"
            csv_file = None
            if seed == sweep_seeds[0]:
                csv_file = artifacts.write_fig_csv(out_dir, record)
            b2t = record["bits_to_tol"]
            summaries.append({
                "experiment": exp.name, "cell": cell.name, "seed": seed,
                "status": status, "steps": eff_steps,
                "mbits_to_tol": b2t["mbits_per_node"],
                "reached": b2t["reached"],
                "final_gap": record["history"]["gaps"][-1],
                "runtime_s": record.get("runtime_s"),
                "artifact": path, "csv": csv_file,
            })
            reach = (f"{b2t['mbits_per_node']:.3f} Mbits to {exp.tol:g}"
                     if b2t["reached"] else
                     f"tol not reached (gap {record['history']['gaps'][-1]:.2e})")
            log(f"  {exp.name}/{cell.name} seed={seed} [{status}] "
                f"{eff_steps} rounds — {reach}")
    return summaries
