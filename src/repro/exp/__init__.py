"""Declarative experiment subsystem: every paper figure as a registered,
resumable, schema-versioned sweep over the unified round engine.

    from repro.exp import get_experiment, run_experiment, build_problem

    exp = get_experiment("fig1r1")
    run_experiment(exp, "results", "results/exp")

or from the shell: ``python -m repro.exp run --fig fig1r1`` / ``--all``.
See `repro.exp.registry` for the experiment catalogue,
`repro.exp.artifacts` for the artifact schema, and docs/REPRODUCING.md
for the figure-by-figure reproduction table.
"""
from .artifacts import CSV_COLUMNS, SCHEMA, SCHEMA_VERSION
from .engine import Problem, build_compressor, build_problem, run_cell, run_experiment
from .metrics import BitsToTol, best_gap_stream, bits_to_tol
from .registry import (
    CompressorCfg,
    Experiment,
    MethodCell,
    ProblemSpec,
    available_experiments,
    get_experiment,
    register_experiment,
)

__all__ = [
    "BitsToTol",
    "CSV_COLUMNS",
    "CompressorCfg",
    "Experiment",
    "MethodCell",
    "Problem",
    "ProblemSpec",
    "SCHEMA",
    "SCHEMA_VERSION",
    "available_experiments",
    "best_gap_stream",
    "bits_to_tol",
    "build_compressor",
    "build_problem",
    "get_experiment",
    "register_experiment",
    "run_cell",
    "run_experiment",
]
