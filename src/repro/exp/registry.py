"""Declarative experiment registry: every paper figure as frozen data.

An `Experiment` is a frozen dataclass naming a problem regime
(`ProblemSpec` — a `glm.make_synthetic` / `glm.TABLE2` configuration plus
the reference-optimum solver), a tuple of `MethodCell`s (method × basis ×
compressor grid × hyperparameters × backend), seeds and a gap tolerance.
The sweep engine (`repro.exp.engine`) executes cells through the public
method entry points (which all run on the unified jitted round engine,
`repro.core.rounds`) and the artifact layer (`repro.exp.artifacts`) writes
one schema-versioned JSON per (cell, seed) — CommLedger per-leg bits
included — plus the figure CSVs under ``results/``.

Registered experiments (``available_experiments()``):

  * ``fig1r1`` … ``fig6`` — the paper's figures (§6 + Appendix A), cell
    configurations and step counts matching the committed ``results/``
    curves (the `--fast` regime of the retired figure script — Table 2's
    LibSVM sizes are scaled down, see docs/REPRODUCING.md).
  * ``fig1-xl``  — a beyond-paper scaled scenario: 512 clients at d=1200
    through the client-sharded shard_map backend with §2.3 block-mode
    coefficient state — a regime the original op-by-op code cannot touch.
  * ``fig1-xxl`` — the cohort-streaming regime: 131072 clients in a
    host-resident `ClientStore`, 512-client cohorts per round through
    `repro.core.cohort.CohortEngine` (per-round cost flat in fleet size);
    ``cohort-smoke`` is its minutes-scale test scenario.
  * ``fig1-bag`` — FedNL + Bernoulli-lazy gradient aggregation
    (`specs.FedNLBAGSpec`, after arXiv 2206.03588) vs FedNL, giving the
    BAG follow-up a reproducible experiment path.
  * ``fig-dnn``  — the BL-DNN deep-network workload (`DNNProblemSpec` +
    method ``bldnn``) on the pytree round engine: bits-to-accuracy for
    the per-layer SVD basis vs uncompressed FedAvg vs no-basis Top-K vs
    stochastic RTop-K.

New experiments register with ``@register_experiment`` and are picked up
automatically by the CLI (``python -m repro.exp``), the registry
completeness test (tests/test_exp.py) and the benchmark wrappers.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

# ==========================================================================
# Declarative pieces
# ==========================================================================


@dataclasses.dataclass(frozen=True)
class ProblemSpec:
    """A problem regime: which federated GLM instance a figure runs on.

    kind="synthetic" draws `glm.make_synthetic(seed, n_clients, m, d, r,
    lam)`; kind="table2" uses the named `glm.TABLE2` regime (scaled-down
    LibSVM shapes).  ``solver`` picks the reference-optimum computation:
    "loop" is the paper-faithful `glm.newton_solve` (stacks per-client
    d×d Hessians — fine at paper scale), "fused" is
    `client_batch.newton_solve_fused` (one Gram contraction, no (n, d, d)
    intermediate — required at fig1-xl scale)."""

    kind: str = "synthetic"          # "synthetic" | "table2" |
    #                                  "synthetic_stream" (host-resident
    #                                  ClientStore fleet for the cohort-
    #                                  streaming engine; solver is the
    #                                  slab-wise host Newton)
    name: Optional[str] = None       # TABLE2 regime name for kind="table2"
    seed: int = 0
    n_clients: int = 10
    m: int = 60
    d: int = 120
    r: int = 24
    lam: float = 1e-3
    newton_iters: int = 20
    solver: str = "loop"             # "loop" | "fused"


@dataclasses.dataclass(frozen=True)
class DNNProblemSpec:
    """Problem regime for the BL-DNN deep-network workload (`fig-dnn`).

    A teacher-labelled synthetic classification fleet whose inputs live in
    a shared r-dimensional subspace (the §2.3 low-rank regime carried to a
    DNN) plus a near-teacher student initialization — built by
    `repro.fed.bldnn.make_synthetic_classification`.  A separate dataclass
    from `ProblemSpec` on purpose: GLM fields (lam, newton_iters, solver)
    don't apply, and existing artifact config digests stay untouched."""

    kind: str = "dnn_synthetic"
    seed: int = 0
    n_clients: int = 8
    m: int = 64                      # samples per client
    d: int = 96                      # input features
    classes: int = 4
    width: int = 32                  # MLP hidden width
    r: int = 8                       # intrinsic data rank (§2.3 analogue)
    heterogeneity: float = 0.5
    label_noise: float = 0.05


@dataclasses.dataclass(frozen=True)
class CompressorCfg:
    """Declarative compressor config; built per-problem by
    `repro.exp.engine.build_compressor` (some kinds derive parameters from
    the problem dimension d, e.g. rrankr's dithering levels)."""

    kind: str                        # identity|topk|randk|rankr|dither|
    #                                  natural|rtopk|ntopk|rrankr|nrankr|
    #                                  bernoulli
    k: int = 0                       # topk/randk/rtopk/ntopk
    r: int = 0                       # rankr/rrankr/nrankr
    s: int = 0                       # dither levels
    p: float = 0.0                   # bernoulli send probability
    symmetrize: bool = False         # topk on the triangular half (§A.2)


@dataclasses.dataclass(frozen=True)
class MethodCell:
    """One curve of a figure: a method, its compressors/basis and params.

    ``name`` is the curve label and the CSV suffix
    (``results/<experiment>_<name>.csv``).  ``params`` is a frozen tuple of
    (key, value) pairs forwarded to the method entry point (alpha, eta, p,
    tau, q, seed, lr, local_steps, k, option, ...).  ``basis`` is a
    `repro.core.basis` registry name (None for basis-free methods).
    """

    name: str
    method: str                      # bl1|bl2|bl3|newton|nl1|gd|diana|
    #                                  adiana|local_gd|dore|fednl_bag
    steps: int
    basis: Optional[str] = None
    hess_comp: Optional[CompressorCfg] = None
    model_comp: Optional[CompressorCfg] = None
    params: Tuple[Tuple[str, Any], ...] = ()
    backend: str = "auto"

    def params_dict(self) -> Dict[str, Any]:
        return dict(self.params)


@dataclasses.dataclass(frozen=True)
class Experiment:
    """A registered, reproducible figure: problem + cells + seeds + tol."""

    name: str
    figure: str                      # "fig1".."fig6" | "extra"
    title: str
    paper_ref: str                   # e.g. "§6 Fig. 1 row 1"
    problem: ProblemSpec
    cells: Tuple[MethodCell, ...]
    seeds: Tuple[int, ...] = (0,)
    tol: float = 1e-6
    tags: Tuple[str, ...] = ()       # e.g. ("xl",) for scaled scenarios

    def cell(self, name: str) -> MethodCell:
        for c in self.cells:
            if c.name == name:
                return c
        raise KeyError(f"{self.name} has no cell {name!r}; "
                       f"cells: {[c.name for c in self.cells]}")


# ==========================================================================
# Registry
# ==========================================================================
EXPERIMENT_REGISTRY: Dict[str, Experiment] = {}


def register_experiment(exp: Experiment) -> Experiment:
    if exp.name in EXPERIMENT_REGISTRY:
        raise ValueError(f"duplicate experiment {exp.name!r}")
    EXPERIMENT_REGISTRY[exp.name] = exp
    return exp


def available_experiments() -> List[str]:
    return sorted(EXPERIMENT_REGISTRY)


def get_experiment(name: str) -> Experiment:
    if name not in EXPERIMENT_REGISTRY:
        raise KeyError(f"unknown experiment {name!r}; "
                       f"registered: {available_experiments()}")
    return EXPERIMENT_REGISTRY[name]


# ==========================================================================
# The paper's figures (§6 + Appendix A)
# ==========================================================================
# All paper figures share one synthetic Table-2-style instance (n=10, m=60,
# d=120, intrinsic rank r=24 — scaled down from the LibSVM regimes so a CPU
# run finishes in minutes; docs/REPRODUCING.md records the scaling).  The
# data basis of this instance has rank exactly r=24, so the Top-K budgets
# below (k=24 = r, k=12 = r/2) are written as literals.
_PROBLEM = ProblemSpec()
_D, _R, _N = _PROBLEM.d, _PROBLEM.r, _PROBLEM.n_clients

_IDENT = CompressorCfg(kind="identity")
_TOPK_R = CompressorCfg(kind="topk", k=_R)
_S = 12       # figure step budget (matches the committed results/ curves)
_SL = 60      # first-order methods need more, cheaper rounds


register_experiment(Experiment(
    name="fig1r1",
    figure="fig1",
    title="Second-order comparison: BL1 (data basis) vs FedNL vs NL1 vs Newton",
    paper_ref="§6 Fig. 1 row 1",
    problem=_PROBLEM,
    cells=(
        MethodCell("BL1", "bl1", _S, basis="data_outer",
                   hess_comp=_TOPK_R, model_comp=_IDENT),
        MethodCell("FedNL", "bl1", _S, basis="standard",
                   hess_comp=CompressorCfg(kind="rankr", r=1),
                   model_comp=_IDENT),
        MethodCell("NL1", "nl1", _S),
        MethodCell("Newton", "newton", _S),
    ),
))

register_experiment(Experiment(
    name="fig1r2",
    figure="fig1",
    title="BL1 vs first-order methods (GD / DIANA / ADIANA / Local-GD)",
    paper_ref="§6 Fig. 1 row 2",
    problem=_PROBLEM,
    cells=(
        MethodCell("BL1", "bl1", _S, basis="data_outer",
                   hess_comp=_TOPK_R, model_comp=_IDENT),
        MethodCell("GD", "gd", _SL),
        # the first-order baselines quantize with s = ⌊√d⌋ dithering levels
        MethodCell("DIANA", "diana", _SL,
                   hess_comp=CompressorCfg(kind="dither", s=10)),
        MethodCell("ADIANA", "adiana", _SL,
                   hess_comp=CompressorCfg(kind="dither", s=10)),
        MethodCell("LocalGD", "local_gd", _SL // 4),
    ),
))

register_experiment(Experiment(
    name="fig1r3",
    figure="fig1",
    title="Composed Rank-R compressors in BL2 (standard basis ⇒ FedNL-PP)",
    paper_ref="§6 Fig. 1 row 3",
    problem=_PROBLEM,
    cells=tuple(
        MethodCell(nm, "bl2", _S, basis="standard",
                   hess_comp=cfg,
                   model_comp=CompressorCfg(kind="topk", k=_D // 10),
                   params=(("p", 0.1),))
        for nm, cfg in (
            ("RankR", CompressorCfg(kind="rankr", r=1)),
            ("RRankR", CompressorCfg(kind="rrankr", r=1)),
            ("NRankR", CompressorCfg(kind="nrankr", r=1)),
        )
    ),
))

register_experiment(Experiment(
    name="fig2",
    figure="fig2",
    title="Newton in the standard vs the data-induced basis (bits per iter)",
    paper_ref="§A.4 Fig. 2",
    problem=_PROBLEM,
    cells=(
        MethodCell("newton_std", "newton", 10),
        MethodCell("newton_basis", "newton", 10, basis="data_outer"),
    ),
))

register_experiment(Experiment(
    name="fig3",
    figure="fig3",
    title="Composed Top-K compressors in BL2 (data basis)",
    paper_ref="§A.5 Fig. 3",
    problem=_PROBLEM,
    cells=tuple(
        MethodCell(nm, "bl2", _S, basis="data_outer",
                   hess_comp=cfg,
                   model_comp=CompressorCfg(kind="topk", k=_R // 2),
                   params=(("p", _R / (2 * _D)),))
        for nm, cfg in (
            ("TopK", _TOPK_R),
            ("RTopK", CompressorCfg(kind="rtopk", k=_R)),
            ("NTopK", CompressorCfg(kind="ntopk", k=_R)),
        )
    ),
))

register_experiment(Experiment(
    name="fig4",
    figure="fig4",
    title="Partial participation: BL2 (data basis) and BL3 at τ ∈ {n, n/2, n/4}",
    paper_ref="§A.6 Fig. 4",
    problem=_PROBLEM,
    cells=tuple(
        MethodCell(f"BL2_tau_{tag}", "bl2", 2 * _S, basis="data_outer",
                   hess_comp=_TOPK_R, model_comp=_IDENT,
                   params=(("tau", tau),))
        for tag, tau in (("full", _N), ("half", _N // 2), ("quarter", _N // 4))
    ) + tuple(
        MethodCell(f"BL3_tau_{tag}", "bl3", 2 * _S,
                   hess_comp=CompressorCfg(kind="topk", k=_D),
                   model_comp=_IDENT,
                   params=(("tau", tau),))
        for tag, tau in (("full", _N), ("half", _N // 2), ("quarter", _N // 4))
    ),
))

register_experiment(Experiment(
    name="fig5",
    figure="fig5",
    title="Bidirectional compression: BL1/BL2/BL3-BC vs FedNL-BC vs DORE",
    paper_ref="§A.7 Fig. 5",
    problem=_PROBLEM,
    cells=(
        MethodCell("FedNL-BC", "bl1", _S, basis="standard",
                   hess_comp=CompressorCfg(kind="topk", k=_D * _D // 2,
                                           symmetrize=True),
                   model_comp=CompressorCfg(kind="topk", k=_D // 2)),
        # K=r (not the paper's K=r/2) and p=1/2: the paper's most aggressive
        # A.7 setting diverges on this harder synthetic instance
        MethodCell("BL1-BC", "bl1", 2 * _S, basis="data_outer",
                   hess_comp=_TOPK_R, model_comp=_TOPK_R,
                   params=(("p", 0.5), ("seed", 3))),
        MethodCell("BL2-BC", "bl2", 2 * _S, basis="data_outer",
                   hess_comp=_TOPK_R, model_comp=_TOPK_R,
                   params=(("p", 0.5),)),
        MethodCell("BL3-BC", "bl3", _S,
                   hess_comp=CompressorCfg(kind="topk", k=_D // 2),
                   model_comp=CompressorCfg(kind="topk", k=_D // 2),
                   params=(("p", 0.5),)),
        MethodCell("DORE", "dore", _SL,
                   hess_comp=CompressorCfg(kind="topk", k=_D // 2),
                   model_comp=CompressorCfg(kind="topk", k=_D // 2)),
    ),
))

register_experiment(Experiment(
    name="fig6",
    figure="fig6",
    title="BL2 vs BL3 under partial participation + bidirectional compression",
    paper_ref="§A.8 Fig. 6",
    problem=_PROBLEM,
    cells=tuple(
        MethodCell(f"{meth.upper()}_p{p:.2f}", meth, 2 * _S,
                   basis=("standard" if meth == "bl2" else None),
                   hess_comp=CompressorCfg(kind="topk", k=max(1, int(p * _D))),
                   model_comp=CompressorCfg(kind="topk", k=max(1, int(p * _D))),
                   params=(("tau", _N // 2), ("p", p)))
        for p in (1.0, 1 / 3)
        for meth in ("bl2", "bl3")
    ),
))


# ==========================================================================
# Beyond the paper
# ==========================================================================
# fig1-xl: the fig1r1 comparison at a scale the original op-by-op code
# cannot run — 512 clients at d=1200 (≈ 737 MB of stacked client data, a
# 5.9 GB/round reconstruction stream) through the client-sharded shard_map
# backend with §2.3 block-mode (n, r, r) coefficient state and the fused
# low-memory Newton reference solver.
_XL = ProblemSpec(seed=0, n_clients=512, m=32, d=1200, r=32, lam=1e-3,
                  newton_iters=12, solver="fused")

register_experiment(Experiment(
    name="fig1-xl",
    figure="extra",
    title="BL1 at scale: 512 clients, d=1200, sharded engine (beyond paper)",
    paper_ref="engine demonstration (no paper counterpart)",
    problem=_XL,
    cells=(
        MethodCell("BL1", "bl1", 8, basis="data_outer",
                   hess_comp=CompressorCfg(kind="topk", k=_XL.r * _XL.r),
                   model_comp=_IDENT, backend="fast+sharded"),
    ),
    tags=("xl",),
))

# fig1-xxl: the cohort-streaming regime — a fleet two-plus orders of
# magnitude past fig1-xl (131072 clients) whose data/shift state lives in a
# host-resident ClientStore; each round touches only a 512-client cohort
# (`repro.core.cohort.CohortEngine`), so per-round wall time is flat in the
# total fleet size (benchmarks/run.py cohort_stream pins ≤1.15× from n=1k
# to n=100k).  Small per-client shapes on purpose: the scale axis here is
# n, not d — fig1-xl already owns the big-d regime.
_XXL = ProblemSpec(kind="synthetic_stream", seed=0, n_clients=131072, m=8,
                   d=24, r=24, lam=1e-3, newton_iters=12, solver="fused")

register_experiment(Experiment(
    name="fig1-xxl",
    figure="extra",
    title="FedNL-PP at fleet scale: 131072 clients, 512-client cohorts, "
          "streaming engine (beyond paper)",
    paper_ref="engine demonstration (no paper counterpart)",
    problem=_XXL,
    cells=(
        MethodCell("BL2", "bl2", 16, basis="standard",
                   hess_comp=CompressorCfg(kind="topk", k=2 * _XXL.d),
                   model_comp=_IDENT, backend="cohort",
                   params=(("tau", 256), ("cohort", 512),
                           ("rounds_per_cohort", 4))),
        MethodCell("FedNL-BAG", "fednl_bag", 16, basis="standard",
                   hess_comp=CompressorCfg(kind="topk", k=2 * _XXL.d),
                   backend="cohort",
                   params=(("q", 0.5), ("cohort", 512),
                           ("rounds_per_cohort", 4))),
    ),
    tags=("xl", "stream"),
))

# cohort-smoke: a minutes-scale streaming scenario for the fault-tolerance
# and resume tests (tests/test_cohort.py kill-9s a serve of this through
# ckpt@2) and for CI — same engine path as fig1-xxl at a fleet small
# enough to also run stacked for parity.
_COHORT_SMOKE = ProblemSpec(kind="synthetic_stream", seed=3, n_clients=96,
                            m=8, d=8, r=8, lam=1e-3, newton_iters=12,
                            solver="fused")

register_experiment(Experiment(
    name="cohort-smoke",
    figure="extra",
    title="Cohort-streaming smoke: 96 clients, 16-client cohorts",
    paper_ref="engine test scenario (no paper counterpart)",
    problem=_COHORT_SMOKE,
    cells=(
        MethodCell("BL2", "bl2", 12, basis="standard",
                   hess_comp=CompressorCfg(kind="topk", k=2 * 8),
                   model_comp=_IDENT, backend="cohort",
                   params=(("tau", 24), ("cohort", 16),
                           ("rounds_per_cohort", 2))),
    ),
    tags=("stream",),
))

# fig-dnn: the BL-DNN deep-network workload on the pytree round engine —
# bits-to-accuracy for the paper's communication mechanism (per-layer SVD
# basis + compressed-shift recursions + Fisher preconditioning) against an
# uncompressed FedAvg baseline and the no-basis Top-K ablation, plus the
# stochastic RTop-K codec (the gap stream is the training ERROR RATE, so
# tol=0.1 makes bits-to-tolerance = bits to 90% train accuracy).
_DNN = DNNProblemSpec()
_DNN_TOPK = CompressorCfg(kind="topk")   # per-leaf k from top_k_frac param

register_experiment(Experiment(
    name="fig-dnn",
    figure="extra",
    title="BL-DNN bits-to-accuracy: SVD basis vs FedAvg vs no-basis Top-K "
          "(beyond paper)",
    paper_ref="§2.3 mechanism on a DNN (no paper counterpart)",
    problem=_DNN,
    tol=0.1,                             # error rate < 0.1 ⇔ 90% accuracy
    cells=(
        MethodCell("BLDNN", "bldnn", 40, basis="per_layer_svd",
                   hess_comp=_DNN_TOPK,
                   params=(("top_k_frac", 0.1), ("lr", 0.05))),
        MethodCell("TopK", "bldnn", 40,
                   hess_comp=_DNN_TOPK,
                   params=(("top_k_frac", 0.1), ("lr", 0.05))),
        MethodCell("RTopK", "bldnn", 40, basis="per_layer_svd",
                   hess_comp=CompressorCfg(kind="rtopk"),
                   params=(("top_k_frac", 0.1), ("lr", 0.05))),
        MethodCell("FedAvg", "bldnn", 60,
                   hess_comp=CompressorCfg(kind="identity"),
                   params=(("lr", 0.5), ("precondition", False))),
    ),
))

# fig-dnn-ship: make the basis pay for itself.  fig-dnn shows the per-layer
# SVD basis winning ROUNDS-to-90% (10 vs 13) but losing the BITS headline
# to no-basis TopK because its dense-f32 shipment costs 0.69 Mbit.  This
# grid attacks the shipment leg itself: the same basis shipped bf16 / int8
# (quantized factors are what the engine rotates with — fidelity loss
# included), plus the FREE structured pytree bases (per-leaf DCT /
# Walsh–Hadamard rotations, zero floats shipped).  Same problem, compressor
# and tolerance as fig-dnn, so bits-to-tol columns compare directly.
register_experiment(Experiment(
    name="fig-dnn-ship",
    figure="extra",
    title="BL-DNN basis shipment: compressed / free bases vs no-basis Top-K "
          "(beyond paper)",
    paper_ref="Table 1 basis_ship leg carried to the DNN workload "
              "(no paper counterpart)",
    problem=_DNN,
    tol=0.1,                             # error rate < 0.1 ⇔ 90% accuracy
    cells=(
        MethodCell("TopK", "bldnn", 40,
                   hess_comp=_DNN_TOPK,
                   params=(("top_k_frac", 0.1), ("lr", 0.05))),
        MethodCell("BLDNN_f32", "bldnn", 40, basis="per_layer_svd",
                   hess_comp=_DNN_TOPK,
                   params=(("top_k_frac", 0.1), ("lr", 0.05))),
        MethodCell("BLDNN_bf16", "bldnn", 40, basis="per_layer_svd",
                   hess_comp=_DNN_TOPK,
                   params=(("top_k_frac", 0.1), ("lr", 0.05),
                           ("ship_float_bits", 16))),
        MethodCell("BLDNN_int8", "bldnn", 40, basis="per_layer_svd",
                   hess_comp=_DNN_TOPK,
                   params=(("top_k_frac", 0.1), ("lr", 0.05),
                           ("ship_float_bits", 8))),
        MethodCell("BLDNN_dct", "bldnn", 40, basis="dct_tree",
                   hess_comp=_DNN_TOPK,
                   params=(("top_k_frac", 0.1), ("lr", 0.05))),
        MethodCell("BLDNN_hadamard", "bldnn", 40, basis="hadamard_tree",
                   hess_comp=_DNN_TOPK,
                   params=(("top_k_frac", 0.1), ("lr", 0.05))),
    ),
))

# fig1-bag: FedNL-BAG (Bernoulli-lazy gradient aggregation, arXiv
# 2206.03588) vs FedNL — the follow-up method's first reproducible
# experiment path in this repo.
register_experiment(Experiment(
    name="fig1-bag",
    figure="extra",
    title="FedNL-BAG (Bernoulli gradient aggregation) vs FedNL (beyond paper)",
    paper_ref="Islamov et al. 2022 (arXiv 2206.03588) §BAG",
    problem=_PROBLEM,
    cells=(
        MethodCell("FedNL", "bl1", 2 * _S, basis="standard",
                   hess_comp=CompressorCfg(kind="rankr", r=1),
                   model_comp=_IDENT),
        MethodCell("BAG_q0.5", "fednl_bag", 2 * _S, basis="standard",
                   hess_comp=CompressorCfg(kind="rankr", r=1),
                   params=(("q", 0.5),)),
        MethodCell("BAG_q1.0", "fednl_bag", 2 * _S, basis="standard",
                   hess_comp=CompressorCfg(kind="rankr", r=1),
                   params=(("q", 1.0), ("eta", 1.0))),
    ),
))
