"""Minimal production optimizers (pytree-based, shard-friendly).

AdamW with configurable state dtype: f32 for ≤20B models; bf16 moments for
the 70B+/MoE configs so optimizer state fits the v5e HBM budget.  Master
weights stay in the parameter dtype (bf16) with an
f32 update path, matching common large-scale TPU practice.
"""
from __future__ import annotations



import jax
import jax.numpy as jnp


def adamw_init(params, state_dtype=jnp.float32):
    zeros = lambda p: jnp.zeros(p.shape, state_dtype)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def adamw_update(
    grads,
    opt_state,
    params,
    lr: float = 3e-4,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
):
    step = opt_state["step"] + 1
    t = step.astype(jnp.float32)
    c1 = 1.0 - b1 ** t
    c2 = 1.0 - b2 ** t

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m_new = b1 * m.astype(jnp.float32) + (1 - b1) * g32
        v_new = b2 * v.astype(jnp.float32) + (1 - b2) * g32 * g32
        mhat = m_new / c1
        vhat = v_new / c2
        delta = mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p.astype(jnp.float32)
        p_new = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return p_new, m_new.astype(m.dtype), v_new.astype(v.dtype)

    out = jax.tree.map(upd, params, grads, opt_state["m"], opt_state["v"])
    p_new = jax.tree.map(lambda t3: t3[0], out, is_leaf=lambda x: isinstance(x, tuple))
    m_new = jax.tree.map(lambda t3: t3[1], out, is_leaf=lambda x: isinstance(x, tuple))
    v_new = jax.tree.map(lambda t3: t3[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return p_new, {"m": m_new, "v": v_new, "step": step}


def sgdm_init(params, state_dtype=jnp.float32):
    return {
        "mom": jax.tree.map(lambda p: jnp.zeros(p.shape, state_dtype), params),
        "step": jnp.zeros((), jnp.int32),
    }


def sgdm_update(grads, opt_state, params, lr: float = 1e-2, momentum: float = 0.9):
    def upd(p, g, m):
        m_new = momentum * m.astype(jnp.float32) + g.astype(jnp.float32)
        p_new = (p.astype(jnp.float32) - lr * m_new).astype(p.dtype)
        return p_new, m_new.astype(m.dtype)

    out = jax.tree.map(upd, params, grads, opt_state["mom"])
    p_new = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    m_new = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    return p_new, {"mom": m_new, "step": opt_state["step"] + 1}
