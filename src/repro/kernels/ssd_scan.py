"""Mamba2 SSD chunked-scan Pallas kernel — TPU target.

The CUDA Mamba kernels are warp-level selective scans; the TPU-native SSD
formulation (Dao & Gu 2024) replaces them with chunk-local dense matmuls
(MXU) plus a sequential inter-chunk state recurrence, which maps exactly onto
a Pallas grid whose chunk axis is innermost-sequential with the running state
(hd × N) held in VMEM scratch.

Inputs (per head h folded into the grid):
  x:  (BH, S, hd)      dt: (BH, S)        A: (BH,)  (negative decay rate)
  Bm: (BH, S, N)       Cm: (BH, S, N)
Output: y (BH, S, hd) — Σ_{k≤q} exp(cs_q − cs_k)·(C_q·B_k)·dt_k·x_k.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, s_scr, *, chunk: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        s_scr[...] = jnp.zeros_like(s_scr)

    x = x_ref[0].astype(jnp.float32)          # (c, hd)
    dt = dt_ref[0].astype(jnp.float32)        # (c,)
    A = a_ref[0]                               # scalar
    Bm = b_ref[0].astype(jnp.float32)          # (c, N)
    Cm = c_ref[0].astype(jnp.float32)          # (c, N)

    dA = dt * A                                # (c,) ≤ 0
    cs = jnp.cumsum(dA)                        # (c,)
    seg = cs[:, None] - cs[None, :]            # (c_q, c_k)
    iota = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    iotk = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    seg = jnp.where(iota >= iotk, seg, -1e30)  # mask BEFORE exp
    L = jnp.exp(seg)

    CB = jnp.dot(Cm, Bm.T, preferred_element_type=jnp.float32)   # (c, c)
    M = CB * L * dt[None, :]
    y_intra = jnp.dot(M, x, preferred_element_type=jnp.float32)  # (c, hd)

    # inter-chunk: contribution of the incoming state
    decay_in = jnp.exp(cs)                      # (c,)
    y_inter = decay_in[:, None] * jnp.dot(Cm, s_scr[...].T,
                                          preferred_element_type=jnp.float32)
    y_ref[0] = (y_intra + y_inter).astype(y_ref.dtype)

    # state update: S ← exp(cs_end)·S + Σ_k exp(cs_end − cs_k)·dt_k·x_k⊗B_k
    decay_out = jnp.exp(cs[-1] - cs) * dt       # (c,)
    s_new = jnp.dot((x * decay_out[:, None]).T, Bm,
                    preferred_element_type=jnp.float32)          # (hd, N)
    s_scr[...] = s_scr[...] * jnp.exp(cs[-1]) + s_new


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(
    x: jax.Array, dt: jax.Array, A: jax.Array, Bm: jax.Array, Cm: jax.Array,
    *, chunk: int = 128, interpret: bool = True,
) -> jax.Array:
    BH, S, hd = x.shape
    N = Bm.shape[-1]
    c = min(chunk, S)
    while S % c:
        c -= 1
    grid = (BH, S // c)
    return pl.pallas_call(
        functools.partial(_ssd_kernel, chunk=c),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, c, hd), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, c), lambda b, i: (b, i)),
            pl.BlockSpec((1,), lambda b, i: (b,)),
            pl.BlockSpec((1, c, N), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, c, N), lambda b, i: (b, i, 0)),
        ],
        out_specs=pl.BlockSpec((1, c, hd), lambda b, i: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, hd), x.dtype),
        scratch_shapes=[pltpu.VMEM((hd, N), jnp.float32)],
        interpret=interpret,
    )(x, dt, A, Bm, Cm)
