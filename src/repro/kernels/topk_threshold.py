"""Top-K compression as a sort-free two-pass histogram → threshold → mask
pipeline (TPU adaptation of the paper's Top-K compressor — DESIGN.md §HW).

GPU implementations of Top-K sort (or radix-select) the |values|; TPU kernels
have no efficient global sort, so we:

  pass 1 (`histogram`): per-tile NBUCKET-bin histogram of |x| / max|x|,
         accumulated across the sequential grid into one output;
  host:  exclusive cumsum of the (tiny) histogram picks the bucket whose
         cumulative count crosses K → magnitude threshold t;
  pass 2 (`sparsify`): out = where(|x| ≥ t, x, 0), tiled elementwise.

The result keeps between K and K + (bucket collisions) entries — the paper's
contraction property (Eq. 6) holds for ANY superset of the top-K support, so
correctness is preserved; the wire-format bit count uses the actual kept
count.  Buckets are spaced on |x|^(1/2) to resolve the heavy tail better.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NBUCKETS = 512


def _hist_kernel(x_ref, maxv_ref, hist_ref, *, nbuckets: int):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        hist_ref[...] = jnp.zeros_like(hist_ref)

    x = x_ref[...].astype(jnp.float32)
    mx = maxv_ref[0]
    a = jnp.abs(x) / jnp.maximum(mx, 1e-30)
    a = jnp.sqrt(a)                       # heavy-tail resolving spacing
    b = jnp.clip((a * nbuckets).astype(jnp.int32), 0, nbuckets - 1)
    onehot = (b[:, :, None] == jax.lax.broadcasted_iota(jnp.int32, (1, 1, nbuckets), 2))
    hist_ref[...] += jnp.sum(onehot, axis=(0, 1)).astype(jnp.float32)


def _mask_kernel(x_ref, t_ref, o_ref):
    x = x_ref[...]
    t = t_ref[0]
    o_ref[...] = jnp.where(jnp.abs(x.astype(jnp.float32)) >= t, x, jnp.zeros_like(x))


def _tile(n, want):
    t = min(want, n)
    while n % t:
        t -= 1
    return t


@functools.partial(jax.jit, static_argnames=("k", "interpret", "nbuckets"))
def topk_threshold(x: jax.Array, k: int, *, interpret: bool = True,
                   nbuckets: int = NBUCKETS):
    """Returns (compressed_dense, threshold, kept_count)."""
    shape = x.shape
    flat = x.reshape(-1)
    n = flat.size
    cols = _tile(n, 4096)
    rows = n // cols
    x2 = flat.reshape(rows, cols)
    br = _tile(rows, 8)
    bc = _tile(cols, 1024)
    grid_r, grid_c = rows // br, cols // bc

    maxv = jnp.max(jnp.abs(flat)).astype(jnp.float32).reshape(1)

    hist = pl.pallas_call(
        functools.partial(_hist_kernel, nbuckets=nbuckets),
        grid=(grid_r * grid_c,),
        in_specs=[
            pl.BlockSpec((br, bc), lambda i: (i // (cols // bc), i % (cols // bc))),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((nbuckets,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((nbuckets,), jnp.float32),
        interpret=interpret,
    )(x2, maxv)

    # host-side (tiny): find the magnitude threshold whose tail count ≥ k
    tail = jnp.cumsum(hist[::-1])[::-1]            # count of |x| in bucket ≥ b
    kk = min(k, n)
    bucket = jnp.argmax(tail <= kk)                 # first bucket from below w/ tail ≤ k
    bucket = jnp.where(tail[bucket] < kk, jnp.maximum(bucket - 1, 0), bucket)
    frac = bucket.astype(jnp.float32) / nbuckets
    t = (frac ** 2) * maxv[0]                       # invert sqrt spacing

    out = pl.pallas_call(
        _mask_kernel,
        grid=(grid_r * grid_c,),
        in_specs=[
            pl.BlockSpec((br, bc), lambda i: (i // (cols // bc), i % (cols // bc))),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((br, bc), lambda i: (i // (cols // bc), i % (cols // bc))),
        out_shape=jax.ShapeDtypeStruct((rows, cols), x.dtype),
        interpret=interpret,
    )(x2, t.reshape(1))

    kept = jnp.sum(out != 0)
    return out.reshape(shape), t, kept
