"""Exact |·|-Top-K threshold selection as a Pallas kernel (the compression
engine's measured hot spot).

GPU Top-K implementations sort (or radix-select) the |values|; TPUs have no
efficient global sort, and XLA's CPU fallback decomposes a partially-dead
``top_k`` into a full stable sort (~75× slower on the engine's d²
coefficient arrays — the reason the XLA selection path needs
``optimization_barrier``s, see `repro.core.compressors.topk_keep_mask`).
This kernel instead finds, per row, the EXACT k-th largest |value| by a
bitwise binary search over f32 bit patterns:

  * |x| ≥ 0, and the IEEE-754 bit pattern of a non-negative float is
    monotone in its value, so selection runs on int32 keys (sign bit 0);
  * 31 count-passes (one per non-sign bit, high → low) greedily build the
    largest threshold t with count(|x| ≥ t) ≥ k — which is exactly the
    k-th largest magnitude, ties included;
  * each pass is a vectorized compare+reduce over the VMEM-resident row —
    no sort, no scatter, O(31·T) work per row, trivially batched over the
    engine's client axis by the grid.

The returned threshold equals ``lax.top_k(|x|, k)[0][..., -1]`` bitwise, so
the shared tie-break algebra (`keep_mask`) selects the SAME entries as the
barrier'd XLA path — that is what lets ``REPRO_BL_PALLAS=1`` swap selection
backends without perturbing trajectories (tests/test_pallas_parity.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def keep_mask(a32: jax.Array, t: jax.Array, k: int) -> jax.Array:
    """Exactly-k selection mask from a per-row threshold, along the last axis.

    `a32` are non-negative f32 magnitudes, `t` the k-th largest per row
    (shape ``a32.shape[:-1] + (1,)``).  Entries strictly above t are kept;
    the tie group at t is broken by earliest index.  This is the ONE
    tie-break rule both selection backends (Pallas kernel / barrier'd XLA
    ``top_k``) feed — identical thresholds ⇒ identical masks.
    """
    above = a32 > t
    eq = a32 == t
    n_above = jnp.sum(above, axis=-1, keepdims=True)
    cum = jnp.cumsum(eq, axis=-1)
    return above | (eq & (cum <= k - n_above))


def _threshold_kernel(a_ref, t_ref, *, k: int):
    a = a_ref[...]                                     # (1, T) f32, |values|
    keys = jax.lax.bitcast_convert_type(a, jnp.int32)  # monotone for a ≥ 0

    def body(i, t):
        cand = t | (jnp.int32(1) << (jnp.int32(30) - i))
        cnt = jnp.sum((keys >= cand).astype(jnp.int32), axis=1, keepdims=True)
        return jnp.where(cnt >= k, cand, t)

    t = jax.lax.fori_loop(0, 31, body, jnp.zeros((a.shape[0], 1), jnp.int32))
    t_ref[...] = jax.lax.bitcast_convert_type(t, jnp.float32)


@functools.partial(jax.jit, static_argnames=("k", "interpret"))
def topk_row_threshold(a32: jax.Array, k: int, *,
                       interpret: bool = True) -> jax.Array:
    """Per-row exact k-th largest of non-negative f32 `a32` (rows, T) →
    (rows, 1).  k is clamped to [1, T] — a threshold is undefined for an
    empty kept set; callers wanting k = 0 handle it before selection (see
    `topk_threshold`)."""
    rows, T = a32.shape
    kk = max(1, min(k, T))
    return pl.pallas_call(
        functools.partial(_threshold_kernel, k=kk),
        grid=(rows,),
        in_specs=[pl.BlockSpec((1, T), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, 1), jnp.float32),
        interpret=interpret,
    )(a32)


def _compress_sum_kernel(v_ref, out_ref, s_ref, *, k: int):
    """Fused compress-then-reduce over a whole (n, T) client stack in VMEM:
    per-row threshold search (the same 31-pass bitwise binary search as
    `_threshold_kernel`, vectorized over rows), the shared tie-break mask,
    the dense masked values, AND the local cross-client partial sum — one
    pass, one kernel."""
    v = v_ref[...]                                     # (n, T) f32 values
    a = jnp.abs(v)
    keys = jax.lax.bitcast_convert_type(a, jnp.int32)  # monotone for a ≥ 0

    def body(i, t):
        cand = t | (jnp.int32(1) << (jnp.int32(30) - i))
        cnt = jnp.sum((keys >= cand).astype(jnp.int32), axis=1, keepdims=True)
        return jnp.where(cnt >= k, cand, t)

    t = jax.lax.fori_loop(0, 31, body, jnp.zeros((v.shape[0], 1), jnp.int32))
    tf = jax.lax.bitcast_convert_type(t, jnp.float32)
    out = jnp.where(keep_mask(a, tf, k), v, jnp.zeros_like(v))
    out_ref[...] = out
    s_ref[...] = jnp.sum(out, axis=0)                  # client-axis partial


@functools.partial(jax.jit, static_argnames=("k", "interpret"))
def topk_compress_sum(v: jax.Array, k: int, *, interpret: bool = True):
    """Exact |·|-Top-K of each row of f32 `v` (n, T) fused with the sum of
    the compressed rows: returns ``(dense (n, T), col_sum (T,))`` with
    ``col_sum == dense.sum(axis=0)``.

    The threshold/tie-break path is shared with `topk_row_threshold` /
    `keep_mask`, so ``dense`` is bitwise the two-pass selection's output
    and ``col_sum`` is bitwise the XLA reduction of it — the fusion saves
    a pass over the stack, not an ulp (pinned by
    tests/test_pallas_parity.py).  k is clamped to [1, T] like
    `topk_row_threshold`."""
    if v.dtype != jnp.float32:
        raise TypeError(
            f"topk_compress_sum runs its bitwise search on f32 bit "
            f"patterns, got {v.dtype}")
    n, T = v.shape
    kk = max(1, min(k, T))
    return pl.pallas_call(
        functools.partial(_compress_sum_kernel, k=kk),
        out_shape=(jax.ShapeDtypeStruct((n, T), jnp.float32),
                   jax.ShapeDtypeStruct((T,), jnp.float32)),
        interpret=interpret,
    )(v)


@functools.partial(jax.jit, static_argnames=("k", "interpret"))
def topk_threshold(x: jax.Array, k: int, *, interpret: bool = True):
    """Global exact Top-K over a whole tensor (flattened): returns
    ``(compressed_dense, threshold, kept_count)`` with kept_count == min(k,
    numel) exactly (tie group broken by earliest index).  k ≤ 0 keeps
    nothing (threshold +inf)."""
    shape = x.shape
    flat = x.reshape(1, -1)
    if k <= 0:
        return (jnp.zeros_like(x), jnp.asarray(jnp.inf, jnp.float32),
                jnp.asarray(0, jnp.int32))
    kk = min(k, flat.shape[1])
    a32 = jnp.abs(flat).astype(jnp.float32)
    t = topk_row_threshold(a32, kk, interpret=interpret)
    mask = keep_mask(a32, t, kk)
    out = jnp.where(mask, flat, jnp.zeros_like(flat))
    return out.reshape(shape), t[0, 0], jnp.sum(mask)
