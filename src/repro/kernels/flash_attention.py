"""Flash attention (blocked online-softmax) Pallas kernel — TPU target.

Grid (B·H, Sq/bq, Sk/bk); the k-grid is innermost and sequential on TPU, so
the running max / denominator / accumulator live in VMEM scratch across k
steps.  Supports causal and sliding-window masking (mask-based: TPU grids are
static, so fully-masked blocks are computed-and-masked rather than skipped —
the roofline ratio in README.md §EXPERIMENTS quantifies that 2× causal
overhead).

q: (BH, Sq, hd)   k, v: (BH, Sk, hd)   → o: (BH, Sq, hd)
GQA is handled by the ops.py wrapper (q heads grouped, k/v broadcast by
index mapping — no KV materialization).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
                  *, scale: float, causal: bool, window: Optional[int],
                  bq: int, bk: int, nk: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)          # (bq, hd)
    k = k_ref[0].astype(jnp.float32)          # (bk, hd)
    s = jnp.dot(q * scale, k.T, preferred_element_type=jnp.float32)  # (bq, bk)

    qpos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    kpos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = jnp.ones((bq, bk), jnp.bool_)
    if causal:
        mask = mask & (kpos <= qpos)
    if window is not None:
        mask = mask & (kpos > qpos - window)
    s = jnp.where(mask, s, NEG)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1)
    m_scr[...] = m_new
    acc_scr[...] = acc_scr[...] * alpha[:, None] + jnp.dot(
        p, v_ref[0].astype(jnp.float32), preferred_element_type=jnp.float32)

    @pl.when(ki == nk - 1)
    def _done():
        denom = jnp.maximum(l_scr[...], 1e-30)[:, None]
        o_ref[0] = (acc_scr[...] / denom).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "bq", "bk",
                                             "interpret"))
def flash_attention(
    q: jax.Array, k: jax.Array, v: jax.Array, *,
    causal: bool = True,
    window: Optional[int] = None,
    bq: int = 128,
    bk: int = 128,
    interpret: bool = True,
) -> jax.Array:
    BH, Sq, hd = q.shape
    Sk = k.shape[1]
    bq_, bk_ = min(bq, Sq), min(bk, Sk)
    while Sq % bq_:
        bq_ -= 1
    while Sk % bk_:
        bk_ -= 1
    grid = (BH, Sq // bq_, Sk // bk_)
    scale = hd ** -0.5
    return pl.pallas_call(
        functools.partial(_flash_kernel, scale=scale, causal=causal,
                          window=window, bq=bq_, bk=bk_, nk=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq_, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk_, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk_, hd), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq_, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq_,), jnp.float32),
            pltpu.VMEM((bq_,), jnp.float32),
            pltpu.VMEM((bq_, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
