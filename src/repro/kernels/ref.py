"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def matmul_ref(a, b, out_dtype=jnp.float32):
    return jnp.dot(a.astype(jnp.float32), b.astype(jnp.float32)).astype(out_dtype)


def basis_project_ref(V, A):
    """Γ = Vᵀ A V (Eq. 5 coefficients in the data-induced basis)."""
    Vf = V.astype(jnp.float32)
    return Vf.T @ A.astype(jnp.float32) @ Vf


def glm_hessian_ref(A, w, lam):
    """(1/m) Aᵀ diag(w) A + λI."""
    m = A.shape[0]
    Af = A.astype(jnp.float32)
    H = (Af * w.astype(jnp.float32)[:, None]).T @ Af / m
    return H + lam * jnp.eye(A.shape[1], dtype=jnp.float32)


def topk_threshold_ref(x, t):
    """Everything with |x| ≥ t — the kernel's kept set BEFORE the exact-k
    tie-break (a superset of the output support when |x| ties at t)."""
    return jnp.where(jnp.abs(x.astype(jnp.float32)) >= t, x, jnp.zeros_like(x))


def attention_ref(q, k, v, causal=True, window: Optional[int] = None):
    """Exact softmax attention (BH, S, hd)."""
    BH, Sq, hd = q.shape
    Sk = k.shape[1]
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * hd**-0.5
    qi = jnp.arange(Sq)[:, None]
    ki = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask = mask & (ki <= qi)
    if window is not None:
        mask = mask & (ki > qi - window)
    s = jnp.where(mask[None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32)).astype(q.dtype)


def ssd_scan_ref(x, dt, A, Bm, Cm):
    """Sequential state-space recurrence (the SSD ground truth):
       S_t = exp(dt_t A) S_{t-1} + dt_t x_t ⊗ B_t ;  y_t = C_t · S_t."""
    BH, S, hd = x.shape
    N = Bm.shape[-1]

    def step(s, inp):
        xt, dtt, bt, ct = inp
        dec = jnp.exp(dtt * A)                       # (BH,)
        s = s * dec[:, None, None] + dtt[:, None, None] * jnp.einsum(
            "bd,bn->bdn", xt.astype(jnp.float32), bt.astype(jnp.float32))
        y = jnp.einsum("bn,bdn->bd", ct.astype(jnp.float32), s)
        return s, y

    s0 = jnp.zeros((BH, hd, N), jnp.float32)
    xs = (x.transpose(1, 0, 2), dt.astype(jnp.float32).T,
          Bm.transpose(1, 0, 2), Cm.transpose(1, 0, 2))
    _, ys = jax.lax.scan(step, s0, xs)
    return ys.transpose(1, 0, 2).astype(x.dtype)
