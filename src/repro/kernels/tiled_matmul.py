"""Generic tiled matmul Pallas kernel — the MXU building block for the BL
compute hot spots (basis projection Γ = VᵀAV, GLM Hessian AᵀDA).

BlockSpec tiling: (bm × bk) · (bk × bn) tiles staged through VMEM, f32
accumulation in a VMEM scratch across the k-grid (TPU grids iterate the last
dimension fastest and sequentially, so the scratch carries between k steps).
Tile sizes default to 128/256 — MXU-aligned (multiples of 128) per the
hardware-adaptation notes in docs/ARCHITECTURE.md (§Pallas switches).
Validated on CPU via interpret=True.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _matmul_kernel(a_ref, b_ref, o_ref, acc_ref, *, nk: int):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        a_ref[...].astype(jnp.float32),
        b_ref[...].astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )

    @pl.when(ki == nk - 1)
    def _done():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def _pad_axis(x, ax, mult):
    r = (-x.shape[ax]) % mult
    if not r:
        return x
    pads = [(0, 0)] * x.ndim
    pads[ax] = (0, r)
    return jnp.pad(x, pads)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret", "out_dtype"))
def matmul(
    a: jax.Array,
    b: jax.Array,
    *,
    bm: int = 128,
    bn: int = 128,
    bk: int = 256,
    interpret: bool = True,
    out_dtype=jnp.float32,
) -> jax.Array:
    """C = A @ B with (bm, bn, bk) VMEM tiles; pads to tile multiples."""
    M, K = a.shape
    K2, N = b.shape
    assert K == K2, (a.shape, b.shape)
    bm_, bn_, bk_ = min(bm, M), min(bn, N), min(bk, K)
    a_p = _pad_axis(_pad_axis(a, 0, bm_), 1, bk_)
    b_p = _pad_axis(_pad_axis(b, 0, bk_), 1, bn_)
    Mp, Kp = a_p.shape
    _, Np = b_p.shape
    grid = (Mp // bm_, Np // bn_, Kp // bk_)

    out = pl.pallas_call(
        functools.partial(_matmul_kernel, nk=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm_, bk_), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk_, bn_), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm_, bn_), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Mp, Np), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm_, bn_), jnp.float32)],
        interpret=interpret,
    )(a_p, b_p)
    return out[:M, :N]
