"""Two-sided basis transform A · gᵢ · B over a client stack as a Pallas
kernel (the BL-DNN rotation hot spot).

The pytree bases (`repro.core.basis.PerLayerSVDBasis` and the structured
DCT/Hadamard kinds) rotate every 2-D weight leaf of every client's gradient:
``(n, d1, d2)`` stacks hit ``Uᵀ g V`` (forward) and ``U c Vᵀ`` (backward)
each round.  XLA's batched matmul handles this fine on CPU; on TPU the two
products want to stay fused in VMEM — one grid step per client, both
``jnp.dot`` contractions on the MXU without spilling the (d1, d2)
intermediate.

Parity contract: the kernel computes ``(A @ gᵢ) @ B`` in the SAME
association order as the engine's default ``A @ g @ B`` (python ``@`` is
left-associative), and in interpret mode each grid step lowers to the same
CPU gemms — the outputs are bitwise-identical to the XLA path (pinned by
tests/test_basis_ship.py), so ``REPRO_BL_PALLAS=1`` swaps rotation backends
without perturbing trajectories.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _transform_kernel(a_ref, g_ref, b_ref, o_ref):
    a = a_ref[...]                       # (da, d1) left factor, whole
    g = g_ref[0]                         # (d1, d2) one client's leaf
    b = b_ref[...]                       # (d2, db) right factor, whole
    t = jnp.dot(a, g, preferred_element_type=jnp.float32)
    o_ref[0] = jnp.dot(t, b, preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("interpret",))
def basis_transform(A: jax.Array, g: jax.Array, B: jax.Array, *,
                    interpret: bool = True) -> jax.Array:
    """``A @ g[i] @ B`` for every client i: (da, d1) × (n, d1, d2) ×
    (d2, db) → (n, da, db), one grid step per client with both factors
    VMEM-resident.  f32 only — the bitwise-parity contract is pinned
    against the f32 XLA batched matmul."""
    if g.ndim != 3:
        raise ValueError(f"expected a client-stacked (n, d1, d2) leaf, "
                         f"got shape {g.shape}")
    for name, x in (("A", A), ("g", g), ("B", B)):
        if x.dtype != jnp.float32:
            raise TypeError(f"basis_transform is f32-only, {name} is "
                            f"{x.dtype}")
    n, d1, d2 = g.shape
    da, db = A.shape[0], B.shape[1]
    if A.shape[1] != d1 or B.shape[0] != d2:
        raise ValueError(
            f"factor/leaf shape mismatch: A {A.shape} · g {g.shape} · "
            f"B {B.shape}")
    return pl.pallas_call(
        _transform_kernel,
        grid=(n,),
        in_specs=[
            pl.BlockSpec((da, d1), lambda i: (0, 0)),
            pl.BlockSpec((1, d1, d2), lambda i: (i, 0, 0)),
            pl.BlockSpec((d2, db), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, da, db), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, da, db), jnp.float32),
        interpret=interpret,
    )(A, g, B)
