"""Jit'd public wrappers around the Pallas kernels.

`INTERPRET` defaults to True on CPU (this container) so every op runs the
kernel body through the Pallas interpreter; on a real TPU backend set
repro.kernels.ops.INTERPRET = False (or env REPRO_PALLAS_COMPILE=1) to lower
to Mosaic.
"""
from __future__ import annotations

import os
from typing import Optional

import jax
import jax.numpy as jnp

from .basis_transform import basis_transform as _basis_transform
from .flash_attention import flash_attention as _flash
from .ssd_scan import ssd_scan as _ssd
from .tiled_matmul import matmul as _matmul
from .topk_threshold import topk_threshold as _topk

INTERPRET = os.environ.get("REPRO_PALLAS_COMPILE", "0") != "1"


def matmul(a, b, out_dtype=jnp.float32, **tiles):
    return _matmul(a, b, interpret=INTERPRET, out_dtype=out_dtype, **tiles)


def basis_project(V, A, **tiles):
    """Γ = Vᵀ A V — the per-iteration BL coefficient computation (Eq. 5).

    Accepts a leading batch dimension (the batched BL engine's stacked-client
    layout): V (n, d, r) with A (n, d, d) → (n, r, r), mapped over the same
    tiled Pallas matmul kernel.  2-D inputs keep the original single-client
    path.  The kernel accumulates in f32 (MXU) — use the engine's default
    einsum path when float64 trajectories matter (CPU parity tests).
    """
    if A.ndim == 3:
        if V.ndim == 2:
            V = jnp.broadcast_to(V, (A.shape[0],) + V.shape)

        def _one(Vi, Ai):
            T = matmul(Ai, Vi, **tiles)                  # (d, r)
            return matmul(Vi.T, T, **tiles)              # (r, r)

        return jax.vmap(_one)(V, A)
    T = matmul(A, V, **tiles)          # (d, r)
    return matmul(V.T, T, **tiles)     # (r, r)


def basis_transform(A, g, B):
    """A · gᵢ · B over a client-stacked (n, d1, d2) leaf — the pytree-basis
    rotation (Uᵀ g V / U c Vᵀ), one fused grid step per client.  Interpret
    mode is bitwise the XLA batched-matmul default (see
    kernels/basis_transform.py's parity contract)."""
    return _basis_transform(A, g, B, interpret=INTERPRET)


def glm_hessian(A, w, lam, **tiles):
    """(1/m) Aᵀ diag(w) A + λI — fused GLM Hessian (Eq. 3)."""
    m, d = A.shape
    Aw = A * w[:, None].astype(A.dtype)
    H = matmul(A.T, Aw, **tiles) / m
    return H + lam * jnp.eye(d, dtype=H.dtype)


def topk_compress(x, k: int):
    """Exact Top-K via the bitwise-binary-search threshold kernel (see
    topk_threshold.py) — keeps exactly min(k, numel) entries, ties broken
    by earliest index.  Returns (compressed_dense, kept_count)."""
    out, _, kept = _topk(x, k, interpret=INTERPRET)
    return out, kept


def attention(q, k, v, *, causal=True, window: Optional[int] = None,
              bq: int = 128, bk: int = 128):
    """Flash attention over (B, S, H, hd) with GQA: kv heads broadcast via
    index mapping (fold heads into batch; repeat kv cheaply by gather)."""
    B, Sq, H, hd = q.shape
    KVH = k.shape[2]
    rep = H // KVH
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, Sq, hd)
    kf = jnp.repeat(k.transpose(0, 2, 1, 3), rep, axis=1).reshape(B * H, -1, hd)
    vf = jnp.repeat(v.transpose(0, 2, 1, 3), rep, axis=1).reshape(B * H, -1, hd)
    o = _flash(qf, kf, vf, causal=causal, window=window, bq=bq, bk=bk,
               interpret=INTERPRET)
    return o.reshape(B, H, Sq, hd).transpose(0, 2, 1, 3)


def ssd(x, dt, A, Bm, Cm, chunk: int = 128):
    """Mamba2 SSD over (BH, S, hd) heads-folded layout."""
    return _ssd(x, dt, A, Bm, Cm, chunk=chunk, interpret=INTERPRET)
