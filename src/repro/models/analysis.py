"""Parameter / FLOP accounting for the roofline analysis."""
from __future__ import annotations


import jax
import jax.numpy as jnp

from . import model as M
from .config import ModelConfig


def param_count(cfg: ModelConfig) -> int:
    import math
    shapes = M.param_shapes(cfg, jnp.bfloat16)
    return sum(math.prod(s.shape) for s in jax.tree.leaves(shapes))


def active_param_count(cfg: ModelConfig) -> int:
    """Parameters touched per token: MoE counts only top_k + shared experts."""
    total = param_count(cfg)
    if cfg.moe is None:
        return total
    mc = cfg.moe
    fe = mc.d_expert or cfg.d_ff
    n_moe_layers = sum(1 for s in cfg.layer_specs() if s.ffn == "moe")
    per_expert = 3 * cfg.d_model * fe
    inactive = n_moe_layers * (mc.n_experts - mc.top_k) * per_expert
    return total - inactive


def model_flops(cfg: ModelConfig, kind: str, global_batch: int, seq_len: int) -> float:
    """6·N_active·tokens for training, 2·N_active·tokens for inference.

    decode processes ONE token per sequence; prefill processes the full
    sequence.  (Attention's seq² term is excluded by convention — the ratio
    vs HLO FLOPs surfaces it.)
    """
    n = active_param_count(cfg)
    if kind == "train":
        tokens = global_batch * seq_len
        return 6.0 * n * tokens
    if kind == "prefill":
        tokens = global_batch * seq_len
        return 2.0 * n * tokens
    tokens = global_batch  # decode: one token per sequence
    return 2.0 * n * tokens
