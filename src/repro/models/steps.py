"""Train / prefill / decode step factories.

Each factory closes over (cfg, rules) and returns a pure function suitable for
jax.jit + .lower().compile() in the dry-run, and for direct execution in the
smoke tests / examples.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from . import model as M
from .config import ModelConfig
from ..optim import adamw_update


def _xent(logits: jax.Array, labels: jax.Array, rules) -> jax.Array:
    """Mean next-token cross entropy over a vocab-sharded logits tensor.

    The label log-prob is extracted with a masked sum instead of
    take_along_axis: a vocab-indexed gather forces XLA to all-gather the full
    (B, S, V) logits (13 GB for mamba2 train_4k); the masked sum keeps every
    shard local and reduces with a scalar all-reduce.
    """
    lg = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lg, axis=-1)
    vocab_iota = jnp.arange(lg.shape[-1], dtype=labels.dtype)
    onehot = (labels[..., None] == vocab_iota)
    ll = jnp.sum(jnp.where(onehot, lg, 0.0), axis=-1)
    return jnp.mean(lse - ll)


def make_fused_vocab_xent(cfg: ModelConfig, rules):
    """Vocab-parallel fused cross entropy (Megatron-style), custom_vjp.

    Motivation (measured in the dry-run, see README.md §EXPERIMENTS): letting
    autodiff
    differentiate `logits = h @ W; CE(logits)` makes XLA all-gather the full
    f32 (B, S, V) cotangent along the vocab shard (13.2 GB/device for mamba2
    train_4k) because it prefers gathering dlogits over an all-reduced dh.
    The custom backward keeps dlogits vocab-sharded, contracts locally, and
    all-reduces only the (B, S, D) dh partial — and recomputes logits instead
    of storing them.
    """
    V = cfg.vocab_size
    Vp = cfg.padded_vocab

    def _logits(h, W):
        lg = jnp.einsum("bsd,dv->bsv", h, W).astype(jnp.float32)
        if rules is not None:
            lg = rules.constrain(lg, ("batch", None, "vocab"))
        if Vp != V:
            pad = jnp.arange(Vp) >= V
            lg = lg + jnp.where(pad, -1e30, 0.0).astype(lg.dtype)
        return lg

    @jax.custom_vjp
    def xent(h, W, labels):
        lg = _logits(h, W)
        lse = jax.nn.logsumexp(lg, axis=-1)
        onehot = labels[..., None] == jnp.arange(Vp, dtype=labels.dtype)
        ll = jnp.sum(jnp.where(onehot, lg, 0.0), axis=-1)
        return jnp.mean(lse - ll)

    def fwd(h, W, labels):
        return xent(h, W, labels), (h, W, labels)

    def bwd(res, g):
        h, W, labels = res
        lg = _logits(h, W)                      # recompute (no logits storage)
        p = jax.nn.softmax(lg, axis=-1)
        onehot = (labels[..., None] == jnp.arange(Vp, dtype=labels.dtype))
        n = h.shape[0] * h.shape[1]
        dlg = (p - onehot.astype(p.dtype)) * (g / n)
        if rules is not None:
            dlg = rules.constrain(dlg, ("batch", None, "vocab"))
        dlg = dlg.astype(h.dtype)
        dh = jnp.einsum("bsv,dv->bsd", dlg, W)
        if rules is not None:
            dh = rules.constrain(dh, ("batch", None, None))
        dW = jnp.einsum("bsd,bsv->dv", h, dlg)
        return dh, dW.astype(W.dtype), None

    xent.defvjp(fwd, bwd)
    return xent


def stub_inputs(cfg: ModelConfig, batch: int, dtype=jnp.bfloat16) -> Dict[str, Any]:
    """Extra (non-token) model inputs for audio/VLM backbones (the stub
    frontends): shape contracts only — content comes from the caller."""
    extras: Dict[str, Any] = {}
    if cfg.n_enc_layers:
        extras["frames"] = jnp.zeros((batch, cfg.enc_seq, cfg.d_model), dtype)
    if cfg.n_prefix_embeds:
        extras["prefix_embeds"] = jnp.zeros((batch, cfg.n_prefix_embeds, cfg.d_model), dtype)
    return extras


def make_train_step(cfg: ModelConfig, rules, lr: float = 3e-4, remat: bool = True,
                    microbatch: int = 1):
    """microbatch > 1: gradient accumulation over `microbatch` slices of the
    global batch (scan with f32 grad accumulator) — divides the per-layer
    activation carry stack by `microbatch` at the cost of re-running the
    (already remat'd) forward per slice (§Perf iteration 3)."""
    xent = make_fused_vocab_xent(cfg, rules)

    def loss_fn(params, batch):
        tokens = batch["tokens"]
        inp, labels = tokens[:, :-1], tokens[:, 1:]
        h, _, aux = M.forward(
            params, cfg, rules, inp,
            prefix_embeds=batch.get("prefix_embeds"),
            frames=batch.get("frames"),
            remat=remat,
            return_hidden=True,
        )
        P = cfg.n_prefix_embeds
        if P:
            h = h[:, P:, :]
        W = params["embed"].T if cfg.tie_embeddings else params["unembed"]
        loss = xent(h, W, labels) + aux
        return loss, aux

    def train_step(params, opt_state, batch):
        if microbatch == 1:
            (loss, aux), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
        else:
            def slice_mb(i, x):
                mb = x.shape[0] // microbatch
                return jax.lax.dynamic_slice_in_dim(x, i * mb, mb, 0)

            def mb_body(acc, i):
                mb_batch = jax.tree.map(lambda x: slice_mb(i, x), batch)
                (l, a), g = jax.value_and_grad(loss_fn, has_aux=True)(params, mb_batch)
                acc = (acc[0] + l, acc[1] + a,
                       jax.tree.map(lambda s, gi: s + gi.astype(jnp.float32),
                                    acc[2], g))
                return acc, None

            zero = (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32),
                    jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params))
            (loss, aux, gsum), _ = jax.lax.scan(
                mb_body, zero, jnp.arange(microbatch))
            loss, aux = loss / microbatch, aux / microbatch
            grads = jax.tree.map(lambda g, p: (g / microbatch).astype(p.dtype),
                                 gsum, params)
        params, opt_state = adamw_update(grads, opt_state, params, lr=lr)
        return params, opt_state, {"loss": loss, "aux": aux}

    return train_step


def make_prefill_step(cfg: ModelConfig, rules, max_seq: Optional[int] = None,
                      cache_dtype=jnp.bfloat16):
    def prefill_step(params, batch, cache):
        tokens = batch["tokens"]
        logits, cache, _ = M.forward(
            params, cfg, rules, tokens,
            cache=cache, cache_pos=jnp.asarray(0, jnp.int32),
            prefix_embeds=batch.get("prefix_embeds"),
            frames=batch.get("frames"),
            remat=False,
        )
        return logits, cache

    return prefill_step


def make_serve_step(cfg: ModelConfig, rules):
    """One decode step: next-token logits + greedy sample + cache update."""
    def serve_step(params, batch, cache, pos):
        tokens = batch["tokens"]  # (B, 1)
        logits, cache, _ = M.forward(
            params, cfg, rules, tokens,
            cache=cache, cache_pos=pos,
            frames=batch.get("frames"),
            remat=False,
        )
        next_tok = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
        return next_tok, cache

    return serve_step
