"""Unified model configuration covering all assigned architecture families.

One `ModelConfig` describes dense GQA decoders, MoE decoders, Mamba2 (SSD)
stacks, hybrid attention/SSM interleaves (Jamba), encoder–decoder audio
backbones (Whisper) and VLM text backbones (M-RoPE).  Layer stacking is
expressed as a repeating *group pattern* so heterogeneous interleaves scan
over groups with the heterogeneity unrolled inside the group.
"""
from __future__ import annotations

import dataclasses
from typing import Literal, Optional, Sequence, Tuple

MixerKind = Literal["attn", "mamba"]
FFNKind = Literal["mlp", "moe", "none"]


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    """One layer inside the repeating group."""
    mixer: MixerKind = "attn"
    ffn: FFNKind = "mlp"
    #: attention window (tokens); None = full/global attention
    window: Optional[int] = None


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 8
    top_k: int = 2
    n_shared: int = 0          # always-on shared experts (DeepSeek-MoE)
    d_expert: int = 0          # per-expert FFN width (0 ⇒ use d_ff)
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    head_dim: int = 64
    expand: int = 2
    conv_width: int = 4
    chunk: int = 256           # SSD chunk length


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                       # 0 ⇒ d_model // n_heads
    group: Tuple[LayerSpec, ...] = (LayerSpec(),)  # repeats n_layers/len(group)×
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    #: gated (SwiGLU) vs plain 2-matrix MLP (GPT/Whisper style)
    mlp_gated: bool = True
    tie_embeddings: bool = False
    rope_theta: float = 10_000.0
    #: M-RoPE (Qwen2-VL): 3-component positions (temporal, h, w)
    mrope: bool = False
    #: encoder–decoder (Whisper): n_enc_layers of full-attention encoder over
    #: stub frame embeddings + cross-attention in every decoder layer
    n_enc_layers: int = 0
    enc_seq: int = 0                        # encoder positions (stub frames/patches)
    #: VLM stub: prepend this many precomputed patch embeddings to the text
    n_prefix_embeds: int = 0
    norm_eps: float = 1e-6
    #: supports sub-quadratic long-context decode (SSM/hybrid/sliding-window)
    subquadratic: bool = False
    max_seq: int = 131_072

    def __post_init__(self):
        assert self.n_layers % len(self.group) == 0, (
            f"{self.name}: n_layers {self.n_layers} not divisible by group "
            f"size {len(self.group)}"
        )

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        """Vocab padded to a multiple of 256 so logits shard over `model`."""
        return (self.vocab_size + 255) // 256 * 256

    @property
    def n_groups(self) -> int:
        return self.n_layers // len(self.group)

    @property
    def d_inner(self) -> int:  # SSM inner width
        assert self.ssm is not None
        return self.ssm.expand * self.d_model

    @property
    def n_ssm_heads(self) -> int:
        assert self.ssm is not None
        return self.d_inner // self.ssm.head_dim

    def layer_specs(self) -> Sequence[LayerSpec]:
        return list(self.group) * self.n_groups

    def reduced(self, **overrides) -> "ModelConfig":
        """Smoke-test variant: ≤2 groups, d_model ≤ 512, ≤4 experts."""
        group = self.group
        d_model = min(self.d_model, 256)
        n_heads = min(self.n_heads, 4)
        n_kv = min(self.n_kv_heads, n_heads)
        hd = 64
        d_ff = min(self.d_ff, 512)
        moe = None
        if self.moe is not None:
            moe = dataclasses.replace(
                self.moe,
                n_experts=min(self.moe.n_experts, 4),
                top_k=min(self.moe.top_k, 2),
                n_shared=min(self.moe.n_shared, 1),
                d_expert=min(self.moe.d_expert, 128) if self.moe.d_expert else 0,
            )
        ssm = None
        if self.ssm is not None:
            ssm = dataclasses.replace(self.ssm, d_state=16, head_dim=32, chunk=32)
        # shrink window for smoke seq lengths
        group = tuple(
            dataclasses.replace(s, window=min(s.window, 8) if s.window else None)
            for s in group
        )
        kw = dict(
            name=self.name + "-smoke",
            n_layers=len(group) * min(self.n_groups, 2 if len(group) == 1 else 1),
            d_model=d_model,
            n_heads=n_heads,
            n_kv_heads=n_kv,
            head_dim=hd,
            d_ff=d_ff,
            vocab_size=min(self.vocab_size, 512),
            group=group,
            moe=moe,
            ssm=ssm,
            mlp_gated=self.mlp_gated,
            tie_embeddings=self.tie_embeddings,
            rope_theta=self.rope_theta,
            mrope=self.mrope,
            n_enc_layers=min(self.n_enc_layers, 2),
            enc_seq=min(self.enc_seq, 16),
            n_prefix_embeds=min(self.n_prefix_embeds, 4),
            norm_eps=self.norm_eps,
            subquadratic=self.subquadratic,
            max_seq=256,
        )
        kw.update(overrides)
        return ModelConfig(**kw)
