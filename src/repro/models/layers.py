"""Model layers: RMSNorm, RoPE/M-RoPE, blocked GQA attention (+KV cache,
sliding window), gated/plain MLP, fine-grained MoE with shared experts, and
the Mamba2 SSD mixer (chunked scan for train/prefill, state update for
decode).

All functions are pure; parameters are nested dicts of arrays.  Activation
sharding constraints are applied through the `rules` object (see
repro.sharding.rules) and become no-ops when rules is None.

Memory discipline: attention over long sequences is computed in query blocks
via lax.scan (exact softmax per block row), bounding peak activation memory to
O(block · seq) instead of O(seq²) — required for prefill_32k to fit HBM.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .config import ModelConfig

Params = Dict[str, Any]

#: measurement mode: unroll every lax.scan so XLA cost_analysis (which counts
#: while-loop bodies ONCE) reports true whole-program costs.  Set only by the
#: dry-run cost extrapolation (launch/dryrun.lower_case_depth).
UNROLL_FOR_COSTS = False


def _init(key, shape, scale, dtype):
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def shard(rules, x, *axes):
    """Apply a logical-axis sharding constraint (no-op without rules)."""
    if rules is None:
        return x
    return rules.constrain(x, axes)


def shard_residual(rules, h):
    """Residual stream: batch-sharded, replicated over `model`.

    (A sequence-parallel residual variant was tried and REFUTED — §Perf
    iteration log: under remat, every backward recompute re-gathers the
    seq-sharded activations, tripling all-gather bytes.  Sequence
    parallelism stays confined to the attention internals where it removes
    genuine redundancy — see _seq_parallel_attn.)"""
    if rules is None:
        return h
    return rules.constrain(h, ("batch", None, None))


# --------------------------------------------------------------------------
# Norm
# --------------------------------------------------------------------------
def init_rmsnorm(d, dtype):
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(p, x, eps):
    h = x.astype(jnp.float32)
    h = h * jax.lax.rsqrt(jnp.mean(h * h, -1, keepdims=True) + eps)
    return (h * p["scale"].astype(jnp.float32)).astype(x.dtype)


# --------------------------------------------------------------------------
# RoPE / M-RoPE
# --------------------------------------------------------------------------
def rope_freqs(hd: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, hd // 2, dtype=jnp.float32) * 2.0 / hd))


def apply_rope(x: jax.Array, pos: jax.Array, theta: float, mrope: bool) -> jax.Array:
    """x: (B, S, H, hd).  pos: (B, S) or (3, B, S) for M-RoPE.

    M-RoPE (Qwen2-VL): the hd/2 frequency slots are split into 3 contiguous
    sections (temporal, height, width), each rotated by its own position
    component.  For text, all three components are equal and M-RoPE reduces
    to 1-D RoPE exactly.
    """
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)  # (hd/2,)
    if mrope:
        assert pos.ndim == 3
        nsec = hd // 2
        sec = np.array([nsec - 2 * (nsec // 3), nsec // 3, nsec // 3])
        comp_idx = np.repeat(np.arange(3), sec)              # static (hd/2,)
        p = pos.astype(jnp.float32)[comp_idx, :, :]          # (hd/2, B, S)
        ang = jnp.einsum("fbs,f->bsf", p, freqs)
    else:
        if pos.ndim == 3:
            pos = pos[0]
        ang = pos.astype(jnp.float32)[:, :, None] * freqs[None, None, :]  # (B,S,hd/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# Attention
# --------------------------------------------------------------------------
def init_attention(key, cfg: ModelConfig, dtype, cross: bool = False) -> Params:
    d, hd = cfg.d_model, cfg.hd
    nh, nkv = cfg.n_heads, cfg.n_kv_heads
    ks = jax.random.split(key, 4)
    s = d ** -0.5
    return {
        "wq": _init(ks[0], (d, nh, hd), s, dtype),
        "wk": _init(ks[1], (d, nkv, hd), s, dtype),
        "wv": _init(ks[2], (d, nkv, hd), s, dtype),
        "wo": _init(ks[3], (nh, hd, d), (nh * hd) ** -0.5, dtype),
    }



def _dus_seq(buf, val, pos):
    """dynamic_update_slice along axis 1 with uniformly-typed int32 indices
    (robust to jax_enable_x64 being flipped on by the core test suite)."""
    z = jnp.zeros((), jnp.int32)
    p = jnp.asarray(pos, jnp.int32)
    return jax.lax.dynamic_update_slice(buf, val, (z, p, z, z))


def _blocked_attn(q, k, v, mask_fn, block: int, rules, q_pos0=0,
                  window: Optional[int] = None):
    """Grouped-query blocked attention (no KV head materialization).

    q: (B, Sq, KVH, rep, hd);  k, v: (B, Sk, KVH, hd).
    Scans over query blocks; each block does an exact softmax over all keys
    with the (causal/window) mask from mask_fn(q_idx, k_idx).  q_pos0 offsets
    the query positions (sequence-parallel shards).

    Sliding-window layers (static `window`) slice each query block's K/V to
    the `block + window` stripe it can actually see instead of masking the
    full sequence — ~Sk/(block+window)× less attention compute/memory
    (§Perf gemma3 iteration 3).
    """
    B, Sq, KVH, rep, hd = q.shape
    Sk = k.shape[1]
    scale = hd ** -0.5
    block = min(block, Sq)
    while Sq % block:  # largest divisor of Sq ≤ requested block
        block -= 1
    n_blocks = Sq // block
    qb = q.reshape(B, n_blocks, block, KVH, rep, hd).transpose(1, 0, 2, 3, 4, 5)

    windowed = window is not None and Sk > block + window
    if windowed:
        width = block + window
        k_use = jnp.pad(k, ((0, 0), (window, 0), (0, 0), (0, 0)))
        v_use = jnp.pad(v, ((0, 0), (window, 0), (0, 0), (0, 0)))
    else:
        k_use, v_use = k, v

    def body(carry, args):
        i, qi = args  # qi: (B, block, KVH, rep, hd)
        q_idx = q_pos0 + i * block + jnp.arange(block)
        if windowed:
            # padded coords: original position p lives at index p + window
            start = q_pos0 + i * block
            kk = jax.lax.dynamic_slice_in_dim(k_use, start, width, 1)
            vv = jax.lax.dynamic_slice_in_dim(v_use, start, width, 1)
            k_idx = start - window + jnp.arange(width)  # original positions
        else:
            kk, vv = k_use, v_use
            k_idx = jnp.arange(Sk)
        s = jnp.einsum("bqgrd,bkgd->bgrqk", qi.astype(jnp.float32) * scale,
                       kk.astype(jnp.float32))
        m = mask_fn(q_idx[:, None], k_idx[None, :])  # (block, kv_width)
        if windowed:
            m = m & (k_idx[None, :] >= 0)  # exclude front-pad rows
        s = jnp.where(m[None, None, None], s, -1e30)
        pr = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bgrqk,bkgd->bqgrd", pr.astype(vv.dtype), vv)
        return carry, o

    if UNROLL_FOR_COSTS:
        outs = [body(None, (jnp.asarray(i), qb[i]))[1] for i in range(n_blocks)]
        ob = jnp.stack(outs)
    else:
        _, ob = jax.lax.scan(body, None, (jnp.arange(n_blocks), qb))
    out = ob.transpose(1, 0, 2, 3, 4, 5).reshape(B, Sq, KVH * rep, hd)
    return shard(rules, out, "batch", None, "heads", None)


def _seq_parallel_attn(qg, k, v, mask_fn, block: int, rules,
                       window: Optional[int] = None):
    """Context-parallel blocked attention over the `model` axis (§Perf).

    Used when n_heads doesn't divide the model axis (gemma3: 8 heads,
    llama4: 40, whisper: 12 vs model=16): instead of replicating the whole
    attention 16×, queries shard over `model` on the SEQUENCE dim; the
    (small, GQA) K/V are all-gathered once per layer.  Exact — masks are
    offset by each shard's query-position base.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    mesh = rules.mesh
    n_model = mesh.shape["model"]
    batch_ax = rules.amap["batch"]
    B, Sq, KVH, rep, hd = qg.shape
    Sq_loc = Sq // n_model

    def local(qg_l, k_l, v_l):
        kf = jax.lax.all_gather(k_l, "model", axis=1, tiled=True)
        vf = jax.lax.all_gather(v_l, "model", axis=1, tiled=True)
        off = jax.lax.axis_index("model") * Sq_loc
        return _blocked_attn(qg_l, kf, vf, mask_fn, block, None, q_pos0=off,
                             window=window)

    o = shard_map(
        local, mesh=mesh,
        in_specs=(P(batch_ax, "model", None, None, None),
                  P(batch_ax, "model", None, None),
                  P(batch_ax, "model", None, None)),
        out_specs=P(batch_ax, "model", None, None),
        check_rep=False,
    )(qg, k, v)
    return o


def attention(
    p: Params,
    x: jax.Array,
    cfg: ModelConfig,
    rules,
    pos: jax.Array,
    window: Optional[int] = None,
    cache: Optional[Tuple[jax.Array, jax.Array]] = None,
    cache_pos: Optional[jax.Array] = None,
    kv_override: Optional[Tuple[jax.Array, jax.Array]] = None,
    causal: bool = True,
    q_block: int = 1024,
) -> Tuple[jax.Array, Optional[Tuple[jax.Array, jax.Array]]]:
    """GQA attention.

    * train (cache=None): full-sequence blocked attention.
    * prefill (cache given, Sq>1): same, but also writes K/V into the cache
      (at `cache_pos`, or the last `window` tokens into the ring buffer for
      sliding-window layers) and returns the updated cache.
    * decode (cache given, Sq==1): single-token query attends to
      cache[: cache_pos+1] within the window; returns updated cache.
      Sliding-window layers keep a RING cache of size `window` — slot
      `pos % window` — so local layers never allocate the full sequence.
    * cross-attention: kv_override=(k, v) precomputed from encoder output
      (no RoPE, no mask).
    * window: static python int — sliding-window size (None ⇒ full).
    """
    B, Sq, D = x.shape
    nh, nkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    rep = nh // nkv

    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    q = shard(rules, q, "batch", None, "heads", None)

    if kv_override is not None:
        k, v = kv_override
        new_cache = None
        mask = lambda qi, ki: jnp.ones((qi.shape[0], ki.shape[1]), bool)
        qg = q.reshape(B, Sq, nkv, rep, hd)
        o = _blocked_attn(qg, k, v, mask, q_block, rules)
        out = jnp.einsum("bqhd,hdm->bqm", o, p["wo"])
        return shard(rules, out, "batch", None, None), None

    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    k = shard(rules, k, "batch", None, "kv_heads", None)
    v = shard(rules, v, "batch", None, "kv_heads", None)
    q = apply_rope(q, pos, cfg.rope_theta, cfg.mrope)
    k = apply_rope(k, pos, cfg.rope_theta, cfg.mrope)
    qg = q.reshape(B, Sq, nkv, rep, hd)

    new_cache = None
    if cache is not None:
        K, V = cache
        Sc = K.shape[1]  # ring size (== window) for sliding layers
        ring = window is not None and Sc <= window
        if Sq == 1:
            slot = cache_pos % Sc if ring else cache_pos
            K = _dus_seq(K, k.astype(K.dtype), slot)
            V = _dus_seq(V, v.astype(V.dtype), slot)
        else:  # prefill
            if Sq >= Sc:
                assert Sq % Sc == 0, (Sq, Sc)
                K = _dus_seq(K, k[:, Sq - Sc :].astype(K.dtype), 0)
                V = _dus_seq(V, v[:, Sq - Sc :].astype(V.dtype), 0)
            else:
                K = _dus_seq(K, k.astype(K.dtype), cache_pos)
                V = _dus_seq(V, v.astype(V.dtype), cache_pos)
        new_cache = (K, V)

    if cache is not None and Sq == 1:
        # decode: attend over the cache (possibly a ring buffer)
        K, V = new_cache
        Sk = K.shape[1]
        k_idx = jnp.arange(Sk)
        if window is not None and Sk <= window:
            # ring: slot s holds position cache_pos − ((cache_pos − s) mod Sk)
            pos_of = cache_pos - jnp.mod(cache_pos - k_idx, Sk)
            valid = pos_of >= 0
        else:
            valid = k_idx <= cache_pos
            if window is not None:
                valid = valid & (k_idx > cache_pos - window)
        s = jnp.einsum("bqgrd,bkgd->bgrqk", qg.astype(jnp.float32) * hd**-0.5,
                       K.astype(jnp.float32))
        s = jnp.where(valid[None, None, None, None, :], s, -1e30)
        pr = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bgrqk,bkgd->bqgrd", pr.astype(V.dtype), V)
        o = o.reshape(B, 1, nh, hd)
    else:
        if causal:
            if window is not None:
                mask = lambda qi, ki: (ki <= qi) & (ki > qi - window)
            else:
                mask = lambda qi, ki: ki <= qi
        else:
            mask = lambda qi, ki: jnp.ones((qi.shape[0], ki.shape[1]), bool)
        n_model = rules.mesh.shape["model"] if rules is not None else 1
        if (rules is not None and nh % n_model != 0 and Sq % n_model == 0
                and Sq >= 4 * n_model and kv_override is None):
            # heads unshardable → sequence-parallel attention (see above)
            o = _seq_parallel_attn(qg, k, v, mask, q_block, rules,
                                   window=window)
        else:
            o = _blocked_attn(qg, k, v, mask, q_block, rules, window=window)

    out = jnp.einsum("bqhd,hdm->bqm", o, p["wo"])
    return shard(rules, out, "batch", None, None), new_cache


# --------------------------------------------------------------------------
# MLP
# --------------------------------------------------------------------------
def init_mlp(key, d, f, gated, dtype):
    ks = jax.random.split(key, 3)
    if gated:
        return {
            "wi": _init(ks[0], (d, f), d**-0.5, dtype),
            "wg": _init(ks[1], (d, f), d**-0.5, dtype),
            "wo": _init(ks[2], (f, d), f**-0.5, dtype),
        }
    return {
        "wi": _init(ks[0], (d, f), d**-0.5, dtype),
        "wo": _init(ks[2], (f, d), f**-0.5, dtype),
    }


def mlp(p, x, gated, rules):
    h = jnp.einsum("bsd,df->bsf", x, p["wi"])
    if gated:
        g = jnp.einsum("bsd,df->bsf", x, p["wg"])
        h = jax.nn.silu(g) * h
    else:
        h = jax.nn.gelu(h)
    h = shard(rules, h, "batch", None, "ffn")
    return jnp.einsum("bsf,fd->bsd", h, p["wo"])


# --------------------------------------------------------------------------
# MoE (fine-grained, shared experts, top-k token-choice with capacity)
# --------------------------------------------------------------------------
def init_moe(key, cfg: ModelConfig, dtype):
    mc = cfg.moe
    d = cfg.d_model
    fe = mc.d_expert or cfg.d_ff
    ks = jax.random.split(key, 5)
    p = {
        "router": _init(ks[0], (d, mc.n_experts), d**-0.5, jnp.float32),
        "wi": _init(ks[1], (mc.n_experts, d, fe), d**-0.5, dtype),
        "wg": _init(ks[2], (mc.n_experts, d, fe), d**-0.5, dtype),
        "wo": _init(ks[3], (mc.n_experts, fe, d), fe**-0.5, dtype),
    }
    if mc.n_shared:
        p["shared"] = init_mlp(ks[4], d, fe * mc.n_shared, True, dtype)
    return p


def _moe_expert_parallel(p, x, cfg: ModelConfig, rules) -> Tuple[jax.Array, jax.Array]:
    """Expert-parallel MoE dispatch via shard_map (§Perf iteration 1).

    Key observation: activations are REPLICATED over the `model` axis in our
    sharding scheme, so every model shard already holds all of its data
    shard's tokens.  Each shard therefore routes locally to its E/n_model
    experts, computes, and the per-expert partial outputs combine with ONE
    (B_loc·S·D) psum over `model` — no token all-to-all / all-gather at all.
    Measured on deepseek-moe train_4k: collective bytes 405 GB → see
    README.md §EXPERIMENTS."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    mc = cfg.moe
    B, S, D = x.shape
    E, K = mc.n_experts, mc.top_k
    mesh = rules.mesh
    n_model = mesh.shape["model"]
    assert E % n_model == 0
    batch_ax = rules.amap["batch"]

    def local(xl, router, wi, wg, wo):
        Bl, Sl, _ = xl.shape
        T = Bl * Sl
        xt = xl.reshape(T, D)
        logits = xt.astype(jnp.float32) @ router
        probs = jax.nn.softmax(logits, -1)
        gate_vals, expert_ids = jax.lax.top_k(probs, K)
        gate_vals = gate_vals / jnp.clip(gate_vals.sum(-1, keepdims=True), 1e-9)

        me = probs.mean(0)
        ce = jnp.zeros((E,), jnp.float32).at[expert_ids.reshape(-1)].add(1.0) / (T * K)
        aux = E * jnp.sum(me * ce) * mc.router_aux_weight
        # per-data-shard (local) aux, averaged — standard local load-balance
        for ax in [a for a in mesh.axis_names if a != "model"]:
            aux = jax.lax.pmean(aux, ax)

        E_loc = wi.shape[0]
        my0 = jax.lax.axis_index("model") * E_loc
        cap = max(int(np.ceil(T * K / E * mc.capacity_factor)), K)

        # (E_loc, cap) token-index table — dispatch buffers stay E_loc·cap
        # sized instead of (T·K, D) (§Perf iteration 2: 12.8× smaller)
        flat_e = expert_ids.reshape(-1)
        local_e = jnp.where((flat_e >= my0) & (flat_e < my0 + E_loc),
                            flat_e - my0, E_loc)          # E_loc = not mine
        order = jnp.argsort(local_e, stable=True)
        sorted_e = local_e[order]
        pos = jnp.arange(T * K) - jnp.searchsorted(sorted_e, sorted_e, "left")
        keep = (sorted_e < E_loc) & (pos < cap)
        token_of = order // K
        e_cl = jnp.where(keep, sorted_e, 0)
        p_cl = jnp.where(keep, pos, cap)                  # cap = spill column
        idx_tbl = jnp.full((E_loc, cap + 1), T, jnp.int32).at[e_cl, p_cl].set(
            jnp.where(keep, token_of, T).astype(jnp.int32))[:, :cap]
        gate_tbl = jnp.zeros((E_loc, cap + 1), jnp.float32).at[e_cl, p_cl].set(
            jnp.where(keep, gate_vals.reshape(-1)[order], 0.0))[:, :cap]

        xt_pad = jnp.concatenate([xt, jnp.zeros((1, D), xt.dtype)], 0)
        xe = xt_pad[idx_tbl]                              # (E_loc, cap, D)
        h = jnp.einsum("ecd,edf->ecf", xe, wi)
        g = jnp.einsum("ecd,edf->ecf", xe, wg)
        ye = jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * h, wo)
        ye = ye * gate_tbl[:, :, None].astype(ye.dtype)
        out = jnp.zeros((T + 1, D), xl.dtype).at[idx_tbl.reshape(-1)].add(
            ye.reshape(E_loc * cap, D))[:T]
        out = jax.lax.psum(out, "model")   # combine expert partials
        return out.reshape(Bl, Sl, D), aux

    other_axes = [a for a in mesh.axis_names if a != "model"]
    bspec = P(batch_ax, None, None)
    out, aux = shard_map(
        local, mesh=mesh,
        in_specs=(bspec, P(), P("model", None, None), P("model", None, None),
                  P("model", None, None)),
        out_specs=(bspec, P()),
        check_rep=False,
    )(x, p["router"], p["wi"], p["wg"], p["wo"])

    if mc.n_shared:
        out = out + mlp(p["shared"], x, True, rules)
    return out, aux


def moe(p, x, cfg: ModelConfig, rules) -> Tuple[jax.Array, jax.Array]:
    """Token-choice top-k routing with per-expert capacity via sort-based
    dispatch (gather → grouped einsum → scatter-add).  Experts are sharded
    over the `model` axis (expert parallelism).  With sharding rules active,
    uses the expert-parallel shard_map path (zero dispatch collectives);
    without rules (single-device tests), the global argsort path below.
    Returns (out, aux_loss)."""
    if (rules is not None and cfg.moe.n_experts % rules.mesh.shape["model"] == 0
            and x.shape[0] * x.shape[1] >= 4096):
        # expert-parallel dispatch pays for its per-layer expert-weight
        # resharding only at prefill/train token counts; decode (1 token/seq)
        # keeps the global path (measured: llama4 decode coll 1.7→4.4 GB
        # regression with shard_map — gated out, §Perf)
        return _moe_expert_parallel(p, x, cfg, rules)
    mc = cfg.moe
    B, S, D = x.shape
    E, K = mc.n_experts, mc.top_k
    T = B * S
    xt = x.reshape(T, D)

    logits = (xt.astype(jnp.float32) @ p["router"])  # (T, E)
    probs = jax.nn.softmax(logits, -1)
    gate_vals, expert_ids = jax.lax.top_k(probs, K)   # (T, K)
    gate_vals = gate_vals / jnp.clip(gate_vals.sum(-1, keepdims=True), 1e-9)

    # load-balance aux loss (Switch-style)
    me = probs.mean(0)
    ce = jnp.zeros((E,), jnp.float32).at[expert_ids.reshape(-1)].add(1.0) / (T * K)
    aux = E * jnp.sum(me * ce) * mc.router_aux_weight

    cap = int(np.ceil(T * K / E * mc.capacity_factor))
    cap = max(cap, K)
    flat_e = expert_ids.reshape(-1)                   # (T*K,)
    # stable sort by expert id → contiguous expert groups
    order = jnp.argsort(flat_e, stable=True)          # (T*K,)
    sorted_e = flat_e[order]
    # position within expert group
    pos_in_e = jnp.arange(T * K) - jnp.searchsorted(sorted_e, sorted_e, side="left")
    keep = pos_in_e < cap
    token_of = order // K                              # source token per slot
    slot = sorted_e * cap + pos_in_e                   # target slot in (E*cap)
    slot = jnp.where(keep, slot, E * cap)              # overflow bucket

    gathered = jnp.zeros((E * cap + 1, D), x.dtype).at[slot].set(xt[token_of])
    xe = gathered[:-1].reshape(E, cap, D)
    xe = shard(rules, xe, "experts", None, None)

    h = jnp.einsum("ecd,edf->ecf", xe, p["wi"])
    g = jnp.einsum("ecd,edf->ecf", xe, p["wg"])
    h = jax.nn.silu(g) * h
    ye = jnp.einsum("ecf,efd->ecd", h, p["wo"])
    ye = shard(rules, ye, "experts", None, None)

    yflat = ye.reshape(E * cap, D)
    gates_sorted = gate_vals.reshape(-1)[order]
    contrib = jnp.where(keep[:, None], yflat[jnp.clip(slot, 0, E * cap - 1)]
                        * gates_sorted[:, None].astype(x.dtype), 0.0)
    out = jnp.zeros((T, D), x.dtype).at[token_of].add(contrib)

    if mc.n_shared:
        out = out + mlp(p["shared"], xt[None], True, rules)[0]
    return out.reshape(B, S, D), aux


# --------------------------------------------------------------------------
# Mamba2 (SSD)
# --------------------------------------------------------------------------
def init_mamba(key, cfg: ModelConfig, dtype):
    sc = cfg.ssm
    d = cfg.d_model
    di = cfg.d_inner
    nh = cfg.n_ssm_heads
    conv_dim = di + 2 * sc.d_state
    ks = jax.random.split(key, 5)
    return {
        "in_proj": _init(ks[0], (d, 2 * di + 2 * sc.d_state + nh), d**-0.5, dtype),
        "conv_w": _init(ks[1], (sc.conv_width, conv_dim), 0.5, dtype),
        "A_log": jnp.zeros((nh,), jnp.float32) + jnp.log(jnp.arange(1, nh + 1, dtype=jnp.float32)),
        "D": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "norm": init_rmsnorm(di, dtype),
        "out_proj": _init(ks[4], (di, d), di**-0.5, dtype),
    }


def _ssd_chunked(xh, dt, A, B_, C, chunk):
    """Mamba2 SSD forward (training/prefill).

    xh: (B, S, H, hd)   dt: (B, S, H)   A: (H,) < 0
    B_, C: (B, S, N)    (single SSM group shared across heads)
    Returns y: (B, S, H, hd) and final state (B, H, hd, N).

    Chunked state-space-duality: within a chunk, a masked quadratic form
    (MXU-friendly matmuls); across chunks, a sequential lax.scan over
    cumulative decay states.
    """
    Bsz, S, H, hd = xh.shape
    N = B_.shape[-1]
    nchunks = S // chunk
    assert S % chunk == 0, (S, chunk)

    xh = xh.reshape(Bsz, nchunks, chunk, H, hd)
    dt = dt.reshape(Bsz, nchunks, chunk, H)
    Bc = B_.reshape(Bsz, nchunks, chunk, N)
    Cc = C.reshape(Bsz, nchunks, chunk, N)

    dA = dt * A[None, None, None, :]                 # (B, n, c, H) ≤ 0
    cs = jnp.cumsum(dA, axis=2)                      # cumulative log-decay
    seg = cs[:, :, :, None, :] - cs[:, :, None, :, :]   # (B,n,c_q,c_k,H)
    idx = jnp.arange(chunk)
    causal = idx[:, None] >= idx[None, :]
    # mask BEFORE exp: exp of a masked huge positive would poison gradients
    seg = jnp.where(causal[None, None, :, :, None], seg, -1e30)
    L = jnp.exp(seg)

    # intra-chunk: y_intra[q] = Σ_k L[q,k] (C_q·B_k) dt_k x_k
    CB = jnp.einsum("bnqs,bnks->bnqk", Cc, Bc)       # (B,n,c,c)
    M = CB[:, :, :, :, None] * L                     # (B,n,q,k,H)
    y_intra = jnp.einsum("bnqkh,bnkh,bnkhd->bnqhd", M, dt, xh)

    # chunk summary states: S_n = Σ_k exp(cs_end − cs_k) dt_k B_k ⊗ x_k
    decay_to_end = jnp.exp(cs[:, :, -1:, :] - cs)    # (B,n,c,H)
    states = jnp.einsum("bnkh,bnkh,bnks,bnkhd->bnhds",
                        decay_to_end, dt, Bc, xh)    # (B,n,H,hd,N)
    chunk_decay = jnp.exp(cs[:, :, -1, :])           # (B,n,H)

    def scan_fn(s_prev, inp):
        st, dec = inp                                 # (B,H,hd,N), (B,H)
        s_new = s_prev * dec[:, :, None, None] + st
        return s_new, s_prev

    s0 = jnp.zeros((Bsz, H, hd, N), xh.dtype)
    xs_states = states.transpose(1, 0, 2, 3, 4)
    xs_decay = chunk_decay.transpose(1, 0, 2)
    if UNROLL_FOR_COSTS:
        s, s_ins = s0, []
        for i in range(nchunks):
            s, prev = scan_fn(s, (xs_states[i], xs_decay[i]))
            s_ins.append(prev)
        s_final, s_in = s, jnp.stack(s_ins)
    else:
        s_final, s_in = jax.lax.scan(scan_fn, s0, (xs_states, xs_decay))
    s_in = s_in.transpose(1, 0, 2, 3, 4)             # state entering each chunk

    # inter-chunk: y_inter[q] = exp(cs_q) C_q · S_in
    decay_from_start = jnp.exp(cs)                   # (B,n,c,H)
    y_inter = jnp.einsum("bnqs,bnhds,bnqh->bnqhd", Cc, s_in, decay_from_start)

    y = (y_intra + y_inter).reshape(Bsz, S, H, hd)
    return y, s_final


def mamba(
    p: Params,
    x: jax.Array,
    cfg: ModelConfig,
    rules,
    cache: Optional[Dict[str, jax.Array]] = None,
) -> Tuple[jax.Array, Optional[Dict[str, jax.Array]]]:
    """Mamba2 block.  cache = {"conv": (B, W-1, conv_dim), "ssm": (B,H,hd,N)}."""
    sc = cfg.ssm
    B, S, D = x.shape
    di = cfg.d_inner
    H = cfg.n_ssm_heads
    hd = sc.head_dim
    N = sc.d_state

    zxbcdt = jnp.einsum("bsd,de->bse", x, p["in_proj"])
    z, xraw, Bmat, Cmat, dt = jnp.split(
        zxbcdt, [di, 2 * di, 2 * di + N, 2 * di + 2 * N], axis=-1
    )
    conv_in = jnp.concatenate([xraw, Bmat, Cmat], -1)  # (B,S,conv_dim)
    conv_dim = conv_in.shape[-1]

    if cache is None:
        pad = jnp.zeros((B, sc.conv_width - 1, conv_dim), conv_in.dtype)
        seq = jnp.concatenate([pad, conv_in], 1)
        new_conv_state = seq[:, -(sc.conv_width - 1):, :] if sc.conv_width > 1 else None
    else:
        seq = jnp.concatenate([cache["conv"].astype(conv_in.dtype), conv_in], 1)
        new_conv_state = seq[:, -(sc.conv_width - 1):, :]

    # causal depthwise conv, width W
    conv = sum(
        seq[:, i : i + S, :] * p["conv_w"][i][None, None, :]
        for i in range(sc.conv_width)
    )
    conv = jax.nn.silu(conv)
    xc, Bc, Cc = jnp.split(conv, [di, di + N], axis=-1)
    xh = xc.reshape(B, S, H, hd)
    xh = shard(rules, xh, "batch", None, "heads", None)

    A = -jnp.exp(p["A_log"])                            # (H,)
    dt_s = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"][None, None, :])
    dt_s = shard(rules, dt_s, "batch", None, "heads")

    if cache is None or S > 1:
        chunk = min(sc.chunk, S)
        y, s_final = _ssd_chunked(
            xh.astype(jnp.float32), dt_s, A, Bc.astype(jnp.float32),
            Cc.astype(jnp.float32), chunk
        )
    else:
        # single-token decode: s = exp(dtA) s + dt B ⊗ x ; y = C·s
        s_prev = cache["ssm"].astype(jnp.float32)       # (B,H,hd,N)
        dec = jnp.exp(dt_s[:, 0] * A[None, :])          # (B,H)
        upd = jnp.einsum("bh,bn,bhd->bhdn", dt_s[:, 0], Bc[:, 0].astype(jnp.float32),
                         xh[:, 0].astype(jnp.float32))
        s_final = s_prev * dec[:, :, None, None] + upd
        y = jnp.einsum("bn,bhdn->bhd", Cc[:, 0].astype(jnp.float32), s_final)[:, None]
        y = y.reshape(B, 1, H, hd)

    y = y + xh.astype(jnp.float32) * p["D"][None, None, :, None]
    y = y.reshape(B, S, di).astype(x.dtype)
    y = rmsnorm(p["norm"], y * jax.nn.silu(z), cfg.norm_eps)
    out = jnp.einsum("bsd,de->bse", y, p["out_proj"])

    new_cache = None
    if cache is not None:
        new_cache = {
            "conv": (new_conv_state if new_conv_state is not None
                     else jnp.zeros((B, max(sc.conv_width - 1, 1), conv_dim), x.dtype)).astype(cache["conv"].dtype),
            "ssm": s_final.astype(jnp.float32),
        }
    return shard(rules, out, "batch", None, None), new_cache
