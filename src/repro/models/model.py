"""Model assembly: parameter init, train/prefill/decode forwards.

Layers are stacked by *group*: identical `LayerSpec` groups scan over a
leading `n_groups` axis (small HLO, fast compile, remat-friendly); the
heterogeneity inside a group (e.g. Jamba's 1 attn : 7 mamba, Llama4's
dense/MoE interleave) is unrolled inside the scanned body.

Encoder–decoder (Whisper): encoder is a full-attention scan over stub frame
embeddings; every decoder layer adds cross-attention against the encoder
output.  VLM (Qwen2-VL): stub patch embeddings are concatenated in front of
the token embeddings and M-RoPE positions are used.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import layers as L
from .config import LayerSpec, ModelConfig

Params = Dict[str, Any]


# --------------------------------------------------------------------------
# Init
# --------------------------------------------------------------------------
def _init_layer(key, spec: LayerSpec, cfg: ModelConfig, dtype) -> Params:
    ks = jax.random.split(key, 4)
    p: Params = {"ln1": L.init_rmsnorm(cfg.d_model, dtype)}
    if spec.mixer == "attn":
        p["attn"] = L.init_attention(ks[0], cfg, dtype)
    else:
        p["mamba"] = L.init_mamba(ks[0], cfg, dtype)
    if cfg.n_enc_layers and spec.mixer == "attn":
        p["ln_x"] = L.init_rmsnorm(cfg.d_model, dtype)
        p["xattn"] = L.init_attention(ks[2], cfg, dtype, cross=True)
    if spec.ffn == "mlp":
        p["ln2"] = L.init_rmsnorm(cfg.d_model, dtype)
        p["mlp"] = L.init_mlp(ks[1], cfg.d_model, cfg.d_ff, cfg.mlp_gated, dtype)
    elif spec.ffn == "moe":
        p["ln2"] = L.init_rmsnorm(cfg.d_model, dtype)
        p["moe"] = L.init_moe(ks[1], cfg, dtype)
    return p


def _init_group(key, cfg: ModelConfig, dtype) -> Params:
    ks = jax.random.split(key, len(cfg.group))
    return {f"l{i}": _init_layer(ks[i], spec, cfg, dtype)
            for i, spec in enumerate(cfg.group)}


def init_params(key, cfg: ModelConfig, dtype=jnp.bfloat16) -> Params:
    ks = jax.random.split(key, 6)
    p: Params = {
        "embed": L._init(ks[0], (cfg.padded_vocab, cfg.d_model), 0.02, dtype),
        "final_norm": L.init_rmsnorm(cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        p["unembed"] = L._init(ks[1], (cfg.d_model, cfg.padded_vocab),
                               cfg.d_model**-0.5, dtype)
    # stacked decoder groups: every leaf gets a leading n_groups axis
    gkeys = jax.random.split(ks[2], cfg.n_groups)
    groups = [_init_group(k, cfg, dtype) for k in gkeys]
    p["layers"] = jax.tree.map(lambda *xs: jnp.stack(xs), *groups)
    if cfg.n_enc_layers:
        ekeys = jax.random.split(ks[3], cfg.n_enc_layers)
        enc_spec = LayerSpec(mixer="attn", ffn="mlp", window=None)
        enc_cfg = cfg  # same width
        encs = []
        for k in ekeys:
            kk = jax.random.split(k, 2)
            encs.append({
                "ln1": L.init_rmsnorm(cfg.d_model, dtype),
                "attn": L.init_attention(kk[0], cfg, dtype),
                "ln2": L.init_rmsnorm(cfg.d_model, dtype),
                "mlp": L.init_mlp(kk[1], cfg.d_model, cfg.d_ff, cfg.mlp_gated, dtype),
            })
        p["encoder"] = jax.tree.map(lambda *xs: jnp.stack(xs), *encs)
        p["enc_pos"] = L._init(ks[4], (cfg.enc_seq, cfg.d_model), 0.02, dtype)
        p["enc_norm"] = L.init_rmsnorm(cfg.d_model, dtype)
    return p


def param_shapes(cfg: ModelConfig, dtype=jnp.bfloat16):
    return jax.eval_shape(lambda k: init_params(k, cfg, dtype), jax.random.PRNGKey(0))


# --------------------------------------------------------------------------
# Cache
# --------------------------------------------------------------------------
def init_cache(cfg: ModelConfig, batch: int, max_seq: int, dtype=jnp.bfloat16) -> Params:
    """Per-group stacked decode caches (leading axis n_groups)."""
    def layer_cache(spec: LayerSpec):
        if spec.mixer == "attn":
            s = min(max_seq, spec.window) if spec.window else max_seq
            kvshape = (cfg.n_groups, batch, s, cfg.n_kv_heads, cfg.hd)
            return {"k": jnp.zeros(kvshape, dtype), "v": jnp.zeros(kvshape, dtype)}
        sc = cfg.ssm
        conv_dim = cfg.d_inner + 2 * sc.d_state
        return {
            "conv": jnp.zeros((cfg.n_groups, batch, sc.conv_width - 1, conv_dim), dtype),
            "ssm": jnp.zeros((cfg.n_groups, batch, cfg.n_ssm_heads, sc.head_dim,
                              sc.d_state), jnp.float32),
        }
    return {f"l{i}": layer_cache(s) for i, s in enumerate(cfg.group)}


def cache_shapes(cfg: ModelConfig, batch: int, max_seq: int, dtype=jnp.bfloat16):
    return jax.eval_shape(lambda: init_cache(cfg, batch, max_seq, dtype))


# --------------------------------------------------------------------------
# Forward
# --------------------------------------------------------------------------
def _apply_layer(
    lp: Params,
    spec: LayerSpec,
    cfg: ModelConfig,
    rules,
    h: jax.Array,
    pos: jax.Array,
    cache: Optional[Params],
    cache_pos,
    enc_out: Optional[jax.Array],
) -> Tuple[jax.Array, Optional[Params], jax.Array]:
    aux = jnp.zeros((), jnp.float32)
    x = L.rmsnorm(lp["ln1"], h, cfg.norm_eps)
    if spec.mixer == "attn":
        kv = (cache["k"], cache["v"]) if cache is not None else None
        out, new_kv = L.attention(
            lp["attn"], x, cfg, rules, pos,
            window=spec.window,
            cache=kv, cache_pos=cache_pos,
        )
        new_cache = {"k": new_kv[0], "v": new_kv[1]} if (cache is not None) else None
    else:
        out, new_state = L.mamba(lp["mamba"], x, cfg, rules,
                                 cache=cache if cache is not None else None)
        new_cache = new_state if cache is not None else None
    h = h + out

    if enc_out is not None and spec.mixer == "attn" and "xattn" in lp:
        xq = L.rmsnorm(lp["ln_x"], h, cfg.norm_eps)
        k = jnp.einsum("bsd,dhk->bshk", enc_out, lp["xattn"]["wk"])
        v = jnp.einsum("bsd,dhk->bshk", enc_out, lp["xattn"]["wv"])
        out, _ = L.attention(lp["xattn"], xq, cfg, rules, pos,
                             kv_override=(k, v), causal=False)
        h = h + out

    if spec.ffn == "mlp":
        x2 = L.rmsnorm(lp["ln2"], h, cfg.norm_eps)
        h = h + L.mlp(lp["mlp"], x2, cfg.mlp_gated, rules)
    elif spec.ffn == "moe":
        x2 = L.rmsnorm(lp["ln2"], h, cfg.norm_eps)
        out, a = L.moe(lp["moe"], x2, cfg, rules)
        h = h + out
        aux = aux + a
    return h, new_cache, aux


def _run_encoder(p: Params, cfg: ModelConfig, rules, frames: jax.Array) -> jax.Array:
    """Whisper-style encoder over stub frame embeddings (B, enc_seq, D)."""
    h = frames + p["enc_pos"][None].astype(frames.dtype)
    pos = jnp.broadcast_to(jnp.arange(frames.shape[1], dtype=jnp.int32)[None],
                           frames.shape[:2])

    def body(h, ep):
        x = L.rmsnorm(ep["ln1"], h, cfg.norm_eps)
        out, _ = L.attention(ep["attn"], x, cfg, rules, pos, causal=False)
        h = h + out
        x2 = L.rmsnorm(ep["ln2"], h, cfg.norm_eps)
        h = h + L.mlp(ep["mlp"], x2, cfg.mlp_gated, rules)
        return h, None

    if L.UNROLL_FOR_COSTS:
        n_enc = jax.tree.leaves(p["encoder"])[0].shape[0]
        for i in range(n_enc):
            h, _ = body(h, jax.tree.map(lambda a: a[i], p["encoder"]))
    else:
        h, _ = jax.lax.scan(body, h, p["encoder"])
    return L.rmsnorm(p["enc_norm"], h, cfg.norm_eps)


def forward(
    p: Params,
    cfg: ModelConfig,
    rules,
    tokens: jax.Array,                    # (B, S) int32
    cache: Optional[Params] = None,       # stacked decode caches
    cache_pos=None,                       # scalar int32 (decode)
    prefix_embeds: Optional[jax.Array] = None,  # (B, P, D) VLM stub
    frames: Optional[jax.Array] = None,   # (B, enc_seq, D) audio stub
    remat: bool = True,
    return_hidden: bool = False,          # skip unembed (fused-CE train path)
) -> Tuple[jax.Array, Optional[Params], jax.Array]:
    """Returns (logits, new_cache, aux_loss).

    Modes: train (cache=None), prefill (cache given, S>1, cache_pos=0),
    decode (cache given, S==1, cache_pos=scalar position).
    """
    B, S = tokens.shape
    h = jnp.take(p["embed"], tokens, axis=0)
    if cfg.tie_embeddings:
        h = h * jnp.asarray(cfg.d_model**0.5, h.dtype)
    if prefix_embeds is not None:
        h = jnp.concatenate([prefix_embeds.astype(h.dtype), h], axis=1)
    h = L.shard_residual(rules, h)
    Sfull = h.shape[1]
    decode = cache is not None and Sfull == 1

    if decode:
        pos = jnp.broadcast_to(jnp.asarray(cache_pos, jnp.int32), (B, 1))
    else:
        base = jnp.arange(Sfull, dtype=jnp.int32)[None]
        if cache_pos is not None:
            base = base + jnp.asarray(cache_pos, jnp.int32)
        pos = jnp.broadcast_to(base, (B, Sfull))
    if cfg.mrope:
        pos = jnp.broadcast_to(pos[None], (3,) + pos.shape)

    enc_out = _run_encoder(p, cfg, rules, frames) if cfg.n_enc_layers else None

    def group_body(h, xs):
        gp, gcache = xs
        new_caches = {}
        aux_total = jnp.zeros((), jnp.float32)
        for i, spec in enumerate(cfg.group):
            lp = gp[f"l{i}"]
            lc = gcache[f"l{i}"] if gcache is not None else None
            h, nc, aux = _apply_layer(lp, spec, cfg, rules, h, pos, lc,
                                      cache_pos, enc_out)
            h = L.shard_residual(rules, h)
            new_caches[f"l{i}"] = nc
            aux_total = aux_total + aux
        return h, (new_caches if gcache is not None else None, aux_total)

    body = group_body
    if remat and cache is None:
        body = jax.checkpoint(group_body,
                              policy=jax.checkpoint_policies.nothing_saveable)

    if L.UNROLL_FOR_COSTS:
        auxs_l, caches_l = [], []
        for gi in range(cfg.n_groups):
            gp = jax.tree.map(lambda a: a[gi], p["layers"])
            gc = (jax.tree.map(lambda a: a[gi], cache)
                  if cache is not None else None)
            h, (nc, aux_g) = body(h, (gp, gc))
            auxs_l.append(aux_g)
            caches_l.append(nc)
        auxs = jnp.stack(auxs_l)
        new_cache = (jax.tree.map(lambda *xs: jnp.stack(xs), *caches_l)
                     if cache is not None else None)
    elif cache is not None:
        h, (new_cache, auxs) = jax.lax.scan(body, h, (p["layers"], cache))
    else:
        h, (_, auxs) = jax.lax.scan(body, h, (p["layers"], None))
        new_cache = None

    if cache is not None and not decode:
        h = h[:, -1:, :]  # prefill: only last-position logits are needed
    h = L.rmsnorm(p["final_norm"], h, cfg.norm_eps)
    if return_hidden:
        return h, new_cache, jnp.sum(auxs)
    unemb = p["embed"].T if cfg.tie_embeddings else p["unembed"]
    logits = jnp.einsum("bsd,dv->bsv", h, unemb)
    logits = L.shard(rules, logits, "batch", None, "vocab")
    if cfg.padded_vocab != cfg.vocab_size:
        # mask padding slots so softmax/argmax never see them
        pad = jnp.arange(cfg.padded_vocab) >= cfg.vocab_size
        logits = logits + jnp.where(pad, -1e30, 0.0).astype(logits.dtype)
    return logits, new_cache, jnp.sum(auxs)
