from .config import LayerSpec, ModelConfig, MoEConfig, SSMConfig  # noqa: F401
from . import layers, model, steps  # noqa: F401
